//! Quickstart: the smallest end-to-end tour of the stack.
//!
//! 1. builds a tiny DNS ground truth in-process (seconds);
//! 2. loads the AOT-compiled policy artifact via PJRT;
//! 3. runs one LES episode where the (untrained) policy controls the
//!    per-element Smagorinsky coefficient;
//! 4. prints the reward trace and the spectrum vs the DNS target.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` once beforehand).

use anyhow::Result;
use relexi::config::{CaseConfig, RunConfig};
use relexi::rl::{gaussian, CfdEnv, LesEnv};
use relexi::runtime::{PolicyRuntime, Registry, Runtime};
use relexi::solver::dns::{generate, TruthParams};
use relexi::util::bench::Table;
use relexi::util::Rng;
use std::path::Path;
use std::sync::Arc;

fn main() -> Result<()> {
    // A reduced 24-DOF-style case: 12^3 LES (2^3 elements of 6^3 points)
    // against a 24^3 DNS, so the whole example runs in ~a minute.
    let mut cfg = RunConfig::default();
    cfg.case = CaseConfig {
        name: "quickstart".into(),
        n: 5,
        elems_per_dir: 2,
        k_max: 4,
        alpha: 0.4,
    };
    cfg.solver.t_end = 1.0; // 10 actions
    cfg.solver.dns_points = 24;

    println!("[1/4] generating a small DNS ground truth (24^3)...");
    let truth = Arc::new(generate(
        &TruthParams {
            n_dns: cfg.solver.dns_points,
            n_les: cfg.case.points_per_dir(),
            nu: cfg.solver.nu,
            ke_target: cfg.solver.ke_target,
            spinup_time: 2.0,
            n_states: 4,
            sample_interval: 0.4,
            seed: 7,
        },
        |i, n| println!("      DNS sample {i}/{n}"),
    ));

    println!("[2/4] loading the AOT policy artifact via PJRT...");
    let rt = Runtime::cpu()?;
    let reg = Registry::open(Path::new("artifacts"))?;
    let policy = PolicyRuntime::load(&rt, &reg, cfg.case.n)?;
    let theta = reg.initial_params(cfg.case.n)?;
    println!("      platform: {}, {} parameters", rt.platform(), theta.len());

    println!("[3/4] running one RL-controlled LES episode...");
    let mut env = LesEnv::new(&cfg.case, &cfg.solver, truth.clone())?;
    let mut rng = Rng::new(2022);
    let mut obs = env.reset(&mut rng, false);
    let n_elems = env.n_elems();
    let mut rewards = Vec::new();
    loop {
        let out = policy.forward(&theta, &obs, n_elems)?;
        let act = gaussian::sample(&out.mean, out.log_std, &mut rng);
        let step = env.step(&act.iter().map(|&a| a as f64).collect::<Vec<_>>());
        rewards.push(step.reward);
        println!(
            "      t={:.1}  reward {:+.4}  spectrum error {:.4}",
            env.solver.t, step.reward, step.spec_error
        );
        if step.done {
            break;
        }
        obs = env.observe();
    }

    println!("[4/4] final spectrum vs DNS target:");
    let spec = env.spectrum();
    let mut t = Table::new(&["k", "E_LES", "E_DNS", "ratio"]);
    for k in 1..=cfg.case.k_max {
        t.row(vec![
            k.to_string(),
            format!("{:.4e}", spec[k]),
            format!("{:.4e}", truth.mean_spectrum[k]),
            format!("{:.3}", spec[k] / truth.mean_spectrum[k]),
        ]);
    }
    t.print("Quickstart spectrum");
    println!(
        "mean reward over the episode: {:+.4} (untrained policy)",
        rewards.iter().sum::<f64>() / rewards.len() as f64
    );
    println!("\nNext: examples/train_hit.rs trains this policy with PPO.");
    Ok(())
}
