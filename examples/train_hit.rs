//! End-to-end training driver (the repo's headline validation run):
//! trains the RL turbulence model on the HIT test case with the full
//! three-layer stack — Rust coordinator + orchestrator + parallel LES env
//! workers, compiled JAX/Pallas policy and PPO train step via PJRT.
//!
//! Default configuration is a reduced-but-real version of the paper's
//! 24-DOF run (Table 1 / Fig. 5): the real 24^3 LES with 4^3 elements,
//! shorter episodes (t_end 2.0 -> 20 actions) and fewer envs/iterations so
//! the run completes in tens of minutes on a workstation.  Every reduction
//! is a CLI flag away from the paper's values:
//!
//! ```text
//! cargo run --release --example train_hit -- \
//!     --truth runs/truth_24dof.bin --envs 16 --iterations 50 --t-end 2.0
//! ```
//!
//! The run is recorded in EXPERIMENTS.md (experiment F5).

use anyhow::{Context, Result};
use relexi::config::RunConfig;
use relexi::coordinator::{eval_baseline, eval_policy, MetricsLog, TrainingLoop};
use relexi::runtime::Trainer; // `lp.trainer` is a `Box<dyn Trainer>`
use relexi::solver::dns::Truth;
use relexi::util::bench::Table;
use relexi::util::cli::Args;
use std::path::Path;
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let mut cfg = RunConfig::default();
    cfg.rl.n_envs = args.get_parse("envs", 8usize)?;
    cfg.rl.iterations = args.get_parse("iterations", 30usize)?;
    cfg.rl.eval_every = args.get_parse("eval-every", 5usize)?;
    cfg.rl.minibatch = args.get_parse("minibatch", 256usize)?;
    cfg.solver.t_end = args.get_parse("t-end", 2.0f64)?;
    cfg.rl.seed = args.get_parse("seed", 2022u64)?;
    cfg.out_dir = args.get_or("out", "runs/train_hit");
    cfg.validate()?;

    let truth_path = args.get_or("truth", "runs/truth_24dof.bin");
    let truth = Arc::new(Truth::load(Path::new(&truth_path)).with_context(|| {
        format!("load {truth_path} — generate it first: ./target/release/relexi gen-truth")
    })?);

    println!(
        "train_hit: {} envs, {} iterations, {} actions/episode, {} elements",
        cfg.rl.n_envs,
        cfg.rl.iterations,
        cfg.steps_per_episode(),
        cfg.case.total_elems()
    );

    // Baselines once, for the final comparison (Fig. 5c).
    println!("evaluating baselines on the held-out test state...");
    let smag = eval_baseline(&cfg, &truth, cfg.solver.smagorinsky_cs)?;
    let implicit = eval_baseline(&cfg, &truth, 0.0)?;
    println!(
        "  Smagorinsky return {:+.4} | implicit return {:+.4}",
        smag.normalized_return, implicit.normalized_return
    );

    std::fs::create_dir_all(&cfg.out_dir)?;
    let mut log = MetricsLog::with_csv(&Path::new(&cfg.out_dir).join("training.csv"))?;
    let mut lp = TrainingLoop::new(cfg.clone(), truth.clone())?;

    // Untrained policy benchmark (Fig. 5d "initial model" histogram).
    let initial = eval_policy(&cfg, &truth, &lp.policy, lp.trainer.theta(), None)?;
    println!("  untrained policy return {:+.4}", initial.normalized_return);

    lp.run(&mut log)?;

    // Final evaluation: the Fig. 5 set.
    let trained = eval_policy(&cfg, &truth, &lp.policy, lp.trainer.theta(), None)?;

    let mut t = Table::new(&["model", "normalized test return"]);
    t.row(vec!["RL (trained)".into(), format!("{:+.4}", trained.normalized_return)]);
    t.row(vec!["RL (untrained)".into(), format!("{:+.4}", initial.normalized_return)]);
    t.row(vec!["Smagorinsky 0.17".into(), format!("{:+.4}", smag.normalized_return)]);
    t.row(vec!["implicit (Cs=0)".into(), format!("{:+.4}", implicit.normalized_return)]);
    t.print("Final comparison (paper Fig. 5)");

    let mut s = Table::new(&["k", "E_DNS", "E_RL", "E_Smag", "E_impl"]);
    for k in 1..=cfg.case.k_max {
        s.row(vec![
            k.to_string(),
            format!("{:.3e}", truth.mean_spectrum[k]),
            format!("{:.3e}", trained.final_spectrum[k]),
            format!("{:.3e}", smag.final_spectrum[k]),
            format!("{:.3e}", implicit.final_spectrum[k]),
        ]);
    }
    s.print("Spectra at t_end on the test state (Fig. 5c)");

    println!("\ntrained-policy Cs distribution (Fig. 5d):");
    println!(
        "{}",
        relexi::util::stats::ascii_histogram(&trained.cs_samples, 0.0, 0.5, 20, 40)
    );
    println!("untrained-policy Cs distribution:");
    println!(
        "{}",
        relexi::util::stats::ascii_histogram(&initial.cs_samples, 0.0, 0.5, 20, 40)
    );

    // Fig. 5a/b: training + test return curves.
    use relexi::util::plot::{render, Scale, Series};
    let its: Vec<f64> = log.history.iter().map(|m| m.iteration as f64).collect();
    let train_curve = Series::new(
        "training return (mean over envs)",
        its.clone(),
        log.history.iter().map(|m| m.return_mean).collect(),
    );
    let test_pts: Vec<(f64, f64)> = log
        .history
        .iter()
        .filter_map(|m| m.test_return.map(|t| (m.iteration as f64, t)))
        .collect();
    let test_curve = Series::new(
        "test return (held-out state)",
        test_pts.iter().map(|p| p.0).collect(),
        test_pts.iter().map(|p| p.1).collect(),
    );
    println!(
        "\n{}",
        render(
            "Normalized return vs iteration (Fig. 5a/b)",
            &[train_curve, test_curve],
            64,
            14,
            Scale::Linear,
            Scale::Linear,
        )
    );

    // Fig. 5c as a log-log terminal plot.
    let ks: Vec<f64> = (1..=cfg.case.k_max).map(|k| k as f64).collect();
    let pick = |spec: &[f64]| ks.iter().map(|&k| spec[k as usize]).collect::<Vec<_>>();
    println!(
        "{}",
        render(
            "Energy spectra at t_end (Fig. 5c, log-log)",
            &[
                Series::new("DNS mean", ks.clone(), pick(&truth.mean_spectrum)),
                Series::new("RL trained", ks.clone(), pick(&trained.final_spectrum)),
                Series::new("Smagorinsky", ks.clone(), pick(&smag.final_spectrum)),
                Series::new("implicit", ks.clone(), pick(&implicit.final_spectrum)),
            ],
            64,
            16,
            Scale::Log10,
            Scale::Log10,
        )
    );

    println!(
        "training curve CSV: {}/training.csv | checkpoint: {}/policy_final.bin",
        cfg.out_dir, cfg.out_dir
    );
    Ok(())
}
