//! Turbulence-model comparison on the held-out test state (Fig. 5 bottom
//! row): RL policy (optionally a trained checkpoint) vs Smagorinsky vs
//! implicit LES, with the DNS min/max band, plus the Cs histogram.
//!
//! ```text
//! cargo run --release --example spectrum_compare -- \
//!     --truth runs/truth_24dof.bin [--checkpoint runs/train_hit/policy_final.bin]
//! ```

use anyhow::{Context, Result};
use relexi::config::RunConfig;
use relexi::coordinator::{eval_baseline, eval_policy};
use relexi::runtime::{PolicyRuntime, Registry, Runtime};
use relexi::solver::dns::Truth;
use relexi::util::bench::Table;
use relexi::util::cli::Args;
use std::path::Path;
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let mut cfg = RunConfig::default();
    cfg.solver.t_end = args.get_parse("t-end", 2.0f64)?;
    let truth_path = args.get_or("truth", "runs/truth_24dof.bin");
    let truth = Arc::new(
        Truth::load(Path::new(&truth_path))
            .with_context(|| format!("load {truth_path}; run relexi gen-truth"))?,
    );

    let rt = Runtime::cpu()?;
    let reg = Registry::open(Path::new(&cfg.artifacts_dir))?;
    let policy = PolicyRuntime::load(&rt, &reg, cfg.case.n)?;
    let (theta, label) = match args.get("checkpoint") {
        Some(p) => (
            relexi::util::binio::read_f32_vec(Path::new(p))?,
            format!("RL trained ({p})"),
        ),
        None => (reg.initial_params(cfg.case.n)?, "RL untrained".to_string()),
    };

    println!("evaluating {label} + baselines on the test state...");
    let rl = eval_policy(&cfg, &truth, &policy, &theta, None)?;
    let smag = eval_baseline(&cfg, &truth, cfg.solver.smagorinsky_cs)?;
    let implicit = eval_baseline(&cfg, &truth, 0.0)?;

    let mut t = Table::new(&["model", "normalized return", "final spectrum err"]);
    let spec_err = |spec: &[f64]| {
        relexi::solver::spectrum::spectrum_error(&truth.mean_spectrum, spec, cfg.case.k_max)
    };
    t.row(vec![
        label.clone(),
        format!("{:+.4}", rl.normalized_return),
        format!("{:.4}", spec_err(&rl.final_spectrum)),
    ]);
    t.row(vec![
        "Smagorinsky Cs=0.17".into(),
        format!("{:+.4}", smag.normalized_return),
        format!("{:.4}", spec_err(&smag.final_spectrum)),
    ]);
    t.row(vec![
        "implicit (Cs=0)".into(),
        format!("{:+.4}", implicit.normalized_return),
        format!("{:.4}", spec_err(&implicit.final_spectrum)),
    ]);
    t.print("Model comparison (Fig. 5)");

    let mut s = Table::new(&["k", "DNS mean", "DNS band", "RL", "Smagorinsky", "implicit"]);
    for k in 1..=cfg.case.k_max {
        s.row(vec![
            k.to_string(),
            format!("{:.3e}", truth.mean_spectrum[k]),
            format!("[{:.2e}, {:.2e}]", truth.min_spectrum[k], truth.max_spectrum[k]),
            format!("{:.3e}", rl.final_spectrum[k]),
            format!("{:.3e}", smag.final_spectrum[k]),
            format!("{:.3e}", implicit.final_spectrum[k]),
        ]);
    }
    s.print("Energy spectra at t_end with DNS band (Fig. 5c)");

    // Fig. 5c as a log-log terminal plot with the DNS band.
    use relexi::util::plot::{render, Scale, Series};
    let ks: Vec<f64> = (1..=cfg.case.k_max).map(|k| k as f64).collect();
    let pick = |spec: &[f64]| ks.iter().map(|&k| spec[k as usize]).collect::<Vec<_>>();
    println!(
        "\n{}",
        render(
            "Energy spectra at t_end (Fig. 5c, log-log)",
            &[
                Series::new("DNS mean", ks.clone(), pick(&truth.mean_spectrum)),
                Series::new(&label, ks.clone(), pick(&rl.final_spectrum)),
                Series::new("Smagorinsky", ks.clone(), pick(&smag.final_spectrum)),
                Series::new("implicit", ks.clone(), pick(&implicit.final_spectrum)),
                Series::new("DNS min", ks.clone(), pick(&truth.min_spectrum)),
                Series::new("DNS max", ks.clone(), pick(&truth.max_spectrum)),
            ],
            64,
            16,
            Scale::Log10,
            Scale::Log10,
        )
    );

    println!("\n{label} — Cs prediction distribution (Fig. 5d):");
    println!(
        "{}",
        relexi::util::stats::ascii_histogram(&rl.cs_samples, 0.0, 0.5, 20, 40)
    );
    Ok(())
}
