//! Scaling study driver: regenerates both panels of Fig. 3 (weak scaling)
//! and Fig. 4 (strong scaling) on the simulated Hawk partition, plus the
//! §3.3 launch-optimization ablations (MPMD vs individual, RAM vs Lustre).
//!
//! ```text
//! cargo run --release --example scaling_study
//! cargo run --release --example scaling_study -- --nodes 16 --csv runs/scaling
//! ```

use anyhow::Result;
use relexi::hpc::{steps_per_action_for, strong_scaling, weak_scaling, ClusterSim,
                  IterationParams};
use relexi::launcher::{LaunchMode, StagingMode};
use relexi::util::bench::Table;
use relexi::util::binio::CsvWriter;
use relexi::util::cli::Args;
use std::path::PathBuf;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let nodes = args.get_parse("nodes", 16usize)?;
    let csv_dir = args.get("csv").map(PathBuf::from);
    let sim = ClusterSim::hawk(nodes);

    // ---- Fig. 3: weak scaling --------------------------------------------
    for dof in [24usize, 32] {
        let spa = steps_per_action_for(dof);
        let mut table = Table::new(&["ranks/env", "n_envs", "cores", "time [s]", "speedup", "eff"]);
        let mut csv = match &csv_dir {
            Some(d) => Some(CsvWriter::create(
                &d.join(format!("weak_{dof}dof.csv")),
                &["ranks_per_env", "n_envs", "cores", "time_s", "speedup", "efficiency"],
            )?),
            None => None,
        };
        for ranks in [2usize, 4, 8, 16] {
            for p in weak_scaling(&sim, dof, ranks, spa)? {
                table.row(vec![
                    ranks.to_string(),
                    p.n_envs.to_string(),
                    (p.n_envs * ranks).to_string(),
                    format!("{:.2}", p.total_s),
                    format!("{:.1}", p.speedup),
                    format!("{:.3}", p.efficiency),
                ]);
                if let Some(c) = &mut csv {
                    c.row_f64(&[
                        ranks as f64,
                        p.n_envs as f64,
                        (p.n_envs * ranks) as f64,
                        p.total_s,
                        p.speedup,
                        p.efficiency,
                    ])?;
                }
            }
        }
        table.print(&format!("Fig. 3 — weak scaling, {dof} DOF ({nodes} Hawk nodes)"));
    }

    // ---- Fig. 4: strong scaling -------------------------------------------
    for dof in [24usize, 32] {
        let spa = steps_per_action_for(dof);
        let mut table = Table::new(&["n_envs", "ranks/env", "time [s]", "speedup", "eff"]);
        let mut csv = match &csv_dir {
            Some(d) => Some(CsvWriter::create(
                &d.join(format!("strong_{dof}dof.csv")),
                &["n_envs", "ranks_per_env", "time_s", "speedup", "efficiency"],
            )?),
            None => None,
        };
        for envs in [2usize, 8, 32, 128] {
            for p in strong_scaling(&sim, dof, envs, &[2, 4, 8, 16], spa)? {
                table.row(vec![
                    envs.to_string(),
                    p.ranks_per_env.to_string(),
                    format!("{:.2}", p.total_s),
                    format!("{:.2}", p.speedup),
                    format!("{:.3}", p.efficiency),
                ]);
                if let Some(c) = &mut csv {
                    c.row_f64(&[
                        envs as f64,
                        p.ranks_per_env as f64,
                        p.total_s,
                        p.speedup,
                        p.efficiency,
                    ])?;
                }
            }
        }
        table.print(&format!("Fig. 4 — strong scaling, {dof} DOF"));
    }

    // ---- §3.3 ablation: launch + staging ----------------------------------
    let mut ab = Table::new(&["n_envs", "mode", "staging", "launch [s]", "sampling [s]", "launch share"]);
    for n_envs in [16usize, 128, 512] {
        for (mode, staging, label) in [
            (LaunchMode::Individual, StagingMode::Lustre, "individual+lustre"),
            (LaunchMode::Individual, StagingMode::RamDrive, "individual+ram"),
            (LaunchMode::Mpmd, StagingMode::Lustre, "mpmd+lustre"),
            (LaunchMode::Mpmd, StagingMode::RamDrive, "mpmd+ram"),
        ] {
            let mut p = IterationParams::for_case(24, n_envs, 4);
            p.launch_mode = mode;
            p.staging = staging;
            let t = sim.simulate(&p)?;
            ab.row(vec![
                n_envs.to_string(),
                label.split('+').next().unwrap().to_string(),
                label.split('+').nth(1).unwrap().to_string(),
                format!("{:.2}", t.launch_s),
                format!("{:.2}", t.sampling_s),
                format!("{:.0}%", 100.0 * t.launch_s / t.total_s()),
            ]);
        }
    }
    ab.print("§3.3 ablation — launch overhead vs simulation time (exp. A2)");
    println!(
        "\nPaper's observation reproduced: without MPMD, \"the time required for\n\
         starting the simulations exceeded the actual simulation time\"; with\n\
         MPMD + RAM staging the launch penalty is negligible."
    );
    Ok(())
}
