#!/usr/bin/env python3
"""Render a TELEMETRY_*.json run summary (emitted by
``rust/src/util/telemetry.rs`` at the end of a telemetry-enabled run)
into markdown phase tables.

Typical use, after ``relexi train`` with ``[telemetry] enabled = true``::

    python3 tools/trace_report.py TELEMETRY_24dof.json

Sections rendered:

* **spans** — per-phase wall-clock breakdown (count, total, p50/p99/max)
  sorted by total time, with each phase's share of the total span time;
* **latency histograms** — the store-op / exchange / policy histogram
  percentiles;
* **events / counters** — instant-event and counter totals (frame kinds
  with byte volumes, supervision incidents, ...);
* **run counters** — the store/pool/supervision/batch sections the
  trainer folds in at consolidation.

The per-process interactive view is the matching TRACE_*.json — load it
in Perfetto (https://ui.perfetto.dev) or chrome://tracing; this tool is
the CI-artifact-friendly text twin.

Stdlib only — no third-party deps (the image has none to spare).
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict:
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "spans" not in doc:
        raise ValueError(f"{path}: not a TELEMETRY_*.json summary")
    return doc


def fmt_us(us: float) -> str:
    """Human duration from microseconds."""
    if us >= 1e6:
        return f"{us / 1e6:.3f} s"
    if us >= 1e3:
        return f"{us / 1e3:.3f} ms"
    return f"{us:.0f} µs"


def fmt_count(n: float) -> str:
    if n >= 1e9:
        return f"{n / 1e9:.2f}G"
    if n >= 1e6:
        return f"{n / 1e6:.2f}M"
    if n >= 1e3:
        return f"{n / 1e3:.1f}k"
    return f"{n:.0f}"


def markdown_table(header: list[str], rows: list[list[str]]) -> str:
    width = [len(h) for h in header]
    for row in rows:
        for i, c in enumerate(row):
            width[i] = max(width[i], len(c))

    def fmt_row(cells: list[str]) -> str:
        return "|" + "|".join(f" {c:<{w}} " for c, w in zip(cells, width)) + "|"

    lines = [fmt_row(header)]
    lines.append("|" + "|".join("-" * (w + 2) for w in width) + "|")
    lines.extend(fmt_row(r) for r in rows)
    return "\n".join(lines)


def span_table(spans: list[dict]) -> str:
    total_all = sum(float(s.get("total_us", 0)) for s in spans) or 1.0
    rows = []
    for s in sorted(spans, key=lambda s: -float(s.get("total_us", 0))):
        total = float(s.get("total_us", 0))
        rows.append(
            [
                s["name"],
                fmt_count(float(s.get("count", 0))),
                fmt_us(total),
                f"{total / total_all * 100.0:.1f}%",
                fmt_us(float(s.get("p50_us", 0))),
                fmt_us(float(s.get("p99_us", 0))),
                fmt_us(float(s.get("max_us", 0))),
            ]
        )
    return markdown_table(
        ["span", "count", "total", "share", "p50", "p99", "max"], rows
    )


def hist_table(hists: list[dict]) -> str:
    rows = []
    for h in hists:
        count = float(h.get("count", 0))
        if count == 0:
            continue
        total = float(h.get("sum_us", 0))
        rows.append(
            [
                h["name"],
                fmt_count(count),
                fmt_us(total),
                fmt_us(total / count),
                fmt_us(float(h.get("p50_us", 0))),
                fmt_us(float(h.get("p99_us", 0))),
            ]
        )
    return markdown_table(["histogram", "count", "total", "mean", "p50", "p99"], rows)


def event_table(events: list[dict], sum_label: str) -> str:
    rows = []
    for e in sorted(events, key=lambda e: -float(e.get("count", 0))):
        rows.append(
            [
                e["name"],
                fmt_count(float(e.get("count", 0))),
                fmt_count(float(e.get("sum", 0))),
            ]
        )
    return markdown_table(["name", "count", sum_label], rows)


def report(path: str, doc: dict) -> None:
    run = doc.get("run", "?")
    print(f"## telemetry report — {run} ({path})\n")
    print(
        f"processes: {doc.get('processes', '?')}   "
        f"dropped records: {doc.get('dropped_records', '?')}\n"
    )

    spans = doc.get("spans", [])
    if spans:
        print("### spans\n")
        print(span_table(spans))
        print()
    hists = [h for h in doc.get("hists", []) if float(h.get("count", 0)) > 0]
    if hists:
        print("### latency histograms\n")
        print(hist_table(hists))
        print()
    events = doc.get("events", [])
    if events:
        print("### events\n")
        print(event_table(events, "sum (payload)"))
        print()
    counters = doc.get("counters", [])
    if counters:
        print("### counters\n")
        print(event_table(counters, "sum (values)"))
        print()

    sections = [
        (name, doc[name])
        for name in ("store", "pool", "supervision", "batch")
        if isinstance(doc.get(name), dict)
    ]
    if sections:
        print("### run counters\n")
        rows = [
            [name, key, fmt_count(float(val))]
            for name, kv in sections
            for key, val in kv.items()
        ]
        print(markdown_table(["section", "counter", "value"], rows))
        print()


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Render TELEMETRY_*.json summaries into markdown tables."
    )
    ap.add_argument(
        "summaries", nargs="+", metavar="TELEMETRY_JSON", help="TELEMETRY_*.json files"
    )
    args = ap.parse_args(argv)

    status = 0
    for path in args.summaries:
        try:
            doc = load(path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"error: {e}", file=sys.stderr)
            status = 1
            continue
        report(path, doc)
    return status


if __name__ == "__main__":
    sys.exit(main())
