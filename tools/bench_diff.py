#!/usr/bin/env python3
"""Diff two BENCH_*.json files (emitted by rust/src/util/bench.rs
``Bench::write_json``) into a markdown table.

The intended A/B loop for PR-9 style perf work: run a bench binary on
the baseline commit and on the candidate, then::

    python3 tools/bench_diff.py BENCH_db.baseline.json BENCH_db.json

Rows are matched by label.  ``speedup`` is baseline_mean / candidate_mean
(>1 means the candidate is faster); ``delta`` is the relative change of
the candidate mean vs baseline.  Labels present in only one file are
listed in their own sections so bench-suite growth (new ``wave-batched/*``
or ``put_many`` rows) is visible rather than silently dropped.

Stdlib only — no third-party deps (the image has none to spare).
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict[str, dict]:
    """Load a BENCH_*.json into {label: result-dict}, preserving order."""
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    results = doc.get("results", [])
    out: dict[str, dict] = {}
    for r in results:
        label = r.get("label")
        if not isinstance(label, str) or "mean_s" not in r:
            raise ValueError(f"{path}: malformed result entry: {r!r}")
        if label in out:
            # Repeated labels (e.g. a bench run twice): keep the last,
            # matching "most recent measurement wins".
            pass
        out[label] = r
    return out


def fmt_s(s: float) -> str:
    """Human duration, mirroring bench.rs fmt_duration."""
    if s >= 1.0:
        return f"{s:.3f} s"
    if s >= 1e-3:
        return f"{s * 1e3:.3f} ms"
    if s >= 1e-6:
        return f"{s * 1e6:.3f} µs"
    return f"{s * 1e9:.1f} ns"


def markdown_table(header: list[str], rows: list[list[str]]) -> str:
    width = [len(h) for h in header]
    for row in rows:
        for i, c in enumerate(row):
            width[i] = max(width[i], len(c))
    def fmt_row(cells: list[str]) -> str:
        return "|" + "|".join(f" {c:<{w}} " for c, w in zip(cells, width)) + "|"
    lines = [fmt_row(header)]
    lines.append("|" + "|".join("-" * (w + 2) for w in width) + "|")
    lines.extend(fmt_row(r) for r in rows)
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff two BENCH_*.json files into a markdown table."
    )
    ap.add_argument("baseline", help="baseline BENCH_*.json")
    ap.add_argument("candidate", help="candidate BENCH_*.json")
    ap.add_argument(
        "--metric",
        choices=["mean_s", "median_s", "min_s"],
        default="mean_s",
        help="which statistic to compare (default: mean_s)",
    )
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.0,
        metavar="PCT",
        help="only show rows whose |delta| exceeds PCT percent "
        "(default 0: show everything)",
    )
    ap.add_argument(
        "--fail-above",
        type=float,
        default=None,
        metavar="PCT",
        help="exit 1 if any common row regresses by more than PCT percent "
        "(for CI gating)",
    )
    args = ap.parse_args(argv)

    base = load(args.baseline)
    cand = load(args.candidate)
    metric = args.metric

    rows: list[list[str]] = []
    worst_regression = 0.0
    for label, b in base.items():
        c = cand.get(label)
        if c is None:
            continue
        bs, cs = float(b[metric]), float(c[metric])
        if bs <= 0.0 or cs <= 0.0:
            continue
        delta = (cs - bs) / bs * 100.0
        worst_regression = max(worst_regression, delta)
        if abs(delta) < args.threshold:
            continue
        rows.append(
            [
                label,
                fmt_s(bs),
                fmt_s(cs),
                f"{bs / cs:.2f}x",
                f"{delta:+.1f}%",
            ]
        )

    print(f"## bench diff — {args.baseline} vs {args.candidate} ({metric})\n")
    if rows:
        print(
            markdown_table(
                ["label", "baseline", "candidate", "speedup", "delta"], rows
            )
        )
    else:
        print("(no common rows above threshold)")

    only_base = [l for l in base if l not in cand]
    only_cand = [l for l in cand if l not in base]
    if only_base:
        print("\n### only in baseline\n")
        for l in only_base:
            print(f"- `{l}` ({fmt_s(float(base[l][metric]))})")
    if only_cand:
        print("\n### only in candidate\n")
        for l in only_cand:
            print(f"- `{l}` ({fmt_s(float(cand[l][metric]))})")

    if args.fail_above is not None and worst_regression > args.fail_above:
        print(
            f"\nFAIL: worst regression {worst_regression:+.1f}% exceeds "
            f"--fail-above {args.fail_above}%",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
