//! Compile-only stub of the `xla` (PJRT) bindings.
//!
//! The original build image bakes an `xla_extension`-backed crate; this
//! container ships neither the bindings nor a crates.io registry, so the
//! runtime dependency is *gated* behind this stub: the API surface that
//! `relexi::runtime::executor` uses compiles as-is, and every entry point
//! that would need a real PJRT runtime returns [`Error::Unavailable`] at
//! runtime instead.  The runtime integration tests already self-skip when
//! no compiled artifacts are present, so the rest of the test suite runs
//! unaffected.  Swapping a real `xla` crate back in is a one-line change
//! in the workspace `Cargo.toml`.

use std::fmt;

/// Stub error: either "no PJRT in this build" or a shape/usage error.
#[derive(Debug)]
pub enum Error {
    /// The operation needs a real PJRT runtime.
    Unavailable(&'static str),
    /// Malformed usage detectable host-side (kept for API fidelity).
    Invalid(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT runtime not available in this build (xla stub; \
                 link the real xla crate to execute artifacts)"
            ),
            Error::Invalid(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Stub PJRT client; construction fails so callers degrade gracefully at
/// one well-defined point.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub: never constructible from text).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable handle (stub: never actually constructible).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal: shape + f32 payload (host-side ops genuinely work so the
/// conversion helpers in `executor.rs` stay testable).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    shape: Vec<i64>,
    data: Vec<f32>,
}

impl Literal {
    pub fn scalar(x: f32) -> Literal {
        Literal { shape: vec![], data: vec![x] }
    }

    pub fn vec1(v: &[f32]) -> Literal {
        Literal { shape: vec![v.len() as i64], data: v.to_vec() }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::Invalid(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { shape: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.shape.clone() })
    }

    pub fn to_vec<T: FromF32>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }
}

/// Element conversion for [`Literal::to_vec`] (f32-only payloads here).
pub trait FromF32 {
    fn from_f32(x: f32) -> Self;
}

impl FromF32 for f32 {
    fn from_f32(x: f32) -> f32 {
        x
    }
}

impl FromF32 for f64 {
    fn from_f32(x: f32) -> f64 {
        x as f64
    }
}

/// Array shape (dims accessor only).
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(format!("{e}").contains("PJRT runtime not available"));
    }

    #[test]
    fn literal_host_ops_work() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
    }
}
