//! Offline stand-in for the `anyhow` crate, implementing exactly the
//! subset relexi uses: `Error`, `Result`, the `Context` extension trait
//! (on `Result` and `Option`), and the `anyhow!` / `bail!` / `ensure!`
//! macros.  The build image ships no crates.io registry, so this lives in
//! `vendor/` as a path dependency.
//!
//! Semantics follow the real crate where it matters to relexi:
//! * `Display` prints the outermost message; the alternate form (`{:#}`)
//!   prints the whole cause chain joined by `": "` (used by `main.rs`).
//! * Any `E: std::error::Error + Send + Sync + 'static` converts via `?`;
//!   the source chain is captured as messages.
//! * `Error` deliberately does NOT implement `std::error::Error`, exactly
//!   like the real crate, so the blanket `From` impl stays coherent.

use std::fmt;

/// Error: an outermost message plus the flattened cause chain.
pub struct Error {
    /// `chain[0]` is the outermost context; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `.context(...)` does).
    fn wrap(mut self, context: String) -> Error {
        self.chain.insert(0, context);
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` with the customary default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "root cause")
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: Result<()> = Err(io_err()).context("outer");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause");
    }

    #[test]
    fn option_context() {
        let r: Result<i32> = None.context("missing");
        assert_eq!(format!("{}", r.unwrap_err()), "missing");
        let r: Result<i32> = Some(3).context("missing");
        assert_eq!(r.unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big: 101");
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
