"""AOT path sanity: the HLO-text emission used by the Rust runtime.

Kept light (one tiny lowering) — the heavyweight artifact round-trip is
covered by the Rust integration test against testvec.json.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_to_hlo_text_emits_parseable_module():
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    # HLO text, not a serialized proto: must be human-readable and name a
    # module with an entry computation.
    assert "HloModule" in text
    assert "ENTRY" in text
    assert "f32[4]" in text


def test_obs_spec_shapes():
    s = aot.obs_spec(5, 64)
    assert s.shape == (64, 6, 6, 6, 3)
    assert s.dtype == jnp.float32
    assert aot.obs_spec(7, 8).shape == (8, 8, 8, 8, 3)


def test_manifest_param_counts_consistent():
    for n in (5, 7):
        _layout, total = model.param_layout(n)
        assert total == 2 * model.trunk_param_count(n) + 1


def test_testvec_roundtrip_values(tmp_path):
    """make_testvec must be reproducible and self-consistent."""
    n = 5
    theta = np.asarray(
        model.init_params(jax.random.PRNGKey(aot.SEED), n), dtype=np.float32
    )
    tv = aot.make_testvec(n, theta, str(tmp_path))
    obs = np.fromfile(tmp_path / f"testvec_obs_n{n}.bin", dtype=np.float32)
    assert obs.shape == (tv["batch"] * 6 * 6 * 6 * 3,)
    np.testing.assert_allclose(obs[:8], tv["obs_first8"], rtol=1e-6)
    # log_std must be the configured init.
    assert tv["log_std"] == pytest.approx(model.LOG_STD_INIT, rel=1e-5)
    # Expected outputs are finite and within the scale layer's range.
    assert all(0.0 <= m <= 0.5 for m in tv["mean"])
    assert np.isfinite(tv["train_loss"])
    assert tv["train_step_out"] == 1.0
