"""L2 correctness: Table-2 architecture, flat-param conventions, PPO math."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def theta_for(n, seed=0):
    return model.init_params(jax.random.PRNGKey(seed), n)


# --- Table 2 ----------------------------------------------------------------


def test_table2_trunk_param_count():
    # 656 + 1736 + 868 + 33 = 3293 — the paper's "around 3,300 parameters"
    assert model.trunk_param_count(5) == 3293


def test_table2_layer_dims_n5():
    dims = [6]
    for k, _f, pad in model.ARCH[5]:
        dims.append(dims[-1] if pad == "same" else dims[-1] - k + 1)
    assert dims == [6, 6, 4, 2, 1]


def test_n7_reduces_to_scalar():
    dims = [8]
    for k, _f, pad in model.ARCH[7]:
        dims.append(dims[-1] if pad == "same" else dims[-1] - k + 1)
    assert dims[-1] == 1


def test_param_layout_is_dense_and_ordered():
    for n in (5, 7):
        layout, total = model.param_layout(n)
        off = 0
        for name, shape, o in layout:
            assert o == off, name
            off += int(math.prod(shape))
        assert off == total
        # actor trunk + log_std + critic trunk
        assert total == 2 * model.trunk_param_count(n) + 1


def test_unflatten_roundtrip():
    n = 5
    _layout, total = model.param_layout(n)
    theta = jnp.arange(total, dtype=jnp.float32)
    params = model.unflatten(theta, n)
    w0 = params["actor/w0"]
    assert w0.shape == (3, 3, 3, 3, 8)
    np.testing.assert_allclose(np.asarray(w0).reshape(-1), np.arange(648))
    assert float(params["log_std"][0]) == 3293.0


# --- policy head ------------------------------------------------------------


def test_policy_mean_in_admissible_range():
    """Scale layer y = 0.5*sigmoid(x): Cs in [0, 0.5] (paper §6.2)."""
    n = 5
    theta = theta_for(n)
    obs = jax.random.normal(jax.random.PRNGKey(1), (32, 6, 6, 6, 3)) * 10.0
    mean, log_std, value = model.policy_apply(theta, obs, n)
    m = np.asarray(mean)
    assert (m >= 0.0).all() and (m <= 0.5).all()
    assert float(log_std[0]) == pytest.approx(model.LOG_STD_INIT)
    assert value.shape == (32,)


def test_policy_pallas_matches_ref_path():
    n = 5
    theta = theta_for(n, seed=3)
    obs = jax.random.normal(jax.random.PRNGKey(2), (16, 6, 6, 6, 3))
    mp, lp, vp = model.policy_apply(theta, obs, n, use_pallas=True)
    mr, lr, vr = model.policy_apply(theta, obs, n, use_pallas=False)
    np.testing.assert_allclose(np.asarray(mp), np.asarray(mr), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(vp), np.asarray(vr), rtol=1e-4, atol=1e-4)


def test_gaussian_logp_matches_closed_form():
    logp = model.gaussian_logp(jnp.float32(0.3), jnp.float32(0.25), jnp.float32(-3.0))
    sigma = math.exp(-3.0)
    want = -0.5 * ((0.3 - 0.25) / sigma) ** 2 - (-3.0) - 0.5 * math.log(2 * math.pi)
    assert float(logp) == pytest.approx(want, rel=1e-5)


# --- PPO train step ----------------------------------------------------------


def make_batch(n, b, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    obs = jax.random.normal(ks[0], (b, n + 1, n + 1, n + 1, 3))
    act = jax.random.uniform(ks[1], (b,), minval=0.0, maxval=0.5)
    adv = jax.random.normal(ks[2], (b,))
    ret = jax.random.normal(ks[3], (b,))
    return obs, act, adv, ret


def test_train_step_adam_matches_manual():
    """One train_step must equal a hand-rolled Adam update of jax.grad."""
    n = 5
    theta = theta_for(n, seed=5)
    obs, act, adv, ret = make_batch(n, 8, seed=6)
    mean, log_std, _ = model.policy_apply(theta, obs, n)
    old_logp = model.gaussian_logp(act, mean, log_std[0])

    zeros = jnp.zeros_like(theta)
    out = model.train_step(theta, zeros, zeros, jnp.float32(0.0),
                           obs, act, old_logp, adv, ret, n)
    theta2 = out[0]

    (loss, _aux), g = jax.value_and_grad(model.ppo_loss, has_aux=True)(
        theta, obs, act, old_logp, adv, ret, n
    )
    m = (1 - model.ADAM_B1) * g
    v = (1 - model.ADAM_B2) * g * g
    mhat = m / (1 - model.ADAM_B1)
    vhat = v / (1 - model.ADAM_B2)
    want = theta - model.LEARNING_RATE * mhat / (jnp.sqrt(vhat) + model.ADAM_EPS)
    np.testing.assert_allclose(np.asarray(theta2), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    assert float(out[4]) == pytest.approx(float(loss), rel=1e-5)


def test_train_step_improves_objective():
    """Repeated steps on a fixed batch must reduce the PPO loss."""
    n = 5
    theta = theta_for(n, seed=9)
    obs, act, adv, ret = make_batch(n, 32, seed=10)
    mean, log_std, _ = model.policy_apply(theta, obs, n)
    old_logp = model.gaussian_logp(act, mean, log_std[0])

    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    step = jnp.float32(0.0)
    losses = []
    fn = jax.jit(lambda *a: model.train_step(*a, n=n))
    for _ in range(30):
        theta, m, v, step, loss, *_ = fn(theta, m, v, step, obs, act,
                                         old_logp, adv, ret)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_ppo_ratio_is_one_on_fresh_batch():
    """With old_logp from the current policy, clipfrac=0 and kl~0."""
    n = 5
    theta = theta_for(n, seed=11)
    obs, act, adv, ret = make_batch(n, 16, seed=12)
    mean, log_std, _ = model.policy_apply(theta, obs, n)
    old_logp = model.gaussian_logp(act, mean, log_std[0])
    _loss, (pg, _vf, _ent, clipfrac, akl) = model.ppo_loss(
        theta, obs, act, old_logp, adv, ret, n
    )
    assert float(clipfrac) == 0.0
    assert abs(float(akl)) < 1e-6
    # with ratio == 1, pg loss is exactly -mean(adv)
    assert float(pg) == pytest.approx(-float(jnp.mean(adv)), rel=1e-4, abs=1e-5)


def test_entropy_constant_in_mean():
    """Gaussian entropy depends only on log_std."""
    ent = 0.5 * math.log(2 * math.pi * math.e) + model.LOG_STD_INIT
    n = 5
    theta = theta_for(n)
    obs, act, adv, ret = make_batch(n, 8)
    _loss, (_pg, _vf, entropy, _cf, _kl) = model.ppo_loss(
        theta, obs, act, jnp.zeros(8), adv, ret, n
    )
    assert float(entropy) == pytest.approx(ent, rel=1e-5)
