"""L1 correctness: Pallas conv3d kernel vs the pure-jnp oracle.

This is the core correctness signal for the kernel that ends up inside
every HLO artifact the Rust coordinator executes.  Hypothesis sweeps the
shape/padding space; fixed tests pin the exact Table-2 layer shapes.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.conv3d import conv3d, _out_spatial
from compile.kernels.ref import conv3d_ref


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


def check(b, d, k, cin, cout, padding, key=0, block_b=None, rtol=1e-5, atol=1e-5):
    x = rand(key, (b, d, d, d, cin))
    w = rand(key + 1, (k, k, k, cin, cout)) * (1.0 / math.sqrt(k**3 * cin))
    bias = rand(key + 2, (cout,))
    got = conv3d(x, w, bias, padding=padding, block_b=block_b)
    want = conv3d_ref(x, w, bias, padding=padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=atol)


# --- fixed shapes: the exact Table-2 layers (N=5) and the N=7 variant -----

TABLE2_LAYERS_N5 = [
    (6, 3, 3, 8, "same"),
    (6, 3, 8, 8, "valid"),
    (4, 3, 8, 4, "valid"),
    (2, 2, 4, 1, "valid"),
]

TABLE2_LAYERS_N7 = [
    (8, 3, 3, 8, "same"),
    (8, 3, 8, 8, "valid"),
    (6, 3, 8, 4, "valid"),
    (4, 3, 4, 4, "valid"),
    (2, 2, 4, 1, "valid"),
]


@pytest.mark.parametrize("d,k,cin,cout,padding", TABLE2_LAYERS_N5)
def test_table2_n5_layers(d, k, cin, cout, padding):
    check(64, d, k, cin, cout, padding)


@pytest.mark.parametrize("d,k,cin,cout,padding", TABLE2_LAYERS_N7)
def test_table2_n7_layers(d, k, cin, cout, padding):
    check(32, d, k, cin, cout, padding)


def test_output_spatial_dims_match_table2():
    # Table 2 dimension column: 6 -> 6 -> 4 -> 2 -> 1
    assert _out_spatial(6, 3, "same") == 6
    assert _out_spatial(6, 3, "valid") == 4
    assert _out_spatial(4, 3, "valid") == 2
    assert _out_spatial(2, 2, "valid") == 1


def test_block_b_tiling_equivalence():
    """Grid tiling must not change the numbers."""
    x = rand(3, (128, 6, 6, 6, 3))
    w = rand(4, (3, 3, 3, 3, 8)) * 0.1
    bias = rand(5, (8,))
    full = conv3d(x, w, bias, padding="same", block_b=128)
    for bb in (16, 32, 64):
        tiled = conv3d(x, w, bias, padding="same", block_b=bb)
        np.testing.assert_allclose(np.asarray(tiled), np.asarray(full), rtol=1e-6)


def test_bias_is_applied():
    x = jnp.zeros((4, 4, 4, 4, 2), dtype=jnp.float32)
    w = jnp.zeros((3, 3, 3, 2, 5), dtype=jnp.float32)
    bias = jnp.arange(5, dtype=jnp.float32)
    out = conv3d(x, w, bias, padding="valid")
    np.testing.assert_allclose(
        np.asarray(out), np.broadcast_to(np.arange(5, dtype=np.float32), out.shape)
    )


def test_identity_kernel_same_padding():
    """A centered delta kernel with 'same' padding is the identity."""
    x = rand(9, (2, 5, 5, 5, 1))
    w = jnp.zeros((3, 3, 3, 1, 1), dtype=jnp.float32).at[1, 1, 1, 0, 0].set(1.0)
    out = conv3d(x, w, jnp.zeros((1,), jnp.float32), padding="same")
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)


def test_rejects_bad_shapes():
    x = jnp.zeros((2, 4, 4, 4, 3), jnp.float32)
    with pytest.raises(ValueError):
        conv3d(x, jnp.zeros((2, 3, 3, 3, 4), jnp.float32), jnp.zeros((4,)))
    with pytest.raises(ValueError):
        conv3d(x, jnp.zeros((3, 3, 3, 5, 4), jnp.float32), jnp.zeros((4,)))
    with pytest.raises(ValueError):
        conv3d(x, jnp.zeros((3, 3, 3, 3, 4), jnp.float32), jnp.zeros((4,)),
               padding="reflect")


# --- hypothesis sweep over the shape space ---------------------------------

shape_strategy = st.tuples(
    st.integers(1, 6),            # batch
    st.integers(2, 7),            # spatial
    st.sampled_from([2, 3]),      # kernel
    st.integers(1, 5),            # cin
    st.integers(1, 6),            # cout
    st.sampled_from(["same", "valid"]),
    st.integers(0, 10_000),       # seed
).filter(lambda t: t[1] >= t[2])  # valid conv needs d >= k


@settings(max_examples=60, deadline=None)
@given(shape_strategy)
def test_hypothesis_matches_ref(params):
    b, d, k, cin, cout, padding, seed = params
    check(b, d, k, cin, cout, padding, key=seed)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_hypothesis_gradients_match_ref(seed):
    """custom_vjp (Pallas fwd) must agree with jax.grad of the oracle."""
    from compile.model import conv3d_ad

    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (3, 5, 5, 5, 2), dtype=jnp.float32)
    w = jax.random.normal(k2, (3, 3, 3, 2, 4), dtype=jnp.float32) * 0.2
    b = jax.random.normal(k3, (4,), dtype=jnp.float32)
    ct = jax.random.normal(k4, (3, 5, 5, 5, 4), dtype=jnp.float32)

    for padding in ("same", "valid"):
        ct_p = ct if padding == "same" else ct[:, :3, :3, :3, :]

        def loss_pallas(x, w, b):
            return jnp.sum(conv3d_ad(x, w, b, padding) * ct_p)

        def loss_ref(x, w, b):
            return jnp.sum(conv3d_ref(x, w, b, padding=padding) * ct_p)

        gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
        for a, e in zip(gp, gr):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(e), rtol=2e-4, atol=2e-4
            )
