"""L2 — the Relexi policy/value networks and the PPO train step in JAX.

Everything here exists only at *build time*: ``aot.py`` lowers these
functions to HLO text once, and the Rust coordinator executes the compiled
artifacts via PJRT on the training hot path.  Python never runs during
training.

The actor is exactly Table 2 of the paper (for N=5; the N=7 variant adds
one valid conv so the 8^3 element reduces to a scalar):

    Input  (B, N+1, N+1, N+1, 3)        nodal velocities of one DG element
    Conv3D k=3, 8 filters, zero pad     ReLU
    Conv3D k=3, 8 filters, no pad       ReLU
    Conv3D k=3, 4 filters, no pad       ReLU
    Conv3D k=2, 1 filter,  no pad       linear
    Scale  y = 0.5 * sigmoid(x)         -> Cs in [0, 0.5]

The actor's trunk has 3,293 parameters for N=5, matching the paper's
"around 3,300".  A scalar learnable log-sigma turns the mean into a
Gaussian policy; a structurally identical critic (linear output head, no
scale layer) provides the value baseline used by the PPO implementation in
TF-Agents that the paper trains with.

All convolutions run through the Pallas kernel in ``kernels/conv3d.py``
(L1), so the kernel lowers into the same HLO modules Rust loads.

Parameter convention: a single flat f32 vector.  The order is
``[actor w1, b1, ..., wn, bn, log_std, critic w1, b1, ..., wn, bn]``;
offsets are published in the artifact manifest so the Rust side can
(de)serialize checkpoints.  Optimizer state (Adam m, v) uses the same flat
layout.
"""

import math
from functools import partial

import jax
import jax.numpy as jnp

from .kernels.conv3d import conv3d
from .kernels.ref import conv3d_ref

# ---------------------------------------------------------------------------
# Architecture (Table 2 and its N=7 generalization)
# ---------------------------------------------------------------------------

# (kernel, filters, padding) per layer; input channels = 3 velocities.
ARCH = {
    5: [(3, 8, "same"), (3, 8, "valid"), (3, 4, "valid"), (2, 1, "valid")],
    7: [
        (3, 8, "same"),
        (3, 8, "valid"),
        (3, 4, "valid"),
        (3, 4, "valid"),
        (2, 1, "valid"),
    ],
}

# PPO hyperparameters (paper §5.3): lr 1e-4, Adam, clip 0.2, entropy coeff 0.
LEARNING_RATE = 1e-4
CLIP_EPS = 0.2
VF_COEF = 0.5
ENT_COEF = 0.0
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
LOG_STD_INIT = math.log(0.05)


def layer_shapes(n: int):
    """[(w_shape, b_shape), ...] for one trunk (actor or critic)."""
    shapes = []
    cin = 3
    for k, cout, _pad in ARCH[n]:
        shapes.append(((k, k, k, cin, cout), (cout,)))
        cin = cout
    return shapes


def param_layout(n: int):
    """Flat-vector layout: list of (name, shape, offset); total size."""
    layout = []
    off = 0

    def add(name, shape):
        nonlocal off
        size = int(math.prod(shape))
        layout.append((name, shape, off))
        off += size

    for i, (ws, bs) in enumerate(layer_shapes(n)):
        add(f"actor/w{i}", ws)
        add(f"actor/b{i}", bs)
    add("log_std", (1,))
    for i, (ws, bs) in enumerate(layer_shapes(n)):
        add(f"critic/w{i}", ws)
        add(f"critic/b{i}", bs)
    return layout, off


def trunk_param_count(n: int) -> int:
    """Parameters of one trunk — 3,293 for N=5 (paper: 'around 3,300')."""
    return sum(
        int(math.prod(ws)) + int(math.prod(bs)) for ws, bs in layer_shapes(n)
    )


def unflatten(theta, n: int):
    """Flat f32 vector -> dict of named parameter arrays."""
    layout, total = param_layout(n)
    assert theta.shape == (total,), (theta.shape, total)
    params = {}
    for name, shape, off in layout:
        size = int(math.prod(shape))
        params[name] = jax.lax.dynamic_slice(theta, (off,), (size,)).reshape(shape)
    return params


def init_params(key, n: int):
    """He-normal trunk init + LOG_STD_INIT, as one flat vector."""
    layout, total = param_layout(n)
    chunks = []
    for name, shape, _off in layout:
        key, sub = jax.random.split(key)
        if name == "log_std":
            chunks.append(jnp.full((1,), LOG_STD_INIT, dtype=jnp.float32))
        elif name.endswith(tuple(f"b{i}" for i in range(8))) and "/b" in name:
            chunks.append(jnp.zeros(shape, dtype=jnp.float32).reshape(-1))
        else:
            fan_in = int(math.prod(shape[:-1]))
            std = math.sqrt(2.0 / fan_in)
            chunks.append(
                (jax.random.normal(sub, shape, dtype=jnp.float32) * std).reshape(-1)
            )
    theta = jnp.concatenate(chunks)
    assert theta.shape == (total,)
    return theta


# ---------------------------------------------------------------------------
# Differentiable conv: Pallas forward, custom VJP
# ---------------------------------------------------------------------------
#
# ``pallas_call`` has no transpose rule in interpret mode, so the PPO
# backward pass needs an explicit VJP.  dx is itself a convolution (flipped,
# in/out-swapped filters; 'valid' forward <-> 'full' backward, 'same' is
# self-adjoint for odd k) and reuses the Pallas kernel; dw/db are small
# dense contractions done with jnp (they still lower to HLO dots).


def _conv_full(x, w, b):
    k = w.shape[0]
    p = k - 1
    xp = jnp.pad(x, ((0, 0), (p, p), (p, p), (p, p), (0, 0)))
    return conv3d(xp, w, b, padding="valid")


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def conv3d_ad(x, w, b, padding: str):
    return conv3d(x, w, b, padding=padding)


def _conv3d_ad_fwd(x, w, b, padding):
    return conv3d(x, w, b, padding=padding), (x, w)


def _conv3d_ad_bwd(padding, res, g):
    x, w = res
    k = w.shape[0]
    wt = jnp.flip(w, axis=(0, 1, 2)).swapaxes(3, 4)  # (k,k,k,Cout,Cin)
    zb = jnp.zeros((wt.shape[-1],), dtype=jnp.float32)
    if padding == "valid":
        dx = _conv_full(g, wt, zb)
        xe = x
    elif padding == "same":
        dx = conv3d(g, wt, zb, padding="same")
        lo = (k - 1) // 2
        hi = k - 1 - lo
        xe = jnp.pad(x, ((0, 0), (lo, hi), (lo, hi), (lo, hi), (0, 0)))
    else:  # pragma: no cover
        raise ValueError(padding)
    do, ho, wo = g.shape[1:4]
    # dw[i,j,l,ci,co] = sum_{b,o} x[b, o+ijl, ci] * g[b, o, co]
    dw = jnp.stack(
        [
            jnp.stack(
                [
                    jnp.stack(
                        [
                            jnp.einsum(
                                "bdhwc,bdhwo->co",
                                xe[:, i : i + do, j : j + ho, l : l + wo, :],
                                g,
                            )
                            for l in range(k)
                        ]
                    )
                    for j in range(k)
                ]
            )
            for i in range(k)
        ]
    )
    db = jnp.sum(g, axis=(0, 1, 2, 3))
    return dx, dw, db


conv3d_ad.defvjp(_conv3d_ad_fwd, _conv3d_ad_bwd)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _trunk(params, prefix, obs, n, conv_fn):
    h = obs
    for i, (_k, _f, pad) in enumerate(ARCH[n]):
        h = conv_fn(h, params[f"{prefix}/w{i}"], params[f"{prefix}/b{i}"], pad)
        if i < len(ARCH[n]) - 1:
            h = jax.nn.relu(h)
    return h.reshape(obs.shape[0])  # (B,1,1,1,1) -> (B,)


def policy_apply(theta, obs, n: int, use_pallas: bool = True):
    """(theta, obs[B, N+1, N+1, N+1, 3]) -> (mean[B], log_std[1], value[B]).

    mean is the scale-layer output 0.5*sigmoid(x) in [0, 0.5] (Table 2).
    """
    conv_fn = (
        (lambda x, w, b, pad: conv3d_ad(x, w, b, pad))
        if use_pallas
        else (lambda x, w, b, pad: conv3d_ref(x, w, b, padding=pad))
    )
    params = unflatten(theta, n)
    logits = _trunk(params, "actor", obs, n, conv_fn)
    mean = 0.5 * jax.nn.sigmoid(logits)
    value = _trunk(params, "critic", obs, n, conv_fn)
    return mean, params["log_std"], value


def gaussian_logp(act, mean, log_std):
    """Elementwise diagonal-Gaussian log density."""
    sigma = jnp.exp(log_std)
    z = (act - mean) / sigma
    return -0.5 * z * z - log_std - 0.5 * math.log(2.0 * math.pi)


# ---------------------------------------------------------------------------
# PPO train step (clipping variant, paper §5.3)
# ---------------------------------------------------------------------------


def ppo_loss(theta, obs, act, old_logp, adv, ret, n: int, use_pallas: bool = True):
    mean, log_std, value = policy_apply(theta, obs, n, use_pallas)
    logp = gaussian_logp(act, mean, log_std[0])
    ratio = jnp.exp(logp - old_logp)
    clipped = jnp.clip(ratio, 1.0 - CLIP_EPS, 1.0 + CLIP_EPS)
    pg_loss = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))
    v_loss = 0.5 * jnp.mean((value - ret) ** 2)
    entropy = jnp.mean(0.5 * math.log(2.0 * math.pi * math.e) + log_std)
    loss = pg_loss + VF_COEF * v_loss - ENT_COEF * entropy
    clipfrac = jnp.mean((jnp.abs(ratio - 1.0) > CLIP_EPS).astype(jnp.float32))
    approx_kl = jnp.mean(old_logp - logp)
    return loss, (pg_loss, v_loss, entropy, clipfrac, approx_kl)


def train_step(theta, m, v, step, obs, act, old_logp, adv, ret, n: int,
               use_pallas: bool = True):
    """One Adam step of the PPO objective on one minibatch.

    All state (params + Adam moments + step counter) is explicit, so the
    Rust coordinator owns it between calls.  Returns
    ``(theta', m', v', step', loss, pg, vf, entropy, clipfrac, approx_kl)``.
    """
    (loss, aux), grads = jax.value_and_grad(ppo_loss, has_aux=True)(
        theta, obs, act, old_logp, adv, ret, n, use_pallas
    )
    step = step + 1.0
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * grads
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * grads * grads
    mhat = m / (1.0 - ADAM_B1**step)
    vhat = v / (1.0 - ADAM_B2**step)
    theta = theta - LEARNING_RATE * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    pg, vf, ent, clipfrac, akl = aux
    return (theta, m, v, step, loss, pg, vf, ent, clipfrac, akl)
