"""Pure-jnp oracle for the Pallas conv3d kernel.

Uses ``lax.conv_general_dilated`` with NDHWC/DHWIO dimension numbers — a
completely independent code path from the shifted-matmul Pallas kernel, so
agreement is a meaningful correctness signal.
"""

import jax.numpy as jnp
from jax import lax


def conv3d_ref(x, w, b, *, padding: str = "valid"):
    """Reference 3-D convolution. Shapes as in ``conv3d.conv3d``."""
    pad = {"same": "SAME", "valid": "VALID"}[padding]
    out = lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1, 1),
        padding=pad,
        dimension_numbers=("NDHWC", "DHWIO", "NDHWC"),
    )
    return out + b.astype(jnp.float32)
