"""L1 — Pallas 3-D convolution kernel for the Relexi policy CNN (Table 2).

The policy network convolves each DG element's nodal velocity field
(``(N+1)^3 x 3``) down to a single Smagorinsky coefficient.  The spatial
extent is tiny (6^3 or 8^3), so the parallel axis is the *batch* of
elements (``n_envs * n_elems``).  The kernel therefore:

* maps the Pallas ``grid`` over batch tiles — one program instance owns a
  contiguous slab of elements whose activations fit comfortably in VMEM
  (``6^3 * 8 ch * 4 B = 6.9 KiB`` per element, far below the ~16 MiB VMEM
  budget even for 512-element tiles);
* expresses the convolution as a sum of **shifted matmuls**: for every
  static kernel offset ``(i, j, l)`` the input slab is sliced and contracted
  against the ``(Cin, Cout)`` filter plane.  Each contraction is a dense
  ``(B*Do*Ho*Wo, Cin) @ (Cin, Cout)`` matmul, i.e. MXU work, instead of the
  CUDA-style thread-per-output gather the paper's A100 setup would use.
  This is the GPU->TPU adaptation described in DESIGN.md §3.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and correctness is what the build-time pytest checks.  The
real-TPU resource estimate for the chosen tiling lives in EXPERIMENTS.md
§Perf.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default number of elements per Pallas program instance.  Chosen so the
# widest activation (6^3 x 8 f32) of a full tile stays < 1 MiB in VMEM while
# still giving the MXU a tall matmul operand. See EXPERIMENTS.md §Perf-L1.
DEFAULT_BLOCK_B = 64


def _out_spatial(in_dim: int, k: int, padding: str) -> int:
    if padding == "same":
        return in_dim
    if padding == "valid":
        return in_dim - k + 1
    raise ValueError(f"unsupported padding {padding!r}")


def _conv3d_kernel(x_ref, w_ref, b_ref, o_ref, *, k: int, padding: str):
    """One batch-tile of direct 3-D convolution as shifted matmuls.

    x_ref: (Bt, D, H, W, Cin)   w_ref: (k, k, k, Cin, Cout)
    b_ref: (Cout,)              o_ref: (Bt, Do, Ho, Wo, Cout)
    """
    x = x_ref[...]
    w = w_ref[...]
    bias = b_ref[...]
    bt, d, h, wd, cin = x.shape
    cout = w.shape[-1]

    if padding == "same":
        # zero padding, matching the paper's first conv layer
        lo = (k - 1) // 2
        hi = k - 1 - lo
        x = jnp.pad(x, ((0, 0), (lo, hi), (lo, hi), (lo, hi), (0, 0)))

    do = _out_spatial(d, k, padding)
    ho = _out_spatial(h, k, padding)
    wo = _out_spatial(wd, k, padding)

    acc = jnp.zeros((bt * do * ho * wo, cout), dtype=jnp.float32)
    # k is a static Python int (2 or 3): the offset loop fully unrolls at
    # trace time into k^3 shifted (rows, Cin) @ (Cin, Cout) matmuls.
    for i in range(k):
        for j in range(k):
            for l in range(k):
                sl = x[:, i : i + do, j : j + ho, l : l + wo, :]
                rows = sl.reshape(bt * do * ho * wo, cin)
                acc = acc + jnp.dot(
                    rows, w[i, j, l], preferred_element_type=jnp.float32
                )
    out = acc.reshape(bt, do, ho, wo, cout) + bias
    o_ref[...] = out.astype(o_ref.dtype)


def conv3d(x, w, b, *, padding: str = "valid", block_b: int | None = None):
    """Batched 3-D convolution (stride 1) via a Pallas kernel.

    Args:
      x: ``(B, D, H, W, Cin)`` input activations.
      w: ``(k, k, k, Cin, Cout)`` filters.
      b: ``(Cout,)`` bias.
      padding: ``"same"`` (zero padding) or ``"valid"``.
      block_b: elements per program instance; must divide ``B``.  Defaults to
        ``min(B, DEFAULT_BLOCK_B)``.

    Returns:
      ``(B, Do, Ho, Wo, Cout)`` output, f32.
    """
    bsz, d, h, wd, cin = x.shape
    k = int(w.shape[0])
    if w.shape[:3] != (k, k, k):
        raise ValueError(f"anisotropic kernels unsupported: {w.shape}")
    if w.shape[3] != cin:
        raise ValueError(f"Cin mismatch: x has {cin}, w has {w.shape[3]}")
    cout = int(w.shape[-1])

    if block_b is None:
        block_b = min(bsz, DEFAULT_BLOCK_B)
    if bsz % block_b != 0:
        # Fall back to one tile; shapes here are small and static.
        block_b = bsz

    do = _out_spatial(d, k, padding)
    ho = _out_spatial(h, k, padding)
    wo = _out_spatial(wd, k, padding)

    grid = (bsz // block_b,)
    return pl.pallas_call(
        partial(_conv3d_kernel, k=k, padding=padding),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d, h, wd, cin), lambda i: (i, 0, 0, 0, 0)),
            pl.BlockSpec((k, k, k, cin, cout), lambda i: (0, 0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec(
            (block_b, do, ho, wo, cout), lambda i: (i, 0, 0, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, do, ho, wo, cout), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w, b)
