"""AOT bridge: lower the L2 JAX functions to HLO *text* artifacts.

Run once by ``make artifacts``; the Rust runtime (``rust/src/runtime``)
loads the text with ``HloModuleProto::from_text_file``, compiles it on the
PJRT CPU client and executes it on the training hot path.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emitted into ``artifacts/``:

  policy_fwd_n{N}_b{B}.hlo.txt   (theta, obs[B,...]) -> (mean, log_std, value)
  train_step_n{N}_b{M}.hlo.txt   full PPO+Adam minibatch update
  params0_n{N}.bin               initial flat parameter vector (f32 LE)
  manifest.json                  layouts, shapes, hyperparameters
  testvec.json                   deterministic vectors for Rust round-trip
                                 tests (inputs + expected outputs)
"""

import argparse
import json
import math
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

POLICY_BATCHES = (64, 256, 1024)
TRAIN_BATCHES = (256, 1024)
NS = (5, 7)
SEED = 2022  # paper year; fixed for reproducible params0


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def obs_spec(n: int, b: int):
    return jax.ShapeDtypeStruct((b, n + 1, n + 1, n + 1, 3), jnp.float32)


def vec_spec(k: int):
    return jax.ShapeDtypeStruct((k,), jnp.float32)


def lower_policy(n: int, b: int, total: int) -> str:
    fn = partial(model.policy_apply, n=n)
    lowered = jax.jit(fn).lower(vec_spec(total), obs_spec(n, b))
    return to_hlo_text(lowered)


def lower_train(n: int, mb: int, total: int) -> str:
    fn = partial(model.train_step, n=n)
    lowered = jax.jit(fn).lower(
        vec_spec(total),                     # theta
        vec_spec(total),                     # adam m
        vec_spec(total),                     # adam v
        jax.ShapeDtypeStruct((), jnp.float32),  # step
        obs_spec(n, mb),
        vec_spec(mb),                        # act
        vec_spec(mb),                        # old_logp
        vec_spec(mb),                        # adv
        vec_spec(mb),                        # ret
    )
    return to_hlo_text(lowered)


def write(path: str, text: str):
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")


def make_testvec(n: int, theta: np.ndarray, out_dir: str) -> dict:
    """Deterministic inputs + expected outputs for the Rust runtime tests."""
    b = 256  # a batch size lowered for BOTH policy_fwd and train_step
    rng = np.random.default_rng(7)
    obs = rng.standard_normal((b, n + 1, n + 1, n + 1, 3)).astype(np.float32)
    obs.reshape(-1).tofile(os.path.join(out_dir, f"testvec_obs_n{n}.bin"))
    mean, log_std, value = jax.jit(partial(model.policy_apply, n=n))(
        jnp.asarray(theta), jnp.asarray(obs)
    )
    act = np.clip(np.asarray(mean) + 0.01, 0.0, 0.5).astype(np.float32)
    old_logp = np.asarray(
        model.gaussian_logp(jnp.asarray(act), mean, log_std[0])
    ).astype(np.float32)
    adv = rng.standard_normal(b).astype(np.float32)
    ret = rng.standard_normal(b).astype(np.float32)
    zeros = np.zeros_like(theta)
    out = jax.jit(partial(model.train_step, n=n))(
        jnp.asarray(theta), jnp.asarray(zeros), jnp.asarray(zeros),
        jnp.float32(0.0), jnp.asarray(obs), jnp.asarray(act),
        jnp.asarray(old_logp), jnp.asarray(adv), jnp.asarray(ret),
    )
    (theta2, _m2, _v2, step2, loss, pg, vf, ent, clipfrac, akl) = out
    return {
        "n": n,
        "batch": b,
        "obs_first8": [float(x) for x in obs.reshape(-1)[:8]],
        "obs_seed": 7,
        "mean": [float(x) for x in np.asarray(mean)],
        "value": [float(x) for x in np.asarray(value)],
        "log_std": float(np.asarray(log_std)[0]),
        "act": [float(x) for x in act],
        "old_logp": [float(x) for x in old_logp],
        "adv": [float(x) for x in adv],
        "ret": [float(x) for x in ret],
        "train_loss": float(loss),
        "train_pg": float(pg),
        "train_vf": float(vf),
        "train_entropy": float(ent),
        "train_clipfrac": float(clipfrac),
        "train_approx_kl": float(akl),
        "train_step_out": float(step2),
        "theta2_first8": [float(x) for x in np.asarray(theta2)[:8]],
        "theta2_l2": float(np.linalg.norm(np.asarray(theta2))),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only N=5, B=64/M=256 (for CI-style smoke runs)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    ns = (5,) if args.quick else NS
    pbs = (64,) if args.quick else POLICY_BATCHES
    tbs = (256,) if args.quick else TRAIN_BATCHES

    manifest = {
        "seed": SEED,
        "hyperparameters": {
            "learning_rate": model.LEARNING_RATE,
            "clip_eps": model.CLIP_EPS,
            "vf_coef": model.VF_COEF,
            "ent_coef": model.ENT_COEF,
            "adam_b1": model.ADAM_B1,
            "adam_b2": model.ADAM_B2,
            "adam_eps": model.ADAM_EPS,
            "log_std_init": model.LOG_STD_INIT,
        },
        "models": {},
        "artifacts": [],
    }

    for n in ns:
        layout, total = model.param_layout(n)
        theta0 = np.asarray(
            model.init_params(jax.random.PRNGKey(SEED), n), dtype=np.float32
        )
        pbin = os.path.join(args.out_dir, f"params0_n{n}.bin")
        theta0.tofile(pbin)
        print(f"  wrote {pbin} ({total} params)")
        manifest["models"][str(n)] = {
            "obs_shape": [n + 1, n + 1, n + 1, 3],
            "param_count": total,
            "trunk_param_count": model.trunk_param_count(n),
            "layout": [
                {"name": name, "shape": list(shape), "offset": off}
                for name, shape, off in layout
            ],
            "arch": [
                {"kernel": k, "filters": f, "padding": p} for k, f, p in model.ARCH[n]
            ],
        }
        for b in pbs:
            path = os.path.join(args.out_dir, f"policy_fwd_n{n}_b{b}.hlo.txt")
            write(path, lower_policy(n, b, total))
            manifest["artifacts"].append(
                {"kind": "policy_fwd", "n": n, "batch": b,
                 "file": os.path.basename(path)}
            )
        for mb in tbs:
            path = os.path.join(args.out_dir, f"train_step_n{n}_b{mb}.hlo.txt")
            write(path, lower_train(n, mb, total))
            manifest["artifacts"].append(
                {"kind": "train_step", "n": n, "batch": mb,
                 "file": os.path.basename(path)}
            )

    testvec = {str(n): make_testvec(n, np.fromfile(
        os.path.join(args.out_dir, f"params0_n{n}.bin"), dtype=np.float32),
        args.out_dir)
        for n in ns}
    with open(os.path.join(args.out_dir, "testvec.json"), "w") as f:
        json.dump(testvec, f)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("AOT artifacts complete.")


if __name__ == "__main__":
    main()
