//! Bench F3 — regenerates BOTH panels of the paper's Fig. 3 (weak scaling
//! of Relexi, 24 DOF and 32 DOF, 2/4/8/16 ranks per env, 2..full-partition
//! environments on 16 Hawk nodes) on the discrete-event cluster simulator,
//! and times the simulator itself.
//!
//! Expected shape (paper §6.1): near-ideal speedup at moderate counts;
//! efficiency decays toward the full partition; fewer ranks/env scale
//! better; a visible 1->2-env dip for 2-rank envs (die bandwidth sharing).

use relexi::hpc::{steps_per_action_for, weak_scaling, ClusterSim};
use relexi::util::bench::{Bench, Table};

fn main() {
    let sim = ClusterSim::hawk(16);

    for dof in [24usize, 32] {
        let spa = steps_per_action_for(dof);
        let mut table = Table::new(&["ranks/env", "n_envs", "cores", "speedup", "ideal", "efficiency"]);
        for ranks in [2usize, 4, 8, 16] {
            let pts = weak_scaling(&sim, dof, ranks, spa).unwrap();
            for p in &pts {
                table.row(vec![
                    ranks.to_string(),
                    p.n_envs.to_string(),
                    (p.n_envs * ranks).to_string(),
                    format!("{:.1}", p.speedup),
                    p.n_envs.to_string(),
                    format!("{:.3}", p.efficiency),
                ]);
            }
        }
        table.print(&format!("Fig. 3 — weak scaling, {dof} DOF"));
    }

    // Shape assertions: the qualitative claims of §6.1 must hold.
    let e2 = weak_scaling(&sim, 24, 2, 3.0).unwrap();
    let e16 = weak_scaling(&sim, 24, 16, 3.0).unwrap();
    let eff = |pts: &[relexi::hpc::ScalingPoint], n: usize| {
        pts.iter().find(|p| p.n_envs == n).map(|p| p.efficiency)
    };
    assert!(eff(&e2, 128).unwrap() > eff(&e16, 128).unwrap(),
            "SHAPE VIOLATION: fewer ranks/env should scale better");
    assert!(eff(&e2, 1024).unwrap() < eff(&e2, 32).unwrap(),
            "SHAPE VIOLATION: efficiency should decay toward full partition");
    println!("\nshape checks passed: fewer-ranks-scale-better, efficiency decay");

    // Timing of the simulator itself (it backs every scaling experiment).
    let mut b = Bench::new("weak-scaling-sim");
    b.run("full Fig.3 sweep (both DOF, 4 rank counts)", || {
        for dof in [24usize, 32] {
            let spa = steps_per_action_for(dof);
            for ranks in [2usize, 4, 8, 16] {
                std::hint::black_box(weak_scaling(&sim, dof, ranks, spa).unwrap());
            }
        }
    });
}
