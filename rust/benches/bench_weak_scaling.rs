//! Bench F3 — two halves:
//!
//! 1. Regenerates BOTH panels of the paper's Fig. 3 (weak scaling of
//!    Relexi, 24 DOF and 32 DOF, 2/4/8/16 ranks per env,
//!    2..full-partition environments on 16 Hawk nodes) on the
//!    discrete-event cluster simulator, with the §6.1 shape assertions,
//!    and times the simulator itself.
//! 2. Measures the REAL exchange, weak-scaled: a FIXED per-env state
//!    payload, so doubling E doubles the bytes per wave — one row per
//!    transport (`wave/{inproc,shm,tcp}/envs{E}`) through the
//!    [`WaveRig`] harness.
//!
//! Expected shape (paper §6.1 + the transport seam): near-ideal DES
//! speedup at moderate counts; in the exchange half, per-wave time
//! divided by E (the per-env cost) stays roughly flat for `tcp` —
//! connections serve envs independently, which is what makes the
//! process-worker split scale.  Results land in
//! `BENCH_weak_scaling.json`; `BENCH_SMOKE=1` shrinks everything to CI
//! size.

use relexi::hpc::{steps_per_action_for, weak_scaling, ClusterSim};
use relexi::orchestrator::waverig::WaveRig;
use relexi::util::bench::{Bench, Table};
use std::time::Duration;

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let sim = ClusterSim::hawk(16);

    for dof in [24usize, 32] {
        let spa = steps_per_action_for(dof);
        let mut table = Table::new(&["ranks/env", "n_envs", "cores", "speedup", "ideal", "efficiency"]);
        for ranks in [2usize, 4, 8, 16] {
            let pts = weak_scaling(&sim, dof, ranks, spa).unwrap();
            for p in &pts {
                table.row(vec![
                    ranks.to_string(),
                    p.n_envs.to_string(),
                    (p.n_envs * ranks).to_string(),
                    format!("{:.1}", p.speedup),
                    p.n_envs.to_string(),
                    format!("{:.3}", p.efficiency),
                ]);
            }
        }
        table.print(&format!("Fig. 3 — weak scaling, {dof} DOF"));
    }

    // Shape assertions: the qualitative claims of §6.1 must hold.
    let e2 = weak_scaling(&sim, 24, 2, 3.0).unwrap();
    let e16 = weak_scaling(&sim, 24, 16, 3.0).unwrap();
    let eff = |pts: &[relexi::hpc::ScalingPoint], n: usize| {
        pts.iter().find(|p| p.n_envs == n).map(|p| p.efficiency)
    };
    assert!(eff(&e2, 128).unwrap() > eff(&e16, 128).unwrap(),
            "SHAPE VIOLATION: fewer ranks/env should scale better");
    assert!(eff(&e2, 1024).unwrap() < eff(&e2, 32).unwrap(),
            "SHAPE VIOLATION: efficiency should decay toward full partition");
    println!("\nshape checks passed: fewer-ranks-scale-better, efficiency decay");

    // Timing of the simulator itself (it backs every scaling experiment).
    let mut b = if smoke {
        Bench::new("weak-scaling")
            .with_warmup(Duration::from_millis(50))
            .with_target(Duration::from_millis(200))
            .with_max_samples(10)
    } else {
        Bench::new("weak-scaling")
    };
    b.run("full Fig.3 sweep (both DOF, 4 rank counts)", || {
        for dof in [24usize, 32] {
            let spa = steps_per_action_for(dof);
            for ranks in [2usize, 4, 8, 16] {
                std::hint::black_box(weak_scaling(&sim, dof, ranks, spa).unwrap());
            }
        }
    });

    // The real exchange, weak-scaled: a FIXED per-env state payload per
    // wave (the per-env LES state doesn't shrink when envs are added).
    let per_env_floats: usize = if smoke { 1 << 12 } else { 1 << 15 };
    let env_counts: &[usize] = if smoke { &[2, 8] } else { &[2, 8, 32] };
    let kinds: &[&str] = if cfg!(unix) {
        &["inproc", "shm", "tcp"]
    } else {
        &["inproc", "tcp"]
    };
    for &kind in kinds {
        for &envs in env_counts {
            let mut rig = WaveRig::start(kind, &vec![per_env_floats; envs], 8)
                .unwrap_or_else(|e| panic!("wave rig {kind}/{envs}: {e:#}"));
            b.run(&format!("wave/{kind}/envs{envs}"), || rig.run_wave());
        }
    }

    // Batched A/B (PR-9): the same weak-scaled waves through the
    // wave-coalesced path (4 envs per block, like the worker plan).
    // Doubling E doubles the bytes per wave but the FRAME count per
    // wave only grows with the block count.
    for &kind in kinds {
        for &envs in env_counts {
            let blocks = (envs / 4).max(1);
            let mut rig = WaveRig::start_batched(kind, &vec![per_env_floats; envs], 8, blocks)
                .unwrap_or_else(|e| panic!("batched wave rig {kind}/{envs}: {e:#}"));
            b.run(&format!("wave-batched/{kind}/envs{envs}"), || rig.run_wave());
        }
    }

    b.write_json("BENCH_weak_scaling.json")
        .expect("write BENCH_weak_scaling.json");
}
