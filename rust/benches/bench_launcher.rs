//! Bench A2 — the §3.3 launch-path optimizations: MPMD vs individual
//! mpirun, RAM-drive vs Lustre staging ("for some configurations, the time
//! required for starting the simulations exceeded the actual simulation
//! time... with these improvements in place, the performance penalty...
//! became negligible"), plus the real cost of rankfile generation.

use relexi::hpc::Topology;
use relexi::launcher::{place, LaunchMode, Launcher, StagingMode};
use relexi::util::bench::{Bench, Table};

fn main() {
    let launcher = Launcher::new(Topology::hawk(16));

    let mut table = Table::new(&[
        "n_envs",
        "ranks",
        "individual+lustre [s]",
        "mpmd+ram [s]",
        "reduction",
    ]);
    for (n_envs, ranks) in [(16usize, 8usize), (64, 8), (256, 4), (512, 4), (1024, 2)] {
        let slow_plan = launcher
            .plan(n_envs, ranks, LaunchMode::Individual, StagingMode::Lustre)
            .unwrap();
        let fast_plan = launcher
            .plan(n_envs, ranks, LaunchMode::Mpmd, StagingMode::RamDrive)
            .unwrap();
        let slow = launcher.startup_time(&slow_plan, 6, 2e6);
        let fast = launcher.startup_time(&fast_plan, 6, 2e6);
        table.row(vec![
            n_envs.to_string(),
            ranks.to_string(),
            format!("{slow:.2}"),
            format!("{fast:.2}"),
            format!("{:.0}x", slow / fast),
        ]);
    }
    table.print("§3.3 — launch overhead: naive vs optimized (exp. A2)");

    // The paper's qualitative claim: at hundreds of envs, naive launch
    // exceeds the ~15-20 s sampling time; optimized launch is negligible.
    let slow_plan = launcher
        .plan(512, 4, LaunchMode::Individual, StagingMode::Lustre)
        .unwrap();
    let fast_plan = launcher
        .plan(512, 4, LaunchMode::Mpmd, StagingMode::RamDrive)
        .unwrap();
    assert!(launcher.startup_time(&slow_plan, 6, 2e6) > 20.0);
    assert!(launcher.startup_time(&fast_plan, 6, 2e6) < 15.0);
    println!("\nshape check passed: naive launch dominates sampling; MPMD+RAM negligible");

    // Real cost of the placement/rankfile machinery itself.
    let topo = Topology::hawk(16);
    let mut b = Bench::new("launcher");
    b.run("place 1024 x 2-rank instances", || {
        std::hint::black_box(place(&topo, 1024, 2).unwrap());
    });
    b.run("rankfile text for 2048 ranks", || {
        let p = place(&topo, 1024, 2).unwrap();
        std::hint::black_box(p.rankfile_text());
    });
    b.run("die occupancy for 2048 ranks", || {
        let p = place(&topo, 1024, 2).unwrap();
        std::hint::black_box(p.die_occupancy());
    });
}
