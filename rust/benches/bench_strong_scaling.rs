//! Bench F4 — two halves:
//!
//! 1. Regenerates both panels of the paper's Fig. 4 (strong scaling of
//!    FLEXI within Relexi: 2/8/32/128 parallel envs, 2->16 ranks per
//!    env, 24 and 32 DOF) on the simulated cluster, with the §6.1 shape
//!    assertions.
//! 2. Measures the REAL exchange: a fixed total state payload split
//!    over E env threads (strong scaling of the wave), one row per
//!    transport (`wave/{inproc,shm,tcp}/envs{E}`) through the
//!    [`WaveRig`] harness — per-wave latency of the transport seam with
//!    zero CFD work in the loop.
//!
//! Expected shape: near-ideal FLEXI scaling in the DES half; in the
//! exchange half `shm` stays within a small factor of `inproc` while
//! `tcp` pays the kernel round trips, and strong-scaling the wave keeps
//! the total bytes constant so per-wave time is dominated by per-env
//! exchange overhead as E grows.  Results land in
//! `BENCH_strong_scaling.json`; `BENCH_SMOKE=1` shrinks everything to
//! CI size.

use relexi::hpc::{steps_per_action_for, strong_scaling, ClusterSim};
use relexi::orchestrator::waverig::WaveRig;
use relexi::util::bench::{Bench, Table};
use std::time::Duration;

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let sim = ClusterSim::hawk(16);
    let ranks = [2usize, 4, 8, 16];

    for dof in [24usize, 32] {
        let spa = steps_per_action_for(dof);
        let mut table = Table::new(&["n_envs", "ranks/env", "time [s]", "speedup", "ideal", "efficiency"]);
        for envs in [2usize, 8, 32, 128] {
            for p in strong_scaling(&sim, dof, envs, &ranks, spa).unwrap() {
                table.row(vec![
                    envs.to_string(),
                    p.ranks_per_env.to_string(),
                    format!("{:.2}", p.total_s),
                    format!("{:.2}", p.speedup),
                    p.ranks_per_env.to_string(),
                    format!("{:.3}", p.efficiency),
                ]);
            }
        }
        table.print(&format!("Fig. 4 — strong scaling, {dof} DOF"));
    }

    // Shape assertions.
    let pts = strong_scaling(&sim, 24, 8, &ranks, 3.0).unwrap();
    assert!(pts.windows(2).all(|w| w[1].speedup > w[0].speedup),
            "SHAPE VIOLATION: speedup must grow with ranks");
    assert!(pts.last().unwrap().efficiency < 0.75,
            "SHAPE VIOLATION: 16 ranks/env should be clearly sub-ideal");
    assert!(pts[1].efficiency > pts.last().unwrap().efficiency,
            "SHAPE VIOLATION: efficiency must decay with ranks");
    println!("\nshape checks passed: monotone speedup, 16-rank saturation");

    let mut b = if smoke {
        Bench::new("strong-scaling")
            .with_warmup(Duration::from_millis(50))
            .with_target(Duration::from_millis(200))
            .with_max_samples(10)
    } else {
        Bench::new("strong-scaling")
    };
    b.run("full Fig.4 sweep (both DOF, 4 env counts)", || {
        for dof in [24usize, 32] {
            let spa = steps_per_action_for(dof);
            for envs in [2usize, 8, 32, 128] {
                std::hint::black_box(strong_scaling(&sim, dof, envs, &ranks, spa).unwrap());
            }
        }
    });

    // The real exchange, strong-scaled: a FIXED total state payload per
    // wave split evenly over E envs, so adding envs adds per-env
    // exchange overhead without adding bytes.
    let total_floats: usize = if smoke { 1 << 14 } else { 1 << 20 };
    let env_counts: &[usize] = if smoke { &[2, 8] } else { &[2, 8, 32] };
    let kinds: &[&str] = if cfg!(unix) {
        &["inproc", "shm", "tcp"]
    } else {
        &["inproc", "tcp"]
    };
    for &kind in kinds {
        for &envs in env_counts {
            let per_env = (total_floats / envs).max(1);
            let mut rig = WaveRig::start(kind, &vec![per_env; envs], 8)
                .unwrap_or_else(|e| panic!("wave rig {kind}/{envs}: {e:#}"));
            b.run(&format!("wave/{kind}/envs{envs}"), || rig.run_wave());
        }
    }

    // Batched A/B (PR-9): the same strong-scaled waves through the
    // wave-coalesced path — one PutMany/TakeMany frame per worker block
    // per wave direction (4 envs per block, like the worker plan)
    // instead of one frame per env per op.
    for &kind in kinds {
        for &envs in env_counts {
            let per_env = (total_floats / envs).max(1);
            let blocks = (envs / 4).max(1);
            let mut rig = WaveRig::start_batched(kind, &vec![per_env; envs], 8, blocks)
                .unwrap_or_else(|e| panic!("batched wave rig {kind}/{envs}: {e:#}"));
            b.run(&format!("wave-batched/{kind}/envs{envs}"), || rig.run_wave());
        }
    }

    b.write_json("BENCH_strong_scaling.json")
        .expect("write BENCH_strong_scaling.json");
}
