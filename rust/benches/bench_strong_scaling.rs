//! Bench F4 — regenerates both panels of the paper's Fig. 4 (strong
//! scaling of FLEXI within Relexi: 2/8/32/128 parallel envs, 2->16 ranks
//! per env, 24 and 32 DOF) on the simulated cluster.
//!
//! Expected shape (paper §6.1): near-ideal FLEXI scaling recovered while
//! the per-core load is healthy; efficiency drops at 16 ranks/env where
//! the load per core falls "quite below the optimal load"; the head-node
//! work makes high-env-count curves saturate earlier.

use relexi::hpc::{steps_per_action_for, strong_scaling, ClusterSim};
use relexi::util::bench::{Bench, Table};

fn main() {
    let sim = ClusterSim::hawk(16);
    let ranks = [2usize, 4, 8, 16];

    for dof in [24usize, 32] {
        let spa = steps_per_action_for(dof);
        let mut table = Table::new(&["n_envs", "ranks/env", "time [s]", "speedup", "ideal", "efficiency"]);
        for envs in [2usize, 8, 32, 128] {
            for p in strong_scaling(&sim, dof, envs, &ranks, spa).unwrap() {
                table.row(vec![
                    envs.to_string(),
                    p.ranks_per_env.to_string(),
                    format!("{:.2}", p.total_s),
                    format!("{:.2}", p.speedup),
                    p.ranks_per_env.to_string(),
                    format!("{:.3}", p.efficiency),
                ]);
            }
        }
        table.print(&format!("Fig. 4 — strong scaling, {dof} DOF"));
    }

    // Shape assertions.
    let pts = strong_scaling(&sim, 24, 8, &ranks, 3.0).unwrap();
    assert!(pts.windows(2).all(|w| w[1].speedup > w[0].speedup),
            "SHAPE VIOLATION: speedup must grow with ranks");
    assert!(pts.last().unwrap().efficiency < 0.75,
            "SHAPE VIOLATION: 16 ranks/env should be clearly sub-ideal");
    assert!(pts[1].efficiency > pts.last().unwrap().efficiency,
            "SHAPE VIOLATION: efficiency must decay with ranks");
    println!("\nshape checks passed: monotone speedup, 16-rank saturation");

    let mut b = Bench::new("strong-scaling-sim");
    b.run("full Fig.4 sweep (both DOF, 4 env counts)", || {
        for dof in [24usize, 32] {
            let spa = steps_per_action_for(dof);
            for envs in [2usize, 8, 32, 128] {
                std::hint::black_box(strong_scaling(&sim, dof, envs, &ranks, spa).unwrap());
            }
        }
    });
}
