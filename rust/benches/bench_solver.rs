//! Bench S — the environment substrate's hot path: FFT, RHS/step costs at
//! the Table-1 resolutions, SGS overhead, and observation gathering.
//! These numbers calibrate the HPC cost model (EnvCostModel) and are the
//! §Perf-L3 baseline in EXPERIMENTS.md.

use relexi::fft::{fft3d_pool, fft3d_ws, Cpx, FftScratch, Plan};
use relexi::solver::dns::filter_to_les_pool;
use relexi::solver::forcing::LinearForcing;
use relexi::solver::init::random_solenoidal;
use relexi::solver::{Grid, Solver};
use relexi::util::bench::{Bench, Table};
use relexi::util::pool::{self, Pool};
use relexi::util::simd::{self, Level};
use relexi::util::Rng;
use std::time::Duration;

fn prepared_solver(n: usize, elems: usize, cs: f64, seed: u64) -> Solver {
    let mut s = Solver::new(n, elems, 1.0 / 400.0, 0.5);
    let mut rng = Rng::new(seed);
    s.set_state(random_solenoidal(&s.grid, 1.5, 4.0, &mut rng));
    s.forcing = Some(LinearForcing::new(1.5, 1.0));
    if cs > 0.0 {
        s.set_cs_uniform(cs);
    }
    // Prime vmax for stable_dt.
    s.advance(1e-3);
    s
}

fn main() {
    let mut b = Bench::new("solver").with_target(Duration::from_secs(2));

    // --- FFT (batched engine through the solver's workspace path) ----------
    for n in [24usize, 32, 48] {
        let plan = Plan::new(n);
        let mut ws = FftScratch::new(n);
        let mut data = vec![Cpx::new(1.0, 0.5); n * n * n];
        b.run(&format!("fft3d {n}^3 (fwd+inv)"), || {
            fft3d_ws(&mut data, &plan, false, &mut ws);
            fft3d_ws(&mut data, &plan, true, &mut ws);
        });
    }

    // --- kernel variants (PR 6): scalar vs SIMD dispatch and 1 vs N ---------
    // --- worker threads on the solver's dominant transform.  Outputs ---------
    // --- are bit-identical across every variant.                     ---------
    let native = simd::level();
    let pool1 = Pool::new(1);
    let pooln = pool::global();
    {
        let n = 48usize;
        let plan_s = Plan::with_level(n, Level::Scalar);
        let plan_v = Plan::new(n);
        let mut ws = FftScratch::new(n);
        let mut data = vec![Cpx::new(1.0, 0.5); n * n * n];
        b.run(&format!("fft3d {n}^3 [scalar] (fwd+inv)"), || {
            fft3d_ws(&mut data, &plan_s, false, &mut ws);
            fft3d_ws(&mut data, &plan_s, true, &mut ws);
        });
        b.run(&format!("fft3d {n}^3 [{}] (fwd+inv)", native.label()), || {
            fft3d_ws(&mut data, &plan_v, false, &mut ws);
            fft3d_ws(&mut data, &plan_v, true, &mut ws);
        });
        let mut buf = vec![Cpx::ZERO; n * n * n];
        let mut plane = vec![Cpx::ZERO; n * n];
        b.run(&format!("fft3d {n}^3 [threads=1] (fwd+inv)"), || {
            fft3d_pool(&mut data, &plan_v, false, &mut buf, &mut plane, &pool1);
            fft3d_pool(&mut data, &plan_v, true, &mut buf, &mut plane, &pool1);
        });
        let label_n = format!("fft3d {n}^3 [threads={}] (fwd+inv)", pooln.threads());
        b.run(&label_n, || {
            fft3d_pool(&mut data, &plan_v, false, &mut buf, &mut plane, &pooln);
            fft3d_pool(&mut data, &plan_v, true, &mut buf, &mut plane, &pooln);
        });
    }

    // --- DNS -> LES spectral filter across pool widths (truth path) ---------
    {
        let dns_grid = Grid::new(48);
        let les_grid = Grid::new(24);
        let mut rng = Rng::new(9);
        let u = random_solenoidal(&dns_grid, 1.5, 4.0, &mut rng);
        b.run("filter 48^3 -> 24^3 [threads=1]", || {
            std::hint::black_box(filter_to_les_pool(&dns_grid, &u, &les_grid, &pool1));
        });
        let label_n = format!("filter 48^3 -> 24^3 [threads={}]", pooln.threads());
        b.run(&label_n, || {
            std::hint::black_box(filter_to_les_pool(&dns_grid, &u, &les_grid, &pooln));
        });
    }

    // --- solver step at Table-1 resolutions --------------------------------
    let mut table = Table::new(&["case", "grid", "SGS", "ms/step", "steps per dt_RL", "s per action"]);
    for (name, n, cs) in [
        ("24 DOF implicit", 24usize, 0.0),
        ("24 DOF smagorinsky", 24, 0.17),
        ("32 DOF implicit", 32, 0.0),
        ("32 DOF smagorinsky", 32, 0.17),
    ] {
        let mut s = prepared_solver(n, 4, cs, 1);
        let dt = s.stable_dt();
        let m = b.run(&format!("step {name}"), || {
            s.step(dt.min(1e-4)); // tiny dt: cost is dt-independent
        });
        let steps_per_action = (0.1 / dt).ceil();
        table.row(vec![
            name.to_string(),
            format!("{n}^3"),
            if cs > 0.0 { "on" } else { "off" }.to_string(),
            format!("{:.2}", m.mean_s * 1e3),
            format!("{steps_per_action:.0}"),
            format!("{:.3}", m.mean_s * steps_per_action),
        ]);
    }
    table.print("Solver cost at Table-1 resolutions (calibrates EnvCostModel)");

    // --- full RL action interval (the per-step cost during training) -------
    let mut s24 = prepared_solver(24, 4, 0.1, 2);
    b.run("advance dt_RL=0.1 @ 24^3 (SGS on)", || {
        s24.advance(0.1);
    });

    // --- observation gather (state extraction for the orchestrator) --------
    let mut s = prepared_solver(24, 4, 0.0, 3);
    b.run("gather observations 64 x 6^3 x 3", || {
        std::hint::black_box(s.observations());
    });

    // --- spectrum (reward path) --------------------------------------------
    let s = prepared_solver(24, 4, 0.0, 4);
    b.run("energy spectrum 24^3", || {
        std::hint::black_box(s.spectrum());
    });

    println!("\ntransform count so far: {}", s24.stats.transforms);

    if let Err(e) = b.write_json("BENCH_solver.json") {
        eprintln!("warning: could not write BENCH_solver.json: {e}");
    } else {
        println!("wrote BENCH_solver.json");
    }
}
