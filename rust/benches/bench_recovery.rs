//! Bench R — what a worker crash costs the fault-tolerant rollout
//! runtime (PR-8).
//!
//! Drives the loopback-TCP 8-env Burgers pool (2 env-worker processes
//! x 4 envs) under deterministic fault plans and reports:
//!
//! * `wave/fault-free`          — per-wave wall clock, no fault plan;
//! * `wave/crash-every-wave`    — per-wave wall clock under
//!   `kill:w0@1*` (every worker-0 generation exits on its second
//!   begin), so each steady-state wave pays one full detect + respawn +
//!   replay cycle; the delta against `wave/fault-free` is the total
//!   price of losing a worker per wave;
//! * `detect/child-exit`, `recover/respawn-replay` — the supervisor's
//!   own per-incident split from [`SupervisionReport`];
//! * `detect/killput`, `recover/killput` — the same split for a
//!   mid-wave `killput:w0@25` crash (the transport aborts the process
//!   after its 25th put, so the block dies with a partial episode
//!   prefix on the wire and recovery must replay it).
//!
//! The crashing run must stay bit-identical to the fault-free run at
//! the same seed — asserted here over a reward/action fingerprint per
//! wave, mirroring the in-tree chaos test.  Results land in
//! `BENCH_recovery.json`; `BENCH_SMOKE=1` shrinks the wave count.
//!
//! [`SupervisionReport`]: relexi::coordinator::SupervisionReport

use relexi::config::{BurgersConfig, EnvVariant, RunConfig};
use relexi::coordinator::EnvPool;
use relexi::orchestrator::{Orchestrator, Protocol};
use relexi::runtime::stub_policy;
use relexi::util::bench::Bench;
use relexi::util::Rng;
use std::time::Instant;

/// The integration suite's 8-env Burgers case over real env-worker
/// processes and loopback TCP, with a tight heartbeat so detection is
/// measured, not the default 10 s expiry.
fn pool_cfg(plan: &str, max_respawns: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.rl.backend = "burgers".to_string();
    cfg.burgers = BurgersConfig {
        points: 48,
        segments: 4,
        k_max: 6,
        t_end: 0.5, // 5 actions at the base horizon
        truth_states: 4,
        truth_spinup: 1.0,
        truth_interval: 0.25,
        ..BurgersConfig::default()
    };
    cfg.rl.n_envs = 8;
    cfg.rl.split_init_pool = true;
    cfg.rl.variants = vec![
        EnvVariant::default(),
        EnvVariant {
            name: "short".into(),
            t_end_scale: 0.6,
            ..EnvVariant::default()
        },
    ];
    cfg.orchestrator.workers = "processes".to_string();
    cfg.orchestrator.transport = "tcp".to_string();
    cfg.orchestrator.env_procs = 2;
    cfg.orchestrator.worker_bin = env!("CARGO_BIN_EXE_relexi").to_string();
    cfg.orchestrator.heartbeat_period_ms = 200;
    cfg.orchestrator.heartbeat_expiry_ms = 2000;
    cfg.fault.plan = plan.to_string();
    cfg.fault.max_respawns = max_respawns;
    cfg
}

/// FNV-1a over every action and reward bit of a wave's episodes: two
/// runs producing the same fingerprint per wave stepped bit-identically.
fn fingerprint(episodes: &[relexi::rl::Episode]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |x: u32| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    for ep in episodes {
        for s in &ep.steps {
            for a in &s.act {
                mix(a.to_bits());
            }
            mix(s.reward.to_bits());
        }
    }
    h
}

struct WaveStats {
    wave_s: Vec<f64>,
    detect_s: Vec<f64>,
    recover_s: Vec<f64>,
    respawns: usize,
    fingerprints: Vec<u64>,
}

/// Run `waves` sampling iterations on one persistent pool, collecting
/// wall-clock and supervision timings.  Panics if any wave degrades
/// (this bench measures recovery, not degradation).
fn run_waves(cfg: RunConfig, seed: u64, waves: usize) -> WaveStats {
    let n_envs = cfg.rl.n_envs;
    let orch = Orchestrator::launch(cfg.hpc.db_shards);
    let mut pool = EnvPool::from_config(cfg, None, &orch).expect("build pool");
    let mut rng = Rng::new(seed);
    let mut out = WaveStats {
        wave_s: Vec::with_capacity(waves),
        detect_s: Vec::new(),
        recover_s: Vec::new(),
        respawns: 0,
        fingerprints: Vec::with_capacity(waves),
    };
    for it in 0..waves {
        let t0 = Instant::now();
        let r = pool
            .collect_with(
                &orch,
                &Protocol::new(&format!("rb{it}")),
                stub_policy,
                &mut rng,
                false,
                n_envs,
            )
            .expect("collect wave");
        out.wave_s.push(t0.elapsed().as_secs_f64());
        orch.clear();
        assert_eq!(
            r.episodes.len(),
            n_envs,
            "wave {it} degraded; raise max_respawns"
        );
        out.detect_s.extend_from_slice(&r.supervision.detect_s);
        out.recover_s.extend_from_slice(&r.supervision.recover_s);
        out.respawns += r.supervision.respawns;
        out.fingerprints.push(fingerprint(&r.episodes));
    }
    out
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let waves = if smoke { 4 } else { 10 };
    let mut b = Bench::new("recovery");

    // Baseline: the same pool with no fault plan.
    let clean = run_waves(pool_cfg("", 0), 101, waves);
    assert_eq!(clean.respawns, 0, "fault-free run respawned a worker");
    b.record("wave/fault-free", &clean.wave_s);

    // One crash per steady-state wave: each worker-0 generation serves
    // exactly one wave, then exits on seeing its second begin.
    let crashy = run_waves(pool_cfg("kill:w0@1*", waves + 1), 101, waves);
    assert_eq!(
        crashy.respawns,
        waves - 1,
        "kill:w0@1* should crash every steady-state wave"
    );
    assert_eq!(
        clean.fingerprints, crashy.fingerprints,
        "recovered waves diverged from the fault-free run"
    );
    b.record("wave/crash-every-wave", &crashy.wave_s[1..]);
    b.record("detect/child-exit", &crashy.detect_s);
    b.record("recover/respawn-replay", &crashy.recover_s);

    // A mid-wave killput: the crashed block has already published part
    // of its episodes, so recovery replays a non-empty action prefix.
    let killput = run_waves(pool_cfg("killput:w0@25", 2), 103, 2);
    assert!(
        killput.respawns >= 1,
        "killput:w0@25 never fired (puts budget off?)"
    );
    assert_eq!(
        killput.fingerprints,
        run_waves(pool_cfg("", 0), 103, 2).fingerprints,
        "killput recovery diverged from the fault-free run"
    );
    if killput.detect_s.is_empty() {
        // The abort can land exactly between waves; the incident is then
        // handled (and timed) by begin_iteration's respawn path instead.
        println!("[recovery] killput landed between waves; no mid-wave split recorded");
    } else {
        b.record("detect/killput", &killput.detect_s);
        b.record("recover/killput", &killput.recover_s);
    }

    b.write_json("BENCH_recovery.json")
        .expect("write BENCH_recovery.json");
}
