//! Bench W1 — the paper's §6.2 wall-clock split: "Sampling the
//! trajectories took 15 and 18 seconds per iteration [16 vs 64 envs],
//! while updating the policy ... took 0.5 and 2 seconds".
//!
//! Measures the REAL system: policy-inference latency per compiled batch
//! size, the compiled PPO train-step latency, a full sampling phase
//! (parallel LES env workers through the orchestrator) at growing env
//! counts, and the sampling/update split of one complete iteration.
//!
//! Requires `make artifacts`.  Uses a reduced 12^3 environment so the
//! bench completes in ~2 minutes; the *ratios* are the experiment.

use relexi::config::{CaseConfig, RunConfig};
use relexi::coordinator::EnvPool;
use relexi::orchestrator::{Orchestrator, Protocol};
use relexi::rl::flatten;
use relexi::runtime::{Minibatch, PolicyRuntime, Registry, Runtime, TrainerRuntime};
use relexi::solver::dns::{generate, TruthParams};
use relexi::util::bench::{Bench, Table};
use relexi::util::Rng;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("bench_training: artifacts missing, run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let reg = Registry::open(dir).unwrap();
    let policy = PolicyRuntime::load(&rt, &reg, 5).unwrap();
    let theta = reg.initial_params(5).unwrap();
    let feat = policy.features();

    // --- policy inference latency per batch ---------------------------------
    let mut b = Bench::new("policy-fwd").with_target(Duration::from_secs(2));
    let mut rng = Rng::new(1);
    let mut table = Table::new(&["batch (elements)", "latency", "us/element"]);
    for batch in [64usize, 256, 1024, 4096] {
        let obs: Vec<f32> = (0..batch * feat).map(|_| rng.normal() as f32).collect();
        let m = b.run(&format!("forward b={batch}"), || {
            std::hint::black_box(policy.forward(&theta, &obs, batch).unwrap());
        });
        table.row(vec![
            batch.to_string(),
            relexi::util::bench::fmt_duration(m.mean_s),
            format!("{:.2}", m.mean_s * 1e6 / batch as f64),
        ]);
    }
    table.print("Policy inference (compiled Pallas CNN via PJRT)");

    // --- compiled PPO train step ---------------------------------------------
    let mut trainer = TrainerRuntime::load(&rt, &reg, 5, 256).unwrap();
    let mb = trainer.minibatch;
    let obs: Vec<f32> = (0..mb * feat).map(|_| rng.normal() as f32).collect();
    let act: Vec<f32> = (0..mb).map(|_| rng.uniform_f32() * 0.5).collect();
    let logp = vec![-1.0f32; mb];
    let adv: Vec<f32> = (0..mb).map(|_| rng.normal() as f32).collect();
    let ret: Vec<f32> = (0..mb).map(|_| rng.normal() as f32).collect();
    let m_train = b.run(&format!("train_step b={mb} (loss+grad+Adam)"), || {
        std::hint::black_box(
            trainer
                .train_minibatch(&Minibatch {
                    obs: &obs,
                    act: &act,
                    old_logp: &logp,
                    adv: &adv,
                    ret: &ret,
                })
                .unwrap(),
        );
    });

    // --- full sampling phase at growing env counts ---------------------------
    // Reduced environment (12^3, 8 elements) so the bench stays short.
    let mut cfg = RunConfig::default();
    cfg.case = CaseConfig {
        name: "bench".into(),
        n: 5,
        elems_per_dir: 2,
        k_max: 3,
        alpha: 0.4,
    };
    cfg.solver.t_end = 0.5; // 5 actions
    cfg.solver.dns_points = 24;
    let truth = Arc::new(generate(
        &TruthParams {
            n_dns: 24,
            n_les: 12,
            nu: cfg.solver.nu,
            ke_target: cfg.solver.ke_target,
            spinup_time: 1.0,
            n_states: 4,
            sample_interval: 0.25,
            seed: 5,
        },
        |_, _| {},
    ));

    let mut split = Table::new(&[
        "n_envs",
        "sampling [s]",
        "policy share [s]",
        "update (5 epochs) [s]",
        "sample:update ratio",
    ]);
    for n_envs in [4usize, 8, 16] {
        let mut cfg_n = cfg.clone();
        cfg_n.rl.n_envs = n_envs;
        let pool = EnvPool::new(cfg_n.clone(), truth.clone());
        let orch = Orchestrator::launch(cfg_n.hpc.db_shards);
        let mut rng_s = Rng::new(100 + n_envs as u64);
        let proto = Protocol::new(&format!("bench{n_envs}"));
        let rollouts = pool
            .collect(&orch, &proto, &policy, &theta, &mut rng_s, false)
            .unwrap();

        // Update phase on the collected data (5 epochs, as in the paper).
        let ds = flatten(&rollouts.episodes, feat, 0.995, 1.0);
        let t0 = std::time::Instant::now();
        for _epoch in 0..5 {
            for idx in ds.minibatch_indices(trainer.minibatch, &mut rng_s) {
                let (obs, act, logp, adv, ret) = ds.gather(&idx);
                trainer
                    .train_minibatch(&Minibatch {
                        obs: &obs,
                        act: &act,
                        old_logp: &logp,
                        adv: &adv,
                        ret: &ret,
                    })
                    .unwrap();
            }
        }
        let update_s = t0.elapsed().as_secs_f64();
        split.row(vec![
            n_envs.to_string(),
            format!("{:.2}", rollouts.sample_time_s),
            format!("{:.3}", rollouts.policy_time_s),
            format!("{update_s:.2}"),
            format!("{:.1}", rollouts.sample_time_s / update_s),
        ]);
    }
    split.print("§6.2 — sampling vs update wall-clock split (exp. W1)");
    println!(
        "Paper's shape: sampling grows sublinearly with envs (parallel) and\n\
         dominates the update time; the update grows with collected samples.\n\
         Single train_step: {}",
        relexi::util::bench::fmt_duration(m_train.mean_s)
    );
}
