//! Bench W1 — the paper's §6.2 wall-clock split: "Sampling the
//! trajectories took 15 and 18 seconds per iteration [16 vs 64 envs],
//! while updating the policy ... took 0.5 and 2 seconds".
//!
//! Two parts:
//!
//! 1. **Collector-mode comparison** (no artifacts needed): the persistent
//!    worker pool sampled lock-step (the paper's synchronous gather, one
//!    blocking poll per env) vs event-driven at full batch vs event-driven
//!    at `min_batch = 1`, with the trainer's policy/idle wall-clock
//!    breakdown per mode.  A deterministic closure stands in for the
//!    policy so the comparison isolates the collection machinery.
//! 2. **Compiled-runtime section** (requires `make artifacts`): policy
//!    inference latency per batch size, the compiled PPO train step, and
//!    the full sampling/update split with the real policy.
//!
//! Results are written to `BENCH_training.json` (`Bench::write_json`) so
//! successive PRs can track the trajectory.  `BENCH_SMOKE=1` shrinks the
//! workload for CI.

use relexi::config::{BurgersConfig, CaseConfig, RunConfig};
use relexi::coordinator::EnvPool;
use relexi::orchestrator::{Orchestrator, Protocol};
use relexi::rl::flatten;
use relexi::runtime::{
    stub_policy, Minibatch, PolicyRuntime, Registry, Runtime, TrainerRuntime,
};
use relexi::solver::dns::{generate, Truth, TruthParams};
use relexi::util::bench::{Bench, Table};
use relexi::util::Rng;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

#[derive(Clone, Copy)]
enum Mode {
    Lockstep,
    EventFull,
    EventMb1,
}

impl Mode {
    fn label(self) -> &'static str {
        match self {
            Mode::Lockstep => "lockstep",
            Mode::EventFull => "event (full batch)",
            Mode::EventMb1 => "event (min_batch=1)",
        }
    }
}

fn bench_cfg(smoke: bool) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.case = CaseConfig {
        name: "bench".into(),
        n: 5,
        elems_per_dir: 2,
        k_max: 3,
        alpha: 0.4,
    };
    cfg.solver.t_end = if smoke { 0.2 } else { 0.5 };
    cfg.solver.dns_points = 24;
    cfg
}

fn bench_truth(cfg: &RunConfig, smoke: bool) -> Arc<Truth> {
    Arc::new(generate(
        &TruthParams {
            n_dns: 24,
            n_les: 12,
            nu: cfg.solver.nu,
            ke_target: cfg.solver.ke_target,
            spinup_time: if smoke { 0.3 } else { 1.0 },
            n_states: 4,
            sample_interval: 0.25,
            seed: 5,
        },
        |_, _| {},
    ))
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    // The kernel-pool width and SIMD level these rows ran at: the CI
    // matrix drives this binary under RELEXI_THREADS=1 and =4, and the
    // results must stay comparable across those runs.
    println!(
        "kernel pool: {} threads | simd dispatch: {}",
        relexi::util::pool::global().threads(),
        relexi::util::simd::level().label()
    );
    let mut bench = Bench::new("training")
        .with_warmup(Duration::from_millis(0))
        .with_max_samples(if smoke { 1 } else { 3 });

    // --- part 1: collector-mode comparison (worker pool machinery) ----------
    let cfg = bench_cfg(smoke);
    let truth = bench_truth(&cfg, smoke);
    let env_counts: &[usize] = if smoke { &[2, 4] } else { &[4, 8, 16] };

    let mut modes = Table::new(&[
        "n_envs",
        "collector",
        "sample [s]",
        "policy share [s]",
        "idle share [s]",
    ]);
    for &n_envs in env_counts {
        for mode in [Mode::Lockstep, Mode::EventFull, Mode::EventMb1] {
            let mut cfg_n = cfg.clone();
            cfg_n.rl.n_envs = n_envs;
            let orch = Orchestrator::launch(cfg_n.hpc.db_shards);
            let mut pool = EnvPool::new(cfg_n, truth.clone(), &orch)
                .expect("bench pool construction");
            let mut rng = Rng::new(100 + n_envs as u64);
            let mut it = 0usize;
            // Accumulate the breakdown over every measured sample so the
            // shares are means over the same runs as `m.mean_s`.
            let (mut policy_acc, mut idle_acc, mut runs) = (0.0f64, 0.0f64, 0usize);
            let m = bench.run(&format!("sample {} n_envs={n_envs}", mode.label()), || {
                let proto = Protocol::new(&format!("b{it}"));
                it += 1;
                let r = match mode {
                    Mode::Lockstep => pool
                        .collect_lockstep_with(&orch, &proto, stub_policy, &mut rng, false),
                    Mode::EventFull => pool
                        .collect_with(&orch, &proto, stub_policy, &mut rng, false, n_envs),
                    Mode::EventMb1 => {
                        pool.collect_with(&orch, &proto, stub_policy, &mut rng, false, 1)
                    }
                }
                .expect("sampling phase");
                orch.clear();
                policy_acc += r.policy_time_s;
                idle_acc += r.idle_time_s;
                runs += 1;
            });
            modes.row(vec![
                n_envs.to_string(),
                mode.label().to_string(),
                format!("{:.3}", m.mean_s),
                format!("{:.3}", policy_acc / runs.max(1) as f64),
                format!("{:.3}", idle_acc / runs.max(1) as f64),
            ]);
        }
    }
    modes.print("Collector modes — persistent pool, sampling phase (exp. W1a)");
    println!(
        "Expected shape: all modes within noise here (homogeneous envs on\n\
         one host); the event-driven collector pays no per-env poll\n\
         ordering cost, which is what widens the gap once env runtimes\n\
         disperse (heterogeneous variants / loaded nodes)."
    );

    // --- part 1b: per-backend series (solver-agnostic pool, PR 4) -----------
    // Same event-driven collector, two CfdEnv backends: the 3D spectral
    // LES at its part-1 sizes, and the 1D stochastic-Burgers testbed at
    // pool sizes the 3D case cannot reach on one CI host.
    let mut per_backend = Table::new(&[
        "backend",
        "n_envs",
        "sample [s]",
        "policy share [s]",
        "idle share [s]",
    ]);
    let les_counts = env_counts;
    let bur_counts: &[usize] = if smoke { &[8, 64] } else { &[64, 256] };
    for (backend, counts) in [("les", les_counts), ("burgers", bur_counts)] {
        for &n_envs in counts {
            let mut cfg_n = cfg.clone();
            cfg_n.rl.backend = backend.to_string();
            cfg_n.rl.n_envs = n_envs;
            if backend == "burgers" {
                cfg_n.burgers = BurgersConfig {
                    points: 48,
                    segments: 4,
                    k_max: 6,
                    t_end: cfg.solver.t_end, // same horizon as the LES rows
                    truth_states: 4,
                    truth_spinup: if smoke { 0.6 } else { 1.5 },
                    truth_interval: 0.25,
                    ..BurgersConfig::default()
                };
            }
            let orch = Orchestrator::launch(cfg_n.hpc.db_shards);
            let truth_arg = (backend == "les").then(|| truth.clone());
            let mut pool = EnvPool::from_config(cfg_n, truth_arg, &orch)
                .expect("bench pool construction");
            let mut rng = Rng::new(300 + n_envs as u64);
            let mut it = 0usize;
            let (mut policy_acc, mut idle_acc, mut runs) = (0.0f64, 0.0f64, 0usize);
            let m = bench.run(&format!("sample backend={backend} n_envs={n_envs}"), || {
                let proto = Protocol::new(&format!("bk{it}"));
                it += 1;
                let r = pool
                    .collect_with(&orch, &proto, stub_policy, &mut rng, false, n_envs)
                    .expect("sampling phase");
                orch.clear();
                policy_acc += r.policy_time_s;
                idle_acc += r.idle_time_s;
                runs += 1;
            });
            per_backend.row(vec![
                backend.to_string(),
                n_envs.to_string(),
                format!("{:.3}", m.mean_s),
                format!("{:.3}", policy_acc / runs.max(1) as f64),
                format!("{:.3}", idle_acc / runs.max(1) as f64),
            ]);
        }
    }
    per_backend.print("Backend scenarios — LES vs stochastic Burgers (PR 4)");
    println!(
        "Expected shape: the Burgers backend's per-iteration cost is small\n\
         enough that pool sizes grow by an order of magnitude at similar\n\
         wall-clock — the scenario axis the solver-agnostic backend layer\n\
         opens; idle share tracks the §6.2 synchronization overhead at\n\
         hundreds of envs."
    );

    // --- part 2: compiled-runtime sections (need artifacts) ------------------
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("\nbench_training: artifacts missing, skipping compiled-policy sections");
        bench
            .write_json("BENCH_training.json")
            .expect("write BENCH_training.json");
        println!("wrote BENCH_training.json");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let reg = Registry::open(dir).unwrap();
    let policy = PolicyRuntime::load(&rt, &reg, 5).unwrap();
    let theta = reg.initial_params(5).unwrap();
    let feat = policy.features();

    // Policy inference latency per batch.
    let mut rng = Rng::new(1);
    let mut table = Table::new(&["batch (elements)", "latency", "us/element"]);
    for batch in [64usize, 256, 1024, 4096] {
        let obs: Vec<f32> = (0..batch * feat).map(|_| rng.normal() as f32).collect();
        let m = bench.run(&format!("forward b={batch}"), || {
            std::hint::black_box(policy.forward(&theta, &obs, batch).unwrap());
        });
        table.row(vec![
            batch.to_string(),
            relexi::util::bench::fmt_duration(m.mean_s),
            format!("{:.2}", m.mean_s * 1e6 / batch as f64),
        ]);
    }
    table.print("Policy inference (compiled Pallas CNN via PJRT)");

    // Compiled PPO train step.
    let mut trainer = TrainerRuntime::load(&rt, &reg, 5, 256).unwrap();
    let mb = trainer.minibatch;
    let obs: Vec<f32> = (0..mb * feat).map(|_| rng.normal() as f32).collect();
    let act: Vec<f32> = (0..mb).map(|_| rng.uniform_f32() * 0.5).collect();
    let logp = vec![-1.0f32; mb];
    let adv: Vec<f32> = (0..mb).map(|_| rng.normal() as f32).collect();
    let ret: Vec<f32> = (0..mb).map(|_| rng.normal() as f32).collect();
    let m_train = bench.run(&format!("train_step b={mb} (loss+grad+Adam)"), || {
        std::hint::black_box(
            trainer
                .train_minibatch(&Minibatch {
                    obs: &obs,
                    act: &act,
                    old_logp: &logp,
                    adv: &adv,
                    ret: &ret,
                })
                .unwrap(),
        );
    });

    // Full §6.2 split with the real policy through the persistent pool.
    let mut split = Table::new(&[
        "n_envs",
        "sampling [s]",
        "policy share [s]",
        "idle share [s]",
        "update (5 epochs) [s]",
        "sample:update ratio",
    ]);
    for &n_envs in env_counts {
        let mut cfg_n = cfg.clone();
        cfg_n.rl.n_envs = n_envs;
        let orch = Orchestrator::launch(cfg_n.hpc.db_shards);
        let mut pool = EnvPool::new(cfg_n, truth.clone(), &orch).unwrap();
        let mut rng_s = Rng::new(100 + n_envs as u64);
        let proto = Protocol::new(&format!("w1-{n_envs}"));
        let rollouts = pool
            .collect(&orch, &proto, &policy, &theta, &mut rng_s, false)
            .unwrap();
        orch.clear();

        // Update phase on the collected data (5 epochs, as in the paper).
        let ds = flatten(&rollouts.episodes, feat, 0.995, 1.0);
        let t0 = std::time::Instant::now();
        for _epoch in 0..5 {
            for idx in ds.minibatch_indices(trainer.minibatch, &mut rng_s) {
                let (obs, act, logp, adv, ret) = ds.gather(&idx);
                trainer
                    .train_minibatch(&Minibatch {
                        obs: &obs,
                        act: &act,
                        old_logp: &logp,
                        adv: &adv,
                        ret: &ret,
                    })
                    .unwrap();
            }
        }
        let update_s = t0.elapsed().as_secs_f64();
        split.row(vec![
            n_envs.to_string(),
            format!("{:.2}", rollouts.sample_time_s),
            format!("{:.3}", rollouts.policy_time_s),
            format!("{:.3}", rollouts.idle_time_s),
            format!("{update_s:.2}"),
            format!("{:.1}", rollouts.sample_time_s / update_s),
        ]);
    }
    split.print("§6.2 — sampling vs update wall-clock split (exp. W1)");
    println!(
        "Paper's shape: sampling grows sublinearly with envs (parallel) and\n\
         dominates the update time; the update grows with collected samples.\n\
         Single train_step: {}",
        relexi::util::bench::fmt_duration(m_train.mean_s)
    );

    bench
        .write_json("BENCH_training.json")
        .expect("write BENCH_training.json");
    println!("wrote BENCH_training.json");
}
