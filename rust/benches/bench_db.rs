//! Bench A1 — the paper's §3.1 KeyDB-vs-Redis observation: "we used the
//! multi-threaded fork of Redis called KeyDB, which provided significantly
//! more performance for our application."
//!
//! Measures REAL concurrent throughput of the orchestrator store with
//! 1 shard (single-threaded-Redis analogue) vs N shards (KeyDB analogue)
//! under the actual Relexi traffic pattern: many env workers writing state
//! tensors and polling for action tensors.
//!
//! PR-3 additions: the subscriber-scaling series (put latency on a hot
//! key while 8/64/256 waiters idle on OTHER keys, per-key wakeups vs the
//! retained seq-lock baseline — per-key must stay flat while seq-lock
//! grows) and interned-key/zero-copy micro rows.
//!
//! PR-4 addition: the persistent-subscription series — consuming an
//! E-key wave through one incrementally-updated `Subscription` (O(E)
//! registry ops total) vs the per-event `wait_any` rebuild the rollout
//! collector used before (O(E) scan/registration work per event, O(E²)
//! per wave).  All rows land in `BENCH_db.json` and are uploaded by the
//! CI smoke job.

use relexi::orchestrator::{
    Key, Orchestrator, Protocol, RemoteTransport, ShardedStore, Subscription, Transport, Value,
    WakeMode,
};
use relexi::util::bench::{fmt_duration, Bench, Table};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One round of Relexi-like traffic: `n_envs` workers each put a state
/// tensor and take their action; the trainer thread serves all of them.
fn run_traffic(orch: &Arc<Orchestrator>, n_envs: usize, state_len: usize, rounds: usize) -> f64 {
    let proto = Protocol::new("bench");
    let t0 = Instant::now();
    let mut workers = Vec::new();
    for i in 0..n_envs {
        let client = orch.client();
        let proto = proto.clone();
        workers.push(std::thread::spawn(move || {
            for t in 0..rounds {
                client.put_tensor(&proto.state_key(i, t), vec![state_len], vec![0.5; state_len]);
                let _ = client
                    .poll_take(&proto.action_key(i, t), Duration::from_secs(60))
                    .expect("no action");
            }
        }));
    }
    let trainer = orch.client();
    for t in 0..rounds {
        for i in 0..n_envs {
            let _ = trainer
                .poll(&proto.state_key(i, t), Duration::from_secs(60))
                .expect("no state");
        }
        for i in 0..n_envs {
            trainer.put_tensor(&proto.action_key(i, t), vec![64], vec![0.17; 64]);
        }
    }
    for w in workers {
        w.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    orch.clear();
    dt
}

/// PR-3 tentpole series: latency of a put on a hot key while `n_waiters`
/// multi-key subscribers idle on disjoint keys.  With per-key wakeups the
/// put touches nobody; with the seq-lock baseline it wakes every waiter,
/// each of which rescans its key set.
fn waiter_scaling_series(b: &mut Bench, table: &mut Table, counts: &[usize]) {
    for (mode, label) in [(WakeMode::PerKey, "per-key"), (WakeMode::SeqLock, "seq-lock")] {
        for &n_waiters in counts {
            let store = Arc::new(ShardedStore::with_wake_mode(16, mode));
            let parked = Arc::new(AtomicUsize::new(0));
            let mut waiters = Vec::new();
            for w in 0..n_waiters {
                let store = store.clone();
                let parked = parked.clone();
                waiters.push(std::thread::spawn(move || {
                    let idle = format!("idle{w}");
                    let keys = [idle.as_str(), "series-done"];
                    parked.fetch_add(1, Ordering::SeqCst);
                    // Parks for the whole measurement; released by the
                    // final put on the shared "series-done" key.
                    store
                        .wait_any(&keys, Duration::from_secs(300))
                        .expect("released by series-done");
                }));
            }
            while parked.load(Ordering::SeqCst) < n_waiters {
                std::thread::yield_now();
            }
            // Give the last registrations time to reach the parked state.
            std::thread::sleep(Duration::from_millis(25));

            let m = b.run(&format!("put with {n_waiters} idle waiters [{label}]"), || {
                store.put("hot", Value::Scalar(1.0));
            });
            store.put("series-done", Value::Flag(true));
            for w in waiters {
                w.join().unwrap();
            }
            table.row(vec![
                label.to_string(),
                n_waiters.to_string(),
                fmt_duration(m.mean_s),
                fmt_duration(m.median_s),
            ]);
        }
    }
}

/// PR-9 series: publish an `e`-key wave either as `e` individual puts
/// (one frame per key on the wire) or as ONE `put_many` (one frame per
/// wave, executed store-side as a single grouped-by-shard pass).  Runs
/// the pair twice: straight into the store (`inproc`), and through a
/// loopback-TCP connection where the coalesced frame count is the whole
/// point of the batched exchange.
fn put_many_series(b: &mut Bench, table: &mut Table, counts: &[usize]) {
    let orch = Orchestrator::launch(16);
    let server = orch.serve("127.0.0.1:0").expect("loopback exchange");
    let tcp: Arc<dyn Transport> =
        RemoteTransport::connect("tcp", &server.addr().to_string(), 2).expect("tcp client");
    let inproc = orch.client();
    let row = |table: &mut Table, label: &str, e: usize, mean_s: f64| {
        table.row(vec![
            label.to_string(),
            e.to_string(),
            fmt_duration(mean_s),
            fmt_duration(mean_s / e as f64),
        ]);
    };
    for &e in counts {
        let names: Vec<Key> = (0..e).map(|i| Key::new(format!("pm{i}"))).collect();
        let strs: Vec<String> = (0..e).map(|i| format!("pm{i}")).collect();

        let m = b.run(&format!("put {e}-key wave [inproc per-key]"), || {
            for k in &names {
                inproc.put_scalar(k, 1.0);
            }
        });
        row(table, "inproc per-key", e, m.mean_s);
        let m = b.run(&format!("put {e}-key wave [inproc put_many]"), || {
            inproc.put_many(names.iter().map(|k| (k.clone(), Value::Scalar(1.0))).collect());
        });
        row(table, "inproc put_many", e, m.mean_s);

        let m = b.run(&format!("put {e}-key wave [tcp per-key]"), || {
            for k in &strs {
                tcp.put(k, Value::Scalar(1.0)).expect("tcp put");
            }
        });
        row(table, "tcp per-key", e, m.mean_s);
        let m = b.run(&format!("put {e}-key wave [tcp put_many]"), || {
            tcp.put_many(strs.iter().map(|k| (k.clone(), Value::Scalar(1.0))).collect())
                .expect("tcp put_many");
        });
        row(table, "tcp put_many", e, m.mean_s);

        orch.clear();
    }
}

/// PR-4 series: consume a wave of `e` distinct keys (produced with a
/// staggered writer, as env states arrive) either through one persistent
/// [`relexi::orchestrator::Subscription`] — register once, O(1) inbox
/// pops per event — or through the retired collector pattern of
/// rebuilding a `wait_any_take` over the outstanding key set per event.
fn subscription_wave_series(b: &mut Bench, table: &mut Table, counts: &[usize]) {
    for (label, persistent) in [("persistent sub", true), ("per-event rebuild", false)] {
        for &e in counts {
            let store = Arc::new(ShardedStore::new(16));
            let names: Arc<Vec<Key>> =
                Arc::new((0..e).map(|i| Key::new(format!("wave{i}"))).collect());
            let m = b.run(&format!("consume {e}-key wave [{label}]"), || {
                let producer = {
                    let store = store.clone();
                    let names = names.clone();
                    std::thread::spawn(move || {
                        for k in names.iter() {
                            store.put(k, Value::Scalar(1.0));
                            std::thread::yield_now();
                        }
                    })
                };
                if persistent {
                    let mut sub = Subscription::new(store.clone());
                    for (i, k) in names.iter().enumerate() {
                        sub.add(i, k);
                    }
                    let mut got = 0usize;
                    while got < e {
                        if sub.wait_take(Duration::from_secs(60)).is_some() {
                            got += 1;
                        }
                    }
                } else {
                    let mut outstanding: Vec<usize> = (0..e).collect();
                    while !outstanding.is_empty() {
                        let keys: Vec<&Key> =
                            outstanding.iter().map(|&i| &names[i]).collect();
                        let (hit, _) = store
                            .wait_any_take(&keys, Duration::from_secs(60))
                            .expect("producer publishes every key");
                        outstanding.remove(hit);
                    }
                }
                producer.join().unwrap();
            });
            table.row(vec![
                label.to_string(),
                e.to_string(),
                fmt_duration(m.mean_s),
                fmt_duration(m.mean_s / e as f64),
            ]);
        }
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    // 24-DOF state tensor: 13,824 DOF x 3 components.
    let state_len = if smoke { 4096 } else { 13_824 * 3 };
    let rounds = if smoke { 3 } else { 20 };
    let env_counts: &[usize] = if smoke { &[4, 16] } else { &[4, 16, 64] };

    let mut table = Table::new(&[
        "n_envs",
        "backend",
        "time [s]",
        "ops/s",
        "MB/s",
        "speedup vs 1-shard",
    ]);
    for &n_envs in env_counts {
        let mut single_time = 0.0;
        for (shards, label) in [(1usize, "redis-like (1 shard)"), (16, "keydb-like (16 shards)")] {
            let orch = Arc::new(Orchestrator::launch(shards));
            // warmup
            run_traffic(&orch, n_envs, state_len, 2);
            let dt = run_traffic(&orch, n_envs, state_len, rounds);
            let ops = (n_envs * rounds * 4) as f64 / dt; // put+get per side
            let mb = (n_envs * rounds * state_len * 4) as f64 / dt / 1e6;
            let speedup = if shards == 1 {
                single_time = dt;
                1.0
            } else {
                single_time / dt
            };
            table.row(vec![
                n_envs.to_string(),
                label.to_string(),
                format!("{dt:.3}"),
                format!("{ops:.0}"),
                format!("{mb:.0}"),
                format!("{speedup:.2}x"),
            ]);
        }
    }
    table.print("§3.1 — orchestrator backend comparison (exp. A1)");
    println!(
        "Expected shape: the sharded (KeyDB-like) backend sustains higher\n\
         throughput under concurrent env traffic, and the gap widens with\n\
         the number of parallel environments."
    );

    // Micro-benchmarks of the primitive ops.
    let orch = Orchestrator::launch(16);
    let c = orch.client();
    let mut b = Bench::new("store-ops");
    b.run("put_tensor state", || {
        c.put_tensor("k", vec![state_len], vec![0.5; state_len]);
    });
    b.run("get state (refcount bump)", || {
        std::hint::black_box(c.get("k"));
    });
    // Zero-copy publish: the producer's Arc buffer is republished without
    // touching the floats.
    let shared: Arc<[f32]> = Arc::from(vec![0.5f32; state_len]);
    let shape: Arc<[usize]> = Arc::from(vec![state_len]);
    b.run("put_tensor_shared state (zero-copy)", || {
        c.put_tensor_shared("ks", shape.clone(), shared.clone());
    });
    b.run("put+take scalar", || {
        c.put_scalar("s", 1.0);
        std::hint::black_box(c.poll_take("s", Duration::from_secs(1)));
    });
    // The event-driven collector's primitive: one subscription over a
    // 64-key wave with a single hot key — string keys vs interned handles.
    let names: Vec<String> = (0..64).map(|i| format!("wave{i}")).collect();
    let keys: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    b.run("poll_any_take over 64 keys", || {
        c.put_scalar(&names[63], 1.0);
        std::hint::black_box(c.poll_any_take(&keys, Duration::from_secs(1)));
    });
    let interned: Vec<Key> = names.iter().map(Key::new).collect();
    let ikeys: Vec<&Key> = interned.iter().collect();
    b.run("poll_any_take over 64 interned keys", || {
        c.put_scalar(&interned[63], 1.0);
        std::hint::black_box(c.poll_any_take(&ikeys, Duration::from_secs(1)));
    });

    // Subscriber-scaling series (acceptance: per-key flat, seq-lock grows).
    let waiter_counts: &[usize] = &[8, 64, 256];
    let mut wtable = Table::new(&["wake mode", "idle waiters", "put mean", "put median"]);
    waiter_scaling_series(&mut b, &mut wtable, waiter_counts);
    wtable.print("Per-key wakeups — put latency vs idle subscribers on other keys");
    println!(
        "Expected shape: per-key put latency is independent of the number\n\
         of waiters registered on other keys; the seq-lock baseline wakes\n\
         all of them per put and grows with the subscriber count."
    );

    // Persistent-subscription wave series (acceptance: per-event cost of
    // the persistent handle flat in E, rebuild growing linearly in E).
    let wave_counts: &[usize] = if smoke { &[16, 64] } else { &[64, 256, 1024] };
    let mut stable = Table::new(&["consumer", "wave keys", "wave mean", "per event"]);
    subscription_wave_series(&mut b, &mut stable, wave_counts);
    stable.print("Persistent subscription vs per-event wait_any rebuild (PR-4)");
    println!(
        "Expected shape: the persistent subscription's per-event cost is\n\
         flat in the wave size (one inbox pop + one shard-locked take);\n\
         the per-event rebuild re-scans and re-registers its whole\n\
         outstanding key set, so its per-event cost grows with E — the\n\
         O(E^2)-per-wave collector behavior PR 4 retired."
    );

    // Batched-exchange primitive (PR-9): one PutMany frame per wave vs
    // one frame per key, inproc and over loopback TCP.
    let pm_counts: &[usize] = if smoke { &[16, 64] } else { &[16, 64, 256] };
    let mut ptable = Table::new(&["path", "wave keys", "wave mean", "per key"]);
    put_many_series(&mut b, &mut ptable, pm_counts);
    ptable.print("Batched put_many vs per-key puts (PR-9)");
    println!(
        "Expected shape: inproc put_many saves the per-key client hop\n\
         (one grouped-by-shard pass); over TCP the win is structural —\n\
         one frame and one syscall round per wave instead of one per\n\
         key, so the per-key cost of the batched row shrinks as the\n\
         wave grows while the per-key row stays flat."
    );

    // Telemetry overhead A/B (PR 10): the same put+take pair with the
    // subsystem disabled (every probe = one relaxed atomic load) and
    // enabled (store-op histogram observe + frame/ring record writes).
    // The on-leg rings intentionally wrap without draining — steady
    // overwrite is the worst case the hot path can see.
    relexi::util::telemetry::init(false, 65_536, "error", "bench");
    b.run("put 1-key [tel-off]", || {
        c.put_scalar("tel", 1.0);
        std::hint::black_box(c.poll_take("tel", Duration::from_secs(1)));
    });
    relexi::util::telemetry::init(true, 65_536, "error", "bench");
    b.run("put 1-key [tel-on]", || {
        c.put_scalar("tel", 1.0);
        std::hint::black_box(c.poll_take("tel", Duration::from_secs(1)));
    });
    relexi::util::telemetry::init(false, 65_536, "error", "bench");
    println!(
        "Expected shape: the tel-off row matches the PR-9 put+take scalar\n\
         baseline (disabled probes cost one relaxed load); the tel-on row\n\
         pays one Instant pair + histogram observe per op — single-digit\n\
         nanoseconds of overhead, never a lock or an allocation."
    );

    b.write_json("BENCH_db.json").expect("write BENCH_db.json");
    println!("wrote BENCH_db.json");
}
