//! Bench F — the FFT engines head to head: the frozen seed path
//! (recursive per-line Cooley–Tukey, element-wise strided gather/scatter)
//! vs the batched iterative Stockham engine behind `fft3d_ws`.  The 3-D
//! transform dominates every solver step, so this ratio bounds the whole
//! training loop (ISSUE 1 acceptance: >= 2x at n = 48).
//!
//! Emits `BENCH_fft.json` for the perf-trajectory log (ROADMAP §Perf log).

use relexi::fft::{fft3d_pool, fft3d_ws, seed, Cpx, FftScratch, Plan};
use relexi::util::bench::{Bench, Table};
use relexi::util::pool::{self, Pool};
use relexi::util::simd::{self, Level};
use relexi::util::Rng;
use std::time::Duration;

fn random_cube(n: usize, seed_v: u64) -> Vec<Cpx> {
    let mut rng = Rng::new(seed_v);
    (0..n * n * n)
        .map(|_| Cpx::new(rng.normal(), rng.normal()))
        .collect()
}

/// Never benchmark a wrong transform: both engines must agree first.
fn verify_engines_agree(n: usize) {
    let plan = Plan::new(n);
    let seed_plan = seed::Plan::new(n);
    let mut ws = FftScratch::new(n);
    let cube = random_cube(n, 999);
    let mut a = cube.clone();
    let mut b = cube;
    fft3d_ws(&mut a, &plan, false, &mut ws);
    seed::fft3d(&mut b, &seed_plan, false);
    let max_err = a
        .iter()
        .zip(&b)
        .map(|(x, y)| (*x - *y).norm_sq().sqrt())
        .fold(0.0, f64::max);
    assert!(
        max_err < 1e-6 * (n * n * n) as f64,
        "engines disagree at n={n}: max_err={max_err}"
    );
}

fn main() {
    let mut b = Bench::new("fft").with_target(Duration::from_secs(2));

    for n in [24usize, 48] {
        verify_engines_agree(n);
    }

    // --- 3-D: seed per-line vs batched, forward+inverse per iteration ---
    let mut table = Table::new(&["n", "seed ms", "batched ms", "speedup"]);
    for n in [24usize, 32, 48, 64, 96] {
        let seed_plan = seed::Plan::new(n);
        let plan = Plan::new(n);
        let mut ws = FftScratch::new(n);

        let mut cube_seed = random_cube(n, 1);
        let m_seed = b.run(&format!("seed fft3d {n}^3 (fwd+inv)"), || {
            seed::fft3d(&mut cube_seed, &seed_plan, false);
            seed::fft3d(&mut cube_seed, &seed_plan, true);
        });

        let mut cube_new = random_cube(n, 2);
        let m_new = b.run(&format!("batched fft3d {n}^3 (fwd+inv)"), || {
            fft3d_ws(&mut cube_new, &plan, false, &mut ws);
            fft3d_ws(&mut cube_new, &plan, true, &mut ws);
        });

        table.row(vec![
            format!("{n}"),
            format!("{:.3}", m_seed.mean_s * 1e3),
            format!("{:.3}", m_new.mean_s * 1e3),
            format!("{:.2}x", m_seed.mean_s / m_new.mean_s),
        ]);
    }
    table.print("Seed vs batched 3-D FFT (one forward + one inverse)");

    // --- 1-D batch scaling: how much the contiguous batch loop buys -----
    let n = 48usize;
    let plan = Plan::new(n);
    for batch in [1usize, 7, n, n * n] {
        let mut rng = Rng::new(batch as u64);
        let mut data: Vec<Cpx> = (0..n * batch)
            .map(|_| Cpx::new(rng.normal(), rng.normal()))
            .collect();
        let mut scratch = vec![Cpx::ZERO; n * batch];
        b.run(&format!("1-D n={n} batch={batch} (whole batch, fwd+inv)"), || {
            plan.forward_batch(&mut data, batch, &mut scratch);
            plan.inverse_batch(&mut data, batch, &mut scratch);
        });
    }

    // --- scalar vs SIMD butterflies (PR 6): same Stockham engine, the ---
    // --- dispatch level forced per plan.  Results are bit-identical, ---
    // --- so the ratio isolates the vector pack/twiddle loops.        ---
    let native = simd::level();
    let mut sv = Table::new(&["n", "scalar ms", "simd ms", "speedup", "level"]);
    for n in [32usize, 48, 64, 96] {
        let plan_s = Plan::with_level(n, Level::Scalar);
        let plan_v = Plan::new(n);
        let mut ws = FftScratch::new(n);

        let mut cube_s = random_cube(n, 3);
        let m_s = b.run(&format!("fft3d {n}^3 [scalar] (fwd+inv)"), || {
            fft3d_ws(&mut cube_s, &plan_s, false, &mut ws);
            fft3d_ws(&mut cube_s, &plan_s, true, &mut ws);
        });

        let mut cube_v = random_cube(n, 4);
        let m_v = b.run(&format!("fft3d {n}^3 [{}] (fwd+inv)", native.label()), || {
            fft3d_ws(&mut cube_v, &plan_v, false, &mut ws);
            fft3d_ws(&mut cube_v, &plan_v, true, &mut ws);
        });

        sv.row(vec![
            format!("{n}"),
            format!("{:.3}", m_s.mean_s * 1e3),
            format!("{:.3}", m_v.mean_s * 1e3),
            format!("{:.2}x", m_s.mean_s / m_v.mean_s),
            native.label().to_string(),
        ]);
    }
    sv.print("Scalar vs SIMD dispatch, 3-D FFT (bit-identical outputs)");

    // --- 1 thread vs native pool width on the plane-batched 3-D pass ---
    let pool1 = Pool::new(1);
    let pooln = pool::global();
    let mut tt = Table::new(&["n", "t1 ms", "tN ms", "speedup", "threads"]);
    for n in [48usize, 64, 96] {
        let plan = Plan::new(n);
        let mut buf = vec![Cpx::ZERO; n * n * n];
        let mut plane = vec![Cpx::ZERO; n * n];

        let mut cube1 = random_cube(n, 5);
        let m1 = b.run(&format!("fft3d {n}^3 [threads=1] (fwd+inv)"), || {
            fft3d_pool(&mut cube1, &plan, false, &mut buf, &mut plane, &pool1);
            fft3d_pool(&mut cube1, &plan, true, &mut buf, &mut plane, &pool1);
        });

        let mut cube_n = random_cube(n, 6);
        let label_n = format!("fft3d {n}^3 [threads={}] (fwd+inv)", pooln.threads());
        let m_n = b.run(&label_n, || {
            fft3d_pool(&mut cube_n, &plan, false, &mut buf, &mut plane, &pooln);
            fft3d_pool(&mut cube_n, &plan, true, &mut buf, &mut plane, &pooln);
        });

        tt.row(vec![
            format!("{n}"),
            format!("{:.3}", m1.mean_s * 1e3),
            format!("{:.3}", m_n.mean_s * 1e3),
            format!("{:.2}x", m1.mean_s / m_n.mean_s),
            pooln.threads().to_string(),
        ]);
    }
    tt.print("Worker-pool plane batching, 3-D FFT (bit-identical outputs)");

    if let Err(e) = b.write_json("BENCH_fft.json") {
        eprintln!("warning: could not write BENCH_fft.json: {e}");
    } else {
        println!("\nwrote BENCH_fft.json");
    }
}
