//! Bench — the native policy/trainer subsystem (PR 5): GEMM micro-kernel
//! throughput, native `forward` latency across batch sizes, and the
//! native `train_step` (forward + backprop + Adam) across minibatch
//! sizes.  The forward series uses the LES element shape (648 features)
//! so the rows are directly comparable with the compiled-policy series
//! in `bench_training`; a Burgers-shaped (12-feature) row shows the
//! small-scenario regime the CI learning smoke runs in.
//!
//! Results are written to `BENCH_policy.json` (`Bench::write_json`) and
//! uploaded next to the other bench artifacts.  `BENCH_SMOKE=1` shrinks
//! the workload for CI.

use relexi::runtime::native::gemm;
use relexi::runtime::{Minibatch, NativeSpec, NativeTrainer};
use relexi::util::bench::{fmt_duration, Bench, Table};
use relexi::util::pool::{self, Pool};
use relexi::util::simd::{self, Level};
use relexi::util::Rng;
use std::time::Duration;

fn spec(features: usize, hidden: Vec<usize>, minibatch: usize) -> NativeSpec {
    NativeSpec {
        features,
        hidden,
        minibatch,
        lr: 1e-4,
        clip_eps: 0.2,
        vf_coef: 0.5,
        ent_coef: 0.0,
        log_std_init: (0.05f64).ln(),
        seed: 2024,
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let mut bench = Bench::new("policy").with_target(Duration::from_millis(if smoke {
        60
    } else {
        400
    }));

    // --- GEMM micro: the kernels the MLP forward/backward run on -----------
    // Head-to-head variants (PR 6): scalar vs SIMD dispatch at one
    // thread, then SIMD at the pool's native width.  All variants
    // compute the same contraction, so one effective-FLOPs figure (the
    // true `2*m*k*n` of the logical shape, not any padded/blocked dims)
    // is shared across the rows of a shape.
    let mut rng = Rng::new(5);
    let native = simd::level();
    let pool1 = Pool::new(1);
    let pooln = pool::global();
    let n1_label = format!("{},t1", native.label());
    let tn_label = format!("{},t{}", native.label(), pooln.threads());
    let mut table = Table::new(&["kernel", "m x k x n", "variant", "latency", "GFLOP/s"]);
    // Forward layer (batch x features -> hidden), backward dW, backward dX.
    let shapes: &[(&str, usize, usize, usize)] = &[
        ("nn (fwd z=x*w)", 256, 648, 64),
        ("nn (fwd hidden)", 256, 64, 64),
        ("tn (bwd dW)", 648, 256, 64),
        ("nt (bwd dX)", 256, 64, 648),
    ];
    for &(label, m, k, n) in shapes {
        let a_rows = if label.starts_with("tn") { k * m } else { m * k };
        let b_rows = if label.starts_with("nt") { n * k } else { k * n };
        let a: Vec<f32> = (0..a_rows).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..b_rows).map(|_| rng.normal() as f32).collect();
        let mut c = vec![0f32; m * n];
        // Effective FLOPs of the logical contraction, shared by every
        // variant row below.
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let variants: &[(&str, Level, &Pool)] = &[
            ("scalar,t1", Level::Scalar, &pool1),
            (n1_label.as_str(), native, &pool1),
            (tn_label.as_str(), native, pooln.as_ref()),
        ];
        for &(variant, level, p) in variants {
            let meas = bench.run(&format!("gemm {label} {m}x{k}x{n} [{variant}]"), || {
                c.iter_mut().for_each(|x| *x = 0.0);
                match &label[..2] {
                    "tn" => gemm::gemm_tn_with(level, p, m, k, n, &a, &b, &mut c),
                    "nt" => gemm::gemm_nt_with(level, p, m, k, n, &a, &b, &mut c),
                    _ => gemm::gemm_nn_with(level, p, m, k, n, &a, &b, &mut c),
                }
                std::hint::black_box(&c);
            });
            table.row(vec![
                label.to_string(),
                format!("{m}x{k}x{n}"),
                variant.to_string(),
                fmt_duration(meas.mean_s),
                format!("{:.2}", flops / meas.mean_s / 1e9),
            ]);
        }
    }
    table.print("GEMM micro-kernels (f32, cache-blocked; scalar vs SIMD x threads)");

    // --- native forward latency across batch sizes --------------------------
    let mut fwd = Table::new(&["shape", "batch (agents)", "latency", "us/agent"]);
    let batches: &[usize] = if smoke { &[64, 256] } else { &[64, 256, 1024, 4096] };
    for (shape_label, features, hidden) in [
        ("les-648f", 648usize, vec![64usize, 64]),
        ("burgers-12f", 12, vec![32]),
    ] {
        let sp = spec(features, hidden, 256);
        let trainer = NativeTrainer::new(sp.clone());
        let policy = relexi::runtime::NativePolicy::new(sp);
        for &b in batches {
            let obs: Vec<f32> = (0..b * features).map(|_| rng.normal() as f32).collect();
            let m = bench.run(&format!("forward {shape_label} b={b}"), || {
                std::hint::black_box(policy.forward(trainer.theta(), &obs, b).unwrap());
            });
            fwd.row(vec![
                shape_label.to_string(),
                b.to_string(),
                fmt_duration(m.mean_s),
                format!("{:.2}", m.mean_s * 1e6 / b as f64),
            ]);
        }
    }
    fwd.print("Native policy forward (MLP via blocked GEMM)");

    // --- native train step across minibatch sizes ----------------------------
    let mut tr = Table::new(&["minibatch", "latency", "us/sample"]);
    let mbs: &[usize] = if smoke { &[256] } else { &[256, 1024, 4096] };
    for &mb_size in mbs {
        let sp = spec(648, vec![64, 64], mb_size);
        let mut trainer = NativeTrainer::new(sp);
        let obs: Vec<f32> = (0..mb_size * 648).map(|_| rng.normal() as f32).collect();
        let act: Vec<f32> = (0..mb_size).map(|_| rng.uniform_f32() * 0.5).collect();
        let logp = vec![-1.0f32; mb_size];
        let adv: Vec<f32> = (0..mb_size).map(|_| rng.normal() as f32).collect();
        let ret: Vec<f32> = (0..mb_size).map(|_| rng.normal() as f32).collect();
        let m = bench.run(&format!("train_step b={mb_size} (loss+grad+Adam)"), || {
            std::hint::black_box(
                trainer
                    .train_minibatch(&Minibatch {
                        obs: &obs,
                        act: &act,
                        old_logp: &logp,
                        adv: &adv,
                        ret: &ret,
                    })
                    .unwrap(),
            );
        });
        tr.row(vec![
            mb_size.to_string(),
            fmt_duration(m.mean_s),
            format!("{:.2}", m.mean_s * 1e6 / mb_size as f64),
        ]);
    }
    tr.print("Native PPO train step (backprop + Adam, les-648f net)");
    println!(
        "Expected shape: forward/train cost linear in batch once past\n\
         per-call overhead; GEMM rows bound what the MLP can reach.  The\n\
         compiled-XLA forward series lives in bench_training for a\n\
         head-to-head at the same 648-feature shape."
    );

    bench
        .write_json("BENCH_policy.json")
        .expect("write BENCH_policy.json");
    println!("wrote BENCH_policy.json");
}
