//! The launcher substrate: the SmartSim-Infrastructure-Library analogue
//! (DESIGN.md S9).  Owns instance placement (rankfiles against the cluster
//! topology), the launch-overhead model (individual vs MPMD starts) and
//! the file-staging model (Lustre vs RAM drive) — the two §3.3
//! optimizations the paper implemented to make environment startup
//! negligible.

pub mod mpmd;
pub mod rankfile;
pub mod staging;

pub use mpmd::{LaunchMode, LaunchModel};
pub use rankfile::{place, Placement};
pub use staging::{StagingMode, StagingModel};

use crate::config::RunConfig;
use crate::hpc::costmodel::HeadCostModel;
use crate::hpc::topology::Topology;
use anyhow::Result;

/// Launch configuration for a batch of environment instances.
#[derive(Debug, Clone)]
pub struct LaunchPlan {
    pub placement: Placement,
    pub mode: LaunchMode,
    pub staging: StagingMode,
}

/// The launcher: builds placements and accounts for startup costs.
pub struct Launcher {
    pub topology: Topology,
    pub launch_model: LaunchModel,
    pub staging_model: StagingModel,
}

impl Launcher {
    /// A launcher for the given worker topology with default cost models.
    pub fn new(topology: Topology) -> Launcher {
        Launcher {
            topology,
            launch_model: LaunchModel::default(),
            staging_model: StagingModel::default(),
        }
    }

    /// Plan a batch launch: place instances and record the modes.
    pub fn plan(
        &self,
        n_instances: usize,
        ranks_per_instance: usize,
        mode: LaunchMode,
        staging: StagingMode,
    ) -> Result<LaunchPlan> {
        Ok(LaunchPlan {
            placement: place(&self.topology, n_instances, ranks_per_instance)?,
            mode,
            staging,
        })
    }

    /// Simulated startup time for a plan: mpirun wireup + input staging.
    /// `files` / `bytes` describe each instance's input set (parameter
    /// file, mesh, restart file — paper §3.3).
    pub fn startup_time(&self, plan: &LaunchPlan, files: usize, bytes: f64) -> f64 {
        let launch = self.launch_model.launch_time(
            plan.mode,
            plan.placement.n_instances,
            plan.placement.ranks_per_instance,
        );
        let staging = self.staging_model.launch_read_time(
            plan.staging,
            plan.placement.n_instances,
            plan.placement.nodes_used(),
            files,
            bytes,
        );
        launch + staging
    }
}

/// Placement plan for the `orchestrator.workers = "processes"` mode: how
/// the env pool is split over `relexi env-worker` OS processes.  Built by
/// [`plan_worker_processes`] from the cluster topology + head cost model,
/// consumed by `coordinator::envpool` when it spawns the workers.
#[derive(Debug, Clone)]
pub struct WorkerPlan {
    /// Worker processes to spawn.
    pub n_procs: usize,
    /// `assignments[p] = (env_start, env_count)` — contiguous blocks in
    /// global env order, covering `0..n_envs` exactly once (the pool's
    /// seed derivation iterates envs in this global order, so the split
    /// never perturbs the RNG streams).
    pub assignments: Vec<(usize, usize)>,
    /// OpenMPI-style rankfile text for the placement (one "rank" per
    /// hosted env thread), kept for parity with the batch-launch path.
    pub rankfile: String,
    /// Modelled startup time of the worker batch (launch + staging).
    pub est_startup_s: f64,
}

/// Head-work budget per collection wave used by the auto split
/// (`orchestrator.env_procs = 0`): processes are sized so one worker's
/// serialized per-wave cost stays within this bound.
const AUTO_WAVE_BUDGET_S: f64 = 0.02;

/// Plan the env -> process split for `n_envs` environments.  An explicit
/// `orchestrator.env_procs >= 1` pins the process count; `0` sizes
/// processes from [`HeadCostModel::envs_per_process_for`] under the
/// cluster topology in `cfg.hpc`.
pub fn plan_worker_processes(cfg: &RunConfig, n_envs: usize) -> Result<WorkerPlan> {
    anyhow::ensure!(n_envs >= 1, "worker plan needs at least one env");
    let n_procs = if cfg.orchestrator.env_procs >= 1 {
        cfg.orchestrator.env_procs.min(n_envs)
    } else {
        let head = HeadCostModel {
            db_shards: cfg.hpc.db_shards.max(1),
            ..HeadCostModel::default()
        };
        // Burgers workers: one "element" per control segment, a
        // points-long f32 state tensor.
        let per = head.envs_per_process_for(
            cfg.burgers.segments,
            cfg.burgers.points as f64 * 4.0,
            AUTO_WAVE_BUDGET_S,
        );
        n_envs.div_ceil(per)
    };
    let base = n_envs / n_procs;
    let rem = n_envs % n_procs;
    let mut assignments = Vec::with_capacity(n_procs);
    let mut start = 0usize;
    for p in 0..n_procs {
        let count = base + usize::from(p < rem);
        assignments.push((start, count));
        start += count;
    }
    debug_assert_eq!(start, n_envs);

    let topology = Topology {
        nodes: cfg.hpc.worker_nodes,
        cores_per_node: cfg.hpc.cores_per_node,
        cores_per_die: cfg.hpc.cores_per_die,
    };
    let launcher = Launcher::new(topology);
    // One instance per worker process, one pinned core per hosted env
    // thread (uniform at the widest assignment so place() never
    // straddles a node).
    let widest = assignments.iter().map(|&(_, c)| c).max().unwrap_or(1);
    let mode = if cfg.hpc.mpmd {
        LaunchMode::Mpmd
    } else {
        LaunchMode::Individual
    };
    let staging = if cfg.hpc.ram_staging {
        StagingMode::RamDrive
    } else {
        StagingMode::Lustre
    };
    let plan = launcher.plan(n_procs, widest.max(1), mode, staging)?;
    // Inputs per worker: the config string + the binary image page-in.
    let est_startup_s = launcher.startup_time(&plan, 2, 4e3);
    Ok(WorkerPlan {
        n_procs,
        assignments,
        rankfile: plan.placement.rankfile_text(),
        est_startup_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_and_startup_time() {
        let l = Launcher::new(Topology::hawk(16));
        let fast = l
            .plan(128, 8, LaunchMode::Mpmd, StagingMode::RamDrive)
            .unwrap();
        let slow = l
            .plan(128, 8, LaunchMode::Individual, StagingMode::Lustre)
            .unwrap();
        let t_fast = l.startup_time(&fast, 6, 2e6);
        let t_slow = l.startup_time(&slow, 6, 2e6);
        // Both §3.3 improvements together: order-of-magnitude reduction.
        assert!(
            t_fast * 10.0 < t_slow,
            "fast={t_fast:.3}s slow={t_slow:.3}s"
        );
    }

    #[test]
    fn worker_plan_partitions_envs_exactly_once() {
        let mut cfg = crate::config::RunConfig::default();
        cfg.rl.backend = "burgers".to_string();
        cfg.orchestrator.workers = "processes".to_string();
        cfg.orchestrator.transport = "tcp".to_string();

        // Explicit process count: contiguous blocks, sizes differ by <= 1.
        cfg.orchestrator.env_procs = 3;
        let p = plan_worker_processes(&cfg, 8).unwrap();
        assert_eq!(p.n_procs, 3);
        assert_eq!(p.assignments, vec![(0, 3), (3, 3), (6, 2)]);
        assert!(!p.rankfile.is_empty());
        assert!(p.est_startup_s > 0.0);

        // More processes than envs clamps to one env per process.
        cfg.orchestrator.env_procs = 100;
        let p = plan_worker_processes(&cfg, 4).unwrap();
        assert_eq!(p.n_procs, 4);
        assert!(p.assignments.iter().all(|&(_, c)| c == 1));

        // Auto mode (env_procs = 0) covers every env exactly once.
        cfg.orchestrator.env_procs = 0;
        let p = plan_worker_processes(&cfg, 64).unwrap();
        assert!(p.n_procs >= 1 && p.n_procs <= 64);
        let total: usize = p.assignments.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 64);
        let mut next = 0;
        for &(start, count) in &p.assignments {
            assert_eq!(start, next, "non-contiguous assignment");
            next += count;
        }
    }

    #[test]
    fn oversubscription_rejected() {
        let l = Launcher::new(Topology::hawk(1));
        assert!(l
            .plan(1025, 2, LaunchMode::Mpmd, StagingMode::RamDrive)
            .is_err());
    }
}
