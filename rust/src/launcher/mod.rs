//! The launcher substrate: the SmartSim-Infrastructure-Library analogue
//! (DESIGN.md S9).  Owns instance placement (rankfiles against the cluster
//! topology), the launch-overhead model (individual vs MPMD starts) and
//! the file-staging model (Lustre vs RAM drive) — the two §3.3
//! optimizations the paper implemented to make environment startup
//! negligible.

pub mod mpmd;
pub mod rankfile;
pub mod staging;

pub use mpmd::{LaunchMode, LaunchModel};
pub use rankfile::{place, Placement};
pub use staging::{StagingMode, StagingModel};

use crate::hpc::topology::Topology;
use anyhow::Result;

/// Launch configuration for a batch of environment instances.
#[derive(Debug, Clone)]
pub struct LaunchPlan {
    pub placement: Placement,
    pub mode: LaunchMode,
    pub staging: StagingMode,
}

/// The launcher: builds placements and accounts for startup costs.
pub struct Launcher {
    pub topology: Topology,
    pub launch_model: LaunchModel,
    pub staging_model: StagingModel,
}

impl Launcher {
    /// A launcher for the given worker topology with default cost models.
    pub fn new(topology: Topology) -> Launcher {
        Launcher {
            topology,
            launch_model: LaunchModel::default(),
            staging_model: StagingModel::default(),
        }
    }

    /// Plan a batch launch: place instances and record the modes.
    pub fn plan(
        &self,
        n_instances: usize,
        ranks_per_instance: usize,
        mode: LaunchMode,
        staging: StagingMode,
    ) -> Result<LaunchPlan> {
        Ok(LaunchPlan {
            placement: place(&self.topology, n_instances, ranks_per_instance)?,
            mode,
            staging,
        })
    }

    /// Simulated startup time for a plan: mpirun wireup + input staging.
    /// `files` / `bytes` describe each instance's input set (parameter
    /// file, mesh, restart file — paper §3.3).
    pub fn startup_time(&self, plan: &LaunchPlan, files: usize, bytes: f64) -> f64 {
        let launch = self.launch_model.launch_time(
            plan.mode,
            plan.placement.n_instances,
            plan.placement.ranks_per_instance,
        );
        let staging = self.staging_model.launch_read_time(
            plan.staging,
            plan.placement.n_instances,
            plan.placement.nodes_used(),
            files,
            bytes,
        );
        launch + staging
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_and_startup_time() {
        let l = Launcher::new(Topology::hawk(16));
        let fast = l
            .plan(128, 8, LaunchMode::Mpmd, StagingMode::RamDrive)
            .unwrap();
        let slow = l
            .plan(128, 8, LaunchMode::Individual, StagingMode::Lustre)
            .unwrap();
        let t_fast = l.startup_time(&fast, 6, 2e6);
        let t_slow = l.startup_time(&slow, 6, 2e6);
        // Both §3.3 improvements together: order-of-magnitude reduction.
        assert!(
            t_fast * 10.0 < t_slow,
            "fast={t_fast:.3}s slow={t_slow:.3}s"
        );
    }

    #[test]
    fn oversubscription_rejected() {
        let l = Launcher::new(Topology::hawk(1));
        assert!(l
            .plan(1025, 2, LaunchMode::Mpmd, StagingMode::RamDrive)
            .is_err());
    }
}
