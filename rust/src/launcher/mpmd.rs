//! Launch-overhead model: individual `mpirun` per instance vs one MPMD
//! (multiple-program-multiple-data) launch.
//!
//! The paper (§3.3): "For some configurations, the time required for
//! starting the simulations exceeded the actual simulation time. ... we
//! employed the MPMD functionality provided by OpenMPI ... all simulations
//! can be started with individual command line arguments within a single
//! call of MPI."  With the improvements "the performance penalty of
//! launching large amounts of environments became negligible".

/// How a batch of environment instances is started.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchMode {
    /// One `mpirun` invocation per instance, serialized by the launcher.
    Individual,
    /// A single MPMD `mpirun` starting every instance at once.
    Mpmd,
}

/// Tunable launch-cost constants (orders of magnitude of `mpirun` startup
/// on an IB cluster).
#[derive(Debug, Clone)]
pub struct LaunchModel {
    /// Fixed cost of one mpirun invocation (daemon spawn, wireup).
    pub mpirun_base_s: f64,
    /// Additional wireup cost per rank in one invocation.
    pub per_rank_s: f64,
    /// Launcher-side serialized bookkeeping per instance (applies to both
    /// modes; Relexi builds rankfiles and argument lists either way).
    pub per_instance_s: f64,
}

impl Default for LaunchModel {
    fn default() -> Self {
        LaunchModel {
            mpirun_base_s: 0.9,
            per_rank_s: 0.004,
            per_instance_s: 0.01,
        }
    }
}

impl LaunchModel {
    /// Simulated seconds to start `n_instances` x `ranks` MPI ranks.
    pub fn launch_time(&self, mode: LaunchMode, n_instances: usize, ranks: usize) -> f64 {
        let n = n_instances as f64;
        let total_ranks = (n_instances * ranks) as f64;
        match mode {
            LaunchMode::Individual => {
                n * (self.mpirun_base_s + ranks as f64 * self.per_rank_s)
                    + n * self.per_instance_s
            }
            LaunchMode::Mpmd => {
                self.mpirun_base_s
                    + total_ranks * self.per_rank_s
                    + n * self.per_instance_s
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mpmd_negligible_individual_dominant() {
        // The paper's observation: at hundreds of envs, individual launch
        // exceeds the ~15 s sampling time; MPMD stays negligible.
        let m = LaunchModel::default();
        let individual = m.launch_time(LaunchMode::Individual, 512, 4);
        let mpmd = m.launch_time(LaunchMode::Mpmd, 512, 4);
        assert!(individual > 400.0, "individual={individual}");
        assert!(mpmd < 15.0, "mpmd={mpmd}");
    }

    #[test]
    fn single_instance_equal_cost() {
        let m = LaunchModel::default();
        let a = m.launch_time(LaunchMode::Individual, 1, 8);
        let b = m.launch_time(LaunchMode::Mpmd, 1, 8);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn mpmd_scales_with_total_ranks() {
        let m = LaunchModel::default();
        let t1 = m.launch_time(LaunchMode::Mpmd, 64, 2);
        let t2 = m.launch_time(LaunchMode::Mpmd, 64, 16);
        assert!(t2 > t1);
    }
}
