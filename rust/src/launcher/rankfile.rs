//! Rankfile generation: topology-aware placement of environment instances.
//!
//! The paper (§3.3): "To ensure that each MPI rank is placed correctly on
//! the available hardware and to avoid double occupancy, Relexi generates
//! rankfiles on-the-fly based on the available hardware resources."
//!
//! Placement policy: instances are packed onto nodes in order, consecutive
//! cores per instance, never straddling a node boundary (a 2..16-rank
//! instance always fits inside a 128-core node).

use crate::hpc::topology::{RankPin, Topology};
use anyhow::{bail, Result};

/// Full placement of a batch of instances.
#[derive(Debug, Clone)]
pub struct Placement {
    pub pins: Vec<RankPin>,
    pub topology: Topology,
    /// Ranks per instance (uniform, as in the paper's benchmarks).
    pub ranks_per_instance: usize,
    pub n_instances: usize,
}

/// Pack `n_instances` x `ranks_per_instance` onto the topology.
pub fn place(topology: &Topology, n_instances: usize, ranks_per_instance: usize) -> Result<Placement> {
    if ranks_per_instance == 0 || n_instances == 0 {
        bail!("placement needs at least one instance with one rank");
    }
    if ranks_per_instance > topology.cores_per_node {
        bail!(
            "instance of {ranks_per_instance} ranks exceeds node size {}",
            topology.cores_per_node
        );
    }
    let per_node = topology.cores_per_node / ranks_per_instance;
    let capacity = per_node * topology.nodes;
    if n_instances > capacity {
        bail!(
            "{n_instances} instances x {ranks_per_instance} ranks exceed capacity \
             ({capacity} instances on {} nodes)",
            topology.nodes
        );
    }
    let mut pins = Vec::with_capacity(n_instances * ranks_per_instance);
    let mut node = 0usize;
    let mut next_core = 0usize;
    for instance in 0..n_instances {
        if next_core + ranks_per_instance > topology.cores_per_node {
            node += 1;
            next_core = 0;
        }
        for rank in 0..ranks_per_instance {
            pins.push(RankPin {
                instance,
                rank,
                node,
                core: next_core + rank,
            });
        }
        next_core += ranks_per_instance;
    }
    Ok(Placement {
        pins,
        topology: topology.clone(),
        ranks_per_instance,
        n_instances,
    })
}

impl Placement {
    /// Number of active ranks on every die (contention model input).
    pub fn die_occupancy(&self) -> Vec<usize> {
        let mut occ = vec![0usize; self.topology.total_dies()];
        for p in &self.pins {
            occ[self.topology.die_of(p.node, p.core)] += 1;
        }
        occ
    }

    /// Max die occupancy seen by any rank of one instance — the rank that
    /// limits the (synchronous) instance under bandwidth contention.
    pub fn max_die_occupancy_of_instance(&self, instance: usize) -> usize {
        let occ = self.die_occupancy();
        self.pins
            .iter()
            .filter(|p| p.instance == instance)
            .map(|p| occ[self.topology.die_of(p.node, p.core)])
            .max()
            .unwrap_or(0)
    }

    /// Nodes actually used.
    pub fn nodes_used(&self) -> usize {
        self.pins.iter().map(|p| p.node).max().map(|n| n + 1).unwrap_or(0)
    }

    /// Render the OpenMPI-style rankfile (`rank N=host slot=core`).
    pub fn rankfile_text(&self) -> String {
        let mut out = String::new();
        for (global_rank, p) in self.pins.iter().enumerate() {
            out.push_str(&format!(
                "rank {}=node{:03} slot={}\n",
                global_rank, p.node, p.core
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn no_double_occupancy() {
        let t = Topology::hawk(4);
        let p = place(&t, 60, 8).unwrap();
        let mut seen = HashSet::new();
        for pin in &p.pins {
            assert!(seen.insert((pin.node, pin.core)), "double occupancy {pin:?}");
            assert!(pin.core < t.cores_per_node);
            assert!(pin.node < t.nodes);
        }
        assert_eq!(p.pins.len(), 480);
    }

    #[test]
    fn instances_do_not_straddle_nodes() {
        let t = Topology::hawk(4);
        // 48-rank instances: 2 per node with 32 cores spare.
        let p = place(&t, 8, 48).unwrap();
        for i in 0..8 {
            let nodes: HashSet<usize> = p
                .pins
                .iter()
                .filter(|x| x.instance == i)
                .map(|x| x.node)
                .collect();
            assert_eq!(nodes.len(), 1, "instance {i} straddles nodes");
        }
    }

    #[test]
    fn capacity_enforced() {
        let t = Topology::hawk(1);
        assert!(place(&t, 65, 2).is_err()); // 64 x 2-rank fit on one node
        assert!(place(&t, 64, 2).is_ok());
        assert!(place(&t, 1, 200).is_err());
        assert!(place(&t, 0, 4).is_err());
    }

    #[test]
    fn two_rank_instances_share_a_die() {
        // The micro-architecture behind the paper's 1->2 env dip: two
        // 2-rank instances land on the same 8-core die.
        let t = Topology::hawk(1);
        let p1 = place(&t, 1, 2).unwrap();
        assert_eq!(p1.max_die_occupancy_of_instance(0), 2);
        let p2 = place(&t, 2, 2).unwrap();
        assert_eq!(p2.max_die_occupancy_of_instance(0), 4);
        assert_eq!(p2.max_die_occupancy_of_instance(1), 4);
    }

    #[test]
    fn sixteen_rank_instances_own_their_dies() {
        // 16-rank instances fill two dies regardless of neighbours, so
        // adding a second instance does not change their die occupancy.
        let t = Topology::hawk(1);
        let p1 = place(&t, 1, 16).unwrap();
        let p2 = place(&t, 2, 16).unwrap();
        assert_eq!(
            p1.max_die_occupancy_of_instance(0),
            p2.max_die_occupancy_of_instance(0)
        );
        assert_eq!(p1.max_die_occupancy_of_instance(0), 8);
    }

    #[test]
    fn rankfile_format() {
        let t = Topology::hawk(1);
        let p = place(&t, 1, 2).unwrap();
        let text = p.rankfile_text();
        assert!(text.contains("rank 0=node000 slot=0"));
        assert!(text.contains("rank 1=node000 slot=1"));
    }

    #[test]
    fn full_partition_fills_all_cores() {
        // The paper's largest weak-scaling point: 1024 x 2-rank envs on
        // 16 nodes = all 2048 cores.
        let t = Topology::hawk(16);
        let p = place(&t, 1024, 2).unwrap();
        assert_eq!(p.pins.len(), 2048);
        assert_eq!(p.nodes_used(), 16);
        let occ = p.die_occupancy();
        assert!(occ.iter().all(|&o| o == 8), "all dies fully occupied");
    }
}
