//! File-staging cost model: parallel filesystem (Lustre) vs node-local RAM
//! drive.  The paper (§3.3): "we implemented a functionality to copy all
//! files required by the simulation, e.g. parameter files and restart
//! files, to local drives located in the RAM of each node.  This reduced
//! the access times compared to using a parallel file system like Lustre
//! significantly."
//!
//! Model: per-instance metadata/open latency plus bandwidth-limited bulk
//! transfer; Lustre metadata ops serialize on the MDS under concurrent
//! load, while RAM-drive access is local and parallel per node.

/// Where instance input files live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StagingMode {
    /// Read every file from the shared Lustre filesystem at launch.
    Lustre,
    /// One copy to each node's RAM drive, then local reads.
    RamDrive,
}

/// Tunable model constants (defaults fitted to typical HDD-era Lustre MDS
/// latencies and HPC node RAM bandwidth orders of magnitude).
#[derive(Debug, Clone)]
pub struct StagingModel {
    /// Lustre metadata ops per second (MDS; shared, serializing).
    pub lustre_meta_ops_per_s: f64,
    /// Lustre aggregate read bandwidth (bytes/s, shared across instances).
    pub lustre_bw: f64,
    /// RAM drive local read bandwidth per node (bytes/s).
    pub ram_bw: f64,
    /// Per-file open cost on the RAM drive (s).
    pub ram_open_s: f64,
    /// One-time per-node copy bandwidth for populating the RAM drive.
    pub stage_in_bw: f64,
}

impl Default for StagingModel {
    fn default() -> Self {
        StagingModel {
            lustre_meta_ops_per_s: 10_000.0,
            lustre_bw: 40e9,
            ram_bw: 12e9,
            ram_open_s: 2e-6,
            stage_in_bw: 5e9,
        }
    }
}

impl StagingModel {
    /// Simulated seconds for `n_instances` (across `nodes` nodes) to read
    /// their input files (`files_per_instance` files, `bytes_per_instance`
    /// total) at launch.
    pub fn launch_read_time(
        &self,
        mode: StagingMode,
        n_instances: usize,
        nodes: usize,
        files_per_instance: usize,
        bytes_per_instance: f64,
    ) -> f64 {
        let n = n_instances as f64;
        match mode {
            StagingMode::Lustre => {
                // Metadata storm serializes on the MDS; bulk reads share
                // the aggregate bandwidth.
                let meta = n * files_per_instance as f64 / self.lustre_meta_ops_per_s;
                let bulk = n * bytes_per_instance / self.lustre_bw;
                meta + bulk
            }
            StagingMode::RamDrive => {
                // One stage-in per node (instances on a node share it),
                // then parallel local reads.
                let stage_in = bytes_per_instance / self.stage_in_bw;
                let per_node_instances = (n / nodes.max(1) as f64).ceil();
                let local = files_per_instance as f64 * self.ram_open_s
                    + per_node_instances * bytes_per_instance / self.ram_bw;
                stage_in + local
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ram_drive_beats_lustre_at_scale() {
        let m = StagingModel::default();
        // Paper regime: hundreds of instances, a few small files each.
        let lustre = m.launch_read_time(StagingMode::Lustre, 512, 16, 6, 2e6);
        let ram = m.launch_read_time(StagingMode::RamDrive, 512, 16, 6, 2e6);
        assert!(
            ram < lustre / 5.0,
            "expected significant RAM-drive win: ram={ram:.4}s lustre={lustre:.4}s"
        );
    }

    #[test]
    fn single_instance_gap_is_small() {
        // With one instance the metadata storm vanishes; the gap shrinks.
        let m = StagingModel::default();
        let lustre = m.launch_read_time(StagingMode::Lustre, 1, 1, 6, 2e6);
        let ram = m.launch_read_time(StagingMode::RamDrive, 1, 1, 6, 2e6);
        assert!(lustre < 0.01, "lustre single-instance should be fast: {lustre}");
        assert!(ram < lustre * 50.0);
    }

    #[test]
    fn lustre_time_scales_linearly_with_instances() {
        let m = StagingModel::default();
        let t128 = m.launch_read_time(StagingMode::Lustre, 128, 16, 6, 2e6);
        let t256 = m.launch_read_time(StagingMode::Lustre, 256, 16, 6, 2e6);
        assert!((t256 / t128 - 2.0).abs() < 0.01);
    }
}
