//! # relexi-rs
//!
//! Rust + JAX + Pallas reproduction of *"Deep Reinforcement Learning for
//! Computational Fluid Dynamics on HPC Systems"* (Kurz, Offenhäuser, Viola,
//! Shcherbakov, Resch, Beck — J. Computational Science, 2022).
//!
//! The crate hosts the Layer-3 coordinator (the paper's Relexi framework)
//! and every substrate it depends on, built from scratch:
//!
//! * [`solver`] — the FLEXI-substitute LES environment (pseudo-spectral
//!   incompressible NS, linear forcing, per-element Smagorinsky).
//! * [`orchestrator`] — the SmartSim-Orchestrator-substitute in-memory
//!   tensor store (sharded KeyDB-like and single-lock Redis-like backends).
//! * [`launcher`] — the SmartSim-IL-substitute instance manager (rankfiles,
//!   MPMD vs individual launch, file-staging models).
//! * [`hpc`] — the Hawk cluster model + discrete-event scaling simulator
//!   that regenerates the paper's Figs. 3–4.
//! * [`rl`] — PPO trajectory machinery, Gaussian policy head, reward.
//! * [`runtime`] — the policy/trainer layer behind the `Policy`/`Trainer`
//!   trait seam: PJRT execution of the AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`, Python never runs at training time) or the
//!   pure-Rust native MLP + PPO subsystem (`runtime.backend = "native"`,
//!   zero artifacts).
//! * [`coordinator`] — the synchronous training loop tying it all together.
//! * [`config`], [`fft`], [`util`] — config system, FFT, and foundations.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping each paper table/figure to a bench or example.

pub mod config;
pub mod coordinator;
pub mod fft;
pub mod hpc;
pub mod launcher;
pub mod orchestrator;
pub mod rl;
pub mod runtime;
pub mod solver;
pub mod util;
