//! Isotropic linear forcing (Lundgren 2003; de Laage de Meux et al. 2015).
//!
//! The paper (§5.2) keeps the HIT quasi-stationary with linear forcing
//! `f = A u` that balances the dissipation of the turbulence model.  We use
//! the controller form: a base rate plus a relaxation term that nudges the
//! kinetic energy toward its target,
//!
//!   A(K) = A0 + (K_target - K) / (2 K_target tau),
//!
//! clamped to `[0, A_MAX]`.  In equilibrium `eps = 2 A K`, so `A0` sets the
//! eddy-turnover time `T = K/eps = 1/(2 A0)`.

/// Linear-forcing controller state.
#[derive(Debug, Clone)]
pub struct LinearForcing {
    /// Target kinetic energy.
    pub ke_target: f64,
    /// Relaxation time of the energy controller.
    pub tau: f64,
    /// Base forcing rate (sets the equilibrium eddy-turnover time).
    pub a0: f64,
    /// Clamp for the forcing coefficient.
    pub a_max: f64,
}

impl LinearForcing {
    /// Controller with the solver-config target and relaxation time.
    pub fn new(ke_target: f64, tau: f64) -> LinearForcing {
        LinearForcing {
            ke_target,
            tau,
            a0: 0.25,
            a_max: 2.0,
        }
    }

    /// Forcing coefficient A for the current kinetic energy.
    pub fn coefficient(&self, ke: f64) -> f64 {
        let relax = (self.ke_target - ke) / (2.0 * self.ke_target * self.tau);
        (self.a0 + relax).clamp(0.0, self.a_max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_target_returns_base_rate() {
        let f = LinearForcing::new(1.5, 1.0);
        assert!((f.coefficient(1.5) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn low_energy_forces_harder() {
        let f = LinearForcing::new(1.5, 1.0);
        assert!(f.coefficient(0.5) > f.coefficient(1.5));
    }

    #[test]
    fn high_energy_backs_off_and_clamps() {
        let f = LinearForcing::new(1.5, 1.0);
        assert!(f.coefficient(3.0) < f.coefficient(1.5));
        // Extremely high energy: clamped at zero, never negative.
        assert_eq!(f.coefficient(100.0), 0.0);
        // Extremely low energy: clamped at a_max.
        let tight = LinearForcing { tau: 1e-3, ..f };
        assert_eq!(tight.coefficient(0.0), tight.a_max);
    }
}
