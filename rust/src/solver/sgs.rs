//! Subgrid-scale (SGS) closure: Smagorinsky with a *per-element* Cs field.
//!
//! This is the actuator the RL agent controls (paper §5.1–5.2): the policy
//! predicts one Cs per DG element; the eddy viscosity follows Eq. (3)
//!   nu_t = (Cs * Delta)^2 * sqrt(2 S_ij S_ij),
//! with Delta the grid spacing.  `Cs = const` gives the classic Smagorinsky
//! baseline; `Cs = 0` is the implicit-LES baseline.

use super::elements::ElementMap;
use super::grid::Grid;
use crate::fft::Cpx;

/// Physical-space symmetric strain-rate tensor components, order
/// (S11, S22, S33, S12, S13, S23).
pub struct Strain {
    pub comps: [Vec<Cpx>; 6],
}

/// Component index pairs for the symmetric strain tensor.
pub const STRAIN_PAIRS: [(usize, usize); 6] = [(0, 0), (1, 1), (2, 2), (0, 1), (0, 2), (1, 2)];

impl Strain {
    /// Allocate zeroed strain storage.
    pub fn zeros(grid: &Grid) -> Strain {
        Strain {
            comps: [
                grid.zeros(),
                grid.zeros(),
                grid.zeros(),
                grid.zeros(),
                grid.zeros(),
                grid.zeros(),
            ],
        }
    }

    /// Strain magnitude |S| = sqrt(2 S_ij S_ij) at a flat physical index.
    #[inline]
    pub fn magnitude(&self, i: usize) -> f64 {
        let d = &self.comps;
        let diag = d[0][i].re * d[0][i].re + d[1][i].re * d[1][i].re + d[2][i].re * d[2][i].re;
        let off = d[3][i].re * d[3][i].re + d[4][i].re * d[4][i].re + d[5][i].re * d[5][i].re;
        (2.0 * (diag + 2.0 * off)).sqrt()
    }
}

/// Pointwise eddy viscosity from the per-element Cs field, Eq. (3).
///
/// `cs` has one entry per element; `emap` maps grid points to elements.
/// Returns nu_t on the grid and its maximum (for the viscous CFL limit).
pub fn eddy_viscosity(
    grid: &Grid,
    strain: &Strain,
    emap: &ElementMap,
    cs: &[f64],
    out: &mut [f64],
) -> f64 {
    debug_assert_eq!(cs.len(), emap.n_elems());
    let delta = grid.dx();
    let mut nu_max: f64 = 0.0;
    for i in 0..grid.len() {
        let c = cs[emap.elem_of_point(i)];
        let nu = (c * delta) * (c * delta) * strain.magnitude(i);
        out[i] = nu;
        nu_max = nu_max.max(nu);
    }
    nu_max
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::elements::ElementMap;

    #[test]
    fn magnitude_of_unit_diagonal() {
        let grid = Grid::new(4);
        let mut s = Strain::zeros(&grid);
        s.comps[0][7] = Cpx::new(1.0, 0.0);
        // |S| = sqrt(2 * 1) = sqrt(2)
        assert!((s.magnitude(7) - 2f64.sqrt()).abs() < 1e-12);
        assert_eq!(s.magnitude(3), 0.0);
    }

    #[test]
    fn off_diagonal_counts_twice() {
        let grid = Grid::new(4);
        let mut s = Strain::zeros(&grid);
        s.comps[3][0] = Cpx::new(1.0, 0.0); // S12 = S21 = 1
        assert!((s.magnitude(0) - 2.0).abs() < 1e-12); // sqrt(2*(2*1)) = 2
    }

    #[test]
    fn eddy_viscosity_elementwise() {
        let grid = Grid::new(8);
        let emap = ElementMap::new(&grid, 2); // 2^3 = 8 elements of 4^3
        let mut s = Strain::zeros(&grid);
        for i in 0..grid.len() {
            s.comps[0][i] = Cpx::new(1.0, 0.0); // |S| = sqrt(2) everywhere
        }
        let mut cs = vec![0.0; 8];
        cs[0] = 0.2;
        let mut nut = vec![0.0; grid.len()];
        let numax = eddy_viscosity(&grid, &s, &emap, &cs, &mut nut);
        let delta = grid.dx();
        let want = (0.2 * delta) * (0.2 * delta) * 2f64.sqrt();
        // First element's corner point:
        assert!((nut[grid.idx(0, 0, 0)] - want).abs() < 1e-12);
        // Point inside another element (x >= 4):
        assert_eq!(nut[grid.idx(5, 0, 0)], 0.0);
        assert!((numax - want).abs() < 1e-12);
    }

    #[test]
    fn zero_cs_gives_zero_nut_everywhere() {
        let grid = Grid::new(8);
        let emap = ElementMap::new(&grid, 2);
        let mut s = Strain::zeros(&grid);
        for i in 0..grid.len() {
            s.comps[4][i] = Cpx::new(3.0, 0.0);
        }
        let cs = vec![0.0; 8];
        let mut nut = vec![1.0; grid.len()];
        let numax = eddy_viscosity(&grid, &s, &emap, &cs, &mut nut);
        assert_eq!(numax, 0.0);
        assert!(nut.iter().all(|&x| x == 0.0));
    }
}
