//! The LES solver: incompressible Navier–Stokes on the periodic box,
//! pseudo-spectral in space (rotational form, 2/3 dealiasing, Leray
//! projection), SSP-RK3 in time, with linear forcing and the per-element
//! Smagorinsky closure.  This is the FLEXI-substitute environment
//! (DESIGN.md §2): it provides the energy cascade, the eddy-viscosity
//! actuator and the element structure the RL task needs.

use super::elements::ElementMap;
use super::forcing::LinearForcing;
use super::grid::Grid;
use super::sgs::{eddy_viscosity, Strain, STRAIN_PAIRS};
#[cfg(test)]
use super::spectral::clone_vec;
use super::spectral::{
    curl, fft_pair_real, ifft_pair, kinetic_energy, max_velocity_ws, project, to_physical,
    zeros_vec, SpecVec,
};
use super::spectrum::energy_spectrum;
use crate::fft::{fft3d_ws, Cpx, FftScratch};
use std::sync::Arc;

/// Scratch buffers reused across RHS evaluations — the workspace arena.
/// Every buffer the step loop touches lives here, so a steady-state step
/// performs **zero heap allocations** (asserted by `step_reuses_buffers`).
struct Workspace {
    omega_hat: SpecVec,
    fhat: SpecVec,
    u_phys: SpecVec,
    w_phys: SpecVec,
    f_phys: SpecVec,
    strain: Strain,
    nut: Vec<f64>,
    /// FFT workspace: Stockham ping-pong buffer, transpose plane and the
    /// Hermitian-pair packing buffer (see `fft::FftScratch`).
    fft: FftScratch,
    /// Divergence diagnostic buffer (`max_divergence`).
    div: Vec<Cpx>,
    /// Preallocated RK stage buffers (avoids per-step allocation).
    u0: SpecVec,
    u1: SpecVec,
}

/// Counters for profiling and the HPC cost model calibration.
#[derive(Debug, Clone, Copy, Default)]
pub struct SolverStats {
    /// Completed RK steps.
    pub steps: u64,
    /// 3-D transforms executed.
    pub transforms: u64,
    /// RHS evaluations.
    pub rhs_evals: u64,
}

/// Pseudo-spectral LES solver state.
pub struct Solver {
    /// Shared spectral grid (wavenumber tables + FFT plan).  `Arc` so many
    /// env workers can share one plan — `fft::Plan` is `Send + Sync`.
    pub grid: Arc<Grid>,
    pub emap: ElementMap,
    /// Spectral velocity (the environment state `s_t`).
    pub uhat: SpecVec,
    /// Per-element Smagorinsky coefficient (the agent's action `a_t`).
    pub cs: Vec<f64>,
    /// Molecular viscosity.
    pub nu: f64,
    /// CFL number.
    pub cfl: f64,
    /// Energy-maintaining linear forcing (None for decaying turbulence).
    pub forcing: Option<LinearForcing>,
    /// Simulation time.
    pub t: f64,
    pub stats: SolverStats,
    vmax: f64,
    numax: f64,
    ws: Workspace,
}

impl Solver {
    /// Build a solver on an `n^3` grid with `elems_per_dir^3` elements.
    pub fn new(n: usize, elems_per_dir: usize, nu: f64, cfl: f64) -> Solver {
        Solver::with_grid(Arc::new(Grid::new(n)), elems_per_dir, nu, cfl)
    }

    /// Build a solver on a shared grid (one plan for many env workers).
    pub fn with_grid(grid: Arc<Grid>, elems_per_dir: usize, nu: f64, cfl: f64) -> Solver {
        let emap = ElementMap::new(&grid, elems_per_dir);
        let uhat = zeros_vec(&grid);
        let ws = Workspace {
            omega_hat: zeros_vec(&grid),
            fhat: zeros_vec(&grid),
            u_phys: zeros_vec(&grid),
            w_phys: zeros_vec(&grid),
            f_phys: zeros_vec(&grid),
            strain: Strain::zeros(&grid),
            nut: vec![0.0; grid.len()],
            fft: FftScratch::new(grid.n),
            div: grid.zeros(),
            u0: zeros_vec(&grid),
            u1: zeros_vec(&grid),
        };
        let n_elems = emap.n_elems();
        Solver {
            grid,
            emap,
            uhat,
            cs: vec![0.0; n_elems],
            nu,
            cfl,
            forcing: None,
            t: 0.0,
            stats: SolverStats::default(),
            vmax: 0.0,
            numax: 0.0,
            ws,
        }
    }

    /// Replace the state (dealiases and projects it for consistency).
    pub fn set_state(&mut self, mut uhat: SpecVec) {
        for c in uhat.iter_mut() {
            self.grid.dealias(c);
        }
        project(&self.grid, &mut uhat);
        self.uhat = uhat;
        self.vmax = max_velocity_ws(
            &self.grid,
            &self.uhat,
            &mut self.ws.fft,
            &mut self.ws.u_phys,
        );
        self.stats.transforms += 3;
    }

    /// Set the per-element Cs action, clamped to the admissible [0, 0.5].
    pub fn set_cs(&mut self, cs: &[f64]) {
        assert_eq!(cs.len(), self.cs.len());
        for (dst, &c) in self.cs.iter_mut().zip(cs) {
            *dst = c.clamp(0.0, 0.5);
        }
    }

    /// Uniform Cs (Smagorinsky baseline / 0.0 for implicit LES).
    pub fn set_cs_uniform(&mut self, cs: f64) {
        let v = vec![cs; self.cs.len()];
        self.set_cs(&v);
    }

    /// Mean kinetic energy of the current state.
    pub fn kinetic_energy(&self) -> f64 {
        kinetic_energy(&self.grid, &self.uhat)
    }

    /// Shell-binned energy spectrum of the current state.
    pub fn spectrum(&self) -> Vec<f64> {
        energy_spectrum(&self.grid, &self.uhat)
    }

    /// Element observations of the current state, `(n_elems, p, p, p, 3)` f32.
    pub fn observations(&mut self) -> Vec<f32> {
        let mut obs = vec![0f32; self.obs_len()];
        self.observations_into(&mut obs);
        obs
    }

    /// [`Solver::observations`] into a caller-owned buffer of
    /// [`Solver::obs_len`] floats — the allocation-free path for reusable
    /// per-worker observation buffers.
    pub fn observations_into(&mut self, obs: &mut [f32]) {
        for c in 0..3 {
            to_physical(
                &self.grid,
                &self.uhat[c],
                &mut self.ws.u_phys[c],
                &mut self.ws.fft,
            );
        }
        self.stats.transforms += 3;
        self.emap.gather_observations_into(&self.ws.u_phys, obs);
    }

    /// Observation length: `n_elems * (N+1)^3 * 3`.
    pub fn obs_len(&self) -> usize {
        self.emap.n_elems() * self.emap.points_per_elem() * 3
    }

    /// Max divergence magnitude (diagnostic; should stay at round-off).
    /// Runs through the workspace buffer — no allocation.
    pub fn max_divergence(&mut self) -> f64 {
        super::spectral::divergence(&self.grid, &self.uhat, &mut self.ws.div);
        self.ws.div.iter().map(|c| c.norm_sq().sqrt()).fold(0.0, f64::max)
    }

    /// Evaluate the RHS at `uin` into `self.ws.fhat`; updates vmax/numax.
    fn rhs(&mut self, uin: &SpecVec) {
        let grid = &self.grid;
        let ws = &mut self.ws;
        self.stats.rhs_evals += 1;

        // Vorticity and physical-space velocity / vorticity.  Real fields
        // are inverse-transformed in Hermitian pairs: 3 FFTs for 6 fields
        // (§Perf-L3 optimization 1).
        curl(grid, uin, &mut ws.omega_hat);
        {
            let (ua, rest) = ws.u_phys.split_at_mut(1);
            let (ub, uc) = rest.split_at_mut(1);
            let (wa, wrest) = ws.w_phys.split_at_mut(1);
            let (wb, wc) = wrest.split_at_mut(1);
            ifft_pair(grid, &uin[0], &uin[1], &mut ws.fft, &mut ua[0], &mut ub[0]);
            ifft_pair(
                grid,
                &uin[2],
                &ws.omega_hat[0],
                &mut ws.fft,
                &mut uc[0],
                &mut wa[0],
            );
            ifft_pair(
                grid,
                &ws.omega_hat[1],
                &ws.omega_hat[2],
                &mut ws.fft,
                &mut wb[0],
                &mut wc[0],
            );
        }
        self.stats.transforms += 3;

        // CFL bookkeeping from the velocity we already have.
        let mut v2max: f64 = 0.0;
        for i in 0..grid.len() {
            let v2 = ws.u_phys[0][i].re * ws.u_phys[0][i].re
                + ws.u_phys[1][i].re * ws.u_phys[1][i].re
                + ws.u_phys[2][i].re * ws.u_phys[2][i].re;
            v2max = v2max.max(v2);
        }
        self.vmax = v2max.sqrt();

        // Rotational-form nonlinear term F = u x omega.
        for i in 0..grid.len() {
            let (ux, uy, uz) = (ws.u_phys[0][i].re, ws.u_phys[1][i].re, ws.u_phys[2][i].re);
            let (wx, wy, wz) = (ws.w_phys[0][i].re, ws.w_phys[1][i].re, ws.w_phys[2][i].re);
            ws.f_phys[0][i] = Cpx::new(uy * wz - uz * wy, 0.0);
            ws.f_phys[1][i] = Cpx::new(uz * wx - ux * wz, 0.0);
            ws.f_phys[2][i] = Cpx::new(ux * wy - uy * wx, 0.0);
        }
        {
            // Forward-transform F in a Hermitian pair + one single.
            let (f01, f2) = ws.f_phys.split_at_mut(2);
            let (f0, f1) = f01.split_at_mut(1);
            fft_pair_real(grid, &mut ws.fft, &mut f0[0], &mut f1[0]);
            ws.fhat[0].copy_from_slice(&f0[0]);
            ws.fhat[1].copy_from_slice(&f1[0]);
            ws.fhat[2].copy_from_slice(&f2[0]);
            fft3d_ws(&mut ws.fhat[2], &grid.plan, false, &mut ws.fft);
        }
        self.stats.transforms += 2;

        // SGS term: div(2 nu_t(x) S) with per-element Cs (skipped entirely
        // for the implicit model, Cs = 0 — the paper's cheap baseline).
        let sgs_active = self.cs.iter().any(|&c| c > 0.0);
        if sgs_active {
            // Strain in spectral space, then to physical — inverse
            // transforms done in Hermitian pairs (6 fields, 3 FFTs).
            for (m, &(a, b)) in STRAIN_PAIRS.iter().enumerate() {
                let comp = &mut ws.strain.comps[m];
                for i in 0..grid.len() {
                    let (kx, ky, kz) = grid.kvec(i);
                    let k = [kx, ky, kz];
                    let v = (uin[a][i].scale(k[b]) + uin[b][i].scale(k[a])).mul_i();
                    comp[i] = v.scale(0.5);
                }
            }
            for m in [0usize, 2, 4] {
                let (lo, hi) = ws.strain.comps.split_at_mut(m + 1);
                let a = &mut lo[m];
                let b = &mut hi[0];
                // ifft_pair needs separate in/out; reuse f_phys as temp out.
                let (ta, tb) = ws.f_phys.split_at_mut(1);
                ifft_pair(grid, a, b, &mut ws.fft, &mut ta[0], &mut tb[0]);
                a.copy_from_slice(&ta[0]);
                b.copy_from_slice(&tb[0]);
            }
            self.stats.transforms += 3;

            self.numax = eddy_viscosity(grid, &ws.strain, &self.emap, &self.cs, &mut ws.nut);

            // tau_ij = 2 nu_t S_ij, in place, then back to spectral —
            // forward transforms in Hermitian pairs (6 fields, 3 FFTs).
            for m in 0..6 {
                let comp = &mut ws.strain.comps[m];
                for i in 0..grid.len() {
                    comp[i] = Cpx::new(2.0 * ws.nut[i] * comp[i].re, 0.0);
                }
            }
            for m in [0usize, 2, 4] {
                let (lo, hi) = ws.strain.comps.split_at_mut(m + 1);
                fft_pair_real(grid, &mut ws.fft, &mut lo[m], &mut hi[0]);
            }
            self.stats.transforms += 3;

            // fhat_a += i k_b tau_ab (tau symmetric; component map).
            // Row a uses tau components: a=0 -> (S11,S12,S13)=(0,3,4),
            // a=1 -> (3,1,5), a=2 -> (4,5,2).
            const ROWS: [[usize; 3]; 3] = [[0, 3, 4], [3, 1, 5], [4, 5, 2]];
            for a in 0..3 {
                for i in 0..grid.len() {
                    let (kx, ky, kz) = grid.kvec(i);
                    let k = [kx, ky, kz];
                    let mut acc = Cpx::ZERO;
                    for b in 0..3 {
                        acc += ws.strain.comps[ROWS[a][b]][i].scale(k[b]);
                    }
                    ws.fhat[a][i] += acc.mul_i();
                }
            }
        } else {
            self.numax = 0.0;
        }

        // Linear terms: molecular viscosity (explicit) + linear forcing.
        let a_coef = self
            .forcing
            .as_ref()
            .map(|f| f.coefficient(kinetic_energy(grid, uin)))
            .unwrap_or(0.0);
        for c in 0..3 {
            for i in 0..grid.len() {
                let k2 = grid.k_sq(i);
                ws.fhat[c][i] += uin[c][i].scale(a_coef - self.nu * k2);
            }
        }

        // Dealias and project.
        for c in 0..3 {
            grid.dealias(&mut ws.fhat[c]);
        }
        project(grid, &mut ws.fhat);
    }

    /// Stable timestep from the most recent vmax/numax.
    pub fn stable_dt(&self) -> f64 {
        let dx = self.grid.dx();
        let adv = self.cfl * dx / self.vmax.max(1e-8);
        let visc_nu = self.nu + self.numax;
        let visc = 0.3 * dx * dx / visc_nu.max(1e-12);
        adv.min(visc)
    }

    /// One SSP-RK3 step of size `dt` (preallocated stage buffers; no
    /// allocation on the hot path — §Perf-L3 optimization 2).
    pub fn step(&mut self, dt: f64) {
        let grid_len = self.grid.len();
        let mut u0 = std::mem::take(&mut self.ws.u0);
        let mut u1 = std::mem::take(&mut self.ws.u1);

        // Stage 1: u1 = u0 + dt L(u0)
        for c in 0..3 {
            u0[c].copy_from_slice(&self.uhat[c]);
        }
        self.rhs(&u0);
        for c in 0..3 {
            for i in 0..grid_len {
                u1[c][i] = u0[c][i] + self.ws.fhat[c][i].scale(dt);
            }
        }

        // Stage 2: u2 = 3/4 u0 + 1/4 (u1 + dt L(u1)), stored back into u1.
        self.rhs(&u1);
        for c in 0..3 {
            for i in 0..grid_len {
                u1[c][i] = u0[c][i].scale(0.75)
                    + (u1[c][i] + self.ws.fhat[c][i].scale(dt)).scale(0.25);
            }
        }

        // Stage 3: u = 1/3 u0 + 2/3 (u2 + dt L(u2))
        self.rhs(&u1);
        for c in 0..3 {
            for i in 0..grid_len {
                self.uhat[c][i] = u0[c][i].scale(1.0 / 3.0)
                    + (u1[c][i] + self.ws.fhat[c][i].scale(dt)).scale(2.0 / 3.0);
            }
        }

        self.ws.u0 = u0;
        self.ws.u1 = u1;
        self.t += dt;
        self.stats.steps += 1;
    }

    /// Advance by `interval` (an RL action interval), choosing stable
    /// timesteps; returns the number of RK steps taken.
    pub fn advance(&mut self, interval: f64) -> usize {
        if self.vmax == 0.0 {
            self.vmax = max_velocity_ws(
                &self.grid,
                &self.uhat,
                &mut self.ws.fft,
                &mut self.ws.u_phys,
            );
            self.stats.transforms += 3;
        }
        let t_stop = self.t + interval;
        let mut steps = 0;
        while self.t < t_stop - 1e-12 {
            let dt = self.stable_dt().min(t_stop - self.t);
            self.step(dt);
            steps += 1;
            assert!(
                steps < 100_000,
                "timestep collapse: dt={} at t={}",
                self.stable_dt(),
                self.t
            );
        }
        steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::init::taylor_green;

    /// 2-D Taylor–Green (z-invariant) is an exact NS solution:
    /// u(t) = u(0) * exp(-2 nu t).  The nonlinear term is a pure gradient,
    /// absorbed by the projection, so this tests advection + projection +
    /// viscosity + RK3 together against an analytic solution.
    #[test]
    fn taylor_green_decay_matches_analytic() {
        let nu = 0.05;
        let mut s = Solver::new(16, 2, nu, 0.4);
        s.set_state(taylor_green(&s.grid));
        let ke0 = s.kinetic_energy();
        assert!((ke0 - 0.25).abs() < 1e-10, "ke0={ke0}");
        let t_end = 0.5;
        s.advance(t_end);
        let ke = s.kinetic_energy();
        let want = ke0 * (-4.0 * nu * s.t).exp(); // KE ~ u^2 -> factor e^{-4 nu t}
        assert!(
            (ke - want).abs() < 1e-6 * want,
            "ke={ke} want={want} (t={})",
            s.t
        );
    }

    #[test]
    fn divergence_stays_zero() {
        let mut s = Solver::new(12, 2, 0.01, 0.4);
        let mut rng = crate::util::Rng::new(1);
        s.set_state(crate::solver::init::random_solenoidal(&s.grid, 1.0, 4.0, &mut rng));
        s.advance(0.2);
        assert!(s.max_divergence() < 1e-8, "div={}", s.max_divergence());
    }

    #[test]
    fn unforced_energy_decays() {
        let mut s = Solver::new(12, 2, 0.02, 0.4);
        let mut rng = crate::util::Rng::new(2);
        s.set_state(crate::solver::init::random_solenoidal(&s.grid, 1.0, 3.0, &mut rng));
        let ke0 = s.kinetic_energy();
        s.advance(0.3);
        assert!(s.kinetic_energy() < ke0);
    }

    #[test]
    fn forcing_sustains_energy() {
        let mut s = Solver::new(12, 2, 0.02, 0.4);
        let mut rng = crate::util::Rng::new(3);
        s.set_state(crate::solver::init::random_solenoidal(&s.grid, 1.0, 3.0, &mut rng));
        s.forcing = Some(LinearForcing::new(1.0, 0.5));
        s.advance(2.0);
        let ke = s.kinetic_energy();
        assert!((0.5..2.0).contains(&ke), "ke={ke} drifted from target 1.0");
    }

    #[test]
    fn smagorinsky_dissipates_more_than_implicit() {
        let mut rng = crate::util::Rng::new(4);
        let grid = Grid::new(12);
        let state = crate::solver::init::random_solenoidal(&grid, 1.0, 3.0, &mut rng);

        let mut implicit = Solver::new(12, 2, 0.01, 0.4);
        implicit.set_state(clone_vec(&state));
        implicit.advance(0.3);

        let mut smag = Solver::new(12, 2, 0.01, 0.4);
        smag.set_state(state);
        smag.set_cs_uniform(0.17);
        smag.advance(0.3);

        assert!(
            smag.kinetic_energy() < implicit.kinetic_energy(),
            "smag={} implicit={}",
            smag.kinetic_energy(),
            implicit.kinetic_energy()
        );
    }

    #[test]
    fn cs_actions_are_clamped() {
        let mut s = Solver::new(8, 2, 0.01, 0.4);
        s.set_cs(&vec![-1.0, 0.3, 2.0, 0.0, 0.1, 0.2, 0.5, 0.05]);
        assert_eq!(s.cs[0], 0.0);
        assert_eq!(s.cs[1], 0.3);
        assert_eq!(s.cs[2], 0.5);
    }

    #[test]
    fn advance_hits_exact_interval() {
        let mut s = Solver::new(12, 2, 0.02, 0.4);
        let mut rng = crate::util::Rng::new(5);
        s.set_state(crate::solver::init::random_solenoidal(&s.grid, 1.0, 3.0, &mut rng));
        s.advance(0.1);
        assert!((s.t - 0.1).abs() < 1e-9, "t={}", s.t);
        s.advance(0.1);
        assert!((s.t - 0.2).abs() < 1e-9);
    }

    #[test]
    fn solvers_share_one_plan_across_threads() {
        // The point of Plan: Send + Sync — env workers share a grid/plan.
        let grid = Arc::new(Grid::new(12));
        // Live solvers must hold the *same* Arc, not a deep copy.
        let s1 = Solver::with_grid(grid.clone(), 2, 0.02, 0.4);
        let s2 = Solver::with_grid(grid.clone(), 2, 0.02, 0.4);
        assert_eq!(Arc::strong_count(&grid), 3, "grid not shared by live solvers");
        assert!(std::ptr::eq(&*s1.grid, &*s2.grid));
        drop(s1);
        drop(s2);
        let mut handles = Vec::new();
        for seed in 0..2u64 {
            let g = grid.clone();
            handles.push(std::thread::spawn(move || {
                let mut s = Solver::with_grid(g, 2, 0.02, 0.4);
                let mut rng = crate::util::Rng::new(seed);
                s.set_state(crate::solver::init::random_solenoidal(
                    &s.grid, 1.0, 3.0, &mut rng,
                ));
                s.advance(0.05);
                s.kinetic_energy()
            }));
        }
        for h in handles {
            let ke = h.join().unwrap();
            assert!(ke.is_finite() && ke > 0.0);
        }
    }

    /// Pointer-identity proof that the steady-state step loop reuses every
    /// workspace buffer (no reallocation, no growth) — the zero-allocation
    /// contract of the batched FFT refactor.
    #[test]
    fn step_reuses_buffers() {
        let mut s = Solver::new(12, 2, 0.02, 0.4);
        let mut rng = crate::util::Rng::new(6);
        s.set_state(crate::solver::init::random_solenoidal(&s.grid, 1.0, 3.0, &mut rng));
        s.set_cs_uniform(0.17); // exercise the SGS branch too
        s.advance(0.02); // prime vmax and warm every code path once

        let snapshot = |s: &Solver| -> Vec<(*const Cpx, usize)> {
            let ws = &s.ws;
            let mut v: Vec<(*const Cpx, usize)> = Vec::new();
            for sv in [&ws.omega_hat, &ws.fhat, &ws.u_phys, &ws.w_phys, &ws.f_phys, &ws.u0, &ws.u1]
            {
                for c in sv.iter() {
                    v.push((c.as_ptr(), c.capacity()));
                }
            }
            for c in ws.strain.comps.iter() {
                v.push((c.as_ptr(), c.capacity()));
            }
            v.push((ws.fft.buf.as_ptr(), ws.fft.buf.capacity()));
            v.push((ws.fft.plane.as_ptr(), ws.fft.plane.capacity()));
            v.push((ws.fft.pair.as_ptr(), ws.fft.pair.capacity()));
            v.push((ws.div.as_ptr(), ws.div.capacity()));
            v
        };

        let before = snapshot(&s);
        let dt = s.stable_dt();
        for _ in 0..3 {
            s.step(dt);
        }
        s.max_divergence();
        let after = snapshot(&s);
        assert_eq!(before, after, "workspace buffers were reallocated");
    }
}
