//! Spectral grid for the triply periodic `[0, 2*pi)^3` HIT domain.
//!
//! Precomputes the signed wavenumber tables, |k|^2, the 2/3-rule dealiasing
//! mask and the shared FFT plan for one resolution.  The grid is immutable
//! after construction and `Send + Sync` (the plan keeps no interior
//! scratch), so one `Arc<Grid>` is shared by all env worker threads.

use crate::fft::{wavenumber, Cpx, FftScratch, Plan};

/// Cubic spectral grid of `n^3` points on `[0, 2*pi)^3`.
pub struct Grid {
    /// Points per direction.
    pub n: usize,
    /// Shared FFT plan of length `n`.
    pub plan: Plan,
    /// Signed integer wavenumber per 1-D bin.
    pub kline: Vec<i64>,
    /// 2/3-rule dealias keep-mask per 1-D bin.
    pub dealias_line: Vec<bool>,
    /// Flat index of the mirrored mode `-k` per flat index (Hermitian
    /// pairing for the two-real-fields-per-FFT trick, §Perf).
    pub neg_index: Vec<u32>,
    /// Precomputed (kx, ky, kz) per flat index (§Perf: avoids div/mod in
    /// every pointwise spectral loop).
    kvec_table: Vec<[f64; 3]>,
}

impl Grid {
    /// Build a grid (and FFT plan) for `n` points per direction.
    pub fn new(n: usize) -> Grid {
        let kline: Vec<i64> = (0..n).map(|i| wavenumber(i, n)).collect();
        let kcut = (n as f64) / 3.0;
        let dealias_line = kline.iter().map(|&k| (k.abs() as f64) <= kcut).collect();
        let mut neg_index = vec![0u32; n * n * n];
        let mut kvec_table = vec![[0.0f64; 3]; n * n * n];
        let neg = |i: usize| (n - i) % n;
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    let idx = (z * n + y) * n + x;
                    neg_index[idx] = ((neg(z) * n + neg(y)) * n + neg(x)) as u32;
                    kvec_table[idx] =
                        [kline[x] as f64, kline[y] as f64, kline[z] as f64];
                }
            }
        }
        Grid {
            n,
            plan: Plan::new(n),
            kline,
            dealias_line,
            neg_index,
            kvec_table,
        }
    }

    /// Total grid points.
    pub fn len(&self) -> usize {
        self.n * self.n * self.n
    }

    /// Grids are never empty; silences clippy's len-without-is_empty.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Grid spacing 2*pi/n (also the LES filter width Delta).
    pub fn dx(&self) -> f64 {
        2.0 * std::f64::consts::PI / self.n as f64
    }

    /// Flat index for (x, y, z).
    #[inline]
    pub fn idx(&self, x: usize, y: usize, z: usize) -> usize {
        (z * self.n + y) * self.n + x
    }

    /// Signed wavevector components for a flat spectral index.
    #[inline]
    pub fn kvec(&self, idx: usize) -> (f64, f64, f64) {
        let k = self.kvec_table[idx];
        (k[0], k[1], k[2])
    }

    /// |k|^2 for a flat spectral index.
    #[inline]
    pub fn k_sq(&self, idx: usize) -> f64 {
        let (kx, ky, kz) = self.kvec(idx);
        kx * kx + ky * ky + kz * kz
    }

    /// Does the 2/3 rule keep this flat spectral index?
    #[inline]
    pub fn keep(&self, idx: usize) -> bool {
        let n = self.n;
        self.dealias_line[idx % n]
            && self.dealias_line[(idx / n) % n]
            && self.dealias_line[idx / (n * n)]
    }

    /// Allocate a zeroed complex field on this grid.
    pub fn zeros(&self) -> Vec<Cpx> {
        vec![Cpx::ZERO; self.len()]
    }

    /// Allocate an FFT workspace sized for this grid.
    pub fn make_scratch(&self) -> FftScratch {
        FftScratch::new(self.n)
    }

    /// Apply the 2/3-rule mask in place.
    pub fn dealias(&self, f: &mut [Cpx]) {
        debug_assert_eq!(f.len(), self.len());
        for i in 0..f.len() {
            if !self.keep(i) {
                f[i] = Cpx::ZERO;
            }
        }
    }

    /// Largest fully-resolved shell index for spectra (n/2 bins).
    pub fn k_nyquist(&self) -> usize {
        self.n / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wavenumbers_symmetric() {
        let g = Grid::new(8);
        assert_eq!(g.kline, vec![0, 1, 2, 3, 4, -3, -2, -1]);
    }

    #[test]
    fn ksq_at_origin_is_zero() {
        let g = Grid::new(12);
        assert_eq!(g.k_sq(0), 0.0);
        let (kx, ky, kz) = g.kvec(g.idx(1, 2, 3));
        assert_eq!((kx, ky, kz), (1.0, 2.0, 3.0));
        assert_eq!(g.k_sq(g.idx(1, 2, 3)), 14.0);
    }

    #[test]
    fn dealias_keeps_low_kills_high() {
        let g = Grid::new(24); // cutoff 8
        assert!(g.keep(g.idx(8, 0, 0)));
        assert!(!g.keep(g.idx(9, 0, 0)));
        assert!(!g.keep(g.idx(0, 0, 12)));
        let mut f = g.zeros();
        f[g.idx(9, 0, 0)] = Cpx::new(1.0, 0.0);
        f[g.idx(2, 2, 2)] = Cpx::new(1.0, 0.0);
        g.dealias(&mut f);
        assert_eq!(f[g.idx(9, 0, 0)], Cpx::ZERO);
        assert_eq!(f[g.idx(2, 2, 2)], Cpx::new(1.0, 0.0));
    }

    #[test]
    fn grid_is_send_sync() {
        // One Arc<Grid> (and its embedded Plan) is shared across env
        // worker threads; this must never regress.
        fn check<T: Send + Sync>() {}
        check::<Grid>();
    }

    #[test]
    fn dx_matches_domain() {
        let g = Grid::new(24);
        assert!((g.dx() * 24.0 - 2.0 * std::f64::consts::PI).abs() < 1e-12);
    }
}
