//! Element view of the spectral grid: the paper's DG structure (Table 1).
//!
//! The flow state lives on an `n^3` collocation grid; for the RL task it is
//! tiled into `elems^3` cubic elements of `(N+1)^3` points each — exactly
//! the `#Elems x (N+1)^3` decomposition of Table 1.  The agent observes one
//! element (its local velocity field, `(N+1)^3 x 3` features, Table 2 input)
//! and acts per element (one Cs each).

use super::grid::Grid;
use crate::fft::Cpx;

/// Mapping between grid points and elements.
pub struct ElementMap {
    /// Grid points per direction.
    pub n: usize,
    /// Elements per direction.
    pub elems_per_dir: usize,
    /// Points per element and direction (N+1).
    pub p: usize,
    /// Element id per flat grid index.
    point_to_elem: Vec<usize>,
}

impl ElementMap {
    /// Build the map; `n` must be divisible by `elems_per_dir`.
    pub fn new(grid: &Grid, elems_per_dir: usize) -> ElementMap {
        let n = grid.n;
        assert!(
            n % elems_per_dir == 0,
            "grid {n} not divisible into {elems_per_dir} elements/dir"
        );
        let p = n / elems_per_dir;
        let mut point_to_elem = vec![0usize; n * n * n];
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    let (ex, ey, ez) = (x / p, y / p, z / p);
                    point_to_elem[(z * n + y) * n + x] =
                        (ez * elems_per_dir + ey) * elems_per_dir + ex;
                }
            }
        }
        ElementMap {
            n,
            elems_per_dir,
            p,
            point_to_elem,
        }
    }

    /// Total number of elements.
    pub fn n_elems(&self) -> usize {
        self.elems_per_dir.pow(3)
    }

    /// Element id owning a flat grid index.
    #[inline]
    pub fn elem_of_point(&self, idx: usize) -> usize {
        self.point_to_elem[idx]
    }

    /// Points per element (= (N+1)^3).
    pub fn points_per_elem(&self) -> usize {
        self.p.pow(3)
    }

    /// Gather the observation tensor for ALL elements from physical-space
    /// velocities: layout `(n_elems, p, p, p, 3)` flattened, f32 — the
    /// policy artifact's input order.
    pub fn gather_observations(&self, u: &[Vec<Cpx>; 3]) -> Vec<f32> {
        let mut obs = vec![0f32; self.n_elems() * self.points_per_elem() * 3];
        self.gather_observations_into(u, &mut obs);
        obs
    }

    /// [`ElementMap::gather_observations`] into a caller-owned buffer —
    /// the allocation-free path the env workers' reusable observation
    /// buffers go through.
    pub fn gather_observations_into(&self, u: &[Vec<Cpx>; 3], obs: &mut [f32]) {
        let (n, p, e) = (self.n, self.p, self.elems_per_dir);
        assert_eq!(obs.len(), self.n_elems() * p * p * p * 3);
        let mut w = 0usize;
        for ez in 0..e {
            for ey in 0..e {
                for ex in 0..e {
                    for lz in 0..p {
                        for ly in 0..p {
                            for lx in 0..p {
                                let gi = ((ez * p + lz) * n + (ey * p + ly)) * n
                                    + (ex * p + lx);
                                obs[w] = u[0][gi].re as f32;
                                obs[w + 1] = u[1][gi].re as f32;
                                obs[w + 2] = u[2][gi].re as f32;
                                w += 3;
                            }
                        }
                    }
                }
            }
        }
    }

    /// Element ids in the order `gather_observations` emits them
    /// (row-major over (ez, ey, ex)) — documents/tests the convention.
    pub fn gather_order(&self) -> Vec<usize> {
        let e = self.elems_per_dir;
        let mut order = Vec::with_capacity(self.n_elems());
        for ez in 0..e {
            for ey in 0..e {
                for ex in 0..e {
                    order.push((ez * e + ey) * e + ex);
                }
            }
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_element_counts() {
        let grid = Grid::new(24);
        let m = ElementMap::new(&grid, 4);
        assert_eq!(m.n_elems(), 64);
        assert_eq!(m.p, 6);
        assert_eq!(m.points_per_elem(), 216);
    }

    #[test]
    fn point_ownership() {
        let grid = Grid::new(8);
        let m = ElementMap::new(&grid, 2);
        assert_eq!(m.elem_of_point(grid.idx(0, 0, 0)), 0);
        assert_eq!(m.elem_of_point(grid.idx(7, 0, 0)), 1);
        assert_eq!(m.elem_of_point(grid.idx(0, 7, 0)), 2);
        assert_eq!(m.elem_of_point(grid.idx(0, 0, 7)), 4);
        assert_eq!(m.elem_of_point(grid.idx(7, 7, 7)), 7);
    }

    #[test]
    #[should_panic]
    fn indivisible_grid_panics() {
        let grid = Grid::new(10);
        ElementMap::new(&grid, 4);
    }

    #[test]
    fn gather_obs_layout() {
        let grid = Grid::new(4);
        let m = ElementMap::new(&grid, 2); // p = 2
        // velocity components encode the grid position:
        let mut u = [grid.zeros(), grid.zeros(), grid.zeros()];
        for z in 0..4 {
            for y in 0..4 {
                for x in 0..4 {
                    let i = grid.idx(x, y, z);
                    u[0][i] = Cpx::new(x as f64, 0.0);
                    u[1][i] = Cpx::new(y as f64, 0.0);
                    u[2][i] = Cpx::new(z as f64, 0.0);
                }
            }
        }
        let obs = m.gather_observations(&u);
        assert_eq!(obs.len(), 8 * 8 * 3);
        // Element 0, local point (0,0,0) -> features (0,0,0)
        assert_eq!(&obs[0..3], &[0.0, 0.0, 0.0]);
        // Element 0, local (lx=1) is the second feature triple
        assert_eq!(&obs[3..6], &[1.0, 0.0, 0.0]);
        // Element 1 (ex=1) starts at offset 8*3: its first point is x=2
        assert_eq!(&obs[24..27], &[2.0, 0.0, 0.0]);
        // Last element (ex=ey=ez=1), last local point = grid (3,3,3)
        let last = obs.len() - 3;
        assert_eq!(&obs[last..], &[3.0, 3.0, 3.0]);
    }

    #[test]
    fn gather_order_matches_elem_ids() {
        let grid = Grid::new(8);
        let m = ElementMap::new(&grid, 2);
        assert_eq!(m.gather_order(), (0..8).collect::<Vec<_>>());
    }
}
