//! Initial conditions: random solenoidal fields with a prescribed energy
//! spectrum (for DNS spin-up) and the Taylor–Green vortex (for validation).

use super::grid::Grid;
use super::spectral::{project, to_spectral, SpecVec};
use super::spectrum::energy_spectrum;
use crate::fft::{Cpx, FftScratch};
use crate::util::Rng;

/// Model spectrum E(k) ~ (k/k0)^4 exp(-2 (k/k0)^2) — the standard
/// von-Karman-like initial distribution peaking near `k0`.
pub fn model_spectrum(k: f64, k0: f64) -> f64 {
    let r = k / k0;
    r.powi(4) * (-2.0 * r * r).exp()
}

/// Random divergence-free velocity field with shell energies matching
/// `model_spectrum`, scaled to total kinetic energy `ke_target`.
///
/// Construction: white Gaussian noise in *physical* space (guarantees a
/// real field / Hermitian spectrum), projected solenoidal, then each shell
/// rescaled to the target spectrum.  Modes beyond the 2/3 cutoff are
/// zeroed so the state starts dealiased.
pub fn random_solenoidal(grid: &Grid, ke_target: f64, k0: f64, rng: &mut Rng) -> SpecVec {
    let mut u: SpecVec = [grid.zeros(), grid.zeros(), grid.zeros()];
    let mut phys = grid.zeros();
    let mut ws = FftScratch::new(grid.n);
    for c in u.iter_mut() {
        for p in phys.iter_mut() {
            *p = Cpx::new(rng.normal(), 0.0);
        }
        to_spectral(grid, &phys, c, &mut ws);
    }
    project(grid, &mut u);
    for c in u.iter_mut() {
        grid.dealias(c);
    }

    // Current and target shell energies.
    let current = energy_spectrum(grid, &u);
    let nbins = current.len();
    let kcut = grid.n as f64 / 3.0;
    let mut target: Vec<f64> = (0..nbins)
        .map(|k| {
            if k == 0 || k as f64 > kcut {
                0.0
            } else {
                model_spectrum(k as f64, k0)
            }
        })
        .collect();
    let sum: f64 = target.iter().sum();
    assert!(sum > 0.0, "empty target spectrum (k0={k0}, n={})", grid.n);
    for t in target.iter_mut() {
        *t *= ke_target / sum;
    }

    // Per-shell rescale.
    let scale: Vec<f64> = (0..nbins)
        .map(|k| {
            if current[k] > 1e-300 && target[k] > 0.0 {
                (target[k] / current[k]).sqrt()
            } else {
                0.0
            }
        })
        .collect();
    for i in 0..grid.len() {
        let bin = grid.k_sq(i).sqrt().round() as usize;
        let s = if bin < nbins { scale[bin] } else { 0.0 };
        for c in u.iter_mut() {
            c[i] = c[i].scale(s);
        }
    }
    u
}

/// 2-D Taylor–Green vortex (z-invariant): u = (sin x cos y, -cos x sin y, 0).
/// An exact Navier–Stokes solution decaying as `exp(-2 nu t)`.
pub fn taylor_green(grid: &Grid) -> SpecVec {
    let n = grid.n;
    let mut ux = grid.zeros();
    let mut uy = grid.zeros();
    let dx = grid.dx();
    let mut phys_x = grid.zeros();
    let mut phys_y = grid.zeros();
    for z in 0..n {
        for y in 0..n {
            for x in 0..n {
                let (xx, yy) = (x as f64 * dx, y as f64 * dx);
                let i = grid.idx(x, y, z);
                phys_x[i] = Cpx::new(xx.sin() * yy.cos(), 0.0);
                phys_y[i] = Cpx::new(-xx.cos() * yy.sin(), 0.0);
            }
        }
    }
    let mut ws = FftScratch::new(grid.n);
    to_spectral(grid, &phys_x, &mut ux, &mut ws);
    to_spectral(grid, &phys_y, &mut uy, &mut ws);
    [ux, uy, grid.zeros()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::spectral::{divergence, kinetic_energy};

    #[test]
    fn random_field_hits_target_energy() {
        let grid = Grid::new(24);
        let mut rng = Rng::new(11);
        let u = random_solenoidal(&grid, 1.5, 4.0, &mut rng);
        let ke = kinetic_energy(&grid, &u);
        assert!((ke - 1.5).abs() < 1e-9, "ke={ke}");
    }

    #[test]
    fn random_field_is_solenoidal_and_dealiased() {
        let grid = Grid::new(24);
        let mut rng = Rng::new(12);
        let u = random_solenoidal(&grid, 1.0, 4.0, &mut rng);
        let mut div = grid.zeros();
        divergence(&grid, &u, &mut div);
        let maxdiv = div.iter().map(|c| c.norm_sq().sqrt()).fold(0.0, f64::max);
        assert!(maxdiv < 1e-9, "maxdiv={maxdiv}");
        for c in u.iter() {
            for (i, v) in c.iter().enumerate() {
                if !grid.keep(i) {
                    assert_eq!(v.norm_sq(), 0.0);
                }
            }
        }
    }

    #[test]
    fn random_field_is_real_in_physical_space() {
        let grid = Grid::new(16);
        let mut rng = Rng::new(13);
        let u = random_solenoidal(&grid, 1.0, 3.0, &mut rng);
        let mut phys = grid.zeros();
        let mut ws = grid.make_scratch();
        super::super::spectral::to_physical(&grid, &u[0], &mut phys, &mut ws);
        let max_imag = phys.iter().map(|c| c.im.abs()).fold(0.0, f64::max);
        let max_real = phys.iter().map(|c| c.re.abs()).fold(0.0, f64::max);
        assert!(max_imag < 1e-10 * max_real.max(1.0), "imag leak {max_imag}");
    }

    #[test]
    fn spectrum_peaks_near_k0() {
        let grid = Grid::new(32);
        let mut rng = Rng::new(14);
        let u = random_solenoidal(&grid, 1.0, 4.0, &mut rng);
        let spec = energy_spectrum(&grid, &u);
        let peak = spec
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!((3..=5).contains(&peak), "peak at k={peak}");
    }

    #[test]
    fn different_seeds_different_fields() {
        let grid = Grid::new(12);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(2);
        let a = random_solenoidal(&grid, 1.0, 3.0, &mut r1);
        let b = random_solenoidal(&grid, 1.0, 3.0, &mut r2);
        let diff: f64 = a[0]
            .iter()
            .zip(&b[0])
            .map(|(x, y)| (*x - *y).norm_sq())
            .sum();
        assert!(diff > 1e-6);
    }
}
