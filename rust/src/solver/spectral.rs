//! Spectral-space operators: derivatives, curl, divergence-free projection,
//! and physical<->spectral conversions for vector fields.
//!
//! All transforms route through a caller-owned [`FftScratch`] so the solver
//! step loop performs no heap allocations (the workspace is held by
//! `Solver`); only explicitly documented cold paths allocate.

use super::grid::Grid;
use crate::fft::{fft3d_with, fft3d_ws, Cpx, FftScratch};

/// A velocity field in spectral space: three complex components.
pub type SpecVec = [Vec<Cpx>; 3];

/// Allocate a zeroed spectral vector field.
pub fn zeros_vec(grid: &Grid) -> SpecVec {
    [grid.zeros(), grid.zeros(), grid.zeros()]
}

/// Deep-copy a spectral vector field.
pub fn clone_vec(v: &SpecVec) -> SpecVec {
    [v[0].clone(), v[1].clone(), v[2].clone()]
}

/// `out = i * k_axis * f` (spectral derivative along one axis).
pub fn derivative(grid: &Grid, f: &[Cpx], axis: usize, out: &mut [Cpx]) {
    for i in 0..f.len() {
        let (kx, ky, kz) = grid.kvec(i);
        let k = [kx, ky, kz][axis];
        out[i] = f[i].mul_i().scale(k);
    }
}

/// Curl of a spectral vector field: `omega = i k x u`.
pub fn curl(grid: &Grid, u: &SpecVec, out: &mut SpecVec) {
    for i in 0..grid.len() {
        let (kx, ky, kz) = grid.kvec(i);
        let (ux, uy, uz) = (u[0][i], u[1][i], u[2][i]);
        // (i k) x u
        out[0][i] = (uz.scale(ky) - uy.scale(kz)).mul_i();
        out[1][i] = (ux.scale(kz) - uz.scale(kx)).mul_i();
        out[2][i] = (uy.scale(kx) - ux.scale(ky)).mul_i();
    }
}

/// Divergence `i k . u` (diagnostic; the state should keep this ~0).
pub fn divergence(grid: &Grid, u: &SpecVec, out: &mut [Cpx]) {
    for i in 0..grid.len() {
        let (kx, ky, kz) = grid.kvec(i);
        out[i] = (u[0][i].scale(kx) + u[1][i].scale(ky) + u[2][i].scale(kz)).mul_i();
    }
}

/// Leray projection `u <- (I - k k^T / k^2) u`; zeroes the mean mode.
pub fn project(grid: &Grid, u: &mut SpecVec) {
    for i in 0..grid.len() {
        let k2 = grid.k_sq(i);
        if k2 == 0.0 {
            u[0][i] = Cpx::ZERO;
            u[1][i] = Cpx::ZERO;
            u[2][i] = Cpx::ZERO;
            continue;
        }
        let (kx, ky, kz) = grid.kvec(i);
        let kdotu = u[0][i].scale(kx) + u[1][i].scale(ky) + u[2][i].scale(kz);
        let s = kdotu.scale(1.0 / k2);
        u[0][i] = u[0][i] - s.scale(kx);
        u[1][i] = u[1][i] - s.scale(ky);
        u[2][i] = u[2][i] - s.scale(kz);
    }
}

/// Spectral -> physical for one component (in-place on a copy).
pub fn to_physical(grid: &Grid, fhat: &[Cpx], out: &mut [Cpx], ws: &mut FftScratch) {
    out.copy_from_slice(fhat);
    fft3d_ws(out, &grid.plan, true, ws);
}

/// Physical -> spectral for one component.
pub fn to_spectral(grid: &Grid, f: &[Cpx], out: &mut [Cpx], ws: &mut FftScratch) {
    out.copy_from_slice(f);
    fft3d_ws(out, &grid.plan, false, ws);
}

/// Inverse-transform TWO spectral fields of real physical signals with a
/// single complex FFT (the classic Hermitian pairing; §Perf-L3): since
/// ifft(a) is real and ifft(b) is real, `ifft(a + i b) = ifft(a) +
/// i*ifft(b)` — the real/imag parts of one inverse transform.
/// Outputs have zero imaginary parts.  Packing goes through `ws.pair`.
pub fn ifft_pair(
    grid: &Grid,
    ahat: &[Cpx],
    bhat: &[Cpx],
    ws: &mut FftScratch,
    out_a: &mut [Cpx],
    out_b: &mut [Cpx],
) {
    let FftScratch { buf, plane, pair } = ws;
    if pair.len() < grid.len() {
        pair.resize(grid.len(), Cpx::ZERO);
    }
    for i in 0..grid.len() {
        pair[i] = ahat[i] + bhat[i].mul_i();
    }
    fft3d_with(&mut pair[..grid.len()], &grid.plan, true, buf, plane);
    for i in 0..grid.len() {
        out_a[i] = Cpx::new(pair[i].re, 0.0);
        out_b[i] = Cpx::new(pair[i].im, 0.0);
    }
}

/// Forward-transform TWO real physical fields (stored in the `.re` parts)
/// with a single complex FFT, splitting the Hermitian-symmetric result:
/// `ahat(k) = (H(k) + conj(H(-k)))/2`, `bhat(k) = -i (H(k) - conj(H(-k)))/2`.
/// In-place: `a` and `b` are replaced by their transforms.
pub fn fft_pair_real(grid: &Grid, ws: &mut FftScratch, a: &mut [Cpx], b: &mut [Cpx]) {
    let FftScratch { buf, plane, pair } = ws;
    if pair.len() < grid.len() {
        pair.resize(grid.len(), Cpx::ZERO);
    }
    for i in 0..grid.len() {
        pair[i] = Cpx::new(a[i].re, b[i].re);
    }
    fft3d_with(&mut pair[..grid.len()], &grid.plan, false, buf, plane);
    for i in 0..grid.len() {
        let h = pair[i];
        let hn = pair[grid.neg_index[i] as usize].conj();
        a[i] = (h + hn).scale(0.5);
        b[i] = (h - hn).scale(0.5).mul_i().scale(-1.0);
    }
}

/// Volume-mean kinetic energy `0.5 <|u|^2>` from the spectral state.
/// With unnormalized forward FFT the coefficients are `uhat / n^3`.
pub fn kinetic_energy(grid: &Grid, u: &SpecVec) -> f64 {
    let n3 = grid.len() as f64;
    let mut sum = 0.0;
    for c in u.iter() {
        for v in c.iter() {
            sum += v.norm_sq();
        }
    }
    0.5 * sum / (n3 * n3)
}

/// Max pointwise |u| in physical space (for the CFL timestep), through
/// caller-owned scratch: `phys` receives the physical-space velocity.
pub fn max_velocity_ws(
    grid: &Grid,
    u: &SpecVec,
    ws: &mut FftScratch,
    phys: &mut SpecVec,
) -> f64 {
    for (c, buf) in u.iter().zip(phys.iter_mut()) {
        to_physical(grid, c, buf, ws);
    }
    let mut vmax: f64 = 0.0;
    for i in 0..grid.len() {
        let v2 = phys[0][i].re * phys[0][i].re
            + phys[1][i].re * phys[1][i].re
            + phys[2][i].re * phys[2][i].re;
        vmax = vmax.max(v2);
    }
    vmax.sqrt()
}

/// Allocating convenience wrapper around [`max_velocity_ws`] (tests and
/// one-off diagnostics; the solver uses its workspace).
pub fn max_velocity(grid: &Grid, u: &SpecVec) -> f64 {
    let mut ws = FftScratch::new(grid.n);
    let mut phys = zeros_vec(grid);
    max_velocity_ws(grid, u, &mut ws, &mut phys)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build u = (sin z, 0, 0): curl = (0, cos z, 0).
    fn single_mode_field(grid: &Grid) -> SpecVec {
        let n = grid.n;
        let mut ux = grid.zeros();
        // sin(z) = (e^{iz} - e^{-iz}) / 2i -> bins kz=+1: -i/2, kz=-1: +i/2
        let scale = (n * n * n) as f64;
        ux[grid.idx(0, 0, 1)] = Cpx::new(0.0, -0.5).scale(scale);
        ux[grid.idx(0, 0, n - 1)] = Cpx::new(0.0, 0.5).scale(scale);
        [ux, grid.zeros(), grid.zeros()]
    }

    #[test]
    fn curl_of_shear_is_cos() {
        let grid = Grid::new(16);
        let mut ws = FftScratch::new(grid.n);
        let u = single_mode_field(&grid);
        let mut w = zeros_vec(&grid);
        curl(&grid, &u, &mut w);
        let mut wy = grid.zeros();
        to_physical(&grid, &w[1], &mut wy, &mut ws);
        for z in 0..grid.n {
            let want = (z as f64 * grid.dx()).cos();
            let got = wy[grid.idx(3, 5, z)].re;
            assert!((got - want).abs() < 1e-9, "z={z}: {got} vs {want}");
        }
    }

    #[test]
    fn projection_removes_divergence() {
        let grid = Grid::new(12);
        let mut rng = crate::util::Rng::new(3);
        let mut u = zeros_vec(&grid);
        for c in u.iter_mut() {
            for v in c.iter_mut() {
                *v = Cpx::new(rng.normal(), rng.normal());
            }
        }
        project(&grid, &mut u);
        let mut div = grid.zeros();
        divergence(&grid, &u, &mut div);
        let max_div = div.iter().map(|c| c.norm_sq().sqrt()).fold(0.0, f64::max);
        assert!(max_div < 1e-10, "max_div={max_div}");
    }

    #[test]
    fn projection_idempotent() {
        let grid = Grid::new(8);
        let mut rng = crate::util::Rng::new(4);
        let mut u = zeros_vec(&grid);
        for c in u.iter_mut() {
            for v in c.iter_mut() {
                *v = Cpx::new(rng.normal(), rng.normal());
            }
        }
        project(&grid, &mut u);
        let once = clone_vec(&u);
        project(&grid, &mut u);
        for c in 0..3 {
            for i in 0..grid.len() {
                assert!((u[c][i] - once[c][i]).norm_sq() < 1e-24);
            }
        }
    }

    #[test]
    fn kinetic_energy_of_sine_mode() {
        // u = (sin z, 0, 0): <u^2>/2 = 1/4.
        let grid = Grid::new(16);
        let u = single_mode_field(&grid);
        let ke = kinetic_energy(&grid, &u);
        assert!((ke - 0.25).abs() < 1e-12, "ke={ke}");
    }

    #[test]
    fn max_velocity_of_sine_mode() {
        let grid = Grid::new(16);
        let u = single_mode_field(&grid);
        let vmax = max_velocity(&grid, &u);
        assert!((vmax - 1.0).abs() < 1e-6, "vmax={vmax}");
    }

    #[test]
    fn paired_transforms_match_singles() {
        let grid = Grid::new(12);
        let mut ws = FftScratch::new(grid.n);
        let mut rng = crate::util::Rng::new(21);
        // Two random REAL physical fields.
        let mut a = grid.zeros();
        let mut b = grid.zeros();
        for i in 0..grid.len() {
            a[i] = Cpx::new(rng.normal(), 0.0);
            b[i] = Cpx::new(rng.normal(), 0.0);
        }
        // Reference forward transforms.
        let mut ar = grid.zeros();
        let mut br = grid.zeros();
        to_spectral(&grid, &a, &mut ar, &mut ws);
        to_spectral(&grid, &b, &mut br, &mut ws);
        // Paired forward.
        let mut ap = a.clone();
        let mut bp = b.clone();
        fft_pair_real(&grid, &mut ws, &mut ap, &mut bp);
        for i in 0..grid.len() {
            assert!((ap[i] - ar[i]).norm_sq().sqrt() < 1e-9, "ahat[{i}]");
            assert!((bp[i] - br[i]).norm_sq().sqrt() < 1e-9, "bhat[{i}]");
        }
        // Paired inverse round-trips to the original real fields.
        let mut ia = grid.zeros();
        let mut ib = grid.zeros();
        ifft_pair(&grid, &ap, &bp, &mut ws, &mut ia, &mut ib);
        for i in 0..grid.len() {
            assert!((ia[i].re - a[i].re).abs() < 1e-9);
            assert!((ib[i].re - b[i].re).abs() < 1e-9);
            assert_eq!(ia[i].im, 0.0);
            assert_eq!(ib[i].im, 0.0);
        }
    }

    #[test]
    fn ifft_pair_matches_single_inverse_transforms() {
        // Hermitian-pairing equivalence on random real fields: ifft_pair
        // must reproduce two independent single inverse transforms.
        let grid = Grid::new(16);
        let mut ws = FftScratch::new(grid.n);
        let mut rng = crate::util::Rng::new(33);
        // Spectra of real fields: start from random REAL physical fields
        // and forward-transform them so a/b have Hermitian symmetry.
        let mut a = grid.zeros();
        let mut b = grid.zeros();
        for i in 0..grid.len() {
            a[i] = Cpx::new(rng.normal(), 0.0);
            b[i] = Cpx::new(rng.normal(), 0.0);
        }
        let mut ahat = grid.zeros();
        let mut bhat = grid.zeros();
        to_spectral(&grid, &a, &mut ahat, &mut ws);
        to_spectral(&grid, &b, &mut bhat, &mut ws);
        // Singles.
        let mut sa = grid.zeros();
        let mut sb = grid.zeros();
        to_physical(&grid, &ahat, &mut sa, &mut ws);
        to_physical(&grid, &bhat, &mut sb, &mut ws);
        // Paired.
        let mut pa = grid.zeros();
        let mut pb = grid.zeros();
        ifft_pair(&grid, &ahat, &bhat, &mut ws, &mut pa, &mut pb);
        for i in 0..grid.len() {
            assert!((pa[i].re - sa[i].re).abs() < 1e-9, "a[{i}]");
            assert!((pb[i].re - sb[i].re).abs() < 1e-9, "b[{i}]");
        }
    }

    #[test]
    fn derivative_of_mode() {
        let grid = Grid::new(16);
        let mut ws = FftScratch::new(grid.n);
        let u = single_mode_field(&grid);
        let mut d = grid.zeros();
        derivative(&grid, &u[0], 2, &mut d);
        let mut phys = grid.zeros();
        to_physical(&grid, &d, &mut phys, &mut ws);
        // d/dz sin z = cos z
        for z in 0..grid.n {
            let want = (z as f64 * grid.dx()).cos();
            assert!((phys[grid.idx(1, 1, z)].re - want).abs() < 1e-9);
        }
    }
}
