//! Shell-binned turbulent kinetic-energy spectrum E(k) and the spectrum
//! error that drives the reward, Eq. (4) of the paper.

use super::grid::Grid;
use super::spectral::SpecVec;

/// Shell-binned energy spectrum.  Bin `k` collects modes with
/// `round(|k_vec|) == k`; the sum over bins equals the mean kinetic energy.
pub fn energy_spectrum(grid: &Grid, u: &SpecVec) -> Vec<f64> {
    let mut spec = vec![0.0; grid.k_nyquist() + 1];
    energy_spectrum_into(grid, u, &mut spec);
    spec
}

/// Zero-allocation variant of [`energy_spectrum`]: accumulates into a
/// caller-owned buffer of `grid.k_nyquist() + 1` bins (reward hot path).
pub fn energy_spectrum_into(grid: &Grid, u: &SpecVec, spec: &mut [f64]) {
    let nbins = grid.k_nyquist() + 1;
    assert_eq!(spec.len(), nbins, "spectrum buffer has wrong bin count");
    let n3 = grid.len() as f64;
    let norm = 1.0 / (n3 * n3);
    spec.fill(0.0);
    for i in 0..grid.len() {
        let kmag = grid.k_sq(i).sqrt();
        let bin = kmag.round() as usize;
        if bin >= nbins {
            continue;
        }
        let e = 0.5 * (u[0][i].norm_sq() + u[1][i].norm_sq() + u[2][i].norm_sq());
        spec[bin] += e * norm;
    }
}

/// Mean relative squared spectrum error, Eq. (4):
/// `l = mean_k [ ((E_dns(k) - E_les(k)) / E_dns(k))^2 ]` over `k in [1, k_max]`.
pub fn spectrum_error(e_dns: &[f64], e_les: &[f64], k_max: usize) -> f64 {
    assert!(k_max >= 1, "k_max must be >= 1");
    assert!(
        e_dns.len() > k_max && e_les.len() > k_max,
        "spectra too short for k_max={k_max}: dns={}, les={}",
        e_dns.len(),
        e_les.len()
    );
    let mut acc = 0.0;
    for k in 1..=k_max {
        debug_assert!(e_dns[k] > 0.0, "DNS spectrum empty at k={k}");
        let rel = (e_dns[k] - e_les[k]) / e_dns[k];
        acc += rel * rel;
    }
    acc / k_max as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::Cpx;
    use crate::solver::spectral::{kinetic_energy, zeros_vec};

    #[test]
    fn spectrum_sums_to_kinetic_energy() {
        let grid = Grid::new(16);
        let mut rng = crate::util::Rng::new(5);
        let mut u = zeros_vec(&grid);
        for c in u.iter_mut() {
            for v in c.iter_mut() {
                *v = Cpx::new(rng.normal(), rng.normal());
            }
        }
        let spec = energy_spectrum(&grid, &u);
        let ke = kinetic_energy(&grid, &u);
        // Bins only cover round(|k|) <= n/2; modes in the corner shells
        // (|k| > n/2) are excluded, so compare against the binned subset.
        let n3 = grid.len() as f64;
        let mut ke_binned = 0.0;
        for i in 0..grid.len() {
            if (grid.k_sq(i).sqrt().round() as usize) < spec.len() {
                ke_binned += 0.5
                    * (u[0][i].norm_sq() + u[1][i].norm_sq() + u[2][i].norm_sq())
                    / (n3 * n3);
            }
        }
        let total: f64 = spec.iter().sum();
        assert!((total - ke_binned).abs() < 1e-10 * ke.max(1.0));
        assert!(total <= ke + 1e-12);
    }

    #[test]
    fn single_mode_lands_in_right_shell() {
        let grid = Grid::new(16);
        let mut u = zeros_vec(&grid);
        let n3 = grid.len() as f64;
        // Mode k = (3, 0, 0), coefficient chosen for E = 0.5 in that shell.
        u[0][grid.idx(3, 0, 0)] = Cpx::new(n3, 0.0);
        let spec = energy_spectrum(&grid, &u);
        assert!((spec[3] - 0.5).abs() < 1e-12);
        for (k, &e) in spec.iter().enumerate() {
            if k != 3 {
                assert_eq!(e, 0.0, "unexpected energy in shell {k}");
            }
        }
    }

    #[test]
    fn spectrum_error_zero_for_identical() {
        let e = vec![1.0, 0.5, 0.25, 0.125];
        assert_eq!(spectrum_error(&e, &e, 3), 0.0);
    }

    #[test]
    fn spectrum_error_matches_hand_computation() {
        let dns = vec![9.9, 1.0, 2.0];
        let les = vec![9.9, 0.5, 3.0];
        // k=1: (0.5/1)^2 = 0.25 ; k=2: (-1/2)^2 = 0.25 ; mean = 0.25
        assert!((spectrum_error(&dns, &les, 2) - 0.25).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn spectrum_error_rejects_short_input() {
        spectrum_error(&[1.0, 1.0], &[1.0, 1.0], 5);
    }
}
