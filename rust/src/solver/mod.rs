//! The flow-solver substrate (FLEXI analogue; DESIGN.md §2): a from-scratch
//! pseudo-spectral incompressible Navier–Stokes solver for the forced
//! homogeneous-isotropic-turbulence test case of the paper, with the
//! element-structured state/action view of Table 1 and DNS ground-truth
//! generation for the reward.

pub mod dns;
pub mod elements;
pub mod forcing;
pub mod grid;
pub mod init;
pub mod sgs;
pub mod spectral;
pub mod spectrum;
pub mod timestep;

pub use elements::ElementMap;
pub use grid::Grid;
pub use timestep::{Solver, SolverStats};
