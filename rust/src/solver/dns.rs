//! DNS ground truth for the RL task (paper §5.2): the mean energy spectrum
//! `E_DNS(k)` the reward compares against, plus a pool of spectrally
//! filtered DNS snapshots used as randomized LES initial states — with one
//! held-out test state, exactly as in the paper.

use super::grid::Grid;
use super::init::random_solenoidal;
use super::spectral::SpecVec;
use super::timestep::Solver;
use crate::fft::{wavenumber, Cpx};
use crate::util::pool;
use crate::util::Rng;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A spectral state serialized as interleaved f32 (re, im) per component.
pub type FlatState = Vec<f32>;

/// Ground-truth package consumed by training.
pub struct Truth {
    /// LES resolution this truth was filtered for.
    pub n_les: usize,
    /// Time-averaged DNS spectrum on LES shell bins.
    pub mean_spectrum: Vec<f64>,
    /// Min/max observed DNS spectrum (the shaded band of Fig. 5c).
    pub min_spectrum: Vec<f64>,
    pub max_spectrum: Vec<f64>,
    /// Training pool of filtered initial states.
    pub states: Vec<FlatState>,
    /// Held-out test state ("kept hidden to evaluate ... on unseen data").
    pub test_state: FlatState,
}

/// Parameters for truth generation.
pub struct TruthParams {
    pub n_dns: usize,
    pub n_les: usize,
    pub nu: f64,
    pub ke_target: f64,
    pub spinup_time: f64,
    pub n_states: usize,
    pub sample_interval: f64,
    pub seed: u64,
}

impl Default for TruthParams {
    fn default() -> Self {
        TruthParams {
            n_dns: 48,
            n_les: 24,
            nu: 1.0 / 45.0, // resolved at 48^3 (see SolverConfig::default)
            ke_target: 1.5,
            spinup_time: 4.0,
            n_states: 10,
            sample_interval: 0.5,
            seed: 2022,
        }
    }
}

/// Pack a spectral state into flat f32 (re, im interleaved, 3 components).
pub fn pack_state(u: &SpecVec) -> FlatState {
    let mut out = Vec::with_capacity(u[0].len() * 6);
    for c in u.iter() {
        for v in c.iter() {
            out.push(v.re as f32);
            out.push(v.im as f32);
        }
    }
    out
}

/// Unpack a flat f32 state onto a grid.
pub fn unpack_state(grid: &Grid, flat: &[f32]) -> SpecVec {
    let n3 = grid.len();
    assert_eq!(flat.len(), n3 * 6, "state size mismatch for n={}", grid.n);
    let mut u: SpecVec = [grid.zeros(), grid.zeros(), grid.zeros()];
    for (c, comp) in u.iter_mut().enumerate() {
        let base = c * n3 * 2;
        for i in 0..n3 {
            comp[i] = Cpx::new(flat[base + 2 * i] as f64, flat[base + 2 * i + 1] as f64);
        }
    }
    u
}

/// Sharp spectral filter: truncate a DNS state to the LES grid.
///
/// Copies all modes with |k_i| < n_les/2 (Nyquist planes zeroed) and
/// rescales by `(n_les/n_dns)^3` for the unnormalized-FFT convention.
pub fn filter_to_les(dns_grid: &Grid, u_dns: &SpecVec, les_grid: &Grid) -> SpecVec {
    filter_to_les_pool(dns_grid, u_dns, les_grid, &pool::global())
}

/// [`filter_to_les`] against an explicit worker pool — the thread-count
/// A/B hook for benches and determinism tests.
pub fn filter_to_les_pool(
    dns_grid: &Grid,
    u_dns: &SpecVec,
    les_grid: &Grid,
    pool: &pool::Pool,
) -> SpecVec {
    let (nd, nl) = (dns_grid.n, les_grid.n);
    assert!(nl <= nd, "LES grid must be coarser than DNS");
    let scale = (nl as f64 / nd as f64).powi(3);
    let half = nl / 2;
    let mut out: SpecVec = [les_grid.zeros(), les_grid.zeros(), les_grid.zeros()];
    // One task per output z-plane per component over the kernel worker
    // pool: tasks write disjoint plane chunks (truncated modes stay at
    // their initialized zero) and only read the shared DNS state, so any
    // pool width produces bit-identical output.
    for (c, comp) in out.iter_mut().enumerate() {
        pool.parallel_chunks_mut(&mut comp[..], nl * nl, |lz, plane| {
            let kz = wavenumber(lz, nl);
            if kz.unsigned_abs() as usize >= half {
                return;
            }
            let dz = if kz >= 0 { kz as usize } else { (nd as i64 + kz) as usize };
            for ly in 0..nl {
                let ky = wavenumber(ly, nl);
                if ky.unsigned_abs() as usize >= half {
                    continue;
                }
                let dy = if ky >= 0 { ky as usize } else { (nd as i64 + ky) as usize };
                for lx in 0..nl {
                    let kx = wavenumber(lx, nl);
                    if kx.unsigned_abs() as usize >= half {
                        continue;
                    }
                    let dx = if kx >= 0 { kx as usize } else { (nd as i64 + kx) as usize };
                    let di = (dz * nd + dy) * nd + dx;
                    plane[ly * nl + lx] = u_dns[c][di].scale(scale);
                }
            }
        });
    }
    out
}

/// Run the DNS and build the truth package.  `progress` is called after
/// every sample with (sample_index, total).
pub fn generate(p: &TruthParams, mut progress: impl FnMut(usize, usize)) -> Truth {
    let mut rng = Rng::new(p.seed);
    let mut dns = Solver::new(p.n_dns, 1, p.nu, 0.5);
    dns.forcing = Some(super::forcing::LinearForcing::new(p.ke_target, 1.0));
    dns.set_state(random_solenoidal(&dns.grid, p.ke_target, 4.0, &mut rng));
    dns.advance(p.spinup_time);

    let les_grid = Grid::new(p.n_les);
    let nbins = les_grid.k_nyquist() + 1;
    let mut mean = vec![0.0; nbins];
    let mut minb = vec![f64::INFINITY; nbins];
    let mut maxb = vec![f64::NEG_INFINITY; nbins];
    let mut states = Vec::new();
    // Reused spectrum buffer (zero-allocation sampling loop).
    let mut spec_dns = vec![0.0; dns.grid.k_nyquist() + 1];

    let total = p.n_states + 1; // +1 for the held-out test state
    for s in 0..total {
        dns.advance(p.sample_interval);
        // DNS spectrum restricted to LES bins.
        super::spectrum::energy_spectrum_into(&dns.grid, &dns.uhat, &mut spec_dns);
        for k in 0..nbins {
            let e = spec_dns[k.min(spec_dns.len() - 1)];
            mean[k] += e / total as f64;
            minb[k] = minb[k].min(e);
            maxb[k] = maxb[k].max(e);
        }
        let filtered = filter_to_les(&dns.grid, &dns.uhat, &les_grid);
        states.push(pack_state(&filtered));
        progress(s + 1, total);
    }
    let test_state = states.pop().unwrap();

    Truth {
        n_les: p.n_les,
        mean_spectrum: mean,
        min_spectrum: minb,
        max_spectrum: maxb,
        states,
        test_state,
    }
}

// ---------------------------------------------------------------------------
// Binary serialization (custom format; no serde in the image)
// ---------------------------------------------------------------------------

const MAGIC: &[u8; 8] = b"RLXTRUTH";
const VERSION: u32 = 1;

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64s(&mut self, vs: &[f64]) {
        self.u32(vs.len() as u32);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn f32s(&mut self, vs: &[f32]) {
        self.u32(vs.len() as u32);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated truth file at byte {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        let b = self.take(n * 8)?;
        Ok(b.chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let b = self.take(n * 4)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

impl Truth {
    /// Serialize to a file.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = Writer { buf: Vec::new() };
        w.buf.extend_from_slice(MAGIC);
        w.u32(VERSION);
        w.u32(self.n_les as u32);
        w.f64s(&self.mean_spectrum);
        w.f64s(&self.min_spectrum);
        w.f64s(&self.max_spectrum);
        w.f32s(&self.test_state);
        w.u32(self.states.len() as u32);
        for s in &self.states {
            w.f32s(s);
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, &w.buf).with_context(|| format!("write {path:?}"))?;
        Ok(())
    }

    /// Deserialize from a file.
    pub fn load(path: &Path) -> Result<Truth> {
        let buf = std::fs::read(path).with_context(|| format!("read {path:?}"))?;
        let mut r = Reader { buf: &buf, pos: 0 };
        if r.take(8)? != MAGIC {
            bail!("{path:?} is not a truth file");
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("truth file version {version}, expected {VERSION}");
        }
        let n_les = r.u32()? as usize;
        let mean_spectrum = r.f64s()?;
        let min_spectrum = r.f64s()?;
        let max_spectrum = r.f64s()?;
        let test_state = r.f32s()?;
        let n_states = r.u32()? as usize;
        let mut states = Vec::with_capacity(n_states);
        for _ in 0..n_states {
            states.push(r.f32s()?);
        }
        Ok(Truth {
            n_les,
            mean_spectrum,
            min_spectrum,
            max_spectrum,
            states,
            test_state,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::spectral::kinetic_energy;
    use crate::solver::spectrum::energy_spectrum;

    #[test]
    fn pack_unpack_roundtrip() {
        let grid = Grid::new(8);
        let mut rng = Rng::new(3);
        let u = random_solenoidal(&grid, 1.0, 2.0, &mut rng);
        let flat = pack_state(&u);
        let back = unpack_state(&grid, &flat);
        for c in 0..3 {
            for i in 0..grid.len() {
                let scale = u[c][i].norm_sq().sqrt().max(1.0);
                assert!((u[c][i] - back[c][i]).norm_sq().sqrt() < 1e-5 * scale);
            }
        }
    }

    #[test]
    fn filter_preserves_low_modes_kills_high() {
        let dns_grid = Grid::new(16);
        let les_grid = Grid::new(8);
        let mut u: SpecVec = [dns_grid.zeros(), dns_grid.zeros(), dns_grid.zeros()];
        let n3 = dns_grid.len() as f64;
        // Low mode k=(2,0,0) and high mode k=(6,0,0).
        u[0][dns_grid.idx(2, 0, 0)] = Cpx::new(n3, 0.0);
        u[0][dns_grid.idx(6, 0, 0)] = Cpx::new(n3, 0.0);
        let f = filter_to_les(&dns_grid, &u, &les_grid);
        let l3 = les_grid.len() as f64;
        // Low mode survives with rescaled coefficient...
        let got = f[0][les_grid.idx(2, 0, 0)];
        assert!((got.re - l3).abs() < 1e-9, "got {got:?}");
        // ...high mode (beyond LES Nyquist) is gone:
        let total: f64 = f[0].iter().map(|c| c.norm_sq()).sum();
        assert!((total - l3 * l3).abs() < 1e-6);
    }

    #[test]
    fn filter_preserves_resolved_spectrum() {
        let dns_grid = Grid::new(24);
        let les_grid = Grid::new(12);
        let mut rng = Rng::new(4);
        let u = random_solenoidal(&dns_grid, 1.5, 3.0, &mut rng);
        let f = filter_to_les(&dns_grid, &u, &les_grid);
        let s_dns = energy_spectrum(&dns_grid, &u);
        let s_les = energy_spectrum(&les_grid, &f);
        // Shells well below the LES Nyquist must carry identical energy.
        for k in 1..5 {
            assert!(
                (s_dns[k] - s_les[k]).abs() < 1e-9 * s_dns[k].max(1e-30),
                "shell {k}: {} vs {}",
                s_dns[k],
                s_les[k]
            );
        }
        // Filtered KE <= DNS KE.
        assert!(kinetic_energy(&les_grid, &f) <= kinetic_energy(&dns_grid, &u));
    }

    #[test]
    fn filter_is_bit_identical_across_pool_widths() {
        let dns_grid = Grid::new(16);
        let les_grid = Grid::new(8);
        let mut rng = Rng::new(6);
        let u = random_solenoidal(&dns_grid, 1.0, 3.0, &mut rng);
        let base = filter_to_les_pool(&dns_grid, &u, &les_grid, &pool::Pool::new(1));
        for threads in [2usize, 8] {
            let got = filter_to_les_pool(&dns_grid, &u, &les_grid, &pool::Pool::new(threads));
            for c in 0..3 {
                for i in 0..les_grid.len() {
                    assert_eq!(base[c][i].re.to_bits(), got[c][i].re.to_bits());
                    assert_eq!(base[c][i].im.to_bits(), got[c][i].im.to_bits());
                }
            }
        }
    }

    #[test]
    fn generate_and_save_load_roundtrip() {
        // Tiny configuration to keep the test fast.
        let p = TruthParams {
            n_dns: 12,
            n_les: 6,
            nu: 0.02,
            ke_target: 1.0,
            spinup_time: 0.2,
            n_states: 2,
            sample_interval: 0.1,
            seed: 7,
        };
        let truth = generate(&p, |_, _| {});
        assert_eq!(truth.states.len(), 2);
        assert_eq!(truth.mean_spectrum.len(), 4); // n_les/2 + 1
        assert!(truth.mean_spectrum[1] > 0.0);
        for k in 1..truth.mean_spectrum.len() {
            assert!(truth.min_spectrum[k] <= truth.mean_spectrum[k]);
            assert!(truth.mean_spectrum[k] <= truth.max_spectrum[k]);
        }

        let dir = std::env::temp_dir().join("relexi_truth_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        truth.save(&path).unwrap();
        let back = Truth::load(&path).unwrap();
        assert_eq!(back.n_les, truth.n_les);
        assert_eq!(back.states.len(), truth.states.len());
        assert_eq!(back.test_state, truth.test_state);
        assert_eq!(back.mean_spectrum, truth.mean_spectrum);
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("relexi_truth_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTTRUTHFILE....").unwrap();
        assert!(Truth::load(&path).is_err());
    }
}
