//! The 1D stochastic-Burgers LES backend (`rl.backend = "burgers"`).
//!
//! The canonical small-scale testbed for RL turbulence modeling: a
//! periodic viscous Burgers flow kept quasi-stationary by linear forcing
//! (the same controller as the 3D HIT case) plus stochastic
//! low-wavenumber noise.  The environment advances a **coarse** grid
//! that cannot resolve the shock-driven energy cascade; the policy picks
//! one Smagorinsky-like coefficient per spatial segment,
//!
//! `nu_t(x) = (C_seg(x) * dx)^2 * |du/dx|`,
//!
//! and is rewarded for matching the energy spectrum of a **resolved**
//! reference run through exactly the Eq. (4)/(5) shaping of the paper
//! ([`crate::solver::spectrum::spectrum_error`] +
//! [`crate::rl::reward::reward_from_error`] — both are
//! resolution-agnostic and reused verbatim).  One episode costs a few
//! thousand floating-point stencil sweeps, so hundreds of envs fit in a
//! CI smoke run — this backend is what exercises the pool at scales the
//! 3D case cannot reach in CI.
//!
//! Discretization: skew-symmetric central differences for the advection
//! term (discretely energy-conserving, so all dissipation is explicit
//! viscosity), conservative variable-viscosity diffusion, Heun (RK2)
//! substeps under a combined advective/viscous stability limit.  The
//! resolved truth runs the identical scheme on a `truth_refine`-times
//! finer grid with zero SGS.

use super::cfd::{CfdBackend, CfdEnv};
use super::env::StepOut;
use super::reward::reward_from_error;
use crate::config::{BurgersConfig, ResolvedVariant};
use crate::solver::forcing::LinearForcing;
use crate::solver::spectrum::spectrum_error;
use crate::util::Rng;
use anyhow::{Context, Result};
use std::f64::consts::TAU;
use std::sync::Arc;

/// Noise seed used for held-out test-state episodes: test resets must
/// not consume caller RNG draws (deterministic evaluation), so the
/// stochastic forcing stream is fixed instead.
const TEST_NOISE_SEED: u64 = 0x5eed_b562;

/// Ground-truth package for the Burgers scenario: the time-averaged
/// resolved spectrum the reward compares against, plus coarse-grained
/// snapshots used as randomized initial states (one held out for
/// evaluation) — the same shape as the 3D [`crate::solver::dns::Truth`].
pub struct BurgersTruth {
    /// Coarse (LES) resolution the states are box-filtered to.
    pub n_les: usize,
    /// Time-averaged resolved spectrum on LES bins `0..=n_les/2`.
    pub mean_spectrum: Vec<f64>,
    /// Training pool of coarse-grained initial states.
    pub states: Vec<Vec<f64>>,
    /// Held-out test state.
    pub test_state: Vec<f64>,
}

/// Physics of one Burgers simulation (coarse env or resolved truth).
#[derive(Debug, Clone)]
struct SimParams {
    n: usize,
    nu: f64,
    ke_target: f64,
    forcing_tau: f64,
    noise_amp: f64,
    noise_modes: usize,
    cfl: f64,
}

/// One Burgers field plus the scratch needed to advance it without
/// per-step allocation.
struct Sim {
    p: SimParams,
    dx: f64,
    u: Vec<f64>,
    /// Per-point SGS coefficient C (zero for the resolved truth run).
    cs_point: Vec<f64>,
    /// Stochastic forcing field, frozen over one RL interval.
    noise: Vec<f64>,
    forcing: LinearForcing,
    // Heun scratch.
    k1: Vec<f64>,
    k2: Vec<f64>,
    u1: Vec<f64>,
    dudx: Vec<f64>,
}

/// Mean kinetic energy `mean(u^2) / 2`.
fn kinetic_energy(u: &[f64]) -> f64 {
    0.5 * u.iter().map(|&v| v * v).sum::<f64>() / u.len() as f64
}

/// Shell energy spectrum of a real periodic signal by direct DFT:
/// `E(k) = |u_hat(k)|^2` for interior bins (conjugate pairs folded), so
/// `sum_k E(k) = mean(u^2)/2`.  Coefficients are continuum-normalized
/// (`u_hat = (1/n) sum u e^{-ikx}`), so spectra from different grid
/// resolutions are directly comparable on shared bins — that is what
/// lets the coarse env score itself against the refined truth.
pub fn energy_spectrum_1d_into(u: &[f64], spec: &mut [f64]) {
    let n = u.len();
    assert!(spec.len() <= n / 2 + 1, "more bins than resolvable modes");
    for (k, s) in spec.iter_mut().enumerate() {
        let (mut re, mut im) = (0.0f64, 0.0f64);
        let w = TAU * k as f64 / n as f64;
        for (j, &uj) in u.iter().enumerate() {
            let th = w * j as f64;
            re += uj * th.cos();
            im -= uj * th.sin();
        }
        re /= n as f64;
        im /= n as f64;
        let e = re * re + im * im;
        // k = 0 and the Nyquist bin have no conjugate partner: halve so
        // the bins sum to the mean kinetic energy (discrete Parseval).
        *s = if k == 0 || 2 * k == n { 0.5 * e } else { e };
    }
}

/// Allocating convenience over [`energy_spectrum_1d_into`] with bins up
/// to the signal's Nyquist.
pub fn energy_spectrum_1d(u: &[f64]) -> Vec<f64> {
    let mut spec = vec![0.0; u.len() / 2 + 1];
    energy_spectrum_1d_into(u, &mut spec);
    spec
}

/// Semi-discrete right-hand side at state `u`:
/// skew-symmetric advection + conservative `(nu + nu_t) u_xx` + linear
/// forcing `a_force * u` + the frozen stochastic field.
#[allow(clippy::too_many_arguments)]
fn rhs_into(
    p: &SimParams,
    dx: f64,
    u: &[f64],
    cs_point: &[f64],
    noise: &[f64],
    a_force: f64,
    dudx: &mut [f64],
    out: &mut [f64],
) {
    let n = p.n;
    for i in 0..n {
        let up = u[(i + 1) % n];
        let um = u[(i + n - 1) % n];
        dudx[i] = (up - um) / (2.0 * dx);
    }
    // Total viscosity per point: molecular + Smagorinsky-like SGS.
    // (Reuses `out` as the nu_tot scratch before the final assembly.)
    for i in 0..n {
        let cd = cs_point[i] * dx;
        out[i] = p.nu + cd * cd * dudx[i].abs();
    }
    for i in 0..n {
        let ip = (i + 1) % n;
        let im = (i + n - 1) % n;
        // Skew-symmetric split of u*u_x: 1/3 (u^2)_x + 1/3 u u_x.
        let adv = ((u[ip] * u[ip] - u[im] * u[im]) / (2.0 * dx) + u[i] * dudx[i]) / 3.0;
        // Conservative diffusion with face-averaged viscosity.
        let nu_p = 0.5 * (out[i] + out[ip]);
        let nu_m = 0.5 * (out[im] + out[i]);
        let visc = (nu_p * (u[ip] - u[i]) - nu_m * (u[i] - u[im])) / (dx * dx);
        dudx[i] = adv - visc; // stash -rhs of the conservative terms
    }
    for i in 0..n {
        out[i] = -dudx[i] + a_force * u[i] + noise[i];
    }
}

impl Sim {
    fn new(p: SimParams) -> Sim {
        let n = p.n;
        Sim {
            dx: TAU / n as f64,
            u: vec![0.0; n],
            cs_point: vec![0.0; n],
            noise: vec![0.0; n],
            forcing: LinearForcing::new(p.ke_target, p.forcing_tau),
            k1: vec![0.0; n],
            k2: vec![0.0; n],
            u1: vec![0.0; n],
            dudx: vec![0.0; n],
            p,
        }
    }

    /// Redraw the stochastic forcing field for the next RL interval:
    /// `noise_amp * sum_k (a_k / k) sin(k x + phi_k)` over the forced
    /// low wavenumbers, frozen in time until the next draw.
    fn draw_noise(&mut self, rng: &mut Rng) {
        self.noise.fill(0.0);
        for k in 1..=self.p.noise_modes {
            let a = self.p.noise_amp * rng.normal() / k as f64;
            let phi = TAU * rng.uniform();
            for (i, f) in self.noise.iter_mut().enumerate() {
                *f += a * (k as f64 * self.dx * i as f64 + phi).sin();
            }
        }
    }

    /// Advance `dt_total` with Heun substeps under the combined
    /// advective/viscous stability limit.  Steady-state calls allocate
    /// nothing.
    fn advance(&mut self, dt_total: f64) {
        let mut remaining = dt_total;
        while remaining > 0.0 {
            let umax = self.u.iter().fold(0.0f64, |a, &b| a.max(b.abs())).max(1e-6);
            // Conservative per-substep viscosity bound: the largest SGS
            // gradient is at most O(umax / dx).
            let cmax = self.cs_point.iter().fold(0.0f64, |a, &b| a.max(b));
            let nu_max = self.p.nu + (cmax * self.dx).powi(2) * (2.0 * umax / self.dx);
            let dt_adv = self.p.cfl * self.dx / umax;
            let dt_visc = 0.4 * self.dx * self.dx / nu_max;
            let dt = remaining.min(dt_adv).min(dt_visc);
            let a1 = self.forcing.coefficient(kinetic_energy(&self.u));
            rhs_into(
                &self.p,
                self.dx,
                &self.u,
                &self.cs_point,
                &self.noise,
                a1,
                &mut self.dudx,
                &mut self.k1,
            );
            for i in 0..self.p.n {
                self.u1[i] = self.u[i] + dt * self.k1[i];
            }
            let a2 = self.forcing.coefficient(kinetic_energy(&self.u1));
            rhs_into(
                &self.p,
                self.dx,
                &self.u1,
                &self.cs_point,
                &self.noise,
                a2,
                &mut self.dudx,
                &mut self.k2,
            );
            for i in 0..self.p.n {
                self.u[i] += 0.5 * dt * (self.k1[i] + self.k2[i]);
            }
            remaining -= dt;
        }
    }
}

/// Box-filter a fine field onto `n_coarse` points (cell averages over
/// `refine` consecutive fine points).
fn coarse_grain(fine: &[f64], n_coarse: usize) -> Vec<f64> {
    let r = fine.len() / n_coarse;
    debug_assert_eq!(fine.len(), n_coarse * r);
    (0..n_coarse)
        .map(|i| fine[i * r..(i + 1) * r].iter().sum::<f64>() / r as f64)
        .collect()
}

/// Run the resolved reference simulation and package the ground truth:
/// spin up from a low-wavenumber random field, then sample
/// `truth_states + 1` snapshots (the last is held out as the test
/// state), accumulating the mean spectrum on LES bins.  Deterministic in
/// `cfg.truth_seed`.
pub fn generate_truth(cfg: &BurgersConfig) -> BurgersTruth {
    let n_fine = cfg.points * cfg.truth_refine;
    let mut sim = Sim::new(SimParams {
        n: n_fine,
        nu: cfg.nu,
        ke_target: cfg.ke_target,
        forcing_tau: cfg.forcing_tau,
        noise_amp: cfg.noise_amp,
        noise_modes: cfg.noise_modes,
        cfl: cfg.cfl,
    });
    let mut rng = Rng::new(cfg.truth_seed);
    // Low-wavenumber random initial condition scaled to the target
    // energy; the spin-up then develops the nonlinear cascade.
    let dx = sim.dx;
    for k in 1..=cfg.noise_modes + 1 {
        let a = rng.normal() / k as f64;
        let phi = TAU * rng.uniform();
        for (i, v) in sim.u.iter_mut().enumerate() {
            *v += a * (k as f64 * dx * i as f64 + phi).sin();
        }
    }
    let ke0 = kinetic_energy(&sim.u).max(1e-12);
    let scale = (cfg.ke_target / ke0).sqrt();
    sim.u.iter_mut().for_each(|v| *v *= scale);

    // Advance in dt_rl chunks, redrawing the stochastic forcing per
    // chunk — the same forcing cadence the envs run under.
    let advance_time = |sim: &mut Sim, rng: &mut Rng, t: f64| {
        let chunks = (t / cfg.dt_rl).round().max(1.0) as usize;
        for _ in 0..chunks {
            sim.draw_noise(rng);
            sim.advance(cfg.dt_rl);
        }
    };
    advance_time(&mut sim, &mut rng, cfg.truth_spinup);

    let nbins = cfg.points / 2 + 1;
    let mut mean_spectrum = vec![0.0; nbins];
    let mut spec = vec![0.0; nbins];
    let mut states = Vec::with_capacity(cfg.truth_states + 1);
    for _ in 0..cfg.truth_states + 1 {
        advance_time(&mut sim, &mut rng, cfg.truth_interval);
        energy_spectrum_1d_into(&sim.u, &mut spec);
        for (m, s) in mean_spectrum.iter_mut().zip(&spec) {
            *m += s;
        }
        states.push(coarse_grain(&sim.u, cfg.points));
    }
    let n_samples = states.len() as f64;
    mean_spectrum.iter_mut().for_each(|m| *m /= n_samples);
    let test_state = states.pop().expect("at least one snapshot");
    BurgersTruth {
        n_les: cfg.points,
        mean_spectrum,
        states,
        test_state,
    }
}

/// One coarse stochastic-Burgers environment instance.
pub struct BurgersEnv {
    sim: Sim,
    truth: Arc<BurgersTruth>,
    segments: usize,
    k_max: usize,
    alpha: f64,
    dt_rl: f64,
    n_actions: usize,
    step_idx: usize,
    /// Reused spectrum bins for the per-step reward (no per-step alloc).
    spec: Vec<f64>,
    /// Per-episode stochastic forcing stream (seeded at reset).
    noise_rng: Rng,
    /// See [`CfdEnv::set_init_family`].
    init_family: Option<(usize, usize)>,
}

impl BurgersEnv {
    /// Build an environment on a shared truth package.  `cfg` is the
    /// variant-resolved configuration (viscosity, horizon, reward knobs
    /// already scaled).
    pub fn new(cfg: &BurgersConfig, truth: Arc<BurgersTruth>) -> Result<BurgersEnv> {
        anyhow::ensure!(
            truth.n_les == cfg.points,
            "truth coarse-grained for n={}, env needs n={}",
            truth.n_les,
            cfg.points
        );
        anyhow::ensure!(
            cfg.segments >= 1 && cfg.points % cfg.segments == 0,
            "segments {} must divide points {}",
            cfg.segments,
            cfg.points
        );
        anyhow::ensure!(
            cfg.k_max >= 1 && cfg.k_max <= cfg.points / 2,
            "k_max {} beyond Nyquist {}",
            cfg.k_max,
            cfg.points / 2
        );
        for (k, &e) in truth.mean_spectrum[1..=cfg.k_max].iter().enumerate() {
            anyhow::ensure!(
                e > 0.0,
                "truth spectrum empty at k={} (reward undefined)",
                k + 1
            );
        }
        Ok(BurgersEnv {
            sim: Sim::new(SimParams {
                n: cfg.points,
                nu: cfg.nu,
                ke_target: cfg.ke_target,
                forcing_tau: cfg.forcing_tau,
                noise_amp: cfg.noise_amp,
                noise_modes: cfg.noise_modes,
                cfl: cfg.cfl,
            }),
            truth,
            segments: cfg.segments,
            k_max: cfg.k_max,
            alpha: cfg.alpha,
            dt_rl: cfg.dt_rl,
            n_actions: (cfg.t_end / cfg.dt_rl).round() as usize,
            step_idx: 0,
            spec: vec![0.0; cfg.points / 2 + 1],
            noise_rng: Rng::new(TEST_NOISE_SEED),
            init_family: None,
        })
    }
}

impl CfdEnv for BurgersEnv {
    fn reset_in_place(&mut self, rng: &mut Rng, test: bool) {
        let state = if test {
            // Fixed noise stream: test episodes consume no caller draws.
            self.noise_rng = Rng::new(TEST_NOISE_SEED);
            &self.truth.test_state
        } else {
            let idx =
                super::cfd::draw_pool_index(self.truth.states.len(), self.init_family, rng);
            self.noise_rng = Rng::new(rng.next_u64());
            &self.truth.states[idx]
        };
        self.sim.u.copy_from_slice(state);
        self.sim.cs_point.fill(0.0);
        self.sim.noise.fill(0.0);
        self.step_idx = 0;
    }

    fn step(&mut self, cs: &[f64]) -> StepOut {
        assert_eq!(cs.len(), self.segments, "one SGS coefficient per segment");
        let pts = self.sim.p.n / self.segments;
        for (i, c) in self.sim.cs_point.iter_mut().enumerate() {
            *c = cs[i / pts].clamp(0.0, 0.5);
        }
        self.sim.draw_noise(&mut self.noise_rng);
        self.sim.advance(self.dt_rl);
        self.step_idx += 1;
        energy_spectrum_1d_into(&self.sim.u, &mut self.spec);
        let spec_error = spectrum_error(&self.truth.mean_spectrum, &self.spec, self.k_max);
        StepOut {
            spec_error,
            reward: reward_from_error(spec_error, self.alpha),
            done: self.step_idx >= self.n_actions,
        }
    }

    fn observe_into(&mut self, out: &mut [f32]) {
        assert_eq!(out.len(), self.sim.p.n);
        for (o, &v) in out.iter_mut().zip(&self.sim.u) {
            *o = v as f32;
        }
    }

    /// One velocity point per float; segments are contiguous slices, so
    /// agent `s` observes `out[s * points/segments ..][..points/segments]`.
    fn obs_len(&self) -> usize {
        self.sim.p.n
    }

    fn n_agents(&self) -> usize {
        self.segments
    }

    fn n_actions(&self) -> usize {
        self.n_actions
    }

    fn spectrum(&self) -> Vec<f64> {
        energy_spectrum_1d(&self.sim.u)
    }

    fn target_spectrum(&self) -> &[f64] {
        &self.truth.mean_spectrum
    }

    fn set_init_family(&mut self, family: usize, n_families: usize) -> Result<()> {
        super::cfd::validate_init_family(self.truth.states.len(), family, n_families)?;
        self.init_family = Some((family, n_families));
        Ok(())
    }
}

/// The Burgers scenario as a pool backend: generates the resolved truth
/// once per run (deterministic in `burgers.truth_seed`) and cuts every
/// env from it.
pub struct BurgersBackend {
    cfg: BurgersConfig,
    truth: Arc<BurgersTruth>,
}

impl BurgersBackend {
    /// Generate the shared resolved truth for this run's configuration.
    /// Per-env parameter guards (segments/k_max, incl. variant
    /// overrides) live in [`BurgersEnv::new`]; config-level validation
    /// is `RunConfig::validate` — only what truth generation itself
    /// needs is checked here.
    pub fn new(cfg: &BurgersConfig) -> Result<BurgersBackend> {
        anyhow::ensure!(cfg.truth_refine >= 1 && cfg.truth_states >= 1);
        let truth = Arc::new(generate_truth(cfg));
        Ok(BurgersBackend {
            cfg: cfg.clone(),
            truth,
        })
    }

    /// The resolved-truth package shared by every env of this backend.
    pub fn truth(&self) -> Arc<BurgersTruth> {
        self.truth.clone()
    }
}

impl CfdBackend for BurgersBackend {
    fn name(&self) -> &str {
        "burgers"
    }

    fn make_env(&self, rv: &ResolvedVariant) -> Result<Box<dyn CfdEnv>> {
        // The Burgers base parameters live in their own config section,
        // so the variant's raw knobs are applied here rather than through
        // the pre-scaled `rv.case`/`rv.solver`.
        let mut cfg = self.cfg.clone();
        cfg.nu *= rv.variant.nu_scale;
        cfg.t_end *= rv.variant.t_end_scale;
        if let Some(a) = rv.variant.alpha {
            cfg.alpha = a;
        }
        if let Some(k) = rv.variant.k_max {
            cfg.k_max = k;
        }
        let mut env = BurgersEnv::new(&cfg, self.truth.clone())
            .with_context(|| format!("burgers env (variant {})", rv.name))?;
        if let Some((family, m)) = rv.init_family {
            env.set_init_family(family, m)
                .with_context(|| format!("burgers env (variant {})", rv.name))?;
        }
        Ok(Box::new(env))
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::config::{EnvVariant, RunConfig};

    /// A small, fast Burgers configuration shared by the backend tests.
    pub fn tiny_burgers() -> BurgersConfig {
        BurgersConfig {
            points: 48,
            segments: 4,
            k_max: 6,
            t_end: 0.3,
            truth_states: 3,
            truth_spinup: 0.6,
            truth_interval: 0.2,
            ..BurgersConfig::default()
        }
    }

    #[test]
    fn spectrum_bins_sum_to_kinetic_energy() {
        let mut rng = Rng::new(9);
        let u: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let spec = energy_spectrum_1d(&u);
        assert_eq!(spec.len(), 33);
        let total: f64 = spec.iter().sum();
        let ke = kinetic_energy(&u);
        assert!((total - ke).abs() < 1e-10 * ke.max(1.0), "{total} vs {ke}");
    }

    #[test]
    fn single_mode_lands_in_right_bin() {
        let n = 32usize;
        let u: Vec<f64> = (0..n).map(|i| (3.0 * TAU * i as f64 / n as f64).sin()).collect();
        let spec = energy_spectrum_1d(&u);
        // sin(3x): ke = 1/4, all of it in bin 3.
        assert!((spec[3] - 0.25).abs() < 1e-12);
        for (k, &e) in spec.iter().enumerate() {
            if k != 3 {
                assert!(e.abs() < 1e-12, "unexpected energy in bin {k}: {e}");
            }
        }
    }

    #[test]
    fn unforced_viscous_flow_dissipates() {
        let cfg = tiny_burgers();
        let mut sim = Sim::new(SimParams {
            n: cfg.points,
            nu: cfg.nu,
            ke_target: cfg.ke_target,
            forcing_tau: cfg.forcing_tau,
            noise_amp: 0.0,
            noise_modes: 1,
            cfl: cfg.cfl,
        });
        sim.forcing.a0 = 0.0;
        sim.forcing.a_max = 0.0; // forcing off: pure decay
        let dx = sim.dx;
        for (i, v) in sim.u.iter_mut().enumerate() {
            *v = (dx * i as f64).sin() + 0.3 * (2.0 * dx * i as f64).cos();
        }
        let ke0 = kinetic_energy(&sim.u);
        sim.advance(0.5);
        let ke1 = kinetic_energy(&sim.u);
        assert!(ke1 < ke0, "viscous decay: {ke1} !< {ke0}");
        assert!(ke1 > 0.0 && ke1.is_finite());
    }

    #[test]
    fn truth_is_deterministic_and_usable() {
        let cfg = tiny_burgers();
        let a = generate_truth(&cfg);
        let b = generate_truth(&cfg);
        assert_eq!(a.mean_spectrum, b.mean_spectrum);
        assert_eq!(a.states, b.states);
        assert_eq!(a.test_state, b.test_state);
        assert_eq!(a.states.len(), cfg.truth_states);
        assert_eq!(a.test_state.len(), cfg.points);
        // The reward needs strictly positive truth energy up to k_max.
        for k in 1..=cfg.k_max {
            assert!(a.mean_spectrum[k] > 0.0, "empty truth bin {k}");
        }
        // The forced field holds a sane energy level.
        let ke = kinetic_energy(&a.test_state);
        assert!(ke > 0.05 * cfg.ke_target && ke < 20.0 * cfg.ke_target, "ke={ke}");
    }

    #[test]
    fn episode_runs_to_done_with_finite_rewards() {
        let cfg = tiny_burgers();
        let backend = BurgersBackend::new(&cfg).unwrap();
        let mut run = RunConfig::default();
        run.burgers = cfg.clone();
        let mut env = backend.make_env(&run.base_resolved()).unwrap();
        assert_eq!(env.n_agents(), 4);
        assert_eq!(env.obs_len(), 48);
        let mut rng = Rng::new(1);
        let obs = env.reset(&mut rng, false);
        assert_eq!(obs.len(), env.obs_len());
        let cs = vec![0.1; env.n_agents()];
        let mut steps = 0;
        loop {
            let out = env.step(&cs);
            assert!(out.spec_error >= 0.0 && out.spec_error.is_finite());
            assert!(out.reward > -1.0 && out.reward <= 1.0, "reward={}", out.reward);
            steps += 1;
            if out.done {
                break;
            }
            assert!(steps <= 3, "t_end/dt_rl = 3 actions");
        }
        assert_eq!(steps, 3);
    }

    #[test]
    fn same_seed_reproduces_and_test_state_ignores_rng() {
        let cfg = tiny_burgers();
        let backend = BurgersBackend::new(&cfg).unwrap();
        let run = {
            let mut r = RunConfig::default();
            r.burgers = cfg;
            r
        };
        let mut e1 = backend.make_env(&run.base_resolved()).unwrap();
        let mut e2 = backend.make_env(&run.base_resolved()).unwrap();
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        assert_eq!(e1.reset(&mut r1, false), e2.reset(&mut r2, false));
        let cs = vec![0.2; e1.n_agents()];
        let (a, b) = (e1.step(&cs), e2.step(&cs));
        assert_eq!(a.reward.to_bits(), b.reward.to_bits());
        assert_eq!(e1.observe(), e2.observe());

        // Test resets are RNG-independent (deterministic evaluation).
        let mut r3 = Rng::new(1);
        let mut r4 = Rng::new(999);
        assert_eq!(e1.reset(&mut r3, true), e2.reset(&mut r4, true));
        let (a, b) = (e1.step(&cs), e2.step(&cs));
        assert_eq!(a.reward.to_bits(), b.reward.to_bits());
    }

    #[test]
    fn sgs_coefficient_changes_the_flow() {
        let cfg = tiny_burgers();
        let backend = BurgersBackend::new(&cfg).unwrap();
        let run = {
            let mut r = RunConfig::default();
            r.burgers = cfg;
            r
        };
        let mut e1 = backend.make_env(&run.base_resolved()).unwrap();
        let mut e2 = backend.make_env(&run.base_resolved()).unwrap();
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        e1.reset_in_place(&mut r1, true);
        e2.reset_in_place(&mut r2, true);
        e1.step(&[0.0; 4]);
        e2.step(&[0.5; 4]);
        let (s1, s2) = (e1.spectrum(), e2.spectrum());
        let diff: f64 = s1.iter().zip(&s2).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-12, "the SGS action must matter");
        // More dissipation -> less small-scale energy.
        let tail = cfg_tail(&s1) - cfg_tail(&s2);
        assert!(tail > 0.0, "Cs=0.5 must damp the tail: {tail}");
    }

    fn cfg_tail(spec: &[f64]) -> f64 {
        spec[spec.len() / 2..].iter().sum()
    }

    #[test]
    fn init_family_restricts_the_pool() {
        let cfg = tiny_burgers(); // 3 truth states
        let backend = BurgersBackend::new(&cfg).unwrap();
        let run = {
            let mut r = RunConfig::default();
            r.burgers = cfg;
            r
        };
        let mut rng = Rng::new(11);
        for fam in 0..3 {
            let mut env = backend.make_env(&run.base_resolved()).unwrap();
            env.set_init_family(fam, 3).unwrap();
            // One state per family: the pool index is pinned, and the
            // initial field must reproduce across resets.
            env.reset_in_place(&mut rng, false);
            let mut a = vec![0f32; env.obs_len()];
            env.observe_into(&mut a);
            env.reset_in_place(&mut rng, false);
            let mut b = vec![0f32; env.obs_len()];
            env.observe_into(&mut b);
            assert_eq!(a, b, "family {fam} has one state");
        }
        let mut env = backend.make_env(&run.base_resolved()).unwrap();
        assert!(env.set_init_family(3, 4).is_err());
    }

    #[test]
    fn variants_scale_viscosity_horizon_and_reward() {
        let cfg = tiny_burgers();
        let backend = BurgersBackend::new(&cfg).unwrap();
        let mut run = RunConfig::default();
        run.burgers = cfg;
        let mut rv = run.base_resolved();
        rv.variant = EnvVariant {
            name: "short".into(),
            nu_scale: 2.0,
            t_end_scale: 2.0,
            alpha: Some(0.8),
            k_max: Some(4),
        };
        let env = backend.make_env(&rv).unwrap();
        assert_eq!(env.n_actions(), 6, "t_end_scale doubles the horizon");
        // Out-of-range k_max override is rejected per env.
        rv.variant.k_max = Some(1000);
        assert!(backend.make_env(&rv).is_err());
    }
}
