//! The 1D stochastic-Burgers LES backend (`rl.backend = "burgers"`).
//!
//! The canonical small-scale testbed for RL turbulence modeling: a
//! periodic viscous Burgers flow kept quasi-stationary by linear forcing
//! (the same controller as the 3D HIT case) plus stochastic
//! low-wavenumber noise.  The environment advances a **coarse** grid
//! that cannot resolve the shock-driven energy cascade; the policy picks
//! one Smagorinsky-like coefficient per spatial segment,
//!
//! `nu_t(x) = (C_seg(x) * dx)^2 * |du/dx|`,
//!
//! and is rewarded for matching the energy spectrum of a **resolved**
//! reference run through exactly the Eq. (4)/(5) shaping of the paper
//! ([`crate::solver::spectrum::spectrum_error`] +
//! [`crate::rl::reward::reward_from_error`] — both are
//! resolution-agnostic and reused verbatim).  One episode costs a few
//! thousand floating-point stencil sweeps, so hundreds of envs fit in a
//! CI smoke run — this backend is what exercises the pool at scales the
//! 3D case cannot reach in CI.
//!
//! Discretization: skew-symmetric central differences for the advection
//! term (discretely energy-conserving, so all dissipation is explicit
//! viscosity), conservative variable-viscosity diffusion, Heun (RK2)
//! substeps under a combined advective/viscous stability limit.  The
//! resolved truth runs the identical scheme on a `truth_refine`-times
//! finer grid with zero SGS.
//!
//! # Cross-env batched stepping (PR 6)
//!
//! Every env cut from one [`BurgersBackend`] shares one [`BurgersBatch`]
//! core.  [`CfdEnv::step`] stages the request (action + fresh noise
//! written into the env's slot) and a **wave leader** — the first staged
//! env — holds the door open for a short grace window, then advances
//! every staged env as one structure-of-arrays batch over the kernel
//! worker pool ([`crate::util::pool`]).  Per-env arithmetic touches only
//! that env's slot, so results are bitwise independent of wave
//! composition: lockstep-vs-event equivalence and all seeded tests are
//! unaffected, and a solo caller simply times out the grace window and
//! runs a wave of one.  [`BatchCounters`] proves the batching happened.

use super::cfd::{CfdBackend, CfdEnv};
use super::env::StepOut;
use super::reward::reward_from_error;
use crate::config::{BurgersConfig, ResolvedVariant};
use crate::fft::{Cpx, Plan};
use crate::solver::forcing::LinearForcing;
use crate::solver::spectrum::spectrum_error;
use crate::util::pool;
use crate::util::Rng;
use anyhow::{Context, Result};
use std::f64::consts::TAU;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Noise seed used for held-out test-state episodes: test resets must
/// not consume caller RNG draws (deterministic evaluation), so the
/// stochastic forcing stream is fixed instead.
const TEST_NOISE_SEED: u64 = 0x5eed_b562;

/// Ground-truth package for the Burgers scenario: the time-averaged
/// resolved spectrum the reward compares against, plus coarse-grained
/// snapshots used as randomized initial states (one held out for
/// evaluation) — the same shape as the 3D [`crate::solver::dns::Truth`].
pub struct BurgersTruth {
    /// Coarse (LES) resolution the states are box-filtered to.
    pub n_les: usize,
    /// Time-averaged resolved spectrum on LES bins `0..=n_les/2`.
    pub mean_spectrum: Vec<f64>,
    /// Training pool of coarse-grained initial states.
    pub states: Vec<Vec<f64>>,
    /// Held-out test state.
    pub test_state: Vec<f64>,
}

/// Physics of one Burgers simulation (coarse env or resolved truth).
#[derive(Debug, Clone)]
struct SimParams {
    n: usize,
    nu: f64,
    ke_target: f64,
    forcing_tau: f64,
    noise_amp: f64,
    noise_modes: usize,
    cfl: f64,
}

/// One Burgers field plus the scratch needed to advance it without
/// per-step allocation.
struct Sim {
    p: SimParams,
    dx: f64,
    u: Vec<f64>,
    /// Per-point SGS coefficient C (zero for the resolved truth run).
    cs_point: Vec<f64>,
    /// Stochastic forcing field, frozen over one RL interval.
    noise: Vec<f64>,
    forcing: LinearForcing,
    // Heun scratch.
    k1: Vec<f64>,
    k2: Vec<f64>,
    u1: Vec<f64>,
    dudx: Vec<f64>,
}

/// Mean kinetic energy `mean(u^2) / 2`.
fn kinetic_energy(u: &[f64]) -> f64 {
    0.5 * u.iter().map(|&v| v * v).sum::<f64>() / u.len() as f64
}

/// Shell energy spectrum of a real periodic signal by direct **O(n^2)**
/// DFT: `E(k) = |u_hat(k)|^2` for interior bins (conjugate pairs
/// folded), so `sum_k E(k) = mean(u^2)/2`.  Coefficients are
/// continuum-normalized (`u_hat = (1/n) sum u e^{-ikx}`), so spectra
/// from different grid resolutions are directly comparable on shared
/// bins — that is what lets the coarse env score itself against the
/// refined truth.
///
/// This is the reference implementation, kept as the test oracle; hot
/// paths (env steps, truth generation) go through the Stockham engine
/// via [`SpectrumPlan`] instead.
pub fn energy_spectrum_1d_naive_into(u: &[f64], spec: &mut [f64]) {
    let n = u.len();
    assert!(spec.len() <= n / 2 + 1, "more bins than resolvable modes");
    for (k, s) in spec.iter_mut().enumerate() {
        let (mut re, mut im) = (0.0f64, 0.0f64);
        let w = TAU * k as f64 / n as f64;
        for (j, &uj) in u.iter().enumerate() {
            let th = w * j as f64;
            re += uj * th.cos();
            im -= uj * th.sin();
        }
        re /= n as f64;
        im /= n as f64;
        let e = re * re + im * im;
        // k = 0 and the Nyquist bin have no conjugate partner: halve so
        // the bins sum to the mean kinetic energy (discrete Parseval).
        *s = if k == 0 || 2 * k == n { 0.5 * e } else { e };
    }
}

/// Reusable Stockham-FFT spectrum engine: identical bins, normalization
/// and conjugate folding as [`energy_spectrum_1d_naive_into`] (asserted
/// against it in tests at ~1e-10 relative), at O(n log n) and with zero
/// steady-state allocation.
pub struct SpectrumPlan {
    plan: Plan,
    buf: Vec<Cpx>,
    scratch: Vec<Cpx>,
}

impl SpectrumPlan {
    /// Build the engine for signals of length `n`.
    pub fn new(n: usize) -> SpectrumPlan {
        SpectrumPlan {
            plan: Plan::new(n),
            buf: vec![Cpx::ZERO; n],
            scratch: vec![Cpx::ZERO; n],
        }
    }

    /// Fill `spec` with the shell energy spectrum of `u` (bins
    /// `0..spec.len()`, at most `n/2 + 1`).
    pub fn energy_into(&mut self, u: &[f64], spec: &mut [f64]) {
        let n = self.plan.len();
        assert_eq!(u.len(), n, "signal length != plan length");
        assert!(spec.len() <= n / 2 + 1, "more bins than resolvable modes");
        for (b, &v) in self.buf.iter_mut().zip(u) {
            *b = Cpx::new(v, 0.0);
        }
        self.plan.forward_batch(&mut self.buf, 1, &mut self.scratch);
        let inv_n = 1.0 / n as f64;
        for (k, s) in spec.iter_mut().enumerate() {
            let re = self.buf[k].re * inv_n;
            let im = self.buf[k].im * inv_n;
            let e = re * re + im * im;
            *s = if k == 0 || 2 * k == n { 0.5 * e } else { e };
        }
    }
}

/// Allocating convenience with bins up to the signal's Nyquist, through
/// the Stockham engine (diagnostics cadence; hot paths hold a
/// [`SpectrumPlan`]).
pub fn energy_spectrum_1d(u: &[f64]) -> Vec<f64> {
    let mut spec = vec![0.0; u.len() / 2 + 1];
    SpectrumPlan::new(u.len()).energy_into(u, &mut spec);
    spec
}

/// Semi-discrete right-hand side at state `u`:
/// skew-symmetric advection + conservative `(nu + nu_t) u_xx` + linear
/// forcing `a_force * u` + the frozen stochastic field.
#[allow(clippy::too_many_arguments)]
fn rhs_into(
    p: &SimParams,
    dx: f64,
    u: &[f64],
    cs_point: &[f64],
    noise: &[f64],
    a_force: f64,
    dudx: &mut [f64],
    out: &mut [f64],
) {
    let n = p.n;
    for i in 0..n {
        let up = u[(i + 1) % n];
        let um = u[(i + n - 1) % n];
        dudx[i] = (up - um) / (2.0 * dx);
    }
    // Total viscosity per point: molecular + Smagorinsky-like SGS.
    // (Reuses `out` as the nu_tot scratch before the final assembly.)
    for i in 0..n {
        let cd = cs_point[i] * dx;
        out[i] = p.nu + cd * cd * dudx[i].abs();
    }
    for i in 0..n {
        let ip = (i + 1) % n;
        let im = (i + n - 1) % n;
        // Skew-symmetric split of u*u_x: 1/3 (u^2)_x + 1/3 u u_x.
        let adv = ((u[ip] * u[ip] - u[im] * u[im]) / (2.0 * dx) + u[i] * dudx[i]) / 3.0;
        // Conservative diffusion with face-averaged viscosity.
        let nu_p = 0.5 * (out[i] + out[ip]);
        let nu_m = 0.5 * (out[im] + out[i]);
        let visc = (nu_p * (u[ip] - u[i]) - nu_m * (u[i] - u[im])) / (dx * dx);
        dudx[i] = adv - visc; // stash -rhs of the conservative terms
    }
    for i in 0..n {
        out[i] = -dudx[i] + a_force * u[i] + noise[i];
    }
}

impl Sim {
    fn new(p: SimParams) -> Sim {
        let n = p.n;
        Sim {
            dx: TAU / n as f64,
            u: vec![0.0; n],
            cs_point: vec![0.0; n],
            noise: vec![0.0; n],
            forcing: LinearForcing::new(p.ke_target, p.forcing_tau),
            k1: vec![0.0; n],
            k2: vec![0.0; n],
            u1: vec![0.0; n],
            dudx: vec![0.0; n],
            p,
        }
    }

    /// Redraw the stochastic forcing field for the next RL interval:
    /// `noise_amp * sum_k (a_k / k) sin(k x + phi_k)` over the forced
    /// low wavenumbers, frozen in time until the next draw.
    fn draw_noise(&mut self, rng: &mut Rng) {
        self.noise.fill(0.0);
        for k in 1..=self.p.noise_modes {
            let a = self.p.noise_amp * rng.normal() / k as f64;
            let phi = TAU * rng.uniform();
            for (i, f) in self.noise.iter_mut().enumerate() {
                *f += a * (k as f64 * self.dx * i as f64 + phi).sin();
            }
        }
    }

    /// Advance `dt_total` with Heun substeps under the combined
    /// advective/viscous stability limit.  Steady-state calls allocate
    /// nothing.
    fn advance(&mut self, dt_total: f64) {
        let mut remaining = dt_total;
        while remaining > 0.0 {
            let umax = self.u.iter().fold(0.0f64, |a, &b| a.max(b.abs())).max(1e-6);
            // Conservative per-substep viscosity bound: the largest SGS
            // gradient is at most O(umax / dx).
            let cmax = self.cs_point.iter().fold(0.0f64, |a, &b| a.max(b));
            let nu_max = self.p.nu + (cmax * self.dx).powi(2) * (2.0 * umax / self.dx);
            let dt_adv = self.p.cfl * self.dx / umax;
            let dt_visc = 0.4 * self.dx * self.dx / nu_max;
            let dt = remaining.min(dt_adv).min(dt_visc);
            let a1 = self.forcing.coefficient(kinetic_energy(&self.u));
            rhs_into(
                &self.p,
                self.dx,
                &self.u,
                &self.cs_point,
                &self.noise,
                a1,
                &mut self.dudx,
                &mut self.k1,
            );
            for i in 0..self.p.n {
                self.u1[i] = self.u[i] + dt * self.k1[i];
            }
            let a2 = self.forcing.coefficient(kinetic_energy(&self.u1));
            rhs_into(
                &self.p,
                self.dx,
                &self.u1,
                &self.cs_point,
                &self.noise,
                a2,
                &mut self.dudx,
                &mut self.k2,
            );
            for i in 0..self.p.n {
                self.u[i] += 0.5 * dt * (self.k1[i] + self.k2[i]);
            }
            remaining -= dt;
        }
    }
}

/// Box-filter a fine field onto `n_coarse` points (cell averages over
/// `refine` consecutive fine points).
fn coarse_grain(fine: &[f64], n_coarse: usize) -> Vec<f64> {
    let r = fine.len() / n_coarse;
    debug_assert_eq!(fine.len(), n_coarse * r);
    (0..n_coarse)
        .map(|i| fine[i * r..(i + 1) * r].iter().sum::<f64>() / r as f64)
        .collect()
}

/// Run the resolved reference simulation and package the ground truth:
/// spin up from a low-wavenumber random field, then sample
/// `truth_states + 1` snapshots (the last is held out as the test
/// state), accumulating the mean spectrum on LES bins.  Deterministic in
/// `cfg.truth_seed`.
pub fn generate_truth(cfg: &BurgersConfig) -> BurgersTruth {
    let n_fine = cfg.points * cfg.truth_refine;
    let mut sim = Sim::new(SimParams {
        n: n_fine,
        nu: cfg.nu,
        ke_target: cfg.ke_target,
        forcing_tau: cfg.forcing_tau,
        noise_amp: cfg.noise_amp,
        noise_modes: cfg.noise_modes,
        cfl: cfg.cfl,
    });
    let mut rng = Rng::new(cfg.truth_seed);
    // Low-wavenumber random initial condition scaled to the target
    // energy; the spin-up then develops the nonlinear cascade.
    let dx = sim.dx;
    for k in 1..=cfg.noise_modes + 1 {
        let a = rng.normal() / k as f64;
        let phi = TAU * rng.uniform();
        for (i, v) in sim.u.iter_mut().enumerate() {
            *v += a * (k as f64 * dx * i as f64 + phi).sin();
        }
    }
    let ke0 = kinetic_energy(&sim.u).max(1e-12);
    let scale = (cfg.ke_target / ke0).sqrt();
    sim.u.iter_mut().for_each(|v| *v *= scale);

    // Advance in dt_rl chunks, redrawing the stochastic forcing per
    // chunk — the same forcing cadence the envs run under.
    let advance_time = |sim: &mut Sim, rng: &mut Rng, t: f64| {
        let chunks = (t / cfg.dt_rl).round().max(1.0) as usize;
        for _ in 0..chunks {
            sim.draw_noise(rng);
            sim.advance(cfg.dt_rl);
        }
    };
    advance_time(&mut sim, &mut rng, cfg.truth_spinup);

    let nbins = cfg.points / 2 + 1;
    let mut splan = SpectrumPlan::new(n_fine);
    let mut mean_spectrum = vec![0.0; nbins];
    let mut spec = vec![0.0; nbins];
    let mut states = Vec::with_capacity(cfg.truth_states + 1);
    for _ in 0..cfg.truth_states + 1 {
        advance_time(&mut sim, &mut rng, cfg.truth_interval);
        splan.energy_into(&sim.u, &mut spec);
        for (m, s) in mean_spectrum.iter_mut().zip(&spec) {
            *m += s;
        }
        states.push(coarse_grain(&sim.u, cfg.points));
    }
    let n_samples = states.len() as f64;
    mean_spectrum.iter_mut().for_each(|m| *m /= n_samples);
    let test_state = states.pop().expect("at least one snapshot");
    BurgersTruth {
        n_les: cfg.points,
        mean_spectrum,
        states,
        test_state,
    }
}

/// Default duration a wave leader holds the door open for co-arriving
/// envs.  Pure latency/throughput knob: wave composition never affects
/// results, so the value only trades batching odds against solo-step
/// latency.
const WAVE_GRACE: Duration = Duration::from_millis(1);

/// Observability counters for the batched step path (every env step goes
/// through it; waves of one are the solo fallback).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchCounters {
    /// Batched solver waves executed.
    pub waves: usize,
    /// Env steps advanced through the batched path.
    pub envs_stepped: usize,
    /// Largest number of envs advanced in a single wave.
    pub max_wave: usize,
}

/// What one env slot is doing, from the batch core's point of view.
#[derive(Clone, Copy)]
enum Phase {
    /// Between steps: the owning handle may read/write the context.
    Idle,
    /// A step request is staged (action + fresh noise already written
    /// into the sim) and waits to be picked up by a wave.
    Pending,
    /// A wave leader took the context and is advancing it off-lock.
    Running,
    /// The wave finished: `(spec_error, reward)` awaits the owner.
    Done((f64, f64)),
}

/// Everything a wave needs to advance and score one env, boxed so a
/// leader can take it out of its slot and step it off-lock.
struct SlotCtx {
    sim: Sim,
    spec_plan: SpectrumPlan,
    /// Reused spectrum bins for the per-step reward (no per-step alloc).
    spec: Vec<f64>,
    truth: Arc<BurgersTruth>,
    k_max: usize,
    alpha: f64,
    dt_rl: f64,
}

impl SlotCtx {
    /// One RL interval: advance the sim and score the spectrum — the
    /// per-env payload a wave runs in parallel.  The arithmetic touches
    /// only this context, so the result is bitwise independent of which
    /// other envs share the wave (and of the pool width).
    fn advance_and_score(&mut self) -> (f64, f64) {
        self.sim.advance(self.dt_rl);
        self.spec_plan.energy_into(&self.sim.u, &mut self.spec);
        let spec_error = spectrum_error(&self.truth.mean_spectrum, &self.spec, self.k_max);
        (spec_error, reward_from_error(spec_error, self.alpha))
    }
}

struct Slot {
    phase: Phase,
    /// `None` while a wave runs it (taken by the leader) or after the
    /// owning handle dropped.
    ctx: Option<Box<SlotCtx>>,
    /// Mid-episode (reset, not yet done): counted in `CoreState::engaged`.
    engaged: bool,
}

struct CoreState {
    slots: Vec<Slot>,
    /// Slots currently `Pending`.
    pending: usize,
    /// Slots mid-episode — the wave rendezvous target: once `pending`
    /// reaches `engaged`, no further env can possibly join this wave, so
    /// the leader launches without burning the grace window.  The count
    /// is a latency heuristic only; correctness never depends on it (a
    /// stale target just means a leader waits out the grace).
    engaged: usize,
    /// A leader is currently collecting or executing a wave.
    wave_in_progress: bool,
}

/// The shared cross-env stepping core: slot registry, wave rendezvous and
/// counters.  All envs cut from one [`BurgersBackend`] share one of
/// these; the first env to stage a step becomes the wave leader, waits up
/// to the grace window for co-arrivals (leaving early once every
/// mid-episode env has staged), then advances the whole wave in parallel
/// over the kernel worker pool.
pub struct BurgersBatch {
    state: Mutex<CoreState>,
    cv: Condvar,
    grace: Duration,
    waves: AtomicUsize,
    envs_stepped: AtomicUsize,
    max_wave: AtomicUsize,
}

impl BurgersBatch {
    /// A fresh core with the default grace window.
    pub fn new() -> BurgersBatch {
        BurgersBatch::with_grace(WAVE_GRACE)
    }

    /// A fresh core with an explicit grace window (tests pin it large to
    /// make wave composition deterministic, or small to bound latency).
    pub fn with_grace(grace: Duration) -> BurgersBatch {
        BurgersBatch {
            state: Mutex::new(CoreState {
                slots: Vec::new(),
                pending: 0,
                engaged: 0,
                wave_in_progress: false,
            }),
            cv: Condvar::new(),
            grace,
            waves: AtomicUsize::new(0),
            envs_stepped: AtomicUsize::new(0),
            max_wave: AtomicUsize::new(0),
        }
    }

    /// Batched-path counters (monotonic; consistent with completed
    /// `step` calls: an env's step only returns after its wave's
    /// counters are published).
    pub fn counters(&self) -> BatchCounters {
        BatchCounters {
            waves: self.waves.load(Ordering::Relaxed),
            envs_stepped: self.envs_stepped.load(Ordering::Relaxed),
            max_wave: self.max_wave.load(Ordering::Relaxed),
        }
    }

    /// Register a new env slot; returns its index.
    fn register(&self, ctx: Box<SlotCtx>) -> usize {
        let mut st = self.state.lock().unwrap();
        st.slots.push(Slot {
            phase: Phase::Idle,
            ctx: Some(ctx),
            engaged: false,
        });
        st.slots.len() - 1
    }
}

impl Default for BurgersBatch {
    fn default() -> Self {
        BurgersBatch::new()
    }
}

/// One wave entry a leader carries off-lock.
struct WaveItem {
    slot: usize,
    ctx: Box<SlotCtx>,
    out: (f64, f64),
}

/// One coarse stochastic-Burgers environment instance: a thin handle on a
/// slot of the shared [`BurgersBatch`] core (episode bookkeeping and the
/// per-episode noise stream live here; the sim itself lives in the slot).
pub struct BurgersEnv {
    core: Arc<BurgersBatch>,
    slot: usize,
    truth: Arc<BurgersTruth>,
    segments: usize,
    points: usize,
    n_actions: usize,
    step_idx: usize,
    /// Per-episode stochastic forcing stream (seeded at reset).
    noise_rng: Rng,
    /// See [`CfdEnv::set_init_family`].
    init_family: Option<(usize, usize)>,
}

impl BurgersEnv {
    /// Build a standalone environment (its own single-slot batch core) on
    /// a shared truth package.  `cfg` is the variant-resolved
    /// configuration (viscosity, horizon, reward knobs already scaled).
    pub fn new(cfg: &BurgersConfig, truth: Arc<BurgersTruth>) -> Result<BurgersEnv> {
        BurgersEnv::on_batch(cfg, truth, Arc::new(BurgersBatch::new()))
    }

    /// Build an environment as one slot of a shared batch core — the
    /// backend constructor, so every env of a pool steps through the same
    /// wave rendezvous.
    pub fn on_batch(
        cfg: &BurgersConfig,
        truth: Arc<BurgersTruth>,
        core: Arc<BurgersBatch>,
    ) -> Result<BurgersEnv> {
        anyhow::ensure!(
            truth.n_les == cfg.points,
            "truth coarse-grained for n={}, env needs n={}",
            truth.n_les,
            cfg.points
        );
        anyhow::ensure!(
            cfg.segments >= 1 && cfg.points % cfg.segments == 0,
            "segments {} must divide points {}",
            cfg.segments,
            cfg.points
        );
        anyhow::ensure!(
            cfg.k_max >= 1 && cfg.k_max <= cfg.points / 2,
            "k_max {} beyond Nyquist {}",
            cfg.k_max,
            cfg.points / 2
        );
        for (k, &e) in truth.mean_spectrum[1..=cfg.k_max].iter().enumerate() {
            anyhow::ensure!(
                e > 0.0,
                "truth spectrum empty at k={} (reward undefined)",
                k + 1
            );
        }
        let ctx = Box::new(SlotCtx {
            sim: Sim::new(SimParams {
                n: cfg.points,
                nu: cfg.nu,
                ke_target: cfg.ke_target,
                forcing_tau: cfg.forcing_tau,
                noise_amp: cfg.noise_amp,
                noise_modes: cfg.noise_modes,
                cfl: cfg.cfl,
            }),
            spec_plan: SpectrumPlan::new(cfg.points),
            spec: vec![0.0; cfg.points / 2 + 1],
            truth: truth.clone(),
            k_max: cfg.k_max,
            alpha: cfg.alpha,
            dt_rl: cfg.dt_rl,
        });
        let slot = core.register(ctx);
        Ok(BurgersEnv {
            core,
            slot,
            truth,
            segments: cfg.segments,
            points: cfg.points,
            n_actions: (cfg.t_end / cfg.dt_rl).round() as usize,
            step_idx: 0,
            noise_rng: Rng::new(TEST_NOISE_SEED),
            init_family: None,
        })
    }
}

impl Drop for BurgersEnv {
    fn drop(&mut self) {
        // No step of this slot can be in flight (`step` is synchronous on
        // `&mut self`), so the slot is safe to vacate.  Waking any grace-
        // waiting leader matters: the rendezvous target may have dropped.
        let mut st = self.core.state.lock().unwrap();
        let slot = &mut st.slots[self.slot];
        slot.ctx = None;
        slot.phase = Phase::Idle;
        if slot.engaged {
            slot.engaged = false;
            st.engaged -= 1;
        }
        drop(st);
        self.core.cv.notify_all();
    }
}

impl CfdEnv for BurgersEnv {
    fn reset_in_place(&mut self, rng: &mut Rng, test: bool) {
        let state = if test {
            // Fixed noise stream: test episodes consume no caller draws.
            self.noise_rng = Rng::new(TEST_NOISE_SEED);
            &self.truth.test_state
        } else {
            let idx =
                super::cfd::draw_pool_index(self.truth.states.len(), self.init_family, rng);
            self.noise_rng = Rng::new(rng.next_u64());
            &self.truth.states[idx]
        };
        let mut st = self.core.state.lock().unwrap();
        let slot = &mut st.slots[self.slot];
        let ctx = slot.ctx.as_mut().expect("resetting a live env");
        ctx.sim.u.copy_from_slice(state);
        ctx.sim.cs_point.fill(0.0);
        ctx.sim.noise.fill(0.0);
        if !slot.engaged {
            slot.engaged = true;
            st.engaged += 1;
        }
        drop(st);
        self.step_idx = 0;
    }

    fn step(&mut self, cs: &[f64]) -> StepOut {
        assert_eq!(cs.len(), self.segments, "one SGS coefficient per segment");
        let core = self.core.clone();
        let mut st = core.state.lock().unwrap();
        {
            // Stage the request: the action field and a fresh noise draw
            // go into the slot now, so the wave only runs solver math.
            let ctx = st.slots[self.slot].ctx.as_mut().expect("stepping a live env");
            let pts = self.points / self.segments;
            for (i, c) in ctx.sim.cs_point.iter_mut().enumerate() {
                *c = cs[i / pts].clamp(0.0, 0.5);
            }
            ctx.sim.draw_noise(&mut self.noise_rng);
        }
        st.slots[self.slot].phase = Phase::Pending;
        st.pending += 1;
        core.cv.notify_all();

        let (spec_error, reward) = loop {
            if let Phase::Done(out) = st.slots[self.slot].phase {
                st.slots[self.slot].phase = Phase::Idle;
                break out;
            }
            if st.wave_in_progress {
                // Another leader owns the current wave (it may or may not
                // have collected us); wait for the next round of news.
                st = core.cv.wait(st).unwrap();
                continue;
            }
            // Become the wave leader: hold the door open until every
            // mid-episode env has staged or the grace window expires.
            // The timeout bounds the wait unconditionally, so a stale
            // `engaged` count can only cost latency, never progress.
            st.wave_in_progress = true;
            let deadline = Instant::now() + core.grace;
            while st.pending < st.engaged {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                st = core.cv.wait_timeout(st, deadline - now).unwrap().0;
            }
            // Collect every staged env (ours included) and step the wave
            // off-lock, in parallel over the kernel worker pool.
            let mut wave: Vec<WaveItem> = Vec::new();
            for (idx, slot) in st.slots.iter_mut().enumerate() {
                if matches!(slot.phase, Phase::Pending) {
                    slot.phase = Phase::Running;
                    wave.push(WaveItem {
                        slot: idx,
                        ctx: slot.ctx.take().expect("pending slot has its ctx"),
                        out: (0.0, 0.0),
                    });
                }
            }
            st.pending -= wave.len();
            drop(st);

            {
                let _sp = crate::span!("burgers.wave");
                let _t = crate::util::telemetry::HistId::WaveAssembly.timer();
                crate::tcount!("burgers.wave_envs", wave.len());
                pool::global().parallel_chunks_mut(&mut wave, 1, |_, item| {
                    let it = &mut item[0];
                    it.out = it.ctx.advance_and_score();
                });
            }

            // Publish counters before the results so any step that has
            // returned is already reflected in them.
            core.waves.fetch_add(1, Ordering::Relaxed);
            core.envs_stepped.fetch_add(wave.len(), Ordering::Relaxed);
            core.max_wave.fetch_max(wave.len(), Ordering::Relaxed);

            st = core.state.lock().unwrap();
            for it in wave {
                st.slots[it.slot].ctx = Some(it.ctx);
                st.slots[it.slot].phase = Phase::Done(it.out);
            }
            st.wave_in_progress = false;
            core.cv.notify_all();
        };

        self.step_idx += 1;
        let done = self.step_idx >= self.n_actions;
        if done && st.slots[self.slot].engaged {
            // Episode over: leave the rendezvous target so later waves
            // don't wait on an env that will not step again.
            st.slots[self.slot].engaged = false;
            st.engaged -= 1;
            drop(st);
            core.cv.notify_all();
        }
        StepOut {
            spec_error,
            reward,
            done,
        }
    }

    fn observe_into(&mut self, out: &mut [f32]) {
        assert_eq!(out.len(), self.points);
        let st = self.core.state.lock().unwrap();
        let ctx = st.slots[self.slot].ctx.as_ref().expect("observing a live env");
        for (o, &v) in out.iter_mut().zip(&ctx.sim.u) {
            *o = v as f32;
        }
    }

    /// One velocity point per float; segments are contiguous slices, so
    /// agent `s` observes `out[s * points/segments ..][..points/segments]`.
    fn obs_len(&self) -> usize {
        self.points
    }

    fn n_agents(&self) -> usize {
        self.segments
    }

    fn n_actions(&self) -> usize {
        self.n_actions
    }

    fn spectrum(&self) -> Vec<f64> {
        let mut st = self.core.state.lock().unwrap();
        let ctx = st.slots[self.slot].ctx.as_mut().expect("live env");
        let mut spec = vec![0.0; self.points / 2 + 1];
        ctx.spec_plan.energy_into(&ctx.sim.u, &mut spec);
        spec
    }

    fn target_spectrum(&self) -> &[f64] {
        &self.truth.mean_spectrum
    }

    fn set_init_family(&mut self, family: usize, n_families: usize) -> Result<()> {
        super::cfd::validate_init_family(self.truth.states.len(), family, n_families)?;
        self.init_family = Some((family, n_families));
        Ok(())
    }
}

/// The Burgers scenario as a pool backend: generates the resolved truth
/// once per run (deterministic in `burgers.truth_seed`) and cuts every
/// env from it.
pub struct BurgersBackend {
    cfg: BurgersConfig,
    truth: Arc<BurgersTruth>,
    /// Shared batched-stepping core: every env cut from this backend
    /// (training variants and the eval env alike) is a slot of it.
    batch: Arc<BurgersBatch>,
}

impl BurgersBackend {
    /// Generate the shared resolved truth for this run's configuration.
    /// Per-env parameter guards (segments/k_max, incl. variant
    /// overrides) live in [`BurgersEnv::on_batch`]; config-level
    /// validation is `RunConfig::validate` — only what truth generation
    /// itself needs is checked here.
    pub fn new(cfg: &BurgersConfig) -> Result<BurgersBackend> {
        anyhow::ensure!(cfg.truth_refine >= 1 && cfg.truth_states >= 1);
        let truth = Arc::new(generate_truth(cfg));
        Ok(BurgersBackend {
            cfg: cfg.clone(),
            truth,
            batch: Arc::new(BurgersBatch::new()),
        })
    }

    /// The resolved-truth package shared by every env of this backend.
    pub fn truth(&self) -> Arc<BurgersTruth> {
        self.truth.clone()
    }

    /// Counters of the shared batched step path (integration tests
    /// assert every env step went through it and that waves coalesced).
    pub fn batch_counters(&self) -> BatchCounters {
        self.batch.counters()
    }
}

impl CfdBackend for BurgersBackend {
    fn name(&self) -> &str {
        "burgers"
    }

    fn batch_stats(&self) -> Vec<(&'static str, u64)> {
        let c = self.batch.counters();
        vec![
            ("waves", c.waves as u64),
            ("envs_stepped", c.envs_stepped as u64),
            ("max_wave", c.max_wave as u64),
        ]
    }

    fn make_env(&self, rv: &ResolvedVariant) -> Result<Box<dyn CfdEnv>> {
        // The Burgers base parameters live in their own config section,
        // so the variant's raw knobs are applied here rather than through
        // the pre-scaled `rv.case`/`rv.solver`.
        let mut cfg = self.cfg.clone();
        cfg.nu *= rv.variant.nu_scale;
        cfg.t_end *= rv.variant.t_end_scale;
        if let Some(a) = rv.variant.alpha {
            cfg.alpha = a;
        }
        if let Some(k) = rv.variant.k_max {
            cfg.k_max = k;
        }
        let mut env = BurgersEnv::on_batch(&cfg, self.truth.clone(), self.batch.clone())
            .with_context(|| format!("burgers env (variant {})", rv.name))?;
        if let Some((family, m)) = rv.init_family {
            env.set_init_family(family, m)
                .with_context(|| format!("burgers env (variant {})", rv.name))?;
        }
        Ok(Box::new(env))
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::config::{EnvVariant, RunConfig};

    /// A small, fast Burgers configuration shared by the backend tests.
    pub fn tiny_burgers() -> BurgersConfig {
        BurgersConfig {
            points: 48,
            segments: 4,
            k_max: 6,
            t_end: 0.3,
            truth_states: 3,
            truth_spinup: 0.6,
            truth_interval: 0.2,
            ..BurgersConfig::default()
        }
    }

    #[test]
    fn spectrum_bins_sum_to_kinetic_energy() {
        let mut rng = Rng::new(9);
        let u: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let spec = energy_spectrum_1d(&u);
        assert_eq!(spec.len(), 33);
        let total: f64 = spec.iter().sum();
        let ke = kinetic_energy(&u);
        assert!((total - ke).abs() < 1e-10 * ke.max(1.0), "{total} vs {ke}");
    }

    #[test]
    fn single_mode_lands_in_right_bin() {
        let n = 32usize;
        let u: Vec<f64> = (0..n).map(|i| (3.0 * TAU * i as f64 / n as f64).sin()).collect();
        let spec = energy_spectrum_1d(&u);
        // sin(3x): ke = 1/4, all of it in bin 3.
        assert!((spec[3] - 0.25).abs() < 1e-12);
        for (k, &e) in spec.iter().enumerate() {
            if k != 3 {
                assert!(e.abs() < 1e-12, "unexpected energy in bin {k}: {e}");
            }
        }
    }

    #[test]
    fn unforced_viscous_flow_dissipates() {
        let cfg = tiny_burgers();
        let mut sim = Sim::new(SimParams {
            n: cfg.points,
            nu: cfg.nu,
            ke_target: cfg.ke_target,
            forcing_tau: cfg.forcing_tau,
            noise_amp: 0.0,
            noise_modes: 1,
            cfl: cfg.cfl,
        });
        sim.forcing.a0 = 0.0;
        sim.forcing.a_max = 0.0; // forcing off: pure decay
        let dx = sim.dx;
        for (i, v) in sim.u.iter_mut().enumerate() {
            *v = (dx * i as f64).sin() + 0.3 * (2.0 * dx * i as f64).cos();
        }
        let ke0 = kinetic_energy(&sim.u);
        sim.advance(0.5);
        let ke1 = kinetic_energy(&sim.u);
        assert!(ke1 < ke0, "viscous decay: {ke1} !< {ke0}");
        assert!(ke1 > 0.0 && ke1.is_finite());
    }

    #[test]
    fn truth_is_deterministic_and_usable() {
        let cfg = tiny_burgers();
        let a = generate_truth(&cfg);
        let b = generate_truth(&cfg);
        assert_eq!(a.mean_spectrum, b.mean_spectrum);
        assert_eq!(a.states, b.states);
        assert_eq!(a.test_state, b.test_state);
        assert_eq!(a.states.len(), cfg.truth_states);
        assert_eq!(a.test_state.len(), cfg.points);
        // The reward needs strictly positive truth energy up to k_max.
        for k in 1..=cfg.k_max {
            assert!(a.mean_spectrum[k] > 0.0, "empty truth bin {k}");
        }
        // The forced field holds a sane energy level.
        let ke = kinetic_energy(&a.test_state);
        assert!(ke > 0.05 * cfg.ke_target && ke < 20.0 * cfg.ke_target, "ke={ke}");
    }

    #[test]
    fn episode_runs_to_done_with_finite_rewards() {
        let cfg = tiny_burgers();
        let backend = BurgersBackend::new(&cfg).unwrap();
        let mut run = RunConfig::default();
        run.burgers = cfg.clone();
        let mut env = backend.make_env(&run.base_resolved()).unwrap();
        assert_eq!(env.n_agents(), 4);
        assert_eq!(env.obs_len(), 48);
        let mut rng = Rng::new(1);
        let obs = env.reset(&mut rng, false);
        assert_eq!(obs.len(), env.obs_len());
        let cs = vec![0.1; env.n_agents()];
        let mut steps = 0;
        loop {
            let out = env.step(&cs);
            assert!(out.spec_error >= 0.0 && out.spec_error.is_finite());
            assert!(out.reward > -1.0 && out.reward <= 1.0, "reward={}", out.reward);
            steps += 1;
            if out.done {
                break;
            }
            assert!(steps <= 3, "t_end/dt_rl = 3 actions");
        }
        assert_eq!(steps, 3);
    }

    #[test]
    fn same_seed_reproduces_and_test_state_ignores_rng() {
        let cfg = tiny_burgers();
        let backend = BurgersBackend::new(&cfg).unwrap();
        let run = {
            let mut r = RunConfig::default();
            r.burgers = cfg;
            r
        };
        let mut e1 = backend.make_env(&run.base_resolved()).unwrap();
        let mut e2 = backend.make_env(&run.base_resolved()).unwrap();
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        assert_eq!(e1.reset(&mut r1, false), e2.reset(&mut r2, false));
        let cs = vec![0.2; e1.n_agents()];
        let (a, b) = (e1.step(&cs), e2.step(&cs));
        assert_eq!(a.reward.to_bits(), b.reward.to_bits());
        assert_eq!(e1.observe(), e2.observe());

        // Test resets are RNG-independent (deterministic evaluation).
        let mut r3 = Rng::new(1);
        let mut r4 = Rng::new(999);
        assert_eq!(e1.reset(&mut r3, true), e2.reset(&mut r4, true));
        let (a, b) = (e1.step(&cs), e2.step(&cs));
        assert_eq!(a.reward.to_bits(), b.reward.to_bits());
    }

    #[test]
    fn sgs_coefficient_changes_the_flow() {
        let cfg = tiny_burgers();
        let backend = BurgersBackend::new(&cfg).unwrap();
        let run = {
            let mut r = RunConfig::default();
            r.burgers = cfg;
            r
        };
        let mut e1 = backend.make_env(&run.base_resolved()).unwrap();
        let mut e2 = backend.make_env(&run.base_resolved()).unwrap();
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        e1.reset_in_place(&mut r1, true);
        e2.reset_in_place(&mut r2, true);
        e1.step(&[0.0; 4]);
        e2.step(&[0.5; 4]);
        let (s1, s2) = (e1.spectrum(), e2.spectrum());
        let diff: f64 = s1.iter().zip(&s2).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-12, "the SGS action must matter");
        // More dissipation -> less small-scale energy.
        let tail = cfg_tail(&s1) - cfg_tail(&s2);
        assert!(tail > 0.0, "Cs=0.5 must damp the tail: {tail}");
    }

    fn cfg_tail(spec: &[f64]) -> f64 {
        spec[spec.len() / 2..].iter().sum()
    }

    #[test]
    fn init_family_restricts_the_pool() {
        let cfg = tiny_burgers(); // 3 truth states
        let backend = BurgersBackend::new(&cfg).unwrap();
        let run = {
            let mut r = RunConfig::default();
            r.burgers = cfg;
            r
        };
        let mut rng = Rng::new(11);
        for fam in 0..3 {
            let mut env = backend.make_env(&run.base_resolved()).unwrap();
            env.set_init_family(fam, 3).unwrap();
            // One state per family: the pool index is pinned, and the
            // initial field must reproduce across resets.
            env.reset_in_place(&mut rng, false);
            let mut a = vec![0f32; env.obs_len()];
            env.observe_into(&mut a);
            env.reset_in_place(&mut rng, false);
            let mut b = vec![0f32; env.obs_len()];
            env.observe_into(&mut b);
            assert_eq!(a, b, "family {fam} has one state");
        }
        let mut env = backend.make_env(&run.base_resolved()).unwrap();
        assert!(env.set_init_family(3, 4).is_err());
    }

    #[test]
    fn fft_spectrum_matches_the_naive_oracle() {
        let mut rng = Rng::new(21);
        // Lengths with radix-4/2/3/5 mixes, matching env and truth grids.
        for n in [48usize, 64, 90, 96] {
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut naive = vec![0.0; n / 2 + 1];
            energy_spectrum_1d_naive_into(&u, &mut naive);
            let mut fast = vec![0.0; n / 2 + 1];
            SpectrumPlan::new(n).energy_into(&u, &mut fast);
            for k in 0..naive.len() {
                assert!(
                    (naive[k] - fast[k]).abs() < 1e-10 * (1.0 + naive[k]),
                    "n={n} bin {k}: naive {} vs fft {}",
                    naive[k],
                    fast[k]
                );
            }
            // And the allocating convenience is the FFT path.
            let alloc = energy_spectrum_1d(&u);
            assert_eq!(alloc, fast);
        }
    }

    #[test]
    fn concurrent_steps_coalesce_into_one_wave() {
        let cfg = tiny_burgers();
        let backend = BurgersBackend::new(&cfg).unwrap();
        // A private core with a huge grace window: once all three envs
        // are engaged and release together, the leader is guaranteed to
        // hold the door until `pending == engaged`, so the wave
        // composition is deterministic.
        let batch = Arc::new(BurgersBatch::with_grace(Duration::from_secs(30)));
        let mut envs: Vec<BurgersEnv> = (0..3)
            .map(|_| BurgersEnv::on_batch(&cfg, backend.truth(), batch.clone()).unwrap())
            .collect();
        let mut rng = Rng::new(3);
        for e in &mut envs {
            e.reset_in_place(&mut rng, false);
        }
        let barrier = std::sync::Barrier::new(3);
        std::thread::scope(|s| {
            for mut e in envs.drain(..) {
                let barrier = &barrier;
                s.spawn(move || {
                    barrier.wait();
                    let out = e.step(&[0.1; 4]);
                    assert!(out.reward.is_finite());
                });
            }
        });
        let c = batch.counters();
        assert_eq!(c.envs_stepped, 3);
        assert_eq!(c.waves, 1, "co-arriving steps must share one wave");
        assert_eq!(c.max_wave, 3);
    }

    #[test]
    fn sequential_steps_fall_back_to_solo_waves() {
        let cfg = tiny_burgers();
        let backend = BurgersBackend::new(&cfg).unwrap();
        let run = {
            let mut r = RunConfig::default();
            r.burgers = cfg;
            r
        };
        let mut e1 = backend.make_env(&run.base_resolved()).unwrap();
        let mut e2 = backend.make_env(&run.base_resolved()).unwrap();
        let mut r1 = Rng::new(4);
        let mut r2 = Rng::new(5);
        // Both engaged, but stepped strictly sequentially from one
        // thread: each step must time out the grace window on its own
        // and run as a wave of one — the solo fallback that keeps every
        // pre-batching caller (and eval) working unchanged.
        e1.reset(&mut r1, false);
        e2.reset(&mut r2, false);
        let cs = vec![0.1; e1.n_agents()];
        e1.step(&cs);
        e2.step(&cs);
        e1.step(&cs);
        let c = backend.batch_counters();
        assert_eq!(c.envs_stepped, 3);
        assert_eq!(c.waves, 3, "sequential steps cannot coalesce");
        assert_eq!(c.max_wave, 1);
    }

    #[test]
    fn variants_scale_viscosity_horizon_and_reward() {
        let cfg = tiny_burgers();
        let backend = BurgersBackend::new(&cfg).unwrap();
        let mut run = RunConfig::default();
        run.burgers = cfg;
        let mut rv = run.base_resolved();
        rv.variant = EnvVariant {
            name: "short".into(),
            nu_scale: 2.0,
            t_end_scale: 2.0,
            alpha: Some(0.8),
            k_max: Some(4),
        };
        let env = backend.make_env(&rv).unwrap();
        assert_eq!(env.n_actions(), 6, "t_end_scale doubles the horizon");
        // Out-of-range k_max override is rejected per env.
        rv.variant.k_max = Some(1000);
        assert!(backend.make_env(&rv).is_err());
    }
}
