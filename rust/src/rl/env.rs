//! The RL environment (paper §5.2): an LES episode on the HIT test case.
//!
//! State: the coarse-scale velocity field, observed element-locally
//! (`(N+1)^3 x 3` per element).  Action: one Smagorinsky Cs per element.
//! Transition: the flow solver advances `dt_RL = 0.1`.  Reward: spectrum
//! error vs the DNS mean spectrum through Eqs. (4)-(5).  Episodes run to
//! `t_end = 5` (50 actions); initial states are drawn from the filtered
//! DNS pool with one held-out test state.

use super::cfd::CfdEnv;
use super::reward::reward_from_error;
use crate::config::{CaseConfig, SolverConfig};
use crate::solver::dns::{unpack_state, Truth};
use crate::solver::forcing::LinearForcing;
use crate::solver::spectrum::{energy_spectrum_into, spectrum_error};
use crate::solver::{Grid, Solver};
use crate::util::Rng;
use anyhow::Result;
use std::sync::Arc;

/// Result of one environment step.
#[derive(Debug, Clone, Copy)]
pub struct StepOut {
    /// Mean relative spectrum error, Eq. (4).
    pub spec_error: f64,
    /// Reward, Eq. (5).
    pub reward: f64,
    /// Episode finished (t reached t_end).
    pub done: bool,
}

/// One LES environment instance (the paper's "FLEXI instance").
pub struct LesEnv {
    pub solver: Solver,
    truth: Arc<Truth>,
    k_max: usize,
    alpha: f64,
    dt_rl: f64,
    n_actions: usize,
    ke_target: f64,
    forcing_tau: f64,
    /// Actions taken in the current episode.
    pub step_idx: usize,
    /// Reused spectrum bins for the per-step reward (no per-step alloc).
    spec: Vec<f64>,
    /// `Some((family, n_families))`: draw initial states only from pool
    /// indices congruent to `family` mod `n_families` (disjoint
    /// initial-state families across a heterogeneous pool).
    init_family: Option<(usize, usize)>,
}

impl LesEnv {
    /// Build an environment for a Table-1 case (private grid).
    pub fn new(case: &CaseConfig, scfg: &SolverConfig, truth: Arc<Truth>) -> Result<LesEnv> {
        let grid = Arc::new(Grid::new(case.points_per_dir()));
        LesEnv::with_grid(case, scfg, truth, grid)
    }

    /// Build an environment on a shared grid: the env pool constructs one
    /// `Arc<Grid>` per case so all workers reuse one FFT plan
    /// (`fft::Plan` is `Send + Sync`; twiddle tables are built once).
    pub fn with_grid(
        case: &CaseConfig,
        scfg: &SolverConfig,
        truth: Arc<Truth>,
        grid: Arc<Grid>,
    ) -> Result<LesEnv> {
        anyhow::ensure!(
            truth.n_les == case.points_per_dir(),
            "truth built for n={}, case needs n={}",
            truth.n_les,
            case.points_per_dir()
        );
        anyhow::ensure!(
            grid.n == case.points_per_dir(),
            "shared grid has n={}, case needs n={}",
            grid.n,
            case.points_per_dir()
        );
        let solver = Solver::with_grid(grid, case.elems_per_dir, scfg.nu, scfg.cfl);
        let nbins = solver.grid.k_nyquist() + 1;
        Ok(LesEnv {
            solver,
            truth,
            k_max: case.k_max,
            alpha: case.alpha,
            dt_rl: scfg.dt_rl,
            n_actions: (scfg.t_end / scfg.dt_rl).round() as usize,
            ke_target: scfg.ke_target,
            forcing_tau: scfg.forcing_tau,
            step_idx: 0,
            spec: vec![0.0; nbins],
            init_family: None,
        })
    }

    /// Number of elements (= actions per step; the trait's
    /// [`CfdEnv::n_agents`]).
    pub fn n_elems(&self) -> usize {
        self.solver.emap.n_elems()
    }
}

/// The LES episode as a [`CfdEnv`] backend: agents are DG elements, the
/// allocating `reset`/`observe` come from the trait's defaults over the
/// in-place core below.
impl CfdEnv for LesEnv {
    /// Restrict initial-state draws to one family of the truth pool
    /// (indices ≡ `family` mod `n_families`).  The family must be
    /// non-empty for this truth's pool size.
    fn set_init_family(&mut self, family: usize, n_families: usize) -> Result<()> {
        super::cfd::validate_init_family(self.truth.states.len(), family, n_families)?;
        self.init_family = Some((family, n_families));
        Ok(())
    }

    /// Actions per episode.
    fn n_actions(&self) -> usize {
        self.n_actions
    }

    /// Agents = elements.
    fn n_agents(&self) -> usize {
        self.n_elems()
    }

    /// Reset to a random pool state (or the held-out test state) without
    /// materializing the observation — the env workers reset in place and
    /// then [`CfdEnv::observe_into`] a reusable buffer, so a steady-state
    /// episode start allocates nothing.  With an init family set, the
    /// draw is restricted to that family's pool indices (one RNG draw
    /// either way, so the consumption pattern is family-independent; test
    /// resets consume none).
    fn reset_in_place(&mut self, rng: &mut Rng, test: bool) {
        let flat = if test {
            &self.truth.test_state
        } else {
            let idx =
                super::cfd::draw_pool_index(self.truth.states.len(), self.init_family, rng);
            &self.truth.states[idx]
        };
        let state = unpack_state(&self.solver.grid, flat);
        self.solver.set_state(state);
        self.solver.t = 0.0;
        self.solver.forcing = Some(LinearForcing::new(self.ke_target, self.forcing_tau));
        self.solver.set_cs_uniform(0.0);
        self.step_idx = 0;
    }

    /// Apply per-element Cs actions and advance one RL interval.
    fn step(&mut self, cs: &[f64]) -> StepOut {
        self.solver.set_cs(cs);
        self.solver.advance(self.dt_rl);
        self.step_idx += 1;
        energy_spectrum_into(&self.solver.grid, &self.solver.uhat, &mut self.spec);
        let spec_error = spectrum_error(&self.truth.mean_spectrum, &self.spec, self.k_max);
        StepOut {
            spec_error,
            reward: reward_from_error(spec_error, self.alpha),
            done: self.step_idx >= self.n_actions,
        }
    }

    /// Current observation into a caller-owned buffer of
    /// [`CfdEnv::obs_len`] floats (no allocation).
    fn observe_into(&mut self, out: &mut [f32]) {
        self.solver.observations_into(out);
    }

    /// Observation length: `n_elems * (N+1)^3 * 3`.
    fn obs_len(&self) -> usize {
        self.solver.obs_len()
    }

    /// Current LES energy spectrum.
    fn spectrum(&self) -> Vec<f64> {
        self.solver.spectrum()
    }

    /// The DNS mean spectrum this env is rewarded against.
    fn target_spectrum(&self) -> &[f64] {
        &self.truth.mean_spectrum
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::config::presets;
    use crate::solver::dns::{generate, TruthParams};

    /// A small truth + case for fast tests (12^3 LES, 2^3 elements).
    pub fn tiny_setup() -> (CaseConfig, SolverConfig, Arc<Truth>) {
        let case = CaseConfig {
            name: "tiny".into(),
            n: 5,
            elems_per_dir: 2,
            k_max: 3,
            alpha: 0.4,
        };
        let scfg = SolverConfig {
            nu: 1.0 / 45.0,
            dns_points: 24,
            t_end: 0.3,
            dt_rl: 0.1,
            ..Default::default()
        };
        let truth = generate(
            &TruthParams {
                n_dns: 24,
                n_les: 12,
                nu: scfg.nu,
                ke_target: scfg.ke_target,
                spinup_time: 0.5,
                n_states: 3,
                sample_interval: 0.2,
                seed: 42,
            },
            |_, _| {},
        );
        (case, scfg, Arc::new(truth))
    }

    #[test]
    fn episode_runs_to_done() {
        let (case, scfg, truth) = tiny_setup();
        let mut env = LesEnv::new(&case, &scfg, truth).unwrap();
        let mut rng = Rng::new(1);
        let obs = env.reset(&mut rng, false);
        assert_eq!(obs.len(), 8 * 216 * 3); // 2^3 elems x 6^3 points x 3 comps
        assert_eq!(obs.len(), env.n_elems() * 648);
        let cs = vec![0.1; env.n_elems()];
        let mut done = false;
        let mut steps = 0;
        while !done {
            let out = env.step(&cs);
            assert!(out.reward <= 1.0 && out.reward > -1.0);
            assert!(out.spec_error >= 0.0);
            done = out.done;
            steps += 1;
            assert!(steps <= 3);
        }
        assert_eq!(steps, 3); // t_end/dt_rl
    }

    #[test]
    fn test_state_is_deterministic() {
        let (case, scfg, truth) = tiny_setup();
        let mut env1 = LesEnv::new(&case, &scfg, truth.clone()).unwrap();
        let mut env2 = LesEnv::new(&case, &scfg, truth).unwrap();
        let mut rng1 = Rng::new(1);
        let mut rng2 = Rng::new(999); // different RNG must not matter for test state
        let o1 = env1.reset(&mut rng1, true);
        let o2 = env2.reset(&mut rng2, true);
        assert_eq!(o1, o2);
    }

    #[test]
    fn init_family_restricts_the_pool() {
        // With 3 truth states and 3 families, each family has exactly one
        // state: every reset in a family must reproduce the same obs.
        let (case, scfg, truth) = tiny_setup();
        let mut rng = Rng::new(7);
        let mut per_family = Vec::new();
        for fam in 0..3 {
            let mut env = LesEnv::new(&case, &scfg, truth.clone()).unwrap();
            env.set_init_family(fam, 3).unwrap();
            let a = env.reset(&mut rng, false);
            let b = env.reset(&mut rng, false);
            assert_eq!(a, b, "family {fam} has one state; resets must match");
            per_family.push(a);
        }
        // Distinct families start from distinct states.
        assert_ne!(per_family[0], per_family[1]);
        assert_ne!(per_family[1], per_family[2]);
        // Empty family rejected (family index beyond the pool).
        let mut env = LesEnv::new(&case, &scfg, truth).unwrap();
        assert!(env.set_init_family(3, 4).is_err());
        assert!(env.set_init_family(2, 2).is_err());
    }

    #[test]
    fn reset_in_place_and_observe_into_match_the_allocating_path() {
        let (case, scfg, truth) = tiny_setup();
        let mut env1 = LesEnv::new(&case, &scfg, truth.clone()).unwrap();
        let mut env2 = LesEnv::new(&case, &scfg, truth).unwrap();
        let mut rng1 = Rng::new(4);
        let mut rng2 = Rng::new(4);
        let a = env1.reset(&mut rng1, false);
        env2.reset_in_place(&mut rng2, false);
        let mut b = vec![0f32; env2.obs_len()];
        assert_eq!(a.len(), env2.obs_len());
        env2.observe_into(&mut b);
        assert_eq!(a, b, "in-place reset + observe_into == reset");
        // Identical RNG consumption: the next draws agree.
        assert_eq!(rng1.next_u64(), rng2.next_u64());

        let cs = vec![0.1; env1.n_elems()];
        env1.step(&cs);
        env2.step(&cs);
        env2.observe_into(&mut b);
        assert_eq!(env1.observe(), b, "observe_into == observe after a step");
    }

    #[test]
    fn mismatched_truth_rejected() {
        let (_case, scfg, truth) = tiny_setup();
        let case32 = presets::dof32();
        assert!(LesEnv::new(&case32, &scfg, truth).is_err());
    }

    #[test]
    fn reward_reflects_spectrum_quality() {
        // An env stepped from a filtered-DNS state should start with a
        // reward well above -1 (its spectrum matches the DNS by
        // construction at resolved scales).
        let (case, scfg, truth) = tiny_setup();
        let mut env = LesEnv::new(&case, &scfg, truth).unwrap();
        let mut rng = Rng::new(3);
        env.reset(&mut rng, false);
        let out = env.step(&vec![0.1; env.n_elems()]);
        assert!(out.reward > -0.5, "reward={}", out.reward);
    }
}
