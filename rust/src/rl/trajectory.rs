//! Trajectory storage and advantage estimation.
//!
//! The policy is element-local (one shared network, one action per DG
//! element — the multi-agent view of Novati et al. that the paper builds
//! on), so one env step yields `n_elems` samples sharing the env-level
//! reward.  Returns are plain discounted sums (Eq. 2); GAE(lambda) against
//! the critic is available with `lambda < 1`, and `lambda = 1` recovers
//! `return - V(s)` advantages.

use crate::util::{stats, Rng};
use std::sync::Arc;

/// Data recorded at one env step (all elements of one env).
///
/// Observation and action blocks are shared buffers: the collector
/// records the very same `Arc` the exchange path published (the worker's
/// observation buffer, the trainer's action buffer), so recording a step
/// bumps two refcounts instead of copying tensors.
#[derive(Debug, Clone)]
pub struct StepRecord {
    /// `n_elems * features` observation block.
    pub obs: Arc<[f32]>,
    /// Per-element actions.
    pub act: Arc<[f32]>,
    /// Per-element behaviour log-probs.
    pub logp: Vec<f32>,
    /// Per-element critic values.
    pub value: Vec<f32>,
    /// Env-level reward r_{t+1} received after this action.
    pub reward: f64,
}

/// One environment episode.
#[derive(Debug, Clone, Default)]
pub struct Episode {
    pub steps: Vec<StepRecord>,
    /// Scenario-family index this episode was sampled under (0 for a
    /// homogeneous pool) — per-variant bookkeeping in the metrics.
    pub variant: usize,
}

impl Episode {
    /// Total (undiscounted-gamma) discounted return, Eq. (2).
    pub fn discounted_return(&self, gamma: f64) -> f64 {
        self.steps
            .iter()
            .enumerate()
            .map(|(t, s)| gamma.powi(t as i32 + 1) * s.reward)
            .sum()
    }

    /// Plain sum of rewards.
    pub fn total_reward(&self) -> f64 {
        self.steps.iter().map(|s| s.reward).sum()
    }
}

/// Flattened training dataset (one row per element-sample).
#[derive(Debug, Default)]
pub struct Dataset {
    pub features: usize,
    pub obs: Vec<f32>,
    pub act: Vec<f32>,
    pub logp: Vec<f32>,
    pub adv: Vec<f32>,
    pub ret: Vec<f32>,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.act.len()
    }

    /// True if no samples were collected.
    pub fn is_empty(&self) -> bool {
        self.act.is_empty()
    }

    /// Shuffled minibatch index sets of exactly `mb` samples each; the
    /// tail wraps around (sampling a few rows twice) so every batch fits
    /// the static shape of the compiled train-step artifact.
    pub fn minibatch_indices(&self, mb: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
        assert!(mb > 0);
        let n = self.len();
        if n == 0 {
            return Vec::new();
        }
        let perm = rng.permutation(n);
        let n_batches = n.div_ceil(mb);
        let mut out = Vec::with_capacity(n_batches);
        for b in 0..n_batches {
            let mut idx = Vec::with_capacity(mb);
            for k in 0..mb {
                idx.push(perm[(b * mb + k) % n]);
            }
            out.push(idx);
        }
        out
    }

    /// Gather one minibatch into dense arrays (obs, act, logp, adv, ret).
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let f = self.features;
        let mut obs = Vec::with_capacity(idx.len() * f);
        let mut act = Vec::with_capacity(idx.len());
        let mut logp = Vec::with_capacity(idx.len());
        let mut adv = Vec::with_capacity(idx.len());
        let mut ret = Vec::with_capacity(idx.len());
        for &i in idx {
            obs.extend_from_slice(&self.obs[i * f..(i + 1) * f]);
            act.push(self.act[i]);
            logp.push(self.logp[i]);
            adv.push(self.adv[i]);
            ret.push(self.ret[i]);
        }
        (obs, act, logp, adv, ret)
    }
}

/// Flatten a set of episodes into a dataset with GAE(lambda) advantages
/// (normalized) and discounted returns as critic targets.
pub fn flatten(episodes: &[Episode], features: usize, gamma: f64, lambda: f64) -> Dataset {
    let mut ds = Dataset {
        features,
        ..Default::default()
    };
    for ep in episodes {
        let t_max = ep.steps.len();
        if t_max == 0 {
            continue;
        }
        let n_elems = ep.steps[0].act.len();
        // Per-element backward pass: returns and GAE.
        let mut ret_t = vec![0.0f64; n_elems]; // R_{t} accumulator
        let mut gae_t = vec![0.0f64; n_elems];
        let mut rows: Vec<(usize, Vec<f32>, Vec<f32>)> = Vec::with_capacity(t_max);
        for t in (0..t_max).rev() {
            let s = &ep.steps[t];
            let v_next: Vec<f64> = if t + 1 < t_max {
                ep.steps[t + 1].value.iter().map(|&v| v as f64).collect()
            } else {
                vec![0.0; n_elems] // terminal bootstrap = 0 (finite episode)
            };
            let mut ret_row = vec![0f32; n_elems];
            let mut adv_row = vec![0f32; n_elems];
            for e in 0..n_elems {
                ret_t[e] = s.reward + gamma * ret_t[e];
                let delta = s.reward + gamma * v_next[e] - s.value[e] as f64;
                gae_t[e] = delta + gamma * lambda * gae_t[e];
                ret_row[e] = ret_t[e] as f32;
                adv_row[e] = gae_t[e] as f32;
            }
            rows.push((t, ret_row, adv_row));
        }
        rows.reverse();
        for (t, ret_row, adv_row) in rows {
            let s = &ep.steps[t];
            ds.obs.extend_from_slice(&s.obs);
            ds.act.extend_from_slice(&s.act);
            ds.logp.extend_from_slice(&s.logp);
            ds.ret.extend_from_slice(&ret_row);
            ds.adv.extend_from_slice(&adv_row);
        }
    }
    // Advantage normalization (standard PPO practice).
    if !ds.adv.is_empty() {
        let advs: Vec<f64> = ds.adv.iter().map(|&a| a as f64).collect();
        let m = stats::mean(&advs);
        let sd = stats::std_dev(&advs).max(1e-8);
        for a in ds.adv.iter_mut() {
            *a = ((*a as f64 - m) / sd) as f32;
        }
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn episode(rewards: &[f64], values: &[f32], n_elems: usize, feat: usize) -> Episode {
        Episode {
            steps: rewards
                .iter()
                .zip(values)
                .map(|(&r, &v)| StepRecord {
                    obs: vec![0.5; n_elems * feat].into(),
                    act: vec![0.1; n_elems].into(),
                    logp: vec![-1.0; n_elems],
                    value: vec![v; n_elems],
                    reward: r,
                })
                .collect(),
            ..Episode::default()
        }
    }

    #[test]
    fn discounted_return_hand_computed() {
        let ep = episode(&[1.0, 0.5, -0.25], &[0.0; 3], 2, 4);
        let g: f64 = 0.9;
        let want = g * 1.0 + g * g * 0.5 + g * g * g * (-0.25);
        assert!((ep.discounted_return(g) - want).abs() < 1e-12);
    }

    #[test]
    fn returns_per_step_decay_correctly() {
        let ep = episode(&[1.0, 1.0], &[0.0, 0.0], 1, 2);
        let ds = flatten(&[ep], 2, 0.5, 1.0);
        // Step 0 return: 1 + 0.5*1 = 1.5; step 1: 1.0
        assert_eq!(ds.len(), 2);
        assert!((ds.ret[0] - 1.5).abs() < 1e-6);
        assert!((ds.ret[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gae_lambda1_equals_return_minus_value() {
        let ep = episode(&[1.0, -0.5, 0.25], &[0.3, -0.1, 0.2], 3, 2);
        let g = 0.95;
        let ds = flatten(&[ep.clone()], 2, g, 1.0);
        // Un-normalize by recomputing mean/std from raw values.
        let raw: Vec<f64> = {
            let mut raws = Vec::new();
            let t_max = 3;
            for t in 0..t_max {
                let mut ret = 0.0;
                for (k, s) in ep.steps[t..].iter().enumerate() {
                    ret += g.powi(k as i32) * s.reward;
                }
                for e in 0..3 {
                    raws.push(ret - ep.steps[t].value[e] as f64);
                }
            }
            raws
        };
        let m = crate::util::stats::mean(&raw);
        let sd = crate::util::stats::std_dev(&raw).max(1e-8);
        for (i, &r) in raw.iter().enumerate() {
            let want = ((r - m) / sd) as f32;
            assert!(
                (ds.adv[i] - want).abs() < 1e-4,
                "sample {i}: {} vs {want}",
                ds.adv[i]
            );
        }
    }

    #[test]
    fn advantages_are_normalized() {
        let eps: Vec<Episode> = (0..4)
            .map(|i| episode(&[i as f64, 1.0 - i as f64], &[0.1, 0.2], 2, 3))
            .collect();
        let ds = flatten(&eps, 3, 0.99, 0.95);
        let advs: Vec<f64> = ds.adv.iter().map(|&a| a as f64).collect();
        assert!(stats::mean(&advs).abs() < 1e-5);
        assert!((stats::std_dev(&advs) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn minibatches_cover_everything_with_wraparound() {
        let ep = episode(&[1.0; 7], &[0.0; 7], 3, 2);
        let ds = flatten(&[ep], 2, 0.99, 1.0);
        assert_eq!(ds.len(), 21);
        let mut rng = Rng::new(5);
        let batches = ds.minibatch_indices(8, &mut rng);
        assert_eq!(batches.len(), 3); // ceil(21/8)
        assert!(batches.iter().all(|b| b.len() == 8));
        let mut seen = vec![false; 21];
        for b in &batches {
            for &i in b {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some samples never visited");
    }

    #[test]
    fn gather_shapes() {
        let ep = episode(&[1.0, 2.0], &[0.0, 0.0], 2, 3);
        let ds = flatten(&[ep], 3, 0.9, 1.0);
        let (obs, act, logp, adv, ret) = ds.gather(&[0, 3, 1]);
        assert_eq!(obs.len(), 9);
        assert_eq!(act.len(), 3);
        assert_eq!(logp.len(), 3);
        assert_eq!(adv.len(), 3);
        assert_eq!(ret.len(), 3);
    }

    #[test]
    fn empty_dataset_behaves() {
        let ds = flatten(&[], 4, 0.99, 1.0);
        assert!(ds.is_empty());
        let mut rng = Rng::new(1);
        assert!(ds.minibatch_indices(8, &mut rng).is_empty());
    }
}
