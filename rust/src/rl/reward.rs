//! Reward shaping, Eqs. (4)–(5) of the paper.
//!
//! Note (also in DESIGN.md §1): the paper prints `r = 2 e^{(l/alpha)} - 1`,
//! which is unbounded and *increases* with the spectrum error; the stated
//! normalization `r in [-1, 1]` implies the intended sign `r = 2
//! e^{-l/alpha} - 1`, which we implement: zero spectrum error gives reward
//! 1, large errors approach -1.

/// Map the mean relative spectrum error `l` (Eq. 4) to a reward in
/// `(-1, 1]` with scaling factor `alpha` (Table 1: 0.4 / 0.2).
pub fn reward_from_error(l: f64, alpha: f64) -> f64 {
    debug_assert!(l >= 0.0, "spectrum error must be non-negative, got {l}");
    debug_assert!(alpha > 0.0);
    2.0 * (-l / alpha).exp() - 1.0
}

/// Maximum achievable return for an episode of `n` steps (used to report
/// the normalized return of Fig. 5).
pub fn max_return(n_steps: usize, gamma: f64) -> f64 {
    // r = 1 every step, discounted as in Eq. (2).
    (1..=n_steps).map(|t| gamma.powi(t as i32)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_spectrum_gives_reward_one() {
        assert!((reward_from_error(0.0, 0.4) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn large_error_approaches_minus_one() {
        assert!(reward_from_error(100.0, 0.4) >= -1.0);
        assert!(reward_from_error(100.0, 0.4) < -0.999);
        assert!(reward_from_error(2.0, 0.4) > -1.0);
    }

    #[test]
    fn monotone_decreasing_in_error() {
        let mut last = f64::INFINITY;
        for i in 0..20 {
            let r = reward_from_error(i as f64 * 0.1, 0.4);
            assert!(r < last);
            last = r;
        }
    }

    #[test]
    fn alpha_scales_tolerance() {
        // Larger alpha forgives larger errors (Table 1: 24 DOF uses 0.4,
        // the better-resolved 32 DOF case uses the stricter 0.2).
        assert!(reward_from_error(0.2, 0.4) > reward_from_error(0.2, 0.2));
    }

    #[test]
    fn max_return_matches_geometric_sum() {
        let g: f64 = 0.995;
        let want = g * (1.0 - g.powi(50)) / (1.0 - g);
        assert!((max_return(50, g) - want).abs() < 1e-9);
    }
}
