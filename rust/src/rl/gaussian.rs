//! Gaussian policy head: action sampling and log-densities on the Rust
//! side, numerically identical to the JAX `gaussian_logp` inside the
//! train-step artifact (same formula, f32-compatible magnitudes).

use crate::util::Rng;

/// ln(2*pi)/2, the normalization constant of the standard normal (shared
/// with the native trainer's PPO loss so both sides of the exchange use
/// one definition).
pub const HALF_LN_2PI: f64 = 0.918_938_533_204_672_7;

/// Sample `a ~ N(mean, exp(log_std))` per element.
pub fn sample(mean: &[f32], log_std: f32, rng: &mut Rng) -> Vec<f32> {
    let mut out = vec![0f32; mean.len()];
    sample_into(mean, log_std, rng, &mut out);
    out
}

/// [`sample`] into a caller-owned buffer (identical RNG consumption) —
/// the allocation-free path for the collector's recycled action buffers.
pub fn sample_into(mean: &[f32], log_std: f32, rng: &mut Rng, out: &mut [f32]) {
    debug_assert_eq!(mean.len(), out.len());
    let sigma = (log_std as f64).exp();
    for (o, &m) in out.iter_mut().zip(mean) {
        *o = (m as f64 + sigma * rng.normal()) as f32;
    }
}

/// Elementwise log density of `act` under `N(mean, exp(log_std))`.
pub fn log_prob(act: &[f32], mean: &[f32], log_std: f32) -> Vec<f32> {
    debug_assert_eq!(act.len(), mean.len());
    let ls = log_std as f64;
    let sigma = ls.exp();
    act.iter()
        .zip(mean)
        .map(|(&a, &m)| {
            let z = (a as f64 - m as f64) / sigma;
            (-0.5 * z * z - ls - HALF_LN_2PI) as f32
        })
        .collect()
}

/// Entropy of the diagonal Gaussian (per element).
pub fn entropy(log_std: f32) -> f64 {
    0.5 + HALF_LN_2PI + log_std as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_prob_matches_closed_form() {
        let lp = log_prob(&[0.3], &[0.25], -3.0)[0] as f64;
        let sigma = (-3.0f64).exp();
        let want = -0.5 * ((0.3 - 0.25) / sigma).powi(2) + 3.0 - HALF_LN_2PI;
        assert!((lp - want).abs() < 1e-5, "{lp} vs {want}");
    }

    #[test]
    fn sample_statistics() {
        let mut rng = Rng::new(7);
        let mean = vec![0.25f32; 20_000];
        let acts = sample(&mean, (0.05f64).ln() as f32, &mut rng);
        let m: f64 = acts.iter().map(|&a| a as f64).sum::<f64>() / acts.len() as f64;
        let v: f64 = acts.iter().map(|&a| (a as f64 - m).powi(2)).sum::<f64>()
            / acts.len() as f64;
        assert!((m - 0.25).abs() < 2e-3, "mean={m}");
        assert!((v.sqrt() - 0.05).abs() < 2e-3, "std={}", v.sqrt());
    }

    #[test]
    fn log_prob_peaks_at_mean() {
        let lp_at_mean = log_prob(&[0.2], &[0.2], -2.0)[0];
        let lp_off = log_prob(&[0.3], &[0.2], -2.0)[0];
        assert!(lp_at_mean > lp_off);
    }

    #[test]
    fn entropy_grows_with_sigma() {
        assert!(entropy(-1.0) > entropy(-2.0));
        // sigma = 0.05 (the init): H = 0.5 + 0.5 ln(2 pi) + ln 0.05
        let want = 0.5 + HALF_LN_2PI + (0.05f64).ln();
        assert!((entropy((0.05f64).ln() as f32) - want).abs() < 1e-6);
    }
}
