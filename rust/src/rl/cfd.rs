//! The solver-agnostic environment backend layer.
//!
//! The paper stresses that Relexi is "built with modularity in mind and
//! allows easy integration of various HPC solvers"; this module is that
//! seam in our stack.  Everything above it — the worker pool, both
//! rollout collectors, evaluation, trajectory recording — runs over
//! [`CfdEnv`] trait objects and never names a concrete solver.  A
//! backend contributes two pieces:
//!
//! * [`CfdEnv`] — one environment instance.  Backends implement only the
//!   in-place core (`reset_in_place` / `observe_into` plus `step` and the
//!   shape/horizon accessors); the allocating `reset`/`observe`
//!   conveniences are trait-provided defaults over that core, so the
//!   zero-allocation exchange path is the primary API, not a bolt-on.
//! * [`CfdBackend`] — the per-run factory.  It owns whatever is shared
//!   across a pool (the LES backend: one `Arc<Grid>` so every worker
//!   reuses one FFT plan, plus the DNS truth package; the Burgers
//!   backend: the resolved-truth spectrum) and builds one env per
//!   resolved scenario variant.
//!
//! Backends register in [`backend_from_config`], keyed by the
//! `rl.backend` config field (see [`crate::config::BACKENDS`]).  The
//! observation layout contract is the element-local one the policy
//! machinery assumes: `obs_len = n_agents * features`, one action per
//! agent per step, every env in a pool sharing one `(obs_len, n_agents,
//! features)` shape so partial batches concatenate.

use super::burgers::BurgersBackend;
use super::env::{LesEnv, StepOut};
use crate::config::{ResolvedVariant, RunConfig};
use crate::solver::dns::Truth;
use crate::solver::Grid;
use crate::util::Rng;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// One CFD environment behind the solver-agnostic rollout stack: an
/// episodic control task whose state is a flow field observed
/// agent-locally and whose action is one scalar per agent per RL step.
///
/// Implementors provide the in-place core; `reset`/`observe` are derived.
pub trait CfdEnv: Send {
    /// Reset to a fresh initial state (a random truth-pool draw, or the
    /// held-out test state when `test`) without materializing the
    /// observation.  Test resets must not consume `rng` draws, so
    /// deterministic evaluation stays deterministic.
    fn reset_in_place(&mut self, rng: &mut Rng, test: bool);

    /// Apply one action per agent and advance one RL interval.
    fn step(&mut self, actions: &[f64]) -> StepOut;

    /// Write the current observation into a caller-owned buffer of
    /// exactly [`CfdEnv::obs_len`] floats (no allocation).
    fn observe_into(&mut self, out: &mut [f32]);

    /// Observation length in floats (= `n_agents * features`).
    fn obs_len(&self) -> usize;

    /// Agents = actions per step (the LES backend: DG elements).
    fn n_agents(&self) -> usize;

    /// Actions per episode (the RL horizon).
    fn n_actions(&self) -> usize;

    /// Current energy spectrum (diagnostics / Fig. 5 evaluation).
    fn spectrum(&self) -> Vec<f64>;

    /// The truth spectrum this env is rewarded against.
    fn target_spectrum(&self) -> &[f64];

    /// Restrict initial-state draws to one family of the truth pool
    /// (indices ≡ `family` mod `n_families`); errors if that family is
    /// empty for this backend's pool.
    fn set_init_family(&mut self, family: usize, n_families: usize) -> Result<()>;

    /// Reset and return the initial observation (allocating convenience,
    /// derived from the in-place core).
    fn reset(&mut self, rng: &mut Rng, test: bool) -> Vec<f32> {
        self.reset_in_place(rng, test);
        self.observe()
    }

    /// Current observation as a fresh vector (allocating convenience,
    /// derived from the in-place core).
    fn observe(&mut self) -> Vec<f32> {
        let mut out = vec![0f32; self.obs_len()];
        self.observe_into(&mut out);
        out
    }
}

/// Validate an init-family restriction against a truth pool of
/// `pool_len` states — shared by every backend's
/// [`CfdEnv::set_init_family`].
pub(crate) fn validate_init_family(
    pool_len: usize,
    family: usize,
    n_families: usize,
) -> Result<()> {
    anyhow::ensure!(n_families >= 1 && family < n_families);
    anyhow::ensure!(
        pool_len > family,
        "init family {family}/{n_families} is empty: truth pool has only {pool_len} states"
    );
    Ok(())
}

/// Draw an initial-state pool index: uniform over the whole pool, or —
/// with an init family set — uniform over the indices ≡ `family`
/// (mod `n_families`).  Exactly one RNG draw either way, so the
/// consumption pattern is family-independent.
pub(crate) fn draw_pool_index(
    pool_len: usize,
    init_family: Option<(usize, usize)>,
    rng: &mut Rng,
) -> usize {
    match init_family {
        Some((family, m)) => {
            let count = (pool_len + m - 1 - family) / m; // #indices ≡ family (mod m)
            family + rng.below(count) * m
        }
        None => rng.below(pool_len),
    }
}

/// Per-run environment factory: owns the state shared across a pool and
/// builds one [`CfdEnv`] per resolved scenario variant.
pub trait CfdBackend: Send + Sync {
    /// Registry name (`rl.backend` value).
    fn name(&self) -> &str;

    /// Build one environment for a resolved variant, applying its
    /// init-family restriction if set.
    fn make_env(&self, rv: &ResolvedVariant) -> Result<Box<dyn CfdEnv>>;

    /// Backend-internal counters for the end-of-run telemetry summary
    /// (e.g. the Burgers wave-batcher's wave/env counts).  Empty by
    /// default: most backends have nothing run-wide to report.
    fn batch_stats(&self) -> Vec<(&'static str, u64)> {
        Vec::new()
    }
}

/// The paper's 3D spectral HIT case as a backend: one shared `Arc<Grid>`
/// (every worker reuses one FFT plan) plus the filtered-DNS truth
/// package.
pub struct LesBackend {
    truth: Arc<Truth>,
    grid: Arc<Grid>,
}

impl LesBackend {
    /// Build the shared grid for the run's case; envs are cut from it in
    /// [`CfdBackend::make_env`].
    pub fn new(cfg: &RunConfig, truth: Arc<Truth>) -> Result<LesBackend> {
        anyhow::ensure!(
            truth.n_les == cfg.case.points_per_dir(),
            "truth built for n={}, case needs n={}",
            truth.n_les,
            cfg.case.points_per_dir()
        );
        Ok(LesBackend {
            truth,
            grid: Arc::new(Grid::new(cfg.case.points_per_dir())),
        })
    }

    /// The spectral grid shared by every env this backend builds.
    pub fn grid(&self) -> Arc<Grid> {
        self.grid.clone()
    }
}

impl CfdBackend for LesBackend {
    fn name(&self) -> &str {
        "les"
    }

    fn make_env(&self, rv: &ResolvedVariant) -> Result<Box<dyn CfdEnv>> {
        let mut env =
            LesEnv::with_grid(&rv.case, &rv.solver, self.truth.clone(), self.grid.clone())
                .with_context(|| format!("les env (variant {})", rv.name))?;
        if let Some((family, m)) = rv.init_family {
            env.set_init_family(family, m)
                .with_context(|| format!("les env (variant {})", rv.name))?;
        }
        Ok(Box::new(env))
    }
}

/// Resolve `rl.backend` to a backend instance.  The LES backend needs
/// the caller-generated DNS `truth`; the Burgers backend generates its
/// own resolved truth from `cfg.burgers` and ignores the argument.
pub fn backend_from_config(
    cfg: &RunConfig,
    truth: Option<Arc<Truth>>,
) -> Result<Arc<dyn CfdBackend>> {
    match cfg.rl.backend.as_str() {
        "les" => {
            let truth = truth.context("rl.backend = \"les\" needs a DNS truth package")?;
            Ok(Arc::new(LesBackend::new(cfg, truth)?))
        }
        "burgers" => Ok(Arc::new(BurgersBackend::new(&cfg.burgers)?)),
        other => bail!(
            "unknown rl.backend {other:?} (expected one of {:?})",
            crate::config::BACKENDS
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::env::tests::tiny_setup;

    #[test]
    fn les_backend_shares_one_grid_and_applies_variants() {
        let (case, scfg, truth) = tiny_setup();
        let mut cfg = RunConfig::default();
        cfg.case = case;
        cfg.solver = scfg;
        let backend = LesBackend::new(&cfg, truth).unwrap();
        assert_eq!(backend.name(), "les");
        let g = backend.grid();
        let mut env = backend.make_env(&cfg.base_resolved()).unwrap();
        assert_eq!(env.n_agents(), 8);
        assert_eq!(env.obs_len(), 8 * 6 * 6 * 6 * 3);
        let mut rng = Rng::new(3);
        let obs = env.reset(&mut rng, false);
        assert_eq!(obs.len(), env.obs_len());
        assert!(Arc::strong_count(&g) >= 2, "env must reuse the shared grid");
    }

    #[test]
    fn registry_covers_every_declared_backend() {
        // `config::BACKENDS` (what validation accepts) and the registry
        // match arms must stay in sync: every declared name resolves to
        // a backend answering to that name.  Adding a name to one side
        // without the other fails here.
        let (case, scfg, truth) = tiny_setup();
        for &name in crate::config::BACKENDS {
            let mut cfg = RunConfig::default();
            cfg.rl.backend = name.to_string();
            cfg.case = case.clone();
            cfg.solver = scfg.clone();
            cfg.burgers.points = 32;
            cfg.burgers.segments = 4;
            cfg.burgers.k_max = 6;
            cfg.burgers.truth_states = 2;
            cfg.burgers.truth_spinup = 0.5;
            cfg.burgers.truth_interval = 0.2;
            cfg.validate().unwrap();
            let b = backend_from_config(&cfg, Some(truth.clone()))
                .unwrap_or_else(|e| panic!("declared backend {name:?} failed to resolve: {e:#}"));
            assert_eq!(b.name(), name);
        }
        // Unknown names bail at resolution too (validation rejects them
        // earlier on config paths).
        let mut cfg = RunConfig::default();
        cfg.rl.backend = "flexi".to_string();
        assert!(backend_from_config(&cfg, None).is_err());
    }

    #[test]
    fn les_registry_path_requires_truth() {
        let (case, scfg, truth) = tiny_setup();
        let mut cfg = RunConfig::default();
        cfg.case = case;
        cfg.solver = scfg;
        assert!(backend_from_config(&cfg, None).is_err(), "les needs truth");
        let b = backend_from_config(&cfg, Some(truth)).unwrap();
        assert_eq!(b.name(), "les");
    }

    #[test]
    fn mismatched_truth_rejected_at_backend_construction() {
        let (_case, scfg, truth) = tiny_setup();
        let mut cfg = RunConfig::default();
        cfg.solver = scfg; // default case is 24^3, truth is 12^3
        assert!(LesBackend::new(&cfg, truth).is_err());
    }
}
