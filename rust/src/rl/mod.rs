//! RL machinery (DESIGN.md S11): the solver-agnostic environment backend
//! layer ([`cfd`]), its two in-tree backends (the paper's 3D spectral
//! LES in [`env`], the 1D stochastic-Burgers testbed in [`burgers`]),
//! the Gaussian policy head, reward shaping (Eqs. 4–5), and
//! trajectory/advantage processing for the clipping-PPO algorithm of
//! paper §5.3.

pub mod burgers;
pub mod cfd;
pub mod env;
pub mod gaussian;
pub mod reward;
pub mod trajectory;

pub use burgers::{BatchCounters, BurgersBackend, BurgersBatch, BurgersEnv, BurgersTruth};
pub use cfd::{backend_from_config, CfdBackend, CfdEnv, LesBackend};
pub use env::{LesEnv, StepOut};
pub use reward::{max_return, reward_from_error};
pub use trajectory::{flatten, Dataset, Episode, StepRecord};
