//! RL machinery (DESIGN.md S11): the LES environment, the Gaussian policy
//! head, reward shaping (Eqs. 4–5), and trajectory/advantage processing
//! for the clipping-PPO algorithm of paper §5.3.

pub mod env;
pub mod gaussian;
pub mod reward;
pub mod trajectory;

pub use env::{LesEnv, StepOut};
pub use reward::{max_return, reward_from_error};
pub use trajectory::{flatten, Dataset, Episode, StepRecord};
