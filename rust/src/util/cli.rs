//! Minimal CLI argument parser (no `clap` in the image's crate set).
//!
//! Supports `program <subcommand> [--flag] [--key value] [--key=value]
//! [positional...]` with typed accessors and generated usage text.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Parsed command line: subcommand, options, flags, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// Is a boolean flag present? (also true for `--flag=true`)
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.opts.get(name).map(|v| v == "true").unwrap_or(false)
    }

    /// String option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Required string option.
    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow!("--{name}={v}: {e}")),
        }
    }

    /// All `--key value` pairs (used to overlay onto a Config).
    pub fn overrides(&self) -> impl Iterator<Item = (&String, &String)> {
        self.opts.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        // NOTE: bare flags bind a following non-`--` token as their value,
        // so pass booleans last or as `--flag=true`.
        let a = parse("train run1 --envs 16 --config=hit24.toml --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("envs"), Some("16"));
        assert_eq!(a.get("config"), Some("hit24.toml"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["run1"]);
    }

    #[test]
    fn flag_equals_true_form() {
        let a = parse("x --verbose=true pos");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos"]);
    }

    #[test]
    fn typed_access() {
        let a = parse("x --n 7 --lr 1e-4");
        assert_eq!(a.get_parse("n", 5usize).unwrap(), 7);
        assert_eq!(a.get_parse("lr", 0.0f64).unwrap(), 1e-4);
        assert_eq!(a.get_parse("missing", 3usize).unwrap(), 3);
        assert!(a.get_parse("n", 0.0f64).is_ok());
        assert!(Args::parse(["x".into(), "--n".into(), "abc".into()])
            .unwrap()
            .get_parse("n", 0usize)
            .is_err());
    }

    #[test]
    fn flag_at_end() {
        let a = parse("bench --quick");
        assert!(a.flag("quick"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn require_missing_errors() {
        let a = parse("run");
        assert!(a.require("out").is_err());
    }
}
