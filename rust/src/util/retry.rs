//! Shared retry policy: capped exponential backoff with deterministic
//! jitter, bounded both by an attempt budget and a wall-clock deadline.
//!
//! Every reconnect path in the runtime (worker dial, trainer-side RPC
//! re-dial, shm bootstrap) routes through one [`RetryPolicy`] so the
//! backoff shape is a single tunable, and failures surface as a
//! structured [`RetryError`] — attempts made, elapsed wall clock, last
//! underlying error — instead of the last error alone.
//!
//! Jitter is deterministic (an xorshift64* stream seeded per policy):
//! retries never synchronise across a worker fleet, yet a given policy
//! replays the same delay sequence run after run, which keeps the
//! fault-injection tests reproducible.

use std::fmt;
use std::time::{Duration, Instant};

/// Capped exponential backoff: attempt `k` sleeps
/// `min(cap, base * 2^k) * U` where `U` is a deterministic jitter
/// factor in `[0.5, 1.0)`.  The loop stops at `max_attempts` tries or
/// when the next sleep would overrun `deadline`, whichever comes first.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// First-retry backoff (attempt 0 -> 1 sleeps ~`base`).
    pub base: Duration,
    /// Ceiling on a single backoff sleep before jitter.
    pub cap: Duration,
    /// Total tries, including the first (`>= 1`).
    pub max_attempts: u32,
    /// Wall-clock budget across all tries and sleeps.
    pub deadline: Duration,
    /// Seed for the deterministic jitter stream.
    pub jitter_seed: u64,
}

impl RetryPolicy {
    pub fn new(base: Duration, cap: Duration, max_attempts: u32, deadline: Duration) -> Self {
        RetryPolicy {
            base,
            cap,
            max_attempts: max_attempts.max(1),
            deadline,
            jitter_seed: 0x5EED_0F_D1A1,
        }
    }

    /// The dial policy used by workers and trainer-side re-dials:
    /// `connect_retries` extra tries after the first, 100 ms doubling
    /// backoff capped at 2 s, all inside a 15 s deadline — the bound the
    /// orphaned-worker teardown tests rely on.
    pub fn dial(connect_retries: u32) -> Self {
        RetryPolicy::new(
            Duration::from_millis(100),
            Duration::from_secs(2),
            connect_retries.saturating_add(1),
            Duration::from_secs(15),
        )
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.jitter_seed = seed;
        self
    }

    /// Backoff for the sleep after attempt `attempt` (0-based), with the
    /// jitter stream threaded through `state`.
    fn delay_for(&self, attempt: u32, state: &mut u64) -> Duration {
        let exp = self
            .base
            .saturating_mul(1u32 << attempt.min(16))
            .min(self.cap);
        // xorshift64* step; state is kept non-zero by the caller.
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        let frac = (x >> 11) as f64 / (1u64 << 53) as f64;
        exp.mul_f64(0.5 + 0.5 * frac)
    }

    /// Run `op` until it succeeds or the policy is exhausted.  `op`
    /// receives the 0-based attempt index.  On exhaustion the error
    /// carries the attempt count, the elapsed wall clock and the last
    /// underlying error (flattened with its context chain).
    pub fn run<T>(
        &self,
        what: &str,
        mut op: impl FnMut(u32) -> anyhow::Result<T>,
    ) -> Result<T, RetryError> {
        let start = Instant::now();
        let mut state = self.jitter_seed | 1;
        let mut last: Option<anyhow::Error> = None;
        let mut attempts = 0u32;
        while attempts < self.max_attempts {
            match op(attempts) {
                Ok(v) => return Ok(v),
                Err(e) => last = Some(e),
            }
            attempts += 1;
            if attempts >= self.max_attempts {
                break;
            }
            let delay = self.delay_for(attempts - 1, &mut state);
            if start.elapsed() + delay > self.deadline {
                break;
            }
            std::thread::sleep(delay);
        }
        Err(RetryError {
            what: what.to_string(),
            attempts,
            elapsed: start.elapsed(),
            last: last.map(|e| format!("{e:#}")).unwrap_or_default(),
        })
    }
}

/// Structured retry failure: what was being attempted, how many tries
/// were made, how long they took, and the last underlying error.
#[derive(Debug)]
pub struct RetryError {
    pub what: String,
    pub attempts: u32,
    pub elapsed: Duration,
    pub last: String,
}

impl fmt::Display for RetryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} failed after {} attempt{} over {:.3} s (last error: {})",
            self.what,
            self.attempts,
            if self.attempts == 1 { "" } else { "s" },
            self.elapsed.as_secs_f64(),
            if self.last.is_empty() { "none" } else { &self.last },
        )
    }
}

// `std::error::Error` (not `anyhow`-native) so the vendored blanket
// `From<E: Error + Send + Sync>` converts it with `?` at call sites.
impl std::error::Error for RetryError {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    fn fast(max_attempts: u32) -> RetryPolicy {
        RetryPolicy::new(
            Duration::from_millis(1),
            Duration::from_millis(4),
            max_attempts,
            Duration::from_secs(5),
        )
    }

    #[test]
    fn first_success_makes_one_attempt() {
        let calls = AtomicU32::new(0);
        let got = fast(5)
            .run("op", |_| {
                calls.fetch_add(1, Ordering::Relaxed);
                Ok(7u32)
            })
            .unwrap();
        assert_eq!(got, 7);
        assert_eq!(calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn exhaustion_reports_attempts_and_last_error() {
        let calls = AtomicU32::new(0);
        let err = fast(3)
            .run::<u32>("dial exchange", |k| {
                assert_eq!(k, calls.fetch_add(1, Ordering::Relaxed));
                anyhow::bail!("refused #{k}")
            })
            .unwrap_err();
        assert_eq!(calls.load(Ordering::Relaxed), 3);
        assert_eq!(err.attempts, 3);
        assert_eq!(err.last, "refused #2");
        let msg = format!("{err}");
        assert!(msg.contains("dial exchange"), "message: {msg}");
        assert!(msg.contains("3 attempts"), "message: {msg}");
        assert!(msg.contains("refused #2"), "message: {msg}");
    }

    #[test]
    fn succeeds_midway_after_transient_failures() {
        let calls = AtomicU32::new(0);
        let got = fast(5)
            .run("op", |k| {
                calls.fetch_add(1, Ordering::Relaxed);
                if k < 2 {
                    anyhow::bail!("transient")
                }
                Ok(k)
            })
            .unwrap();
        assert_eq!(got, 2);
        assert_eq!(calls.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn deadline_stops_the_loop_early() {
        let policy = RetryPolicy::new(
            Duration::from_millis(50),
            Duration::from_millis(50),
            1000,
            Duration::from_millis(120),
        );
        let start = Instant::now();
        let err = policy
            .run::<()>("op", |_| anyhow::bail!("down"))
            .unwrap_err();
        assert!(err.attempts < 1000, "deadline must cut the budget short");
        assert!(
            start.elapsed() < Duration::from_secs(2),
            "loop ran far past its deadline"
        );
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let p = fast(8).with_seed(42);
        let mut s1 = p.jitter_seed | 1;
        let mut s2 = p.jitter_seed | 1;
        for attempt in 0..8 {
            let a = p.delay_for(attempt, &mut s1);
            let b = p.delay_for(attempt, &mut s2);
            assert_eq!(a, b, "same seed must replay the same delays");
            let exp = p.base.saturating_mul(1 << attempt.min(16)).min(p.cap);
            assert!(a >= exp.mul_f64(0.5) && a <= exp, "attempt {attempt}: {a:?} vs {exp:?}");
        }
    }

    #[test]
    fn backoff_growth_is_capped() {
        let p = RetryPolicy::new(
            Duration::from_millis(100),
            Duration::from_secs(2),
            10,
            Duration::from_secs(60),
        );
        let mut s = p.jitter_seed | 1;
        // Attempt 10 uncapped would be 102.4 s; the cap holds it at 2 s.
        let d = p.delay_for(10, &mut s);
        assert!(d <= Duration::from_secs(2));
        assert!(d >= Duration::from_secs(1));
    }

    #[test]
    fn retry_error_converts_into_anyhow() {
        fn inner() -> anyhow::Result<()> {
            Err(fast(1).run::<()>("op", |_| anyhow::bail!("boom")).unwrap_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e:#}").contains("boom"));
    }
}
