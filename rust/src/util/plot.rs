//! Terminal line plots for the paper's figures: training-return curves
//! (Fig. 5a/b) and log-log energy spectra (Fig. 5c) without any plotting
//! dependency.  Multiple labelled series share one canvas.

/// One labelled data series.
pub struct Series {
    pub label: String,
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
}

impl Series {
    /// Build a series; x/y lengths must match.
    pub fn new(label: &str, xs: Vec<f64>, ys: Vec<f64>) -> Series {
        assert_eq!(xs.len(), ys.len(), "series {label}: x/y length mismatch");
        Series {
            label: label.to_string(),
            xs,
            ys,
        }
    }
}

/// Axis scaling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Linear,
    Log10,
}

fn tx(v: f64, scale: Scale) -> f64 {
    match scale {
        Scale::Linear => v,
        Scale::Log10 => v.max(1e-300).log10(),
    }
}

/// Render labelled series onto a `width x height` character canvas.
/// Each series gets a distinct glyph; a legend and axis ranges are
/// appended below the canvas.
pub fn render(
    title: &str,
    series: &[Series],
    width: usize,
    height: usize,
    xscale: Scale,
    yscale: Scale,
) -> String {
    const GLYPHS: [char; 6] = ['*', 'o', '+', 'x', '#', '@'];
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for s in series {
        for (&x, &y) in s.xs.iter().zip(&s.ys) {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let (px, py) = (tx(x, xscale), tx(y, yscale));
            xmin = xmin.min(px);
            xmax = xmax.max(px);
            ymin = ymin.min(py);
            ymax = ymax.max(py);
        }
    }
    if !xmin.is_finite() || xmax <= xmin {
        xmax = xmin + 1.0;
    }
    if !ymin.is_finite() || ymax <= ymin {
        ymax = ymin + 1.0;
    }

    let mut canvas = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let g = GLYPHS[si % GLYPHS.len()];
        for (&x, &y) in s.xs.iter().zip(&s.ys) {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let px = ((tx(x, xscale) - xmin) / (xmax - xmin) * (width - 1) as f64).round();
            let py = ((tx(y, yscale) - ymin) / (ymax - ymin) * (height - 1) as f64).round();
            let (cx, cy) = (px as usize, height - 1 - py as usize);
            if cx < width && cy < height {
                canvas[cy][cx] = g;
            }
        }
    }

    let fmt = |v: f64, scale: Scale| match scale {
        Scale::Linear => format!("{v:.3}"),
        Scale::Log10 => format!("1e{v:.1}"),
    };
    let mut out = format!("## {title}\n");
    for row in &canvas {
        out.push_str("  |");
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!(
        "  +{}\n   x: [{} .. {}]  y: [{} .. {}]\n",
        "-".repeat(width),
        fmt(xmin, xscale),
        fmt(xmax, xscale),
        fmt(ymin, yscale),
        fmt(ymax, yscale),
    ));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("   {} {}\n", GLYPHS[si % GLYPHS.len()], s.label));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_points_and_legend() {
        let s = Series::new("linear", vec![0.0, 1.0, 2.0], vec![0.0, 1.0, 2.0]);
        let r = render("t", &[s], 21, 7, Scale::Linear, Scale::Linear);
        assert!(r.contains("## t"));
        assert!(r.contains("* linear"));
        // Diagonal: first and last rows contain the glyph.
        let rows: Vec<&str> = r.lines().collect();
        assert!(rows[1].contains('*')); // top row = max y
        assert!(rows[7].contains('*')); // bottom row = min y
    }

    #[test]
    fn log_scale_compresses_decades() {
        let s = Series::new("spec", vec![1.0, 10.0, 100.0], vec![1.0, 0.01, 1e-4]);
        let r = render("spectrum", &[s], 30, 8, Scale::Log10, Scale::Log10);
        assert!(r.contains("1e0.0"));
        assert!(r.contains("1e-4.0"));
    }

    #[test]
    fn multiple_series_distinct_glyphs() {
        let a = Series::new("a", vec![0.0, 1.0], vec![0.0, 1.0]);
        let b = Series::new("b", vec![0.0, 1.0], vec![1.0, 0.0]);
        let r = render("two", &[a, b], 11, 5, Scale::Linear, Scale::Linear);
        assert!(r.contains("* a"));
        assert!(r.contains("o b"));
        assert!(r.contains('o'));
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        Series::new("bad", vec![0.0], vec![0.0, 1.0]);
    }

    #[test]
    fn degenerate_ranges_do_not_crash() {
        let s = Series::new("const", vec![1.0, 1.0], vec![2.0, 2.0]);
        let r = render("c", &[s], 10, 4, Scale::Linear, Scale::Linear);
        assert!(r.contains("const"));
        let empty = Series::new("e", vec![], vec![]);
        let r2 = render("e", &[empty], 10, 4, Scale::Linear, Scale::Linear);
        assert!(r2.contains("## e"));
    }
}
