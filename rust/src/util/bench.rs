//! Criterion-less benchmark harness (criterion is not in the image's crate
//! set).  Provides warmup + adaptive iteration timing with mean/std/median
//! reporting, and markdown table emission so each bench binary can print the
//! rows of the paper table/figure it regenerates (DESIGN.md §6).

use crate::util::stats;
use std::time::{Duration, Instant};

/// One measured benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub label: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub median_s: f64,
    pub min_s: f64,
}

impl Measurement {
    /// Throughput given a per-iteration item count.
    pub fn per_second(&self, items: f64) -> f64 {
        items / self.mean_s
    }
}

/// Benchmark runner: measures closures with warmup and repeated samples.
pub struct Bench {
    name: String,
    warmup: Duration,
    target: Duration,
    max_samples: usize,
    results: Vec<Measurement>,
}

impl Bench {
    /// A runner with defaults appropriate for sub-second cases.
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            warmup: Duration::from_millis(200),
            target: Duration::from_secs(1),
            max_samples: 50,
            results: Vec::new(),
        }
    }

    /// Override the total measurement budget per case.
    pub fn with_target(mut self, target: Duration) -> Self {
        self.target = target;
        self
    }

    /// Override warmup duration.
    pub fn with_warmup(mut self, warmup: Duration) -> Self {
        self.warmup = warmup;
        self
    }

    /// Limit sample count (for expensive cases).
    pub fn with_max_samples(mut self, n: usize) -> Self {
        self.max_samples = n;
        self
    }

    /// Measure `f`, which performs one logical iteration per call.
    pub fn run<F: FnMut()>(&mut self, label: &str, mut f: F) -> Measurement {
        // Warmup
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        // Estimate per-iter cost from warmup to size the sample count
        // (at least 3, unless the caller capped max_samples below that).
        let per_iter = (w0.elapsed().as_secs_f64() / warm_iters.max(1) as f64).max(1e-9);
        let samples = ((self.target.as_secs_f64() / per_iter) as usize)
            .clamp(self.max_samples.min(3), self.max_samples);

        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement {
            label: label.to_string(),
            iters: samples,
            mean_s: stats::mean(&times),
            std_s: stats::std_dev(&times),
            median_s: stats::median(&times),
            min_s: stats::min(&times),
        };
        println!(
            "[{}] {:<44} {:>12}  ±{:>10}  (n={})",
            self.name,
            m.label,
            fmt_duration(m.mean_s),
            fmt_duration(m.std_s),
            m.iters
        );
        self.results.push(m.clone());
        m
    }

    /// Record a case from externally collected per-event samples
    /// (seconds) instead of timing a closure — for benches whose numbers
    /// come from instrumentation rather than repetition (e.g. the
    /// supervision report's per-incident detect/recover splits).
    pub fn record(&mut self, label: &str, samples: &[f64]) -> Measurement {
        assert!(!samples.is_empty(), "record() needs at least one sample");
        let m = Measurement {
            label: label.to_string(),
            iters: samples.len(),
            mean_s: stats::mean(samples),
            std_s: stats::std_dev(samples),
            median_s: stats::median(samples),
            min_s: stats::min(samples),
        };
        println!(
            "[{}] {:<44} {:>12}  ±{:>10}  (n={})",
            self.name,
            m.label,
            fmt_duration(m.mean_s),
            fmt_duration(m.std_s),
            m.iters
        );
        self.results.push(m.clone());
        m
    }

    /// All measurements taken so far.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Write every measurement as machine-readable JSON (`BENCH_*.json`)
    /// so successive PRs can track the perf trajectory.  Hand-rolled
    /// serialization — no serde in the image.
    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(&self.name)));
        s.push_str("  \"results\": [\n");
        for (i, m) in self.results.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"label\": \"{}\", \"iters\": {}, \"mean_s\": {:e}, \
                 \"std_s\": {:e}, \"median_s\": {:e}, \"min_s\": {:e}}}{}\n",
                json_escape(&m.label),
                m.iters,
                m.mean_s,
                m.std_s,
                m.median_s,
                m.min_s,
                if i + 1 == self.results.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(path.as_ref(), s)
    }
}

/// Minimal JSON string escaping for bench labels.
fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => vec!['\\', '"'],
            '\\' => vec!['\\', '\\'],
            '\n' => vec!['\\', 'n'],
            c => vec![c],
        })
        .collect()
}

/// Human-friendly duration formatting.
pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Markdown table builder used by benches to print paper-figure rows.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column names.
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringified by the caller).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    /// Render as a markdown table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('|');
        for w in &width {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
        }
        out
    }

    /// Print to stdout with a title.
    pub fn print(&self, title: &str) {
        println!("\n## {title}\n\n{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("t")
            .with_warmup(Duration::from_millis(5))
            .with_target(Duration::from_millis(20));
        let m = b.run("noop-ish", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(m.mean_s > 0.0);
        assert!(m.iters >= 3);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn max_samples_below_three_does_not_panic() {
        let mut b = Bench::new("t")
            .with_warmup(Duration::from_millis(0))
            .with_target(Duration::from_millis(5))
            .with_max_samples(1);
        let m = b.run("single-sample", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(m.iters, 1);
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("| a | bb |"));
        assert!(r.contains("| 1 | 2  |"));
        assert!(r.lines().count() == 3);
    }

    #[test]
    fn write_json_emits_all_measurements() {
        let mut b = Bench::new("jsontest")
            .with_warmup(Duration::from_millis(1))
            .with_target(Duration::from_millis(5));
        b.run("case \"a\"", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        let dir = std::env::temp_dir().join("relexi_bench_json");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        b.write_json(&path).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.contains("\"bench\": \"jsontest\""));
        assert!(s.contains("case \\\"a\\\""));
        assert!(s.contains("\"mean_s\""));
    }

    #[test]
    fn fmt_duration_ranges() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(2e-3).ends_with(" ms"));
        assert!(fmt_duration(2e-6).ends_with(" µs"));
        assert!(fmt_duration(2e-9).ends_with(" ns"));
    }
}
