//! Run-wide telemetry: lock-free per-thread span/event rings, log-bucketed
//! latency histograms, a leveled `tlog!` logger, and a cross-process trace
//! merger that emits one Chrome-trace-event JSON (Perfetto-loadable) per run.
//!
//! Design constraints (PR 10):
//!
//! * **Near-zero cost when disabled.** Every recording entry point first does
//!   a single relaxed load of a global `AtomicBool`; when `[telemetry]
//!   enabled = false` no clock is read, no ring is touched, no allocation
//!   happens.  `span!` expands to an `Option<SpanGuard>` that is `None`.
//! * **Zero steady-state allocation when enabled.** A thread's ring buffer
//!   is allocated once, on that thread's first record (warm-up); span names
//!   are `&'static str`s interned once per *call site* through a per-site
//!   `static AtomicU32` cache, so the hot path writes one 32-byte POD record
//!   into a preallocated slot and bumps an atomic head.  The steady-state
//!   alloc gates therefore stay green with telemetry off AND on.
//! * **No external deps.** Wire format is hand-rolled little-endian (binio
//!   style); the trace/summary JSON is hand-written like `util::bench`.
//!
//! Concurrency: each ring has exactly one writer (its owning thread) and is
//! drained by the process's telemetry collector (trainer main thread, or the
//! env-worker control thread).  The drain uses the same seqlock discipline as
//! the store's waiter path: snapshot `head`, volatile-read the slots, re-read
//! `head`, and discard any record the writer may have overwritten mid-read.
//! Records are plain integers (names are interned ids, not pointers), so a
//! torn read is harmless garbage that the index check throws away.
//!
//! Cross-process story: env-worker processes record locally and ship their
//! rings over the store ctl plane (`__relexi:ctl:tel:wK`, exempt from the
//! `frames`/`batched_keys` accounting) when the trainer bumps the flush key
//! at iteration end.  The merger maps each worker's monotonic timestamps onto
//! the trainer's timeline using the wall-clock anchor captured at `init` and
//! clamps with the begin-key handshake (a worker cannot have *received* a
//! begin before the trainer *put* it), then writes all processes into a
//! single trace.

use std::cell::{OnceCell, UnsafeCell};
use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use anyhow::{bail, Result};

// ---------------------------------------------------------------------------
// Global state
// ---------------------------------------------------------------------------

/// Master switch for span/event/histogram recording.  One relaxed load on
/// every entry point; everything downstream is skipped when false.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Current log level for `tlog!` (independent of the tracing switch: logging
/// works even when tracing is off).
static LOG_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Ring capacity (records per thread), fixed at ring creation.
static RING_CAPACITY: AtomicUsize = AtomicUsize::new(65_536);

/// Monotonic epoch all of this process's timestamps are relative to.
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Wall-clock (unix µs) captured at the same moment as `EPOCH`; the coarse
/// cross-process alignment anchor.
static WALL_ANCHOR_US: AtomicU64 = AtomicU64::new(0);

/// Process label for logs and the merged trace ("trainer", "w3", ...).
static PROC_LABEL: OnceLock<String> = OnceLock::new();

/// Monotonic µs of the latest begin-key receipt (env workers only); ships in
/// the blob header as the causality clamp for clock alignment.
static BEGIN_RECV_US: AtomicU64 = AtomicU64::new(0);

/// Sequential thread ids for ring/trace labeling.
static NEXT_TID: AtomicU32 = AtomicU32::new(0);

/// Interned span/event names; a record stores `index + 1` (0 = unset).
static NAMES: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());

/// Every ring ever created in this process (rings outlive their threads).
static REGISTRY: Mutex<Vec<Arc<Ring>>> = Mutex::new(Vec::new());

/// Initialize telemetry for this process.  Idempotent on the label/epoch;
/// the switches are plain stores so tests may re-init.  `RELEXI_LOG`
/// overrides the configured log level when set to a valid level name.
pub fn init(enabled: bool, ring_capacity: usize, log_level: &str, proc_label: &str) {
    let level = match std::env::var("RELEXI_LOG") {
        Ok(v) => Level::parse(&v).or_else(|| Level::parse(log_level)),
        Err(_) => Level::parse(log_level),
    }
    .unwrap_or(Level::Info);
    LOG_LEVEL.store(level as u8, Ordering::Relaxed);
    RING_CAPACITY.store(ring_capacity.max(16), Ordering::Relaxed);
    let _ = PROC_LABEL.set(proc_label.to_string());
    // Capture the monotonic epoch and the wall anchor back-to-back so the
    // pair describes the same instant (within a few ns).
    let _ = EPOCH.set(Instant::now());
    WALL_ANCHOR_US.store(unix_now_us(), Ordering::Relaxed);
    ENABLED.store(enabled, Ordering::Relaxed);
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Monotonic µs since this process's telemetry epoch.
#[inline]
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

fn unix_now_us() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

/// This process's label ("trainer", "w0", ...); "-" before `init`.
pub fn proc_label() -> &'static str {
    PROC_LABEL.get().map(|s| s.as_str()).unwrap_or("-")
}

/// Record the receipt of a begin key (env workers call this from the control
/// loop); the value ships in the telemetry blob as the causality clamp.
pub fn note_begin_recv() {
    BEGIN_RECV_US.store(now_us().max(1), Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Leveled logging
// ---------------------------------------------------------------------------

/// Log severity for `tlog!`.  Ordered so that `level <= configured` emits.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }
}

#[inline]
pub fn log_enabled(level: Level) -> bool {
    (level as u8) <= LOG_LEVEL.load(Ordering::Relaxed)
}

/// Emit one structured stderr line: `[relexi LEVEL proc] message`.  The
/// prefix makes multi-process stderr greppable by worker id.
pub fn log_emit(level: Level, args: fmt::Arguments<'_>) {
    eprintln!("[relexi {} {}] {}", level.tag(), proc_label(), args);
}

/// Leveled log macro: `tlog!(warn, "worker {w} died")`.  The level is a
/// lowercase ident; emission is gated on `[telemetry] log_level` /
/// `RELEXI_LOG`, independent of the tracing switch.
#[macro_export]
macro_rules! tlog {
    (error, $($arg:tt)*) => { $crate::tlog!(@ $crate::util::telemetry::Level::Error, $($arg)*) };
    (warn,  $($arg:tt)*) => { $crate::tlog!(@ $crate::util::telemetry::Level::Warn,  $($arg)*) };
    (info,  $($arg:tt)*) => { $crate::tlog!(@ $crate::util::telemetry::Level::Info,  $($arg)*) };
    (debug, $($arg:tt)*) => { $crate::tlog!(@ $crate::util::telemetry::Level::Debug, $($arg)*) };
    (@ $lvl:expr, $($arg:tt)*) => {{
        if $crate::util::telemetry::log_enabled($lvl) {
            $crate::util::telemetry::log_emit($lvl, format_args!($($arg)*));
        }
    }};
}

// ---------------------------------------------------------------------------
// Name interning
// ---------------------------------------------------------------------------

/// Intern a call site's name once; later hits are a single relaxed load.
/// The site cache lives in a `static` the macros expand inline, so the lock
/// is taken exactly once per call site per process lifetime (warm-up).
pub fn intern_site(site: &AtomicU32, name: &'static str) -> u32 {
    let id = site.load(Ordering::Relaxed);
    if id != 0 {
        return id;
    }
    let mut names = NAMES.lock().unwrap();
    // Another thread may have won the race for this same site.
    let id = site.load(Ordering::Relaxed);
    if id != 0 {
        return id;
    }
    names.push(name);
    let id = names.len() as u32;
    site.store(id, Ordering::Relaxed);
    id
}

fn names_snapshot() -> Vec<String> {
    NAMES.lock().unwrap().iter().map(|s| s.to_string()).collect()
}

// ---------------------------------------------------------------------------
// Records and rings
// ---------------------------------------------------------------------------

pub const KIND_SPAN: u8 = 0;
pub const KIND_INSTANT: u8 = 1;
pub const KIND_COUNTER: u8 = 2;

/// One telemetry record: 32 bytes of plain integers (no pointers, so a torn
/// seqlock read is discardable garbage, never UB-prone).
#[derive(Clone, Copy, Debug, PartialEq)]
#[repr(C)]
pub struct Record {
    /// Span start / event time, µs since the process epoch.
    pub t_us: u64,
    /// Event payload (byte count, wave size, worker id, ...).
    pub a: u64,
    /// Span duration in µs (0 for instants/counters).
    pub dur_us: u32,
    /// Interned name id (see `intern_site`).
    pub name_id: u32,
    pub kind: u8,
    _pad: [u8; 7],
}

impl Record {
    fn new(t_us: u64, a: u64, dur_us: u32, name_id: u32, kind: u8) -> Record {
        Record { t_us, a, dur_us, name_id, kind, _pad: [0; 7] }
    }
}

/// Single-writer ring buffer of records.  The owning thread writes; the
/// process's collector drains with the seqlock discipline described in the
/// module docs.  `shipped` is the collector's watermark so per-iteration
/// drains are incremental.
pub struct Ring {
    slots: Box<[UnsafeCell<Record>]>,
    head: AtomicU64,
    shipped: AtomicU64,
    tid: u32,
    label: String,
}

// SAFETY: the slots are raced intentionally under the seqlock protocol; see
// the module docs.  All fields of `Record` are plain integers.
unsafe impl Sync for Ring {}
unsafe impl Send for Ring {}

impl Ring {
    fn new(capacity: usize, tid: u32, label: String) -> Ring {
        let zero = Record::new(0, 0, 0, 0, KIND_SPAN);
        Ring {
            slots: (0..capacity.max(16)).map(|_| UnsafeCell::new(zero)).collect(),
            head: AtomicU64::new(0),
            shipped: AtomicU64::new(0),
            tid,
            label,
        }
    }

    #[inline]
    fn push(&self, rec: Record) {
        let cap = self.slots.len() as u64;
        let h = self.head.load(Ordering::Relaxed);
        // SAFETY: single writer (the owning thread); readers tolerate torn
        // slots via the head re-check in `drain`.
        unsafe {
            std::ptr::write_volatile(self.slots[(h % cap) as usize].get(), rec);
        }
        self.head.store(h + 1, Ordering::Release);
    }

    /// Drain records written since the last drain.  Returns the surviving
    /// records (oldest first) and how many were dropped — either overwritten
    /// before this drain (wraparound) or discarded as potentially torn.
    fn drain(&self) -> (Vec<Record>, u64) {
        let cap = self.slots.len() as u64;
        let h1 = self.head.load(Ordering::Acquire);
        let from = self.shipped.load(Ordering::Relaxed);
        let start = from.max(h1.saturating_sub(cap));
        let mut out = Vec::with_capacity((h1 - start) as usize);
        for idx in start..h1 {
            // SAFETY: volatile POD read; torn results are filtered below.
            out.push(unsafe { std::ptr::read_volatile(self.slots[(idx % cap) as usize].get()) });
        }
        // Any record the writer might have overwritten while we read is
        // suspect; keep only indices still safely inside the window.
        let h2 = self.head.load(Ordering::Acquire);
        let safe_from = h2.saturating_sub(cap);
        let torn = safe_from.saturating_sub(start) as usize;
        if torn > 0 {
            out.drain(..torn.min(out.len()));
        }
        let dropped = (start - from) + torn as u64;
        self.shipped.store(h1, Ordering::Relaxed);
        (out, dropped)
    }
}

thread_local! {
    static LOCAL_RING: OnceCell<Arc<Ring>> = const { OnceCell::new() };
}

fn make_ring() -> Arc<Ring> {
    let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    let label = std::thread::current()
        .name()
        .map(|n| n.to_string())
        .unwrap_or_else(|| format!("t{tid}"));
    let ring = Arc::new(Ring::new(RING_CAPACITY.load(Ordering::Relaxed), tid, label));
    REGISTRY.lock().unwrap().push(ring.clone());
    ring
}

#[inline]
fn push_record(rec: Record) {
    LOCAL_RING.with(|cell| cell.get_or_init(make_ring).push(rec));
}

/// One ring's drained contents, for serialization or local merging.
pub struct RingDrain {
    pub tid: u32,
    pub label: String,
    pub dropped: u64,
    pub records: Vec<Record>,
}

/// Drain every ring in this process (incremental since the last drain).
pub fn drain_all() -> Vec<RingDrain> {
    let rings: Vec<Arc<Ring>> = REGISTRY.lock().unwrap().clone();
    rings
        .iter()
        .map(|r| {
            let (records, dropped) = r.drain();
            RingDrain { tid: r.tid, label: r.label.clone(), dropped, records }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Spans and events
// ---------------------------------------------------------------------------

/// RAII span guard: records one `KIND_SPAN` record (start + duration) on
/// drop.  Only constructed when telemetry is enabled.
pub struct SpanGuard {
    name_id: u32,
    start_us: u64,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let dur = now_us().saturating_sub(self.start_us).min(u32::MAX as u64) as u32;
        push_record(Record::new(self.start_us, 0, dur, self.name_id, KIND_SPAN));
    }
}

#[inline]
pub fn span_site(site: &AtomicU32, name: &'static str) -> Option<SpanGuard> {
    if !enabled() {
        return None;
    }
    Some(SpanGuard { name_id: intern_site(site, name), start_us: now_us() })
}

#[inline]
pub fn event_site(site: &AtomicU32, name: &'static str, a: u64, kind: u8) {
    if !enabled() {
        return;
    }
    let id = intern_site(site, name);
    push_record(Record::new(now_us(), a, 0, id, kind));
}

/// Open a named span for the enclosing scope:
/// `let _sp = span!("wave.collect");`
#[macro_export]
macro_rules! span {
    ($name:literal) => {{
        static __TEL_SITE: std::sync::atomic::AtomicU32 = std::sync::atomic::AtomicU32::new(0);
        $crate::util::telemetry::span_site(&__TEL_SITE, $name)
    }};
}

/// Record an instant event with a payload: `tevent!("frame.put", bytes)`.
#[macro_export]
macro_rules! tevent {
    ($name:literal, $a:expr) => {{
        static __TEL_SITE: std::sync::atomic::AtomicU32 = std::sync::atomic::AtomicU32::new(0);
        $crate::util::telemetry::event_site(
            &__TEL_SITE,
            $name,
            $a as u64,
            $crate::util::telemetry::KIND_INSTANT,
        )
    }};
}

/// Record a counter/gauge sample: `tcount!("wave.envs", n)`.  Rendered as a
/// Chrome `"C"` (counter) event so Perfetto plots it as a time series.
#[macro_export]
macro_rules! tcount {
    ($name:literal, $a:expr) => {{
        static __TEL_SITE: std::sync::atomic::AtomicU32 = std::sync::atomic::AtomicU32::new(0);
        $crate::util::telemetry::event_site(
            &__TEL_SITE,
            $name,
            $a as u64,
            $crate::util::telemetry::KIND_COUNTER,
        )
    }};
}

// ---------------------------------------------------------------------------
// Latency histograms
// ---------------------------------------------------------------------------

/// The instrumented latency distributions.  Enum-indexed into a static
/// table so recording is a couple of relaxed `fetch_add`s.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(usize)]
pub enum HistId {
    StorePut = 0,
    StoreGet = 1,
    StoreTake = 2,
    StorePutMany = 3,
    StoreTakeMany = 4,
    Exchange = 5,
    PolicyForward = 6,
    TrainMinibatch = 7,
    WaveAssembly = 8,
}

pub const N_HISTS: usize = 9;

pub const HIST_NAMES: [&str; N_HISTS] = [
    "store.put",
    "store.get",
    "store.take",
    "store.put_many",
    "store.take_many",
    "exchange.wait",
    "policy.forward",
    "train.minibatch",
    "burgers.wave_assembly",
];

/// 256 log buckets over µs: exact below 16 µs, then 4 sub-buckets per
/// octave (~19% relative resolution) up to u64::MAX.
pub const N_BUCKETS: usize = 256;

struct HistCell {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

#[allow(clippy::declare_interior_mutable_const)]
const ZERO_U64: AtomicU64 = AtomicU64::new(0);
#[allow(clippy::declare_interior_mutable_const)]
const HIST_ZERO: HistCell = HistCell {
    buckets: [ZERO_U64; N_BUCKETS],
    count: AtomicU64::new(0),
    sum_us: AtomicU64::new(0),
};

static HISTS: [HistCell; N_HISTS] = [HIST_ZERO; N_HISTS];

/// Map a µs value to its bucket index.
pub fn bucket_index(us: u64) -> usize {
    if us < 16 {
        us as usize
    } else {
        let o = 63 - us.leading_zeros() as u64; // >= 4
        let sub = (us >> (o - 2)) & 3;
        (16 + (o - 4) * 4 + sub) as usize
    }
}

/// Inclusive lower bound of a bucket, in µs.
pub fn bucket_floor(idx: usize) -> u64 {
    if idx < 16 {
        idx as u64
    } else {
        let o = 4 + (idx - 16) as u64 / 4;
        let sub = (idx - 16) as u64 % 4;
        (4 + sub) << (o - 2)
    }
}

impl HistId {
    #[inline]
    pub fn observe_us(self, us: u64) {
        if !enabled() {
            return;
        }
        let h = &HISTS[self as usize];
        h.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        h.count.fetch_add(1, Ordering::Relaxed);
        h.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Start timing an operation; records on guard drop.  `None` (free)
    /// when telemetry is disabled.
    #[inline]
    pub fn timer(self) -> HistTimer {
        if enabled() {
            HistTimer(Some((self, Instant::now())))
        } else {
            HistTimer(None)
        }
    }
}

/// RAII histogram timer from [`HistId::timer`].
pub struct HistTimer(Option<(HistId, Instant)>);

impl Drop for HistTimer {
    fn drop(&mut self) {
        if let Some((id, t0)) = self.0.take() {
            id.observe_us(t0.elapsed().as_micros() as u64);
        }
    }
}

/// Sparse point-in-time copy of one histogram.
#[derive(Clone, Debug, Default)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum_us: u64,
    /// Non-zero buckets as `(bucket_index, count)`.
    pub buckets: Vec<(u32, u64)>,
}

impl HistSnapshot {
    /// Counts accumulated since `earlier` (which must be an older snapshot
    /// of the same histogram).
    pub fn since(&self, earlier: &HistSnapshot) -> HistSnapshot {
        let mut dense = [0u64; N_BUCKETS];
        for &(i, c) in &self.buckets {
            dense[i as usize] = c;
        }
        for &(i, c) in &earlier.buckets {
            dense[i as usize] = dense[i as usize].saturating_sub(c);
        }
        HistSnapshot {
            count: self.count.saturating_sub(earlier.count),
            sum_us: self.sum_us.saturating_sub(earlier.sum_us),
            buckets: dense
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(i, &c)| (i as u32, c))
                .collect(),
        }
    }

    /// Approximate percentile (0.0..=1.0) in µs: the floor of the bucket
    /// holding the p-th sample.  0 when empty.
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        let mut sorted = self.buckets.clone();
        sorted.sort_unstable_by_key(|&(i, _)| i);
        for (i, c) in sorted {
            seen += c;
            if seen >= target {
                return bucket_floor(i as usize);
            }
        }
        bucket_floor(N_BUCKETS - 1)
    }
}

/// Snapshot one histogram's current state.
pub fn snapshot_hist(id: HistId) -> HistSnapshot {
    let h = &HISTS[id as usize];
    let buckets = h
        .buckets
        .iter()
        .enumerate()
        .filter_map(|(i, b)| {
            let c = b.load(Ordering::Relaxed);
            (c > 0).then_some((i as u32, c))
        })
        .collect();
    HistSnapshot {
        count: h.count.load(Ordering::Relaxed),
        sum_us: h.sum_us.load(Ordering::Relaxed),
        buckets,
    }
}

/// Snapshot all histograms, indexed by `HistId as usize`.
pub fn snapshot_all_hists() -> Vec<HistSnapshot> {
    const IDS: [HistId; N_HISTS] = [
        HistId::StorePut,
        HistId::StoreGet,
        HistId::StoreTake,
        HistId::StorePutMany,
        HistId::StoreTakeMany,
        HistId::Exchange,
        HistId::PolicyForward,
        HistId::TrainMinibatch,
        HistId::WaveAssembly,
    ];
    IDS.iter().map(|&id| snapshot_hist(id)).collect()
}

// ---------------------------------------------------------------------------
// Wire format: ship a process's telemetry over the store ctl plane
// ---------------------------------------------------------------------------

const BLOB_MAGIC: &[u8; 4] = b"RTL1";

fn w_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}
fn w_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn w_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn w_str(b: &mut Vec<u8>, s: &str) {
    w_u32(b, s.len() as u32);
    b.extend_from_slice(s.as_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("telemetry blob truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8_lossy(self.take(n)?).into_owned())
    }
}

/// Serialize everything recorded in this process since the last call:
/// header (wall anchor, begin-recv clamp), the interned name table, every
/// ring's new records, and cumulative histogram state.
pub fn serialize_process() -> Vec<u8> {
    let drains = drain_all();
    // Names are locked AFTER the drain so every id in the records resolves.
    let names = names_snapshot();
    let mut b = Vec::with_capacity(4096);
    b.extend_from_slice(BLOB_MAGIC);
    w_str(&mut b, proc_label());
    w_u64(&mut b, WALL_ANCHOR_US.load(Ordering::Relaxed));
    w_u64(&mut b, BEGIN_RECV_US.load(Ordering::Relaxed));
    w_u32(&mut b, names.len() as u32);
    for n in &names {
        w_str(&mut b, n);
    }
    w_u32(&mut b, drains.len() as u32);
    for d in &drains {
        w_u32(&mut b, d.tid);
        w_str(&mut b, &d.label);
        w_u64(&mut b, d.dropped);
        w_u32(&mut b, d.records.len() as u32);
        for r in &d.records {
            w_u64(&mut b, r.t_us);
            w_u64(&mut b, r.a);
            w_u32(&mut b, r.dur_us);
            w_u32(&mut b, r.name_id);
            w_u8(&mut b, r.kind);
        }
    }
    let hists = snapshot_all_hists();
    w_u32(&mut b, hists.len() as u32);
    for h in &hists {
        w_u64(&mut b, h.count);
        w_u64(&mut b, h.sum_us);
        w_u32(&mut b, h.buckets.len() as u32);
        for &(i, c) in &h.buckets {
            w_u32(&mut b, i);
            w_u64(&mut b, c);
        }
    }
    b
}

/// A parsed process blob (one `serialize_process` payload).
pub struct ProcBlob {
    pub label: String,
    pub wall_anchor_us: u64,
    pub begin_recv_us: u64,
    pub names: Vec<String>,
    pub rings: Vec<RingDrain>,
    pub hists: Vec<HistSnapshot>,
}

pub fn parse_blob(bytes: &[u8]) -> Result<ProcBlob> {
    let mut r = Reader { b: bytes, i: 0 };
    if r.take(4)? != BLOB_MAGIC {
        bail!("not a telemetry blob (bad magic)");
    }
    let label = r.str()?;
    let wall_anchor_us = r.u64()?;
    let begin_recv_us = r.u64()?;
    let n_names = r.u32()? as usize;
    let mut names = Vec::with_capacity(n_names);
    for _ in 0..n_names {
        names.push(r.str()?);
    }
    let n_rings = r.u32()? as usize;
    let mut rings = Vec::with_capacity(n_rings);
    for _ in 0..n_rings {
        let tid = r.u32()?;
        let rlabel = r.str()?;
        let dropped = r.u64()?;
        let n = r.u32()? as usize;
        let mut records = Vec::with_capacity(n);
        for _ in 0..n {
            let t_us = r.u64()?;
            let a = r.u64()?;
            let dur_us = r.u32()?;
            let name_id = r.u32()?;
            let kind = r.u8()?;
            records.push(Record::new(t_us, a, dur_us, name_id, kind));
        }
        rings.push(RingDrain { tid, label: rlabel, dropped, records });
    }
    let n_hists = r.u32()? as usize;
    let mut hists = Vec::with_capacity(n_hists);
    for _ in 0..n_hists {
        let count = r.u64()?;
        let sum_us = r.u64()?;
        let nb = r.u32()? as usize;
        let mut buckets = Vec::with_capacity(nb);
        for _ in 0..nb {
            let i = r.u32()?;
            let c = r.u64()?;
            buckets.push((i, c));
        }
        hists.push(HistSnapshot { count, sum_us, buckets });
    }
    Ok(ProcBlob { label, wall_anchor_us, begin_recv_us, names, rings, hists })
}

// ---------------------------------------------------------------------------
// Trace merger
// ---------------------------------------------------------------------------

/// Normalized event on the trainer timeline.
struct Ev {
    ts_us: u64,
    dur_us: u32,
    kind: u8,
    name: u32, // merger-local name id
    a: u64,
}

struct ThreadEvents {
    tid: u32,
    label: String,
    events: Vec<Ev>,
}

struct ProcEvents {
    label: String,
    threads: Vec<ThreadEvents>,
    /// Latest cumulative histogram state shipped by this process.
    hists: Vec<HistSnapshot>,
    dropped: u64,
}

/// Merges per-process telemetry blobs onto the trainer's timeline and emits
/// the Chrome-trace JSON plus an aggregate summary.
pub struct TraceMerger {
    names: Vec<String>,
    name_ids: HashMap<String, u32>,
    procs: Vec<ProcEvents>,
}

impl Default for TraceMerger {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceMerger {
    pub fn new() -> TraceMerger {
        TraceMerger { names: Vec::new(), name_ids: HashMap::new(), procs: Vec::new() }
    }

    fn name_id(&mut self, name: &str) -> u32 {
        if let Some(&id) = self.name_ids.get(name) {
            return id;
        }
        self.names.push(name.to_string());
        let id = self.names.len() as u32 - 1;
        self.name_ids.insert(name.to_string(), id);
        id
    }

    fn proc_slot(&mut self, label: &str) -> usize {
        if let Some(i) = self.procs.iter().position(|p| p.label == label) {
            return i;
        }
        self.procs.push(ProcEvents {
            label: label.to_string(),
            threads: Vec::new(),
            hists: Vec::new(),
            dropped: 0,
        });
        self.procs.len() - 1
    }

    fn absorb_rings(
        &mut self,
        slot: usize,
        names: &[String],
        rings: Vec<RingDrain>,
        offset_us: i64,
    ) {
        for d in rings {
            let mapped: Vec<Ev> = d
                .records
                .iter()
                .map(|r| {
                    let raw = names
                        .get(r.name_id.wrapping_sub(1) as usize)
                        .map(|s| s.as_str())
                        .unwrap_or("?");
                    let name = self.name_id(raw);
                    let ts = (r.t_us as i64 + offset_us).max(0) as u64;
                    Ev { ts_us: ts, dur_us: r.dur_us, kind: r.kind, name, a: r.a }
                })
                .collect();
            let p = &mut self.procs[slot];
            p.dropped += d.dropped;
            match p.threads.iter_mut().find(|t| t.tid == d.tid) {
                Some(t) => t.events.extend(mapped),
                None => p.threads.push(ThreadEvents { tid: d.tid, label: d.label, events: mapped }),
            }
        }
    }

    /// Drain and absorb this process's own rings (offset 0).  Call once per
    /// iteration on the trainer so rings never wrap between merges.
    pub fn absorb_local(&mut self) {
        let rings = drain_all();
        let names = names_snapshot();
        let slot = self.proc_slot(&proc_label().to_string());
        self.absorb_rings(slot, &names, rings, 0);
    }

    /// Absorb a worker's shipped blob.  `trainer_begin_put_us` is the
    /// trainer's monotonic µs when it put the latest begin key for this
    /// worker (0 = unknown): the causality clamp — the worker cannot have
    /// received that begin earlier than the trainer put it.
    pub fn absorb_blob(&mut self, bytes: &[u8], trainer_begin_put_us: u64) -> Result<()> {
        let blob = parse_blob(bytes)?;
        let trainer_anchor = WALL_ANCHOR_US.load(Ordering::Relaxed) as i64;
        let mut offset = blob.wall_anchor_us as i64 - trainer_anchor;
        if blob.begin_recv_us > 0 && trainer_begin_put_us > 0 {
            offset = offset.max(trainer_begin_put_us as i64 - blob.begin_recv_us as i64);
        }
        let slot = self.proc_slot(&blob.label);
        self.procs[slot].hists = blob.hists;
        self.absorb_rings(slot, &blob.names, blob.rings, offset);
        Ok(())
    }

    /// Render the merged timeline as Chrome trace events (JSON array),
    /// globally sorted by timestamp.  pid 0 is the trainer (first absorbed
    /// process); workers follow in absorb order.
    pub fn chrome_trace_json(&self) -> String {
        let mut lines: Vec<(u64, String)> = Vec::new();
        let mut meta: Vec<String> = Vec::new();
        for (pid, p) in self.procs.iter().enumerate() {
            meta.push(format!(
                "{{\"ph\":\"M\",\"pid\":{pid},\"name\":\"process_name\",\"args\":{{\"name\":{}}}}}",
                json_str(&p.label)
            ));
            for t in &p.threads {
                meta.push(format!(
                    "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{},\"name\":\"thread_name\",\"args\":{{\"name\":{}}}}}",
                    t.tid,
                    json_str(&t.label)
                ));
                for e in &t.events {
                    let name = json_str(&self.names[e.name as usize]);
                    let line = match e.kind {
                        KIND_SPAN => format!(
                            "{{\"name\":{name},\"ph\":\"X\",\"pid\":{pid},\"tid\":{},\"ts\":{},\"dur\":{}}}",
                            t.tid, e.ts_us, e.dur_us
                        ),
                        KIND_COUNTER => format!(
                            "{{\"name\":{name},\"ph\":\"C\",\"pid\":{pid},\"tid\":{},\"ts\":{},\"args\":{{\"value\":{}}}}}",
                            t.tid, e.ts_us, e.a
                        ),
                        _ => format!(
                            "{{\"name\":{name},\"ph\":\"i\",\"s\":\"t\",\"pid\":{pid},\"tid\":{},\"ts\":{},\"args\":{{\"a\":{}}}}}",
                            t.tid, e.ts_us, e.a
                        ),
                    };
                    lines.push((e.ts_us, line));
                }
            }
        }
        lines.sort_by_key(|&(ts, _)| ts);
        let mut out = String::from("[\n");
        let mut first = true;
        for m in meta.into_iter().chain(lines.into_iter().map(|(_, l)| l)) {
            if !first {
                out.push_str(",\n");
            }
            first = false;
            out.push_str(&m);
        }
        out.push_str("\n]\n");
        out
    }

    /// Aggregate the merged records: per-span-name duration stats, per-event
    /// totals, and per-histogram percentiles (trainer's live histograms plus
    /// the latest shipped state of every worker).
    pub fn summary(&self) -> Summary {
        let mut spans: HashMap<u32, Vec<u64>> = HashMap::new();
        let mut events: HashMap<u32, (u64, u64)> = HashMap::new();
        let mut counters: HashMap<u32, (u64, u64)> = HashMap::new();
        let mut dropped = 0u64;
        for p in &self.procs {
            dropped += p.dropped;
            for t in &p.threads {
                for e in &t.events {
                    match e.kind {
                        KIND_SPAN => spans.entry(e.name).or_default().push(e.dur_us as u64),
                        KIND_COUNTER => {
                            let c = counters.entry(e.name).or_insert((0, 0));
                            c.0 += 1;
                            c.1 += e.a;
                        }
                        _ => {
                            let c = events.entry(e.name).or_insert((0, 0));
                            c.0 += 1;
                            c.1 += e.a;
                        }
                    }
                }
            }
        }
        let mut span_rows: Vec<SpanAgg> = spans
            .into_iter()
            .map(|(name, mut durs)| {
                durs.sort_unstable();
                let total: u64 = durs.iter().sum();
                let pick = |p: f64| durs[((p * (durs.len() - 1) as f64).round() as usize).min(durs.len() - 1)];
                SpanAgg {
                    name: self.names[name as usize].clone(),
                    count: durs.len() as u64,
                    total_us: total,
                    p50_us: pick(0.50),
                    p99_us: pick(0.99),
                    max_us: *durs.last().unwrap(),
                }
            })
            .collect();
        span_rows.sort_by(|a, b| b.total_us.cmp(&a.total_us));
        let to_rows = |m: HashMap<u32, (u64, u64)>| {
            let mut rows: Vec<(String, u64, u64)> = m
                .into_iter()
                .map(|(name, (count, sum))| (self.names[name as usize].clone(), count, sum))
                .collect();
            rows.sort_by(|a, b| a.0.cmp(&b.0));
            rows
        };
        // Trainer histograms are live statics; workers shipped theirs.
        let mut hists: Vec<HistAgg> = Vec::new();
        let local = snapshot_all_hists();
        for (i, name) in HIST_NAMES.iter().enumerate() {
            let mut dense = [0u64; N_BUCKETS];
            let mut count = 0u64;
            let mut sum_us = 0u64;
            let mut add = |h: &HistSnapshot| {
                count += h.count;
                sum_us += h.sum_us;
                for &(bi, c) in &h.buckets {
                    dense[bi as usize] += c;
                }
            };
            add(&local[i]);
            for p in &self.procs {
                if p.label != proc_label() {
                    if let Some(h) = p.hists.get(i) {
                        add(h);
                    }
                }
            }
            let snap = HistSnapshot {
                count,
                sum_us,
                buckets: dense
                    .iter()
                    .enumerate()
                    .filter(|(_, &c)| c > 0)
                    .map(|(bi, &c)| (bi as u32, c))
                    .collect(),
            };
            hists.push(HistAgg {
                name: name.to_string(),
                count,
                sum_us,
                p50_us: snap.percentile_us(0.50),
                p99_us: snap.percentile_us(0.99),
            });
        }
        Summary {
            spans: span_rows,
            events: to_rows(events),
            counters: to_rows(counters),
            hists,
            dropped_records: dropped,
            n_procs: self.procs.len() as u64,
        }
    }
}

/// Aggregated statistics for one span name across the whole run.
pub struct SpanAgg {
    pub name: String,
    pub count: u64,
    pub total_us: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

/// Aggregated histogram row (merged across processes).
pub struct HistAgg {
    pub name: String,
    pub count: u64,
    pub sum_us: u64,
    pub p50_us: u64,
    pub p99_us: u64,
}

/// Run-wide aggregate emitted as `TELEMETRY_{run}.json`.
pub struct Summary {
    pub spans: Vec<SpanAgg>,
    /// `(name, count, sum_of_payload)` for instant events.
    pub events: Vec<(String, u64, u64)>,
    /// `(name, count, sum_of_values)` for counter samples.
    pub counters: Vec<(String, u64, u64)>,
    pub hists: Vec<HistAgg>,
    pub dropped_records: u64,
    pub n_procs: u64,
}

impl Summary {
    /// Render as JSON, with caller-supplied extra numeric sections (store /
    /// pool / batch / supervision counters) appended verbatim.
    pub fn to_json(&self, run: &str, extra_sections: &[(&str, Vec<(String, f64)>)]) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"run\": {},\n", json_str(run)));
        s.push_str(&format!("  \"processes\": {},\n", self.n_procs));
        s.push_str(&format!("  \"dropped_records\": {},\n", self.dropped_records));
        s.push_str("  \"spans\": [\n");
        for (i, r) in self.spans.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": {}, \"count\": {}, \"total_us\": {}, \"p50_us\": {}, \"p99_us\": {}, \"max_us\": {}}}{}\n",
                json_str(&r.name), r.count, r.total_us, r.p50_us, r.p99_us, r.max_us,
                if i + 1 < self.spans.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n  \"hists\": [\n");
        for (i, r) in self.hists.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": {}, \"count\": {}, \"sum_us\": {}, \"p50_us\": {}, \"p99_us\": {}}}{}\n",
                json_str(&r.name), r.count, r.sum_us, r.p50_us, r.p99_us,
                if i + 1 < self.hists.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n  \"events\": [\n");
        for (i, (name, count, sum)) in self.events.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": {}, \"count\": {}, \"sum\": {}}}{}\n",
                json_str(name),
                count,
                sum,
                if i + 1 < self.events.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n  \"counters\": [\n");
        for (i, (name, count, sum)) in self.counters.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": {}, \"count\": {}, \"sum\": {}}}{}\n",
                json_str(name),
                count,
                sum,
                if i + 1 < self.counters.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]");
        for (section, rows) in extra_sections {
            s.push_str(&format!(",\n  {}: {{", json_str(section)));
            for (i, (k, v)) in rows.iter().enumerate() {
                s.push_str(&format!(
                    "{}\"{}\": {}",
                    if i == 0 { "" } else { ", " },
                    k,
                    fmt_f64(*v)
                ));
            }
            s.push('}');
        }
        s.push_str("\n}\n");
        s
    }
}

fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Parse `"__relexi:ctl:tel:wK"`-shipped blob sender label "wK" to a worker
/// index, used by tests and the gather path.
pub fn worker_label(w: usize) -> String {
    format!("w{w}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_math_floor_is_consistent() {
        // floor(idx(v)) <= v, and v < floor(idx(v)+1) for every probe.
        let probes: Vec<u64> = (0..64)
            .flat_map(|o| {
                let base = 1u64 << o.min(62);
                vec![base, base + 1, base + base / 3, base * 2 - 1]
            })
            .chain(0..40)
            .collect();
        for &v in &probes {
            let idx = bucket_index(v);
            assert!(idx < N_BUCKETS, "idx {idx} out of range for {v}");
            assert!(bucket_floor(idx) <= v, "floor({idx})={} > {v}", bucket_floor(idx));
            if idx + 1 < N_BUCKETS {
                assert!(
                    v < bucket_floor(idx + 1),
                    "{v} >= next floor {}",
                    bucket_floor(idx + 1)
                );
            }
        }
        // Bucket index is monotone in the value.
        let mut last = 0;
        for v in 0..100_000u64 {
            let i = bucket_index(v);
            assert!(i >= last);
            last = i;
        }
    }

    #[test]
    fn bucket_floor_is_strictly_increasing() {
        for i in 1..N_BUCKETS {
            assert!(bucket_floor(i) > bucket_floor(i - 1), "bucket {i} not increasing");
        }
    }

    #[test]
    fn ring_wraparound_drops_oldest_and_counts() {
        let ring = Ring::new(32, 0, "test".into());
        for k in 0..37u64 {
            ring.push(Record::new(k, k, 0, 1, KIND_INSTANT));
        }
        let (records, dropped) = ring.drain();
        assert_eq!(dropped, 5, "oldest 5 of 37 must be dropped at capacity 32");
        assert_eq!(records.len(), 32);
        // Survivors are the newest 32, oldest first.
        for (i, r) in records.iter().enumerate() {
            assert_eq!(r.t_us, 5 + i as u64);
        }
        // Incremental drain: nothing new yet.
        let (records, dropped) = ring.drain();
        assert!(records.is_empty());
        assert_eq!(dropped, 0);
        ring.push(Record::new(99, 0, 0, 1, KIND_INSTANT));
        let (records, dropped) = ring.drain();
        assert_eq!(records.len(), 1);
        assert_eq!(dropped, 0);
        assert_eq!(records[0].t_us, 99);
    }

    #[test]
    fn hist_percentiles_from_known_values() {
        let mut dense = [0u64; N_BUCKETS];
        // 99 samples at ~100us, 1 sample at ~100ms.
        dense[bucket_index(100)] = 99;
        dense[bucket_index(100_000)] = 1;
        let snap = HistSnapshot {
            count: 100,
            sum_us: 99 * 100 + 100_000,
            buckets: dense
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(i, &c)| (i as u32, c))
                .collect(),
        };
        let p50 = snap.percentile_us(0.50);
        let p99 = snap.percentile_us(0.99);
        let p999 = snap.percentile_us(0.999);
        assert!(p50 >= 64 && p50 <= 100, "p50 {p50} should bracket 100us");
        assert!(p99 >= 64 && p99 <= 100, "p99 {p99} should still be in the 100us bucket");
        assert!(p999 >= 65_536, "p99.9 {p999} should land in the 100ms bucket");
    }

    #[test]
    fn hist_snapshot_diff_subtracts() {
        let early = HistSnapshot { count: 5, sum_us: 500, buckets: vec![(20, 5)] };
        let late = HistSnapshot { count: 8, sum_us: 1100, buckets: vec![(20, 5), (24, 3)] };
        let d = late.since(&early);
        assert_eq!(d.count, 3);
        assert_eq!(d.sum_us, 600);
        assert_eq!(d.buckets, vec![(24, 3)]);
    }

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("WARNING"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Error < Level::Debug);
    }

    #[test]
    fn blob_roundtrip_preserves_records_and_names() {
        // Build a blob by hand (serialize_process drains *global* state,
        // which other tests share; the wire format is what's under test).
        let mut b = Vec::new();
        b.extend_from_slice(BLOB_MAGIC);
        w_str(&mut b, "w7");
        w_u64(&mut b, 1_000_000); // wall anchor
        w_u64(&mut b, 42); // begin recv
        w_u32(&mut b, 2);
        w_str(&mut b, "wave.step");
        w_str(&mut b, "frame.put");
        w_u32(&mut b, 1); // one ring
        w_u32(&mut b, 3); // tid
        w_str(&mut b, "ctl");
        w_u64(&mut b, 7); // dropped
        w_u32(&mut b, 2); // two records
        for (t, a, dur, id, kind) in
            [(10u64, 0u64, 5u32, 1u32, KIND_SPAN), (20, 4096, 0, 2, KIND_INSTANT)]
        {
            w_u64(&mut b, t);
            w_u64(&mut b, a);
            w_u32(&mut b, dur);
            w_u32(&mut b, id);
            w_u8(&mut b, kind);
        }
        w_u32(&mut b, 1); // one hist
        w_u64(&mut b, 9);
        w_u64(&mut b, 900);
        w_u32(&mut b, 1);
        w_u32(&mut b, 22);
        w_u64(&mut b, 9);

        let blob = parse_blob(&b).unwrap();
        assert_eq!(blob.label, "w7");
        assert_eq!(blob.wall_anchor_us, 1_000_000);
        assert_eq!(blob.begin_recv_us, 42);
        assert_eq!(blob.names, vec!["wave.step", "frame.put"]);
        assert_eq!(blob.rings.len(), 1);
        assert_eq!(blob.rings[0].tid, 3);
        assert_eq!(blob.rings[0].dropped, 7);
        assert_eq!(blob.rings[0].records.len(), 2);
        assert_eq!(blob.rings[0].records[1].a, 4096);
        assert_eq!(blob.hists[0].count, 9);
        assert_eq!(blob.hists[0].buckets, vec![(22, 9)]);

        // Truncation must error, not panic.
        assert!(parse_blob(&b[..b.len() - 3]).is_err());
        assert!(parse_blob(b"RTLX").is_err());
    }

    #[test]
    fn merger_aligns_clamps_and_sorts() {
        let mut m = TraceMerger::new();
        // Local process (the "trainer" in this test): absorb a hand-built
        // ring at offset 0 via the blob path with anchor == local anchor.
        let anchor = WALL_ANCHOR_US.load(Ordering::Relaxed);
        let mk_blob = |label: &str, wall: u64, begin_recv: u64, t0: u64| {
            let mut b = Vec::new();
            b.extend_from_slice(BLOB_MAGIC);
            w_str(&mut b, label);
            w_u64(&mut b, wall);
            w_u64(&mut b, begin_recv);
            w_u32(&mut b, 1);
            w_str(&mut b, "wave.step");
            w_u32(&mut b, 1);
            w_u32(&mut b, 0);
            w_str(&mut b, "main");
            w_u64(&mut b, 0);
            w_u32(&mut b, 1);
            w_u64(&mut b, t0);
            w_u64(&mut b, 0);
            w_u32(&mut b, 10);
            w_u32(&mut b, 1);
            w_u8(&mut b, KIND_SPAN);
            w_u32(&mut b, 0); // no hists
            b
        };
        // Worker clock identical to trainer's, but its "begin recv" (t=5)
        // precedes the trainer's put (t=1000): the clamp must shift it.
        m.absorb_blob(&mk_blob("w0", anchor, 5, 5), 1000).unwrap();
        let json = m.chrome_trace_json();
        // Clamp: offset = max(0, 1000 - 5) = 995, so ts = 5 + 995 = 1000.
        assert!(json.contains("\"ts\":1000"), "clamped ts missing: {json}");
        assert!(json.contains("\"process_name\""));
        assert!(json.contains("\"wave.step\""));
        // Events are globally sorted by ts.
        let mut last = 0u64;
        for part in json.split("\"ts\":").skip(1) {
            let ts: u64 =
                part.split(|c: char| !c.is_ascii_digit()).next().unwrap().parse().unwrap();
            assert!(ts >= last, "trace not sorted: {ts} after {last}");
            last = ts;
        }
        let summary = m.summary();
        let wave = summary.spans.iter().find(|s| s.name == "wave.step").unwrap();
        assert_eq!(wave.count, 1);
        assert_eq!(wave.total_us, 10);
        let json = summary.to_json("test", &[("store", vec![("frames".into(), 3.0)])]);
        assert!(json.contains("\"store\": {\"frames\": 3}"), "{json}");
    }

    #[test]
    fn summary_json_is_parseable() {
        let m = TraceMerger::new();
        let s = m.summary().to_json("run", &[("pool", vec![("hits".into(), 1.5)])]);
        crate::util::binio::Json::parse(&s).expect("summary JSON must parse");
    }

    #[test]
    fn disabled_sites_record_nothing() {
        // Regardless of what other tests did, force-disable and verify the
        // macro entry points bail before touching rings.
        let was = ENABLED.swap(false, Ordering::Relaxed);
        let before: u64 = REGISTRY.lock().unwrap().iter().map(|r| r.head.load(Ordering::Relaxed)).sum();
        {
            let _sp = crate::span!("tel.test.noop");
            crate::tevent!("tel.test.noop_ev", 1);
            HistId::StorePut.observe_us(10);
        }
        let after: u64 = REGISTRY.lock().unwrap().iter().map(|r| r.head.load(Ordering::Relaxed)).sum();
        assert_eq!(before, after, "disabled telemetry must not record");
        ENABLED.store(was, Ordering::Relaxed);
    }

    #[test]
    fn enabled_sites_record_spans_and_events() {
        // Run in a dedicated thread so this test's ring is its own.
        let was = ENABLED.swap(true, Ordering::Relaxed);
        let drained = std::thread::Builder::new()
            .name("tel-test".into())
            .spawn(|| {
                {
                    let _sp = crate::span!("tel.test.span");
                    crate::tevent!("tel.test.event", 123);
                    crate::tcount!("tel.test.count", 7);
                }
                LOCAL_RING.with(|c| c.get_or_init(make_ring).drain())
            })
            .unwrap()
            .join()
            .unwrap();
        ENABLED.store(was, Ordering::Relaxed);
        let (records, dropped) = drained;
        assert_eq!(dropped, 0);
        // Event + counter land before the span (span records on drop).
        assert_eq!(records.len(), 3);
        assert_eq!(records[0].kind, KIND_INSTANT);
        assert_eq!(records[0].a, 123);
        assert_eq!(records[1].kind, KIND_COUNTER);
        assert_eq!(records[1].a, 7);
        assert_eq!(records[2].kind, KIND_SPAN);
        // The span encloses the events: start <= event ts <= start + dur.
        assert!(records[2].t_us <= records[0].t_us);
        assert!(records[0].t_us <= records[2].t_us + records[2].dur_us as u64);
    }
}
