//! Deterministic PRNG for the whole stack (no `rand` crate in the image).
//!
//! xoshiro256++ seeded through SplitMix64 — the standard construction. Every
//! stochastic component (initial-state draws, Gaussian action sampling,
//! interconnect jitter in the HPC simulator, property-test generators) takes
//! an explicit [`Rng`] so runs are reproducible from a single seed.

/// xoshiro256++ PRNG with a Box–Muller cache for Gaussian draws.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    gauss_cache: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_cache: None }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.split_seed(tag))
    }

    /// The 64-bit seed [`Rng::split`] would build its stream from —
    /// shippable across a process boundary (the env-worker begin
    /// message), with `Rng::new(seed)` reconstructing the exact stream.
    pub fn split_seed(&mut self, tag: u64) -> u64 {
        self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_cache.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_cache = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(19);
        let p = r.permutation(50);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(23);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let xs: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn split_seed_reconstructs_the_split_stream_bitwise() {
        // A seed shipped to another process must rebuild the exact
        // stream `split` would have produced locally.
        let mut local = Rng::new(2022);
        let mut remote = Rng::new(2022);
        for tag in [0u64, 1, 7, u64::MAX] {
            let mut a = local.split(tag);
            let mut b = Rng::new(remote.split_seed(tag));
            for _ in 0..16 {
                assert_eq!(a.next_u64(), b.next_u64());
            }
            assert_eq!(a.normal(), b.normal());
        }
    }
}
