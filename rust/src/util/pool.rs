//! Persistent worker-pool `parallel_for` for node-level kernel parallelism.
//!
//! ROADMAP open item 2: the FFT plane batches, the GEMM macro-tiles, and
//! the DNS/truth-generation loops all want threads without paying a spawn
//! per call.  [`Pool::new`] spawns `threads - 1` workers ONCE (the caller
//! is the remaining lane) and posts jobs through a single mutex + two
//! condvars; steady-state collection makes **zero** spawns, asserted via
//! [`PoolCounters`] exactly like the env pool's spawn gate.
//!
//! Determinism contract: every helper partitions work into DISJOINT output
//! chunks and never changes per-element arithmetic order, so results are
//! bit-identical for any thread count and any claiming order.  The repo's
//! bitwise gates (Adam determinism, lockstep-vs-event equivalence, the
//! learning smoke under `RELEXI_THREADS=1` vs `4`) rely on this.
//!
//! Safety sketch for the borrowed-task window: a posted [`Job`] holds a raw
//! fat pointer to the caller's closure.  The caller returns only once
//! `remaining == 0`, which requires all `n_tasks` claims to have FINISHED;
//! the claim counter is monotonic, so any later `fetch_add` by a straggler
//! worker yields an index `>= n_tasks` and the dangling pointer is never
//! dereferenced after the caller's frame dies.  Workers keep only `Arc`s
//! past that point.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};

/// Monotonic spawn/job accounting for the "no steady-state spawns" gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolCounters {
    /// OS threads spawned over the pool's lifetime — written once at
    /// construction (`threads - 1`), never again.
    pub threads_spawned: usize,
    /// Multi-task jobs posted to the workers.  Inline calls (single-task
    /// jobs, 1-thread pools, nested calls from inside a task) bypass the
    /// posting machinery entirely and are deliberately not counted.
    pub jobs: usize,
}

/// Type-erased borrowed task: a fat pointer into the caller's frame.  Its
/// lifetime is enforced by the `remaining` protocol (module docs).
#[derive(Clone, Copy)]
struct TaskRef(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared calls are fine) and the protocol
// guarantees it outlives every dereference.
unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

#[derive(Clone)]
struct Job {
    task: TaskRef,
    n_tasks: usize,
    /// Next task index to claim (monotonic; claims >= n_tasks are no-ops).
    next: Arc<AtomicUsize>,
    /// Tasks not yet retired; the caller returns when this hits zero.
    remaining: Arc<AtomicUsize>,
    panicked: Arc<AtomicBool>,
}

struct JobState {
    epoch: u64,
    job: Option<Job>,
    shutdown: bool,
}

struct Inner {
    state: Mutex<JobState>,
    /// Workers wait here for a new epoch.
    work_cv: Condvar,
    /// The posting caller waits here for `remaining == 0`.
    done_cv: Condvar,
    jobs: AtomicUsize,
}

/// A fixed-width persistent thread pool.  One job runs at a time
/// (concurrent `run` callers serialize on an internal posting lock);
/// nested `run` calls from inside a task degrade to inline execution
/// instead of deadlocking.
pub struct Pool {
    inner: Arc<Inner>,
    post_lock: Mutex<()>,
    handles: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

thread_local! {
    /// True while this thread is executing a pool task, so nested `run`
    /// calls fall back to inline execution.
    static IN_TASK: Cell<bool> = Cell::new(false);
}

fn worker_loop(inner: &Inner) {
    let mut seen_epoch = 0u64;
    loop {
        let job = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                match &st.job {
                    Some(j) if st.epoch != seen_epoch => {
                        seen_epoch = st.epoch;
                        break j.clone();
                    }
                    _ => st = inner.work_cv.wait(st).unwrap(),
                }
            }
        };
        run_tasks(inner, &job);
    }
}

/// Claim-and-execute loop shared by workers and the posting caller.  Runs
/// each task under `catch_unwind` so one panicking task cannot unwind past
/// peers that still borrow the closure; the caller re-raises afterwards.
fn run_tasks(inner: &Inner, job: &Job) {
    let prev = IN_TASK.with(|t| t.replace(true));
    loop {
        let idx = job.next.fetch_add(1, Ordering::Relaxed);
        if idx >= job.n_tasks {
            break;
        }
        // SAFETY: idx < n_tasks means the caller is still inside `run`
        // (it waits for this task's retirement below), so the pointee
        // is alive.
        if catch_unwind(AssertUnwindSafe(|| (unsafe { &*job.task.0 })(idx))).is_err() {
            job.panicked.store(true, Ordering::Relaxed);
        }
        // AcqRel + the final Acquire load forms a release sequence across
        // all decrementers: every task's writes are visible to the caller.
        if job.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Notify under the mutex: the caller checks `remaining` while
            // holding it, so the wakeup cannot be lost.
            let _st = inner.state.lock().unwrap();
            inner.done_cv.notify_all();
        }
    }
    IN_TASK.with(|t| t.set(prev));
}

impl Pool {
    /// A pool of `threads` lanes total (`threads - 1` spawned workers; the
    /// calling thread always participates).  `threads == 0` is clamped
    /// to 1.
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let inner = Arc::new(Inner {
            state: Mutex::new(JobState { epoch: 0, job: None, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            jobs: AtomicUsize::new(0),
        });
        let handles = (0..threads - 1)
            .map(|_| {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || worker_loop(&inner))
            })
            .collect();
        Pool { inner, post_lock: Mutex::new(()), handles, threads }
    }

    /// Total lanes (spawned workers + the caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn counters(&self) -> PoolCounters {
        PoolCounters {
            threads_spawned: self.handles.len(),
            jobs: self.inner.jobs.load(Ordering::Relaxed),
        }
    }

    /// Run `task(i)` for every `i in 0..n_tasks` across the pool (the
    /// caller participates).  Tasks must write disjoint data.  A panic in
    /// any task propagates to the caller — after every claimed task has
    /// retired, so no peer still borrows the closure.
    pub fn run(&self, n_tasks: usize, task: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        let nested = IN_TASK.with(|t| t.get());
        if self.handles.is_empty() || n_tasks == 1 || nested {
            for i in 0..n_tasks {
                task(i);
            }
            return;
        }
        let _post = self.post_lock.lock().unwrap();
        self.inner.jobs.fetch_add(1, Ordering::Relaxed);
        let job = Job {
            task: TaskRef(task as *const (dyn Fn(usize) + Sync)),
            n_tasks,
            next: Arc::new(AtomicUsize::new(0)),
            remaining: Arc::new(AtomicUsize::new(n_tasks)),
            panicked: Arc::new(AtomicBool::new(false)),
        };
        {
            let mut st = self.inner.state.lock().unwrap();
            st.epoch = st.epoch.wrapping_add(1);
            st.job = Some(job.clone());
            self.inner.work_cv.notify_all();
        }
        run_tasks(&self.inner, &job);
        {
            let mut st = self.inner.state.lock().unwrap();
            while job.remaining.load(Ordering::Acquire) != 0 {
                st = self.inner.done_cv.wait(st).unwrap();
            }
            st.job = None;
        }
        if job.panicked.load(Ordering::Relaxed) {
            panic!("worker-pool task panicked (original message above)");
        }
    }

    /// Split `0..n` into `grain`-sized ranges and run `f(start, end)` for
    /// each.  Chunk boundaries depend only on `(n, grain)` — never on the
    /// thread count — so any per-chunk arithmetic is reproducible.
    pub fn parallel_for<F: Fn(usize, usize) + Sync>(&self, n: usize, grain: usize, f: F) {
        if n == 0 {
            return;
        }
        let grain = grain.max(1);
        let n_chunks = (n + grain - 1) / grain;
        self.run(n_chunks, &|c| {
            let start = c * grain;
            f(start, (start + grain).min(n));
        });
    }

    /// Run `f(chunk_index, chunk)` over consecutive `chunk_len` slices of
    /// `data` in parallel.  Equivalent to `data.chunks_mut(chunk_len)`
    /// with the index attached.
    pub fn parallel_chunks_mut<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk_len > 0, "chunk_len must be positive");
        let len = data.len();
        if len == 0 {
            return;
        }
        let n_chunks = (len + chunk_len - 1) / chunk_len;
        let base = SendPtr(data.as_mut_ptr());
        self.run(n_chunks, &|c| {
            let start = c * chunk_len;
            let end = (start + chunk_len).min(len);
            // SAFETY: chunks are disjoint by construction and the caller's
            // `&mut data` pins exclusive access for the whole `run`.
            let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
            f(c, chunk);
        });
    }

    /// Two same-length slices chunked in lockstep — `f(chunk_index,
    /// a_chunk, b_chunk)`.  The FFT plane passes use this to hand every
    /// task its data plane plus a matching scratch plane.
    pub fn parallel_chunks_mut2<T, U, F>(&self, a: &mut [T], b: &mut [U], chunk_len: usize, f: F)
    where
        T: Send,
        U: Send,
        F: Fn(usize, &mut [T], &mut [U]) + Sync,
    {
        assert!(chunk_len > 0, "chunk_len must be positive");
        assert_eq!(a.len(), b.len(), "zipped slices must have equal length");
        let len = a.len();
        if len == 0 {
            return;
        }
        let n_chunks = (len + chunk_len - 1) / chunk_len;
        let pa = SendPtr(a.as_mut_ptr());
        let pb = SendPtr(b.as_mut_ptr());
        self.run(n_chunks, &|c| {
            let start = c * chunk_len;
            let end = (start + chunk_len).min(len);
            // SAFETY: disjoint chunks; exclusive access pinned by the two
            // `&mut` borrows for the whole `run`.
            let ca = unsafe { std::slice::from_raw_parts_mut(pa.0.add(start), end - start) };
            let cb = unsafe { std::slice::from_raw_parts_mut(pb.0.add(start), end - start) };
            f(c, ca, cb);
        });
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            self.inner.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
// SAFETY: only used to re-slice disjoint chunks of a caller-held `&mut`.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

// ---------------------------------------------------------------------------
// Process-wide pool
// ---------------------------------------------------------------------------

static GLOBAL: OnceLock<RwLock<Arc<Pool>>> = OnceLock::new();

fn global_cell() -> &'static RwLock<Arc<Pool>> {
    GLOBAL.get_or_init(|| RwLock::new(Arc::new(Pool::new(resolve_threads(0)))))
}

/// The process-wide kernel pool.  Defaults to the auto width (see
/// [`resolve_threads`]); [`configure_global`] resizes it.
pub fn global() -> Arc<Pool> {
    global_cell().read().unwrap().clone()
}

/// Install the process-wide pool width resolved from `[hpc] threads`.
/// No-op when the pool already has the requested width, so steady state
/// never respawns; in-flight `global()` handles keep the old pool alive
/// until their jobs finish.
pub fn configure_global(config_threads: usize) {
    let want = resolve_threads(config_threads);
    let cell = global_cell();
    if cell.read().unwrap().threads() == want {
        return;
    }
    *cell.write().unwrap() = Arc::new(Pool::new(want));
}

/// Thread-count resolution: `RELEXI_THREADS` env (CI matrices, bench
/// series) > nonzero `[hpc] threads` config > `available_parallelism()`.
pub fn resolve_threads(config_threads: usize) -> usize {
    let env = std::env::var("RELEXI_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1);
    resolve_from(env, config_threads)
}

fn resolve_from(env: Option<usize>, config_threads: usize) -> usize {
    if let Some(n) = env {
        return n;
    }
    if config_threads >= 1 {
        return config_threads;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn fill_deterministic(threads: usize) -> Vec<f64> {
        let pool = Pool::new(threads);
        let mut out = vec![0.0f64; 1013]; // odd length -> ragged tail chunk
        pool.parallel_chunks_mut(&mut out, 7, |c, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                let g = (c * 7 + i) as f64;
                *x = (g * 0.3).sin() + (g + 1.0).sqrt();
            }
        });
        out
    }

    #[test]
    fn results_bit_identical_across_1_2_8_threads() {
        let a = fill_deterministic(1);
        let b = fill_deterministic(2);
        let c = fill_deterministic(8);
        for i in 0..a.len() {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "lane {i} differs at 2 threads");
            assert_eq!(a[i].to_bits(), c[i].to_bits(), "lane {i} differs at 8 threads");
        }
    }

    #[test]
    fn steady_state_posts_jobs_without_spawning() {
        let pool = Pool::new(4);
        assert_eq!(pool.counters().threads_spawned, 3);
        let hits = AtomicUsize::new(0);
        for _ in 0..100 {
            pool.run(8, &|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 800);
        let c = pool.counters();
        assert_eq!(c.threads_spawned, 3, "steady state must not spawn");
        assert_eq!(c.jobs, 100);
    }

    #[test]
    fn single_thread_and_single_task_run_inline() {
        let solo = Pool::new(1);
        let hits = AtomicUsize::new(0);
        solo.run(5, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 5);
        assert_eq!(solo.counters(), PoolCounters { threads_spawned: 0, jobs: 0 });

        let pool = Pool::new(4);
        pool.run(1, &|_| {});
        assert_eq!(pool.counters().jobs, 0, "single-task jobs bypass posting");
    }

    #[test]
    fn panic_in_task_propagates_and_pool_survives() {
        let pool = Pool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, &|i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "task panic must reach the caller");
        // The pool stays usable afterwards.
        let hits = AtomicUsize::new(0);
        pool.run(8, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn parallel_for_covers_exact_disjoint_ranges() {
        let pool = Pool::new(3);
        let ranges = Mutex::new(Vec::new());
        pool.parallel_for(23, 5, |s, e| {
            ranges.lock().unwrap().push((s, e));
        });
        let mut got = ranges.into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![(0, 5), (5, 10), (10, 15), (15, 20), (20, 23)]);
    }

    #[test]
    fn chunks_mut2_zips_matching_chunks() {
        let pool = Pool::new(4);
        let mut a = vec![0usize; 50];
        let mut b = vec![0usize; 50];
        pool.parallel_chunks_mut2(&mut a, &mut b, 8, |c, ca, cb| {
            assert_eq!(ca.len(), cb.len());
            for (i, (x, y)) in ca.iter_mut().zip(cb.iter_mut()).enumerate() {
                *x = c * 8 + i;
                *y = 2 * (c * 8 + i);
            }
        });
        for i in 0..50 {
            assert_eq!(a[i], i);
            assert_eq!(b[i], 2 * i);
        }
    }

    #[test]
    fn nested_run_degrades_to_inline_instead_of_deadlocking() {
        let pool = Pool::new(4);
        let hits = AtomicUsize::new(0);
        pool.run(4, &|_| {
            pool.run(4, &|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 16);
        assert_eq!(pool.counters().jobs, 1, "inner runs must stay inline");
    }

    #[test]
    fn resolution_precedence_env_config_auto() {
        assert_eq!(resolve_from(Some(3), 8), 3, "env wins over config");
        assert_eq!(resolve_from(None, 8), 8, "nonzero config wins over auto");
        let auto = resolve_from(None, 0);
        assert!(auto >= 1, "auto resolves to available parallelism");
    }

    #[test]
    fn global_reconfigure_swaps_only_on_width_change() {
        // Only exercised when no env override pins the width (the CI
        // matrix sets RELEXI_THREADS, under which configure_global is a
        // no-op by design).
        if std::env::var("RELEXI_THREADS").is_ok() {
            return;
        }
        configure_global(2);
        let p = global();
        assert_eq!(p.threads(), 2);
        configure_global(2);
        assert!(Arc::ptr_eq(&p, &global()), "same width must not respawn");
        configure_global(0); // back to auto for other tests in-process
    }
}
