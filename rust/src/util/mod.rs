//! Foundation utilities built from scratch for this repo (the image's crate
//! registry only carries `xla` + `anyhow`): PRNG, statistics, binary/JSON IO,
//! a criterion-style bench harness, a CLI parser, runtime-dispatched SIMD
//! vectors, and a persistent worker pool for node-level kernel parallelism.

pub mod bench;
pub mod binio;
pub mod cli;
pub mod plot;
pub mod pool;
pub mod retry;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod telemetry;

pub use rng::Rng;
