//! Foundation utilities built from scratch for this repo (the image's crate
//! registry only carries `xla` + `anyhow`): PRNG, statistics, binary/JSON IO,
//! a criterion-style bench harness, and a CLI parser.

pub mod bench;
pub mod binio;
pub mod cli;
pub mod plot;
pub mod rng;
pub mod stats;

pub use rng::Rng;
