//! Explicit-width SIMD building blocks with runtime dispatch.
//!
//! The image's crate registry has no `wide`/`packed_simd`, so this module
//! rolls its own fixed-width vectors as plain arrays with
//! `#[inline(always)]` element-wise ops.  There are deliberately **no raw
//! intrinsics**: hot kernels (the GEMM micro-kernels, the Stockham
//! radix-2/4 butterflies) write their inner loop once against
//! [`F32x8`]/[`F64x4`] and instantiate it twice —
//!
//! * a plain scalar symbol (the reference semantics, always available), and
//! * an `#[target_feature(enable = "avx2")]` symbol (x86_64 only) where the
//!   compiler autovectorizes the very same array ops into 256-bit code —
//!
//! then pick between them at runtime via [`level`] (one cached CPUID probe,
//! overridable with `RELEXI_SIMD=scalar`).  Because both symbols compile
//! identical element-wise arithmetic (and Rust never contracts `a*b + c`
//! into an FMA behind your back), lane-parallel kernels are
//! **bit-identical** across levels; only kernels that reorder a reduction
//! (e.g. the `gemm_nt` dot product, whose accumulator association changes)
//! can differ, and those are asserted at f32 tolerance in tests.

use std::sync::OnceLock;

/// Instruction-set level selected at runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// Portable scalar loops — always available, the reference semantics.
    Scalar,
    /// 256-bit AVX2 instantiations of the same kernels (x86_64 only).
    Avx2,
}

impl Level {
    /// Stable label for bench rows and logs.
    pub fn label(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Avx2 => "avx2",
        }
    }
}

static LEVEL: OnceLock<Level> = OnceLock::new();

/// The dispatch level for this process: one CPUID probe, cached.  Set
/// `RELEXI_SIMD=scalar` to force the reference path (the override can only
/// lower the level — never force an ISA the CPU lacks).
pub fn level() -> Level {
    *LEVEL.get_or_init(|| detect(std::env::var("RELEXI_SIMD").ok().as_deref()))
}

/// Pure resolution (testable without touching process env).
fn detect(override_env: Option<&str>) -> Level {
    if let Some(v) = override_env {
        if v.eq_ignore_ascii_case("scalar") {
            return Level::Scalar;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Level::Avx2;
        }
    }
    Level::Scalar
}

/// Eight `f32` lanes (one AVX2 `ymm` worth).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct F32x8(pub [f32; 8]);

impl F32x8 {
    pub const LANES: usize = 8;

    #[inline(always)]
    pub fn splat(x: f32) -> Self {
        F32x8([x; 8])
    }

    /// Load from the first 8 elements of `s` (panics when shorter).
    #[inline(always)]
    pub fn load(s: &[f32]) -> Self {
        let mut v = [0.0f32; 8];
        v.copy_from_slice(&s[..8]);
        F32x8(v)
    }

    /// Store into the first 8 elements of `s` (panics when shorter).
    #[inline(always)]
    pub fn store(self, s: &mut [f32]) {
        s[..8].copy_from_slice(&self.0);
    }

    #[inline(always)]
    pub fn add(self, o: Self) -> Self {
        let mut v = [0.0f32; 8];
        for i in 0..8 {
            v[i] = self.0[i] + o.0[i];
        }
        F32x8(v)
    }

    #[inline(always)]
    pub fn sub(self, o: Self) -> Self {
        let mut v = [0.0f32; 8];
        for i in 0..8 {
            v[i] = self.0[i] - o.0[i];
        }
        F32x8(v)
    }

    #[inline(always)]
    pub fn mul(self, o: Self) -> Self {
        let mut v = [0.0f32; 8];
        for i in 0..8 {
            v[i] = self.0[i] * o.0[i];
        }
        F32x8(v)
    }

    /// Horizontal sum with a FIXED pairwise tree — the same association on
    /// every dispatch level, so a reduction built on it differs from a
    /// scalar running sum only by rounding (tested tolerance), and never
    /// differs between scalar and AVX2 instantiations of the same kernel.
    #[inline(always)]
    pub fn hsum(self) -> f32 {
        let v = &self.0;
        ((v[0] + v[1]) + (v[2] + v[3])) + ((v[4] + v[5]) + (v[6] + v[7]))
    }
}

/// Four `f64` lanes (one AVX2 `ymm` worth) — two interleaved complex
/// numbers `[re0, im0, re1, im1]` in the FFT kernels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct F64x4(pub [f64; 4]);

impl F64x4 {
    pub const LANES: usize = 4;

    #[inline(always)]
    pub fn splat(x: f64) -> Self {
        F64x4([x; 4])
    }

    /// Load from the first 4 elements of `s` (panics when shorter).
    #[inline(always)]
    pub fn load(s: &[f64]) -> Self {
        let mut v = [0.0f64; 4];
        v.copy_from_slice(&s[..4]);
        F64x4(v)
    }

    /// Store into the first 4 elements of `s` (panics when shorter).
    #[inline(always)]
    pub fn store(self, s: &mut [f64]) {
        s[..4].copy_from_slice(&self.0);
    }

    #[inline(always)]
    pub fn add(self, o: Self) -> Self {
        let mut v = [0.0f64; 4];
        for i in 0..4 {
            v[i] = self.0[i] + o.0[i];
        }
        F64x4(v)
    }

    #[inline(always)]
    pub fn sub(self, o: Self) -> Self {
        let mut v = [0.0f64; 4];
        for i in 0..4 {
            v[i] = self.0[i] - o.0[i];
        }
        F64x4(v)
    }

    #[inline(always)]
    pub fn mul(self, o: Self) -> Self {
        let mut v = [0.0f64; 4];
        for i in 0..4 {
            v[i] = self.0[i] * o.0[i];
        }
        F64x4(v)
    }

    /// Swap adjacent lanes: `[a, b, c, d] -> [b, a, d, c]`.  On two
    /// interleaved complex numbers this turns `[re, im, re, im]` into
    /// `[im, re, im, re]` — the building block of the exact complex
    /// multiply in the FFT butterflies (a `vpermilpd` under AVX2).
    #[inline(always)]
    pub fn swap_pairs(self) -> Self {
        let v = self.0;
        F64x4([v[1], v[0], v[3], v[2]])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_override_wins_regardless_of_cpu() {
        assert_eq!(detect(Some("scalar")), Level::Scalar);
        assert_eq!(detect(Some("SCALAR")), Level::Scalar);
    }

    #[test]
    fn unknown_override_falls_back_to_probe() {
        // "auto"/garbage never forces an ISA up — it just defers to the
        // CPU probe, which must agree with the no-override result.
        assert_eq!(detect(Some("auto")), detect(None));
    }

    #[test]
    fn level_is_cached_and_stable() {
        assert_eq!(level(), level());
    }

    #[test]
    fn f32x8_elementwise_ops() {
        let a = F32x8::load(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let b = F32x8::splat(2.0);
        assert_eq!(a.add(b).0, [3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0]);
        assert_eq!(a.sub(b).0, [-1.0, 0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.mul(b).0, [2.0, 4.0, 6.0, 8.0, 10.0, 12.0, 14.0, 16.0]);
        let mut out = [0.0f32; 8];
        a.store(&mut out);
        assert_eq!(out, a.0);
    }

    #[test]
    fn f32x8_hsum_uses_the_fixed_tree() {
        let a = F32x8::load(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.hsum(), 36.0);
        // The association is pinned: ((0+1)+(2+3)) + ((4+5)+(6+7)).
        let v = [1e8f32, 1.0, -1e8, 1.0, 1e8, 1.0, -1e8, 1.0];
        let expect = ((v[0] + v[1]) + (v[2] + v[3])) + ((v[4] + v[5]) + (v[6] + v[7]));
        assert_eq!(F32x8(v).hsum().to_bits(), expect.to_bits());
    }

    #[test]
    fn f64x4_ops_and_swap_pairs() {
        let a = F64x4::load(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.swap_pairs().0, [2.0, 1.0, 4.0, 3.0]);
        assert_eq!(a.add(F64x4::splat(1.0)).0, [2.0, 3.0, 4.0, 5.0]);
        assert_eq!(a.sub(F64x4::splat(1.0)).0, [0.0, 1.0, 2.0, 3.0]);
        assert_eq!(a.mul(F64x4::splat(3.0)).0, [3.0, 6.0, 9.0, 12.0]);
        let mut out = [0.0f64; 4];
        a.store(&mut out);
        assert_eq!(out, a.0);
    }

    #[test]
    fn complex_multiply_via_swap_pairs_is_bit_exact() {
        // The FFT kernels compute (re,im)*(wr,wi) as
        //   d*splat(wr) + swap_pairs(d)*[-wi, wi, -wi, wi]
        // which must match the scalar complex product bit-for-bit:
        // products share sign rules and x + (-y) == x - y in IEEE-754.
        let d = F64x4([0.3, -1.7, 2.5, 0.01]);
        let (wr, wi) = (0.8090169943749475, -0.5877852522924731);
        let got = d
            .mul(F64x4::splat(wr))
            .add(d.swap_pairs().mul(F64x4([-wi, wi, -wi, wi])));
        for pair in 0..2 {
            let (re, im) = (d.0[2 * pair], d.0[2 * pair + 1]);
            let sre = re * wr - im * wi;
            let sim = re * wi + im * wr;
            assert_eq!(got.0[2 * pair].to_bits(), sre.to_bits());
            assert_eq!(got.0[2 * pair + 1].to_bits(), sim.to_bits());
        }
    }
}
