//! Binary / text IO helpers: f32 vectors (artifact `params0_*.bin`,
//! checkpoints), CSV emission for experiment results, and a minimal JSON
//! reader for the artifact manifest and test vectors (no serde in the
//! image's crate set — see DESIGN.md §9).

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::Write;
use std::path::Path;

/// Read a little-endian f32 vector from a file.
pub fn read_f32_vec(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("read {path:?}"))?;
    if bytes.len() % 4 != 0 {
        bail!("{path:?}: length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a little-endian f32 vector to a file.
pub fn write_f32_vec(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("write {path:?}"))?;
    Ok(())
}

/// Append-or-create CSV writer with a fixed header.
pub struct CsvWriter {
    file: std::fs::File,
}

impl CsvWriter {
    /// Create/truncate `path` and write the header row.
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file })
    }

    /// Write one row of values.
    pub fn row(&mut self, values: &[String]) -> Result<()> {
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    /// Convenience: write a row of f64s.
    pub fn row_f64(&mut self, values: &[f64]) -> Result<()> {
        let v: Vec<String> = values.iter().map(|x| format!("{x}")).collect();
        self.row(&v)
    }
}

// ---------------------------------------------------------------------------
// Minimal JSON (subset: objects, arrays, strings, numbers, bools, null)
// ---------------------------------------------------------------------------

/// Parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = JsonParser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    /// Numeric value.
    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// String value.
    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    /// Array value.
    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    /// Array of numbers as f32.
    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self
            .arr()?
            .iter()
            .map(|j| j.num().map(|x| x as f32))
            .collect::<Result<Vec<_>>>()?)
    }
}

struct JsonParser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> JsonParser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = HashMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape"),
                    }
                }
                _ => s.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| format!("bad number {text:?}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let dir = std::env::temp_dir().join("relexi_binio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v.bin");
        let data = vec![1.5f32, -2.25, 0.0, f32::MAX];
        write_f32_vec(&path, &data).unwrap();
        assert_eq!(read_f32_vec(&path).unwrap(), data);
    }

    #[test]
    fn json_parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny"}, "d": true}"#).unwrap();
        assert_eq!(j.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(j.get("a").unwrap().arr().unwrap()[2].num().unwrap(), -300.0);
        assert_eq!(j.get("b").unwrap().get("c").unwrap().str().unwrap(), "x\ny");
        assert_eq!(*j.get("d").unwrap(), Json::Bool(true));
    }

    #[test]
    fn json_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\": 1} extra").is_err());
    }

    #[test]
    fn json_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert!(matches!(Json::parse("{}").unwrap(), Json::Obj(_)));
    }
}
