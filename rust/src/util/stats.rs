//! Small statistics helpers shared by benches, metrics and tests.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Minimum (NaN-free input assumed); +inf for empty.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Maximum; -inf for empty.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Linear-interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Fixed-width histogram over `[lo, hi)`; values outside clamp to edge bins.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    if xs.is_empty() || bins == 0 || hi <= lo {
        return h;
    }
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let b = (((x - lo) / w) as isize).clamp(0, bins as isize - 1) as usize;
        h[b] += 1;
    }
    h
}

/// Render a histogram as a terminal bar chart (used by spectrum_compare).
pub fn ascii_histogram(xs: &[f64], lo: f64, hi: f64, bins: usize, width: usize) -> String {
    let h = histogram(xs, lo, hi, bins);
    let maxc = h.iter().copied().max().unwrap_or(1).max(1);
    let mut out = String::new();
    let w = (hi - lo) / bins as f64;
    for (i, &c) in h.iter().enumerate() {
        let bar = "#".repeat(c * width / maxc);
        out.push_str(&format!(
            "{:>8.3} | {:<width$} {}\n",
            lo + (i as f64 + 0.5) * w,
            bar,
            c,
            width = width
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn median_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn histogram_counts_and_clamps() {
        let h = histogram(&[-1.0, 0.1, 0.5, 0.9, 2.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 3]);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert!(percentile(&[], 50.0).is_nan());
    }
}
