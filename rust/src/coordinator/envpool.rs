//! The persistent, event-driven environment runtime — the heart of the
//! Relexi dataflow (paper Fig. 2 / Algorithm 1), split into two halves:
//!
//! * **Worker pool** (the "FLEXI instances", Fig. 2 left): one OS thread
//!   and one [`LesEnv`] per environment, built **once** in
//!   [`EnvPool::new`] and reused for every training iteration.  Workers
//!   block on a per-iteration begin message carrying the iteration's key
//!   namespace ([`Protocol`]) and RNG stream, run one episode — write
//!   state, poll action, advance `dt_RL`, write the spectrum error, raise
//!   the done-flag at termination (§3.1) — and park again.  Steady-state
//!   iterations therefore spawn zero threads and rebuild zero
//!   `LesEnv`/`Grid` instances (asserted by [`PoolCounters`]).
//!
//! * **Rollout collector** (the trainer side of Algorithm 1, lines 4-13):
//!   consumes env states **in arrival order** through the store's
//!   multi-key subscription ([`Client::poll_any_take`]) instead of one
//!   blocking poll per env, batches the policy over whichever states have
//!   arrived once `min_batch` are staged, and keeps per-env done/error
//!   bookkeeping so an early-terminating env can never stall the batch —
//!   the synchronization overhead paper §6.2 measures.  With
//!   `min_batch = n_envs` (the default) the collector waits for the full
//!   wave and reproduces the paper's synchronous PPO bit-for-bit; the
//!   retained [`EnvPool::collect_lockstep_with`] reference implements the
//!   literal per-env polling loop for that equivalence test and for the
//!   §6.2 baseline bench.
//!
//! Heterogeneous pools: each env runs a scenario variant
//! ([`crate::config::EnvVariant`], round-robin), so one pool can sample
//! across Reynolds-number, reward-shaping, horizon and initial-state
//! families while sharing one `Grid`, one truth package and one policy.

use crate::config::RunConfig;
use crate::orchestrator::{Client, Orchestrator, Protocol, Value};
use crate::rl::{gaussian, reward_from_error, Episode, LesEnv, StepRecord};
use crate::runtime::{PolicyOut, PolicyRuntime};
use crate::solver::dns::Truth;
use crate::solver::Grid;
use crate::util::Rng;
use anyhow::{anyhow, bail, Context, Result};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Timeout for any single poll; generous because env steps include real
/// CFD work.
const POLL_TIMEOUT: Duration = Duration::from_secs(600);

/// Result of one sampling phase.
pub struct Rollouts {
    pub episodes: Vec<Episode>,
    /// Wall-clock seconds spent sampling (the paper's §6.2 metric).
    pub sample_time_s: f64,
    /// Wall-clock seconds the trainer spent inside policy inference.
    pub policy_time_s: f64,
    /// Wall-clock seconds the trainer spent blocked on arrivals (the
    /// synchronization overhead the event-driven collector attacks).
    pub idle_time_s: f64,
}

/// Construction counters proving worker persistence: after `new`, no
/// call ever increments them again.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolCounters {
    /// OS threads spawned (== n_envs, only in `new`).
    pub threads_spawned: usize,
    /// `LesEnv` instances constructed (== n_envs, only in `new`).
    pub envs_built: usize,
    /// Spectral grids constructed (== 1, only in `new`).
    pub grids_built: usize,
    /// Sampling phases served by the persistent workers.
    pub iterations: usize,
}

/// Per-iteration begin message a parked worker blocks on.
struct Begin {
    proto: Protocol,
    rng: Rng,
}

/// Collects rollouts from `n_envs` persistent parallel environments.
pub struct EnvPool {
    cfg: RunConfig,
    grid: Arc<Grid>,
    /// Begin-message channels, one per worker (dropping them shuts the
    /// pool down).
    txs: Vec<mpsc::Sender<Begin>>,
    handles: Vec<JoinHandle<()>>,
    counters: PoolCounters,
    /// Client + last begun protocol, so `Drop` can raise the abort flag
    /// for workers still blocked inside an interrupted iteration.
    abort_client: Client,
    current_proto: Option<Protocol>,
    /// Per-env resolved bookkeeping (round-robin variants).
    variant_of: Vec<usize>,
    alpha_of: Vec<f64>,
    n_actions_of: Vec<usize>,
    /// Observation features per element ((N+1)^3 * 3).
    feat: usize,
    /// Elements per env.
    n_elems: usize,
    /// Reused forward-batch scratch (n_envs * n_elems * feat floats,
    /// allocated once here, never per iteration).
    batch_obs: Vec<f32>,
}

impl EnvPool {
    /// Build the pool for a run configuration and its ground truth:
    /// construct the shared spectral grid, every `LesEnv` (one scenario
    /// variant each) and every worker thread exactly once.  All later
    /// iterations reuse them.
    pub fn new(cfg: RunConfig, truth: Arc<Truth>, orch: &Orchestrator) -> Result<EnvPool> {
        cfg.validate()?;
        let n_envs = cfg.rl.n_envs;
        if cfg.rl.split_init_pool {
            anyhow::ensure!(
                truth.states.len() >= cfg.n_variants(),
                "split_init_pool needs >= {} truth states (one per variant), got {}",
                cfg.n_variants(),
                truth.states.len()
            );
        }
        // One shared spectral grid for the whole pool: `fft::Plan` is
        // `Send + Sync`, so every worker reuses the same twiddle tables.
        let grid = Arc::new(Grid::new(cfg.case.points_per_dir()));
        let mut counters = PoolCounters {
            threads_spawned: 0,
            envs_built: 0,
            grids_built: 1,
            iterations: 0,
        };

        let mut txs = Vec::with_capacity(n_envs);
        let mut handles = Vec::with_capacity(n_envs);
        let mut variant_of = Vec::with_capacity(n_envs);
        let mut alpha_of = Vec::with_capacity(n_envs);
        let mut n_actions_of = Vec::with_capacity(n_envs);
        for i in 0..n_envs {
            let rv = cfg.variant_for(i);
            let mut env = LesEnv::with_grid(&rv.case, &rv.solver, truth.clone(), grid.clone())
                .with_context(|| format!("env {i} (variant {})", rv.name))?;
            if let Some((family, m)) = rv.init_family {
                env.set_init_family(family, m)
                    .with_context(|| format!("env {i} (variant {})", rv.name))?;
            }
            counters.envs_built += 1;
            variant_of.push(rv.index);
            alpha_of.push(rv.case.alpha);
            n_actions_of.push(env.n_actions());

            let (tx, rx) = mpsc::channel::<Begin>();
            let client = orch.client();
            let handle = std::thread::Builder::new()
                .name(format!("env-worker-{i}"))
                .spawn(move || worker_loop(env, client, i, rx))?;
            counters.threads_spawned += 1;
            txs.push(tx);
            handles.push(handle);
        }

        let n_elems = cfg.case.total_elems();
        let feat = cfg.case.elem_points().pow(3) * 3;
        Ok(EnvPool {
            batch_obs: vec![0f32; n_envs * n_elems * feat],
            cfg,
            grid,
            txs,
            handles,
            counters,
            abort_client: orch.client(),
            current_proto: None,
            variant_of,
            alpha_of,
            n_actions_of,
            feat,
            n_elems,
        })
    }

    /// Elements per env (actions per step per env).
    pub fn n_elems(&self) -> usize {
        self.n_elems
    }

    /// The spectral grid shared by every env in the pool.
    pub fn grid(&self) -> Arc<Grid> {
        self.grid.clone()
    }

    /// Construction counters (steady-state assertion: unchanged across
    /// `collect` calls).
    pub fn counters(&self) -> PoolCounters {
        self.counters
    }

    /// Run one sampling phase under the current policy (`theta`),
    /// event-driven with the configured `rl.min_batch` (0 = full batch =
    /// synchronous PPO).  `run_tag` via `proto` namespaces the keys; `rng`
    /// drives initial-state draws and action sampling.
    pub fn collect(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        policy: &PolicyRuntime,
        theta: &[f32],
        rng: &mut Rng,
        deterministic: bool,
    ) -> Result<Rollouts> {
        anyhow::ensure!(
            policy.features() == self.feat,
            "policy features {} != pool features {}",
            policy.features(),
            self.feat
        );
        let min_batch = self.cfg.min_batch_effective();
        self.collect_with(
            orch,
            proto,
            |obs, n| policy.forward(theta, obs, n),
            rng,
            deterministic,
            min_batch,
        )
    }

    /// Event-driven sampling phase with an explicit policy closure
    /// (`forward(obs, n_samples)`) — the policy-agnostic core, also used
    /// by tests and benches that run without compiled artifacts.
    pub fn collect_with<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        forward: F,
        rng: &mut Rng,
        deterministic: bool,
        min_batch: usize,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let res = self.collect_event_inner(orch, proto, forward, rng, deterministic, min_batch);
        self.finish_iteration(proto, res.is_err());
        res
    }

    fn collect_event_inner<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        mut forward: F,
        rng: &mut Rng,
        deterministic: bool,
        min_batch: usize,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let t_start = Instant::now();
        let n_envs = self.cfg.rl.n_envs;
        let chunk = self.n_elems * self.feat;
        let trainer = orch.client();
        self.begin_iteration(proto, rng)?;
        let keys = KeyCache::new(proto, &self.n_actions_of);

        let mut episodes = self.fresh_episodes();
        // Per-env: step index of the state we are waiting for (None once
        // the done-flag arrived), plus staged-but-unacted states and
        // outstanding error scalars.
        let mut expect_state: Vec<Option<usize>> = vec![Some(0); n_envs];
        let mut staged: Vec<(usize, usize, Vec<f32>)> = Vec::with_capacity(n_envs);
        let mut pending_errs: Vec<(usize, usize)> = Vec::with_capacity(n_envs);
        let mut policy_time = 0.0f64;
        let mut idle_time = 0.0f64;

        // Scratch for the per-event subscription (&str views into `keys`).
        let mut subs: Vec<&str> = Vec::new();
        let mut events: Vec<Event> = Vec::new();
        let mut fail_subbed = vec![false; n_envs];

        loop {
            let expecting = expect_state.iter().filter(|e| e.is_some()).count();
            if expecting == 0 && staged.is_empty() && pending_errs.is_empty() {
                break;
            }

            // Flush the policy batch once enough states arrived — or once
            // no further state can arrive without us acting first.
            if !staged.is_empty() && (staged.len() >= min_batch || expecting == 0) {
                staged.sort_unstable_by_key(|&(env, _, _)| env);
                let n_act = staged.len();
                for (k, (_, _, obs)) in staged.iter().enumerate() {
                    self.batch_obs[k * chunk..(k + 1) * chunk].copy_from_slice(obs);
                }
                let tp = Instant::now();
                let out = forward(&self.batch_obs[..n_act * chunk], n_act * self.n_elems)?;
                policy_time += tp.elapsed().as_secs_f64();
                anyhow::ensure!(
                    out.mean.len() == n_act * self.n_elems
                        && out.value.len() == n_act * self.n_elems,
                    "policy returned {} means for {} samples",
                    out.mean.len(),
                    n_act * self.n_elems
                );

                // Sample + write actions in env order (ties the RNG stream
                // to env indices, not arrival order: full-batch collection
                // is bitwise-identical to the lock-step reference).
                for (k, (env, t, obs)) in staged.drain(..).enumerate() {
                    let mean = &out.mean[k * self.n_elems..(k + 1) * self.n_elems];
                    let value = &out.value[k * self.n_elems..(k + 1) * self.n_elems];
                    let act = if deterministic {
                        mean.to_vec()
                    } else {
                        gaussian::sample(mean, out.log_std, rng)
                    };
                    let logp = gaussian::log_prob(&act, mean, out.log_std);
                    trainer.put_tensor(&keys.action[env][t], vec![self.n_elems], act.clone());
                    episodes[env].steps.push(StepRecord {
                        obs,
                        act,
                        logp,
                        value: value.to_vec(),
                        reward: 0.0, // filled by the error event
                    });
                    pending_errs.push((env, t));
                    expect_state[env] = Some(t + 1);
                }
                continue;
            }

            // Wait for the next event: any outstanding state, error,
            // done-flag or failure report, whichever arrives first.  Each
            // involved env's fail key is subscribed exactly once.
            subs.clear();
            events.clear();
            fail_subbed.fill(false);
            for (env, e) in expect_state.iter().enumerate() {
                if let Some(t) = e {
                    subs.push(&keys.state[env][*t]);
                    events.push(Event::State(env, *t));
                    subs.push(&keys.done[env]);
                    events.push(Event::Done(env));
                    subs.push(&keys.fail[env]);
                    events.push(Event::Fail(env));
                    fail_subbed[env] = true;
                }
            }
            for &(env, t) in &pending_errs {
                subs.push(&keys.err[env][t]);
                events.push(Event::Err(env, t));
                if !fail_subbed[env] {
                    subs.push(&keys.fail[env]);
                    events.push(Event::Fail(env));
                    fail_subbed[env] = true;
                }
            }
            let ti = Instant::now();
            let (hit, val) = trainer
                .poll_any_take(&subs, POLL_TIMEOUT)
                .with_context(|| {
                    format!(
                        "collector timed out: {} states expected, {} errors pending",
                        expect_state.iter().filter(|e| e.is_some()).count(),
                        pending_errs.len()
                    )
                })?;
            idle_time += ti.elapsed().as_secs_f64();
            match events[hit] {
                Event::State(env, t) => {
                    let data = match val {
                        Value::Tensor { data, .. } => data,
                        other => bail!("env {env} state at step {t} is {other:?}, not a tensor"),
                    };
                    anyhow::ensure!(
                        data.len() == chunk,
                        "env {env} state has {} floats, expected {chunk}",
                        data.len()
                    );
                    staged.push((env, t, data));
                    expect_state[env] = None; // parked in `staged` until acted on
                }
                Event::Done(env) => {
                    expect_state[env] = None;
                }
                Event::Err(env, t) => {
                    let err = val
                        .as_scalar()
                        .with_context(|| format!("env {env} error at step {t} not a scalar"))?;
                    episodes[env].steps[t].reward = reward_from_error(err, self.alpha_of[env]);
                    pending_errs.retain(|&(e, s)| (e, s) != (env, t));
                }
                Event::Fail(env) => {
                    bail!("env worker {env} failed: {}", fail_message(&val));
                }
            }
        }

        self.counters.iterations += 1;
        Ok(Rollouts {
            episodes,
            sample_time_s: t_start.elapsed().as_secs_f64(),
            policy_time_s: policy_time,
            idle_time_s: idle_time,
        })
    }

    /// Lock-step reference collector: the paper's literal synchronous
    /// gather — one wave per RL step, states polled env-by-env — kept as
    /// the bitwise-equivalence oracle for the event-driven path and as
    /// the §6.2 baseline for the training bench.  Unlike the seed
    /// implementation it checks the done-flag at every step, so an env
    /// that terminates early can no longer wedge the gather loop until
    /// the poll timeout.
    pub fn collect_lockstep_with<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        forward: F,
        rng: &mut Rng,
        deterministic: bool,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let res = self.collect_lockstep_inner(orch, proto, forward, rng, deterministic);
        self.finish_iteration(proto, res.is_err());
        res
    }

    fn collect_lockstep_inner<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        mut forward: F,
        rng: &mut Rng,
        deterministic: bool,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let t_start = Instant::now();
        let n_envs = self.cfg.rl.n_envs;
        let chunk = self.n_elems * self.feat;
        let trainer = orch.client();
        self.begin_iteration(proto, rng)?;
        let keys = KeyCache::new(proto, &self.n_actions_of);

        let mut episodes = self.fresh_episodes();
        let mut done = vec![false; n_envs];
        let mut acted: Vec<usize> = Vec::with_capacity(n_envs);
        let mut policy_time = 0.0f64;
        let mut idle_time = 0.0f64;
        let max_t = self.n_actions_of.iter().copied().max().unwrap_or(0);

        for t in 0..max_t {
            // Gather the wave's states in env order, checking the
            // done-flag per env so early terminations are absorbed.
            acted.clear();
            for env in 0..n_envs {
                if done[env] {
                    continue;
                }
                let ti = Instant::now();
                let (hit, val) = trainer
                    .poll_any_take(
                        &[&keys.state[env][t], &keys.done[env], &keys.fail[env]],
                        POLL_TIMEOUT,
                    )
                    .with_context(|| format!("trainer: no state from env {env} step {t}"))?;
                idle_time += ti.elapsed().as_secs_f64();
                match hit {
                    0 => {
                        let (_, data) = val.as_tensor().context("state must be a tensor")?;
                        anyhow::ensure!(
                            data.len() == chunk,
                            "env {env} state has {} floats, expected {chunk}",
                            data.len()
                        );
                        self.batch_obs[acted.len() * chunk..(acted.len() + 1) * chunk]
                            .copy_from_slice(data);
                        acted.push(env);
                    }
                    1 => done[env] = true,
                    _ => bail!("env worker {env} failed: {}", fail_message(&val)),
                }
            }
            if acted.is_empty() {
                break; // every env terminated before the longest horizon
            }

            // One batched policy evaluation for the wave.
            let n_act = acted.len();
            let tp = Instant::now();
            let out = forward(&self.batch_obs[..n_act * chunk], n_act * self.n_elems)?;
            policy_time += tp.elapsed().as_secs_f64();

            // Sample actions, write them back, record the steps.
            for (k, &env) in acted.iter().enumerate() {
                let mean = &out.mean[k * self.n_elems..(k + 1) * self.n_elems];
                let value = &out.value[k * self.n_elems..(k + 1) * self.n_elems];
                let act = if deterministic {
                    mean.to_vec()
                } else {
                    gaussian::sample(mean, out.log_std, rng)
                };
                let logp = gaussian::log_prob(&act, mean, out.log_std);
                trainer.put_tensor(&keys.action[env][t], vec![self.n_elems], act.clone());
                episodes[env].steps.push(StepRecord {
                    obs: self.batch_obs[k * chunk..(k + 1) * chunk].to_vec(),
                    act,
                    logp,
                    value: value.to_vec(),
                    reward: 0.0, // filled in below
                });
            }

            // Collect the spectrum errors -> rewards (Eqs. 4-5).
            for &env in &acted {
                let ti = Instant::now();
                let (hit, val) = trainer
                    .poll_any_take(&[&keys.err[env][t], &keys.fail[env]], POLL_TIMEOUT)
                    .with_context(|| format!("trainer: no error from env {env} step {t}"))?;
                idle_time += ti.elapsed().as_secs_f64();
                if hit != 0 {
                    bail!("env worker {env} failed: {}", fail_message(&val));
                }
                let err = val.as_scalar().context("error must be a scalar")?;
                episodes[env].steps[t].reward = reward_from_error(err, self.alpha_of[env]);
            }
        }

        // Every env must have signalled termination.
        for env in 0..n_envs {
            if done[env] {
                continue;
            }
            let (hit, val) = trainer
                .poll_any_take(&[&keys.done[env], &keys.fail[env]], POLL_TIMEOUT)
                .with_context(|| format!("env {env} never signalled done"))?;
            if hit != 0 {
                bail!("env worker {env} failed: {}", fail_message(&val));
            }
        }

        self.counters.iterations += 1;
        Ok(Rollouts {
            episodes,
            sample_time_s: t_start.elapsed().as_secs_f64(),
            policy_time_s: policy_time,
            idle_time_s: idle_time,
        })
    }

    /// Raise the iteration's abort flag so workers still blocked on an
    /// action key of a failed iteration unpark immediately (instead of
    /// running out POLL_TIMEOUT) and return to the begin-channel, leaving
    /// the pool usable for a retry.
    fn abort_iteration(&self, proto: &Protocol) {
        self.abort_client.put_flag(&proto.abort_key(), true);
    }

    /// Close out one sampling phase: on failure raise the abort flag; on
    /// success forget the protocol so a later `Drop` does not write a
    /// stray abort key for a cleanly completed iteration.
    fn finish_iteration(&mut self, proto: &Protocol, failed: bool) {
        if failed {
            self.abort_iteration(proto);
        } else {
            self.current_proto = None;
        }
    }

    /// Wake every parked worker for one iteration (per-env RNG streams
    /// split in env order, exactly as the seed's spawn loop did).
    fn begin_iteration(&mut self, proto: &Protocol, rng: &mut Rng) -> Result<()> {
        self.current_proto = Some(proto.clone());
        for (i, tx) in self.txs.iter().enumerate() {
            tx.send(Begin {
                proto: proto.clone(),
                rng: rng.split(i as u64),
            })
            .map_err(|_| anyhow!("env worker {i} has exited (earlier panic?)"))?;
        }
        Ok(())
    }

    /// Empty per-env episodes tagged with their scenario variants.
    fn fresh_episodes(&self) -> Vec<Episode> {
        self.variant_of
            .iter()
            .map(|&variant| Episode {
                variant,
                ..Episode::default()
            })
            .collect()
    }
}

impl Drop for EnvPool {
    fn drop(&mut self) {
        // Unblock workers stuck mid-iteration (e.g. after an external
        // kill): they subscribe to the abort flag next to their action
        // key, so this wakes them without waiting out the poll timeout.
        if let Some(proto) = self.current_proto.take() {
            self.abort_iteration(&proto);
        }
        // Dropping the begin-channels unparks every idle worker with a
        // recv error, which is the shutdown signal.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One collector event: a key subscription resolved to its meaning.
#[derive(Clone, Copy)]
enum Event {
    /// State tensor from env at step.
    State(usize, usize),
    /// Done-flag: no further states from this env.
    Done(usize),
    /// Spectrum-error scalar for (env, step).
    Err(usize, usize),
    /// Worker failure report.
    Fail(usize),
}

/// All key strings one iteration can touch, built once per iteration so
/// the event loop only pushes `&str` views instead of formatting keys on
/// every wait.
struct KeyCache {
    /// `state[env][t]`, `t` up to and including the never-written
    /// post-terminal index (the done-flag resolves that wait).
    state: Vec<Vec<String>>,
    action: Vec<Vec<String>>,
    err: Vec<Vec<String>>,
    done: Vec<String>,
    fail: Vec<String>,
}

impl KeyCache {
    fn new(proto: &Protocol, n_actions_of: &[usize]) -> KeyCache {
        KeyCache {
            state: n_actions_of
                .iter()
                .enumerate()
                .map(|(i, &n)| (0..=n).map(|t| proto.state_key(i, t)).collect())
                .collect(),
            action: n_actions_of
                .iter()
                .enumerate()
                .map(|(i, &n)| (0..n).map(|t| proto.action_key(i, t)).collect())
                .collect(),
            err: n_actions_of
                .iter()
                .enumerate()
                .map(|(i, &n)| (0..n).map(|t| proto.error_key(i, t)).collect())
                .collect(),
            done: (0..n_actions_of.len()).map(|i| proto.done_key(i)).collect(),
            fail: (0..n_actions_of.len()).map(|i| proto.fail_key(i)).collect(),
        }
    }
}

/// Render a failure-report value (bytes put by the worker) for an error.
fn fail_message(val: &Value) -> String {
    match val {
        Value::Bytes(b) => String::from_utf8_lossy(b).into_owned(),
        other => format!("{other:?}"),
    }
}

/// The persistent worker body: park on the begin-channel, run one episode
/// through the store, park again.  Exits when the pool drops the channel.
///
/// Both `Err` returns and panics inside the episode (caught so the thread
/// survives; the next begin resets the env completely) are surfaced
/// through the fail key, so the collector aborts the iteration instead of
/// running into its poll timeout.
fn worker_loop(mut env: LesEnv, client: Client, idx: usize, rx: mpsc::Receiver<Begin>) {
    while let Ok(Begin { proto, mut rng }) = rx.recv() {
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_episode(&mut env, &client, &proto, idx, &mut rng)
        }));
        let failure = match outcome {
            Ok(Ok(())) => None,
            Ok(Err(e)) => Some(format!("{e:#}")),
            Err(payload) => Some(format!("panic: {}", panic_message(&payload))),
        };
        if let Some(msg) = failure {
            client.put_bytes(&proto.fail_key(idx), msg.into_bytes());
        }
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// One episode of the paper's env side (Fig. 2 right): reset from the
/// truth pool, then state-out / action-in / error-out per RL step, with
/// the done-flag raised at termination.
fn run_episode(
    env: &mut LesEnv,
    client: &Client,
    proto: &Protocol,
    idx: usize,
    rng: &mut Rng,
) -> Result<()> {
    let obs = env.reset(rng, false);
    client.put_tensor(&proto.state_key(idx, 0), vec![obs.len()], obs);
    let abort_key = proto.abort_key();
    for t in 0..env.n_actions() {
        let action_key = proto.action_key(idx, t);
        let (hit, act) = client
            .poll_any(&[&action_key, &abort_key], POLL_TIMEOUT)
            .with_context(|| format!("env {idx}: no action at step {t}"))?;
        anyhow::ensure!(hit == 0, "env {idx}: iteration aborted at step {t}");
        // Consume the action (seed semantics): only the shared abort flag
        // must stay readable by every worker, so the subscription above is
        // non-consuming and the action is deleted explicitly.
        client.delete(&action_key);
        let cs: Vec<f64> = act
            .as_tensor()
            .context("action must be a tensor")?
            .1
            .iter()
            .map(|&a| a as f64)
            .collect();
        let out = env.step(&cs);
        client.put_scalar(&proto.error_key(idx, t), out.spec_error);
        if out.done {
            client.put_flag(&proto.done_key(idx), true);
            break;
        }
        let obs = env.observe();
        client.put_tensor(&proto.state_key(idx, t + 1), vec![obs.len()], obs);
    }
    Ok(())
}
