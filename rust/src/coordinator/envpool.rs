//! The persistent, event-driven environment runtime — the heart of the
//! Relexi dataflow (paper Fig. 2 / Algorithm 1), split into two halves:
//!
//! * **Worker pool** (the "FLEXI instances", Fig. 2 left): one OS thread
//!   and one [`LesEnv`] per environment, built **once** in
//!   [`EnvPool::new`] and reused for every training iteration.  Workers
//!   block on a per-iteration begin message carrying the iteration's key
//!   namespace ([`Protocol`]) and RNG stream, run one episode — write
//!   state, poll action, advance `dt_RL`, write the spectrum error, raise
//!   the done-flag at termination (§3.1) — and park again.  Steady-state
//!   iterations therefore spawn zero threads and rebuild zero
//!   `LesEnv`/`Grid` instances (asserted by [`PoolCounters`]).
//!
//! * **Rollout collector** (the trainer side of Algorithm 1, lines 4-13):
//!   consumes env states **in arrival order** through the store's
//!   multi-key subscription ([`Client::poll_any_take`]) instead of one
//!   blocking poll per env, batches the policy over whichever states have
//!   arrived once `min_batch` are staged, and keeps per-env done/error
//!   bookkeeping so an early-terminating env can never stall the batch —
//!   the synchronization overhead paper §6.2 measures.  With
//!   `min_batch = n_envs` (the default) the collector waits for the full
//!   wave and reproduces the paper's synchronous PPO bit-for-bit; the
//!   retained [`EnvPool::collect_lockstep_with`] reference implements the
//!   literal per-env polling loop for that equivalence test and for the
//!   §6.2 baseline bench.
//!
//! The exchange itself is zero-copy and, in steady state, zero-alloc:
//! both sides publish recycled `Arc<[f32]>` buffers
//! ([`crate::orchestrator::TensorPool`]) under interned key handles
//! (built once per iteration via [`Protocol::env_keys`] /
//! [`Protocol::pool_keys`]), the store hands consumers refcount bumps
//! instead of tensor copies, and per-key wakeups make every `put` wake
//! exactly the party waiting on that key.  `PoolCounters::exchange_allocs`
//! counts the pools' fresh allocations; after the warm-up iteration it
//! must not advance (integration-tested, gated in CI).
//!
//! Heterogeneous pools: each env runs a scenario variant
//! ([`crate::config::EnvVariant`], round-robin), so one pool can sample
//! across Reynolds-number, reward-shaping, horizon and initial-state
//! families while sharing one `Grid`, one truth package and one policy.

use crate::config::RunConfig;
use crate::orchestrator::{Client, EnvKeys, Key, Orchestrator, Protocol, TensorPool, Value};
use crate::rl::{gaussian, reward_from_error, Episode, LesEnv, StepRecord};
use crate::runtime::{PolicyOut, PolicyRuntime};
use crate::solver::dns::Truth;
use crate::solver::Grid;
use crate::util::Rng;
use anyhow::{anyhow, bail, Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Timeout for any single poll; generous because env steps include real
/// CFD work.
const POLL_TIMEOUT: Duration = Duration::from_secs(600);

/// Result of one sampling phase.
pub struct Rollouts {
    pub episodes: Vec<Episode>,
    /// Wall-clock seconds spent sampling (the paper's §6.2 metric).
    pub sample_time_s: f64,
    /// Wall-clock seconds the trainer spent inside policy inference.
    pub policy_time_s: f64,
    /// Wall-clock seconds the trainer spent blocked on arrivals (the
    /// synchronization overhead the event-driven collector attacks).
    pub idle_time_s: f64,
}

/// Construction counters proving worker persistence and exchange-path
/// allocation discipline: after the warm-up, no call ever advances them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolCounters {
    /// OS threads spawned (== n_envs, only in `new`).
    pub threads_spawned: usize,
    /// `LesEnv` instances constructed (== n_envs, only in `new`).
    pub envs_built: usize,
    /// Spectral grids constructed (== 1, only in `new`).
    pub grids_built: usize,
    /// Sampling phases served by the persistent workers.
    pub iterations: usize,
    /// Exchange-path tensor-buffer allocations: pool misses across every
    /// worker's observation pool and the trainer's action pool.  Grows
    /// while the pools warm up (iteration 0), then must stay flat.
    pub exchange_allocs: u64,
}

/// Per-iteration begin message a parked worker blocks on.
struct Begin {
    proto: Protocol,
    rng: Rng,
}

/// Collects rollouts from `n_envs` persistent parallel environments.
pub struct EnvPool {
    cfg: RunConfig,
    grid: Arc<Grid>,
    /// Begin-message channels, one per worker (dropping them shuts the
    /// pool down).
    txs: Vec<mpsc::Sender<Begin>>,
    handles: Vec<JoinHandle<()>>,
    counters: PoolCounters,
    /// Client + last begun protocol, so `Drop` can raise the abort flag
    /// for workers still blocked inside an interrupted iteration.
    abort_client: Client,
    current_proto: Option<Protocol>,
    /// Per-env resolved bookkeeping (round-robin variants).
    variant_of: Vec<usize>,
    alpha_of: Vec<f64>,
    n_actions_of: Vec<usize>,
    /// Observation features per element ((N+1)^3 * 3).
    feat: usize,
    /// Elements per env.
    n_elems: usize,
    /// Reused forward-batch scratch (n_envs * n_elems * feat floats,
    /// allocated once here, never per iteration).
    batch_obs: Vec<f32>,
    /// Recycled action buffers (published zero-copy, recorded in the
    /// episode, freed when the rollouts are dropped).
    act_pool: TensorPool,
    /// Action tensor shape `[n_elems]`, shared across all publishes.
    act_shape: Arc<[usize]>,
    /// Shared exchange-allocation counter (this pool + every worker's
    /// observation pool).
    exchange_allocs: Arc<AtomicU64>,
}

impl EnvPool {
    /// Build the pool for a run configuration and its ground truth:
    /// construct the shared spectral grid, every `LesEnv` (one scenario
    /// variant each) and every worker thread exactly once.  All later
    /// iterations reuse them.
    pub fn new(cfg: RunConfig, truth: Arc<Truth>, orch: &Orchestrator) -> Result<EnvPool> {
        cfg.validate()?;
        let n_envs = cfg.rl.n_envs;
        if cfg.rl.split_init_pool {
            anyhow::ensure!(
                truth.states.len() >= cfg.n_variants(),
                "split_init_pool needs >= {} truth states (one per variant), got {}",
                cfg.n_variants(),
                truth.states.len()
            );
        }
        // One shared spectral grid for the whole pool: `fft::Plan` is
        // `Send + Sync`, so every worker reuses the same twiddle tables.
        let grid = Arc::new(Grid::new(cfg.case.points_per_dir()));
        let mut counters = PoolCounters {
            threads_spawned: 0,
            envs_built: 0,
            grids_built: 1,
            iterations: 0,
            exchange_allocs: 0,
        };
        let exchange_allocs = Arc::new(AtomicU64::new(0));

        let mut txs = Vec::with_capacity(n_envs);
        let mut handles = Vec::with_capacity(n_envs);
        let mut variant_of = Vec::with_capacity(n_envs);
        let mut alpha_of = Vec::with_capacity(n_envs);
        let mut n_actions_of = Vec::with_capacity(n_envs);
        for i in 0..n_envs {
            let rv = cfg.variant_for(i);
            let mut env = LesEnv::with_grid(&rv.case, &rv.solver, truth.clone(), grid.clone())
                .with_context(|| format!("env {i} (variant {})", rv.name))?;
            if let Some((family, m)) = rv.init_family {
                env.set_init_family(family, m)
                    .with_context(|| format!("env {i} (variant {})", rv.name))?;
            }
            counters.envs_built += 1;
            variant_of.push(rv.index);
            alpha_of.push(rv.case.alpha);
            n_actions_of.push(env.n_actions());

            let (tx, rx) = mpsc::channel::<Begin>();
            let client = orch.client();
            let allocs = exchange_allocs.clone();
            let handle = std::thread::Builder::new()
                .name(format!("env-worker-{i}"))
                .spawn(move || worker_loop(env, client, i, rx, allocs))?;
            counters.threads_spawned += 1;
            txs.push(tx);
            handles.push(handle);
        }

        let n_elems = cfg.case.total_elems();
        let feat = cfg.case.elem_points().pow(3) * 3;
        // One iteration publishes one action per env per step, all held
        // by the episode records until the rollouts drop — that sum is
        // the action pool's steady-state working set (and its cap).
        let act_cap = n_actions_of.iter().sum::<usize>() + 2;
        Ok(EnvPool {
            batch_obs: vec![0f32; n_envs * n_elems * feat],
            act_pool: TensorPool::new(exchange_allocs.clone(), act_cap),
            act_shape: Arc::from(vec![n_elems]),
            exchange_allocs,
            cfg,
            grid,
            txs,
            handles,
            counters,
            abort_client: orch.client(),
            current_proto: None,
            variant_of,
            alpha_of,
            n_actions_of,
            feat,
            n_elems,
        })
    }

    /// Elements per env (actions per step per env).
    pub fn n_elems(&self) -> usize {
        self.n_elems
    }

    /// The spectral grid shared by every env in the pool.
    pub fn grid(&self) -> Arc<Grid> {
        self.grid.clone()
    }

    /// Construction counters (steady-state assertion: only `iterations`
    /// may change across `collect` calls, and `exchange_allocs` only
    /// during the warm-up iteration).
    pub fn counters(&self) -> PoolCounters {
        PoolCounters {
            exchange_allocs: self.exchange_allocs.load(Ordering::Relaxed),
            ..self.counters
        }
    }

    /// Run one sampling phase under the current policy (`theta`),
    /// event-driven with the configured `rl.min_batch` (0 = full batch =
    /// synchronous PPO).  `run_tag` via `proto` namespaces the keys; `rng`
    /// drives initial-state draws and action sampling.
    pub fn collect(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        policy: &PolicyRuntime,
        theta: &[f32],
        rng: &mut Rng,
        deterministic: bool,
    ) -> Result<Rollouts> {
        anyhow::ensure!(
            policy.features() == self.feat,
            "policy features {} != pool features {}",
            policy.features(),
            self.feat
        );
        let min_batch = self.cfg.min_batch_effective();
        self.collect_with(
            orch,
            proto,
            |obs, n| policy.forward(theta, obs, n),
            rng,
            deterministic,
            min_batch,
        )
    }

    /// Event-driven sampling phase with an explicit policy closure
    /// (`forward(obs, n_samples)`) — the policy-agnostic core, also used
    /// by tests and benches that run without compiled artifacts.
    pub fn collect_with<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        forward: F,
        rng: &mut Rng,
        deterministic: bool,
        min_batch: usize,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let res = self.collect_event_inner(orch, proto, forward, rng, deterministic, min_batch);
        self.finish_iteration(proto, res.is_err());
        res
    }

    fn collect_event_inner<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        mut forward: F,
        rng: &mut Rng,
        deterministic: bool,
        min_batch: usize,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let t_start = Instant::now();
        let n_envs = self.cfg.rl.n_envs;
        let chunk = self.n_elems * self.feat;
        let trainer = orch.client();
        self.begin_iteration(proto, rng)?;
        let keys = proto.pool_keys(&self.n_actions_of);

        let mut episodes = self.fresh_episodes();
        // Per-env: step index of the state we are waiting for (None once
        // the done-flag arrived), plus staged-but-unacted states and
        // outstanding error scalars.
        let mut expect_state: Vec<Option<usize>> = vec![Some(0); n_envs];
        let mut staged: Vec<(usize, usize, Arc<[f32]>)> = Vec::with_capacity(n_envs);
        let mut pending_errs: Vec<(usize, usize)> = Vec::with_capacity(n_envs);
        let mut policy_time = 0.0f64;
        let mut idle_time = 0.0f64;

        // Scratch for the per-event subscription (interned key handles —
        // no string building or rehashing inside this loop).
        let mut subs: Vec<&Key> = Vec::new();
        let mut events: Vec<Event> = Vec::new();
        let mut fail_subbed = vec![false; n_envs];

        loop {
            let expecting = expect_state.iter().filter(|e| e.is_some()).count();
            if expecting == 0 && staged.is_empty() && pending_errs.is_empty() {
                break;
            }

            // Flush the policy batch once enough states arrived — or once
            // no further state can arrive without us acting first.
            if !staged.is_empty() && (staged.len() >= min_batch || expecting == 0) {
                staged.sort_unstable_by_key(|&(env, _, _)| env);
                let n_act = staged.len();
                for (k, (_, _, obs)) in staged.iter().enumerate() {
                    self.batch_obs[k * chunk..(k + 1) * chunk].copy_from_slice(obs);
                }
                let tp = Instant::now();
                let out = forward(&self.batch_obs[..n_act * chunk], n_act * self.n_elems)?;
                policy_time += tp.elapsed().as_secs_f64();
                anyhow::ensure!(
                    out.mean.len() == n_act * self.n_elems
                        && out.value.len() == n_act * self.n_elems,
                    "policy returned {} means for {} samples",
                    out.mean.len(),
                    n_act * self.n_elems
                );

                // Sample + write actions in env order (ties the RNG stream
                // to env indices, not arrival order: full-batch collection
                // is bitwise-identical to the lock-step reference).
                for (k, (env, t, obs)) in staged.drain(..).enumerate() {
                    let mean = &out.mean[k * self.n_elems..(k + 1) * self.n_elems];
                    let value = &out.value[k * self.n_elems..(k + 1) * self.n_elems];
                    publish_action(
                        &trainer,
                        &keys.envs[env].action[t],
                        &self.act_shape,
                        &mut self.act_pool,
                        &mut episodes[env],
                        obs,
                        mean,
                        value,
                        out.log_std,
                        rng,
                        deterministic,
                    );
                    pending_errs.push((env, t));
                    expect_state[env] = Some(t + 1);
                }
                continue;
            }

            // Wait for the next event: any outstanding state, error,
            // done-flag or failure report, whichever arrives first.  Each
            // involved env's fail key is subscribed exactly once.
            subs.clear();
            events.clear();
            fail_subbed.fill(false);
            for (env, e) in expect_state.iter().enumerate() {
                if let Some(t) = e {
                    let ek = &keys.envs[env];
                    subs.push(&ek.state[*t]);
                    events.push(Event::State(env, *t));
                    subs.push(&ek.done);
                    events.push(Event::Done(env));
                    subs.push(&ek.fail);
                    events.push(Event::Fail(env));
                    fail_subbed[env] = true;
                }
            }
            for &(env, t) in &pending_errs {
                let ek = &keys.envs[env];
                subs.push(&ek.err[t]);
                events.push(Event::Err(env, t));
                if !fail_subbed[env] {
                    subs.push(&ek.fail);
                    events.push(Event::Fail(env));
                    fail_subbed[env] = true;
                }
            }
            let ti = Instant::now();
            let (hit, val) = trainer
                .poll_any_take(&subs, POLL_TIMEOUT)
                .with_context(|| {
                    format!(
                        "collector timed out: {} states expected, {} errors pending",
                        expect_state.iter().filter(|e| e.is_some()).count(),
                        pending_errs.len()
                    )
                })?;
            idle_time += ti.elapsed().as_secs_f64();
            match events[hit] {
                Event::State(env, t) => {
                    let data = val
                        .tensor_data()
                        .with_context(|| format!("env {env} state at step {t} is not a tensor"))?;
                    anyhow::ensure!(
                        data.len() == chunk,
                        "env {env} state has {} floats, expected {chunk}",
                        data.len()
                    );
                    staged.push((env, t, data));
                    expect_state[env] = None; // parked in `staged` until acted on
                }
                Event::Done(env) => {
                    expect_state[env] = None;
                }
                Event::Err(env, t) => {
                    let err = val
                        .as_scalar()
                        .with_context(|| format!("env {env} error at step {t} not a scalar"))?;
                    episodes[env].steps[t].reward = reward_from_error(err, self.alpha_of[env]);
                    pending_errs.retain(|&(e, s)| (e, s) != (env, t));
                }
                Event::Fail(env) => {
                    bail!("env worker {env} failed: {}", fail_message(&val));
                }
            }
        }

        self.counters.iterations += 1;
        Ok(Rollouts {
            episodes,
            sample_time_s: t_start.elapsed().as_secs_f64(),
            policy_time_s: policy_time,
            idle_time_s: idle_time,
        })
    }

    /// Lock-step reference collector: the paper's literal synchronous
    /// gather — one wave per RL step, states polled env-by-env — kept as
    /// the bitwise-equivalence oracle for the event-driven path and as
    /// the §6.2 baseline for the training bench.  Unlike the seed
    /// implementation it checks the done-flag at every step, so an env
    /// that terminates early can no longer wedge the gather loop until
    /// the poll timeout.
    pub fn collect_lockstep_with<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        forward: F,
        rng: &mut Rng,
        deterministic: bool,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let res = self.collect_lockstep_inner(orch, proto, forward, rng, deterministic);
        self.finish_iteration(proto, res.is_err());
        res
    }

    fn collect_lockstep_inner<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        mut forward: F,
        rng: &mut Rng,
        deterministic: bool,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let t_start = Instant::now();
        let n_envs = self.cfg.rl.n_envs;
        let chunk = self.n_elems * self.feat;
        let trainer = orch.client();
        self.begin_iteration(proto, rng)?;
        let keys = proto.pool_keys(&self.n_actions_of);

        let mut episodes = self.fresh_episodes();
        let mut done = vec![false; n_envs];
        let mut acted: Vec<usize> = Vec::with_capacity(n_envs);
        let mut wave_obs: Vec<Arc<[f32]>> = Vec::with_capacity(n_envs);
        let mut policy_time = 0.0f64;
        let mut idle_time = 0.0f64;
        let max_t = self.n_actions_of.iter().copied().max().unwrap_or(0);

        for t in 0..max_t {
            // Gather the wave's states in env order, checking the
            // done-flag per env so early terminations are absorbed.
            acted.clear();
            wave_obs.clear();
            for env in 0..n_envs {
                if done[env] {
                    continue;
                }
                let ek = &keys.envs[env];
                let ti = Instant::now();
                let (hit, val) = trainer
                    .poll_any_take(&[&ek.state[t], &ek.done, &ek.fail], POLL_TIMEOUT)
                    .with_context(|| format!("trainer: no state from env {env} step {t}"))?;
                idle_time += ti.elapsed().as_secs_f64();
                match hit {
                    0 => {
                        let data = val.tensor_data().context("state must be a tensor")?;
                        anyhow::ensure!(
                            data.len() == chunk,
                            "env {env} state has {} floats, expected {chunk}",
                            data.len()
                        );
                        self.batch_obs[acted.len() * chunk..(acted.len() + 1) * chunk]
                            .copy_from_slice(&data);
                        acted.push(env);
                        wave_obs.push(data);
                    }
                    1 => done[env] = true,
                    _ => bail!("env worker {env} failed: {}", fail_message(&val)),
                }
            }
            if acted.is_empty() {
                break; // every env terminated before the longest horizon
            }

            // One batched policy evaluation for the wave.
            let n_act = acted.len();
            let tp = Instant::now();
            let out = forward(&self.batch_obs[..n_act * chunk], n_act * self.n_elems)?;
            policy_time += tp.elapsed().as_secs_f64();

            // Sample actions, write them back, record the steps (the one
            // shared publish site with the event-driven collector).
            for (k, &env) in acted.iter().enumerate() {
                let mean = &out.mean[k * self.n_elems..(k + 1) * self.n_elems];
                let value = &out.value[k * self.n_elems..(k + 1) * self.n_elems];
                publish_action(
                    &trainer,
                    &keys.envs[env].action[t],
                    &self.act_shape,
                    &mut self.act_pool,
                    &mut episodes[env],
                    wave_obs[k].clone(),
                    mean,
                    value,
                    out.log_std,
                    rng,
                    deterministic,
                );
            }

            // Collect the spectrum errors -> rewards (Eqs. 4-5).
            for &env in &acted {
                let ek = &keys.envs[env];
                let ti = Instant::now();
                let (hit, val) = trainer
                    .poll_any_take(&[&ek.err[t], &ek.fail], POLL_TIMEOUT)
                    .with_context(|| format!("trainer: no error from env {env} step {t}"))?;
                idle_time += ti.elapsed().as_secs_f64();
                if hit != 0 {
                    bail!("env worker {env} failed: {}", fail_message(&val));
                }
                let err = val.as_scalar().context("error must be a scalar")?;
                episodes[env].steps[t].reward = reward_from_error(err, self.alpha_of[env]);
            }
        }

        // Every env must have signalled termination.
        for env in 0..n_envs {
            if done[env] {
                continue;
            }
            let ek = &keys.envs[env];
            let (hit, val) = trainer
                .poll_any_take(&[&ek.done, &ek.fail], POLL_TIMEOUT)
                .with_context(|| format!("env {env} never signalled done"))?;
            if hit != 0 {
                bail!("env worker {env} failed: {}", fail_message(&val));
            }
        }

        self.counters.iterations += 1;
        Ok(Rollouts {
            episodes,
            sample_time_s: t_start.elapsed().as_secs_f64(),
            policy_time_s: policy_time,
            idle_time_s: idle_time,
        })
    }

    /// Raise the iteration's abort flag so workers still blocked on an
    /// action key of a failed iteration unpark immediately (instead of
    /// running out POLL_TIMEOUT) and return to the begin-channel.  The
    /// flag is deliberately never deleted: a worker that was mid-CFD-step
    /// when the abort was raised subscribes to `[action, abort]` later
    /// and must still find it.  The pool stays usable afterwards, but a
    /// retry must use a **fresh run tag** — the failed tag's namespace
    /// (abort flag, stale state/err keys) is burned.
    fn abort_iteration(&self, proto: &Protocol) {
        self.abort_client.put_flag(&proto.abort_key(), true);
    }

    /// Close out one sampling phase: on failure raise the abort flag; on
    /// success forget the protocol so a later `Drop` does not write a
    /// stray abort key for a cleanly completed iteration.
    fn finish_iteration(&mut self, proto: &Protocol, failed: bool) {
        if failed {
            self.abort_iteration(proto);
        } else {
            self.current_proto = None;
        }
    }

    /// Wake every parked worker for one iteration (per-env RNG streams
    /// split in env order, exactly as the seed's spawn loop did).
    fn begin_iteration(&mut self, proto: &Protocol, rng: &mut Rng) -> Result<()> {
        self.current_proto = Some(proto.clone());
        for (i, tx) in self.txs.iter().enumerate() {
            tx.send(Begin {
                proto: proto.clone(),
                rng: rng.split(i as u64),
            })
            .map_err(|_| anyhow!("env worker {i} has exited (earlier panic?)"))?;
        }
        Ok(())
    }

    /// Empty per-env episodes tagged with their scenario variants.
    fn fresh_episodes(&self) -> Vec<Episode> {
        self.variant_of
            .iter()
            .map(|&variant| Episode {
                variant,
                ..Episode::default()
            })
            .collect()
    }
}

impl Drop for EnvPool {
    fn drop(&mut self) {
        // Unblock workers stuck mid-iteration (e.g. after an external
        // kill): they subscribe to the abort flag next to their action
        // key, so this wakes them without waiting out the poll timeout.
        if let Some(proto) = self.current_proto.take() {
            self.abort_iteration(&proto);
        }
        // Dropping the begin-channels unparks every idle worker with a
        // recv error, which is the shutdown signal.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One collector event: a key subscription resolved to its meaning.
#[derive(Clone, Copy)]
enum Event {
    /// State tensor from env at step.
    State(usize, usize),
    /// Done-flag: no further states from this env.
    Done(usize),
    /// Spectrum-error scalar for (env, step).
    Err(usize, usize),
    /// Worker failure report.
    Fail(usize),
}

/// Sample (or, when deterministic, copy) one env's action from the policy
/// head, publish it zero-copy under the env's action key and record the
/// step — the single action-publish site shared by the event-driven and
/// lock-step collectors.  The action buffer comes from the recycled pool;
/// the store, the episode record and the pool share one allocation.
#[allow(clippy::too_many_arguments)]
fn publish_action(
    trainer: &Client,
    action_key: &Key,
    act_shape: &Arc<[usize]>,
    act_pool: &mut TensorPool,
    episode: &mut Episode,
    obs: Arc<[f32]>,
    mean: &[f32],
    value: &[f32],
    log_std: f32,
    rng: &mut Rng,
    deterministic: bool,
) {
    let mut act = act_pool.take_free(mean.len());
    {
        let dst = Arc::get_mut(&mut act).expect("pool hands out unique buffers");
        if deterministic {
            dst.copy_from_slice(mean);
        } else {
            gaussian::sample_into(mean, log_std, rng, dst);
        }
    }
    let logp = gaussian::log_prob(&act, mean, log_std);
    trainer.put_tensor_shared(action_key, act_shape.clone(), act.clone());
    episode.steps.push(StepRecord {
        obs,
        act: act.clone(),
        logp,
        value: value.to_vec(),
        reward: 0.0, // filled by the error event
    });
    act_pool.put_back(act);
}

/// Render a failure-report value (bytes put by the worker) for an error.
fn fail_message(val: &Value) -> String {
    match val {
        Value::Bytes(b) => String::from_utf8_lossy(b).into_owned(),
        other => format!("{other:?}"),
    }
}

/// The persistent worker body: park on the begin-channel, run one episode
/// through the store, park again.  Exits when the pool drops the channel.
/// The observation buffer pool and the action-conversion scratch persist
/// across iterations, so a steady-state episode allocates nothing on the
/// exchange path.
///
/// Both `Err` returns and panics inside the episode (caught so the thread
/// survives; the next begin resets the env completely) are surfaced
/// through the fail key, so the collector aborts the iteration instead of
/// running into its poll timeout.
fn worker_loop(
    mut env: LesEnv,
    client: Client,
    idx: usize,
    rx: mpsc::Receiver<Begin>,
    allocs: Arc<AtomicU64>,
) {
    // Working set: one obs buffer per step (held by the trainer until
    // the iteration's rollouts drop) plus the initial state.
    let mut obs_pool = TensorPool::new(allocs, env.n_actions() + 2);
    let mut cs_buf: Vec<f64> = Vec::with_capacity(env.n_elems());
    let obs_shape: Arc<[usize]> = Arc::from(vec![env.obs_len()]);
    while let Ok(Begin { proto, mut rng }) = rx.recv() {
        let keys = proto.env_keys(idx, env.n_actions());
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_episode(
                &mut env,
                &client,
                &keys,
                idx,
                &mut rng,
                &mut obs_pool,
                &mut cs_buf,
                &obs_shape,
            )
        }));
        let failure = match outcome {
            Ok(Ok(())) => None,
            Ok(Err(e)) => Some(format!("{e:#}")),
            Err(payload) => Some(format!("panic: {}", panic_message(&payload))),
        };
        if let Some(msg) = failure {
            client.put_bytes(&keys.fail, msg.into_bytes());
        }
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// One episode of the paper's env side (Fig. 2 right): reset from the
/// truth pool, then state-out / action-in / error-out per RL step, with
/// the done-flag raised at termination.  All keys are interned handles,
/// observations go out through recycled `Arc` buffers, and the received
/// action is only borrowed (refcount bump) — a steady-state step neither
/// formats strings nor allocates tensor storage.
#[allow(clippy::too_many_arguments)]
fn run_episode(
    env: &mut LesEnv,
    client: &Client,
    keys: &EnvKeys,
    idx: usize,
    rng: &mut Rng,
    obs_pool: &mut TensorPool,
    cs_buf: &mut Vec<f64>,
    obs_shape: &Arc<[usize]>,
) -> Result<()> {
    let obs_len = env.obs_len();
    env.reset_in_place(rng, false);
    let mut buf = obs_pool.take_free(obs_len);
    env.observe_into(Arc::get_mut(&mut buf).expect("pool hands out unique buffers"));
    client.put_tensor_shared(&keys.state[0], obs_shape.clone(), buf.clone());
    obs_pool.put_back(buf);
    for t in 0..env.n_actions() {
        let (hit, act) = client
            .poll_any(&[&keys.action[t], &keys.abort], POLL_TIMEOUT)
            .with_context(|| format!("env {idx}: no action at step {t}"))?;
        anyhow::ensure!(hit == 0, "env {idx}: iteration aborted at step {t}");
        // Consume the action (seed semantics): only the shared abort flag
        // must stay readable by every worker, so the subscription above is
        // non-consuming and the action is deleted explicitly.
        client.delete(&keys.action[t]);
        let data = act.as_tensor().context("action must be a tensor")?.1;
        cs_buf.clear();
        cs_buf.extend(data.iter().map(|&a| a as f64));
        let out = env.step(cs_buf);
        client.put_scalar(&keys.err[t], out.spec_error);
        if out.done {
            client.put_flag(&keys.done, true);
            break;
        }
        let mut buf = obs_pool.take_free(obs_len);
        env.observe_into(Arc::get_mut(&mut buf).expect("pool hands out unique buffers"));
        client.put_tensor_shared(&keys.state[t + 1], obs_shape.clone(), buf.clone());
        obs_pool.put_back(buf);
    }
    Ok(())
}
