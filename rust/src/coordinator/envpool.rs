//! The persistent, event-driven environment runtime — the heart of the
//! Relexi dataflow (paper Fig. 2 / Algorithm 1), split into two halves:
//!
//! * **Worker pool** (the "FLEXI instances", Fig. 2 left): one OS thread
//!   and one environment per slot, built **once** in [`EnvPool::new`]
//!   and reused for every training iteration.  The pool is
//!   solver-agnostic: workers drive `dyn` [`CfdEnv`] instances cut from
//!   a [`CfdBackend`] (the paper's "easy integration of various HPC
//!   solvers" — `rl.backend` selects the 3D spectral LES or the 1D
//!   stochastic-Burgers testbed; see [`crate::rl::cfd`]).  Workers block
//!   on a per-iteration begin message carrying the iteration's key
//!   namespace ([`Protocol`]) and RNG stream, run one episode — write
//!   state, poll action, advance `dt_RL`, write the shaped reward, raise
//!   the done-flag at termination (§3.1) — and park again.  Steady-state
//!   iterations therefore spawn zero threads and rebuild zero env/shared
//!   backend instances (asserted by [`PoolCounters`]).
//!
//! * **Rollout collector** (the trainer side of Algorithm 1, lines 4-13):
//!   consumes env events **in arrival order** through one persistent
//!   store [`Subscription`] per sampling phase: done/fail channels
//!   register once per iteration, and each event applies only the
//!   single-key deltas it implies (retire the received state key, add
//!   the next one, add/retire a reward key around each action) — so a
//!   collection wave over `E` envs costs O(E) registry ops where the
//!   per-event `poll_any` rebuild it replaced cost O(E²)
//!   (counter-asserted via `StoreStats::sub_ops`).  The collector
//!   batches the policy over whichever states have arrived once
//!   `min_batch` are staged, and keeps per-env done/reward bookkeeping
//!   so an early-terminating env can never stall the batch — the
//!   synchronization overhead paper §6.2 measures.  With
//!   `min_batch = n_envs` (the default) the collector waits for the full
//!   wave and reproduces the paper's synchronous PPO bit-for-bit; the
//!   retained [`EnvPool::collect_lockstep_with`] reference implements the
//!   literal per-env polling loop for that equivalence test and for the
//!   §6.2 baseline bench.
//!
//! The exchange itself is zero-copy and, in steady state, zero-alloc:
//! both sides publish recycled `Arc<[f32]>` buffers
//! ([`crate::orchestrator::TensorPool`]) under interned key handles
//! (built once per iteration via [`Protocol::env_keys`] /
//! [`Protocol::pool_keys`]), the store hands consumers refcount bumps
//! instead of tensor copies, and per-key wakeups make every `put` wake
//! exactly the party waiting on that key.  `PoolCounters::exchange_allocs`
//! counts the pools' fresh allocations; after the warm-up iteration it
//! must not advance (integration-tested, gated in CI).
//!
//! Heterogeneous pools: each env runs a scenario variant
//! ([`crate::config::EnvVariant`], round-robin), so one pool can sample
//! across Reynolds-number, reward-shaping, horizon and initial-state
//! families while sharing one backend context and one policy.
//!
//! **Supervision** (processes mode): the collector slices its blocking
//! wait so it can watch child exits (`try_wait`) and heartbeat expiry
//! ([`crate::orchestrator::protocol::ctl_hb_key`]) between events.  A
//! dead or wedged worker is killed, respawned under a fresh generation,
//! and its env block is **replayed** under a fresh run tag: the block's
//! recorded per-env seeds rebuild the identical RNG streams and the
//! recorded action tensors are pre-published into the replay namespace,
//! so the replacement streams to the crash point without a single new
//! policy draw — the completed wave is bit-identical to a crash-free
//! run (in full-batch collection, where no action is drawn while any
//! live env's state is missing).  A worker that exhausts its
//! `[fault] max_respawns` budget is dropped instead: the wave completes
//! short, surfacing the loss in [`SupervisionReport`] rather than
//! aborting training.

use super::supervise::{HeartbeatMonitor, SupervisionReport};
use crate::config::RunConfig;
use crate::launcher::{plan_worker_processes, WorkerPlan};
use crate::orchestrator::protocol::{
    ctl_begin_key, ctl_hb_key, ctl_hello_key, ctl_tel_key, encode_begin, CTL_STOP_KEY,
    CTL_TEL_FLUSH_KEY,
};
use crate::orchestrator::{
    Client, EnvKeys, ExchangeServer, Key, Orchestrator, Protocol, TensorPool, Value,
};
use crate::rl::{backend_from_config, gaussian, CfdBackend, CfdEnv, Episode, StepRecord};
use crate::runtime::{Policy, PolicyOut};
use crate::solver::dns::Truth;
use crate::util::Rng;
use anyhow::{anyhow, bail, Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Result of one sampling phase.
pub struct Rollouts {
    pub episodes: Vec<Episode>,
    /// Wall-clock seconds spent sampling (the paper's §6.2 metric).
    pub sample_time_s: f64,
    /// Wall-clock seconds the trainer spent inside policy inference.
    pub policy_time_s: f64,
    /// Wall-clock seconds the trainer spent blocked on arrivals (the
    /// synchronization overhead the event-driven collector attacks).
    pub idle_time_s: f64,
    /// What the supervision layer did during this wave (respawns,
    /// dropped env blocks, detect/recover latencies).  All-zero for a
    /// crash-free wave and always for the threads mode.
    pub supervision: SupervisionReport,
}

/// Construction counters proving worker persistence and exchange-path
/// allocation discipline: after the warm-up, no call ever advances them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolCounters {
    /// OS threads spawned (== n_envs, only in construction).
    pub threads_spawned: usize,
    /// Environment instances constructed (== n_envs, only in
    /// construction).
    pub envs_built: usize,
    /// Shared backend contexts constructed (== 1, only in construction:
    /// the LES backend's spectral grid + truth, the Burgers backend's
    /// resolved-truth package).
    pub grids_built: usize,
    /// Sampling phases served by the persistent workers.
    pub iterations: usize,
    /// Exchange-path tensor-buffer allocations: pool misses across every
    /// worker's observation pool and the trainer's action pool.  Grows
    /// while the pools warm up (iteration 0), then must stay flat.
    pub exchange_allocs: u64,
}

/// Per-iteration begin message a parked worker blocks on.
struct Begin {
    proto: Protocol,
    rng: Rng,
}

/// Per-iteration begin message a batched block thread blocks on: the
/// run's protocol plus every `(global env index, rng seed)` of the
/// block, ascending by env index.
struct BlockBegin {
    proto: Protocol,
    seeds: Vec<(usize, u64)>,
}

/// How the pool's environments are hosted (`orchestrator.workers`).
enum Workers {
    /// Env threads inside the trainer process (the seed architecture;
    /// pairs with the in-process store — no wire anywhere).
    Threads,
    /// `relexi env-worker` OS processes dialing the exchange over a
    /// network transport.  The control plane (begin / hello / stop /
    /// heartbeat) rides the same store as the data plane.
    Processes(ProcState),
}

/// Everything the supervision layer tracks about the worker processes.
struct ProcState {
    /// Spawned children, in worker-id order (= plan assignment order).
    /// A respawn replaces the slot in place.
    children: Vec<std::process::Child>,
    /// The exchange serving the trainer's store to the workers; read
    /// only for its address (respawns re-dial it) and held so it
    /// outlives the children (the `Drop` reap runs before this drops).
    server: ExchangeServer,
    /// env -> process split (contiguous blocks in global env order).
    plan: WorkerPlan,
    /// Per-worker incarnation counter, bumped on every respawn and
    /// passed as `--generation` (fault-plan directives default to
    /// generation 0 only).
    generation: Vec<u32>,
    /// Per-worker respawns consumed from the `[fault] max_respawns`
    /// budget (pool lifetime, not per wave).
    respawns_used: Vec<usize>,
    /// Workers whose budget is exhausted: their env block is dropped
    /// and every later wave completes short without them.
    dropped: Vec<bool>,
    /// Per-worker heartbeat keys, interned once at pool construction:
    /// the supervisor reads one per worker per check slice, so handing
    /// it a pre-hashed handle keeps the liveness path allocation-free
    /// (and exempt from batching — control keys never ride the waves).
    hb_keys: Vec<Key>,
}

impl ProcState {
    /// Env block hosted by worker `w`.
    fn block(&self, w: usize) -> (usize, usize) {
        self.plan.assignments[w]
    }

    /// True when `env` belongs to a dropped worker's block.
    fn env_dropped(&self, env: usize) -> bool {
        self.plan
            .assignments
            .iter()
            .enumerate()
            .any(|(w, &(start, count))| {
                self.dropped[w] && env >= start && env < start + count
            })
    }

    /// All envs of dropped workers, ascending.
    fn dropped_envs(&self) -> Vec<usize> {
        let mut out = Vec::new();
        for (w, &(start, count)) in self.plan.assignments.iter().enumerate() {
            if self.dropped[w] {
                out.extend(start..start + count);
            }
        }
        out
    }

    /// Kill worker `w`'s current incarnation (there must never be two
    /// publishers for one env block), clear its stale control keys,
    /// spawn a replacement under the next generation and wait for its
    /// hello.  On error the slot holds the dead replacement (or the
    /// killed original); the caller decides whether to retry or drop.
    fn respawn_process(&mut self, cfg: &RunConfig, client: &Client, w: usize) -> Result<()> {
        let _ = self.children[w].kill();
        let _ = self.children[w].wait();
        client.delete(&ctl_hello_key(w));
        client.delete(&ctl_begin_key(w));
        client.delete(&self.hb_keys[w]);
        self.generation[w] += 1;
        let (start, count) = self.block(w);
        let addr = self.server.addr().to_string();
        self.children[w] = spawn_one_worker(cfg, &addr, w, start, count, self.generation[w])?;
        let deadline = Instant::now() + hello_timeout(cfg);
        wait_one_hello(client, &mut self.children[w], w, deadline)
    }
}

fn poll_timeout(cfg: &RunConfig) -> Duration {
    Duration::from_secs_f64(cfg.orchestrator.poll_timeout_s)
}

fn hello_timeout(cfg: &RunConfig) -> Duration {
    Duration::from_secs_f64(cfg.orchestrator.hello_timeout_s)
}

fn reap_timeout(cfg: &RunConfig) -> Duration {
    Duration::from_secs_f64(cfg.orchestrator.reap_timeout_s)
}

/// Collects rollouts from `n_envs` persistent parallel environments.
pub struct EnvPool {
    cfg: RunConfig,
    /// The backend the pool's environments were cut from (shared context:
    /// grid/truth), kept for building matching evaluation envs.
    backend: Arc<dyn CfdBackend>,
    /// Begin-message channels, one per worker (dropping them shuts the
    /// pool down).
    txs: Vec<mpsc::Sender<Begin>>,
    handles: Vec<JoinHandle<()>>,
    /// Threads (the seed architecture) or spawned worker processes.
    workers: Workers,
    counters: PoolCounters,
    /// Client + the protocols begun this phase (the iteration tag plus
    /// any replay tags recovery opened), so `Drop` can raise the abort
    /// flags for workers still blocked inside an interrupted iteration.
    abort_client: Client,
    active_protos: Vec<Protocol>,
    /// Per-env resolved bookkeeping (round-robin variants).
    variant_of: Vec<usize>,
    n_actions_of: Vec<usize>,
    /// Observation features per agent (`obs_len / n_agents`).
    feat: usize,
    /// Agents per env (actions per step; the LES backend: DG elements).
    n_agents: usize,
    /// Observation floats per env.
    obs_len: usize,
    /// Reused forward-batch scratch (n_envs * obs_len floats, allocated
    /// once here, never per iteration).
    batch_obs: Vec<f32>,
    /// Recycled action buffers (published zero-copy, recorded in the
    /// episode, freed when the rollouts are dropped).
    act_pool: TensorPool,
    /// Action tensor shape `[n_agents]`, shared across all publishes.
    act_shape: Arc<[usize]>,
    /// Shared exchange-allocation counter (this pool + every worker's
    /// observation pool).
    exchange_allocs: Arc<AtomicU64>,
    /// Trainer-side monotonic µs of the latest begin-key put per process
    /// worker (the trace merger's causality clamp); empty in threads mode.
    last_begin_put_us: Vec<u64>,
}

impl EnvPool {
    /// Build the pool for a run configuration: resolve `cfg.rl.backend`
    /// against the registry (the LES backend consumes `truth`; others
    /// bring their own) and construct every env and worker thread
    /// exactly once.  All later iterations reuse them.
    pub fn new(cfg: RunConfig, truth: Arc<Truth>, orch: &Orchestrator) -> Result<EnvPool> {
        EnvPool::from_config(cfg, Some(truth), orch)
    }

    /// [`EnvPool::new`] with the DNS truth optional — backends other
    /// than `"les"` generate their own ground truth from the config.
    pub fn from_config(
        cfg: RunConfig,
        truth: Option<Arc<Truth>>,
        orch: &Orchestrator,
    ) -> Result<EnvPool> {
        cfg.validate()?;
        let backend = backend_from_config(&cfg, truth)?;
        EnvPool::with_backend_unchecked(cfg, backend, orch)
    }

    /// Build the pool over an explicit backend instance (the registry
    /// bypass for tests and external backends): construct every env (one
    /// scenario variant each) and every worker thread exactly once.
    pub fn with_backend(
        cfg: RunConfig,
        backend: Arc<dyn CfdBackend>,
        orch: &Orchestrator,
    ) -> Result<EnvPool> {
        cfg.validate()?;
        EnvPool::with_backend_unchecked(cfg, backend, orch)
    }

    /// [`EnvPool::with_backend`] for callers that already validated the
    /// configuration (both public constructors funnel here).
    fn with_backend_unchecked(
        cfg: RunConfig,
        backend: Arc<dyn CfdBackend>,
        orch: &Orchestrator,
    ) -> Result<EnvPool> {
        let n_envs = cfg.rl.n_envs;
        let mut counters = PoolCounters {
            threads_spawned: 0,
            envs_built: 0,
            grids_built: 1,
            iterations: 0,
            exchange_allocs: 0,
        };
        let exchange_allocs = Arc::new(AtomicU64::new(0));

        let mut txs = Vec::with_capacity(n_envs);
        let mut handles = Vec::with_capacity(n_envs);
        let mut variant_of = Vec::with_capacity(n_envs);
        let mut n_actions_of = Vec::with_capacity(n_envs);
        let (mut obs_len, mut n_agents) = (0usize, 0usize);
        let workers = if cfg.orchestrator.workers == "processes" {
            // Shape probe: the envs themselves live in the worker
            // processes, but the collector still needs the pool's
            // shapes and per-env horizons.  Variants never change the
            // obs/action shape (asserted below) and fully determine the
            // horizon, so one probe env per variant suffices.
            let n_var = cfg.n_variants();
            let mut probe_actions = Vec::with_capacity(n_var);
            for v in 0..n_var {
                let rv = cfg.variant_for(v);
                let env = backend
                    .make_env(&rv)
                    .with_context(|| format!("probe env (variant {})", rv.name))?;
                if v == 0 {
                    obs_len = env.obs_len();
                    n_agents = env.n_agents();
                }
                anyhow::ensure!(
                    env.obs_len() == obs_len && env.n_agents() == n_agents,
                    "variant {} shape mismatch: obs {}x{} vs pool {}x{}",
                    rv.name,
                    env.n_agents(),
                    env.obs_len(),
                    n_agents,
                    obs_len
                );
                counters.envs_built += 1;
                probe_actions.push(env.n_actions());
            }
            for i in 0..n_envs {
                variant_of.push(i % n_var);
                n_actions_of.push(probe_actions[i % n_var]);
            }

            let server = orch.serve(&cfg.orchestrator.bind)?;
            let plan = plan_worker_processes(&cfg, n_envs)?;
            let mut children =
                spawn_worker_processes(&cfg, &server.addr().to_string(), &plan)?;
            if let Err(e) = wait_workers_hello(&cfg, orch, &mut children) {
                for c in &mut children {
                    let _ = c.kill();
                    let _ = c.wait();
                }
                return Err(e);
            }
            let n_procs = plan.n_procs;
            Workers::Processes(ProcState {
                children,
                server,
                plan,
                generation: vec![0; n_procs],
                respawns_used: vec![0; n_procs],
                dropped: vec![false; n_procs],
                hb_keys: (0..n_procs).map(|w| Key::new(&ctl_hb_key(w))).collect(),
            })
        } else {
            for i in 0..n_envs {
                let rv = cfg.variant_for(i);
                let env = backend
                    .make_env(&rv)
                    .with_context(|| format!("env {i} (variant {})", rv.name))?;
                if i == 0 {
                    obs_len = env.obs_len();
                    n_agents = env.n_agents();
                }
                // Variants never change the observation/action shape: one
                // policy batch serves the whole pool.
                anyhow::ensure!(
                    env.obs_len() == obs_len && env.n_agents() == n_agents,
                    "env {i} (variant {}) shape mismatch: obs {}x{} vs pool {}x{}",
                    rv.name,
                    env.n_agents(),
                    env.obs_len(),
                    n_agents,
                    obs_len
                );
                counters.envs_built += 1;
                variant_of.push(rv.index);
                n_actions_of.push(env.n_actions());

                let (tx, rx) = mpsc::channel::<Begin>();
                let client = orch.client();
                let allocs = exchange_allocs.clone();
                let wl_timeout = poll_timeout(&cfg);
                let handle = std::thread::Builder::new()
                    .name(format!("env-worker-{i}"))
                    .spawn(move || worker_loop(env, client, i, rx, allocs, wl_timeout))?;
                counters.threads_spawned += 1;
                txs.push(tx);
                handles.push(handle);
            }
            Workers::Threads
        };
        anyhow::ensure!(
            n_agents >= 1 && obs_len % n_agents == 0,
            "backend {}: obs_len {obs_len} must split evenly over {n_agents} agents",
            backend.name()
        );

        // One iteration publishes one action per env per step, all held
        // by the episode records until the rollouts drop — that sum is
        // the action pool's steady-state working set (and its cap).
        let act_cap = n_actions_of.iter().sum::<usize>() + 2;
        let n_proc_workers = match &workers {
            Workers::Processes(p) => p.plan.n_procs,
            Workers::Threads => 0,
        };
        Ok(EnvPool {
            last_begin_put_us: vec![0u64; n_proc_workers],
            batch_obs: vec![0f32; n_envs * obs_len],
            act_pool: TensorPool::new(exchange_allocs.clone(), act_cap),
            act_shape: Arc::from(vec![n_agents]),
            exchange_allocs,
            cfg,
            backend,
            txs,
            handles,
            workers,
            counters,
            abort_client: orch.client(),
            active_protos: Vec::new(),
            variant_of,
            n_actions_of,
            feat: obs_len / n_agents,
            n_agents,
            obs_len,
        })
    }

    /// Agents per env (actions per step per env).
    pub fn n_agents(&self) -> usize {
        self.n_agents
    }

    /// Observation features per agent (`obs_len / n_agents`) — what a
    /// policy consuming this pool must be shaped for.
    pub fn features(&self) -> usize {
        self.feat
    }

    /// The backend this pool's environments were cut from.
    pub fn backend(&self) -> Arc<dyn CfdBackend> {
        self.backend.clone()
    }

    /// A fresh evaluation environment on the pool's shared backend
    /// context (base scenario, no variant overrides) — the training loop
    /// builds one once and reuses it.
    pub fn make_eval_env(&self) -> Result<Box<dyn CfdEnv>> {
        self.backend.make_env(&self.cfg.base_resolved())
    }

    /// Construction counters (steady-state assertion: only `iterations`
    /// may change across `collect` calls, and `exchange_allocs` only
    /// during the warm-up iteration).
    pub fn counters(&self) -> PoolCounters {
        PoolCounters {
            exchange_allocs: self.exchange_allocs.load(Ordering::Relaxed),
            ..self.counters
        }
    }

    /// Run one sampling phase under the current policy (`theta`),
    /// event-driven with the configured `rl.min_batch` (0 = full batch =
    /// synchronous PPO).  The policy is any [`Policy`] runtime backend
    /// (compiled XLA or native).  `run_tag` via `proto` namespaces the
    /// keys; `rng` drives initial-state draws and action sampling.
    pub fn collect(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        policy: &dyn Policy,
        theta: &[f32],
        rng: &mut Rng,
        deterministic: bool,
    ) -> Result<Rollouts> {
        anyhow::ensure!(
            policy.features() == self.feat,
            "policy features {} != pool features {}",
            policy.features(),
            self.feat
        );
        let min_batch = self.cfg.min_batch_effective();
        self.collect_with(
            orch,
            proto,
            |obs, n| policy.forward(theta, obs, n),
            rng,
            deterministic,
            min_batch,
        )
    }

    /// Event-driven sampling phase with an explicit policy closure
    /// (`forward(obs, n_samples)`) — the policy-agnostic core, also used
    /// by tests and benches that run without compiled artifacts.
    pub fn collect_with<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        forward: F,
        rng: &mut Rng,
        deterministic: bool,
        min_batch: usize,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let res = self.collect_event_inner(orch, proto, forward, rng, deterministic, min_batch);
        self.finish_iteration(res.is_err());
        res
    }

    fn collect_event_inner<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        mut forward: F,
        rng: &mut Rng,
        deterministic: bool,
        min_batch: usize,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let t_start = Instant::now();
        let _sp_wave = crate::span!("wave.collect");
        let n_envs = self.cfg.rl.n_envs;
        let chunk = self.obs_len;
        let trainer = orch.client();
        let mut report = SupervisionReport::default();
        let seeds = self.begin_iteration(proto, rng, &mut report)?;
        // Per-env current key set: starts in the iteration's namespace;
        // recovery retargets a crashed block to its replay namespace.
        let mut env_keys: Vec<EnvKeys> = proto.pool_keys(&self.n_actions_of).envs;

        let mut episodes = self.fresh_episodes();
        // Per-env: step index of the state we are waiting for (None once
        // the done-flag arrived or the state is parked in `staged`).
        let mut expect_state: Vec<Option<usize>> = vec![Some(0); n_envs];
        let mut staged: Vec<(usize, usize, Arc<[f32]>)> = Vec::with_capacity(n_envs);
        let mut pending_rewards = 0usize;
        // Per-env completion/outstanding bookkeeping the supervision
        // layer consults: which envs have terminated (or were dropped),
        // and how many rewards each still owes.
        let mut done_seen: Vec<bool> = vec![false; n_envs];
        let mut pending_by_env: Vec<usize> = vec![0; n_envs];
        let mut policy_time = 0.0f64;
        let mut idle_time = 0.0f64;

        // Supervision parameters.  Only the processes mode pays the
        // sliced wait — the threads mode blocks the full poll timeout in
        // one call, exactly as before.
        let poll_to = poll_timeout(&self.cfg);
        let hb_expiry = Duration::from_millis(self.cfg.orchestrator.heartbeat_expiry_ms);
        let slice = (hb_expiry / 4).clamp(Duration::from_millis(10), Duration::from_millis(250));
        let n_workers = match &self.workers {
            Workers::Processes(p) => p.plan.n_procs,
            Workers::Threads => 0,
        };
        // Wave-coalesced action scatter (`orchestrator.batch_ops`,
        // processes mode): sampled actions stage in `act_wave` during
        // the flush and go out as ONE `put_many` per worker block —
        // the trainer-side mirror of the workers' batched take.
        // `block_of[env]` = owning worker (blocks are contiguous env
        // ranges, and the flush walks envs ascending, so consecutive
        // grouping is exact).  Threads mode keeps the per-key publish:
        // there is no wire to coalesce and the allocation gate covers
        // that path.
        let batch_actions =
            self.cfg.orchestrator.batch_ops && matches!(&self.workers, Workers::Processes(_));
        let block_of: Vec<usize> = match &self.workers {
            Workers::Processes(p) => {
                let mut m = vec![0usize; n_envs];
                for (w, &(start, count)) in p.plan.assignments.iter().enumerate() {
                    for e in start..start + count {
                        m[e] = w;
                    }
                }
                m
            }
            Workers::Threads => Vec::new(),
        };
        let mut act_wave: Vec<(Key, Value)> = Vec::new();
        let mut act_wave_envs: Vec<usize> = Vec::new();
        let mut monitor = HeartbeatMonitor::new(n_workers, hb_expiry, Instant::now());
        let mut last_check = Instant::now();
        let mut procs: Option<&mut ProcState> = match &mut self.workers {
            Workers::Processes(p) => Some(p),
            Workers::Threads => None,
        };
        // Envs of workers dropped in earlier waves never start: mark
        // them complete before the wave begins.
        if let Some(p) = procs.as_deref() {
            for env in 0..n_envs {
                if p.env_dropped(env) {
                    expect_state[env] = None;
                    done_seen[env] = true;
                }
            }
        }

        // One persistent subscription for the whole sampling phase.
        // Fixed tags per env for its state/done/fail channels; reward
        // tags come from a free list (an env can have several rewards
        // outstanding).  `tag_events[tag]` is what the tag currently
        // means; every event applies only its own add/remove deltas, so
        // a wave over E envs costs O(E) registry ops (the `sub_ops`
        // counter the integration test asserts on).  `tag_live` tracks
        // which reward tags are registered, so recovery can retarget or
        // retire exactly the outstanding ones.
        let mut sub = trainer.subscription();
        let mut tag_events: Vec<Event> = Vec::with_capacity(4 * n_envs);
        for env in 0..n_envs {
            tag_events.push(Event::State(env, 0));
            tag_events.push(Event::Done(env));
            tag_events.push(Event::Fail(env));
        }
        let mut tag_live: Vec<bool> = vec![false; 3 * n_envs];
        for env in 0..n_envs {
            if done_seen[env] {
                continue; // dropped before start: nothing to wait on
            }
            let ek = &env_keys[env];
            sub.add(3 * env, &ek.state[0]);
            sub.add(3 * env + 1, &ek.done);
            sub.add(3 * env + 2, &ek.fail);
        }
        let mut free_reward_tags: Vec<usize> = Vec::new();

        'wave: loop {
            let expecting = expect_state.iter().filter(|e| e.is_some()).count();
            if expecting == 0 && staged.is_empty() && pending_rewards == 0 {
                break;
            }

            // Flush the policy batch once enough states arrived — or once
            // no further state can arrive without us acting first.
            if !staged.is_empty() && (staged.len() >= min_batch || expecting == 0) {
                staged.sort_unstable_by_key(|&(env, _, _)| env);
                let n_act = staged.len();
                for (k, (_, _, obs)) in staged.iter().enumerate() {
                    self.batch_obs[k * chunk..(k + 1) * chunk].copy_from_slice(obs);
                }
                let tp = Instant::now();
                let out = {
                    let _sp = crate::span!("wave.policy");
                    forward(&self.batch_obs[..n_act * chunk], n_act * self.n_agents)?
                };
                policy_time += tp.elapsed().as_secs_f64();
                anyhow::ensure!(
                    out.mean.len() == n_act * self.n_agents
                        && out.value.len() == n_act * self.n_agents,
                    "policy returned {} means for {} samples",
                    out.mean.len(),
                    n_act * self.n_agents
                );

                // Sample + write actions in env order (ties the RNG stream
                // to env indices, not arrival order: full-batch collection
                // is bitwise-identical to the lock-step reference).
                for (k, (env, t, obs)) in staged.drain(..).enumerate() {
                    let ek = &env_keys[env];
                    let mean = &out.mean[k * self.n_agents..(k + 1) * self.n_agents];
                    let value = &out.value[k * self.n_agents..(k + 1) * self.n_agents];
                    if batch_actions {
                        act_wave_envs.push(env);
                    }
                    publish_action(
                        &trainer,
                        &ek.action[t],
                        &self.act_shape,
                        &mut self.act_pool,
                        &mut episodes[env],
                        obs,
                        mean,
                        value,
                        out.log_std,
                        rng,
                        deterministic,
                        if batch_actions { Some(&mut act_wave) } else { None },
                    );
                    // Subscribe the action's reward and the next state.
                    let rtag = free_reward_tags.pop().unwrap_or_else(|| {
                        tag_events.push(Event::Reward(0, 0));
                        tag_live.push(false);
                        tag_events.len() - 1
                    });
                    tag_events[rtag] = Event::Reward(env, t);
                    tag_live[rtag] = true;
                    sub.add(rtag, &ek.rew[t]);
                    pending_rewards += 1;
                    pending_by_env[env] += 1;
                    expect_state[env] = Some(t + 1);
                    tag_events[3 * env] = Event::State(env, t + 1);
                    sub.add(3 * env, &ek.state[t + 1]);
                }
                // Scatter the staged wave: one `put_many` per worker
                // block, envs ascending within each frame.
                if !act_wave.is_empty() {
                    let _sp = crate::span!("wave.scatter");
                    crate::tcount!("wave.scatter_actions", act_wave.len() as u64);
                    let mut group: Vec<(Key, Value)> = Vec::with_capacity(act_wave.len());
                    let mut cur_w = block_of[act_wave_envs[0]];
                    for (env, kv) in act_wave_envs.drain(..).zip(act_wave.drain(..)) {
                        let w = block_of[env];
                        if w != cur_w {
                            trainer.put_many(std::mem::take(&mut group));
                            cur_w = w;
                        }
                        group.push(kv);
                    }
                    trainer.put_many(group);
                }
                continue;
            }

            // Wait for whichever registered event arrives first.  In the
            // processes mode the wait is sliced so the supervisor can
            // check child exits and heartbeat expiry between events.
            let ti = Instant::now();
            let (tag, val) = loop {
                if let Some(p) = procs.as_deref_mut() {
                    if last_check.elapsed() >= slice {
                        let now = Instant::now();
                        let mut dropped_block = false;
                        for w in 0..p.plan.n_procs {
                            if p.dropped[w] {
                                continue;
                            }
                            let (start, count) = p.block(w);
                            if !block_outstanding(
                                start,
                                count,
                                &expect_state,
                                &done_seen,
                                &pending_by_env,
                            ) {
                                // Block complete: a post-completion stall
                                // is invisible and must not trip respawns.
                                continue;
                            }
                            let hb = trainer.get(&p.hb_keys[w]).and_then(|v| v.as_scalar());
                            let hb_expired = monitor.observe(w, hb, now);
                            let child_dead = matches!(p.children[w].try_wait(), Ok(Some(_)));
                            if !hb_expired && !child_dead {
                                continue;
                            }
                            report.detect_s.push(monitor.stale_for(w, now));
                            crate::tevent!("supervise.detect", w as u64);
                            crate::tlog!(
                                warn,
                                "[supervise] worker {w} {} mid-wave; recovering",
                                if child_dead {
                                    "process exited"
                                } else {
                                    "heartbeat expired (wedged)"
                                }
                            );
                            let t_rec = Instant::now();
                            let recovered = loop {
                                if p.respawns_used[w] >= self.cfg.fault.max_respawns {
                                    break false;
                                }
                                p.respawns_used[w] += 1;
                                report.respawns += 1;
                                // Replay under a fresh namespace: the old
                                // tag's keys hold arbitrary prefixes of
                                // the block's streams and are burned.
                                let rtag =
                                    format!("{}~r{}", proto.run_tag(), report.respawns);
                                let rproto = Protocol::new(&rtag);
                                // Pre-feed every action drawn so far, so
                                // the replacement streams to the crash
                                // point without one new policy draw.
                                for env in start..start + count {
                                    let nk = rproto.env_keys(env, self.n_actions_of[env]);
                                    for (t, step) in episodes[env].steps.iter().enumerate() {
                                        trainer.put_tensor_shared(
                                            &nk.action[t],
                                            self.act_shape.clone(),
                                            step.act.clone(),
                                        );
                                    }
                                }
                                match p.respawn_process(&self.cfg, &trainer, w) {
                                    Ok(()) => {
                                        let envs: Vec<(usize, u64)> = (start..start + count)
                                            .map(|i| (i, seeds[i]))
                                            .collect();
                                        trainer.put_bytes(
                                            &ctl_begin_key(w),
                                            encode_begin(rproto.run_tag(), &envs),
                                        );
                                        self.last_begin_put_us[w] =
                                            crate::util::telemetry::now_us();
                                        // Retarget the block's live
                                        // subscriptions into the replay
                                        // namespace (`add` on a tag
                                        // replaces its key; queued stale
                                        // deliveries from the old keys
                                        // are skipped on receipt).
                                        for env in start..start + count {
                                            let nk = rproto
                                                .env_keys(env, self.n_actions_of[env]);
                                            if let Some(t) = expect_state[env] {
                                                sub.add(3 * env, &nk.state[t]);
                                            }
                                            if !done_seen[env] {
                                                sub.add(3 * env + 1, &nk.done);
                                            }
                                            sub.add(3 * env + 2, &nk.fail);
                                            for tag in 3 * n_envs..tag_events.len() {
                                                if !tag_live[tag] {
                                                    continue;
                                                }
                                                if let Event::Reward(e, t) = tag_events[tag]
                                                {
                                                    if e == env {
                                                        sub.add(tag, &nk.rew[t]);
                                                    }
                                                }
                                            }
                                            env_keys[env] = nk;
                                        }
                                        self.active_protos.push(rproto);
                                        break true;
                                    }
                                    Err(e) => {
                                        crate::tlog!(
                                            error,
                                            "[supervise] respawn of worker {w} failed: {e:#}"
                                        );
                                    }
                                }
                            };
                            if recovered {
                                monitor.arm(w, Instant::now());
                                report.recover_s.push(t_rec.elapsed().as_secs_f64());
                                crate::tevent!("supervise.recover", w as u64);
                                crate::tlog!(
                                    warn,
                                    "[supervise] worker {w} respawned (budget {}/{})",
                                    p.respawns_used[w], self.cfg.fault.max_respawns
                                );
                            } else {
                                // Budget exhausted: drop the block and
                                // finish the wave short instead of
                                // aborting training.
                                let _ = p.children[w].kill();
                                let _ = p.children[w].wait();
                                p.dropped[w] = true;
                                staged.retain(|&(e, _, _)| e < start || e >= start + count);
                                for env in start..start + count {
                                    if expect_state[env].is_some() {
                                        sub.remove(3 * env);
                                        expect_state[env] = None;
                                    }
                                    if !done_seen[env] {
                                        sub.remove(3 * env + 1);
                                        done_seen[env] = true;
                                    }
                                    sub.remove(3 * env + 2);
                                    for tag in 3 * n_envs..tag_events.len() {
                                        if !tag_live[tag] {
                                            continue;
                                        }
                                        if let Event::Reward(e, _) = tag_events[tag] {
                                            if e == env {
                                                sub.remove(tag);
                                                tag_live[tag] = false;
                                                free_reward_tags.push(tag);
                                                pending_rewards -= 1;
                                                pending_by_env[env] -= 1;
                                            }
                                        }
                                    }
                                }
                                crate::tlog!(
                                    error,
                                    "[supervise] worker {w} dropped after exhausting \
                                     max_respawns = {}; envs {start}..{} finish short",
                                    self.cfg.fault.max_respawns,
                                    start + count
                                );
                                dropped_block = true;
                            }
                        }
                        last_check = Instant::now();
                        if dropped_block {
                            // The drop may have completed the wave or
                            // unblocked a flush: re-evaluate from the top.
                            idle_time += ti.elapsed().as_secs_f64();
                            continue 'wave;
                        }
                    }
                }
                let wait = if procs.is_some() { slice } else { poll_to };
                let t_wait = crate::util::telemetry::enabled().then(Instant::now);
                if let Some(hit) = sub.wait_take(wait) {
                    if let Some(t0) = t_wait {
                        crate::util::telemetry::HistId::Exchange
                            .observe_us(t0.elapsed().as_micros() as u64);
                    }
                    break hit;
                }
                anyhow::ensure!(
                    ti.elapsed() < poll_to,
                    "collector timed out: {} states expected, {} rewards pending",
                    expect_state.iter().filter(|e| e.is_some()).count(),
                    pending_rewards
                );
            };
            idle_time += ti.elapsed().as_secs_f64();
            match tag_events[tag] {
                Event::State(env, t) => {
                    let data = val
                        .tensor_data()
                        .with_context(|| format!("env {env} state at step {t} is not a tensor"))?;
                    anyhow::ensure!(
                        data.len() == chunk,
                        "env {env} state has {} floats, expected {chunk}",
                        data.len()
                    );
                    staged.push((env, t, data));
                    expect_state[env] = None; // parked in `staged` until acted on
                    sub.remove(3 * env);
                }
                Event::Done(env) => {
                    expect_state[env] = None;
                    done_seen[env] = true;
                    // Neither the post-terminal state nor another done
                    // can arrive: retire both channels (fail stays).
                    sub.remove(3 * env);
                    sub.remove(3 * env + 1);
                }
                Event::Reward(env, t) => {
                    let r = val
                        .as_scalar()
                        .with_context(|| format!("env {env} reward at step {t} not a scalar"))?;
                    episodes[env].steps[t].reward = r;
                    pending_rewards -= 1;
                    pending_by_env[env] -= 1;
                    tag_live[tag] = false;
                    sub.remove(tag);
                    free_reward_tags.push(tag);
                }
                Event::Fail(env) => {
                    bail!("env worker {env} failed: {}", fail_message(&val));
                }
            }
        }

        // A degraded wave completes short: surface the dropped envs and
        // return only the surviving episodes (per-variant accounting
        // stays correct — every episode carries its variant tag).
        if let Some(p) = procs.as_deref() {
            report.dropped_envs = p.dropped_envs();
            for &env in report.dropped_envs.iter().rev() {
                episodes.remove(env);
            }
        }

        self.counters.iterations += 1;
        Ok(Rollouts {
            episodes,
            sample_time_s: t_start.elapsed().as_secs_f64(),
            policy_time_s: policy_time,
            idle_time_s: idle_time,
            supervision: report,
        })
    }

    /// Lock-step reference collector: the paper's literal synchronous
    /// gather — one wave per RL step, states polled env-by-env — kept as
    /// the bitwise-equivalence oracle for the event-driven path and as
    /// the §6.2 baseline for the training bench.  Unlike the seed
    /// implementation it checks the done-flag at every step, so an env
    /// that terminates early can no longer wedge the gather loop until
    /// the poll timeout.
    pub fn collect_lockstep_with<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        forward: F,
        rng: &mut Rng,
        deterministic: bool,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let res = self.collect_lockstep_inner(orch, proto, forward, rng, deterministic);
        self.finish_iteration(res.is_err());
        res
    }

    fn collect_lockstep_inner<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        mut forward: F,
        rng: &mut Rng,
        deterministic: bool,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let t_start = Instant::now();
        let n_envs = self.cfg.rl.n_envs;
        let chunk = self.obs_len;
        let poll_to = poll_timeout(&self.cfg);
        let trainer = orch.client();
        let mut report = SupervisionReport::default();
        self.begin_iteration(proto, rng, &mut report)?;
        // The lock-step oracle has no recovery path: a degraded pool
        // (dropped workers) must use the event-driven collector.
        if let Workers::Processes(p) = &self.workers {
            anyhow::ensure!(
                !p.dropped.iter().any(|&d| d),
                "lock-step collector cannot run a degraded pool (dropped envs: {:?})",
                p.dropped_envs()
            );
        }
        let keys = proto.pool_keys(&self.n_actions_of);

        let mut episodes = self.fresh_episodes();
        let mut done = vec![false; n_envs];
        let mut acted: Vec<usize> = Vec::with_capacity(n_envs);
        let mut wave_obs: Vec<Arc<[f32]>> = Vec::with_capacity(n_envs);
        let mut policy_time = 0.0f64;
        let mut idle_time = 0.0f64;
        let max_t = self.n_actions_of.iter().copied().max().unwrap_or(0);

        for t in 0..max_t {
            // Gather the wave's states in env order, checking the
            // done-flag per env so early terminations are absorbed.
            acted.clear();
            wave_obs.clear();
            for env in 0..n_envs {
                if done[env] {
                    continue;
                }
                let ek = &keys.envs[env];
                let ti = Instant::now();
                let (hit, val) = trainer
                    .poll_any_take(&[&ek.state[t], &ek.done, &ek.fail], poll_to)
                    .with_context(|| format!("trainer: no state from env {env} step {t}"))?;
                idle_time += ti.elapsed().as_secs_f64();
                match hit {
                    0 => {
                        let data = val.tensor_data().context("state must be a tensor")?;
                        anyhow::ensure!(
                            data.len() == chunk,
                            "env {env} state has {} floats, expected {chunk}",
                            data.len()
                        );
                        self.batch_obs[acted.len() * chunk..(acted.len() + 1) * chunk]
                            .copy_from_slice(&data);
                        acted.push(env);
                        wave_obs.push(data);
                    }
                    1 => done[env] = true,
                    _ => bail!("env worker {env} failed: {}", fail_message(&val)),
                }
            }
            if acted.is_empty() {
                break; // every env terminated before the longest horizon
            }

            // One batched policy evaluation for the wave.
            let n_act = acted.len();
            let tp = Instant::now();
            let out = forward(&self.batch_obs[..n_act * chunk], n_act * self.n_agents)?;
            policy_time += tp.elapsed().as_secs_f64();

            // Sample actions, write them back, record the steps (the one
            // shared publish site with the event-driven collector).
            for (k, &env) in acted.iter().enumerate() {
                let mean = &out.mean[k * self.n_agents..(k + 1) * self.n_agents];
                let value = &out.value[k * self.n_agents..(k + 1) * self.n_agents];
                publish_action(
                    &trainer,
                    &keys.envs[env].action[t],
                    &self.act_shape,
                    &mut self.act_pool,
                    &mut episodes[env],
                    wave_obs[k].clone(),
                    mean,
                    value,
                    out.log_std,
                    rng,
                    deterministic,
                    None,
                );
            }

            // Collect the shaped rewards (computed env-side, Eqs. 4-5
            // for the in-tree backends).
            for &env in &acted {
                let ek = &keys.envs[env];
                let ti = Instant::now();
                let (hit, val) = trainer
                    .poll_any_take(&[&ek.rew[t], &ek.fail], poll_to)
                    .with_context(|| format!("trainer: no reward from env {env} step {t}"))?;
                idle_time += ti.elapsed().as_secs_f64();
                if hit != 0 {
                    bail!("env worker {env} failed: {}", fail_message(&val));
                }
                let r = val.as_scalar().context("reward must be a scalar")?;
                episodes[env].steps[t].reward = r;
            }
        }

        // Every env must have signalled termination.
        for env in 0..n_envs {
            if done[env] {
                continue;
            }
            let ek = &keys.envs[env];
            let (hit, val) = trainer
                .poll_any_take(&[&ek.done, &ek.fail], poll_to)
                .with_context(|| format!("env {env} never signalled done"))?;
            if hit != 0 {
                bail!("env worker {env} failed: {}", fail_message(&val));
            }
        }

        self.counters.iterations += 1;
        Ok(Rollouts {
            episodes,
            sample_time_s: t_start.elapsed().as_secs_f64(),
            policy_time_s: policy_time,
            idle_time_s: idle_time,
            supervision: report,
        })
    }

    /// Raise the iteration's abort flag so workers still blocked on an
    /// action key of a failed iteration unpark immediately (instead of
    /// running out the poll timeout) and return to the begin-channel.  The
    /// flag is deliberately never deleted: a worker that was mid-CFD-step
    /// when the abort was raised subscribes to `[action, abort]` later
    /// and must still find it.  The pool stays usable afterwards, but a
    /// retry must use a **fresh run tag** — the failed tag's namespace
    /// (abort flag, stale state/reward keys) is burned.
    fn abort_iteration(&self, proto: &Protocol) {
        self.abort_client.put_flag(&proto.abort_key(), true);
    }

    /// Close out one sampling phase: on failure raise the abort flag for
    /// every namespace the phase touched (the iteration tag plus any
    /// replay tags recovery opened); on success forget them so a later
    /// `Drop` does not write stray abort keys for a cleanly completed
    /// iteration.
    fn finish_iteration(&mut self, failed: bool) {
        if failed {
            for p in std::mem::take(&mut self.active_protos) {
                self.abort_iteration(&p);
            }
        } else {
            self.active_protos.clear();
        }
    }

    /// Wake every parked worker for one iteration (per-env RNG streams
    /// split in env order, exactly as the seed's spawn loop did — both
    /// arms draw the identical `split_seed` sequence in the identical
    /// global env order, so the env->process split is invisible to every
    /// RNG stream in the run).  Returns the seed vector: the supervision
    /// layer replays a crashed block's streams from it bit-identically.
    ///
    /// A worker found dead *between* waves is respawned here against the
    /// `[fault] max_respawns` budget (no replay needed — nothing of this
    /// wave has started); on exhaustion its block is dropped.
    fn begin_iteration(
        &mut self,
        proto: &Protocol,
        rng: &mut Rng,
        report: &mut SupervisionReport,
    ) -> Result<Vec<u64>> {
        self.active_protos.push(proto.clone());
        let seeds: Vec<u64> = (0..self.cfg.rl.n_envs)
            .map(|i| rng.split_seed(i as u64))
            .collect();
        match &mut self.workers {
            Workers::Threads => {
                for (i, tx) in self.txs.iter().enumerate() {
                    tx.send(Begin {
                        proto: proto.clone(),
                        rng: Rng::new(seeds[i]),
                    })
                    .map_err(|_| anyhow!("env worker {i} has exited (earlier panic?)"))?;
                }
            }
            Workers::Processes(p) => {
                for w in 0..p.plan.n_procs {
                    if p.dropped[w] {
                        continue;
                    }
                    if matches!(p.children[w].try_wait(), Ok(Some(_))) {
                        crate::tevent!("supervise.detect", w as u64);
                        crate::tlog!(
                            warn,
                            "[supervise] worker {w} died between waves; respawning"
                        );
                        let recovered = loop {
                            if p.respawns_used[w] >= self.cfg.fault.max_respawns {
                                break false;
                            }
                            p.respawns_used[w] += 1;
                            report.respawns += 1;
                            match p.respawn_process(&self.cfg, &self.abort_client, w) {
                                Ok(()) => break true,
                                Err(e) => {
                                    crate::tlog!(
                                        error,
                                        "[supervise] respawn of worker {w} failed: {e:#}"
                                    );
                                }
                            }
                        };
                        if !recovered {
                            let _ = p.children[w].kill();
                            let _ = p.children[w].wait();
                            p.dropped[w] = true;
                            crate::tlog!(
                                error,
                                "[supervise] worker {w} dropped after exhausting \
                                 max_respawns = {}",
                                self.cfg.fault.max_respawns
                            );
                            continue;
                        }
                        crate::tevent!("supervise.recover", w as u64);
                    }
                    let (start, count) = p.block(w);
                    let envs: Vec<(usize, u64)> =
                        (start..start + count).map(|i| (i, seeds[i])).collect();
                    self.abort_client
                        .put_bytes(&ctl_begin_key(w), encode_begin(proto.run_tag(), &envs));
                    self.last_begin_put_us[w] = crate::util::telemetry::now_us();
                }
            }
        }
        Ok(seeds)
    }

    /// Ask every live env-worker process to ship its telemetry buffers
    /// and collect the blobs: bump the flush scalar (read non-consuming
    /// worker-side, so one key serves every worker), then take each
    /// worker's blob off its `ctl:tel` key.  Returns
    /// `(worker, blob, begin_put_us)` triples — the begin timestamp is
    /// the trainer-side half of the clock-alignment handshake the trace
    /// merger clamps worker offsets with.  Empty in threads mode (the
    /// trainer's own rings already hold everything) or with telemetry
    /// off.  Telemetry keys live under the ctl prefix, so none of this
    /// moves the store's data-frame or batched-key counters.
    pub fn gather_worker_telemetry(&mut self, iteration: u64) -> Vec<(usize, Vec<u8>, u64)> {
        if !crate::util::telemetry::enabled() {
            return Vec::new();
        }
        let p = match &self.workers {
            Workers::Processes(p) => p,
            Workers::Threads => return Vec::new(),
        };
        self.abort_client
            .put_scalar(CTL_TEL_FLUSH_KEY, iteration as f64 + 1.0);
        let wait = poll_timeout(&self.cfg).min(Duration::from_secs(5));
        let mut blobs = Vec::new();
        for w in 0..p.plan.n_procs {
            if p.dropped[w] {
                continue;
            }
            match self.abort_client.poll_take(&ctl_tel_key(w), wait) {
                Some(Value::Bytes(b)) => {
                    blobs.push((w, b.to_vec(), self.last_begin_put_us[w]));
                }
                Some(_) => {
                    crate::tlog!(warn, "worker {w} telemetry blob has unexpected type");
                }
                None => {
                    crate::tlog!(
                        warn,
                        "worker {w} telemetry blob did not arrive within {:?}",
                        wait
                    );
                }
            }
        }
        blobs
    }

    /// Empty per-env episodes tagged with their scenario variants.
    fn fresh_episodes(&self) -> Vec<Episode> {
        self.variant_of
            .iter()
            .map(|&variant| Episode {
                variant,
                ..Episode::default()
            })
            .collect()
    }
}

impl Drop for EnvPool {
    fn drop(&mut self) {
        // Unblock workers stuck mid-iteration (e.g. after an external
        // kill): they subscribe to the abort flag next to their action
        // key, so this wakes them without waiting out the poll timeout.
        for proto in std::mem::take(&mut self.active_protos) {
            self.abort_iteration(&proto);
        }
        if let Workers::Processes(p) = &mut self.workers {
            // Stop flag first (read non-consuming, so one flag serves
            // every worker), then a bounded reap; a worker that ignores
            // it is killed.  The exchange server (`server`) drops only
            // after this body, i.e. it keeps serving until the children
            // are gone.
            self.abort_client.put_flag(CTL_STOP_KEY, true);
            let deadline = Instant::now() + reap_timeout(&self.cfg);
            for child in p.children.iter_mut() {
                loop {
                    match child.try_wait() {
                        Ok(Some(_)) | Err(_) => break,
                        Ok(None) if Instant::now() >= deadline => {
                            let _ = child.kill();
                            let _ = child.wait();
                            break;
                        }
                        Ok(None) => std::thread::sleep(Duration::from_millis(25)),
                    }
                }
            }
        }
        // Dropping the begin-channels unparks every idle worker with a
        // recv error, which is the shutdown signal.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One collector event: a subscription tag resolved to its meaning.
#[derive(Clone, Copy)]
enum Event {
    /// State tensor from env at step.
    State(usize, usize),
    /// Done-flag: no further states from this env.
    Done(usize),
    /// Shaped-reward scalar for (env, step).
    Reward(usize, usize),
    /// Worker failure report.
    Fail(usize),
}

/// Sample (or, when deterministic, copy) one env's action from the policy
/// head, publish it zero-copy under the env's action key and record the
/// step — the single action-publish site shared by the event-driven and
/// lock-step collectors.  The action buffer comes from the recycled pool;
/// the store, the episode record and the pool share one allocation.
#[allow(clippy::too_many_arguments)]
/// With `batch: Some(wave)` the action is staged instead of published —
/// the caller scatters the whole wave as one `put_many` per worker
/// block.  Sampling, log-prob and step recording are identical either
/// way, so the RNG stream (and hence every episode) does not depend on
/// which path ran.
#[allow(clippy::too_many_arguments)]
fn publish_action(
    trainer: &Client,
    action_key: &Key,
    act_shape: &Arc<[usize]>,
    act_pool: &mut TensorPool,
    episode: &mut Episode,
    obs: Arc<[f32]>,
    mean: &[f32],
    value: &[f32],
    log_std: f32,
    rng: &mut Rng,
    deterministic: bool,
    batch: Option<&mut Vec<(Key, Value)>>,
) {
    let mut act = act_pool.take_free(mean.len());
    {
        let dst = Arc::get_mut(&mut act).expect("pool hands out unique buffers");
        if deterministic {
            dst.copy_from_slice(mean);
        } else {
            gaussian::sample_into(mean, log_std, rng, dst);
        }
    }
    let logp = gaussian::log_prob(&act, mean, log_std);
    match batch {
        Some(wave) => wave.push((
            action_key.clone(),
            Value::tensor_shared(act_shape.clone(), act.clone()),
        )),
        None => trainer.put_tensor_shared(action_key, act_shape.clone(), act.clone()),
    }
    episode.steps.push(StepRecord {
        obs,
        act: act.clone(),
        logp,
        value: value.to_vec(),
        reward: 0.0, // filled by the reward event
    });
    act_pool.put_back(act);
}

/// Does worker block `start..start+count` still owe the collector
/// anything — a state, a done-flag, or an outstanding reward?  Blocks
/// with nothing outstanding are exempt from liveness checks: a worker
/// that wedges *after* finishing its block cannot stall the wave, so
/// respawning it mid-wave would be pure waste.
fn block_outstanding(
    start: usize,
    count: usize,
    expect_state: &[Option<usize>],
    done_seen: &[bool],
    pending_by_env: &[usize],
) -> bool {
    (start..start + count)
        .any(|e| expect_state[e].is_some() || !done_seen[e] || pending_by_env[e] > 0)
}

/// Render a failure-report value (bytes put by the worker) for an error.
fn fail_message(val: &Value) -> String {
    match val {
        Value::Bytes(b) => String::from_utf8_lossy(b).into_owned(),
        other => format!("{other:?}"),
    }
}

/// The persistent worker body: park on the begin-channel, run one episode
/// through the store, park again.  Exits when the pool drops the channel.
/// The observation buffer pool and the action-conversion scratch persist
/// across iterations, so a steady-state episode allocates nothing on the
/// exchange path.
///
/// Both `Err` returns and panics inside the episode (caught so the thread
/// survives; the next begin resets the env completely) are surfaced
/// through the fail key, so the collector aborts the iteration instead of
/// running into its poll timeout.
fn worker_loop(
    mut env: Box<dyn CfdEnv>,
    client: Client,
    idx: usize,
    rx: mpsc::Receiver<Begin>,
    allocs: Arc<AtomicU64>,
    poll_timeout: Duration,
) {
    // Working set: one obs buffer per step (held by the trainer until
    // the iteration's rollouts drop) plus the initial state.
    let mut obs_pool = TensorPool::new(allocs, env.n_actions() + 2);
    let mut act_buf: Vec<f64> = Vec::with_capacity(env.n_agents());
    let obs_shape: Arc<[usize]> = Arc::from(vec![env.obs_len()]);
    while let Ok(Begin { proto, mut rng }) = rx.recv() {
        let keys = proto.env_keys(idx, env.n_actions());
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_episode(
                env.as_mut(),
                &client,
                &keys,
                idx,
                &mut rng,
                &mut obs_pool,
                &mut act_buf,
                &obs_shape,
                poll_timeout,
            )
        }));
        let failure = match outcome {
            Ok(Ok(())) => None,
            Ok(Err(e)) => Some(format!("{e:#}")),
            Err(payload) => Some(format!("panic: {}", panic_message(&payload))),
        };
        if let Some(msg) = failure {
            client.put_bytes(&keys.fail, msg.into_bytes());
        }
    }
}

/// Per-env working set of a batched block thread — exactly
/// [`worker_loop`]'s locals, one per hosted env.
struct BlockSlot {
    idx: usize,
    env: Box<dyn CfdEnv>,
    obs_pool: TensorPool,
    act_buf: Vec<f64>,
    obs_shape: Arc<[usize]>,
}

/// How long one batched action take blocks before re-checking the
/// shared abort flag and the step deadline.
const BLOCK_TAKE_SLICE: Duration = Duration::from_millis(250);

/// Lockstep replacement for the per-env [`worker_loop`] threads
/// (`orchestrator.batch_ops`): one thread hosts the whole block and a
/// failure lands on the *offending* env's fail key so supervision
/// attributes it correctly.
fn block_worker_loop(
    envs: Vec<(usize, Box<dyn CfdEnv>)>,
    client: Client,
    rx: mpsc::Receiver<BlockBegin>,
    allocs: Arc<AtomicU64>,
    poll_timeout: Duration,
) {
    let mut slots: Vec<BlockSlot> = envs
        .into_iter()
        .map(|(idx, env)| BlockSlot {
            idx,
            obs_pool: TensorPool::new(allocs.clone(), env.n_actions() + 2),
            act_buf: Vec::with_capacity(env.n_agents()),
            obs_shape: Arc::from(vec![env.obs_len()]),
            env,
        })
        .collect();
    while let Ok(BlockBegin { proto, seeds }) = rx.recv() {
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_block_episode(&mut slots, &client, &proto, &seeds, poll_timeout)
        }));
        let failure = match outcome {
            Ok(Ok(())) => None,
            Ok(Err((idx, e))) => Some((idx, format!("{e:#}"))),
            Err(payload) => {
                // A panic unwound out of the lockstep loop; attribute it
                // to the block's first env (the collector only needs
                // *an* owner inside the block to fail the iteration).
                let idx = slots.first().map(|s| s.idx).unwrap_or(0);
                Some((idx, format!("panic: {}", panic_message(&payload))))
            }
        };
        if let Some((idx, msg)) = failure {
            if let Some(slot) = slots.iter().find(|s| s.idx == idx) {
                let keys = proto.env_keys(idx, slot.env.n_actions());
                client.put_bytes(&keys.fail, msg.into_bytes());
            }
        }
    }
}

/// One wave-coalesced episode batch over a worker's env block: the
/// wire pattern collapses to ONE `put_many` frame per block per step
/// direction (all initial states; then, per step, one batched action
/// take and one batched rewards/dones/next-states publish) instead of
/// ~4 per-key frames per env per step.  Every per-env data stream —
/// reset draw, action application, reward, observation — is exactly
/// [`run_episode`]'s, so episodes are bit-identical to the per-key
/// path; only the grouping on the wire changes.  Envs leave the
/// lockstep set as they terminate, so mixed-horizon blocks work.
fn run_block_episode(
    slots: &mut [BlockSlot],
    client: &Client,
    proto: &Protocol,
    seeds: &[(usize, u64)],
    poll_timeout: Duration,
) -> std::result::Result<(), (usize, anyhow::Error)> {
    struct Live {
        slot: usize,
        keys: EnvKeys,
        rng: Rng,
        n_actions: usize,
    }
    let mut lives: Vec<Live> = Vec::with_capacity(seeds.len());
    for &(env_idx, seed) in seeds {
        let slot = slots
            .iter()
            .position(|s| s.idx == env_idx)
            .ok_or_else(|| (env_idx, anyhow!("begin env {env_idx} not hosted by this block")))?;
        let n_actions = slots[slot].env.n_actions();
        lives.push(Live {
            slot,
            keys: proto.env_keys(env_idx, n_actions),
            rng: Rng::new(seed),
            n_actions,
        });
    }
    // Wave 0: reset every env, publish ALL initial states as one frame.
    let mut batch: Vec<(Key, Value)> = Vec::with_capacity(lives.len() * 3);
    for l in &mut lives {
        let s = &mut slots[l.slot];
        s.env.reset_in_place(&mut l.rng, false);
        let mut buf = s.obs_pool.take_free(s.env.obs_len());
        s.env
            .observe_into(Arc::get_mut(&mut buf).expect("pool hands out unique buffers"));
        batch.push((
            l.keys.state[0].clone(),
            Value::tensor_shared(s.obs_shape.clone(), buf.clone()),
        ));
        s.obs_pool.put_back(buf);
    }
    client.put_many(std::mem::take(&mut batch));
    let mut t = 0usize;
    let mut actions: Vec<Option<Value>> = Vec::new();
    while !lives.is_empty() {
        // One batched take per step: every take consumes the action key
        // (seed semantics) and the shared abort flag is polled
        // non-consumingly on empty rounds, never taken — a take would
        // eat it for the other workers.
        actions.clear();
        actions.resize(lives.len(), None);
        let mut missing = lives.len();
        let deadline = Instant::now() + poll_timeout;
        while missing > 0 {
            let mut pending_idx: Vec<usize> = Vec::with_capacity(missing);
            let mut want: Vec<&Key> = Vec::with_capacity(missing);
            for (i, l) in lives.iter().enumerate() {
                if actions[i].is_none() {
                    pending_idx.push(i);
                    want.push(&l.keys.action[t]);
                }
            }
            let hits = client.take_many(&want, BLOCK_TAKE_SLICE);
            if hits.is_empty() {
                let owner = slots[lives[0].slot].idx;
                if client.poll(&lives[0].keys.abort, Duration::ZERO).is_some() {
                    return Err((owner, anyhow!("env {owner}: iteration aborted at step {t}")));
                }
                if Instant::now() >= deadline {
                    return Err((owner, anyhow!("env {owner}: no action at step {t}")));
                }
                continue;
            }
            for (wi, v) in hits {
                actions[pending_idx[wi]] = Some(v);
                missing -= 1;
            }
        }
        // Step every env in ascending env order, publish the block's
        // rewards / done flags / next states as one frame.
        let mut finished: Vec<bool> = vec![false; lives.len()];
        for (i, l) in lives.iter_mut().enumerate() {
            let s = &mut slots[l.slot];
            let act = actions[i].take().expect("collected above");
            let data = act
                .as_tensor()
                .ok_or_else(|| (s.idx, anyhow!("env {}: action must be a tensor", s.idx)))?
                .1;
            s.act_buf.clear();
            s.act_buf.extend(data.iter().map(|&a| a as f64));
            let out = s.env.step(&s.act_buf);
            batch.push((l.keys.rew[t].clone(), Value::Scalar(out.reward)));
            if out.done {
                batch.push((l.keys.done.clone(), Value::Flag(true)));
                finished[i] = true;
            } else {
                let mut buf = s.obs_pool.take_free(s.env.obs_len());
                s.env
                    .observe_into(Arc::get_mut(&mut buf).expect("pool hands out unique buffers"));
                batch.push((
                    l.keys.state[t + 1].clone(),
                    Value::tensor_shared(s.obs_shape.clone(), buf.clone()),
                ));
                s.obs_pool.put_back(buf);
                if t + 1 >= l.n_actions {
                    finished[i] = true;
                }
            }
        }
        client.put_many(std::mem::take(&mut batch));
        let mut i = 0;
        lives.retain(|_| {
            let f = finished[i];
            i += 1;
            !f
        });
        t += 1;
    }
    Ok(())
}

/// Resolve the binary to spawn as `relexi env-worker`: the
/// `RELEXI_WORKER_BIN` env var (integration tests point it at the
/// Cargo-built binary) > `orchestrator.worker_bin` > the currently
/// running executable.
fn worker_binary(cfg: &RunConfig) -> Result<std::path::PathBuf> {
    if let Ok(p) = std::env::var("RELEXI_WORKER_BIN") {
        if !p.is_empty() {
            return Ok(p.into());
        }
    }
    if !cfg.orchestrator.worker_bin.is_empty() {
        return Ok(cfg.orchestrator.worker_bin.clone().into());
    }
    std::env::current_exe().context("resolving the running executable as worker binary")
}

/// Spawn one `relexi env-worker` child per plan assignment (all at
/// generation 0; respawns go through [`spawn_one_worker`] directly).
fn spawn_worker_processes(
    cfg: &RunConfig,
    addr: &str,
    plan: &WorkerPlan,
) -> Result<Vec<std::process::Child>> {
    let mut children = Vec::with_capacity(plan.n_procs);
    for (w, &(start, count)) in plan.assignments.iter().enumerate() {
        children.push(spawn_one_worker(cfg, addr, w, start, count, 0)?);
    }
    Ok(children)
}

/// Spawn one `relexi env-worker` child.  The full effective config
/// travels in the `RELEXI_WORKER_CONFIG` env var (no staging to a shared
/// filesystem needed); the exchange address and the worker's env block
/// go on the command line.  `generation` counts the worker's
/// incarnations — respawns bump it, and fault-plan directives default to
/// firing only at generation 0, so a replacement does not re-trip the
/// fault that killed its predecessor.
fn spawn_one_worker(
    cfg: &RunConfig,
    addr: &str,
    w: usize,
    start: usize,
    count: usize,
    generation: u32,
) -> Result<std::process::Child> {
    let bin = worker_binary(cfg)?;
    std::process::Command::new(&bin)
        .arg("env-worker")
        .arg("--connect")
        .arg(addr)
        .arg("--transport")
        .arg(&cfg.orchestrator.transport)
        .arg("--worker-id")
        .arg(w.to_string())
        .arg("--env-start")
        .arg(start.to_string())
        .arg("--env-count")
        .arg(count.to_string())
        .arg("--generation")
        .arg(generation.to_string())
        .env("RELEXI_WORKER_CONFIG", cfg.to_toml_string())
        .spawn()
        .with_context(|| format!("spawning env-worker {w} ({})", bin.display()))
}

/// Block until every spawned worker has put its hello flag (its env
/// threads are up and its transport works), detecting workers that died
/// during startup instead of waiting out the timeout.
fn wait_workers_hello(
    cfg: &RunConfig,
    orch: &Orchestrator,
    children: &mut [std::process::Child],
) -> Result<()> {
    let client = orch.client();
    let deadline = Instant::now() + hello_timeout(cfg);
    for (w, child) in children.iter_mut().enumerate() {
        wait_one_hello(&client, child, w, deadline)?;
    }
    Ok(())
}

/// Block until worker `w` puts its hello flag or the deadline passes,
/// detecting a child that died during startup instead of waiting it out.
fn wait_one_hello(
    client: &Client,
    child: &mut std::process::Child,
    w: usize,
    deadline: Instant,
) -> Result<()> {
    let key = ctl_hello_key(w);
    loop {
        if client.poll(&key, Duration::from_millis(200)).is_some() {
            return Ok(());
        }
        if let Ok(Some(status)) = child.try_wait() {
            bail!("env-worker {w} exited during startup ({status})");
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "env-worker {w} did not say hello before its deadline"
        );
    }
}

/// The env-worker process' half of the pool: hosts one contiguous block
/// of the global env range as persistent worker threads — the exact
/// [`worker_loop`] the threads mode runs, fed from decoded begin
/// messages instead of an in-process channel fan-out.  Constructed by
/// `relexi env-worker` after dialing the exchange; its `Drop` joins the
/// threads (teardown is driven by the caller's control loop reacting to
/// the stop flag or a dead transport).
pub struct WorkerHost {
    txs: Vec<mpsc::Sender<Begin>>,
    /// Batched block mode (`orchestrator.batch_ops`): one lockstep
    /// thread runs the whole env block and exchanges one frame per
    /// block per step direction instead of ~4 per env per step.
    block_tx: Option<mpsc::Sender<BlockBegin>>,
    handles: Vec<JoinHandle<()>>,
    env_start: usize,
    env_count: usize,
}

impl WorkerHost {
    /// Build the block's envs (scenario variants resolved by *global*
    /// env index, so the split changes nothing) and spawn their worker
    /// threads on `client` — normally a remote client dialing the
    /// trainer's exchange.  With `orchestrator.batch_ops` (the
    /// default), the block runs as ONE lockstep thread whose wire
    /// pattern is wave-coalesced ([`run_block_episode`]); per-env
    /// episode streams are bit-identical either way.
    pub fn spawn(
        cfg: &RunConfig,
        client: &Client,
        env_start: usize,
        env_count: usize,
    ) -> Result<WorkerHost> {
        cfg.validate()?;
        anyhow::ensure!(
            env_count >= 1 && env_start + env_count <= cfg.rl.n_envs,
            "env block {env_start}..{} outside the pool of {}",
            env_start + env_count,
            cfg.rl.n_envs
        );
        let backend = backend_from_config(cfg, None)?;
        let allocs = Arc::new(AtomicU64::new(0));
        let wl_timeout = poll_timeout(cfg);
        if cfg.orchestrator.batch_ops {
            let mut envs = Vec::with_capacity(env_count);
            for i in env_start..env_start + env_count {
                let rv = cfg.variant_for(i);
                let env = backend
                    .make_env(&rv)
                    .with_context(|| format!("env {i} (variant {})", rv.name))?;
                envs.push((i, env));
            }
            let (tx, rx) = mpsc::channel::<BlockBegin>();
            let c = client.clone();
            let handle = std::thread::Builder::new()
                .name(format!("env-block-{env_start}"))
                .spawn(move || block_worker_loop(envs, c, rx, allocs, wl_timeout))?;
            return Ok(WorkerHost {
                txs: Vec::new(),
                block_tx: Some(tx),
                handles: vec![handle],
                env_start,
                env_count,
            });
        }
        let mut txs = Vec::with_capacity(env_count);
        let mut handles = Vec::with_capacity(env_count);
        for i in env_start..env_start + env_count {
            let rv = cfg.variant_for(i);
            let env = backend
                .make_env(&rv)
                .with_context(|| format!("env {i} (variant {})", rv.name))?;
            let (tx, rx) = mpsc::channel::<Begin>();
            let c = client.clone();
            let a = allocs.clone();
            let handle = std::thread::Builder::new()
                .name(format!("env-worker-{i}"))
                .spawn(move || worker_loop(env, c, i, rx, a, wl_timeout))?;
            txs.push(tx);
            handles.push(handle);
        }
        Ok(WorkerHost {
            txs,
            block_tx: None,
            handles,
            env_start,
            env_count,
        })
    }

    /// Envs hosted by this block.
    pub fn env_count(&self) -> usize {
        self.env_count
    }

    /// Kick one iteration from a decoded begin message: `envs` =
    /// `(global env index, rng seed)`, which must cover exactly this
    /// host's block.  `Rng::new(seed)` reconstructs the stream the
    /// threads mode would have split off locally.
    pub fn begin(&self, run_tag: &str, envs: &[(usize, u64)]) -> Result<()> {
        anyhow::ensure!(
            envs.len() == self.env_count,
            "begin message covers {} envs, host holds {}",
            envs.len(),
            self.env_count
        );
        let proto = Protocol::new(run_tag);
        for &(env, _) in envs {
            anyhow::ensure!(
                env >= self.env_start && env < self.env_start + self.env_count,
                "begin message env {env} outside block {}..{}",
                self.env_start,
                self.env_start + self.env_count
            );
        }
        if let Some(tx) = &self.block_tx {
            // Ascending env order keeps the lockstep schedule (and so
            // every per-env RNG draw) independent of message order.
            let mut seeds = envs.to_vec();
            seeds.sort_unstable_by_key(|&(e, _)| e);
            tx.send(BlockBegin { proto, seeds })
                .map_err(|_| anyhow!("block thread has exited"))?;
            return Ok(());
        }
        for &(env, seed) in envs {
            let slot = env - self.env_start;
            self.txs[slot]
                .send(Begin {
                    proto: proto.clone(),
                    rng: Rng::new(seed),
                })
                .map_err(|_| anyhow!("env thread {env} has exited"))?;
        }
        Ok(())
    }
}

impl Drop for WorkerHost {
    fn drop(&mut self) {
        self.txs.clear();
        self.block_tx = None;
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// One episode of the paper's env side (Fig. 2 right): reset from the
/// truth pool, then state-out / action-in / reward-out per RL step, with
/// the done-flag raised at termination.  The reward is shaped env-side
/// (each backend owns its reward), so the collector needs no backend
/// knowledge.  All keys are interned handles, observations go out
/// through recycled `Arc` buffers, and the received action is only
/// borrowed (refcount bump) — a steady-state step neither formats
/// strings nor allocates tensor storage.
#[allow(clippy::too_many_arguments)]
fn run_episode(
    env: &mut dyn CfdEnv,
    client: &Client,
    keys: &EnvKeys,
    idx: usize,
    rng: &mut Rng,
    obs_pool: &mut TensorPool,
    act_buf: &mut Vec<f64>,
    obs_shape: &Arc<[usize]>,
    poll_timeout: Duration,
) -> Result<()> {
    let obs_len = env.obs_len();
    env.reset_in_place(rng, false);
    let mut buf = obs_pool.take_free(obs_len);
    env.observe_into(Arc::get_mut(&mut buf).expect("pool hands out unique buffers"));
    client.put_tensor_shared(&keys.state[0], obs_shape.clone(), buf.clone());
    obs_pool.put_back(buf);
    for t in 0..env.n_actions() {
        let (hit, act) = client
            .poll_any(&[&keys.action[t], &keys.abort], poll_timeout)
            .with_context(|| format!("env {idx}: no action at step {t}"))?;
        anyhow::ensure!(hit == 0, "env {idx}: iteration aborted at step {t}");
        // Consume the action (seed semantics): only the shared abort flag
        // must stay readable by every worker, so the subscription above is
        // non-consuming and the action is deleted explicitly.
        client.delete(&keys.action[t]);
        let data = act.as_tensor().context("action must be a tensor")?.1;
        act_buf.clear();
        act_buf.extend(data.iter().map(|&a| a as f64));
        let out = env.step(act_buf);
        client.put_scalar(&keys.rew[t], out.reward);
        if out.done {
            client.put_flag(&keys.done, true);
            break;
        }
        let mut buf = obs_pool.take_free(obs_len);
        env.observe_into(Arc::get_mut(&mut buf).expect("pool hands out unique buffers"));
        client.put_tensor_shared(&keys.state[t + 1], obs_shape.clone(), buf.clone());
        obs_pool.put_back(buf);
    }
    Ok(())
}
