//! The persistent, event-driven environment runtime — the heart of the
//! Relexi dataflow (paper Fig. 2 / Algorithm 1), split into two halves:
//!
//! * **Worker pool** (the "FLEXI instances", Fig. 2 left): one OS thread
//!   and one environment per slot, built **once** in [`EnvPool::new`]
//!   and reused for every training iteration.  The pool is
//!   solver-agnostic: workers drive `dyn` [`CfdEnv`] instances cut from
//!   a [`CfdBackend`] (the paper's "easy integration of various HPC
//!   solvers" — `rl.backend` selects the 3D spectral LES or the 1D
//!   stochastic-Burgers testbed; see [`crate::rl::cfd`]).  Workers block
//!   on a per-iteration begin message carrying the iteration's key
//!   namespace ([`Protocol`]) and RNG stream, run one episode — write
//!   state, poll action, advance `dt_RL`, write the shaped reward, raise
//!   the done-flag at termination (§3.1) — and park again.  Steady-state
//!   iterations therefore spawn zero threads and rebuild zero env/shared
//!   backend instances (asserted by [`PoolCounters`]).
//!
//! * **Rollout collector** (the trainer side of Algorithm 1, lines 4-13):
//!   consumes env events **in arrival order** through one persistent
//!   store [`Subscription`] per sampling phase: done/fail channels
//!   register once per iteration, and each event applies only the
//!   single-key deltas it implies (retire the received state key, add
//!   the next one, add/retire a reward key around each action) — so a
//!   collection wave over `E` envs costs O(E) registry ops where the
//!   per-event `poll_any` rebuild it replaced cost O(E²)
//!   (counter-asserted via `StoreStats::sub_ops`).  The collector
//!   batches the policy over whichever states have arrived once
//!   `min_batch` are staged, and keeps per-env done/reward bookkeeping
//!   so an early-terminating env can never stall the batch — the
//!   synchronization overhead paper §6.2 measures.  With
//!   `min_batch = n_envs` (the default) the collector waits for the full
//!   wave and reproduces the paper's synchronous PPO bit-for-bit; the
//!   retained [`EnvPool::collect_lockstep_with`] reference implements the
//!   literal per-env polling loop for that equivalence test and for the
//!   §6.2 baseline bench.
//!
//! The exchange itself is zero-copy and, in steady state, zero-alloc:
//! both sides publish recycled `Arc<[f32]>` buffers
//! ([`crate::orchestrator::TensorPool`]) under interned key handles
//! (built once per iteration via [`Protocol::env_keys`] /
//! [`Protocol::pool_keys`]), the store hands consumers refcount bumps
//! instead of tensor copies, and per-key wakeups make every `put` wake
//! exactly the party waiting on that key.  `PoolCounters::exchange_allocs`
//! counts the pools' fresh allocations; after the warm-up iteration it
//! must not advance (integration-tested, gated in CI).
//!
//! Heterogeneous pools: each env runs a scenario variant
//! ([`crate::config::EnvVariant`], round-robin), so one pool can sample
//! across Reynolds-number, reward-shaping, horizon and initial-state
//! families while sharing one backend context and one policy.

use crate::config::RunConfig;
use crate::launcher::{plan_worker_processes, WorkerPlan};
use crate::orchestrator::protocol::{ctl_begin_key, ctl_hello_key, encode_begin, CTL_STOP_KEY};
use crate::orchestrator::{
    Client, EnvKeys, ExchangeServer, Key, Orchestrator, Protocol, TensorPool, Value,
};
use crate::rl::{backend_from_config, gaussian, CfdBackend, CfdEnv, Episode, StepRecord};
use crate::runtime::{Policy, PolicyOut};
use crate::solver::dns::Truth;
use crate::util::Rng;
use anyhow::{anyhow, bail, Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Timeout for any single poll; generous because env steps include real
/// CFD work.
const POLL_TIMEOUT: Duration = Duration::from_secs(600);

/// Result of one sampling phase.
pub struct Rollouts {
    pub episodes: Vec<Episode>,
    /// Wall-clock seconds spent sampling (the paper's §6.2 metric).
    pub sample_time_s: f64,
    /// Wall-clock seconds the trainer spent inside policy inference.
    pub policy_time_s: f64,
    /// Wall-clock seconds the trainer spent blocked on arrivals (the
    /// synchronization overhead the event-driven collector attacks).
    pub idle_time_s: f64,
}

/// Construction counters proving worker persistence and exchange-path
/// allocation discipline: after the warm-up, no call ever advances them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolCounters {
    /// OS threads spawned (== n_envs, only in construction).
    pub threads_spawned: usize,
    /// Environment instances constructed (== n_envs, only in
    /// construction).
    pub envs_built: usize,
    /// Shared backend contexts constructed (== 1, only in construction:
    /// the LES backend's spectral grid + truth, the Burgers backend's
    /// resolved-truth package).
    pub grids_built: usize,
    /// Sampling phases served by the persistent workers.
    pub iterations: usize,
    /// Exchange-path tensor-buffer allocations: pool misses across every
    /// worker's observation pool and the trainer's action pool.  Grows
    /// while the pools warm up (iteration 0), then must stay flat.
    pub exchange_allocs: u64,
}

/// Per-iteration begin message a parked worker blocks on.
struct Begin {
    proto: Protocol,
    rng: Rng,
}

/// How the pool's environments are hosted (`orchestrator.workers`).
enum Workers {
    /// Env threads inside the trainer process (the seed architecture;
    /// pairs with the in-process store — no wire anywhere).
    Threads,
    /// `relexi env-worker` OS processes dialing the exchange over a
    /// network transport.  The control plane (begin / hello / stop)
    /// rides the same store as the data plane.
    Processes {
        /// Spawned children, in worker-id order (= plan assignment
        /// order).
        children: Vec<std::process::Child>,
        /// The exchange serving the trainer's store to the workers;
        /// never read after construction, held so it outlives the
        /// children (the `Drop` reap runs before this field drops).
        _server: ExchangeServer,
        /// env -> process split (contiguous blocks in global env order).
        plan: WorkerPlan,
    },
}

/// How long worker processes get to dial back and say hello (includes
/// their own backend construction — e.g. the Burgers truth package).
const HELLO_TIMEOUT: Duration = Duration::from_secs(120);

/// Bounded teardown: workers that ignore the stop flag this long are
/// killed.
const REAP_TIMEOUT: Duration = Duration::from_secs(10);

/// Collects rollouts from `n_envs` persistent parallel environments.
pub struct EnvPool {
    cfg: RunConfig,
    /// The backend the pool's environments were cut from (shared context:
    /// grid/truth), kept for building matching evaluation envs.
    backend: Arc<dyn CfdBackend>,
    /// Begin-message channels, one per worker (dropping them shuts the
    /// pool down).
    txs: Vec<mpsc::Sender<Begin>>,
    handles: Vec<JoinHandle<()>>,
    /// Threads (the seed architecture) or spawned worker processes.
    workers: Workers,
    counters: PoolCounters,
    /// Client + last begun protocol, so `Drop` can raise the abort flag
    /// for workers still blocked inside an interrupted iteration.
    abort_client: Client,
    current_proto: Option<Protocol>,
    /// Per-env resolved bookkeeping (round-robin variants).
    variant_of: Vec<usize>,
    n_actions_of: Vec<usize>,
    /// Observation features per agent (`obs_len / n_agents`).
    feat: usize,
    /// Agents per env (actions per step; the LES backend: DG elements).
    n_agents: usize,
    /// Observation floats per env.
    obs_len: usize,
    /// Reused forward-batch scratch (n_envs * obs_len floats, allocated
    /// once here, never per iteration).
    batch_obs: Vec<f32>,
    /// Recycled action buffers (published zero-copy, recorded in the
    /// episode, freed when the rollouts are dropped).
    act_pool: TensorPool,
    /// Action tensor shape `[n_agents]`, shared across all publishes.
    act_shape: Arc<[usize]>,
    /// Shared exchange-allocation counter (this pool + every worker's
    /// observation pool).
    exchange_allocs: Arc<AtomicU64>,
}

impl EnvPool {
    /// Build the pool for a run configuration: resolve `cfg.rl.backend`
    /// against the registry (the LES backend consumes `truth`; others
    /// bring their own) and construct every env and worker thread
    /// exactly once.  All later iterations reuse them.
    pub fn new(cfg: RunConfig, truth: Arc<Truth>, orch: &Orchestrator) -> Result<EnvPool> {
        EnvPool::from_config(cfg, Some(truth), orch)
    }

    /// [`EnvPool::new`] with the DNS truth optional — backends other
    /// than `"les"` generate their own ground truth from the config.
    pub fn from_config(
        cfg: RunConfig,
        truth: Option<Arc<Truth>>,
        orch: &Orchestrator,
    ) -> Result<EnvPool> {
        cfg.validate()?;
        let backend = backend_from_config(&cfg, truth)?;
        EnvPool::with_backend_unchecked(cfg, backend, orch)
    }

    /// Build the pool over an explicit backend instance (the registry
    /// bypass for tests and external backends): construct every env (one
    /// scenario variant each) and every worker thread exactly once.
    pub fn with_backend(
        cfg: RunConfig,
        backend: Arc<dyn CfdBackend>,
        orch: &Orchestrator,
    ) -> Result<EnvPool> {
        cfg.validate()?;
        EnvPool::with_backend_unchecked(cfg, backend, orch)
    }

    /// [`EnvPool::with_backend`] for callers that already validated the
    /// configuration (both public constructors funnel here).
    fn with_backend_unchecked(
        cfg: RunConfig,
        backend: Arc<dyn CfdBackend>,
        orch: &Orchestrator,
    ) -> Result<EnvPool> {
        let n_envs = cfg.rl.n_envs;
        let mut counters = PoolCounters {
            threads_spawned: 0,
            envs_built: 0,
            grids_built: 1,
            iterations: 0,
            exchange_allocs: 0,
        };
        let exchange_allocs = Arc::new(AtomicU64::new(0));

        let mut txs = Vec::with_capacity(n_envs);
        let mut handles = Vec::with_capacity(n_envs);
        let mut variant_of = Vec::with_capacity(n_envs);
        let mut n_actions_of = Vec::with_capacity(n_envs);
        let (mut obs_len, mut n_agents) = (0usize, 0usize);
        let workers = if cfg.orchestrator.workers == "processes" {
            // Shape probe: the envs themselves live in the worker
            // processes, but the collector still needs the pool's
            // shapes and per-env horizons.  Variants never change the
            // obs/action shape (asserted below) and fully determine the
            // horizon, so one probe env per variant suffices.
            let n_var = cfg.n_variants();
            let mut probe_actions = Vec::with_capacity(n_var);
            for v in 0..n_var {
                let rv = cfg.variant_for(v);
                let env = backend
                    .make_env(&rv)
                    .with_context(|| format!("probe env (variant {})", rv.name))?;
                if v == 0 {
                    obs_len = env.obs_len();
                    n_agents = env.n_agents();
                }
                anyhow::ensure!(
                    env.obs_len() == obs_len && env.n_agents() == n_agents,
                    "variant {} shape mismatch: obs {}x{} vs pool {}x{}",
                    rv.name,
                    env.n_agents(),
                    env.obs_len(),
                    n_agents,
                    obs_len
                );
                counters.envs_built += 1;
                probe_actions.push(env.n_actions());
            }
            for i in 0..n_envs {
                variant_of.push(i % n_var);
                n_actions_of.push(probe_actions[i % n_var]);
            }

            let server = orch.serve(&cfg.orchestrator.bind)?;
            let plan = plan_worker_processes(&cfg, n_envs)?;
            let mut children =
                spawn_worker_processes(&cfg, &server.addr().to_string(), &plan)?;
            if let Err(e) = wait_workers_hello(orch, &mut children) {
                for c in &mut children {
                    let _ = c.kill();
                    let _ = c.wait();
                }
                return Err(e);
            }
            Workers::Processes {
                children,
                _server: server,
                plan,
            }
        } else {
            for i in 0..n_envs {
                let rv = cfg.variant_for(i);
                let env = backend
                    .make_env(&rv)
                    .with_context(|| format!("env {i} (variant {})", rv.name))?;
                if i == 0 {
                    obs_len = env.obs_len();
                    n_agents = env.n_agents();
                }
                // Variants never change the observation/action shape: one
                // policy batch serves the whole pool.
                anyhow::ensure!(
                    env.obs_len() == obs_len && env.n_agents() == n_agents,
                    "env {i} (variant {}) shape mismatch: obs {}x{} vs pool {}x{}",
                    rv.name,
                    env.n_agents(),
                    env.obs_len(),
                    n_agents,
                    obs_len
                );
                counters.envs_built += 1;
                variant_of.push(rv.index);
                n_actions_of.push(env.n_actions());

                let (tx, rx) = mpsc::channel::<Begin>();
                let client = orch.client();
                let allocs = exchange_allocs.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("env-worker-{i}"))
                    .spawn(move || worker_loop(env, client, i, rx, allocs))?;
                counters.threads_spawned += 1;
                txs.push(tx);
                handles.push(handle);
            }
            Workers::Threads
        };
        anyhow::ensure!(
            n_agents >= 1 && obs_len % n_agents == 0,
            "backend {}: obs_len {obs_len} must split evenly over {n_agents} agents",
            backend.name()
        );

        // One iteration publishes one action per env per step, all held
        // by the episode records until the rollouts drop — that sum is
        // the action pool's steady-state working set (and its cap).
        let act_cap = n_actions_of.iter().sum::<usize>() + 2;
        Ok(EnvPool {
            batch_obs: vec![0f32; n_envs * obs_len],
            act_pool: TensorPool::new(exchange_allocs.clone(), act_cap),
            act_shape: Arc::from(vec![n_agents]),
            exchange_allocs,
            cfg,
            backend,
            txs,
            handles,
            workers,
            counters,
            abort_client: orch.client(),
            current_proto: None,
            variant_of,
            n_actions_of,
            feat: obs_len / n_agents,
            n_agents,
            obs_len,
        })
    }

    /// Agents per env (actions per step per env).
    pub fn n_agents(&self) -> usize {
        self.n_agents
    }

    /// Observation features per agent (`obs_len / n_agents`) — what a
    /// policy consuming this pool must be shaped for.
    pub fn features(&self) -> usize {
        self.feat
    }

    /// The backend this pool's environments were cut from.
    pub fn backend(&self) -> Arc<dyn CfdBackend> {
        self.backend.clone()
    }

    /// A fresh evaluation environment on the pool's shared backend
    /// context (base scenario, no variant overrides) — the training loop
    /// builds one once and reuses it.
    pub fn make_eval_env(&self) -> Result<Box<dyn CfdEnv>> {
        self.backend.make_env(&self.cfg.base_resolved())
    }

    /// Construction counters (steady-state assertion: only `iterations`
    /// may change across `collect` calls, and `exchange_allocs` only
    /// during the warm-up iteration).
    pub fn counters(&self) -> PoolCounters {
        PoolCounters {
            exchange_allocs: self.exchange_allocs.load(Ordering::Relaxed),
            ..self.counters
        }
    }

    /// Run one sampling phase under the current policy (`theta`),
    /// event-driven with the configured `rl.min_batch` (0 = full batch =
    /// synchronous PPO).  The policy is any [`Policy`] runtime backend
    /// (compiled XLA or native).  `run_tag` via `proto` namespaces the
    /// keys; `rng` drives initial-state draws and action sampling.
    pub fn collect(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        policy: &dyn Policy,
        theta: &[f32],
        rng: &mut Rng,
        deterministic: bool,
    ) -> Result<Rollouts> {
        anyhow::ensure!(
            policy.features() == self.feat,
            "policy features {} != pool features {}",
            policy.features(),
            self.feat
        );
        let min_batch = self.cfg.min_batch_effective();
        self.collect_with(
            orch,
            proto,
            |obs, n| policy.forward(theta, obs, n),
            rng,
            deterministic,
            min_batch,
        )
    }

    /// Event-driven sampling phase with an explicit policy closure
    /// (`forward(obs, n_samples)`) — the policy-agnostic core, also used
    /// by tests and benches that run without compiled artifacts.
    pub fn collect_with<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        forward: F,
        rng: &mut Rng,
        deterministic: bool,
        min_batch: usize,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let res = self.collect_event_inner(orch, proto, forward, rng, deterministic, min_batch);
        self.finish_iteration(proto, res.is_err());
        res
    }

    fn collect_event_inner<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        mut forward: F,
        rng: &mut Rng,
        deterministic: bool,
        min_batch: usize,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let t_start = Instant::now();
        let n_envs = self.cfg.rl.n_envs;
        let chunk = self.obs_len;
        let trainer = orch.client();
        self.begin_iteration(proto, rng)?;
        let keys = proto.pool_keys(&self.n_actions_of);

        let mut episodes = self.fresh_episodes();
        // Per-env: step index of the state we are waiting for (None once
        // the done-flag arrived or the state is parked in `staged`).
        let mut expect_state: Vec<Option<usize>> = vec![Some(0); n_envs];
        let mut staged: Vec<(usize, usize, Arc<[f32]>)> = Vec::with_capacity(n_envs);
        let mut pending_rewards = 0usize;
        let mut policy_time = 0.0f64;
        let mut idle_time = 0.0f64;

        // One persistent subscription for the whole sampling phase.
        // Fixed tags per env for its state/done/fail channels; reward
        // tags come from a free list (an env can have several rewards
        // outstanding).  `tag_events[tag]` is what the tag currently
        // means; every event applies only its own add/remove deltas, so
        // a wave over E envs costs O(E) registry ops (the `sub_ops`
        // counter the integration test asserts on).
        let mut sub = trainer.subscription();
        let mut tag_events: Vec<Event> = Vec::with_capacity(4 * n_envs);
        for env in 0..n_envs {
            tag_events.push(Event::State(env, 0));
            tag_events.push(Event::Done(env));
            tag_events.push(Event::Fail(env));
        }
        for env in 0..n_envs {
            let ek = &keys.envs[env];
            sub.add(3 * env, &ek.state[0]);
            sub.add(3 * env + 1, &ek.done);
            sub.add(3 * env + 2, &ek.fail);
        }
        let mut free_reward_tags: Vec<usize> = Vec::new();

        loop {
            let expecting = expect_state.iter().filter(|e| e.is_some()).count();
            if expecting == 0 && staged.is_empty() && pending_rewards == 0 {
                break;
            }

            // Flush the policy batch once enough states arrived — or once
            // no further state can arrive without us acting first.
            if !staged.is_empty() && (staged.len() >= min_batch || expecting == 0) {
                staged.sort_unstable_by_key(|&(env, _, _)| env);
                let n_act = staged.len();
                for (k, (_, _, obs)) in staged.iter().enumerate() {
                    self.batch_obs[k * chunk..(k + 1) * chunk].copy_from_slice(obs);
                }
                let tp = Instant::now();
                let out = forward(&self.batch_obs[..n_act * chunk], n_act * self.n_agents)?;
                policy_time += tp.elapsed().as_secs_f64();
                anyhow::ensure!(
                    out.mean.len() == n_act * self.n_agents
                        && out.value.len() == n_act * self.n_agents,
                    "policy returned {} means for {} samples",
                    out.mean.len(),
                    n_act * self.n_agents
                );

                // Sample + write actions in env order (ties the RNG stream
                // to env indices, not arrival order: full-batch collection
                // is bitwise-identical to the lock-step reference).
                for (k, (env, t, obs)) in staged.drain(..).enumerate() {
                    let ek = &keys.envs[env];
                    let mean = &out.mean[k * self.n_agents..(k + 1) * self.n_agents];
                    let value = &out.value[k * self.n_agents..(k + 1) * self.n_agents];
                    publish_action(
                        &trainer,
                        &ek.action[t],
                        &self.act_shape,
                        &mut self.act_pool,
                        &mut episodes[env],
                        obs,
                        mean,
                        value,
                        out.log_std,
                        rng,
                        deterministic,
                    );
                    // Subscribe the action's reward and the next state.
                    let rtag = free_reward_tags.pop().unwrap_or_else(|| {
                        tag_events.push(Event::Reward(0, 0));
                        tag_events.len() - 1
                    });
                    tag_events[rtag] = Event::Reward(env, t);
                    sub.add(rtag, &ek.rew[t]);
                    pending_rewards += 1;
                    expect_state[env] = Some(t + 1);
                    tag_events[3 * env] = Event::State(env, t + 1);
                    sub.add(3 * env, &ek.state[t + 1]);
                }
                continue;
            }

            // Wait for whichever registered event arrives first.
            let ti = Instant::now();
            let (tag, val) = sub.wait_take(POLL_TIMEOUT).with_context(|| {
                format!(
                    "collector timed out: {} states expected, {} rewards pending",
                    expect_state.iter().filter(|e| e.is_some()).count(),
                    pending_rewards
                )
            })?;
            idle_time += ti.elapsed().as_secs_f64();
            match tag_events[tag] {
                Event::State(env, t) => {
                    let data = val
                        .tensor_data()
                        .with_context(|| format!("env {env} state at step {t} is not a tensor"))?;
                    anyhow::ensure!(
                        data.len() == chunk,
                        "env {env} state has {} floats, expected {chunk}",
                        data.len()
                    );
                    staged.push((env, t, data));
                    expect_state[env] = None; // parked in `staged` until acted on
                    sub.remove(3 * env);
                }
                Event::Done(env) => {
                    expect_state[env] = None;
                    // Neither the post-terminal state nor another done
                    // can arrive: retire both channels (fail stays).
                    sub.remove(3 * env);
                    sub.remove(3 * env + 1);
                }
                Event::Reward(env, t) => {
                    let r = val
                        .as_scalar()
                        .with_context(|| format!("env {env} reward at step {t} not a scalar"))?;
                    episodes[env].steps[t].reward = r;
                    pending_rewards -= 1;
                    sub.remove(tag);
                    free_reward_tags.push(tag);
                }
                Event::Fail(env) => {
                    bail!("env worker {env} failed: {}", fail_message(&val));
                }
            }
        }

        self.counters.iterations += 1;
        Ok(Rollouts {
            episodes,
            sample_time_s: t_start.elapsed().as_secs_f64(),
            policy_time_s: policy_time,
            idle_time_s: idle_time,
        })
    }

    /// Lock-step reference collector: the paper's literal synchronous
    /// gather — one wave per RL step, states polled env-by-env — kept as
    /// the bitwise-equivalence oracle for the event-driven path and as
    /// the §6.2 baseline for the training bench.  Unlike the seed
    /// implementation it checks the done-flag at every step, so an env
    /// that terminates early can no longer wedge the gather loop until
    /// the poll timeout.
    pub fn collect_lockstep_with<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        forward: F,
        rng: &mut Rng,
        deterministic: bool,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let res = self.collect_lockstep_inner(orch, proto, forward, rng, deterministic);
        self.finish_iteration(proto, res.is_err());
        res
    }

    fn collect_lockstep_inner<F>(
        &mut self,
        orch: &Orchestrator,
        proto: &Protocol,
        mut forward: F,
        rng: &mut Rng,
        deterministic: bool,
    ) -> Result<Rollouts>
    where
        F: FnMut(&[f32], usize) -> Result<PolicyOut>,
    {
        let t_start = Instant::now();
        let n_envs = self.cfg.rl.n_envs;
        let chunk = self.obs_len;
        let trainer = orch.client();
        self.begin_iteration(proto, rng)?;
        let keys = proto.pool_keys(&self.n_actions_of);

        let mut episodes = self.fresh_episodes();
        let mut done = vec![false; n_envs];
        let mut acted: Vec<usize> = Vec::with_capacity(n_envs);
        let mut wave_obs: Vec<Arc<[f32]>> = Vec::with_capacity(n_envs);
        let mut policy_time = 0.0f64;
        let mut idle_time = 0.0f64;
        let max_t = self.n_actions_of.iter().copied().max().unwrap_or(0);

        for t in 0..max_t {
            // Gather the wave's states in env order, checking the
            // done-flag per env so early terminations are absorbed.
            acted.clear();
            wave_obs.clear();
            for env in 0..n_envs {
                if done[env] {
                    continue;
                }
                let ek = &keys.envs[env];
                let ti = Instant::now();
                let (hit, val) = trainer
                    .poll_any_take(&[&ek.state[t], &ek.done, &ek.fail], POLL_TIMEOUT)
                    .with_context(|| format!("trainer: no state from env {env} step {t}"))?;
                idle_time += ti.elapsed().as_secs_f64();
                match hit {
                    0 => {
                        let data = val.tensor_data().context("state must be a tensor")?;
                        anyhow::ensure!(
                            data.len() == chunk,
                            "env {env} state has {} floats, expected {chunk}",
                            data.len()
                        );
                        self.batch_obs[acted.len() * chunk..(acted.len() + 1) * chunk]
                            .copy_from_slice(&data);
                        acted.push(env);
                        wave_obs.push(data);
                    }
                    1 => done[env] = true,
                    _ => bail!("env worker {env} failed: {}", fail_message(&val)),
                }
            }
            if acted.is_empty() {
                break; // every env terminated before the longest horizon
            }

            // One batched policy evaluation for the wave.
            let n_act = acted.len();
            let tp = Instant::now();
            let out = forward(&self.batch_obs[..n_act * chunk], n_act * self.n_agents)?;
            policy_time += tp.elapsed().as_secs_f64();

            // Sample actions, write them back, record the steps (the one
            // shared publish site with the event-driven collector).
            for (k, &env) in acted.iter().enumerate() {
                let mean = &out.mean[k * self.n_agents..(k + 1) * self.n_agents];
                let value = &out.value[k * self.n_agents..(k + 1) * self.n_agents];
                publish_action(
                    &trainer,
                    &keys.envs[env].action[t],
                    &self.act_shape,
                    &mut self.act_pool,
                    &mut episodes[env],
                    wave_obs[k].clone(),
                    mean,
                    value,
                    out.log_std,
                    rng,
                    deterministic,
                );
            }

            // Collect the shaped rewards (computed env-side, Eqs. 4-5
            // for the in-tree backends).
            for &env in &acted {
                let ek = &keys.envs[env];
                let ti = Instant::now();
                let (hit, val) = trainer
                    .poll_any_take(&[&ek.rew[t], &ek.fail], POLL_TIMEOUT)
                    .with_context(|| format!("trainer: no reward from env {env} step {t}"))?;
                idle_time += ti.elapsed().as_secs_f64();
                if hit != 0 {
                    bail!("env worker {env} failed: {}", fail_message(&val));
                }
                let r = val.as_scalar().context("reward must be a scalar")?;
                episodes[env].steps[t].reward = r;
            }
        }

        // Every env must have signalled termination.
        for env in 0..n_envs {
            if done[env] {
                continue;
            }
            let ek = &keys.envs[env];
            let (hit, val) = trainer
                .poll_any_take(&[&ek.done, &ek.fail], POLL_TIMEOUT)
                .with_context(|| format!("env {env} never signalled done"))?;
            if hit != 0 {
                bail!("env worker {env} failed: {}", fail_message(&val));
            }
        }

        self.counters.iterations += 1;
        Ok(Rollouts {
            episodes,
            sample_time_s: t_start.elapsed().as_secs_f64(),
            policy_time_s: policy_time,
            idle_time_s: idle_time,
        })
    }

    /// Raise the iteration's abort flag so workers still blocked on an
    /// action key of a failed iteration unpark immediately (instead of
    /// running out POLL_TIMEOUT) and return to the begin-channel.  The
    /// flag is deliberately never deleted: a worker that was mid-CFD-step
    /// when the abort was raised subscribes to `[action, abort]` later
    /// and must still find it.  The pool stays usable afterwards, but a
    /// retry must use a **fresh run tag** — the failed tag's namespace
    /// (abort flag, stale state/reward keys) is burned.
    fn abort_iteration(&self, proto: &Protocol) {
        self.abort_client.put_flag(&proto.abort_key(), true);
    }

    /// Close out one sampling phase: on failure raise the abort flag; on
    /// success forget the protocol so a later `Drop` does not write a
    /// stray abort key for a cleanly completed iteration.
    fn finish_iteration(&mut self, proto: &Protocol, failed: bool) {
        if failed {
            self.abort_iteration(proto);
        } else {
            self.current_proto = None;
        }
    }

    /// Wake every parked worker for one iteration (per-env RNG streams
    /// split in env order, exactly as the seed's spawn loop did).  The
    /// processes arm draws the identical `split_seed` sequence in the
    /// identical global env order and ships the seeds inside the begin
    /// messages, so the env->process split is invisible to every RNG
    /// stream in the run.
    fn begin_iteration(&mut self, proto: &Protocol, rng: &mut Rng) -> Result<()> {
        self.current_proto = Some(proto.clone());
        match &mut self.workers {
            Workers::Threads => {
                for (i, tx) in self.txs.iter().enumerate() {
                    tx.send(Begin {
                        proto: proto.clone(),
                        rng: rng.split(i as u64),
                    })
                    .map_err(|_| anyhow!("env worker {i} has exited (earlier panic?)"))?;
                }
            }
            Workers::Processes { children, plan, .. } => {
                let seeds: Vec<u64> = (0..self.cfg.rl.n_envs)
                    .map(|i| rng.split_seed(i as u64))
                    .collect();
                for (w, &(start, count)) in plan.assignments.iter().enumerate() {
                    if let Ok(Some(status)) = children[w].try_wait() {
                        bail!("env-worker process {w} died ({status})");
                    }
                    let envs: Vec<(usize, u64)> =
                        (start..start + count).map(|i| (i, seeds[i])).collect();
                    self.abort_client
                        .put_bytes(&ctl_begin_key(w), encode_begin(proto.run_tag(), &envs));
                }
            }
        }
        Ok(())
    }

    /// Empty per-env episodes tagged with their scenario variants.
    fn fresh_episodes(&self) -> Vec<Episode> {
        self.variant_of
            .iter()
            .map(|&variant| Episode {
                variant,
                ..Episode::default()
            })
            .collect()
    }
}

impl Drop for EnvPool {
    fn drop(&mut self) {
        // Unblock workers stuck mid-iteration (e.g. after an external
        // kill): they subscribe to the abort flag next to their action
        // key, so this wakes them without waiting out the poll timeout.
        if let Some(proto) = self.current_proto.take() {
            self.abort_iteration(&proto);
        }
        if let Workers::Processes { children, .. } = &mut self.workers {
            // Stop flag first (read non-consuming, so one flag serves
            // every worker), then a bounded reap; a worker that ignores
            // it is killed.  The exchange server (`_server`) drops only
            // after this body, i.e. it keeps serving until the children
            // are gone.
            self.abort_client.put_flag(CTL_STOP_KEY, true);
            let deadline = Instant::now() + REAP_TIMEOUT;
            for child in children.iter_mut() {
                loop {
                    match child.try_wait() {
                        Ok(Some(_)) | Err(_) => break,
                        Ok(None) if Instant::now() >= deadline => {
                            let _ = child.kill();
                            let _ = child.wait();
                            break;
                        }
                        Ok(None) => std::thread::sleep(Duration::from_millis(25)),
                    }
                }
            }
        }
        // Dropping the begin-channels unparks every idle worker with a
        // recv error, which is the shutdown signal.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One collector event: a subscription tag resolved to its meaning.
#[derive(Clone, Copy)]
enum Event {
    /// State tensor from env at step.
    State(usize, usize),
    /// Done-flag: no further states from this env.
    Done(usize),
    /// Shaped-reward scalar for (env, step).
    Reward(usize, usize),
    /// Worker failure report.
    Fail(usize),
}

/// Sample (or, when deterministic, copy) one env's action from the policy
/// head, publish it zero-copy under the env's action key and record the
/// step — the single action-publish site shared by the event-driven and
/// lock-step collectors.  The action buffer comes from the recycled pool;
/// the store, the episode record and the pool share one allocation.
#[allow(clippy::too_many_arguments)]
fn publish_action(
    trainer: &Client,
    action_key: &Key,
    act_shape: &Arc<[usize]>,
    act_pool: &mut TensorPool,
    episode: &mut Episode,
    obs: Arc<[f32]>,
    mean: &[f32],
    value: &[f32],
    log_std: f32,
    rng: &mut Rng,
    deterministic: bool,
) {
    let mut act = act_pool.take_free(mean.len());
    {
        let dst = Arc::get_mut(&mut act).expect("pool hands out unique buffers");
        if deterministic {
            dst.copy_from_slice(mean);
        } else {
            gaussian::sample_into(mean, log_std, rng, dst);
        }
    }
    let logp = gaussian::log_prob(&act, mean, log_std);
    trainer.put_tensor_shared(action_key, act_shape.clone(), act.clone());
    episode.steps.push(StepRecord {
        obs,
        act: act.clone(),
        logp,
        value: value.to_vec(),
        reward: 0.0, // filled by the reward event
    });
    act_pool.put_back(act);
}

/// Render a failure-report value (bytes put by the worker) for an error.
fn fail_message(val: &Value) -> String {
    match val {
        Value::Bytes(b) => String::from_utf8_lossy(b).into_owned(),
        other => format!("{other:?}"),
    }
}

/// The persistent worker body: park on the begin-channel, run one episode
/// through the store, park again.  Exits when the pool drops the channel.
/// The observation buffer pool and the action-conversion scratch persist
/// across iterations, so a steady-state episode allocates nothing on the
/// exchange path.
///
/// Both `Err` returns and panics inside the episode (caught so the thread
/// survives; the next begin resets the env completely) are surfaced
/// through the fail key, so the collector aborts the iteration instead of
/// running into its poll timeout.
fn worker_loop(
    mut env: Box<dyn CfdEnv>,
    client: Client,
    idx: usize,
    rx: mpsc::Receiver<Begin>,
    allocs: Arc<AtomicU64>,
) {
    // Working set: one obs buffer per step (held by the trainer until
    // the iteration's rollouts drop) plus the initial state.
    let mut obs_pool = TensorPool::new(allocs, env.n_actions() + 2);
    let mut act_buf: Vec<f64> = Vec::with_capacity(env.n_agents());
    let obs_shape: Arc<[usize]> = Arc::from(vec![env.obs_len()]);
    while let Ok(Begin { proto, mut rng }) = rx.recv() {
        let keys = proto.env_keys(idx, env.n_actions());
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_episode(
                env.as_mut(),
                &client,
                &keys,
                idx,
                &mut rng,
                &mut obs_pool,
                &mut act_buf,
                &obs_shape,
            )
        }));
        let failure = match outcome {
            Ok(Ok(())) => None,
            Ok(Err(e)) => Some(format!("{e:#}")),
            Err(payload) => Some(format!("panic: {}", panic_message(&payload))),
        };
        if let Some(msg) = failure {
            client.put_bytes(&keys.fail, msg.into_bytes());
        }
    }
}

/// Resolve the binary to spawn as `relexi env-worker`: the
/// `RELEXI_WORKER_BIN` env var (integration tests point it at the
/// Cargo-built binary) > `orchestrator.worker_bin` > the currently
/// running executable.
fn worker_binary(cfg: &RunConfig) -> Result<std::path::PathBuf> {
    if let Ok(p) = std::env::var("RELEXI_WORKER_BIN") {
        if !p.is_empty() {
            return Ok(p.into());
        }
    }
    if !cfg.orchestrator.worker_bin.is_empty() {
        return Ok(cfg.orchestrator.worker_bin.clone().into());
    }
    std::env::current_exe().context("resolving the running executable as worker binary")
}

/// Spawn one `relexi env-worker` child per plan assignment.  The full
/// effective config travels in the `RELEXI_WORKER_CONFIG` env var (no
/// staging to a shared filesystem needed); the exchange address and the
/// worker's env block go on the command line.
fn spawn_worker_processes(
    cfg: &RunConfig,
    addr: &str,
    plan: &WorkerPlan,
) -> Result<Vec<std::process::Child>> {
    let bin = worker_binary(cfg)?;
    let config_text = cfg.to_toml_string();
    let mut children = Vec::with_capacity(plan.n_procs);
    for (w, &(start, count)) in plan.assignments.iter().enumerate() {
        let child = std::process::Command::new(&bin)
            .arg("env-worker")
            .arg("--connect")
            .arg(addr)
            .arg("--transport")
            .arg(&cfg.orchestrator.transport)
            .arg("--worker-id")
            .arg(w.to_string())
            .arg("--env-start")
            .arg(start.to_string())
            .arg("--env-count")
            .arg(count.to_string())
            .env("RELEXI_WORKER_CONFIG", &config_text)
            .spawn()
            .with_context(|| format!("spawning env-worker {w} ({})", bin.display()))?;
        children.push(child);
    }
    Ok(children)
}

/// Block until every spawned worker has put its hello flag (its env
/// threads are up and its transport works), detecting workers that died
/// during startup instead of waiting out the timeout.
fn wait_workers_hello(orch: &Orchestrator, children: &mut [std::process::Child]) -> Result<()> {
    let client = orch.client();
    let deadline = Instant::now() + HELLO_TIMEOUT;
    for w in 0..children.len() {
        let key = ctl_hello_key(w);
        loop {
            if client.poll(&key, Duration::from_millis(200)).is_some() {
                break;
            }
            if let Ok(Some(status)) = children[w].try_wait() {
                bail!("env-worker {w} exited during startup ({status})");
            }
            anyhow::ensure!(
                Instant::now() < deadline,
                "env-worker {w} did not say hello within {HELLO_TIMEOUT:?}"
            );
        }
    }
    Ok(())
}

/// The env-worker process' half of the pool: hosts one contiguous block
/// of the global env range as persistent worker threads — the exact
/// [`worker_loop`] the threads mode runs, fed from decoded begin
/// messages instead of an in-process channel fan-out.  Constructed by
/// `relexi env-worker` after dialing the exchange; its `Drop` joins the
/// threads (teardown is driven by the caller's control loop reacting to
/// the stop flag or a dead transport).
pub struct WorkerHost {
    txs: Vec<mpsc::Sender<Begin>>,
    handles: Vec<JoinHandle<()>>,
    env_start: usize,
}

impl WorkerHost {
    /// Build the block's envs (scenario variants resolved by *global*
    /// env index, so the split changes nothing) and spawn their worker
    /// threads on `client` — normally a remote client dialing the
    /// trainer's exchange.
    pub fn spawn(
        cfg: &RunConfig,
        client: &Client,
        env_start: usize,
        env_count: usize,
    ) -> Result<WorkerHost> {
        cfg.validate()?;
        anyhow::ensure!(
            env_count >= 1 && env_start + env_count <= cfg.rl.n_envs,
            "env block {env_start}..{} outside the pool of {}",
            env_start + env_count,
            cfg.rl.n_envs
        );
        let backend = backend_from_config(cfg, None)?;
        let allocs = Arc::new(AtomicU64::new(0));
        let mut txs = Vec::with_capacity(env_count);
        let mut handles = Vec::with_capacity(env_count);
        for i in env_start..env_start + env_count {
            let rv = cfg.variant_for(i);
            let env = backend
                .make_env(&rv)
                .with_context(|| format!("env {i} (variant {})", rv.name))?;
            let (tx, rx) = mpsc::channel::<Begin>();
            let c = client.clone();
            let a = allocs.clone();
            let handle = std::thread::Builder::new()
                .name(format!("env-worker-{i}"))
                .spawn(move || worker_loop(env, c, i, rx, a))?;
            txs.push(tx);
            handles.push(handle);
        }
        Ok(WorkerHost {
            txs,
            handles,
            env_start,
        })
    }

    /// Envs hosted by this block.
    pub fn env_count(&self) -> usize {
        self.txs.len()
    }

    /// Kick one iteration from a decoded begin message: `envs` =
    /// `(global env index, rng seed)`, which must cover exactly this
    /// host's block.  `Rng::new(seed)` reconstructs the stream the
    /// threads mode would have split off locally.
    pub fn begin(&self, run_tag: &str, envs: &[(usize, u64)]) -> Result<()> {
        anyhow::ensure!(
            envs.len() == self.txs.len(),
            "begin message covers {} envs, host holds {}",
            envs.len(),
            self.txs.len()
        );
        let proto = Protocol::new(run_tag);
        for &(env, seed) in envs {
            let slot = env
                .checked_sub(self.env_start)
                .filter(|&s| s < self.txs.len())
                .ok_or_else(|| {
                    anyhow!(
                        "begin message env {env} outside block {}..{}",
                        self.env_start,
                        self.env_start + self.txs.len()
                    )
                })?;
            self.txs[slot]
                .send(Begin {
                    proto: proto.clone(),
                    rng: Rng::new(seed),
                })
                .map_err(|_| anyhow!("env thread {env} has exited"))?;
        }
        Ok(())
    }
}

impl Drop for WorkerHost {
    fn drop(&mut self) {
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// One episode of the paper's env side (Fig. 2 right): reset from the
/// truth pool, then state-out / action-in / reward-out per RL step, with
/// the done-flag raised at termination.  The reward is shaped env-side
/// (each backend owns its reward), so the collector needs no backend
/// knowledge.  All keys are interned handles, observations go out
/// through recycled `Arc` buffers, and the received action is only
/// borrowed (refcount bump) — a steady-state step neither formats
/// strings nor allocates tensor storage.
#[allow(clippy::too_many_arguments)]
fn run_episode(
    env: &mut dyn CfdEnv,
    client: &Client,
    keys: &EnvKeys,
    idx: usize,
    rng: &mut Rng,
    obs_pool: &mut TensorPool,
    act_buf: &mut Vec<f64>,
    obs_shape: &Arc<[usize]>,
) -> Result<()> {
    let obs_len = env.obs_len();
    env.reset_in_place(rng, false);
    let mut buf = obs_pool.take_free(obs_len);
    env.observe_into(Arc::get_mut(&mut buf).expect("pool hands out unique buffers"));
    client.put_tensor_shared(&keys.state[0], obs_shape.clone(), buf.clone());
    obs_pool.put_back(buf);
    for t in 0..env.n_actions() {
        let (hit, act) = client
            .poll_any(&[&keys.action[t], &keys.abort], POLL_TIMEOUT)
            .with_context(|| format!("env {idx}: no action at step {t}"))?;
        anyhow::ensure!(hit == 0, "env {idx}: iteration aborted at step {t}");
        // Consume the action (seed semantics): only the shared abort flag
        // must stay readable by every worker, so the subscription above is
        // non-consuming and the action is deleted explicitly.
        client.delete(&keys.action[t]);
        let data = act.as_tensor().context("action must be a tensor")?.1;
        act_buf.clear();
        act_buf.extend(data.iter().map(|&a| a as f64));
        let out = env.step(act_buf);
        client.put_scalar(&keys.rew[t], out.reward);
        if out.done {
            client.put_flag(&keys.done, true);
            break;
        }
        let mut buf = obs_pool.take_free(obs_len);
        env.observe_into(Arc::get_mut(&mut buf).expect("pool hands out unique buffers"));
        client.put_tensor_shared(&keys.state[t + 1], obs_shape.clone(), buf.clone());
        obs_pool.put_back(buf);
    }
    Ok(())
}
