//! Parallel environment execution through the orchestrator — the heart of
//! the Relexi dataflow (paper Fig. 2 / Algorithm 1):
//!
//! 1. a batch of environment workers ("FLEXI instances") is started;
//! 2. each writes its state tensor to the orchestrator and polls for its
//!    action; the trainer polls states, evaluates the policy once for the
//!    whole batch, samples actions and writes them back;
//! 3. every env advances `dt_RL` and the loop repeats until `t_end`
//!    (synchronous PPO: the iteration waits for all envs).
//!
//! Workers are real OS threads running the real LES solver; all traffic
//! goes through the in-memory store exactly as in the paper (states and
//! spectrum errors in, actions out, done-flags at termination).

use crate::config::RunConfig;
use crate::orchestrator::{Orchestrator, Protocol};
use crate::rl::{gaussian, reward_from_error, Episode, LesEnv, StepRecord};
use crate::runtime::PolicyRuntime;
use crate::solver::dns::Truth;
use crate::solver::Grid;
use crate::util::Rng;
use anyhow::{Context, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Timeout for any single poll; generous because env steps include real
/// CFD work.
const POLL_TIMEOUT: Duration = Duration::from_secs(600);

/// Result of one sampling phase.
pub struct Rollouts {
    pub episodes: Vec<Episode>,
    /// Wall-clock seconds spent sampling (the paper's §6.2 metric).
    pub sample_time_s: f64,
    /// Wall-clock seconds the trainer spent inside policy inference.
    pub policy_time_s: f64,
}

/// Collects rollouts from `n_envs` parallel environments.
pub struct EnvPool {
    cfg: RunConfig,
    truth: Arc<Truth>,
}

impl EnvPool {
    /// Build a pool for a run configuration and its ground truth.
    pub fn new(cfg: RunConfig, truth: Arc<Truth>) -> EnvPool {
        EnvPool { cfg, truth }
    }

    /// Elements per env (actions per step per env).
    pub fn n_elems(&self) -> usize {
        self.cfg.case.total_elems()
    }

    /// Run one synchronous sampling phase: `n_envs` episodes under the
    /// current policy (`theta`), exchanging all data via `orch`.
    ///
    /// `run_tag` namespaces the keys (one per iteration); `rng` drives
    /// initial-state draws and action sampling.
    pub fn collect(
        &self,
        orch: &Orchestrator,
        proto: &Protocol,
        policy: &PolicyRuntime,
        theta: &[f32],
        rng: &mut Rng,
        deterministic: bool,
    ) -> Result<Rollouts> {
        let t_start = Instant::now();
        let n_envs = self.cfg.rl.n_envs;
        let n_actions = self.cfg.steps_per_episode();
        let n_elems = self.n_elems();
        let feat = policy.features();

        // --- start the environment workers (the "FLEXI instances") -----
        // One shared spectral grid for the whole pool: `fft::Plan` is
        // `Send + Sync`, so every worker reuses the same twiddle tables
        // instead of rebuilding them per environment.
        let grid = Arc::new(Grid::new(self.cfg.case.points_per_dir()));
        let mut workers = Vec::with_capacity(n_envs);
        for i in 0..n_envs {
            let client = orch.client();
            let proto = proto.clone();
            let case = self.cfg.case.clone();
            let scfg = self.cfg.solver.clone();
            let truth = self.truth.clone();
            let grid = grid.clone();
            let mut env_rng = rng.split(i as u64);
            workers.push(std::thread::spawn(move || -> Result<()> {
                let mut env = LesEnv::with_grid(&case, &scfg, truth, grid)?;
                let obs = env.reset(&mut env_rng, false);
                client.put_tensor(&proto.state_key(i, 0), vec![obs.len()], obs);
                for t in 0..n_actions {
                    let act = client
                        .poll_take(&proto.action_key(i, t), POLL_TIMEOUT)
                        .with_context(|| format!("env {i}: no action at step {t}"))?;
                    let cs: Vec<f64> = act
                        .as_tensor()
                        .context("action must be a tensor")?
                        .1
                        .iter()
                        .map(|&a| a as f64)
                        .collect();
                    let out = env.step(&cs);
                    client.put_scalar(&proto.error_key(i, t), out.spec_error);
                    if out.done {
                        client.put_flag(&proto.done_key(i), true);
                        break;
                    }
                    let obs = env.observe();
                    client.put_tensor(&proto.state_key(i, t + 1), vec![obs.len()], obs);
                }
                Ok(())
            }));
        }

        // --- trainer side: poll states, act, collect rewards ------------
        let trainer = orch.client();
        let mut episodes = vec![Episode::default(); n_envs];
        let mut policy_time = 0.0f64;
        let mut batch_obs = vec![0f32; n_envs * n_elems * feat];

        for t in 0..n_actions {
            // Gather all env states (blocking poll per env).
            for (i, _ep) in episodes.iter().enumerate() {
                let state = trainer
                    .poll(&proto.state_key(i, t), POLL_TIMEOUT)
                    .with_context(|| format!("trainer: no state from env {i} step {t}"))?;
                let (_, data) = state.as_tensor().context("state must be a tensor")?;
                anyhow::ensure!(
                    data.len() == n_elems * feat,
                    "env {i} state has {} floats, expected {}",
                    data.len(),
                    n_elems * feat
                );
                batch_obs[i * n_elems * feat..(i + 1) * n_elems * feat]
                    .copy_from_slice(data);
            }

            // One batched policy evaluation for all envs.
            let tp = Instant::now();
            let out = policy.forward(theta, &batch_obs, n_envs * n_elems)?;
            policy_time += tp.elapsed().as_secs_f64();

            // Sample actions, write them back, record the step.
            for (i, ep) in episodes.iter_mut().enumerate() {
                let mean = &out.mean[i * n_elems..(i + 1) * n_elems];
                let value = &out.value[i * n_elems..(i + 1) * n_elems];
                let act = if deterministic {
                    mean.to_vec()
                } else {
                    gaussian::sample(mean, out.log_std, rng)
                };
                let logp = gaussian::log_prob(&act, mean, out.log_std);
                trainer.put_tensor(&proto.action_key(i, t), vec![n_elems], act.clone());
                ep.steps.push(StepRecord {
                    obs: batch_obs[i * n_elems * feat..(i + 1) * n_elems * feat].to_vec(),
                    act,
                    logp,
                    value: value.to_vec(),
                    reward: 0.0, // filled in below
                });
            }

            // Collect the spectrum errors -> rewards (Eqs. 4-5).
            for (i, ep) in episodes.iter_mut().enumerate() {
                let err = trainer
                    .poll(&proto.error_key(i, t), POLL_TIMEOUT)
                    .with_context(|| format!("trainer: no error from env {i} step {t}"))?
                    .as_scalar()
                    .context("error must be a scalar")?;
                ep.steps[t].reward = reward_from_error(err, self.cfg.case.alpha);
            }
        }

        // All envs must have signalled termination.
        for i in 0..n_envs {
            trainer
                .poll(&proto.done_key(i), POLL_TIMEOUT)
                .with_context(|| format!("env {i} never signalled done"))?;
        }
        for (i, w) in workers.into_iter().enumerate() {
            w.join()
                .map_err(|_| anyhow::anyhow!("env worker {i} panicked"))??;
        }

        Ok(Rollouts {
            episodes,
            sample_time_s: t_start.elapsed().as_secs_f64(),
            policy_time_s: policy_time,
        })
    }
}
