//! Training metrics: per-iteration records, console logging and CSV
//! emission (the data behind Fig. 5 top row).

use crate::util::binio::CsvWriter;
use anyhow::Result;
use std::path::Path;

/// One training iteration's record.
#[derive(Debug, Clone, Default)]
pub struct IterationMetrics {
    pub iteration: usize,
    /// Mean / min / max normalized return over the training envs.
    pub return_mean: f64,
    pub return_min: f64,
    pub return_max: f64,
    /// Normalized return on the held-out test state (eval iterations).
    pub test_return: Option<f64>,
    /// Wall-clock split (paper §6.2: sampling vs update time).
    pub sample_time_s: f64,
    pub train_time_s: f64,
    pub policy_time_s: f64,
    /// Seconds the collector spent blocked waiting for env arrivals.
    pub idle_time_s: f64,
    /// PPO diagnostics (averaged over the iteration's minibatches).
    pub loss: f64,
    pub clip_frac: f64,
    pub approx_kl: f64,
    /// Mean normalized return per scenario variant (empty when the pool
    /// is homogeneous); console-only, the CSV schema stays fixed.
    pub variant_returns: Vec<(String, f64)>,
    /// Exchange-wait latency percentiles over this iteration, from the
    /// telemetry histogram snapshot diff (0 with telemetry off).
    pub exchange_p50_ms: f64,
    pub exchange_p99_ms: f64,
    /// Wire frames the exchange served during this iteration (0 for the
    /// in-process transport or with telemetry off).
    pub frames: u64,
}

/// Collects records and mirrors them to CSV + console.
pub struct MetricsLog {
    pub history: Vec<IterationMetrics>,
    csv: Option<CsvWriter>,
}

const HEADER: [&str; 15] = [
    "iteration",
    "return_mean",
    "return_min",
    "return_max",
    "test_return",
    "sample_time_s",
    "train_time_s",
    "policy_time_s",
    "idle_time_s",
    "loss",
    "clip_frac",
    "approx_kl",
    "exchange_p50_ms",
    "exchange_p99_ms",
    "frames",
];

impl MetricsLog {
    /// Log to memory only.
    pub fn in_memory() -> MetricsLog {
        MetricsLog { history: Vec::new(), csv: None }
    }

    /// Log to memory + a CSV file.
    pub fn with_csv(path: &Path) -> Result<MetricsLog> {
        Ok(MetricsLog {
            history: Vec::new(),
            csv: Some(CsvWriter::create(path, &HEADER)?),
        })
    }

    /// Record one iteration (also prints a console line).
    pub fn record(&mut self, m: IterationMetrics) -> Result<()> {
        let test = m
            .test_return
            .map(|t| format!("{t:.4}"))
            .unwrap_or_else(|| "-".to_string());
        println!(
            "[it {:>5}] return {:+.4} [{:+.4}, {:+.4}]  test {}  sample {:.2}s  train {:.2}s  kl {:.2e}",
            m.iteration,
            m.return_mean,
            m.return_min,
            m.return_max,
            test,
            m.sample_time_s,
            m.train_time_s,
            m.approx_kl,
        );
        if !m.variant_returns.is_empty() {
            let parts: Vec<String> = m
                .variant_returns
                .iter()
                .map(|(name, r)| format!("{name} {r:+.4}"))
                .collect();
            println!("           variants: {}", parts.join("  "));
        }
        if let Some(csv) = &mut self.csv {
            csv.row(&[
                m.iteration.to_string(),
                format!("{}", m.return_mean),
                format!("{}", m.return_min),
                format!("{}", m.return_max),
                m.test_return.map(|t| format!("{t}")).unwrap_or_default(),
                format!("{}", m.sample_time_s),
                format!("{}", m.train_time_s),
                format!("{}", m.policy_time_s),
                format!("{}", m.idle_time_s),
                format!("{}", m.loss),
                format!("{}", m.clip_frac),
                format!("{}", m.approx_kl),
                format!("{}", m.exchange_p50_ms),
                format!("{}", m.exchange_p99_ms),
                m.frames.to_string(),
            ])?;
        }
        self.history.push(m);
        Ok(())
    }

    /// Best mean return seen so far.
    pub fn best_return(&self) -> f64 {
        self.history
            .iter()
            .map(|m| m.return_mean)
            .fold(f64::NEG_INFINITY, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_tracks_best() {
        let mut log = MetricsLog::in_memory();
        for (i, r) in [(0usize, -0.5), (1, 0.1), (2, 0.05)] {
            log.record(IterationMetrics {
                iteration: i,
                return_mean: r,
                ..Default::default()
            })
            .unwrap();
        }
        assert_eq!(log.history.len(), 3);
        assert!((log.best_return() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn csv_written() {
        let dir = std::env::temp_dir().join("relexi_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.csv");
        {
            let mut log = MetricsLog::with_csv(&path).unwrap();
            log.record(IterationMetrics {
                iteration: 7,
                return_mean: 0.25,
                test_return: Some(0.3),
                exchange_p50_ms: 1.5,
                frames: 42,
                ..Default::default()
            })
            .unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("iteration,"));
        assert!(text.contains("exchange_p50_ms,exchange_p99_ms,frames"));
        assert!(text.contains("7,0.25"));
        assert!(text.contains("0.3"));
        assert!(text.contains("1.5"));
        assert!(text.contains(",42"));
    }
}
