//! The Layer-3 coordinator — the paper's system contribution (Relexi):
//! PPO training of an LES turbulence model with a persistent pool of
//! parallel environment workers coupled through the in-memory
//! orchestrator (event-driven arrival-order collection, lock-step
//! reference retained), the compiled JAX/Pallas policy and train-step
//! artifacts on the hot path, and evaluation utilities for the paper's
//! Fig. 5 comparisons.

pub mod envpool;
pub mod evaluate;
pub mod metrics;
pub mod supervise;
pub mod training;

pub use envpool::{EnvPool, PoolCounters, Rollouts, WorkerHost};
pub use supervise::{FaultPlan, SupervisionReport};
pub use evaluate::{eval_baseline, eval_policy, eval_policy_in, EvalResult};
pub use metrics::{IterationMetrics, MetricsLog};
pub use training::TrainingLoop;
