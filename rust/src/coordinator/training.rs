//! The Relexi training loop (Algorithm 1): launch orchestrator, build the
//! persistent env pool once (over whichever backend `rl.backend`
//! selects), repeat {begin iteration -> event-driven sampling -> PPO
//! update}, evaluating on the held-out state every `eval_every`
//! iterations.  After iteration 0 the loop spawns no threads and
//! rebuilds no env/backend instances: workers outlive iterations and the
//! evaluation environment is constructed once on the pool's shared
//! backend context.

use super::envpool::EnvPool;
use super::evaluate::{eval_policy_in, EvalResult};
use super::metrics::{IterationMetrics, MetricsLog};
use crate::config::RunConfig;
use crate::orchestrator::{Orchestrator, Protocol, WakeMode};
use crate::rl::{flatten, max_return, CfdEnv};
use crate::runtime::{runtime_from_config, Minibatch, Policy, Trainer};
use crate::solver::dns::Truth;
use crate::util::binio::write_f32_vec;
use crate::util::Rng;
use anyhow::Result;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

/// The assembled training system.  The policy/trainer pair comes from
/// the `runtime.backend` registry: the compiled-XLA path or the native
/// in-process path, both behind the [`Policy`]/[`Trainer`] traits.
pub struct TrainingLoop {
    pub cfg: RunConfig,
    /// The DNS truth package the LES backend was built on (`None` for
    /// backends that generate their own ground truth, e.g. Burgers).
    pub truth: Option<Arc<Truth>>,
    pub policy: Box<dyn Policy>,
    pub trainer: Box<dyn Trainer>,
    pub orch: Orchestrator,
    pool: EnvPool,
    /// Held-out-state evaluation env, built once on the pool's shared
    /// backend context.
    eval_env: Box<dyn CfdEnv>,
    rng: Rng,
}

impl TrainingLoop {
    /// Wire up runtime, artifacts, orchestrator and the persistent env
    /// pool (workers and environments are constructed here, once) for a
    /// run that has a DNS truth package (the LES backend).
    pub fn new(cfg: RunConfig, truth: Arc<Truth>) -> Result<TrainingLoop> {
        TrainingLoop::from_config(cfg, Some(truth))
    }

    /// [`TrainingLoop::new`] with the DNS truth optional: backends other
    /// than `"les"` generate their own ground truth from the config, so
    /// constructing a `rl.backend = "burgers"` loop never runs the 3D
    /// DNS.
    ///
    /// The env pool is built first so the runtime registry can size the
    /// native policy's input layer from `pool.features()` — with
    /// `runtime.backend = "native"` ANY registered CFD backend trains
    /// end-to-end with zero artifacts.  The XLA path keeps its
    /// lowering-time shapes, so its policy must still match the
    /// backend's observation shape — checked here, at construction, so
    /// a mismatch (today's artifacts are LES-shaped) fails fast instead
    /// of on the first forward.
    pub fn from_config(cfg: RunConfig, truth: Option<Arc<Truth>>) -> Result<TrainingLoop> {
        cfg.validate()?;
        // Size the kernel worker pool (SIMD/GEMM/FFT/solver waves) from
        // `[hpc] threads` before any env or trainer math runs.  Kernel
        // results are bit-identical for every width.
        crate::util::pool::configure_global(cfg.hpc.threads);
        // Per-key wakeups by default; `hpc.db_seqlock_wake` retains the
        // PR-2 sequence-lock baseline for A/B runs.
        let wake = if cfg.hpc.db_seqlock_wake {
            WakeMode::SeqLock
        } else {
            WakeMode::PerKey
        };
        let orch = Orchestrator::launch_mode(cfg.hpc.db_shards, wake);
        let pool = EnvPool::from_config(cfg.clone(), truth.clone(), &orch)?;
        let (policy, trainer) = runtime_from_config(&cfg, pool.features())?;
        anyhow::ensure!(
            policy.features() == pool.features(),
            "the {:?} runtime provides {} features/agent but the {:?} backend produces {} — \
             compiled artifacts exist for the LES shapes (N in {{5, 7}}); use \
             runtime.backend = \"native\" (sized from the pool) for other backends",
            cfg.runtime.backend,
            policy.features(),
            cfg.rl.backend,
            pool.features()
        );
        let eval_env = pool.make_eval_env()?;
        let rng = Rng::new(cfg.rl.seed);
        Ok(TrainingLoop {
            cfg,
            truth,
            policy,
            trainer,
            orch,
            pool,
            eval_env,
            rng,
        })
    }

    /// Run `iterations` training iterations; returns the metrics log.
    pub fn run(&mut self, log: &mut MetricsLog) -> Result<()> {
        let out_dir = PathBuf::from(&self.cfg.out_dir);
        std::fs::create_dir_all(&out_dir)?;

        for it in 0..self.cfg.rl.iterations {
            // --- sampling phase (Algorithm 1, lines 4-13) ---------------
            let proto = Protocol::new(&format!("it{it}"));
            let rollouts = self.pool.collect(
                &self.orch,
                &proto,
                &self.policy,
                self.trainer.theta(),
                &mut self.rng,
                false,
            )?;
            self.orch.clear(); // drop this iteration's keys

            // Normalize per episode: heterogeneous variants may run
            // different horizons, so each return is scaled by its own
            // maximum achievable return.
            let returns: Vec<f64> = rollouts
                .episodes
                .iter()
                .map(|e| {
                    e.discounted_return(self.cfg.rl.gamma)
                        / max_return(e.steps.len().max(1), self.cfg.rl.gamma)
                })
                .collect();

            // Per-variant bookkeeping (console metrics for mixed pools).
            let variant_returns: Vec<(String, f64)> = if self.cfg.n_variants() > 1 {
                (0..self.cfg.n_variants())
                    .map(|v| {
                        let rs: Vec<f64> = rollouts
                            .episodes
                            .iter()
                            .zip(&returns)
                            .filter(|(e, _)| e.variant == v)
                            .map(|(_, &r)| r)
                            .collect();
                        (
                            self.cfg.rl.variants[v].name.clone(),
                            crate::util::stats::mean(&rs),
                        )
                    })
                    .collect()
            } else {
                Vec::new()
            };

            // --- update phase (lines 14-16) ------------------------------
            let t_train = Instant::now();
            let ds = flatten(
                &rollouts.episodes,
                self.policy.features(),
                self.cfg.rl.gamma,
                self.cfg.rl.gae_lambda,
            );
            let mut loss_acc = 0.0;
            let mut clip_acc = 0.0;
            let mut kl_acc = 0.0;
            let mut n_mb = 0usize;
            for _epoch in 0..self.cfg.rl.epochs {
                for idx in ds.minibatch_indices(self.trainer.minibatch(), &mut self.rng) {
                    let (obs, act, logp, adv, ret) = ds.gather(&idx);
                    let m = self.trainer.train_minibatch(&Minibatch {
                        obs: &obs,
                        act: &act,
                        old_logp: &logp,
                        adv: &adv,
                        ret: &ret,
                    })?;
                    loss_acc += m.loss as f64;
                    clip_acc += m.clip_frac as f64;
                    kl_acc += m.approx_kl as f64;
                    n_mb += 1;
                }
            }
            let train_time_s = t_train.elapsed().as_secs_f64();

            // --- evaluation on the held-out state (persistent env) ------
            let test_return = if self.cfg.rl.eval_every > 0
                && it % self.cfg.rl.eval_every == 0
            {
                Some(self.evaluate()?.normalized_return)
            } else {
                None
            };

            log.record(IterationMetrics {
                iteration: it,
                return_mean: crate::util::stats::mean(&returns),
                return_min: crate::util::stats::min(&returns),
                return_max: crate::util::stats::max(&returns),
                test_return,
                sample_time_s: rollouts.sample_time_s,
                train_time_s,
                policy_time_s: rollouts.policy_time_s,
                idle_time_s: rollouts.idle_time_s,
                loss: loss_acc / n_mb.max(1) as f64,
                clip_frac: clip_acc / n_mb.max(1) as f64,
                approx_kl: kl_acc / n_mb.max(1) as f64,
                variant_returns,
            })?;
        }

        // Final checkpoint.
        self.save_checkpoint(&out_dir.join("policy_final.bin"))?;
        Ok(())
    }

    /// Deterministic (mean-action) evaluation of the current policy on
    /// the held-out test state, in the persistent evaluation env.
    pub fn evaluate(&mut self) -> Result<EvalResult> {
        eval_policy_in(
            self.eval_env.as_mut(),
            &self.cfg,
            self.policy.as_ref(),
            self.trainer.theta(),
            None,
        )
    }

    /// Worker-pool construction counters: steady-state iterations must
    /// leave everything but `iterations` untouched.
    pub fn pool_counters(&self) -> super::PoolCounters {
        self.pool.counters()
    }

    /// Persist the current flat parameter vector.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        write_f32_vec(path, self.trainer.theta())
    }

    /// Restore parameters from a checkpoint (length-checked against the
    /// runtime's architecture).
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let theta = crate::util::binio::read_f32_vec(path)?;
        self.trainer.set_theta(theta)
    }
}
