//! The Relexi training loop (Algorithm 1): launch orchestrator, build the
//! persistent env pool once (over whichever backend `rl.backend`
//! selects), repeat {begin iteration -> event-driven sampling -> PPO
//! update}, evaluating on the held-out state every `eval_every`
//! iterations.  After iteration 0 the loop spawns no threads and
//! rebuilds no env/backend instances: workers outlive iterations and the
//! evaluation environment is constructed once on the pool's shared
//! backend context.

use super::envpool::EnvPool;
use super::evaluate::{eval_policy_in, EvalResult};
use super::metrics::{IterationMetrics, MetricsLog};
use super::supervise::SupervisionReport;
use crate::config::RunConfig;
use crate::orchestrator::{Orchestrator, Protocol, WakeMode};
use crate::rl::{flatten, max_return, CfdEnv};
use crate::runtime::{runtime_from_config, Minibatch, Policy, Trainer};
use crate::solver::dns::Truth;
use crate::util::binio::write_f32_vec;
use crate::util::Rng;
use anyhow::Result;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

/// The assembled training system.  The policy/trainer pair comes from
/// the `runtime.backend` registry: the compiled-XLA path or the native
/// in-process path, both behind the [`Policy`]/[`Trainer`] traits.
pub struct TrainingLoop {
    pub cfg: RunConfig,
    /// The DNS truth package the LES backend was built on (`None` for
    /// backends that generate their own ground truth, e.g. Burgers).
    pub truth: Option<Arc<Truth>>,
    pub policy: Box<dyn Policy>,
    pub trainer: Box<dyn Trainer>,
    pub orch: Orchestrator,
    pool: EnvPool,
    /// Held-out-state evaluation env, built once on the pool's shared
    /// backend context.
    eval_env: Box<dyn CfdEnv>,
    rng: Rng,
}

impl TrainingLoop {
    /// Wire up runtime, artifacts, orchestrator and the persistent env
    /// pool (workers and environments are constructed here, once) for a
    /// run that has a DNS truth package (the LES backend).
    pub fn new(cfg: RunConfig, truth: Arc<Truth>) -> Result<TrainingLoop> {
        TrainingLoop::from_config(cfg, Some(truth))
    }

    /// [`TrainingLoop::new`] with the DNS truth optional: backends other
    /// than `"les"` generate their own ground truth from the config, so
    /// constructing a `rl.backend = "burgers"` loop never runs the 3D
    /// DNS.
    ///
    /// The env pool is built first so the runtime registry can size the
    /// native policy's input layer from `pool.features()` — with
    /// `runtime.backend = "native"` ANY registered CFD backend trains
    /// end-to-end with zero artifacts.  The XLA path keeps its
    /// lowering-time shapes, so its policy must still match the
    /// backend's observation shape — checked here, at construction, so
    /// a mismatch (today's artifacts are LES-shaped) fails fast instead
    /// of on the first forward.
    pub fn from_config(cfg: RunConfig, truth: Option<Arc<Truth>>) -> Result<TrainingLoop> {
        cfg.validate()?;
        // Size the kernel worker pool (SIMD/GEMM/FFT/solver waves) from
        // `[hpc] threads` before any env or trainer math runs.  Kernel
        // results are bit-identical for every width.
        crate::util::pool::configure_global(cfg.hpc.threads);
        // Per-key wakeups by default; `hpc.db_seqlock_wake` retains the
        // PR-2 sequence-lock baseline for A/B runs.
        let wake = if cfg.hpc.db_seqlock_wake {
            WakeMode::SeqLock
        } else {
            WakeMode::PerKey
        };
        let orch = Orchestrator::launch_mode(cfg.hpc.db_shards, wake);
        let pool = EnvPool::from_config(cfg.clone(), truth.clone(), &orch)?;
        let (policy, trainer) = runtime_from_config(&cfg, pool.features())?;
        anyhow::ensure!(
            policy.features() == pool.features(),
            "the {:?} runtime provides {} features/agent but the {:?} backend produces {} — \
             compiled artifacts exist for the LES shapes (N in {{5, 7}}); use \
             runtime.backend = \"native\" (sized from the pool) for other backends",
            cfg.runtime.backend,
            policy.features(),
            cfg.rl.backend,
            pool.features()
        );
        let eval_env = pool.make_eval_env()?;
        let rng = Rng::new(cfg.rl.seed);
        Ok(TrainingLoop {
            cfg,
            truth,
            policy,
            trainer,
            orch,
            pool,
            eval_env,
            rng,
        })
    }

    /// Run `iterations` training iterations; returns the metrics log.
    pub fn run(&mut self, log: &mut MetricsLog) -> Result<()> {
        let out_dir = PathBuf::from(&self.cfg.out_dir);
        std::fs::create_dir_all(&out_dir)?;

        // Telemetry state for the run: the cross-process trace merger,
        // the accumulated supervision record, and the Exchange-histogram
        // / frame-counter baselines the per-iteration CSV deltas diff
        // against.  All inert when `[telemetry] enabled = false`.
        let tel_on = crate::util::telemetry::enabled();
        let mut merger = crate::util::telemetry::TraceMerger::new();
        let mut sup_acc = SupervisionReport::default();
        let mut exch_prev =
            crate::util::telemetry::snapshot_hist(crate::util::telemetry::HistId::Exchange);
        let mut frames_prev = self.orch.stats().frames;

        for it in 0..self.cfg.rl.iterations {
            // --- sampling phase (Algorithm 1, lines 4-13) ---------------
            let proto = Protocol::new(&format!("it{it}"));
            let rollouts = self.pool.collect(
                &self.orch,
                &proto,
                &self.policy,
                self.trainer.theta(),
                &mut self.rng,
                false,
            )?;
            self.orch.clear(); // drop this iteration's keys

            // --- telemetry: iteration deltas + worker buffer gather -----
            let (exchange_p50_ms, exchange_p99_ms, frames) = if tel_on {
                let snap = crate::util::telemetry::snapshot_hist(
                    crate::util::telemetry::HistId::Exchange,
                );
                let d = snap.since(&exch_prev);
                exch_prev = snap;
                let f = self.orch.stats().frames;
                let df = f.saturating_sub(frames_prev);
                frames_prev = f;
                (
                    d.percentile_us(0.5) as f64 / 1e3,
                    d.percentile_us(0.99) as f64 / 1e3,
                    df,
                )
            } else {
                (0.0, 0.0, 0)
            };
            if tel_on {
                // Drain our own rings every iteration so they never wrap
                // between merges, then pull each worker's shipped blob
                // (the flush key must go out after `clear()` or it would
                // be dropped with the iteration's data keys).
                merger.absorb_local();
                for (w, blob, begin_us) in self.pool.gather_worker_telemetry(it as u64) {
                    if let Err(e) = merger.absorb_blob(&blob, begin_us) {
                        crate::tlog!(warn, "worker {w} telemetry blob rejected: {e:#}");
                    }
                }
            }
            sup_acc.respawns += rollouts.supervision.respawns;
            sup_acc
                .dropped_envs
                .extend(&rollouts.supervision.dropped_envs);
            sup_acc.detect_s.extend(&rollouts.supervision.detect_s);
            sup_acc.recover_s.extend(&rollouts.supervision.recover_s);

            // Normalize per episode: heterogeneous variants may run
            // different horizons, so each return is scaled by its own
            // maximum achievable return.
            let returns: Vec<f64> = rollouts
                .episodes
                .iter()
                .map(|e| {
                    e.discounted_return(self.cfg.rl.gamma)
                        / max_return(e.steps.len().max(1), self.cfg.rl.gamma)
                })
                .collect();

            // Per-variant bookkeeping (console metrics for mixed pools).
            let variant_returns: Vec<(String, f64)> = if self.cfg.n_variants() > 1 {
                (0..self.cfg.n_variants())
                    .map(|v| {
                        let rs: Vec<f64> = rollouts
                            .episodes
                            .iter()
                            .zip(&returns)
                            .filter(|(e, _)| e.variant == v)
                            .map(|(_, &r)| r)
                            .collect();
                        (
                            self.cfg.rl.variants[v].name.clone(),
                            crate::util::stats::mean(&rs),
                        )
                    })
                    .collect()
            } else {
                Vec::new()
            };

            // --- update phase (lines 14-16) ------------------------------
            let t_train = Instant::now();
            let ds = flatten(
                &rollouts.episodes,
                self.policy.features(),
                self.cfg.rl.gamma,
                self.cfg.rl.gae_lambda,
            );
            let mut loss_acc = 0.0;
            let mut clip_acc = 0.0;
            let mut kl_acc = 0.0;
            let mut n_mb = 0usize;
            for _epoch in 0..self.cfg.rl.epochs {
                for idx in ds.minibatch_indices(self.trainer.minibatch(), &mut self.rng) {
                    let (obs, act, logp, adv, ret) = ds.gather(&idx);
                    let m = self.trainer.train_minibatch(&Minibatch {
                        obs: &obs,
                        act: &act,
                        old_logp: &logp,
                        adv: &adv,
                        ret: &ret,
                    })?;
                    loss_acc += m.loss as f64;
                    clip_acc += m.clip_frac as f64;
                    kl_acc += m.approx_kl as f64;
                    n_mb += 1;
                }
            }
            let train_time_s = t_train.elapsed().as_secs_f64();

            // --- evaluation on the held-out state (persistent env) ------
            let test_return = if self.cfg.rl.eval_every > 0
                && it % self.cfg.rl.eval_every == 0
            {
                Some(self.evaluate()?.normalized_return)
            } else {
                None
            };

            log.record(IterationMetrics {
                iteration: it,
                return_mean: crate::util::stats::mean(&returns),
                return_min: crate::util::stats::min(&returns),
                return_max: crate::util::stats::max(&returns),
                test_return,
                sample_time_s: rollouts.sample_time_s,
                train_time_s,
                policy_time_s: rollouts.policy_time_s,
                idle_time_s: rollouts.idle_time_s,
                loss: loss_acc / n_mb.max(1) as f64,
                clip_frac: clip_acc / n_mb.max(1) as f64,
                approx_kl: kl_acc / n_mb.max(1) as f64,
                variant_returns,
                exchange_p50_ms,
                exchange_p99_ms,
                frames,
            })?;
        }

        // Final checkpoint.
        self.save_checkpoint(&out_dir.join("policy_final.bin"))?;

        if tel_on {
            self.finish_telemetry(&mut merger, &sup_acc)?;
        }
        Ok(())
    }

    /// End-of-run telemetry consolidation: drain the trainer's remaining
    /// rings, write the merged Chrome-trace JSON (Perfetto-loadable) and
    /// the `TELEMETRY_{run}.json` aggregate folding in the store / pool /
    /// backend / supervision counters, and print one summary block.
    fn finish_telemetry(
        &mut self,
        merger: &mut crate::util::telemetry::TraceMerger,
        sup: &SupervisionReport,
    ) -> Result<()> {
        merger.absorb_local();
        let run = self.cfg.case.name.clone();
        let trace_path = if self.cfg.telemetry.trace_path.is_empty() {
            PathBuf::from(format!("TRACE_{run}.json"))
        } else {
            PathBuf::from(&self.cfg.telemetry.trace_path)
        };
        if let Some(dir) = trace_path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(&trace_path, merger.chrome_trace_json())?;

        let st = self.orch.stats();
        let pc = self.pool.counters();
        // Empty-slice-safe aggregates: NaN/-inf would corrupt the JSON.
        let agg = |v: &[f64]| -> (f64, f64) {
            if v.is_empty() {
                (0.0, 0.0)
            } else {
                (
                    v.iter().sum::<f64>() / v.len() as f64,
                    v.iter().cloned().fold(0.0, f64::max),
                )
            }
        };
        let (detect_mean, detect_max) = agg(&sup.detect_s);
        let (recover_mean, recover_max) = agg(&sup.recover_s);
        let mut extra: Vec<(&str, Vec<(String, f64)>)> = vec![
            (
                "store",
                vec![
                    ("puts".to_string(), st.puts as f64),
                    ("gets".to_string(), st.gets as f64),
                    ("hits".to_string(), st.hits as f64),
                    ("bytes_in".to_string(), st.bytes_in as f64),
                    ("bytes_out".to_string(), st.bytes_out as f64),
                    ("sub_ops".to_string(), st.sub_ops as f64),
                    ("frames".to_string(), st.frames as f64),
                    ("batched_keys".to_string(), st.batched_keys as f64),
                ],
            ),
            (
                "pool",
                vec![
                    ("threads_spawned".to_string(), pc.threads_spawned as f64),
                    ("envs_built".to_string(), pc.envs_built as f64),
                    ("grids_built".to_string(), pc.grids_built as f64),
                    ("iterations".to_string(), pc.iterations as f64),
                    ("exchange_allocs".to_string(), pc.exchange_allocs as f64),
                ],
            ),
            (
                "supervision",
                vec![
                    ("respawns".to_string(), sup.respawns as f64),
                    ("dropped_envs".to_string(), sup.dropped_envs.len() as f64),
                    ("incidents".to_string(), sup.detect_s.len() as f64),
                    ("detect_s_mean".to_string(), detect_mean),
                    ("detect_s_max".to_string(), detect_max),
                    ("recover_s_mean".to_string(), recover_mean),
                    ("recover_s_max".to_string(), recover_max),
                ],
            ),
        ];
        let batch = self.pool.backend().batch_stats();
        if !batch.is_empty() {
            extra.push((
                "batch",
                batch.iter().map(|&(k, v)| (k.to_string(), v as f64)).collect(),
            ));
        }
        let summary = merger.summary();
        std::fs::write(format!("TELEMETRY_{run}.json"), summary.to_json(&run, &extra))?;

        println!(
            "\n[telemetry] run {run}: {} process(es), {} dropped record(s) -> {} + TELEMETRY_{run}.json",
            summary.n_procs,
            summary.dropped_records,
            trace_path.display()
        );
        for r in &summary.spans {
            println!(
                "[telemetry]   span {:<24} n {:>8}  p50 {:>9}us  p99 {:>9}us  max {:>9}us",
                r.name, r.count, r.p50_us, r.p99_us, r.max_us
            );
        }
        for r in summary.hists.iter().filter(|r| r.count > 0) {
            println!(
                "[telemetry]   hist {:<24} n {:>8}  p50 {:>9}us  p99 {:>9}us",
                r.name, r.count, r.p50_us, r.p99_us
            );
        }
        Ok(())
    }

    /// Deterministic (mean-action) evaluation of the current policy on
    /// the held-out test state, in the persistent evaluation env.
    pub fn evaluate(&mut self) -> Result<EvalResult> {
        eval_policy_in(
            self.eval_env.as_mut(),
            &self.cfg,
            self.policy.as_ref(),
            self.trainer.theta(),
            None,
        )
    }

    /// Worker-pool construction counters: steady-state iterations must
    /// leave everything but `iterations` untouched.
    pub fn pool_counters(&self) -> super::PoolCounters {
        self.pool.counters()
    }

    /// Persist the current flat parameter vector.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        write_f32_vec(path, self.trainer.theta())
    }

    /// Restore parameters from a checkpoint (length-checked against the
    /// runtime's architecture).
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let theta = crate::util::binio::read_f32_vec(path)?;
        self.trainer.set_theta(theta)
    }
}
