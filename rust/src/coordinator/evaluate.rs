//! Policy evaluation and baseline comparison (Fig. 5 bottom row):
//! deterministic rollout on the held-out test state, final energy spectra
//! for RL / Smagorinsky / implicit LES against the DNS band, and the
//! distribution of predicted Cs values.

use crate::config::RunConfig;
use crate::rl::{gaussian, max_return, CfdEnv, LesEnv};
use crate::runtime::Policy;
use crate::solver::dns::Truth;
use crate::util::Rng;
use anyhow::Result;
use std::sync::Arc;

/// Outcome of one evaluation episode.
#[derive(Debug, Clone)]
pub struct EvalResult {
    /// Return normalized by the maximum achievable return.
    pub normalized_return: f64,
    /// Energy spectrum at t_end.
    pub final_spectrum: Vec<f64>,
    /// Every Cs the model predicted during the episode (Fig. 5d).
    pub cs_samples: Vec<f64>,
}

/// Deterministic policy rollout (mean actions) on the test state,
/// constructing a fresh LES environment (grid included) per call.
/// Prefer [`eval_policy_in`] when a reusable environment is available —
/// the training loop keeps one alive (built on the pool's shared
/// backend context) so steady-state evaluation allocates nothing
/// grid-sized.
pub fn eval_policy(
    cfg: &RunConfig,
    truth: &Arc<Truth>,
    policy: &dyn Policy,
    theta: &[f32],
    stochastic_rng: Option<&mut Rng>,
) -> Result<EvalResult> {
    let mut env = LesEnv::new(&cfg.case, &cfg.solver, truth.clone())?;
    eval_policy_in(&mut env, cfg, policy, theta, stochastic_rng)
}

/// Deterministic policy rollout (mean actions) on the test state, run in
/// a caller-owned environment of any backend, under any [`Policy`]
/// runtime backend.
pub fn eval_policy_in(
    env: &mut dyn CfdEnv,
    cfg: &RunConfig,
    policy: &dyn Policy,
    theta: &[f32],
    stochastic_rng: Option<&mut Rng>,
) -> Result<EvalResult> {
    let n_agents = env.n_agents();
    let mut rng_holder = stochastic_rng;
    let mut reset_rng = Rng::new(0); // unused for the test state
    let mut obs = env.reset(&mut reset_rng, true);
    let mut ret = 0.0;
    let mut cs_samples = Vec::with_capacity(n_agents * env.n_actions());
    let gamma = cfg.rl.gamma;
    for t in 0..env.n_actions() {
        let out = policy.forward(theta, &obs, n_agents)?;
        let act: Vec<f32> = match rng_holder.as_deref_mut() {
            Some(rng) => gaussian::sample(&out.mean, out.log_std, rng),
            None => out.mean.clone(),
        };
        cs_samples.extend(act.iter().map(|&a| (a as f64).clamp(0.0, 0.5)));
        let step = env.step(&act.iter().map(|&a| a as f64).collect::<Vec<_>>());
        ret += gamma.powi(t as i32 + 1) * step.reward;
        if step.done {
            break;
        }
        // Refill the observation buffer in place (no per-step allocation).
        env.observe_into(&mut obs);
    }
    Ok(EvalResult {
        normalized_return: ret / max_return(env.n_actions(), gamma),
        final_spectrum: env.spectrum(),
        cs_samples,
    })
}

/// Baseline rollout with a constant Cs (0.17 = classic Smagorinsky,
/// 0.0 = implicit LES) on the test state.
pub fn eval_baseline(cfg: &RunConfig, truth: &Arc<Truth>, cs: f64) -> Result<EvalResult> {
    let mut env = LesEnv::new(&cfg.case, &cfg.solver, truth.clone())?;
    let n_elems = env.n_elems();
    let mut rng = Rng::new(0);
    env.reset(&mut rng, true);
    let actions = vec![cs; n_elems];
    let mut ret = 0.0;
    let gamma = cfg.rl.gamma;
    for t in 0..env.n_actions() {
        let step = env.step(&actions);
        ret += gamma.powi(t as i32 + 1) * step.reward;
        if step.done {
            break;
        }
    }
    Ok(EvalResult {
        normalized_return: ret / max_return(env.n_actions(), gamma),
        final_spectrum: env.spectrum(),
        cs_samples: actions,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CaseConfig;
    use crate::solver::dns::{generate, TruthParams};

    fn tiny_cfg() -> (RunConfig, Arc<Truth>) {
        let mut cfg = RunConfig::default();
        cfg.case = CaseConfig {
            name: "tiny".into(),
            n: 5,
            elems_per_dir: 2,
            k_max: 3,
            alpha: 0.4,
        };
        cfg.solver.t_end = 0.2;
        cfg.solver.dns_points = 24;
        let truth = generate(
            &TruthParams {
                n_dns: 24,
                n_les: 12,
                nu: cfg.solver.nu,
                ke_target: cfg.solver.ke_target,
                spinup_time: 0.3,
                n_states: 2,
                sample_interval: 0.2,
                seed: 11,
            },
            |_, _| {},
        );
        (cfg, Arc::new(truth))
    }

    #[test]
    fn baselines_run_and_differ() {
        let (cfg, truth) = tiny_cfg();
        let smag = eval_baseline(&cfg, &truth, 0.17).unwrap();
        let implicit = eval_baseline(&cfg, &truth, 0.0).unwrap();
        assert!(smag.normalized_return <= 1.0 && smag.normalized_return >= -1.0);
        // Different models must produce different spectra.
        let diff: f64 = smag
            .final_spectrum
            .iter()
            .zip(&implicit.final_spectrum)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-12);
        // Smagorinsky baseline predicts Cs=0.17 everywhere.
        assert!(smag.cs_samples.iter().all(|&c| (c - 0.17).abs() < 1e-12));
    }
}
