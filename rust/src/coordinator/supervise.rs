//! Supervision primitives for the process-worker runtime: the
//! deterministic fault-injection plan the chaos tests drive, the
//! heartbeat-expiry monitor the collector polls between subscription
//! slices, and the per-iteration supervision report surfaced on
//! [`crate::coordinator::Rollouts`].
//!
//! The fault plan is a `;`-separated directive string, config- or
//! env-var-driven (`[fault] plan` / `RELEXI_FAULT_PLAN`):
//!
//! * `kill:w<K>@<W>`    — worker `K` exits cleanly instead of processing
//!   its begin message for local wave `W` (waves counted per process
//!   from 0, so a respawned worker starts again at wave 0);
//! * `killput:w<K>@<N>` — worker `K` aborts the process after its `N`th
//!   transport put — a mid-episode crash with frames already in flight,
//!   the hard case for replay;
//! * `hbstall:w<K>@<W>` — worker `K` stops publishing heartbeats from
//!   local wave `W` while its env threads keep running (a wedged-but-
//!   alive worker, detectable only via heartbeat expiry);
//! * `drop:<N>`         — the `N`th frame sent on a faulted
//!   [`crate::orchestrator::transport::RemoteTransport`] fails with a
//!   synthetic I/O error (forces the reconnect path);
//! * `delay:<N>:<MS>`   — the `N`th frame send sleeps `MS` milliseconds
//!   first (straggler injection).
//!
//! A directive fires only in the process's first incarnation
//! (`--generation 0`) unless suffixed with `*` (`kill:w0@0*`), which is
//! how the degradation tests burn an entire respawn budget.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

/// One parsed directive plus its generation gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Directive {
    pub fault: Fault,
    /// `true` (the `*` suffix): fire in every incarnation of the target
    /// worker; `false`: only at `--generation 0`.
    pub all_generations: bool,
}

/// The injectable fault kinds (see module docs for the grammar).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    Kill { worker: usize, wave: u64 },
    KillPut { worker: usize, put: u64 },
    HbStall { worker: usize, wave: u64 },
    Drop { frame: u64 },
    Delay { frame: u64, ms: u64 },
}

/// A deterministic fault-injection plan.  Parsed once at config
/// validation (so a malformed plan is a load-time error) and again by
/// whichever component executes each directive.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub directives: Vec<Directive>,
}

impl FaultPlan {
    /// Parse a plan string; `""` is the empty plan.
    pub fn parse(plan: &str) -> Result<FaultPlan> {
        let mut directives = Vec::new();
        for raw in plan.split(';') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            let (body, all_generations) = match raw.strip_suffix('*') {
                Some(b) => (b, true),
                None => (raw, false),
            };
            let (kind, rest) = match body.split_once(':') {
                Some(kv) => kv,
                None => bail!("fault directive {raw:?}: expected <kind>:<args>"),
            };
            let fault = match kind {
                "kill" => {
                    let (worker, wave) = parse_target(rest)?;
                    Fault::Kill { worker, wave }
                }
                "killput" => {
                    let (worker, put) = parse_target(rest)?;
                    Fault::KillPut { worker, put }
                }
                "hbstall" => {
                    let (worker, wave) = parse_target(rest)?;
                    Fault::HbStall { worker, wave }
                }
                "drop" => Fault::Drop {
                    frame: parse_u64(rest, "drop frame")?,
                },
                "delay" => match rest.split_once(':') {
                    Some((n, ms)) => Fault::Delay {
                        frame: parse_u64(n, "delay frame")?,
                        ms: parse_u64(ms, "delay ms")?,
                    },
                    None => bail!("delay directive {raw:?}: expected delay:<N>:<MS>"),
                },
                other => bail!(
                    "unknown fault kind {other:?} in {raw:?} \
                     (expected kill | killput | hbstall | drop | delay)"
                ),
            };
            directives.push(Directive {
                fault,
                all_generations,
            });
        }
        Ok(FaultPlan { directives })
    }

    /// The runtime plan: `RELEXI_FAULT_PLAN` overrides the config string
    /// (the env var is how chaos tests reach worker processes spawned by
    /// code they don't construct).
    pub fn from_env_or(config_plan: &str) -> Result<FaultPlan> {
        match std::env::var("RELEXI_FAULT_PLAN") {
            Ok(p) => FaultPlan::parse(&p),
            Err(_) => FaultPlan::parse(config_plan),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.directives.is_empty()
    }

    fn fires(&self, d: &Directive, generation: u32) -> bool {
        d.all_generations || generation == 0
    }

    /// Local wave at which `worker` should exit instead of beginning
    /// work, if any.
    pub fn kill_wave(&self, worker: usize, generation: u32) -> Option<u64> {
        self.directives.iter().find_map(|d| match d.fault {
            Fault::Kill { worker: w, wave } if w == worker && self.fires(d, generation) => {
                Some(wave)
            }
            _ => None,
        })
    }

    /// Transport-put count after which `worker` should abort, if any.
    pub fn killput_threshold(&self, worker: usize, generation: u32) -> Option<u64> {
        self.directives.iter().find_map(|d| match d.fault {
            Fault::KillPut { worker: w, put } if w == worker && self.fires(d, generation) => {
                Some(put)
            }
            _ => None,
        })
    }

    /// Local wave from which `worker` should stop heartbeating, if any.
    pub fn hbstall_wave(&self, worker: usize, generation: u32) -> Option<u64> {
        self.directives.iter().find_map(|d| match d.fault {
            Fault::HbStall { worker: w, wave } if w == worker && self.fires(d, generation) => {
                Some(wave)
            }
            _ => None,
        })
    }

    /// Frame indices whose send should fail once (0-based count of
    /// frames sent over the faulted transport).
    pub fn drop_frames(&self) -> Vec<u64> {
        self.directives
            .iter()
            .filter_map(|d| match d.fault {
                Fault::Drop { frame } => Some(frame),
                _ => None,
            })
            .collect()
    }

    /// `(frame, delay)` pairs for straggler injection.
    pub fn delay_frames(&self) -> Vec<(u64, Duration)> {
        self.directives
            .iter()
            .filter_map(|d| match d.fault {
                Fault::Delay { frame, ms } => Some((frame, Duration::from_millis(ms))),
                _ => None,
            })
            .collect()
    }
}

fn parse_target(s: &str) -> Result<(usize, u64)> {
    let body = match s.strip_prefix('w') {
        Some(b) => b,
        None => bail!("fault target {s:?}: expected w<worker>@<n>"),
    };
    let (w, n) = match body.split_once('@') {
        Some(p) => p,
        None => bail!("fault target {s:?}: expected w<worker>@<n>"),
    };
    Ok((
        parse_u64(w, "worker index")? as usize,
        parse_u64(n, "threshold")?,
    ))
}

fn parse_u64(s: &str, what: &str) -> Result<u64> {
    match s.trim().parse::<u64>() {
        Ok(v) => Ok(v),
        Err(_) => bail!("fault plan: bad {what} {s:?}"),
    }
}

/// Per-worker heartbeat-expiry tracking.  The collector feeds it the
/// latest heartbeat counters between subscription slices; a worker whose
/// counter has not advanced within `expiry` of its last advance (or of
/// its arm time) is reported expired.  Timestamps are passed in so the
/// tests can drive synthetic clocks.
#[derive(Debug)]
pub struct HeartbeatMonitor {
    expiry: Duration,
    last: Vec<(Option<f64>, Instant)>,
}

impl HeartbeatMonitor {
    pub fn new(n_workers: usize, expiry: Duration, now: Instant) -> Self {
        HeartbeatMonitor {
            expiry,
            last: vec![(None, now); n_workers],
        }
    }

    /// Re-arm `worker`'s window (after a respawn: the fresh process gets
    /// a full expiry to produce its first beat).
    pub fn arm(&mut self, worker: usize, now: Instant) {
        self.last[worker] = (None, now);
    }

    /// Record the latest observed counter for `worker`; returns `true`
    /// when the worker's heartbeat has expired.
    pub fn observe(&mut self, worker: usize, counter: Option<f64>, now: Instant) -> bool {
        let slot = &mut self.last[worker];
        if counter.is_some() && counter != slot.0 {
            *slot = (counter, now);
        }
        now.duration_since(slot.1) > self.expiry
    }

    /// Seconds since `worker`'s counter last advanced (or was armed).
    pub fn stale_for(&self, worker: usize, now: Instant) -> f64 {
        now.duration_since(self.last[worker].1).as_secs_f64()
    }
}

/// What the supervision layer did during one collection wave; rides on
/// [`crate::coordinator::Rollouts`].  A crash-free wave is all zeros.
#[derive(Debug, Clone, Default)]
pub struct SupervisionReport {
    /// Worker respawns performed (mid-wave and between waves).
    pub respawns: usize,
    /// Global env indices whose block exhausted `[fault] max_respawns`
    /// and was dropped; their episodes are excluded from the wave.
    pub dropped_envs: Vec<usize>,
    /// Per-incident seconds from the last observed sign of life
    /// (heartbeat advance or wave start) to detection.
    pub detect_s: Vec<f64>,
    /// Per-incident seconds from detection to the replacement worker
    /// being live again (hello + replay feed complete).
    pub recover_s: Vec<f64>,
}

impl SupervisionReport {
    /// True when every env completed without intervention.
    pub fn clean(&self) -> bool {
        self.respawns == 0 && self.dropped_envs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_blank_plans_parse_to_nothing() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ;  ; ").unwrap().is_empty());
    }

    #[test]
    fn full_grammar_round_trips() {
        let p = FaultPlan::parse(
            "kill:w0@1; killput:w2@40 ;hbstall:w1@0*;drop:3;delay:5:250",
        )
        .unwrap();
        assert_eq!(p.directives.len(), 5);
        assert_eq!(p.kill_wave(0, 0), Some(1));
        assert_eq!(p.killput_threshold(2, 0), Some(40));
        assert_eq!(p.hbstall_wave(1, 0), Some(0));
        assert_eq!(p.drop_frames(), vec![3]);
        assert_eq!(p.delay_frames(), vec![(5, Duration::from_millis(250))]);
        // Untargeted workers see nothing.
        assert_eq!(p.kill_wave(1, 0), None);
        assert_eq!(p.killput_threshold(0, 0), None);
    }

    #[test]
    fn directives_gate_on_generation_unless_starred() {
        let p = FaultPlan::parse("kill:w0@0;hbstall:w1@2*").unwrap();
        // Plain directive: first incarnation only.
        assert_eq!(p.kill_wave(0, 0), Some(0));
        assert_eq!(p.kill_wave(0, 1), None);
        // Starred directive: every incarnation.
        assert_eq!(p.hbstall_wave(1, 0), Some(2));
        assert_eq!(p.hbstall_wave(1, 3), Some(2));
    }

    #[test]
    fn malformed_plans_are_rejected() {
        for bad in [
            "kill:w0",         // missing @wave
            "kill:0@1",        // missing w prefix
            "killput:w@3",     // empty worker index
            "hbstall:wx@1",    // non-numeric worker
            "drop:",           // empty frame
            "delay:3",         // missing ms
            "explode:w0@1",    // unknown kind
            "kill",            // no args at all
            "kill:w0@1 extra", // trailing junk inside a directive
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn heartbeat_monitor_expires_only_a_silent_worker() {
        let t0 = Instant::now();
        let expiry = Duration::from_millis(500);
        let mut mon = HeartbeatMonitor::new(2, expiry, t0);

        // Worker 0 beats; worker 1 never does.
        assert!(!mon.observe(0, Some(1.0), t0 + Duration::from_millis(100)));
        assert!(!mon.observe(1, None, t0 + Duration::from_millis(100)));

        // 400 ms later: worker 0's counter advanced, worker 1 still
        // silent but inside its window.
        assert!(!mon.observe(0, Some(2.0), t0 + Duration::from_millis(400)));
        assert!(!mon.observe(1, None, t0 + Duration::from_millis(400)));

        // Past the expiry from arm time: only the silent worker trips.
        assert!(!mon.observe(0, Some(3.0), t0 + Duration::from_millis(700)));
        assert!(mon.observe(1, None, t0 + Duration::from_millis(700)));

        // A stalled counter (same value repeated) also trips.
        assert!(mon.observe(0, Some(3.0), t0 + Duration::from_millis(1300)));
        assert!(mon.stale_for(0, t0 + Duration::from_millis(1300)) > 0.5);

        // Re-arming grants a fresh window.
        mon.arm(1, t0 + Duration::from_millis(1300));
        assert!(!mon.observe(1, None, t0 + Duration::from_millis(1500)));
    }

    #[test]
    fn report_default_is_clean() {
        let r = SupervisionReport::default();
        assert!(r.clean());
        let r2 = SupervisionReport {
            respawns: 1,
            ..SupervisionReport::default()
        };
        assert!(!r2.clean());
    }
}
