//! Discrete-event simulation of one Relexi training iteration on the
//! modelled cluster (DESIGN.md S10).  This is the substitute for the
//! paper's 2,048-core Hawk testbed (repro band 0 — no such machine here):
//! it composes the launcher, contention, environment and head-node cost
//! models into the synchronous iteration timeline of Algorithm 1 and
//! Figure 2, from which the scaling studies (Figs. 3–4) are regenerated.

use super::contention::ContentionModel;
use super::costmodel::{EnvCostModel, HeadCostModel};
use super::topology::Topology;
use crate::launcher::{LaunchMode, Launcher, StagingMode};
use crate::util::Rng;
use anyhow::Result;

/// Workload description for one simulated iteration.
#[derive(Debug, Clone)]
pub struct IterationParams {
    /// Total solver DOF per environment (Table 1: 13,824 / 32,768).
    pub dof: usize,
    /// Elements per environment (Table 1: 64).
    pub n_elems: usize,
    /// Bytes of one state tensor sent to the orchestrator.
    pub state_bytes: f64,
    /// Parallel environments this iteration.
    pub n_envs: usize,
    /// MPI ranks per environment.
    pub ranks_per_env: usize,
    /// RL actions per episode (paper: 50).
    pub n_actions: usize,
    /// Launch mode (MPMD vs individual mpirun).
    pub launch_mode: LaunchMode,
    /// File staging mode (RAM drive vs Lustre).
    pub staging: StagingMode,
    /// Input files per instance and total bytes (staging model).
    pub input_files: usize,
    pub input_bytes: f64,
    /// Interconnect-jitter scale at full partition (paper §6.1 attributes
    /// outliers at 2,048 cores to interconnect load fluctuations).
    pub jitter_sigma_full: f64,
    /// RNG seed for the jitter draws.
    pub seed: u64,
}

impl IterationParams {
    /// Defaults for a Table-1 case on the paper's workload shape.
    pub fn for_case(dof_per_dir: usize, n_envs: usize, ranks_per_env: usize) -> Self {
        let dof = dof_per_dir.pow(3);
        IterationParams {
            dof,
            n_elems: 64,
            state_bytes: (dof * 3 * 4) as f64,
            n_envs,
            ranks_per_env,
            n_actions: 50,
            launch_mode: LaunchMode::Mpmd,
            staging: StagingMode::RamDrive,
            input_files: 6,
            input_bytes: 2e6,
            jitter_sigma_full: 0.08,
            seed: 2022,
        }
    }
}

/// The cluster + cost-model bundle.
pub struct ClusterSim {
    pub launcher: Launcher,
    pub env_model: EnvCostModel,
    pub head_model: HeadCostModel,
    pub contention: ContentionModel,
}

/// Timing breakdown of one simulated iteration.
#[derive(Debug, Clone)]
pub struct IterationTiming {
    pub launch_s: f64,
    pub sampling_s: f64,
    /// Slowest / mean environment action time (contention + jitter).
    pub env_max_s: f64,
    pub env_mean_s: f64,
    /// Head-node serialized time per RL step.
    pub head_step_s: f64,
}

impl IterationTiming {
    /// Total measured execution time (paper: launch + run to termination).
    pub fn total_s(&self) -> f64 {
        self.launch_s + self.sampling_s
    }
}

impl ClusterSim {
    /// A simulator for a Hawk-like partition of `nodes` worker nodes.
    pub fn hawk(nodes: usize) -> ClusterSim {
        ClusterSim {
            launcher: Launcher::new(Topology::hawk(nodes)),
            env_model: EnvCostModel::default(),
            head_model: HeadCostModel::default(),
            contention: ContentionModel::default(),
        }
    }

    /// Simulate one synchronous training iteration.
    pub fn simulate(&self, p: &IterationParams) -> Result<IterationTiming> {
        let plan = self
            .launcher
            .plan(p.n_envs, p.ranks_per_env, p.launch_mode, p.staging)?;
        let launch_s = self
            .launcher
            .startup_time(&plan, p.input_files, p.input_bytes);

        // Per-env action time: die contention (from the actual placement)
        // plus a per-episode interconnect jitter factor that grows with
        // the occupied fraction of the partition.
        let total_ranks = (p.n_envs * p.ranks_per_env) as f64;
        let frac = total_ranks / self.launcher.topology.total_cores() as f64;
        let sigma = p.jitter_sigma_full * frac.sqrt();
        let mut rng = Rng::new(p.seed ^ (p.n_envs as u64) << 16 ^ p.ranks_per_env as u64);

        let mut env_times = Vec::with_capacity(p.n_envs);
        for i in 0..p.n_envs {
            let occ = plan.placement.max_die_occupancy_of_instance(i);
            let slow = self.contention.slowdown(occ);
            let jitter = (sigma * rng.normal()).exp();
            env_times.push(self.env_model.action_time(p.dof, p.ranks_per_env, slow) * jitter);
        }
        let env_max_s = env_times.iter().cloned().fold(0.0, f64::max);
        let env_mean_s = env_times.iter().sum::<f64>() / env_times.len() as f64;

        let head_step_s = self
            .head_model
            .step_time(p.n_envs, p.n_elems, p.state_bytes);

        // Synchronous algorithm: every RL step waits for the slowest env,
        // then the head does its serialized work.
        let sampling_s = p.n_actions as f64 * (env_max_s + head_step_s);

        Ok(IterationTiming {
            launch_s,
            sampling_s,
            env_max_s,
            env_mean_s,
            head_step_s,
        })
    }

    /// The paper's speedup metric (§6.1): time to run `n_envs` envs
    /// sequentially over the parallel execution time.
    pub fn speedup(&self, p: &IterationParams) -> Result<f64> {
        let parallel = self.simulate(p)?;
        let mut single = p.clone();
        single.n_envs = 1;
        let t1 = self.simulate(&single)?;
        Ok(p.n_envs as f64 * t1.total_s() / parallel.total_s())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iteration_timing_composes() {
        let sim = ClusterSim::hawk(16);
        let p = IterationParams::for_case(24, 16, 8);
        let t = sim.simulate(&p).unwrap();
        assert!(t.launch_s > 0.0);
        assert!(t.sampling_s > t.launch_s, "launch should be negligible (MPMD)");
        assert!(t.env_max_s >= t.env_mean_s);
        // §6.2 ballpark: sampling ~ 15 s for 16 envs x 8 ranks at 24 DOF.
        assert!(
            (5.0..40.0).contains(&t.sampling_s),
            "sampling={:.1}s",
            t.sampling_s
        );
    }

    #[test]
    fn speedup_reasonable_and_below_ideal() {
        let sim = ClusterSim::hawk(16);
        for n_envs in [2usize, 8, 32] {
            let p = IterationParams::for_case(24, n_envs, 8);
            let s = sim.speedup(&p).unwrap();
            assert!(s > 0.5 * n_envs as f64, "n={n_envs}: speedup {s:.2} too low");
            assert!(s <= 1.05 * n_envs as f64, "n={n_envs}: speedup {s:.2} superlinear");
        }
    }

    #[test]
    fn fewer_ranks_scale_better() {
        // Paper §6.1: "runs with fewer ranks per FLEXI instance scale
        // better than the runs using more ranks" (relative efficiency).
        let sim = ClusterSim::hawk(16);
        let e = |ranks: usize, envs: usize| {
            let p = IterationParams::for_case(24, envs, ranks);
            sim.speedup(&p).unwrap() / envs as f64
        };
        assert!(e(2, 128) > e(16, 128) - 0.02);
    }

    #[test]
    fn oversubscription_is_an_error() {
        let sim = ClusterSim::hawk(16);
        let p = IterationParams::for_case(24, 1024, 16);
        assert!(sim.simulate(&p).is_err());
    }

    #[test]
    fn jitter_deterministic_per_seed() {
        let sim = ClusterSim::hawk(16);
        let p = IterationParams::for_case(24, 64, 4);
        let a = sim.simulate(&p).unwrap();
        let b = sim.simulate(&p).unwrap();
        assert_eq!(a.env_max_s, b.env_max_s);
    }
}
