//! Scaling-study drivers: regenerate the weak-scaling (Fig. 3) and
//! strong-scaling (Fig. 4) curves of the paper on the simulated cluster.

use super::desim::{ClusterSim, IterationParams};
use anyhow::Result;

/// One point of a scaling curve.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub dof_per_dir: usize,
    pub n_envs: usize,
    pub ranks_per_env: usize,
    pub total_s: f64,
    pub speedup: f64,
    /// speedup / ideal (ideal = n_envs for weak scaling).
    pub efficiency: f64,
}

/// Weak scaling (Fig. 3): double the environments at fixed ranks/env until
/// the partition is full; speedup vs running them sequentially.
pub fn weak_scaling(
    sim: &ClusterSim,
    dof_per_dir: usize,
    ranks_per_env: usize,
    steps_per_action: f64,
) -> Result<Vec<ScalingPoint>> {
    let total_cores = sim.launcher.topology.total_cores();
    let max_envs = total_cores / ranks_per_env;
    let mut points = Vec::new();
    let mut n_envs = 2usize;
    while n_envs <= max_envs {
        let mut p = IterationParams::for_case(dof_per_dir, n_envs, ranks_per_env);
        let mut sim_local = clone_with_steps(sim, steps_per_action);
        let t = sim_local.simulate(&p)?;
        p.n_envs = n_envs;
        let speedup = sim_local.speedup(&p)?;
        points.push(ScalingPoint {
            dof_per_dir,
            n_envs,
            ranks_per_env,
            total_s: t.total_s(),
            speedup,
            efficiency: speedup / n_envs as f64,
        });
        n_envs *= 2;
        let _ = &mut sim_local;
    }
    Ok(points)
}

/// Strong scaling (Fig. 4): fixed environment count, increasing ranks/env;
/// speedup relative to the 2-rank baseline (ideal line = ranks).
pub fn strong_scaling(
    sim: &ClusterSim,
    dof_per_dir: usize,
    n_envs: usize,
    ranks_list: &[usize],
    steps_per_action: f64,
) -> Result<Vec<ScalingPoint>> {
    let sim_local = clone_with_steps(sim, steps_per_action);
    let base_ranks = ranks_list[0];
    let base = sim_local
        .simulate(&IterationParams::for_case(dof_per_dir, n_envs, base_ranks))?
        .total_s();
    let mut points = Vec::new();
    for &ranks in ranks_list {
        if n_envs * ranks > sim.launcher.topology.total_cores() {
            continue;
        }
        let t = sim_local
            .simulate(&IterationParams::for_case(dof_per_dir, n_envs, ranks))?
            .total_s();
        let speedup = base_ranks as f64 * base / t;
        points.push(ScalingPoint {
            dof_per_dir,
            n_envs,
            ranks_per_env: ranks,
            total_s: t,
            speedup,
            efficiency: speedup / ranks as f64,
        });
    }
    Ok(points)
}

fn clone_with_steps(sim: &ClusterSim, steps_per_action: f64) -> ClusterSim {
    let mut env_model = sim.env_model.clone();
    env_model.steps_per_action = steps_per_action;
    ClusterSim {
        launcher: crate::launcher::Launcher::new(sim.launcher.topology.clone()),
        env_model,
        head_model: sim.head_model.clone(),
        contention: sim.contention.clone(),
    }
}

/// Solver steps per RL action for a Table-1 case (CFL: dt ~ dx, so the
/// 32-DOF case needs ~4/3 more steps than the 24-DOF case).
pub fn steps_per_action_for(dof_per_dir: usize) -> f64 {
    3.0 * dof_per_dir as f64 / 24.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weak_scaling_shape_matches_fig3() {
        let sim = ClusterSim::hawk(16);
        let pts = weak_scaling(&sim, 24, 2, 3.0).unwrap();
        // Doubling from 2 envs to the full partition (1024 at 2 ranks).
        assert_eq!(pts.last().unwrap().n_envs, 1024);
        // Efficiency at moderate counts stays high...
        let p32 = pts.iter().find(|p| p.n_envs == 32).unwrap();
        assert!(p32.efficiency > 0.6, "eff(32)={:.2}", p32.efficiency);
        // ...and decreases toward the full partition.
        let last = pts.last().unwrap();
        assert!(
            last.efficiency < p32.efficiency,
            "eff should decay: {:.2} -> {:.2}",
            p32.efficiency,
            last.efficiency
        );
    }

    #[test]
    fn fewer_ranks_per_env_scale_better_at_high_counts() {
        let sim = ClusterSim::hawk(16);
        let e2 = weak_scaling(&sim, 24, 2, 3.0).unwrap();
        let e16 = weak_scaling(&sim, 24, 16, 3.0).unwrap();
        let eff_at = |pts: &[ScalingPoint], n: usize| {
            pts.iter().find(|p| p.n_envs == n).unwrap().efficiency
        };
        // At 128 envs both exist; 2-rank envs (longer per-env sim time)
        // hide the head-node serialization better.
        assert!(eff_at(&e2, 128) > eff_at(&e16, 128));
    }

    #[test]
    fn strong_scaling_saturates_at_16_ranks() {
        let sim = ClusterSim::hawk(16);
        let pts = strong_scaling(&sim, 24, 8, &[2, 4, 8, 16], 3.0).unwrap();
        assert_eq!(pts.len(), 4);
        // Speedup grows with ranks but falls below ideal at 16.
        assert!(pts[1].speedup > pts[0].speedup);
        assert!(pts[3].speedup > pts[2].speedup * 0.9);
        let p16 = &pts[3];
        assert!(
            p16.efficiency < 0.75,
            "16-rank efficiency {:.2} should be clearly sub-ideal",
            p16.efficiency
        );
        // 2-rank baseline is ideal by construction.
        assert!((pts[0].speedup - 2.0).abs() < 1e-9);
    }

    #[test]
    fn dof32_tracks_the_same_trends() {
        let sim = ClusterSim::hawk(16);
        let pts = weak_scaling(&sim, 32, 8, 4.0).unwrap();
        assert_eq!(pts.last().unwrap().n_envs, 256);
        assert!(pts.iter().all(|p| p.speedup > 0.0));
    }
}
