//! Cluster topology model: HLRS Hawk worker nodes (paper §4.1).
//!
//! A node = 2 x 64-core AMD EPYC 7742; each EPYC is built from 8-core dies
//! (CCDs) whose cores share memory bandwidth — the micro-architectural fact
//! behind the paper's counterintuitive 1->2-environment slowdown (§6.1,
//! footnote 5).  Core ids are flat per node: die = core / cores_per_die.

/// Static description of the worker partition.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    /// Worker nodes available to the launcher (paper benchmarks: 16).
    pub nodes: usize,
    /// Cores per node (Hawk: 128).
    pub cores_per_node: usize,
    /// Cores per die sharing memory bandwidth (EPYC Rome: 8).
    pub cores_per_die: usize,
}

impl Topology {
    /// Hawk worker partition as used in the paper's benchmarks.
    pub fn hawk(nodes: usize) -> Topology {
        Topology {
            nodes,
            cores_per_node: 128,
            cores_per_die: 8,
        }
    }

    /// Total cores across the partition.
    pub fn total_cores(&self) -> usize {
        self.nodes * self.cores_per_node
    }

    /// Dies per node.
    pub fn dies_per_node(&self) -> usize {
        self.cores_per_node / self.cores_per_die
    }

    /// Global die id for (node, core).
    pub fn die_of(&self, node: usize, core: usize) -> usize {
        node * self.dies_per_node() + core / self.cores_per_die
    }

    /// Total dies across the partition.
    pub fn total_dies(&self) -> usize {
        self.nodes * self.dies_per_node()
    }
}

/// One MPI rank pinned to one core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RankPin {
    /// Environment-instance id.
    pub instance: usize,
    /// Rank within the instance.
    pub rank: usize,
    /// Node id.
    pub node: usize,
    /// Core id within the node.
    pub core: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hawk_node_shape() {
        let t = Topology::hawk(16);
        assert_eq!(t.total_cores(), 2048); // the paper's max worker cores
        assert_eq!(t.dies_per_node(), 16);
        assert_eq!(t.total_dies(), 256);
    }

    #[test]
    fn die_mapping() {
        let t = Topology::hawk(2);
        assert_eq!(t.die_of(0, 0), 0);
        assert_eq!(t.die_of(0, 7), 0);
        assert_eq!(t.die_of(0, 8), 1);
        assert_eq!(t.die_of(0, 127), 15);
        assert_eq!(t.die_of(1, 0), 16);
    }
}
