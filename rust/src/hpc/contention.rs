//! Memory-bandwidth contention on shared dies.
//!
//! Paper §6.1, footnote 5: "The used EPYC CPUs comprise several dies, which
//! contain 8 cores each.  All cores on a single die share the available
//! memory bandwidth."  A memory-bound CFD kernel saturates a die's
//! bandwidth with a few active cores; beyond that, per-core throughput
//! falls proportionally.

/// Die-bandwidth contention model.
#[derive(Debug, Clone)]
pub struct ContentionModel {
    /// How many fully-active cores a die's bandwidth can feed at full
    /// speed (EPYC Rome CCD with a memory-bound spectral/DG kernel: ~3).
    pub bw_cores: f64,
    /// Sub-linear exponent: a DG/spectral kernel is only partly
    /// bandwidth-bound (L3-resident working sets soften the contention).
    pub exponent: f64,
}

impl Default for ContentionModel {
    fn default() -> Self {
        ContentionModel {
            bw_cores: 3.0,
            exponent: 0.3,
        }
    }
}

impl ContentionModel {
    /// Multiplicative slowdown for a rank on a die with `active` busy
    /// cores: 1.0 while the die's bandwidth covers them, then
    /// `(active/bw_cores)^exponent`.
    pub fn slowdown(&self, active: usize) -> f64 {
        (active as f64 / self.bw_cores).powf(self.exponent).max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn light_occupancy_full_speed() {
        let m = ContentionModel::default();
        assert_eq!(m.slowdown(1), 1.0);
        assert_eq!(m.slowdown(2), 1.0);
        assert_eq!(m.slowdown(3), 1.0);
    }

    #[test]
    fn saturated_die_slows_down() {
        let m = ContentionModel::default();
        // Mild at 4 active cores, clearly visible at 8 (full die).
        assert!(m.slowdown(4) > 1.05 && m.slowdown(4) < 1.2);
        assert!(m.slowdown(8) > 1.25 && m.slowdown(8) < 1.5);
    }

    #[test]
    fn monotone() {
        let m = ContentionModel::default();
        for a in 1..8 {
            assert!(m.slowdown(a + 1) >= m.slowdown(a));
        }
    }

    #[test]
    fn reproduces_the_paper_dip_structure() {
        // Two 2-rank envs packed on one die (occupancy 4) run slower than
        // one alone (occupancy 2): the paper's 1->2 env dip...
        let m = ContentionModel::default();
        assert!(m.slowdown(4) > m.slowdown(2));
        // ...while a 16-rank env already fills its dies (occupancy 8)
        // whether or not a neighbour instance exists: no dip.
        assert_eq!(m.slowdown(8), m.slowdown(8));
    }
}
