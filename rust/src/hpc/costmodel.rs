//! Cost models for the discrete-event scaling simulator (DESIGN.md S10).
//!
//! Two halves, matching the paper's decomposition of an iteration:
//!
//! * [`EnvCostModel`] — one FLEXI-like environment advancing one RL action
//!   interval on `R` ranks: volume work (parallel, bandwidth-sensitive),
//!   surface/halo communication (the DG face-flux exchange), and per-step
//!   latency.  Strong-scaling saturation emerges from the surface and
//!   latency terms once the per-rank load drops — §6.1's "optimal load per
//!   core".
//! * [`HeadCostModel`] — the serialized head-node work per RL step:
//!   policy inference (batched, cheap per element), per-env data
//!   management in the coordinator (the paper's "sequential work done by
//!   Relexi"), and orchestrator transfer time.
//!
//! Defaults are calibrated so the 24-DOF / 8-rank / 16-env configuration
//! reproduces the paper's §6.2 wall-clock scale (~15 s sampling per
//! iteration, 50 actions); `calibrate_to_solver` re-fits the volume-work
//! constant to the real Rust solver for self-consistent experiments.

use crate::solver::Solver;

/// Per-environment simulation cost.
#[derive(Debug, Clone)]
pub struct EnvCostModel {
    /// Seconds of volume work per DOF per solver step on one core.
    pub work_per_dof_step_s: f64,
    /// Seconds per surface DOF per step (halo exchange + face fluxes).
    pub comm_per_dof_step_s: f64,
    /// Fixed latency per solver step per rank-pair level (collectives).
    pub latency_per_step_s: f64,
    /// Solver steps per RL action interval (dt_RL / dt).
    pub steps_per_action: f64,
}

impl Default for EnvCostModel {
    fn default() -> Self {
        // Fitted to paper §6.2: 24 DOF (13,824 DOF), 8 ranks, 50 actions
        // ~= 15-20 s per iteration, with strong-scaling saturation at
        // 16 ranks ("quite below the optimal load per core", §6.1).
        EnvCostModel {
            work_per_dof_step_s: 4.5e-5,
            comm_per_dof_step_s: 2.5e-4,
            latency_per_step_s: 5.0e-3,
            steps_per_action: 3.0,
        }
    }
}

impl EnvCostModel {
    /// Seconds for one environment to advance one RL action interval on
    /// `ranks` ranks, with the bandwidth `slowdown` factor of its most
    /// contended die (the synchronous solver runs at the slowest rank).
    pub fn action_time(&self, dof: usize, ranks: usize, slowdown: f64) -> f64 {
        let load = dof as f64 / ranks as f64;
        let volume = self.work_per_dof_step_s * load * slowdown;
        // Surface of a cubic per-rank partition ~ load^(2/3).
        let surface = if ranks > 1 {
            self.comm_per_dof_step_s * load.powf(2.0 / 3.0)
        } else {
            0.0
        };
        let latency = self.latency_per_step_s * (ranks as f64).ln_1p();
        self.steps_per_action * (volume + surface + latency)
    }

    /// Re-fit the volume-work constant by timing the real Rust solver for
    /// one action interval at resolution `n` (self-consistent DES inputs).
    pub fn calibrate_to_solver(&mut self, n: usize, dt_rl: f64) {
        let mut s = Solver::new(n, 1, 1.0 / 400.0, 0.5);
        let mut rng = crate::util::Rng::new(1);
        s.set_state(crate::solver::init::random_solenoidal(&s.grid, 1.5, 4.0, &mut rng));
        s.forcing = Some(crate::solver::forcing::LinearForcing::new(1.5, 1.0));
        // Warm up one short interval, then measure.
        s.advance(dt_rl * 0.2);
        let t0 = std::time::Instant::now();
        let steps = s.advance(dt_rl);
        let elapsed = t0.elapsed().as_secs_f64();
        let dof = n * n * n;
        self.steps_per_action = steps as f64;
        self.work_per_dof_step_s = elapsed / (steps as f64 * dof as f64);
    }
}

/// Head-node (Relexi + orchestrator) cost per RL step.
#[derive(Debug, Clone)]
pub struct HeadCostModel {
    /// Per-inference-call overhead (graph dispatch on the head GPU).
    pub policy_base_s: f64,
    /// Per-element policy inference cost (batched).
    pub policy_per_elem_s: f64,
    /// Serialized coordinator bookkeeping per environment per step
    /// (the paper's "sequential work done by Relexi").
    pub seq_per_env_s: f64,
    /// Orchestrator sustained throughput (bytes/s) per shard.
    pub db_bw_per_shard: f64,
    /// Orchestrator shards (1 = single-threaded Redis).
    pub db_shards: usize,
}

impl Default for HeadCostModel {
    fn default() -> Self {
        HeadCostModel {
            policy_base_s: 2.0e-3,
            policy_per_elem_s: 1.5e-6,
            seq_per_env_s: 1.0e-3,
            db_bw_per_shard: 2.0e9,
            db_shards: 8,
        }
    }
}

impl HeadCostModel {
    /// Seconds of head-node work per synchronous RL step with `n_envs`
    /// environments of `n_elems` elements and `state_bytes` per state.
    pub fn step_time(&self, n_envs: usize, n_elems: usize, state_bytes: f64) -> f64 {
        let inference =
            self.policy_base_s + self.policy_per_elem_s * (n_envs * n_elems) as f64;
        let seq = self.seq_per_env_s * n_envs as f64;
        // State in + action out per env; shards serve envs concurrently.
        let bytes = n_envs as f64 * (state_bytes + n_elems as f64 * 4.0);
        let effective_shards = self.db_shards.min(n_envs).max(1) as f64;
        let db = bytes / (self.db_bw_per_shard * effective_shards);
        inference + seq + db
    }

    /// Largest env count one worker process can host while its serialized
    /// per-wave head work ([`HeadCostModel::step_time`]) stays within
    /// `budget_s` — the envs-per-process knob of the launcher's
    /// process-placement plan (`launcher::plan_worker_processes`).
    /// Always at least 1 (a single env may legitimately blow the budget).
    pub fn envs_per_process_for(
        &self,
        n_elems: usize,
        state_bytes: f64,
        budget_s: f64,
    ) -> usize {
        let mut n = 1usize;
        while n < 4096 && self.step_time(n + 1, n_elems, state_bytes) <= budget_s {
            n += 1;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_wallclock_scale() {
        // 24 DOF, 8 ranks, no contention: ~0.3 s per action => 50 actions
        // ~ 15 s (paper §6.2 sampling time).
        let m = EnvCostModel::default();
        let t = m.action_time(13_824, 8, 1.0);
        let episode = 50.0 * t;
        assert!(
            (10.0..25.0).contains(&episode),
            "episode time {episode:.1}s out of the paper's ballpark"
        );
    }

    #[test]
    fn more_ranks_faster_but_saturating() {
        let m = EnvCostModel::default();
        let t2 = m.action_time(13_824, 2, 1.0);
        let t8 = m.action_time(13_824, 8, 1.0);
        let t16 = m.action_time(13_824, 16, 1.0);
        assert!(t8 < t2 && t16 < t8);
        // Efficiency must degrade: speedup(16 vs 2) well below 8x.
        let speedup = t2 / t16;
        assert!(speedup < 6.5, "speedup={speedup:.2} too ideal");
        assert!(speedup > 2.0, "speedup={speedup:.2} too pessimistic");
    }

    #[test]
    fn contention_slows_volume_work() {
        let m = EnvCostModel::default();
        assert!(m.action_time(13_824, 2, 2.0) > 1.5 * m.action_time(13_824, 2, 1.0) * 0.9);
    }

    #[test]
    fn head_cost_grows_linearly_with_envs() {
        let h = HeadCostModel::default();
        let t16 = h.step_time(16, 64, 220e3);
        let t64 = h.step_time(64, 64, 220e3);
        assert!(t64 > 2.5 * t16, "t16={t16} t64={t64}");
    }

    #[test]
    fn envs_per_process_scales_with_the_budget() {
        let h = HeadCostModel::default();
        let tight = h.envs_per_process_for(8, 384.0, 0.004);
        let loose = h.envs_per_process_for(8, 384.0, 0.05);
        assert!(tight >= 1);
        assert!(loose > tight, "tight={tight} loose={loose}");
        // An impossible budget still yields a runnable plan.
        assert_eq!(h.envs_per_process_for(8, 384.0, 0.0), 1);
    }

    #[test]
    fn single_shard_db_is_slower_at_scale() {
        let redis = HeadCostModel { db_shards: 1, ..Default::default() };
        let keydb = HeadCostModel { db_shards: 8, ..Default::default() };
        assert!(redis.step_time(512, 64, 220e3) > keydb.step_time(512, 64, 220e3));
    }

    #[test]
    #[ignore] // timing-dependent; run explicitly: cargo test -- --ignored
    fn calibration_produces_sane_constants() {
        let mut m = EnvCostModel::default();
        m.calibrate_to_solver(12, 0.05);
        assert!(m.work_per_dof_step_s > 1e-10 && m.work_per_dof_step_s < 1e-3);
        assert!(m.steps_per_action >= 1.0);
    }
}
