//! The HPC-system substrate (DESIGN.md S10): a model of the paper's Hawk
//! testbed — node/die topology, memory-bandwidth contention, launch and
//! head-node cost models — and a discrete-event simulator that regenerates
//! the weak/strong scaling studies (Figs. 3–4) without the 2,048-core
//! machine.

pub mod contention;
pub mod costmodel;
pub mod desim;
pub mod scaling;
pub mod topology;

pub use contention::ContentionModel;
pub use costmodel::{EnvCostModel, HeadCostModel};
pub use desim::{ClusterSim, IterationParams, IterationTiming};
pub use scaling::{steps_per_action_for, strong_scaling, weak_scaling, ScalingPoint};
pub use topology::Topology;
