//! Typed run configuration: Table-1 case presets, solver, RL, and HPC
//! sections, loadable from a TOML-subset file with CLI overlays.

pub mod presets;
pub mod toml;

use anyhow::Result;
use toml::Toml;

/// One LES case from Table 1 of the paper.
#[derive(Debug, Clone, PartialEq)]
pub struct CaseConfig {
    /// Case name, e.g. "24dof".
    pub name: String,
    /// Polynomial degree N; an element has (N+1)^3 solution points.
    pub n: usize,
    /// Elements per spatial direction (paper: 4).
    pub elems_per_dir: usize,
    /// Maximum wavenumber entering the reward, Eq. (4).
    pub k_max: usize,
    /// Reward scaling factor alpha, Eq. (5).
    pub alpha: f64,
}

impl CaseConfig {
    /// Solution points per spatial direction = #elems * (N+1).
    pub fn points_per_dir(&self) -> usize {
        self.elems_per_dir * (self.n + 1)
    }

    /// Total number of degrees of freedom (#DOF column of Table 1).
    pub fn total_dof(&self) -> usize {
        self.points_per_dir().pow(3)
    }

    /// Total number of elements.
    pub fn total_elems(&self) -> usize {
        self.elems_per_dir.pow(3)
    }

    /// Points per element and direction (= N + 1).
    pub fn elem_points(&self) -> usize {
        self.n + 1
    }
}

/// Flow-solver parameters (the FLEXI-substitute; DESIGN.md §2).
#[derive(Debug, Clone)]
pub struct SolverConfig {
    /// Molecular viscosity.
    pub nu: f64,
    /// CFL number for the adaptive timestep.
    pub cfl: f64,
    /// Target turbulent kinetic energy maintained by the linear forcing.
    pub ke_target: f64,
    /// Forcing-controller relaxation time.
    pub forcing_tau: f64,
    /// Physical time between RL actions (paper: 0.1).
    pub dt_rl: f64,
    /// Episode end time (paper: 5.0).
    pub t_end: f64,
    /// DNS resolution (points per direction) for ground-truth generation.
    pub dns_points: usize,
    /// Fixed Smagorinsky constant for the baseline model.
    pub smagorinsky_cs: f64,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            // Chosen so the 48^3 dealiased DNS is resolved (k_max*eta ~ 1
            // at the forced equilibrium eps = 0.75): Re_lambda ~ 30.  The
            // paper's Re_lambda ~ 200 would need a >=512^3 DNS
            // (substitution documented in DESIGN.md / EXPERIMENTS.md).
            nu: 1.0 / 45.0,
            cfl: 0.5,
            ke_target: 1.5, // u_rms ~ 1
            forcing_tau: 1.0,
            dt_rl: 0.1,
            t_end: 5.0,
            dns_points: 48,
            smagorinsky_cs: 0.17,
        }
    }
}

/// PPO / training-loop parameters (paper §5.3).
#[derive(Debug, Clone)]
pub struct RlConfig {
    /// Discount factor (paper: 0.995).
    pub gamma: f64,
    /// Parallel environments per training iteration.
    pub n_envs: usize,
    /// Training iterations.
    pub iterations: usize,
    /// Optimization epochs per iteration (paper: 5).
    pub epochs: usize,
    /// Minibatch size fed to the train_step artifact.
    pub minibatch: usize,
    /// Evaluate on the held-out test state every this many iterations.
    pub eval_every: usize,
    /// Master seed.
    pub seed: u64,
    /// GAE lambda (1.0 = plain discounted returns, as in the paper).
    pub gae_lambda: f64,
}

impl Default for RlConfig {
    fn default() -> Self {
        RlConfig {
            gamma: 0.995,
            n_envs: 16,
            iterations: 100,
            epochs: 5,
            minibatch: 256,
            eval_every: 10,
            seed: 2022,
            gae_lambda: 1.0,
        }
    }
}

/// Cluster model + orchestrator parameters (Hawk / Hawk-AI, §4).
#[derive(Debug, Clone)]
pub struct HpcConfig {
    /// Worker nodes available (paper benchmarks: 16).
    pub worker_nodes: usize,
    /// Cores per node (Hawk: 2 x 64-core EPYC 7742).
    pub cores_per_node: usize,
    /// Cores per die sharing memory bandwidth (EPYC: 8).
    pub cores_per_die: usize,
    /// MPI ranks per environment instance.
    pub ranks_per_env: usize,
    /// Orchestrator shards (1 = single-threaded Redis-like).
    pub db_shards: usize,
    /// Use MPMD batched launch (paper §3.3 improvement).
    pub mpmd: bool,
    /// Stage files to RAM drive instead of the parallel FS (§3.3).
    pub ram_staging: bool,
}

impl Default for HpcConfig {
    fn default() -> Self {
        HpcConfig {
            worker_nodes: 16,
            cores_per_node: 128,
            cores_per_die: 8,
            ranks_per_env: 8,
            db_shards: 8,
            mpmd: true,
            ram_staging: true,
        }
    }
}

/// Complete run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub case: CaseConfig,
    pub solver: SolverConfig,
    pub rl: RlConfig,
    pub hpc: HpcConfig,
    /// Directory with AOT artifacts.
    pub artifacts_dir: String,
    /// Output directory for metrics/checkpoints.
    pub out_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            case: presets::dof24(),
            solver: SolverConfig::default(),
            rl: RlConfig::default(),
            hpc: HpcConfig::default(),
            artifacts_dir: "artifacts".to_string(),
            out_dir: "runs/out".to_string(),
        }
    }
}

impl RunConfig {
    /// Build from a parsed TOML document (missing keys keep defaults).
    pub fn from_toml(t: &Toml) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(v) = t.get("case.preset") {
            cfg.case = presets::by_name(v.as_str()?)?;
        }
        if let Some(v) = t.get("case.n") {
            cfg.case.n = v.as_int()? as usize;
        }
        if let Some(v) = t.get("case.elems_per_dir") {
            cfg.case.elems_per_dir = v.as_int()? as usize;
        }
        if let Some(v) = t.get("case.k_max") {
            cfg.case.k_max = v.as_int()? as usize;
        }
        if let Some(v) = t.get("case.alpha") {
            cfg.case.alpha = v.as_float()?;
        }

        cfg.solver.nu = t.float_or("solver.nu", cfg.solver.nu)?;
        cfg.solver.cfl = t.float_or("solver.cfl", cfg.solver.cfl)?;
        cfg.solver.ke_target = t.float_or("solver.ke_target", cfg.solver.ke_target)?;
        cfg.solver.forcing_tau = t.float_or("solver.forcing_tau", cfg.solver.forcing_tau)?;
        cfg.solver.dt_rl = t.float_or("solver.dt_rl", cfg.solver.dt_rl)?;
        cfg.solver.t_end = t.float_or("solver.t_end", cfg.solver.t_end)?;
        cfg.solver.dns_points =
            t.int_or("solver.dns_points", cfg.solver.dns_points as i64)? as usize;
        cfg.solver.smagorinsky_cs =
            t.float_or("solver.smagorinsky_cs", cfg.solver.smagorinsky_cs)?;

        cfg.rl.gamma = t.float_or("rl.gamma", cfg.rl.gamma)?;
        cfg.rl.n_envs = t.int_or("rl.n_envs", cfg.rl.n_envs as i64)? as usize;
        cfg.rl.iterations = t.int_or("rl.iterations", cfg.rl.iterations as i64)? as usize;
        cfg.rl.epochs = t.int_or("rl.epochs", cfg.rl.epochs as i64)? as usize;
        cfg.rl.minibatch = t.int_or("rl.minibatch", cfg.rl.minibatch as i64)? as usize;
        cfg.rl.eval_every = t.int_or("rl.eval_every", cfg.rl.eval_every as i64)? as usize;
        cfg.rl.seed = t.int_or("rl.seed", cfg.rl.seed as i64)? as u64;
        cfg.rl.gae_lambda = t.float_or("rl.gae_lambda", cfg.rl.gae_lambda)?;

        cfg.hpc.worker_nodes =
            t.int_or("hpc.worker_nodes", cfg.hpc.worker_nodes as i64)? as usize;
        cfg.hpc.cores_per_node =
            t.int_or("hpc.cores_per_node", cfg.hpc.cores_per_node as i64)? as usize;
        cfg.hpc.cores_per_die =
            t.int_or("hpc.cores_per_die", cfg.hpc.cores_per_die as i64)? as usize;
        cfg.hpc.ranks_per_env =
            t.int_or("hpc.ranks_per_env", cfg.hpc.ranks_per_env as i64)? as usize;
        cfg.hpc.db_shards = t.int_or("hpc.db_shards", cfg.hpc.db_shards as i64)? as usize;
        cfg.hpc.mpmd = t.bool_or("hpc.mpmd", cfg.hpc.mpmd)?;
        cfg.hpc.ram_staging = t.bool_or("hpc.ram_staging", cfg.hpc.ram_staging)?;

        cfg.artifacts_dir = t.str_or("paths.artifacts", &cfg.artifacts_dir)?;
        cfg.out_dir = t.str_or("paths.out", &cfg.out_dir)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from file + CLI `--key value` overlays (dotted keys).
    pub fn load(
        path: Option<&str>,
        overrides: impl Iterator<Item = (String, String)>,
    ) -> Result<RunConfig> {
        let mut doc = match path {
            Some(p) => Toml::load(std::path::Path::new(p))?,
            None => Toml::default(),
        };
        for (k, v) in overrides {
            if k.contains('.') {
                doc.set_raw(&k, &v)?;
            }
        }
        RunConfig::from_toml(&doc)
    }

    /// Sanity checks that would otherwise surface as weird failures deep
    /// inside the solver or the runtime.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.case.n == 5 || self.case.n == 7,
            "policy artifacts exist for N in {{5, 7}}, got N={}",
            self.case.n
        );
        anyhow::ensure!(self.case.elems_per_dir >= 1, "need at least one element");
        anyhow::ensure!(
            self.case.k_max <= self.case.points_per_dir() / 2,
            "k_max {} beyond Nyquist {}",
            self.case.k_max,
            self.case.points_per_dir() / 2
        );
        anyhow::ensure!(self.solver.dt_rl > 0.0 && self.solver.t_end > 0.0);
        anyhow::ensure!(self.rl.n_envs >= 1 && self.rl.minibatch >= 1);
        anyhow::ensure!(
            self.hpc.cores_per_node % self.hpc.cores_per_die == 0,
            "cores_per_node must be a multiple of cores_per_die"
        );
        Ok(())
    }

    /// Actions per episode = t_end / dt_rl (paper: 50).
    pub fn steps_per_episode(&self) -> usize {
        (self.solver.t_end / self.solver.dt_rl).round() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid_table1_24dof() {
        let c = RunConfig::default();
        c.validate().unwrap();
        assert_eq!(c.case.total_dof(), 13_824);
        assert_eq!(c.steps_per_episode(), 50);
    }

    #[test]
    fn from_toml_overrides() {
        let doc = Toml::parse(
            "[case]\npreset = \"32dof\"\n[rl]\nn_envs = 64\n[solver]\nt_end = 2.0\n",
        )
        .unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.case.n, 7);
        assert_eq!(c.rl.n_envs, 64);
        assert_eq!(c.steps_per_episode(), 20);
    }

    #[test]
    fn invalid_kmax_rejected() {
        let doc = Toml::parse("[case]\nk_max = 100\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn invalid_n_rejected() {
        let doc = Toml::parse("[case]\nn = 6\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
    }
}
