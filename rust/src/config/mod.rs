//! Typed run configuration: Table-1 case presets, solver, RL,
//! policy/trainer runtime, and HPC sections, loadable from a TOML-subset
//! file with CLI overlays (see `examples/config.toml` for a documented
//! reference of every section).

pub mod presets;
pub mod toml;

use anyhow::{Context, Result};
use toml::Toml;

/// One LES case from Table 1 of the paper.
#[derive(Debug, Clone, PartialEq)]
pub struct CaseConfig {
    /// Case name, e.g. "24dof".
    pub name: String,
    /// Polynomial degree N; an element has (N+1)^3 solution points.
    pub n: usize,
    /// Elements per spatial direction (paper: 4).
    pub elems_per_dir: usize,
    /// Maximum wavenumber entering the reward, Eq. (4).
    pub k_max: usize,
    /// Reward scaling factor alpha, Eq. (5).
    pub alpha: f64,
}

impl CaseConfig {
    /// Solution points per spatial direction = #elems * (N+1).
    pub fn points_per_dir(&self) -> usize {
        self.elems_per_dir * (self.n + 1)
    }

    /// Total number of degrees of freedom (#DOF column of Table 1).
    pub fn total_dof(&self) -> usize {
        self.points_per_dir().pow(3)
    }

    /// Total number of elements.
    pub fn total_elems(&self) -> usize {
        self.elems_per_dir.pow(3)
    }

    /// Points per element and direction (= N + 1).
    pub fn elem_points(&self) -> usize {
        self.n + 1
    }

    /// Element-local observation width: `(N+1)^3` solution points times 3
    /// velocity components — what `LesEnv::obs_len` produces per agent
    /// and what an LES-shaped policy (compiled artifact or native MLP)
    /// must be sized for.
    pub fn elem_features(&self) -> usize {
        self.elem_points().pow(3) * 3
    }
}

/// Flow-solver parameters (the FLEXI-substitute; DESIGN.md §2).
#[derive(Debug, Clone)]
pub struct SolverConfig {
    /// Molecular viscosity.
    pub nu: f64,
    /// CFL number for the adaptive timestep.
    pub cfl: f64,
    /// Target turbulent kinetic energy maintained by the linear forcing.
    pub ke_target: f64,
    /// Forcing-controller relaxation time.
    pub forcing_tau: f64,
    /// Physical time between RL actions (paper: 0.1).
    pub dt_rl: f64,
    /// Episode end time (paper: 5.0).
    pub t_end: f64,
    /// DNS resolution (points per direction) for ground-truth generation.
    pub dns_points: usize,
    /// Fixed Smagorinsky constant for the baseline model.
    pub smagorinsky_cs: f64,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            // Chosen so the 48^3 dealiased DNS is resolved (k_max*eta ~ 1
            // at the forced equilibrium eps = 0.75): Re_lambda ~ 30.  The
            // paper's Re_lambda ~ 200 would need a >=512^3 DNS
            // (substitution documented in DESIGN.md / EXPERIMENTS.md).
            nu: 1.0 / 45.0,
            cfl: 0.5,
            ke_target: 1.5, // u_rms ~ 1
            forcing_tau: 1.0,
            dt_rl: 0.1,
            t_end: 5.0,
            dns_points: 48,
            smagorinsky_cs: 0.17,
        }
    }
}

/// The 1D stochastic-Burgers LES scenario (`rl.backend = "burgers"`): a
/// periodic viscous Burgers flow kept quasi-stationary by linear forcing
/// plus stochastic low-wavenumber noise, coarse-grained onto `points`
/// grid points.  RL picks one Smagorinsky-like SGS coefficient per
/// spatial segment; the reward compares the coarse energy spectrum
/// against a resolved-truth mean spectrum through the same Eqs. (4)-(5)
/// shaping as the 3D HIT case.  Orders of magnitude cheaper than the
/// spectral LES, so hundreds of envs fit in a CI smoke run.
#[derive(Debug, Clone)]
pub struct BurgersConfig {
    /// Coarse (LES) grid points on `[0, 2*pi)`.
    pub points: usize,
    /// Control segments = agents (one SGS coefficient each); must divide
    /// `points`.
    pub segments: usize,
    /// Molecular viscosity.
    pub nu: f64,
    /// Target kinetic energy `mean(u^2)/2` held by the linear forcing.
    pub ke_target: f64,
    /// Relaxation time of the energy controller.
    pub forcing_tau: f64,
    /// Amplitude of the stochastic low-wavenumber forcing.
    pub noise_amp: f64,
    /// Forced wavenumbers `1..=noise_modes`.
    pub noise_modes: usize,
    /// Maximum wavenumber entering the reward, Eq. (4).
    pub k_max: usize,
    /// Reward scaling factor alpha, Eq. (5).
    pub alpha: f64,
    /// Physical time between RL actions.
    pub dt_rl: f64,
    /// Episode end time.
    pub t_end: f64,
    /// CFL number for the adaptive substeps.
    pub cfl: f64,
    /// Resolved-truth refinement: the truth runs on `truth_refine *
    /// points` grid points.
    pub truth_refine: usize,
    /// Initial-state pool size (plus one held-out test state).
    pub truth_states: usize,
    /// Truth spin-up time before sampling starts.
    pub truth_spinup: f64,
    /// Physical time between truth snapshots.
    pub truth_interval: f64,
    /// Seed of the truth simulation (shared by every env in a pool).
    pub truth_seed: u64,
}

impl Default for BurgersConfig {
    fn default() -> Self {
        BurgersConfig {
            points: 96,
            segments: 8,
            // Resolved on the refined truth grid (shock thickness ~ nu/u
            // ~ 0.04 vs truth dx ~ 0.033) while leaving the coarse grid
            // genuinely under-resolved — the SGS coefficient matters.
            nu: 0.04,
            ke_target: 0.5, // u_rms ~ 1
            forcing_tau: 0.5,
            noise_amp: 0.25,
            noise_modes: 3,
            k_max: 8,
            alpha: 0.4,
            dt_rl: 0.1,
            t_end: 1.0,
            cfl: 0.4,
            truth_refine: 2,
            truth_states: 8,
            truth_spinup: 2.0,
            truth_interval: 0.5,
            truth_seed: 2022,
        }
    }
}

/// One scenario family in a heterogeneous environment pool.
///
/// A variant perturbs the base case/solver configuration without changing
/// the spatial resolution, so every env in the pool shares one `Grid`, one
/// ground-truth package and one policy artifact set, and their element
/// observations batch together in a single policy forward.  Envs are
/// assigned round-robin: env `i` runs variant `i % n_variants`.
#[derive(Debug, Clone, PartialEq)]
pub struct EnvVariant {
    /// Display name ("base", "re_low", ...).
    pub name: String,
    /// Viscosity multiplier vs `solver.nu` (the Reynolds-number family).
    pub nu_scale: f64,
    /// Episode-horizon multiplier vs `solver.t_end`: variants with scale
    /// < 1 terminate early, exercising the done-flag path mid-iteration.
    pub t_end_scale: f64,
    /// Reward scaling override, Eq. (5) (`None` = case alpha).
    pub alpha: Option<f64>,
    /// Reward cutoff override, Eq. (4) (`None` = case k_max).
    pub k_max: Option<usize>,
}

impl Default for EnvVariant {
    fn default() -> Self {
        EnvVariant {
            name: "base".to_string(),
            nu_scale: 1.0,
            t_end_scale: 1.0,
            alpha: None,
            k_max: None,
        }
    }
}

/// A variant resolved against the base configuration: the exact case and
/// solver parameters one environment worker is constructed with.
#[derive(Debug, Clone)]
pub struct ResolvedVariant {
    /// Index into `rl.variants` (0 for the homogeneous pool).
    pub index: usize,
    pub name: String,
    pub case: CaseConfig,
    pub solver: SolverConfig,
    /// `Some((family, n_families))`: restrict initial-state draws to pool
    /// indices congruent to `family` mod `n_families` (disjoint
    /// initial-state families per variant).
    pub init_family: Option<(usize, usize)>,
    /// The raw variant knobs, for backends whose base parameters live
    /// outside `case`/`solver` (the Burgers backend scales its own
    /// viscosity/horizon by `variant.nu_scale`/`variant.t_end_scale`).
    pub variant: EnvVariant,
}

/// CFD backends selectable via `rl.backend` (the solver-agnostic
/// environment layer; see `crate::rl::cfd` for the registry).
pub const BACKENDS: &[&str] = &["les", "burgers"];

/// Policy/trainer runtime backends selectable via `runtime.backend`
/// (see `crate::runtime::api` for the registry): `"xla"` executes the
/// pre-compiled PJRT artifacts, `"native"` runs the in-process
/// MLP + PPO subsystem with zero artifacts.
pub const RUNTIME_BACKENDS: &[&str] = &["xla", "native"];

/// The policy/trainer runtime layer (`[runtime]` section): which ML
/// execution backend serves `policy_fwd`/`train_step`, and — for the
/// native backend — the MLP architecture and PPO/Adam hyperparameters
/// (the XLA path bakes these into the artifacts at lowering time).
#[derive(Debug, Clone)]
pub struct RuntimeConfig {
    /// `"xla"` (compiled artifacts) or `"native"` (in-process MLP+PPO).
    /// See [`RUNTIME_BACKENDS`].
    pub backend: String,
    /// Native MLP hidden-layer widths (tanh activations).
    pub hidden: Vec<usize>,
    /// Native Adam learning rate (paper §5.3: 1e-4).
    pub lr: f64,
    /// Native PPO clipping radius (paper §5.3: 0.2).
    pub clip_eps: f64,
    /// Native value-loss coefficient.
    pub vf_coef: f64,
    /// Native entropy-bonus coefficient (paper §5.3: 0).
    pub ent_coef: f64,
    /// Native initial global log standard deviation.
    pub log_std_init: f64,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig {
            backend: "xla".to_string(),
            hidden: vec![64, 64],
            lr: 1e-4,
            clip_eps: 0.2,
            vf_coef: 0.5,
            ent_coef: 0.0,
            // sigma = 0.05, the artifact init (python/compile/model.py).
            log_std_init: -2.995_732_273_553_991, // ln(0.05)
        }
    }
}

impl RuntimeConfig {
    /// Section-local sanity checks — the single source of truth for what
    /// a runnable `[runtime]` section looks like, shared by
    /// [`RunConfig::validate`] and `runtime::NativeSpec::from_config`
    /// (which also serves callers that never went through a full config).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            RUNTIME_BACKENDS.contains(&self.backend.as_str()),
            "unknown runtime.backend {:?} (expected one of {RUNTIME_BACKENDS:?})",
            self.backend
        );
        anyhow::ensure!(
            !self.hidden.is_empty(),
            "runtime.hidden must name at least one hidden layer"
        );
        for (i, &h) in self.hidden.iter().enumerate() {
            anyhow::ensure!(
                (1..=65_536).contains(&h),
                "runtime.hidden[{i}] = {h} outside [1, 65536] (negative or absurd width?)"
            );
        }
        anyhow::ensure!(self.lr > 0.0, "runtime.lr must be positive");
        anyhow::ensure!(
            self.clip_eps > 0.0 && self.clip_eps < 1.0,
            "runtime.clip_eps must lie in (0, 1)"
        );
        anyhow::ensure!(
            self.vf_coef >= 0.0 && self.ent_coef >= 0.0,
            "runtime.vf_coef / runtime.ent_coef must be non-negative"
        );
        Ok(())
    }
}

/// PPO / training-loop parameters (paper §5.3).
#[derive(Debug, Clone)]
pub struct RlConfig {
    /// CFD backend the environment pool runs (`"les"` = the paper's 3D
    /// spectral HIT case; `"burgers"` = the 1D stochastic-Burgers
    /// testbed).  See [`BACKENDS`].
    pub backend: String,
    /// Discount factor (paper: 0.995).
    pub gamma: f64,
    /// Parallel environments per training iteration.
    pub n_envs: usize,
    /// Training iterations.
    pub iterations: usize,
    /// Optimization epochs per iteration (paper: 5).
    pub epochs: usize,
    /// Minibatch size fed to the train_step artifact.
    pub minibatch: usize,
    /// Evaluate on the held-out test state every this many iterations.
    pub eval_every: usize,
    /// Master seed.
    pub seed: u64,
    /// GAE lambda (1.0 = plain discounted returns, as in the paper).
    pub gae_lambda: f64,
    /// Event-driven collector: evaluate the policy as soon as this many
    /// env states have arrived.  `0` (default) = wait for the full batch,
    /// which reproduces the paper's synchronous PPO bit-for-bit.
    pub min_batch: usize,
    /// Scenario families sampled by one pool (empty = homogeneous base
    /// case).  Env `i` runs variant `i % variants.len()`.
    pub variants: Vec<EnvVariant>,
    /// Give each variant a disjoint family of initial states from the
    /// truth pool (index mod n_variants) instead of the shared pool.
    pub split_init_pool: bool,
}

impl Default for RlConfig {
    fn default() -> Self {
        RlConfig {
            backend: "les".to_string(),
            gamma: 0.995,
            n_envs: 16,
            iterations: 100,
            epochs: 5,
            minibatch: 256,
            eval_every: 10,
            seed: 2022,
            gae_lambda: 1.0,
            min_batch: 0,
            variants: Vec::new(),
            split_init_pool: false,
        }
    }
}

/// Cluster model + orchestrator parameters (Hawk / Hawk-AI, §4).
#[derive(Debug, Clone)]
pub struct HpcConfig {
    /// Worker nodes available (paper benchmarks: 16).
    pub worker_nodes: usize,
    /// Cores per node (Hawk: 2 x 64-core EPYC 7742).
    pub cores_per_node: usize,
    /// Cores per die sharing memory bandwidth (EPYC: 8).
    pub cores_per_die: usize,
    /// MPI ranks per environment instance.
    pub ranks_per_env: usize,
    /// Node-level kernel worker-pool width (FFT plane batches, GEMM
    /// macro-tiles, DNS/truth loops, batched Burgers waves).  `0` = auto
    /// (available parallelism); the `RELEXI_THREADS` env var overrides
    /// both.  Kernel results are bit-identical for every width.
    pub threads: usize,
    /// Orchestrator shards (1 = single-threaded Redis-like).
    pub db_shards: usize,
    /// Retain the PR-2 store-level sequence-lock wakeup protocol (every
    /// put wakes every multi-key subscriber) instead of the default
    /// per-key waiter registration.  Baseline knob for A/B perf runs.
    pub db_seqlock_wake: bool,
    /// Use MPMD batched launch (paper §3.3 improvement).
    pub mpmd: bool,
    /// Stage files to RAM drive instead of the parallel FS (§3.3).
    pub ram_staging: bool,
}

impl Default for HpcConfig {
    fn default() -> Self {
        HpcConfig {
            worker_nodes: 16,
            cores_per_node: 128,
            cores_per_die: 8,
            ranks_per_env: 8,
            threads: 0,
            db_shards: 8,
            db_seqlock_wake: false,
            mpmd: true,
            ram_staging: true,
        }
    }
}

/// Env-worker hosting modes selectable via `orchestrator.workers`:
/// `"threads"` hosts every env as a thread inside the trainer process
/// (the baseline; pairs with the in-process store), `"processes"`
/// splits the pool over separate `relexi env-worker` OS processes that
/// dial the exchange over a network-capable transport.
pub const WORKER_MODES: &[&str] = &["threads", "processes"];

/// The store transport + worker-process section (`[orchestrator]`):
/// which exchange flavour serves the state/action dataflow and how the
/// environment pool is hosted.  See `crate::orchestrator::transport`
/// for the transport seam itself and `crate::launcher` for the
/// env->process placement plan.
#[derive(Debug, Clone)]
pub struct OrchestratorConfig {
    /// Store transport: `"inproc"` (in-process sharded store, the
    /// bit-identical fast path), `"shm"` (shared-memory rings
    /// bootstrapped over loopback TCP) or `"tcp"` (length-prefixed
    /// frames over a socket).  See `orchestrator::TRANSPORTS`.
    pub transport: String,
    /// `"threads"` or `"processes"` (see [`WORKER_MODES`]).
    pub workers: String,
    /// Worker processes the env pool is split over (processes mode).
    /// `0` = auto: the launcher plans the split from the topology +
    /// cost model ([`crate::launcher::plan_worker_processes`]).
    pub env_procs: usize,
    /// Exchange bind address; port `0` = ephemeral (the pool passes the
    /// resolved address to the workers it spawns).
    pub bind: String,
    /// Worker-side dial attempts (200 ms apart) before giving up.
    pub connect_retries: usize,
    /// Binary spawned as `<worker_bin> env-worker ...`; `""` = the
    /// currently running executable.  The `RELEXI_WORKER_BIN`
    /// environment variable overrides both (how integration tests point
    /// the pool at the Cargo-built binary).
    pub worker_bin: String,
    /// Collector/worker blocking-wait bound per event (seconds).  The
    /// supervision layer slices this wait to watch heartbeats, so in
    /// processes mode a dead worker is detected long before it expires.
    pub poll_timeout_s: f64,
    /// How long the pool waits for a spawned worker's hello (seconds).
    pub hello_timeout_s: f64,
    /// How long `Drop` waits for workers to honour the stop flag before
    /// killing them (seconds).
    pub reap_timeout_s: f64,
    /// Cadence at which env-workers publish their heartbeat counter
    /// (milliseconds).
    pub heartbeat_period_ms: u64,
    /// A worker whose heartbeat counter has not advanced for this long
    /// (milliseconds) is declared wedged and respawned.  Must exceed
    /// `heartbeat_period_ms`.
    pub heartbeat_expiry_ms: u64,
    /// Wave-coalesced batched exchange (PR 9): workers publish each
    /// step's whole env block as ONE `PutMany` frame and block on one
    /// batched action take, and the collector scatters an action wave
    /// as one `PutMany` per worker block — O(W·T) frames per wave
    /// instead of O(E·T).  `false` keeps the per-key wire pattern as
    /// the A/B baseline; both legs are bit-identical at the same seed.
    pub batch_ops: bool,
}

impl Default for OrchestratorConfig {
    fn default() -> Self {
        OrchestratorConfig {
            transport: "inproc".to_string(),
            workers: "threads".to_string(),
            env_procs: 0,
            bind: "127.0.0.1:0".to_string(),
            connect_retries: 3,
            worker_bin: String::new(),
            poll_timeout_s: 600.0,
            hello_timeout_s: 120.0,
            reap_timeout_s: 10.0,
            heartbeat_period_ms: 1000,
            heartbeat_expiry_ms: 10_000,
            batch_ops: true,
        }
    }
}

/// Fault-tolerance section (`[fault]`): the supervision layer's respawn
/// budget and the deterministic fault-injection plan used by the chaos
/// tests (see `crate::coordinator::supervise::FaultPlan` for the plan
/// grammar).  The `RELEXI_FAULT_PLAN` environment variable overrides
/// `plan` at runtime.
#[derive(Debug, Clone)]
pub struct FaultConfig {
    /// Per-worker respawn budget within one pool lifetime.  When a
    /// worker exhausts it, its env block is dropped and waves complete
    /// short (per-variant accounting) instead of aborting training.
    /// `0` disables respawns entirely (detection still applies).
    pub max_respawns: usize,
    /// Fault-injection plan, `;`-separated directives such as
    /// `kill:w0@1`, `killput:w1@40`, `hbstall:w0@0`, `drop:3`,
    /// `delay:5:250`.  Empty = no injected faults.
    pub plan: String,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            max_respawns: 2,
            plan: String::new(),
        }
    }
}

/// Telemetry section (`[telemetry]`): the run-wide tracing subsystem
/// (`util::telemetry`).  When `enabled`, every process records spans /
/// events / latency histograms into per-thread lock-free rings, env-worker
/// processes ship theirs over the store ctl plane at iteration end, and the
/// trainer writes one merged Chrome-trace JSON plus a `TELEMETRY_{run}.json`
/// summary.  The `RELEXI_LOG` environment variable overrides `log_level`;
/// recording is designed to allocate nothing in steady state, so the
/// exchange alloc gates hold with telemetry on or off.
#[derive(Debug, Clone)]
pub struct TelemetryConfig {
    /// Master switch for span/event/histogram recording.  Logging via
    /// `tlog!` works regardless (it is gated only by `log_level`).
    pub enabled: bool,
    /// Records per thread ring; on overflow the oldest records are dropped
    /// and counted (`dropped_records` in the summary).
    pub buffer_capacity: usize,
    /// `tlog!` threshold: "error" | "warn" | "info" | "debug".
    pub log_level: String,
    /// Merged Chrome-trace output path; `""` = `TRACE_{case}.json` in the
    /// working directory (next to the `BENCH_*.json` artifacts).
    pub trace_path: String,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            enabled: false,
            buffer_capacity: 65_536,
            log_level: "info".to_string(),
            trace_path: String::new(),
        }
    }
}

/// Complete run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub case: CaseConfig,
    pub solver: SolverConfig,
    pub burgers: BurgersConfig,
    pub rl: RlConfig,
    pub runtime: RuntimeConfig,
    pub hpc: HpcConfig,
    pub orchestrator: OrchestratorConfig,
    pub fault: FaultConfig,
    pub telemetry: TelemetryConfig,
    /// Directory with AOT artifacts.
    pub artifacts_dir: String,
    /// Output directory for metrics/checkpoints.
    pub out_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            case: presets::dof24(),
            solver: SolverConfig::default(),
            burgers: BurgersConfig::default(),
            rl: RlConfig::default(),
            runtime: RuntimeConfig::default(),
            hpc: HpcConfig::default(),
            orchestrator: OrchestratorConfig::default(),
            fault: FaultConfig::default(),
            telemetry: TelemetryConfig::default(),
            artifacts_dir: "artifacts".to_string(),
            out_dir: "runs/out".to_string(),
        }
    }
}

impl RunConfig {
    /// Build from a parsed TOML document (missing keys keep defaults).
    pub fn from_toml(t: &Toml) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(v) = t.get("case.preset") {
            cfg.case = presets::by_name(v.as_str()?)?;
        }
        if let Some(v) = t.get("case.name") {
            cfg.case.name = v.as_str()?.to_string();
        }
        if let Some(v) = t.get("case.n") {
            cfg.case.n = v.as_int()? as usize;
        }
        if let Some(v) = t.get("case.elems_per_dir") {
            cfg.case.elems_per_dir = v.as_int()? as usize;
        }
        if let Some(v) = t.get("case.k_max") {
            cfg.case.k_max = v.as_int()? as usize;
        }
        if let Some(v) = t.get("case.alpha") {
            cfg.case.alpha = v.as_float()?;
        }

        cfg.solver.nu = t.float_or("solver.nu", cfg.solver.nu)?;
        cfg.solver.cfl = t.float_or("solver.cfl", cfg.solver.cfl)?;
        cfg.solver.ke_target = t.float_or("solver.ke_target", cfg.solver.ke_target)?;
        cfg.solver.forcing_tau = t.float_or("solver.forcing_tau", cfg.solver.forcing_tau)?;
        cfg.solver.dt_rl = t.float_or("solver.dt_rl", cfg.solver.dt_rl)?;
        cfg.solver.t_end = t.float_or("solver.t_end", cfg.solver.t_end)?;
        cfg.solver.dns_points =
            t.int_or("solver.dns_points", cfg.solver.dns_points as i64)? as usize;
        cfg.solver.smagorinsky_cs =
            t.float_or("solver.smagorinsky_cs", cfg.solver.smagorinsky_cs)?;

        cfg.burgers.points = t.int_or("burgers.points", cfg.burgers.points as i64)? as usize;
        cfg.burgers.segments =
            t.int_or("burgers.segments", cfg.burgers.segments as i64)? as usize;
        cfg.burgers.nu = t.float_or("burgers.nu", cfg.burgers.nu)?;
        cfg.burgers.ke_target = t.float_or("burgers.ke_target", cfg.burgers.ke_target)?;
        cfg.burgers.forcing_tau = t.float_or("burgers.forcing_tau", cfg.burgers.forcing_tau)?;
        cfg.burgers.noise_amp = t.float_or("burgers.noise_amp", cfg.burgers.noise_amp)?;
        cfg.burgers.noise_modes =
            t.int_or("burgers.noise_modes", cfg.burgers.noise_modes as i64)? as usize;
        cfg.burgers.k_max = t.int_or("burgers.k_max", cfg.burgers.k_max as i64)? as usize;
        cfg.burgers.alpha = t.float_or("burgers.alpha", cfg.burgers.alpha)?;
        cfg.burgers.dt_rl = t.float_or("burgers.dt_rl", cfg.burgers.dt_rl)?;
        cfg.burgers.t_end = t.float_or("burgers.t_end", cfg.burgers.t_end)?;
        cfg.burgers.cfl = t.float_or("burgers.cfl", cfg.burgers.cfl)?;
        cfg.burgers.truth_refine =
            t.int_or("burgers.truth_refine", cfg.burgers.truth_refine as i64)? as usize;
        cfg.burgers.truth_states =
            t.int_or("burgers.truth_states", cfg.burgers.truth_states as i64)? as usize;
        cfg.burgers.truth_spinup =
            t.float_or("burgers.truth_spinup", cfg.burgers.truth_spinup)?;
        cfg.burgers.truth_interval =
            t.float_or("burgers.truth_interval", cfg.burgers.truth_interval)?;
        cfg.burgers.truth_seed =
            t.int_or("burgers.truth_seed", cfg.burgers.truth_seed as i64)? as u64;

        cfg.rl.backend = t.str_or("rl.backend", &cfg.rl.backend)?;
        cfg.rl.gamma = t.float_or("rl.gamma", cfg.rl.gamma)?;
        cfg.rl.n_envs = t.int_or("rl.n_envs", cfg.rl.n_envs as i64)? as usize;
        cfg.rl.iterations = t.int_or("rl.iterations", cfg.rl.iterations as i64)? as usize;
        cfg.rl.epochs = t.int_or("rl.epochs", cfg.rl.epochs as i64)? as usize;
        cfg.rl.minibatch = t.int_or("rl.minibatch", cfg.rl.minibatch as i64)? as usize;
        cfg.rl.eval_every = t.int_or("rl.eval_every", cfg.rl.eval_every as i64)? as usize;
        cfg.rl.seed = t.int_or("rl.seed", cfg.rl.seed as i64)? as u64;
        cfg.rl.gae_lambda = t.float_or("rl.gae_lambda", cfg.rl.gae_lambda)?;
        cfg.rl.min_batch = t.int_or("rl.min_batch", cfg.rl.min_batch as i64)? as usize;
        cfg.rl.split_init_pool = t.bool_or("rl.split_init_pool", cfg.rl.split_init_pool)?;
        if let Some(v) = t.get("rl.variant_preset") {
            cfg.rl.variants = presets::variant_preset(v.as_str()?, &cfg.case)?;
        }
        if let Some(v) = t.get("rl.variant_names") {
            // Parallel flat arrays (the TOML subset has no array-of-tables):
            // names define the variant count; the optional per-field arrays
            // must match it.  A non-positive alpha/k_max entry means "no
            // override" (keep the base case's value).
            let names = v.as_str_vec().context("rl.variant_names")?;
            let n = names.len();
            let floats = |key: &str, default: f64| -> Result<Vec<f64>> {
                match t.get(key) {
                    Some(v) => {
                        let xs = v.as_float_vec().with_context(|| key.to_string())?;
                        anyhow::ensure!(
                            xs.len() == n,
                            "{key} has {} entries, expected {n} (one per variant_names entry)",
                            xs.len()
                        );
                        Ok(xs)
                    }
                    None => Ok(vec![default; n]),
                }
            };
            let nu_scale = floats("rl.variant_nu_scale", 1.0)?;
            let t_end_scale = floats("rl.variant_t_end_scale", 1.0)?;
            let alpha = floats("rl.variant_alpha", 0.0)?;
            let k_max = floats("rl.variant_k_max", 0.0)?;
            cfg.rl.variants = names
                .into_iter()
                .enumerate()
                .map(|(i, name)| EnvVariant {
                    name,
                    nu_scale: nu_scale[i],
                    t_end_scale: t_end_scale[i],
                    alpha: (alpha[i] > 0.0).then_some(alpha[i]),
                    k_max: (k_max[i] > 0.0).then_some(k_max[i] as usize),
                })
                .collect();
        }

        cfg.runtime.backend = t.str_or("runtime.backend", &cfg.runtime.backend)?;
        if let Some(v) = t.get("runtime.hidden") {
            cfg.runtime.hidden = v
                .as_int_vec()
                .context("runtime.hidden")?
                .into_iter()
                .map(|h| h as usize)
                .collect();
        }
        cfg.runtime.lr = t.float_or("runtime.lr", cfg.runtime.lr)?;
        cfg.runtime.clip_eps = t.float_or("runtime.clip_eps", cfg.runtime.clip_eps)?;
        cfg.runtime.vf_coef = t.float_or("runtime.vf_coef", cfg.runtime.vf_coef)?;
        cfg.runtime.ent_coef = t.float_or("runtime.ent_coef", cfg.runtime.ent_coef)?;
        cfg.runtime.log_std_init =
            t.float_or("runtime.log_std_init", cfg.runtime.log_std_init)?;

        cfg.hpc.worker_nodes =
            t.int_or("hpc.worker_nodes", cfg.hpc.worker_nodes as i64)? as usize;
        cfg.hpc.cores_per_node =
            t.int_or("hpc.cores_per_node", cfg.hpc.cores_per_node as i64)? as usize;
        cfg.hpc.cores_per_die =
            t.int_or("hpc.cores_per_die", cfg.hpc.cores_per_die as i64)? as usize;
        cfg.hpc.ranks_per_env =
            t.int_or("hpc.ranks_per_env", cfg.hpc.ranks_per_env as i64)? as usize;
        cfg.hpc.threads = t.int_or("hpc.threads", cfg.hpc.threads as i64)? as usize;
        cfg.hpc.db_shards = t.int_or("hpc.db_shards", cfg.hpc.db_shards as i64)? as usize;
        cfg.hpc.db_seqlock_wake =
            t.bool_or("hpc.db_seqlock_wake", cfg.hpc.db_seqlock_wake)?;
        cfg.hpc.mpmd = t.bool_or("hpc.mpmd", cfg.hpc.mpmd)?;
        cfg.hpc.ram_staging = t.bool_or("hpc.ram_staging", cfg.hpc.ram_staging)?;

        let orc = &mut cfg.orchestrator;
        orc.transport = t.str_or("orchestrator.transport", &orc.transport)?;
        orc.workers = t.str_or("orchestrator.workers", &orc.workers)?;
        orc.env_procs = t.int_or("orchestrator.env_procs", orc.env_procs as i64)? as usize;
        orc.bind = t.str_or("orchestrator.bind", &orc.bind)?;
        orc.connect_retries =
            t.int_or("orchestrator.connect_retries", orc.connect_retries as i64)? as usize;
        orc.worker_bin = t.str_or("orchestrator.worker_bin", &orc.worker_bin)?;
        orc.poll_timeout_s = t.float_or("orchestrator.poll_timeout_s", orc.poll_timeout_s)?;
        orc.hello_timeout_s =
            t.float_or("orchestrator.hello_timeout_s", orc.hello_timeout_s)?;
        orc.reap_timeout_s = t.float_or("orchestrator.reap_timeout_s", orc.reap_timeout_s)?;
        orc.heartbeat_period_ms = t.int_or(
            "orchestrator.heartbeat_period_ms",
            orc.heartbeat_period_ms as i64,
        )? as u64;
        orc.heartbeat_expiry_ms = t.int_or(
            "orchestrator.heartbeat_expiry_ms",
            orc.heartbeat_expiry_ms as i64,
        )? as u64;
        orc.batch_ops = t.bool_or("orchestrator.batch_ops", orc.batch_ops)?;

        cfg.fault.max_respawns =
            t.int_or("fault.max_respawns", cfg.fault.max_respawns as i64)? as usize;
        cfg.fault.plan = t.str_or("fault.plan", &cfg.fault.plan)?;

        let tel = &mut cfg.telemetry;
        tel.enabled = t.bool_or("telemetry.enabled", tel.enabled)?;
        tel.buffer_capacity =
            t.int_or("telemetry.buffer_capacity", tel.buffer_capacity as i64)? as usize;
        tel.log_level = t.str_or("telemetry.log_level", &tel.log_level)?;
        tel.trace_path = t.str_or("telemetry.trace_path", &tel.trace_path)?;

        cfg.artifacts_dir = t.str_or("paths.artifacts", &cfg.artifacts_dir)?;
        cfg.out_dir = t.str_or("paths.out", &cfg.out_dir)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from file + CLI `--key value` overlays (dotted keys).
    pub fn load(
        path: Option<&str>,
        overrides: impl Iterator<Item = (String, String)>,
    ) -> Result<RunConfig> {
        let mut doc = match path {
            Some(p) => Toml::load(std::path::Path::new(p))?,
            None => Toml::default(),
        };
        for (k, v) in overrides {
            if k.contains('.') {
                doc.set_raw(&k, &v)?;
            }
        }
        RunConfig::from_toml(&doc)
    }

    /// Sanity checks that would otherwise surface as weird failures deep
    /// inside the solver or the runtime.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            BACKENDS.contains(&self.rl.backend.as_str()),
            "unknown rl.backend {:?} (expected one of {BACKENDS:?})",
            self.rl.backend
        );
        if self.rl.backend == "burgers" {
            let b = &self.burgers;
            anyhow::ensure!(b.points >= 8, "burgers.points must be >= 8");
            anyhow::ensure!(
                b.segments >= 1 && b.points % b.segments == 0,
                "burgers.segments {} must divide burgers.points {}",
                b.segments,
                b.points
            );
            anyhow::ensure!(
                b.k_max >= 1 && b.k_max <= b.points / 2,
                "burgers.k_max {} beyond Nyquist {}",
                b.k_max,
                b.points / 2
            );
            anyhow::ensure!(
                b.noise_modes >= 1 && b.noise_modes <= b.points / 2,
                "burgers.noise_modes must lie in [1, Nyquist]"
            );
            anyhow::ensure!(b.nu > 0.0 && b.cfl > 0.0 && b.alpha > 0.0);
            anyhow::ensure!(b.ke_target > 0.0 && b.forcing_tau > 0.0);
            anyhow::ensure!(b.dt_rl > 0.0 && b.t_end > 0.0);
            anyhow::ensure!(
                (b.t_end / b.dt_rl).round() as usize >= 1,
                "burgers.t_end/dt_rl rounds to 0 steps"
            );
            anyhow::ensure!(b.truth_refine >= 1, "burgers.truth_refine must be >= 1");
            anyhow::ensure!(b.truth_states >= 1, "burgers.truth_states must be >= 1");
            anyhow::ensure!(b.truth_interval > 0.0);
        }
        self.runtime.validate()?;
        // The compiled artifacts only exist for the paper's two element
        // shapes; the native runtime sizes itself from the env pool and
        // carries no such constraint.
        if self.runtime.backend == "xla" {
            anyhow::ensure!(
                self.case.n == 5 || self.case.n == 7,
                "policy artifacts exist for N in {{5, 7}}, got N={} \
                 (runtime.backend = \"native\" lifts this constraint)",
                self.case.n
            );
        }
        anyhow::ensure!(self.case.elems_per_dir >= 1, "need at least one element");
        anyhow::ensure!(
            self.case.k_max <= self.case.points_per_dir() / 2,
            "k_max {} beyond Nyquist {}",
            self.case.k_max,
            self.case.points_per_dir() / 2
        );
        anyhow::ensure!(self.solver.dt_rl > 0.0 && self.solver.t_end > 0.0);
        anyhow::ensure!(self.rl.n_envs >= 1 && self.rl.minibatch >= 1);
        anyhow::ensure!(self.steps_per_episode() >= 1, "t_end/dt_rl rounds to 0 steps");
        anyhow::ensure!(
            self.rl.min_batch <= self.rl.n_envs,
            "rl.min_batch {} exceeds rl.n_envs {} (use 0 for full batch)",
            self.rl.min_batch,
            self.rl.n_envs
        );
        anyhow::ensure!(
            self.rl.variants.len() <= self.rl.n_envs,
            "{} env variants but only {} envs (round-robin would starve some variants)",
            self.rl.variants.len(),
            self.rl.n_envs
        );
        // Variant overrides are checked against the ACTIVE backend's
        // spectral resolution and episode horizon.
        let (nyquist, base_t_end, base_dt_rl) = if self.rl.backend == "burgers" {
            (self.burgers.points / 2, self.burgers.t_end, self.burgers.dt_rl)
        } else {
            (self.case.points_per_dir() / 2, self.solver.t_end, self.solver.dt_rl)
        };
        for (i, v) in self.rl.variants.iter().enumerate() {
            anyhow::ensure!(
                v.nu_scale > 0.0 && v.t_end_scale > 0.0,
                "variant {i} ({}): nu_scale and t_end_scale must be positive",
                v.name
            );
            if let Some(k) = v.k_max {
                anyhow::ensure!(
                    k >= 1 && k <= nyquist,
                    "variant {i} ({}): k_max {k} beyond Nyquist {nyquist}",
                    v.name
                );
            }
            if let Some(a) = v.alpha {
                anyhow::ensure!(a > 0.0, "variant {i} ({}): alpha must be positive", v.name);
            }
            anyhow::ensure!(
                (base_t_end * v.t_end_scale / base_dt_rl).round() as usize >= 1,
                "variant {i} ({}): horizon rounds to 0 steps",
                v.name
            );
        }
        anyhow::ensure!(
            self.hpc.cores_per_node % self.hpc.cores_per_die == 0,
            "cores_per_node must be a multiple of cores_per_die"
        );
        let orc = &self.orchestrator;
        anyhow::ensure!(
            crate::orchestrator::TRANSPORTS.contains(&orc.transport.as_str()),
            "unknown orchestrator.transport {:?} (expected one of {:?})",
            orc.transport,
            crate::orchestrator::TRANSPORTS
        );
        anyhow::ensure!(
            WORKER_MODES.contains(&orc.workers.as_str()),
            "unknown orchestrator.workers {:?} (expected one of {WORKER_MODES:?})",
            orc.workers
        );
        if orc.workers == "threads" {
            anyhow::ensure!(
                orc.transport == "inproc",
                "orchestrator.workers = \"threads\" hosts envs inside the trainer \
                 process; use transport = \"inproc\" (got {:?})",
                orc.transport
            );
        } else {
            anyhow::ensure!(
                orc.transport != "inproc",
                "orchestrator.workers = \"processes\" needs a network-capable \
                 transport (\"shm\" or \"tcp\"), not \"inproc\""
            );
            anyhow::ensure!(
                self.rl.backend == "burgers",
                "orchestrator.workers = \"processes\" currently supports only \
                 rl.backend = \"burgers\" (the LES backend ships ground-truth \
                 packages the worker process cannot reload yet)"
            );
            anyhow::ensure!(
                orc.env_procs <= self.rl.n_envs,
                "orchestrator.env_procs {} exceeds rl.n_envs {}",
                orc.env_procs,
                self.rl.n_envs
            );
        }
        anyhow::ensure!(
            orc.connect_retries >= 1,
            "orchestrator.connect_retries must be >= 1"
        );
        anyhow::ensure!(
            orc.poll_timeout_s > 0.0 && orc.poll_timeout_s.is_finite(),
            "orchestrator.poll_timeout_s must be positive"
        );
        anyhow::ensure!(
            orc.hello_timeout_s > 0.0 && orc.hello_timeout_s.is_finite(),
            "orchestrator.hello_timeout_s must be positive"
        );
        anyhow::ensure!(
            orc.reap_timeout_s > 0.0 && orc.reap_timeout_s.is_finite(),
            "orchestrator.reap_timeout_s must be positive"
        );
        anyhow::ensure!(
            orc.heartbeat_period_ms >= 1,
            "orchestrator.heartbeat_period_ms must be >= 1"
        );
        anyhow::ensure!(
            orc.heartbeat_expiry_ms > orc.heartbeat_period_ms,
            "orchestrator.heartbeat_expiry_ms ({}) must exceed heartbeat_period_ms ({})",
            orc.heartbeat_expiry_ms,
            orc.heartbeat_period_ms
        );
        if let Err(e) = crate::coordinator::supervise::FaultPlan::parse(&self.fault.plan) {
            anyhow::bail!("invalid fault.plan {:?}: {e:#}", self.fault.plan);
        }
        let tel = &self.telemetry;
        anyhow::ensure!(
            crate::util::telemetry::Level::parse(&tel.log_level).is_some(),
            "unknown telemetry.log_level {:?} (expected error|warn|info|debug)",
            tel.log_level
        );
        anyhow::ensure!(
            tel.buffer_capacity >= 1024,
            "telemetry.buffer_capacity {} too small (need >= 1024 records)",
            tel.buffer_capacity
        );
        Ok(())
    }

    /// Actions per episode = t_end / dt_rl (paper: 50) for the base case;
    /// variants with `t_end_scale != 1` deviate (see
    /// [`RunConfig::variant_for`]).
    pub fn steps_per_episode(&self) -> usize {
        (self.solver.t_end / self.solver.dt_rl).round() as usize
    }

    /// Actions per episode of the **active backend's** base scenario
    /// (the Burgers horizon lives in its own config section).
    pub fn backend_steps_per_episode(&self) -> usize {
        if self.rl.backend == "burgers" {
            (self.burgers.t_end / self.burgers.dt_rl).round() as usize
        } else {
            self.steps_per_episode()
        }
    }

    /// Number of scenario families in the pool (1 = homogeneous).
    pub fn n_variants(&self) -> usize {
        self.rl.variants.len().max(1)
    }

    /// Effective arrival-batch threshold: `rl.min_batch`, with `0`
    /// meaning the full synchronous batch of `n_envs` states.
    pub fn min_batch_effective(&self) -> usize {
        if self.rl.min_batch == 0 {
            self.rl.n_envs
        } else {
            self.rl.min_batch
        }
    }

    /// Resolve the scenario variant env `env` runs (round-robin).
    pub fn variant_for(&self, env: usize) -> ResolvedVariant {
        let n_var = self.n_variants();
        let index = env % n_var;
        let base = EnvVariant::default();
        let v = self.rl.variants.get(index).unwrap_or(&base);
        let mut case = self.case.clone();
        if let Some(a) = v.alpha {
            case.alpha = a;
        }
        if let Some(k) = v.k_max {
            case.k_max = k;
        }
        let mut solver = self.solver.clone();
        solver.nu *= v.nu_scale;
        solver.t_end *= v.t_end_scale;
        ResolvedVariant {
            index,
            name: v.name.clone(),
            case,
            solver,
            init_family: self.rl.split_init_pool.then_some((index, n_var)),
            variant: v.clone(),
        }
    }

    /// Serialize the complete configuration to the TOML subset
    /// [`RunConfig::from_toml`] reads back.  The trainer hands each
    /// `relexi env-worker` process its exact effective config (file +
    /// CLI overlays already applied) through the `RELEXI_WORKER_CONFIG`
    /// environment variable, so every knob an env construction touches
    /// must survive the round trip bit-for-bit — floats are emitted via
    /// Rust's shortest-round-trip formatting.
    pub fn to_toml_string(&self) -> String {
        use std::fmt::Write as _;
        fn q(s: &str) -> String {
            format!("\"{}\"", s.replace('"', "\\\""))
        }
        fn fs(xs: &[f64]) -> String {
            let parts: Vec<String> = xs.iter().map(|x| format!("{x}")).collect();
            format!("[{}]", parts.join(", "))
        }
        let mut o = String::new();
        let c = &self.case;
        let _ = writeln!(o, "[case]");
        let _ = writeln!(o, "name = {}", q(&c.name));
        let _ = writeln!(o, "n = {}", c.n);
        let _ = writeln!(o, "elems_per_dir = {}", c.elems_per_dir);
        let _ = writeln!(o, "k_max = {}", c.k_max);
        let _ = writeln!(o, "alpha = {}", c.alpha);
        let s = &self.solver;
        let _ = writeln!(o, "[solver]");
        let _ = writeln!(o, "nu = {}", s.nu);
        let _ = writeln!(o, "cfl = {}", s.cfl);
        let _ = writeln!(o, "ke_target = {}", s.ke_target);
        let _ = writeln!(o, "forcing_tau = {}", s.forcing_tau);
        let _ = writeln!(o, "dt_rl = {}", s.dt_rl);
        let _ = writeln!(o, "t_end = {}", s.t_end);
        let _ = writeln!(o, "dns_points = {}", s.dns_points);
        let _ = writeln!(o, "smagorinsky_cs = {}", s.smagorinsky_cs);
        let b = &self.burgers;
        let _ = writeln!(o, "[burgers]");
        let _ = writeln!(o, "points = {}", b.points);
        let _ = writeln!(o, "segments = {}", b.segments);
        let _ = writeln!(o, "nu = {}", b.nu);
        let _ = writeln!(o, "ke_target = {}", b.ke_target);
        let _ = writeln!(o, "forcing_tau = {}", b.forcing_tau);
        let _ = writeln!(o, "noise_amp = {}", b.noise_amp);
        let _ = writeln!(o, "noise_modes = {}", b.noise_modes);
        let _ = writeln!(o, "k_max = {}", b.k_max);
        let _ = writeln!(o, "alpha = {}", b.alpha);
        let _ = writeln!(o, "dt_rl = {}", b.dt_rl);
        let _ = writeln!(o, "t_end = {}", b.t_end);
        let _ = writeln!(o, "cfl = {}", b.cfl);
        let _ = writeln!(o, "truth_refine = {}", b.truth_refine);
        let _ = writeln!(o, "truth_states = {}", b.truth_states);
        let _ = writeln!(o, "truth_spinup = {}", b.truth_spinup);
        let _ = writeln!(o, "truth_interval = {}", b.truth_interval);
        let _ = writeln!(o, "truth_seed = {}", b.truth_seed);
        let r = &self.rl;
        let _ = writeln!(o, "[rl]");
        let _ = writeln!(o, "backend = {}", q(&r.backend));
        let _ = writeln!(o, "gamma = {}", r.gamma);
        let _ = writeln!(o, "n_envs = {}", r.n_envs);
        let _ = writeln!(o, "iterations = {}", r.iterations);
        let _ = writeln!(o, "epochs = {}", r.epochs);
        let _ = writeln!(o, "minibatch = {}", r.minibatch);
        let _ = writeln!(o, "eval_every = {}", r.eval_every);
        let _ = writeln!(o, "seed = {}", r.seed);
        let _ = writeln!(o, "gae_lambda = {}", r.gae_lambda);
        let _ = writeln!(o, "min_batch = {}", r.min_batch);
        let _ = writeln!(o, "split_init_pool = {}", r.split_init_pool);
        if !r.variants.is_empty() {
            // Parallel flat arrays, exactly as `from_toml` expects: a
            // non-positive alpha / k_max entry means "no override".
            let names: Vec<String> = r.variants.iter().map(|v| q(&v.name)).collect();
            let _ = writeln!(o, "variant_names = [{}]", names.join(", "));
            let _ = writeln!(
                o,
                "variant_nu_scale = {}",
                fs(&r.variants.iter().map(|v| v.nu_scale).collect::<Vec<_>>())
            );
            let _ = writeln!(
                o,
                "variant_t_end_scale = {}",
                fs(&r.variants.iter().map(|v| v.t_end_scale).collect::<Vec<_>>())
            );
            let _ = writeln!(
                o,
                "variant_alpha = {}",
                fs(&r.variants.iter().map(|v| v.alpha.unwrap_or(0.0)).collect::<Vec<_>>())
            );
            let _ = writeln!(
                o,
                "variant_k_max = {}",
                fs(&r
                    .variants
                    .iter()
                    .map(|v| v.k_max.unwrap_or(0) as f64)
                    .collect::<Vec<_>>())
            );
        }
        let rt = &self.runtime;
        let _ = writeln!(o, "[runtime]");
        let _ = writeln!(o, "backend = {}", q(&rt.backend));
        let hidden: Vec<String> = rt.hidden.iter().map(|h| h.to_string()).collect();
        let _ = writeln!(o, "hidden = [{}]", hidden.join(", "));
        let _ = writeln!(o, "lr = {}", rt.lr);
        let _ = writeln!(o, "clip_eps = {}", rt.clip_eps);
        let _ = writeln!(o, "vf_coef = {}", rt.vf_coef);
        let _ = writeln!(o, "ent_coef = {}", rt.ent_coef);
        let _ = writeln!(o, "log_std_init = {}", rt.log_std_init);
        let h = &self.hpc;
        let _ = writeln!(o, "[hpc]");
        let _ = writeln!(o, "worker_nodes = {}", h.worker_nodes);
        let _ = writeln!(o, "cores_per_node = {}", h.cores_per_node);
        let _ = writeln!(o, "cores_per_die = {}", h.cores_per_die);
        let _ = writeln!(o, "ranks_per_env = {}", h.ranks_per_env);
        let _ = writeln!(o, "threads = {}", h.threads);
        let _ = writeln!(o, "db_shards = {}", h.db_shards);
        let _ = writeln!(o, "db_seqlock_wake = {}", h.db_seqlock_wake);
        let _ = writeln!(o, "mpmd = {}", h.mpmd);
        let _ = writeln!(o, "ram_staging = {}", h.ram_staging);
        let orc = &self.orchestrator;
        let _ = writeln!(o, "[orchestrator]");
        let _ = writeln!(o, "transport = {}", q(&orc.transport));
        let _ = writeln!(o, "workers = {}", q(&orc.workers));
        let _ = writeln!(o, "env_procs = {}", orc.env_procs);
        let _ = writeln!(o, "bind = {}", q(&orc.bind));
        let _ = writeln!(o, "connect_retries = {}", orc.connect_retries);
        let _ = writeln!(o, "worker_bin = {}", q(&orc.worker_bin));
        let _ = writeln!(o, "poll_timeout_s = {}", orc.poll_timeout_s);
        let _ = writeln!(o, "hello_timeout_s = {}", orc.hello_timeout_s);
        let _ = writeln!(o, "reap_timeout_s = {}", orc.reap_timeout_s);
        let _ = writeln!(o, "heartbeat_period_ms = {}", orc.heartbeat_period_ms);
        let _ = writeln!(o, "heartbeat_expiry_ms = {}", orc.heartbeat_expiry_ms);
        let _ = writeln!(o, "batch_ops = {}", orc.batch_ops);
        let f = &self.fault;
        let _ = writeln!(o, "[fault]");
        let _ = writeln!(o, "max_respawns = {}", f.max_respawns);
        let _ = writeln!(o, "plan = {}", q(&f.plan));
        let tel = &self.telemetry;
        let _ = writeln!(o, "[telemetry]");
        let _ = writeln!(o, "enabled = {}", tel.enabled);
        let _ = writeln!(o, "buffer_capacity = {}", tel.buffer_capacity);
        let _ = writeln!(o, "log_level = {}", q(&tel.log_level));
        let _ = writeln!(o, "trace_path = {}", q(&tel.trace_path));
        let _ = writeln!(o, "[paths]");
        let _ = writeln!(o, "artifacts = {}", q(&self.artifacts_dir));
        let _ = writeln!(o, "out = {}", q(&self.out_dir));
        o
    }

    /// The unmodified base scenario (no variant overrides, no init-family
    /// restriction) — what evaluation environments are built from.
    pub fn base_resolved(&self) -> ResolvedVariant {
        ResolvedVariant {
            index: 0,
            name: "base".to_string(),
            case: self.case.clone(),
            solver: self.solver.clone(),
            init_family: None,
            variant: EnvVariant::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid_table1_24dof() {
        let c = RunConfig::default();
        c.validate().unwrap();
        assert_eq!(c.case.total_dof(), 13_824);
        assert_eq!(c.steps_per_episode(), 50);
    }

    #[test]
    fn from_toml_overrides() {
        let doc = Toml::parse(
            "[case]\npreset = \"32dof\"\n[rl]\nn_envs = 64\n[solver]\nt_end = 2.0\n",
        )
        .unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.case.n, 7);
        assert_eq!(c.rl.n_envs, 64);
        assert_eq!(c.steps_per_episode(), 20);
    }

    #[test]
    fn seqlock_wake_flag_parses_and_defaults_off() {
        assert!(!RunConfig::default().hpc.db_seqlock_wake);
        let doc = Toml::parse("[hpc]\ndb_seqlock_wake = true\n").unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert!(c.hpc.db_seqlock_wake);
    }

    #[test]
    fn hpc_threads_parses_and_defaults_to_auto() {
        assert_eq!(RunConfig::default().hpc.threads, 0, "0 = auto width");
        let doc = Toml::parse("[hpc]\nthreads = 4\n").unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.hpc.threads, 4);
    }

    #[test]
    fn invalid_kmax_rejected() {
        let doc = Toml::parse("[case]\nk_max = 100\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn invalid_n_rejected() {
        let doc = Toml::parse("[case]\nn = 6\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn variants_from_parallel_arrays() {
        let doc = Toml::parse(
            "[rl]\n\
             n_envs = 4\n\
             min_batch = 2\n\
             split_init_pool = true\n\
             variant_names = [\"a\", \"b\"]\n\
             variant_nu_scale = [1.0, 2.0]\n\
             variant_t_end_scale = [1.0, 0.5]\n\
             variant_alpha = [0, 0.8]\n\
             variant_k_max = [0, 4]\n",
        )
        .unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.rl.min_batch, 2);
        assert_eq!(c.n_variants(), 2);
        // Non-positive entries mean "no override".
        assert_eq!(c.rl.variants[0].alpha, None);
        assert_eq!(c.rl.variants[0].k_max, None);
        assert_eq!(c.rl.variants[1].alpha, Some(0.8));
        assert_eq!(c.rl.variants[1].k_max, Some(4));

        // Round-robin resolution applies the overrides.
        let v0 = c.variant_for(0);
        let v1 = c.variant_for(1);
        let v2 = c.variant_for(2); // wraps back to variant 0
        assert_eq!(v0.name, "a");
        assert_eq!(v2.index, 0);
        assert_eq!(v1.solver.nu, c.solver.nu * 2.0);
        assert_eq!(v1.solver.t_end, c.solver.t_end * 0.5);
        assert_eq!(v1.case.alpha, 0.8);
        assert_eq!(v1.case.k_max, 4);
        assert_eq!(v0.init_family, Some((0, 2)));
        assert_eq!(v1.init_family, Some((1, 2)));
    }

    #[test]
    fn variant_preset_key_and_homogeneous_defaults() {
        let doc = Toml::parse("[rl]\nvariant_preset = \"re-sweep\"\n").unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.n_variants(), 3);

        let base = RunConfig::default();
        assert_eq!(base.n_variants(), 1);
        assert_eq!(base.min_batch_effective(), base.rl.n_envs);
        let v = base.variant_for(5);
        assert_eq!(v.index, 0);
        assert_eq!(v.case, base.case);
        assert_eq!(v.init_family, None);
    }

    #[test]
    fn backend_field_parses_and_validates() {
        assert_eq!(RunConfig::default().rl.backend, "les");
        let doc = Toml::parse("[rl]\nbackend = \"burgers\"\n").unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.rl.backend, "burgers");
        let doc = Toml::parse("[rl]\nbackend = \"flexi\"\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn burgers_section_overrides_and_validates() {
        let doc = Toml::parse(
            "[rl]\nbackend = \"burgers\"\n[burgers]\npoints = 64\nsegments = 4\nk_max = 6\n",
        )
        .unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.burgers.points, 64);
        assert_eq!(c.burgers.segments, 4);
        assert_eq!(c.burgers.k_max, 6);
        // Segments must divide points.
        let doc = Toml::parse(
            "[rl]\nbackend = \"burgers\"\n[burgers]\npoints = 64\nsegments = 5\n",
        )
        .unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        // k_max beyond the Burgers Nyquist.
        let doc = Toml::parse(
            "[rl]\nbackend = \"burgers\"\n[burgers]\npoints = 16\nk_max = 9\n",
        )
        .unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        // The same overrides are inert under the LES backend.
        let doc = Toml::parse("[burgers]\npoints = 16\nk_max = 9\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_ok());
    }

    #[test]
    fn variant_checks_follow_the_backend() {
        // k_max = 20 is beyond the 12^3 LES Nyquist but fine for the
        // default 96-point Burgers spectrum.
        let toml = "[rl]\nbackend = \"BACKEND\"\nvariant_names = [\"a\"]\nvariant_k_max = [20]\n\
                    [case]\nn = 5\nelems_per_dir = 2\nk_max = 3\n";
        let les = Toml::parse(&toml.replace("BACKEND", "les")).unwrap();
        assert!(RunConfig::from_toml(&les).is_err());
        let burgers = Toml::parse(&toml.replace("BACKEND", "burgers")).unwrap();
        let c = RunConfig::from_toml(&burgers).unwrap();
        assert_eq!(c.rl.variants[0].k_max, Some(20));
        // The raw knobs ride along on the resolved variant.
        assert_eq!(c.variant_for(0).variant.k_max, Some(20));
        assert_eq!(c.base_resolved().variant, EnvVariant::default());
    }

    #[test]
    fn example_config_parses_and_validates() {
        // The documented example config must stay loadable (it is the
        // reference for every section, including `[runtime]`).
        let path =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/config.toml");
        let doc = Toml::load(&path).unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.runtime.backend, "xla");
        assert_eq!(c.runtime.hidden, vec![64, 64]);
        assert_eq!(c.runtime.lr, 1e-4);
        assert_eq!(c.rl.n_envs, 16);
        assert_eq!(c.case.name, "24dof");
    }

    #[test]
    fn runtime_section_parses_and_defaults_to_xla() {
        let base = RunConfig::default();
        assert_eq!(base.runtime.backend, "xla");
        assert_eq!(base.runtime.hidden, vec![64, 64]);
        let doc = Toml::parse(
            "[runtime]\nbackend = \"native\"\nhidden = [32, 16]\nlr = 0.003\nclip_eps = 0.1\n",
        )
        .unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.runtime.backend, "native");
        assert_eq!(c.runtime.hidden, vec![32, 16]);
        assert_eq!(c.runtime.lr, 0.003);
        assert_eq!(c.runtime.clip_eps, 0.1);
        // Untouched knobs keep their defaults.
        assert_eq!(c.runtime.vf_coef, 0.5);
        assert!((c.runtime.log_std_init - (0.05f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn invalid_runtime_section_rejected() {
        for bad in [
            "[runtime]\nbackend = \"tpu\"\n",
            "[runtime]\nbackend = \"native\"\nhidden = []\n",
            "[runtime]\nhidden = [-3]\n",
            "[runtime]\nlr = 0\n",
            "[runtime]\nclip_eps = 1.5\n",
            "[runtime]\nvf_coef = -0.1\n",
        ] {
            let doc = Toml::parse(bad).unwrap();
            assert!(RunConfig::from_toml(&doc).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn native_runtime_lifts_the_artifact_shape_constraint() {
        // N = 6 has no compiled artifacts: rejected under xla, fine under
        // the shape-agnostic native runtime.
        let doc = Toml::parse("[case]\nn = 6\nk_max = 3\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        let doc = Toml::parse("[case]\nn = 6\nk_max = 3\n[runtime]\nbackend = \"native\"\n")
            .unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.case.n, 6);
    }

    #[test]
    fn orchestrator_section_parses_and_defaults_to_inproc_threads() {
        let base = RunConfig::default();
        assert_eq!(base.orchestrator.transport, "inproc");
        assert_eq!(base.orchestrator.workers, "threads");
        assert_eq!(base.orchestrator.env_procs, 0, "0 = launcher-planned");
        assert_eq!(base.orchestrator.connect_retries, 3);
        assert!(base.orchestrator.worker_bin.is_empty());
        // The PR-8 supervision knobs default to the former hardcoded
        // consts (600/120/10 s) and a 1 s heartbeat with 10 s expiry.
        assert_eq!(base.orchestrator.poll_timeout_s, 600.0);
        assert_eq!(base.orchestrator.hello_timeout_s, 120.0);
        assert_eq!(base.orchestrator.reap_timeout_s, 10.0);
        assert_eq!(base.orchestrator.heartbeat_period_ms, 1000);
        assert_eq!(base.orchestrator.heartbeat_expiry_ms, 10_000);
        assert!(base.orchestrator.batch_ops, "batched exchange is the default");
        let doc = Toml::parse(
            "[rl]\nbackend = \"burgers\"\n\
             [orchestrator]\ntransport = \"tcp\"\nworkers = \"processes\"\n\
             env_procs = 2\nbind = \"127.0.0.1:7700\"\nconnect_retries = 5\n\
             poll_timeout_s = 30\nhello_timeout_s = 12.5\nreap_timeout_s = 3\n\
             heartbeat_period_ms = 200\nheartbeat_expiry_ms = 1500\nbatch_ops = false\n",
        )
        .unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.orchestrator.transport, "tcp");
        assert_eq!(c.orchestrator.workers, "processes");
        assert_eq!(c.orchestrator.env_procs, 2);
        assert_eq!(c.orchestrator.bind, "127.0.0.1:7700");
        assert_eq!(c.orchestrator.connect_retries, 5);
        assert_eq!(c.orchestrator.poll_timeout_s, 30.0);
        assert_eq!(c.orchestrator.hello_timeout_s, 12.5);
        assert_eq!(c.orchestrator.reap_timeout_s, 3.0);
        assert_eq!(c.orchestrator.heartbeat_period_ms, 200);
        assert_eq!(c.orchestrator.heartbeat_expiry_ms, 1500);
        assert!(!c.orchestrator.batch_ops);
    }

    #[test]
    fn fault_section_parses_and_defaults() {
        let base = RunConfig::default();
        assert_eq!(base.fault.max_respawns, 2);
        assert!(base.fault.plan.is_empty());
        let doc = Toml::parse(
            "[fault]\nmax_respawns = 0\nplan = \"kill:w0@1;drop:3;delay:5:250\"\n",
        )
        .unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.fault.max_respawns, 0);
        assert_eq!(c.fault.plan, "kill:w0@1;drop:3;delay:5:250");
    }

    #[test]
    fn invalid_orchestrator_section_rejected() {
        for bad in [
            // Unknown transport / workers mode.
            "[orchestrator]\ntransport = \"udp\"\n",
            "[orchestrator]\nworkers = \"fibers\"\n",
            // Threads mode is the in-process baseline.
            "[orchestrator]\ntransport = \"tcp\"\n",
            // Process workers need a network-capable transport ...
            "[rl]\nbackend = \"burgers\"\n[orchestrator]\nworkers = \"processes\"\n",
            // ... and only the Burgers backend supports them.
            "[orchestrator]\nworkers = \"processes\"\ntransport = \"tcp\"\n",
            // More worker processes than envs.
            "[rl]\nbackend = \"burgers\"\nn_envs = 2\n\
             [orchestrator]\nworkers = \"processes\"\ntransport = \"shm\"\nenv_procs = 3\n",
            "[orchestrator]\nconnect_retries = 0\n",
            // Supervision knobs must be positive / ordered.
            "[orchestrator]\npoll_timeout_s = 0\n",
            "[orchestrator]\nhello_timeout_s = -1\n",
            "[orchestrator]\nreap_timeout_s = 0.0\n",
            "[orchestrator]\nheartbeat_period_ms = 0\n",
            "[orchestrator]\nheartbeat_period_ms = 500\nheartbeat_expiry_ms = 500\n",
            // Malformed fault plans are rejected at load time.
            "[fault]\nplan = \"kill:w0\"\n",
            "[fault]\nplan = \"explode:w0@1\"\n",
            "[fault]\nplan = \"drop:\"\n",
        ] {
            let doc = Toml::parse(bad).unwrap();
            assert!(RunConfig::from_toml(&doc).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn to_toml_string_round_trips_every_section() {
        // The worker process rebuilds its envs from this string, so the
        // round trip must preserve every knob bit-for-bit — compare the
        // full Debug rendering (f64 Debug/Display are shortest-repr
        // round-trippable, so equality here is exact equality).
        let doc = Toml::parse(
            "[case]\npreset = \"32dof\"\nalpha = 0.7\n\
             [solver]\nnu = 0.031\nt_end = 2.5\n\
             [burgers]\npoints = 48\nsegments = 4\nnoise_amp = 0.3\ntruth_seed = 99\n\
             [rl]\nbackend = \"burgers\"\nn_envs = 8\nseed = 7\ngamma = 0.97\n\
             min_batch = 3\nsplit_init_pool = true\n\
             variant_names = [\"a\", \"b\"]\nvariant_nu_scale = [1.0, 2.0]\n\
             variant_t_end_scale = [1.0, 0.5]\nvariant_alpha = [0, 0.8]\nvariant_k_max = [0, 4]\n\
             [runtime]\nbackend = \"native\"\nhidden = [32, 16]\nlr = 0.003\n\
             [hpc]\nthreads = 4\ndb_shards = 2\ndb_seqlock_wake = true\nmpmd = false\n\
             [orchestrator]\ntransport = \"tcp\"\nworkers = \"processes\"\nenv_procs = 2\n\
             bind = \"127.0.0.1:7700\"\nworker_bin = \"target/release/relexi\"\n\
             poll_timeout_s = 45.5\nheartbeat_period_ms = 250\nheartbeat_expiry_ms = 2000\n\
             [fault]\nmax_respawns = 1\nplan = \"killput:w0@40;hbstall:w1@2\"\n\
             [telemetry]\nenabled = true\nbuffer_capacity = 4096\n\
             log_level = \"debug\"\ntrace_path = \"out/trace.json\"\n\
             [paths]\nartifacts = \"art\"\nout = \"runs/x\"\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        let text = cfg.to_toml_string();
        let back = RunConfig::from_toml(&Toml::parse(&text).unwrap()).unwrap();
        assert_eq!(format!("{cfg:?}"), format!("{back:?}"), "round trip:\n{text}");

        // The default config round-trips too (incl. ln(0.05) and the
        // empty variant list / empty worker_bin).
        let d = RunConfig::default();
        let back = RunConfig::from_toml(&Toml::parse(&d.to_toml_string()).unwrap()).unwrap();
        assert_eq!(format!("{d:?}"), format!("{back:?}"));
    }

    #[test]
    fn telemetry_section_parses_and_rejects_bad_values() {
        let doc = Toml::parse(
            "[telemetry]\nenabled = true\nbuffer_capacity = 2048\nlog_level = \"warn\"\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert!(cfg.telemetry.enabled);
        assert_eq!(cfg.telemetry.buffer_capacity, 2048);
        assert_eq!(cfg.telemetry.log_level, "warn");
        assert_eq!(cfg.telemetry.trace_path, "");
        // Defaults: disabled, info level.
        let d = RunConfig::default();
        assert!(!d.telemetry.enabled);
        assert_eq!(d.telemetry.log_level, "info");
        // Invalid level / undersized ring are rejected at validate time.
        let doc = Toml::parse("[telemetry]\nlog_level = \"loud\"\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        let doc = Toml::parse("[telemetry]\nbuffer_capacity = 8\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn invalid_variants_rejected() {
        // Length mismatch between parallel arrays.
        let doc = Toml::parse(
            "[rl]\nvariant_names = [\"a\", \"b\"]\nvariant_nu_scale = [1.0]\n",
        )
        .unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        // min_batch beyond the pool.
        let doc = Toml::parse("[rl]\nn_envs = 2\nmin_batch = 3\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        // More variants than envs.
        let doc = Toml::parse(
            "[rl]\nn_envs = 2\nvariant_names = [\"a\", \"b\", \"c\"]\n",
        )
        .unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        // Variant k_max beyond Nyquist.
        let doc = Toml::parse(
            "[rl]\nvariant_names = [\"a\"]\nvariant_k_max = [100]\n",
        )
        .unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
    }
}
