//! TOML-subset parser for run configuration files (no `serde`/`toml` crates
//! in the image).  Supported: `[section]` / `[a.b]` headers, `key = value`
//! with strings, integers, floats, booleans, and flat arrays; `#` comments.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    /// Integer accessor (also accepts exact floats).
    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            Value::Float(f) if f.fract() == 0.0 => Ok(*f as i64),
            _ => bail!("expected integer, got {self:?}"),
        }
    }

    /// Float accessor (accepts ints).
    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            _ => bail!("expected float, got {self:?}"),
        }
    }

    /// String accessor.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// Boolean accessor.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    /// Array-of-integers accessor.
    pub fn as_int_vec(&self) -> Result<Vec<i64>> {
        match self {
            Value::Array(v) => v.iter().map(|x| x.as_int()).collect(),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// Array-of-floats accessor (accepts ints).
    pub fn as_float_vec(&self) -> Result<Vec<f64>> {
        match self {
            Value::Array(v) => v.iter().map(|x| x.as_float()).collect(),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// Array-of-strings accessor.
    pub fn as_str_vec(&self) -> Result<Vec<String>> {
        match self {
            Value::Array(v) => v.iter().map(|x| x.as_str().map(str::to_string)).collect(),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    fn parse_scalar(text: &str) -> Result<Value> {
        let t = text.trim();
        if t.is_empty() {
            bail!("empty value");
        }
        if let Some(inner) = t.strip_prefix('"') {
            let inner = inner
                .strip_suffix('"')
                .ok_or_else(|| anyhow!("unterminated string: {t}"))?;
            return Ok(Value::Str(inner.replace("\\n", "\n").replace("\\\"", "\"")));
        }
        if t == "true" {
            return Ok(Value::Bool(true));
        }
        if t == "false" {
            return Ok(Value::Bool(false));
        }
        if let Ok(i) = t.replace('_', "").parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = t.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        bail!("cannot parse value: {t:?}")
    }

    fn parse(text: &str) -> Result<Value> {
        let t = text.trim();
        if let Some(inner) = t.strip_prefix('[') {
            let inner = inner
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("unterminated array: {t}"))?;
            let mut items = Vec::new();
            if !inner.trim().is_empty() {
                for part in split_top_level(inner) {
                    items.push(Value::parse_scalar(&part)?);
                }
            }
            return Ok(Value::Array(items));
        }
        Value::parse_scalar(t)
    }
}

/// Split a flat array body on commas (strings may contain commas).
fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                parts.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur.trim().to_string());
    }
    parts
}

/// A parsed TOML document: dotted keys -> values.
#[derive(Debug, Clone, Default)]
pub struct Toml {
    entries: BTreeMap<String, Value>,
}

impl Toml {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<Toml> {
        let mut doc = Toml::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(h) = line.strip_prefix('[') {
                let name = h
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: bad section {raw:?}", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value: {raw:?}", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = Value::parse(v)
                .with_context(|| format!("line {}: {raw:?}", lineno + 1))?;
            doc.entries.insert(key, val);
        }
        Ok(doc)
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Toml> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Toml::parse(&text)
    }

    /// Look up a dotted key.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// Set / override a dotted key.
    pub fn set(&mut self, key: &str, value: Value) {
        self.entries.insert(key.to_string(), value);
    }

    /// Override from a raw string (CLI overlay); value syntax as in TOML.
    pub fn set_raw(&mut self, key: &str, raw: &str) -> Result<()> {
        // Allow bare strings from the CLI (no quotes needed).
        let v = Value::parse(raw).unwrap_or_else(|_| Value::Str(raw.to_string()));
        self.entries.insert(key.to_string(), v);
        Ok(())
    }

    /// Typed getters with defaults.
    pub fn int_or(&self, key: &str, default: i64) -> Result<i64> {
        self.get(key).map(|v| v.as_int()).unwrap_or(Ok(default))
    }

    pub fn float_or(&self, key: &str, default: f64) -> Result<f64> {
        self.get(key).map(|v| v.as_float()).unwrap_or(Ok(default))
    }

    pub fn str_or(&self, key: &str, default: &str) -> Result<String> {
        self.get(key)
            .map(|v| v.as_str().map(|s| s.to_string()))
            .unwrap_or(Ok(default.to_string()))
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        self.get(key).map(|v| v.as_bool()).unwrap_or(Ok(default))
    }

    /// All keys (for validation / debugging).
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# run configuration
title = "hit24"            # inline comment
[env]
n = 5
elems = 4
t_end = 5.0
deterministic = false
ranks = [2, 4, 8, 16]
[rl.ppo]
lr = 1e-4
"#;

    #[test]
    fn parses_sections_and_types() {
        let t = Toml::parse(DOC).unwrap();
        assert_eq!(t.get("title").unwrap().as_str().unwrap(), "hit24");
        assert_eq!(t.get("env.n").unwrap().as_int().unwrap(), 5);
        assert_eq!(t.get("env.t_end").unwrap().as_float().unwrap(), 5.0);
        assert!(!t.get("env.deterministic").unwrap().as_bool().unwrap());
        assert_eq!(
            t.get("env.ranks").unwrap().as_int_vec().unwrap(),
            vec![2, 4, 8, 16]
        );
        assert_eq!(t.get("rl.ppo.lr").unwrap().as_float().unwrap(), 1e-4);
    }

    #[test]
    fn typed_array_accessors() {
        let t = Toml::parse(
            "names = [\"a\", \"b, c\"]\nscales = [0.5, 1, 2.0]\nints = [1, 2]\n",
        )
        .unwrap();
        assert_eq!(
            t.get("names").unwrap().as_str_vec().unwrap(),
            vec!["a".to_string(), "b, c".to_string()]
        );
        assert_eq!(
            t.get("scales").unwrap().as_float_vec().unwrap(),
            vec![0.5, 1.0, 2.0]
        );
        // Mixed / wrong element types are rejected.
        assert!(t.get("names").unwrap().as_float_vec().is_err());
        assert!(t.get("ints").unwrap().as_str_vec().is_err());
        assert!(Value::Int(3).as_float_vec().is_err());
    }

    #[test]
    fn defaults_apply() {
        let t = Toml::parse(DOC).unwrap();
        assert_eq!(t.int_or("missing.key", 7).unwrap(), 7);
        assert_eq!(t.float_or("env.n", 0.0).unwrap(), 5.0);
    }

    #[test]
    fn overrides() {
        let mut t = Toml::parse(DOC).unwrap();
        t.set_raw("env.n", "7").unwrap();
        assert_eq!(t.get("env.n").unwrap().as_int().unwrap(), 7);
        t.set_raw("title", "other").unwrap();
        assert_eq!(t.get("title").unwrap().as_str().unwrap(), "other");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Toml::parse("[unclosed").is_err());
        assert!(Toml::parse("novalue").is_err());
        assert!(Toml::parse("x = ").is_err());
    }

    #[test]
    fn comment_inside_string_kept() {
        let t = Toml::parse("s = \"a # b\"").unwrap();
        assert_eq!(t.get("s").unwrap().as_str().unwrap(), "a # b");
    }

    #[test]
    fn underscored_ints() {
        let t = Toml::parse("n = 13_824").unwrap();
        assert_eq!(t.get("n").unwrap().as_int().unwrap(), 13_824);
    }
}
