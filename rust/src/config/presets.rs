//! Table 1 of the paper as code: the two investigated LES configurations.
//!
//! | name   | N | #Elems | #DOF   | k_max | alpha |
//! |--------|---|--------|--------|-------|-------|
//! | 24 DOF | 5 | 4^3    | 13,824 | 9     | 0.4   |
//! | 32 DOF | 7 | 4^3    | 32,768 | 12    | 0.2   |

use super::CaseConfig;
use anyhow::{bail, Result};

/// The "24 DOF" configuration (Table 1, row 1).
pub fn dof24() -> CaseConfig {
    CaseConfig {
        name: "24dof".to_string(),
        n: 5,
        elems_per_dir: 4,
        k_max: 9,
        alpha: 0.4,
    }
}

/// The "32 DOF" configuration (Table 1, row 2).
pub fn dof32() -> CaseConfig {
    CaseConfig {
        name: "32dof".to_string(),
        n: 7,
        elems_per_dir: 4,
        k_max: 12,
        alpha: 0.2,
    }
}

/// Look up a preset by name ("24dof" / "32dof").
pub fn by_name(name: &str) -> Result<CaseConfig> {
    match name {
        "24dof" | "24" => Ok(dof24()),
        "32dof" | "32" => Ok(dof32()),
        _ => bail!("unknown case preset {name:?} (expected 24dof or 32dof)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_dof_counts() {
        // #DOF = #Elems * (N+1)^3
        assert_eq!(dof24().total_dof(), 13_824);
        assert_eq!(dof32().total_dof(), 32_768);
        assert_eq!(dof24().points_per_dir(), 24);
        assert_eq!(dof32().points_per_dir(), 32);
    }

    #[test]
    fn table1_hyperparameters() {
        assert_eq!(dof24().k_max, 9);
        assert_eq!(dof32().k_max, 12);
        assert!((dof24().alpha - 0.4).abs() < 1e-12);
        assert!((dof32().alpha - 0.2).abs() < 1e-12);
    }

    #[test]
    fn lookup() {
        assert_eq!(by_name("24dof").unwrap(), dof24());
        assert_eq!(by_name("32").unwrap(), dof32());
        assert!(by_name("48dof").is_err());
    }
}
