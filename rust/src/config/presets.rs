//! Table 1 of the paper as code: the two investigated LES configurations,
//! plus named scenario-family presets for heterogeneous pools.
//!
//! | name   | N | #Elems | #DOF   | k_max | alpha |
//! |--------|---|--------|--------|-------|-------|
//! | 24 DOF | 5 | 4^3    | 13,824 | 9     | 0.4   |
//! | 32 DOF | 7 | 4^3    | 32,768 | 12    | 0.2   |

use super::{CaseConfig, EnvVariant};
use anyhow::{bail, Result};

/// The "24 DOF" configuration (Table 1, row 1).
pub fn dof24() -> CaseConfig {
    CaseConfig {
        name: "24dof".to_string(),
        n: 5,
        elems_per_dir: 4,
        k_max: 9,
        alpha: 0.4,
    }
}

/// The "32 DOF" configuration (Table 1, row 2).
pub fn dof32() -> CaseConfig {
    CaseConfig {
        name: "32dof".to_string(),
        n: 7,
        elems_per_dir: 4,
        k_max: 12,
        alpha: 0.2,
    }
}

/// Look up a preset by name ("24dof" / "32dof").
pub fn by_name(name: &str) -> Result<CaseConfig> {
    match name {
        "24dof" | "24" => Ok(dof24()),
        "32dof" | "32" => Ok(dof32()),
        _ => bail!("unknown case preset {name:?} (expected 24dof or 32dof)"),
    }
}

/// Reynolds-number sweep: one pool training across three viscosity
/// families around the base case (nu x2 / x1 / x0.5).
pub fn re_sweep() -> Vec<EnvVariant> {
    [("re_low", 2.0), ("re_base", 1.0), ("re_high", 0.5)]
        .into_iter()
        .map(|(name, nu_scale)| EnvVariant {
            name: name.to_string(),
            nu_scale,
            ..EnvVariant::default()
        })
        .collect()
}

/// Mixed-horizon pool: half the envs run full episodes, half terminate at
/// t_end/2 — a standing exercise of the early-done protocol path.
pub fn horizon_mix() -> Vec<EnvVariant> {
    vec![
        EnvVariant::default(),
        EnvVariant {
            name: "short".to_string(),
            t_end_scale: 0.5,
            ..EnvVariant::default()
        },
    ]
}

/// Reward-shaping mix: the base reward plus a stricter family (larger
/// alpha, lower cutoff) sharing the same physics.
pub fn reward_mix(base: &CaseConfig) -> Vec<EnvVariant> {
    vec![
        EnvVariant::default(),
        EnvVariant {
            name: "strict".to_string(),
            alpha: Some(base.alpha * 2.0),
            k_max: Some((base.k_max / 2).max(1)),
            ..EnvVariant::default()
        },
    ]
}

/// Look up a scenario-family preset by name (`rl.variant_preset`),
/// resolved against the run's configured base case.
pub fn variant_preset(name: &str, base: &CaseConfig) -> Result<Vec<EnvVariant>> {
    match name {
        "re-sweep" | "re_sweep" => Ok(re_sweep()),
        "horizon-mix" | "horizon_mix" => Ok(horizon_mix()),
        "reward-mix" | "reward_mix" => Ok(reward_mix(base)),
        _ => bail!(
            "unknown variant preset {name:?} (expected re-sweep, horizon-mix or reward-mix)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_dof_counts() {
        // #DOF = #Elems * (N+1)^3
        assert_eq!(dof24().total_dof(), 13_824);
        assert_eq!(dof32().total_dof(), 32_768);
        assert_eq!(dof24().points_per_dir(), 24);
        assert_eq!(dof32().points_per_dir(), 32);
    }

    #[test]
    fn table1_hyperparameters() {
        assert_eq!(dof24().k_max, 9);
        assert_eq!(dof32().k_max, 12);
        assert!((dof24().alpha - 0.4).abs() < 1e-12);
        assert!((dof32().alpha - 0.2).abs() < 1e-12);
    }

    #[test]
    fn lookup() {
        assert_eq!(by_name("24dof").unwrap(), dof24());
        assert_eq!(by_name("32").unwrap(), dof32());
        assert!(by_name("48dof").is_err());
    }

    #[test]
    fn variant_presets_resolve_and_validate() {
        let re = variant_preset("re-sweep", &dof24()).unwrap();
        assert_eq!(re.len(), 3);
        assert!(re.iter().any(|v| v.nu_scale > 1.0));
        assert!(re.iter().any(|v| v.nu_scale < 1.0));

        let hz = variant_preset("horizon_mix", &dof24()).unwrap();
        assert_eq!(hz.len(), 2);
        assert!(hz[1].t_end_scale < 1.0);

        // reward-mix scales the *configured* base case, not a hardcoded one.
        for case in [dof24(), dof32()] {
            let rw = variant_preset("reward-mix", &case).unwrap();
            assert_eq!(rw[1].alpha, Some(case.alpha * 2.0));
            assert_eq!(rw[1].k_max, Some((case.k_max / 2).max(1)));
        }

        assert!(variant_preset("nope", &dof24()).is_err());

        // Every preset passes RunConfig validation on the default case.
        for name in ["re-sweep", "horizon-mix", "reward-mix"] {
            let mut cfg = crate::config::RunConfig::default();
            cfg.rl.variants = variant_preset(name, &cfg.case.clone()).unwrap();
            cfg.validate().unwrap();
        }
    }
}
