//! `relexi` — the leader binary: truth generation, training, evaluation
//! and scaling studies from one CLI.
//!
//! ```text
//! relexi gen-truth  [--config cfg.toml] [--out truth.bin] [--case.preset 24dof]
//! relexi train      [--config cfg.toml] [--truth truth.bin] [--rl.iterations N] ...
//! relexi eval       --truth truth.bin --checkpoint policy.bin
//! relexi scaling    [--mode weak|strong] [--case.preset 24dof]
//! relexi env-worker --connect host:port [--transport tcp|shm] [--worker-id N]
//! relexi info
//! ```
//!
//! Any dotted config key (`--rl.n_envs 16`, `--solver.t_end 2.0`) can be
//! passed as a CLI override.

use anyhow::{bail, Context, Result};
use relexi::config::RunConfig;
use relexi::coordinator::{eval_baseline, eval_policy, MetricsLog, TrainingLoop};
use relexi::hpc::{steps_per_action_for, strong_scaling, weak_scaling, ClusterSim};
use relexi::solver::dns::{generate, Truth, TruthParams};
use relexi::util::bench::Table;
use relexi::util::cli::Args;
use std::path::Path;
use std::sync::Arc;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let overrides = args
        .overrides()
        .map(|(k, v)| (k.clone(), v.clone()));
    RunConfig::load(args.get("config"), overrides)
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("gen-truth") => cmd_gen_truth(&args),
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("scaling") => cmd_scaling(&args),
        Some("env-worker") => cmd_env_worker(&args),
        Some("info") => cmd_info(),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand {cmd:?}\n");
            }
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "relexi — RL for CFD on HPC systems (Kurz et al. 2022 reproduction)\n\n\
         USAGE: relexi <subcommand> [--config file.toml] [--dotted.key value ...]\n\n\
         SUBCOMMANDS:\n\
           gen-truth   run the DNS, build the ground-truth package (--out)\n\
           train       run the PPO training loop (--truth, --rl.iterations, ...)\n\
           eval        evaluate a checkpoint vs the baselines (--checkpoint)\n\
           scaling     regenerate the Fig. 3/4 scaling studies (--mode weak|strong)\n\
           env-worker  host an env block as a separate process dialing the exchange\n\
                       (--connect host:port --transport tcp|shm --worker-id N\n\
                        --env-start N --env-count N --generation N;\n\
                        config via RELEXI_WORKER_CONFIG, faults via RELEXI_FAULT_PLAN)\n\
           info        print artifact/runtime diagnostics"
    );
}

fn cmd_gen_truth(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    // Truth generation runs outside TrainingLoop, so size the kernel
    // worker pool (FFT planes, DNS filter loops) here.
    relexi::util::pool::configure_global(cfg.hpc.threads);
    let out = args.get_or("out", &format!("runs/truth_{}.bin", cfg.case.name));
    let params = TruthParams {
        n_dns: cfg.solver.dns_points,
        n_les: cfg.case.points_per_dir(),
        nu: cfg.solver.nu,
        ke_target: cfg.solver.ke_target,
        spinup_time: args.get_parse("spinup", 4.0f64)?,
        n_states: args.get_parse("states", 10usize)?,
        sample_interval: args.get_parse("interval", 0.5f64)?,
        seed: cfg.rl.seed,
    };
    println!(
        "generating truth: DNS {}^3 -> LES {}^3, {} states + 1 test",
        params.n_dns, params.n_les, params.n_states
    );
    let t0 = std::time::Instant::now();
    let truth = generate(&params, |i, total| {
        println!("  sample {i}/{total} ({:.1}s)", t0.elapsed().as_secs_f64());
    });
    truth.save(Path::new(&out))?;
    println!("wrote {out} ({:.1}s)", t0.elapsed().as_secs_f64());
    println!("DNS mean spectrum (k: E):");
    for (k, e) in truth.mean_spectrum.iter().enumerate().skip(1) {
        println!("  {k:>3}: {e:.6e}");
    }
    Ok(())
}

fn default_truth_path(cfg: &RunConfig) -> String {
    format!("runs/truth_{}.bin", cfg.case.name)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    relexi::util::telemetry::init(
        cfg.telemetry.enabled,
        cfg.telemetry.buffer_capacity,
        &cfg.telemetry.log_level,
        "trainer",
    );
    // Only the LES backend consumes the 3D DNS truth package; other
    // backends (burgers) generate their own ground truth from the config.
    let truth = if cfg.rl.backend == "les" {
        let truth_path = args.get_or("truth", &default_truth_path(&cfg));
        Some(Arc::new(
            Truth::load(Path::new(&truth_path))
                .with_context(|| format!("load {truth_path}; run `relexi gen-truth` first"))?,
        ))
    } else {
        None
    };
    std::fs::create_dir_all(&cfg.out_dir)?;
    let csv = Path::new(&cfg.out_dir).join("training.csv");
    let mut log = MetricsLog::with_csv(&csv)?;
    println!(
        "training: backend {} | runtime {} | case {} | {} envs x {} actions | {} iterations{}",
        cfg.rl.backend,
        cfg.runtime.backend,
        cfg.case.name,
        cfg.rl.n_envs,
        cfg.backend_steps_per_episode(),
        cfg.rl.iterations,
        if cfg.runtime.backend == "xla" {
            format!(" | artifacts {}", cfg.artifacts_dir)
        } else {
            " | artifact-free".to_string()
        }
    );
    let mut lp = TrainingLoop::from_config(cfg, truth)?;
    if let Some(ckpt) = args.get("checkpoint") {
        lp.load_checkpoint(Path::new(ckpt))?;
        println!("resumed from {ckpt}");
    }
    lp.run(&mut log)?;
    println!(
        "done: best normalized return {:.4}; metrics -> {}",
        log.best_return(),
        csv.display()
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    // The Fig.-5 evaluation (and both Cs baselines) rolls out on the
    // LES test state; the Burgers backend is evaluated inside its CI
    // learning smoke instead.
    anyhow::ensure!(
        cfg.rl.backend == "les",
        "`relexi eval` rolls out on the LES test state; rl.backend {:?} is evaluated \
         through the CI learning smoke / benches instead",
        cfg.rl.backend
    );
    let truth_path = args.get_or("truth", &default_truth_path(&cfg));
    let truth = Arc::new(Truth::load(Path::new(&truth_path))?);

    // Either runtime backend serves the policy: compiled artifacts, or
    // the artifact-free native MLP sized for the LES element shape.
    let checkpoint = args.get("checkpoint");
    let (policy, theta): (Box<dyn relexi::runtime::Policy>, Vec<f32>) =
        match cfg.runtime.backend.as_str() {
            "native" => {
                let features = cfg.case.elem_features();
                let spec = relexi::runtime::NativeSpec::from_config(&cfg, features)?;
                let theta = match checkpoint {
                    Some(p) => relexi::util::binio::read_f32_vec(Path::new(p))?,
                    None => spec.init_theta(),
                };
                anyhow::ensure!(
                    theta.len() == spec.param_count(),
                    "checkpoint has {} params but runtime.hidden {:?} on {features} \
                     features needs {}",
                    theta.len(),
                    spec.hidden,
                    spec.param_count()
                );
                (Box::new(relexi::runtime::NativePolicy::new(spec)), theta)
            }
            _ => {
                let rt = relexi::runtime::Runtime::cpu()?;
                let reg = relexi::runtime::Registry::open(Path::new(&cfg.artifacts_dir))?;
                let policy = relexi::runtime::PolicyRuntime::load(&rt, &reg, cfg.case.n)?;
                let theta = match checkpoint {
                    Some(p) => relexi::util::binio::read_f32_vec(Path::new(p))?,
                    None => reg.initial_params(cfg.case.n)?,
                };
                (Box::new(policy), theta)
            }
        };

    let rl = eval_policy(&cfg, &truth, policy.as_ref(), &theta, None)?;
    let smag = eval_baseline(&cfg, &truth, cfg.solver.smagorinsky_cs)?;
    let implicit = eval_baseline(&cfg, &truth, 0.0)?;

    let mut t = Table::new(&["model", "normalized return"]);
    t.row(vec!["RL policy".into(), format!("{:+.4}", rl.normalized_return)]);
    t.row(vec![
        format!("Smagorinsky Cs={}", cfg.solver.smagorinsky_cs),
        format!("{:+.4}", smag.normalized_return),
    ]);
    t.row(vec!["implicit (Cs=0)".into(), format!("{:+.4}", implicit.normalized_return)]);
    t.print("Test-state returns (Fig. 5 style)");

    let mut s = Table::new(&["k", "E_DNS", "E_RL", "E_Smag", "E_implicit"]);
    for k in 1..=cfg.case.k_max {
        s.row(vec![
            k.to_string(),
            format!("{:.4e}", truth.mean_spectrum[k]),
            format!("{:.4e}", rl.final_spectrum[k]),
            format!("{:.4e}", smag.final_spectrum[k]),
            format!("{:.4e}", implicit.final_spectrum[k]),
        ]);
    }
    s.print("Final energy spectra at t_end (Fig. 5c)");

    println!("\nCs prediction distribution (Fig. 5d):");
    println!(
        "{}",
        relexi::util::stats::ascii_histogram(&rl.cs_samples, 0.0, 0.5, 20, 40)
    );
    Ok(())
}

fn cmd_scaling(args: &Args) -> Result<()> {
    let mode = args.get_or("mode", "weak");
    let nodes = args.get_parse("nodes", 16usize)?;
    let sim = ClusterSim::hawk(nodes);
    for dof in [24usize, 32] {
        let spa = steps_per_action_for(dof);
        match mode.as_str() {
            "weak" => {
                let mut t = Table::new(&["ranks/env", "n_envs", "time [s]", "speedup", "efficiency"]);
                for ranks in [2usize, 4, 8, 16] {
                    for p in weak_scaling(&sim, dof, ranks, spa)? {
                        t.row(vec![
                            ranks.to_string(),
                            p.n_envs.to_string(),
                            format!("{:.2}", p.total_s),
                            format!("{:.1}", p.speedup),
                            format!("{:.3}", p.efficiency),
                        ]);
                    }
                }
                t.print(&format!("Weak scaling, {dof} DOF (Fig. 3)"));
            }
            "strong" => {
                let mut t = Table::new(&["n_envs", "ranks/env", "time [s]", "speedup", "efficiency"]);
                for envs in [2usize, 8, 32, 128] {
                    for p in strong_scaling(&sim, dof, envs, &[2, 4, 8, 16], spa)? {
                        t.row(vec![
                            envs.to_string(),
                            p.ranks_per_env.to_string(),
                            format!("{:.2}", p.total_s),
                            format!("{:.2}", p.speedup),
                            format!("{:.3}", p.efficiency),
                        ]);
                    }
                }
                t.print(&format!("Strong scaling, {dof} DOF (Fig. 4)"));
            }
            other => bail!("unknown scaling mode {other:?} (weak|strong)"),
        }
    }
    Ok(())
}

/// `relexi env-worker` — host a contiguous block of environments as a
/// separate OS process.  Spawned by the trainer (`orchestrator.workers =
/// "processes"`), dials the trainer's exchange over `--transport`
/// (`tcp`/`shm`), announces itself with a hello flag, publishes a
/// liveness heartbeat on a configurable cadence, then serves
/// begin-iteration commands shipped through the store itself until the
/// stop flag is posted or the connection is lost (bounded reconnects are
/// handled inside the transport; exhausting them exits the worker).
///
/// `--generation` counts this worker id's incarnations (the supervisor
/// bumps it on respawn).  The deterministic fault plan (`[fault] plan`
/// or `RELEXI_FAULT_PLAN`) is evaluated against worker id + generation:
/// `kill`/`hbstall` directives act in this control loop, `killput`/
/// `drop`/`delay` directives are compiled into a [`TransportFault`]
/// driven by the transport itself.
fn cmd_env_worker(args: &Args) -> Result<()> {
    use relexi::coordinator::{FaultPlan, WorkerHost};
    use relexi::orchestrator::protocol::{
        ctl_begin_key, ctl_hb_key, ctl_hello_key, ctl_tel_key, decode_begin, CTL_STOP_KEY,
        CTL_TEL_FLUSH_KEY,
    };
    use relexi::orchestrator::{Client, RemoteTransport, TransportFault, Value};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::Duration;

    // The trainer ships its exact RunConfig through the environment so
    // both sides build identical env stacks; a standalone invocation
    // (tests, debugging) falls back to --config + dotted overrides.
    let cfg = match std::env::var("RELEXI_WORKER_CONFIG") {
        Ok(text) if !text.is_empty() => {
            let doc = relexi::config::toml::Toml::parse(&text)
                .context("parse RELEXI_WORKER_CONFIG")?;
            RunConfig::from_toml(&doc).context("RELEXI_WORKER_CONFIG")?
        }
        _ => load_config(args)?,
    };
    relexi::util::pool::configure_global(cfg.hpc.threads);

    let addr = args
        .get("connect")
        .context("env-worker needs --connect <host:port>")?
        .to_string();
    let kind = args.get_or("transport", &cfg.orchestrator.transport);
    let worker_id = args.get_parse("worker-id", 0usize)?;
    let env_start = args.get_parse("env-start", 0usize)?;
    let env_count = args.get_parse("env-count", cfg.rl.n_envs)?;
    let generation = args.get_parse("generation", 0u32)?;
    relexi::util::telemetry::init(
        cfg.telemetry.enabled,
        cfg.telemetry.buffer_capacity,
        &cfg.telemetry.log_level,
        &relexi::util::telemetry::worker_label(worker_id),
    );

    let plan = FaultPlan::from_env_or(&cfg.fault.plan)?;
    let fault = TransportFault::new(
        plan.killput_threshold(worker_id, generation),
        plan.drop_frames(),
        plan.delay_frames(),
    );
    let transport = RemoteTransport::connect_with_fault(
        &kind,
        &addr,
        cfg.orchestrator.connect_retries as u32,
        fault,
    )?;
    let client = Client::remote(transport.clone());
    let host = WorkerHost::spawn(&cfg, &client, env_start, env_count)?;
    client.put_flag(&ctl_hello_key(worker_id), true);

    // Liveness heartbeat: a monotonic counter the supervisor watches;
    // a counter frozen past `heartbeat_expiry_ms` marks this worker
    // wedged.  The hbstall directive freezes it deliberately.
    let hb_stop = Arc::new(AtomicBool::new(false));
    let hb_stalled = Arc::new(AtomicBool::new(false));
    let hb_thread = {
        let t = transport.clone();
        let stop = hb_stop.clone();
        let stalled = hb_stalled.clone();
        let key = ctl_hb_key(worker_id);
        let period = Duration::from_millis(cfg.orchestrator.heartbeat_period_ms);
        std::thread::Builder::new()
            .name(format!("hb-w{worker_id}"))
            .spawn(move || {
                let mut n = 0u64;
                // The key string is interned once and each beat encodes
                // into this persistent scratch: zero allocations per
                // beat, and a ctl-prefixed key the exchange exempts
                // from data-frame accounting — heartbeats ride outside
                // the batched waves, so liveness latency is unchanged
                // by `batch_ops`.
                let mut scratch: Vec<u8> = Vec::with_capacity(64);
                while !stop.load(Ordering::Relaxed) {
                    if !stalled.load(Ordering::Relaxed) {
                        n += 1;
                        // A failed put means the trainer is going away;
                        // the control loop notices on its own.
                        let _ = t.put_interned(&mut scratch, &key, Value::Scalar(n as f64));
                    }
                    std::thread::sleep(period);
                }
            })?
    };

    let kill_at = plan.kill_wave(worker_id, generation);
    let stall_at = plan.hbstall_wave(worker_id, generation);
    let begin_key = ctl_begin_key(worker_id);
    let tel_key = ctl_tel_key(worker_id);
    // Last telemetry-flush scalar this worker answered: the trainer bumps
    // the (non-consumed, one-per-run) flush key each iteration; NaN never
    // equals anything, so the first observation always ships.
    let mut tel_flushed = f64::NAN;
    let mut wave: u64 = 0;
    loop {
        // The stop and telemetry-flush flags are read non-consuming (one
        // key serves every worker); the begin command is taken exactly
        // once below.
        match transport.wait_any(
            &[begin_key.as_str(), CTL_STOP_KEY, CTL_TEL_FLUSH_KEY],
            Duration::from_millis(500),
            false,
        ) {
            Ok(Some((0, _))) => {
                if kill_at == Some(wave) {
                    // Fault directive: die before touching this wave's
                    // begin message (it stays in the store; the
                    // supervisor's respawn path clears it).
                    relexi::tlog!(warn, "[fault] kill: worker {worker_id} exiting at wave {wave}");
                    break;
                }
                if stall_at.is_some_and(|sw| wave >= sw) {
                    hb_stalled.store(true, Ordering::Relaxed);
                }
                match transport.take(&begin_key) {
                    Ok(Some(Value::Bytes(b))) => {
                        relexi::util::telemetry::note_begin_recv();
                        let (tag, envs) = decode_begin(&b)?;
                        host.begin(&tag, &envs)?;
                        wave += 1;
                    }
                    // Raced with a concurrent take or saw a stale type:
                    // the next wait re-observes whatever is there.
                    Ok(_) => continue,
                    Err(e) => {
                        relexi::tlog!(
                            warn,
                            "env-worker {worker_id}: exchange lost ({e:#}); exiting"
                        );
                        break;
                    }
                }
            }
            Ok(Some((2, v))) => {
                // Telemetry flush: ship this process's buffers once per
                // bump; an already-answered bump waits out a short tick
                // (the key stays put, so this arm would otherwise spin).
                match v.as_scalar() {
                    Some(s) if s != tel_flushed => {
                        tel_flushed = s;
                        client.put_bytes(&tel_key, relexi::util::telemetry::serialize_process());
                    }
                    _ => std::thread::sleep(Duration::from_millis(50)),
                }
            }
            Ok(Some(_)) => {
                // Stop flag posted: clean shutdown.  Ship the tail of the
                // telemetry buffers first (best-effort; the trainer may
                // already be gone).
                if relexi::util::telemetry::enabled() {
                    client.put_bytes(&tel_key, relexi::util::telemetry::serialize_process());
                }
                break;
            }
            Ok(None) => continue, // timeout tick; poll again
            Err(e) => {
                // RemoteTransport already retried the dial + one fresh
                // reconnect per op; a surfaced error means the trainer
                // is gone.  Exit cleanly rather than spin.
                relexi::tlog!(warn, "env-worker {worker_id}: exchange lost ({e:#}); exiting");
                break;
            }
        }
    }
    hb_stop.store(true, Ordering::Relaxed);
    let _ = hb_thread.join();
    drop(host);
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!(
        "runtime backends: {:?} (\"native\" trains any rl.backend artifact-free)",
        relexi::config::RUNTIME_BACKENDS
    );
    let rt = relexi::runtime::Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    match relexi::runtime::Registry::open(Path::new("artifacts")) {
        Ok(reg) => {
            println!("artifacts:");
            for e in &reg.entries {
                println!("  {:?} n={} batch={} -> {}", e.kind, e.n, e.batch, e.path.display());
            }
            for (n, c) in &reg.param_counts {
                println!("  params N={n}: {c} floats");
            }
        }
        Err(e) => println!("no artifact registry: {e:#}"),
    }
    Ok(())
}
