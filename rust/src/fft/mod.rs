//! Mixed-radix complex FFT, built from scratch (no FFT crate in the image).
//!
//! # Architecture (batched iterative engine)
//!
//! The pseudo-spectral solver (DESIGN.md S1/S2) needs sizes 24, 32, 48, 64,
//! 96 — products of 2, 3 and 5 — with other prime factors handled by an
//! exact O(n·p) generic-radix stage.  The engine is a **Stockham autosort**
//! FFT: an iterative decimation-in-frequency ladder that ping-pongs between
//! the data buffer and a caller-owned scratch buffer and needs no bit
//! reversal.  Three design points carry the performance:
//!
//! 1. **Batching.**  [`Plan::forward_batch`] transforms `batch` lines at
//!    once, stored transposed (`data[t * batch + b]` = element `t` of line
//!    `b`), so the innermost loop runs over the *batch* index with stride
//!    one.  Every butterfly then becomes a long contiguous elementwise
//!    loop the compiler can vectorize; a whole Stockham stage for one
//!    twiddle index `j` is a single pass over `r` contiguous input blocks
//!    into `r` contiguous output blocks.
//! 2. **Precomputed per-stage twiddle tables.**  [`Plan::new`] stores one
//!    forward and one conjugated inverse table per stage, so the kernels do
//!    no `% n` index arithmetic and no branchy `conj` — the
//!    forward/inverse decision only selects a table (and is a compile-time
//!    `const` parameter of each kernel, so the butterflies themselves are
//!    branch-free).
//! 3. **Caller-owned scratch.**  All working memory lives in
//!    [`FftScratch`], owned by the solver workspace; `Plan` is immutable
//!    after construction and therefore `Send + Sync`, so one plan can be
//!    shared by every environment worker thread.
//!
//! The 3-D transform [`fft3d_ws`] is built from three *plane-batched*
//! passes over the `idx = (z*n + y)*n + x` cube:
//!
//! * **x-pass** — each z-plane is transposed (blocked, cache-friendly) into
//!   the scratch plane so the x-lines land in batched layout (`batch = n`),
//!   transformed, and transposed back;
//! * **y-pass** — each z-plane already *is* a batched set of y-lines with
//!   `batch = n` (x is the contiguous inner index), so it is transformed in
//!   place with no data movement at all;
//! * **z-pass** — the whole cube is one batched set of z-lines with
//!   `batch = n²`, transformed in a single call.
//!
//! The original recursive per-line engine is preserved verbatim in
//! [`seed`] as the frozen baseline for `benches/bench_fft.rs`.
//!
//! # Node-level parallelism (PR 6)
//!
//! Two orthogonal layers sit on top of the batched engine:
//!
//! * **SIMD.**  The radix-2/4 butterflies and the inverse scale/copy loops
//!   are written once against [`crate::util::simd::F64x4`] (two interleaved
//!   complex numbers per vector) and instantiated per dispatch level —
//!   scalar and `#[target_feature(enable = "avx2")]` — selected at plan
//!   build time ([`Plan::new`] probes the CPU; [`Plan::with_level`] pins a
//!   level for A/B runs; `RELEXI_SIMD=scalar` forces the reference path).
//!   The twiddle multiply `d*splat(w.re) + swap_pairs(d)*[-w.im, w.im, ..]`
//!   is bit-identical to the scalar complex product (product signs are
//!   exact, `x + (-y) == x - y`, addition commutes), so **every level
//!   computes bit-identical transforms**.  Radix-3/5/generic stay scalar.
//! * **Threads.**  [`fft3d_with`] runs its x/y plane passes one z-plane per
//!   task on the persistent worker pool (`[hpc] threads`), each task using
//!   its own `buf` chunk as staging/scratch.  Per-plane arithmetic is
//!   untouched, so results are bit-identical for every pool width; the
//!   z-pass (one `batch = n²` call, memory-bound) stays serial.

use crate::util::pool::{self, Pool};
use crate::util::simd::{self, F64x4, Level};

/// Complex number (f64) with the handful of ops the FFT and solver need.
/// `#[repr(C)]` pins the `[re, im]` layout the SIMD kernels view as
/// interleaved f64 lanes.
#[repr(C)]
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Cpx {
    pub re: f64,
    pub im: f64,
}

impl Cpx {
    pub const ZERO: Cpx = Cpx { re: 0.0, im: 0.0 };
    pub const ONE: Cpx = Cpx { re: 1.0, im: 0.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> Cpx {
        Cpx { re, im }
    }

    #[inline]
    pub fn conj(self) -> Cpx {
        Cpx { re: self.re, im: -self.im }
    }

    #[inline]
    pub fn scale(self, s: f64) -> Cpx {
        Cpx { re: self.re * s, im: self.im * s }
    }

    #[inline]
    pub fn norm_sq(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// Multiply by i (used for spectral derivatives).
    #[inline]
    pub fn mul_i(self) -> Cpx {
        Cpx { re: -self.im, im: self.re }
    }

    /// Multiply by -i (the forward-transform twin of [`Cpx::mul_i`]).
    #[inline]
    pub fn mul_neg_i(self) -> Cpx {
        Cpx { re: self.im, im: -self.re }
    }
}

impl std::ops::Add for Cpx {
    type Output = Cpx;
    #[inline]
    fn add(self, o: Cpx) -> Cpx {
        Cpx::new(self.re + o.re, self.im + o.im)
    }
}

impl std::ops::Sub for Cpx {
    type Output = Cpx;
    #[inline]
    fn sub(self, o: Cpx) -> Cpx {
        Cpx::new(self.re - o.re, self.im - o.im)
    }
}

impl std::ops::Mul for Cpx {
    type Output = Cpx;
    #[inline]
    fn mul(self, o: Cpx) -> Cpx {
        Cpx::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl std::ops::AddAssign for Cpx {
    #[inline]
    fn add_assign(&mut self, o: Cpx) {
        self.re += o.re;
        self.im += o.im;
    }
}

/// View a complex slice as its interleaved `[re, im, re, im, ...]` f64
/// lanes — sound because [`Cpx`] is `#[repr(C)]` with two f64 fields.
#[inline(always)]
fn cpx_f64(s: &[Cpx]) -> &[f64] {
    // SAFETY: repr(C) { re: f64, im: f64 } has size 16, align 8, no
    // padding; reinterpreting N Cpx as 2N f64 is exact.
    unsafe { std::slice::from_raw_parts(s.as_ptr() as *const f64, 2 * s.len()) }
}

/// Mutable twin of [`cpx_f64`].
#[inline(always)]
fn cpx_f64_mut(s: &mut [Cpx]) -> &mut [f64] {
    // SAFETY: as in `cpx_f64`; exclusivity carries over from `&mut [Cpx]`.
    unsafe { std::slice::from_raw_parts_mut(s.as_mut_ptr() as *mut f64, 2 * s.len()) }
}

// ---------------------------------------------------------------------------
// SIMD butterfly passes: one body each, instantiated per dispatch level.
// Interleaved-complex vectors hold two Cpx per F64x4; the twiddle product
// and the +-i rotation are exact rewrites of the scalar complex ops, so
// both instantiations (and the scalar remainder for odd `mb`) are
// bit-identical to the original per-Cpx loops.
// ---------------------------------------------------------------------------

macro_rules! instantiate {
    ($scalar:ident, $avx2:ident, $body:ident ( $($arg:ident : $ty:ty),* )) => {
        #[allow(clippy::too_many_arguments)]
        fn $scalar($($arg: $ty),*) {
            $body($($arg),*)
        }
        #[cfg(target_arch = "x86_64")]
        #[target_feature(enable = "avx2")]
        #[allow(clippy::too_many_arguments)]
        unsafe fn $avx2($($arg: $ty),*) {
            $body($($arg),*)
        }
    };
}

/// One radix-2 twiddle group: `y0 = a + b`, `y1 = (a - b) * w` over the
/// interleaved f64 view of `mb` complex elements.
#[inline(always)]
fn radix2_body(w: Cpx, x0: &[f64], x1: &[f64], y0: &mut [f64], y1: &mut [f64]) {
    let len = x0.len();
    let len4 = len - len % 4;
    let wre = F64x4::splat(w.re);
    let wim = F64x4([-w.im, w.im, -w.im, w.im]);
    let mut i = 0;
    while i < len4 {
        let a = F64x4::load(&x0[i..]);
        let b = F64x4::load(&x1[i..]);
        a.add(b).store(&mut y0[i..]);
        let d = a.sub(b);
        d.mul(wre).add(d.swap_pairs().mul(wim)).store(&mut y1[i..]);
        i += 4;
    }
    while i < len {
        let a = Cpx::new(x0[i], x0[i + 1]);
        let b = Cpx::new(x1[i], x1[i + 1]);
        let s = a + b;
        let d = (a - b) * w;
        y0[i] = s.re;
        y0[i + 1] = s.im;
        y1[i] = d.re;
        y1[i + 1] = d.im;
        i += 2;
    }
}

instantiate!(radix2_scalar, radix2_avx2, radix2_body(w: Cpx, x0: &[f64], x1: &[f64], y0: &mut [f64], y1: &mut [f64]));

#[inline]
fn radix2_pass(level: Level, w: Cpx, x0: &[f64], x1: &[f64], y0: &mut [f64], y1: &mut [f64]) {
    match level {
        // SAFETY: Level::Avx2 only comes from the CPUID probe.
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { radix2_avx2(w, x0, x1, y0, y1) },
        _ => radix2_scalar(w, x0, x1, y0, y1),
    }
}

/// One radix-4 twiddle group.  `s` selects the +-i rotation of `t3`
/// (`+1` forward = `mul_neg_i`, `-1` inverse = `mul_i`): the rotation is
/// `swap_pairs(t3) * [s, -s, s, -s]`, exact either way.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn radix4_body(
    w1: Cpx,
    w2: Cpx,
    w3: Cpx,
    s: f64,
    x0: &[f64],
    x1: &[f64],
    x2: &[f64],
    x3: &[f64],
    y0: &mut [f64],
    y1: &mut [f64],
    y2: &mut [f64],
    y3: &mut [f64],
) {
    let len = x0.len();
    let len4 = len - len % 4;
    let rot = F64x4([s, -s, s, -s]);
    let (w1re, w1im) = (F64x4::splat(w1.re), F64x4([-w1.im, w1.im, -w1.im, w1.im]));
    let (w2re, w2im) = (F64x4::splat(w2.re), F64x4([-w2.im, w2.im, -w2.im, w2.im]));
    let (w3re, w3im) = (F64x4::splat(w3.re), F64x4([-w3.im, w3.im, -w3.im, w3.im]));
    let mut i = 0;
    while i < len4 {
        let a0 = F64x4::load(&x0[i..]);
        let a1 = F64x4::load(&x1[i..]);
        let a2 = F64x4::load(&x2[i..]);
        let a3 = F64x4::load(&x3[i..]);
        let t0 = a0.add(a2);
        let t2 = a0.sub(a2);
        let t1 = a1.add(a3);
        let t3 = a1.sub(a3);
        let t3r = t3.swap_pairs().mul(rot);
        t0.add(t1).store(&mut y0[i..]);
        let u1 = t2.add(t3r);
        u1.mul(w1re).add(u1.swap_pairs().mul(w1im)).store(&mut y1[i..]);
        let u2 = t0.sub(t1);
        u2.mul(w2re).add(u2.swap_pairs().mul(w2im)).store(&mut y2[i..]);
        let u3 = t2.sub(t3r);
        u3.mul(w3re).add(u3.swap_pairs().mul(w3im)).store(&mut y3[i..]);
        i += 4;
    }
    while i < len {
        let a0 = Cpx::new(x0[i], x0[i + 1]);
        let a1 = Cpx::new(x1[i], x1[i + 1]);
        let a2 = Cpx::new(x2[i], x2[i + 1]);
        let a3 = Cpx::new(x3[i], x3[i + 1]);
        let t0 = a0 + a2;
        let t2 = a0 - a2;
        let t1 = a1 + a3;
        let t3 = a1 - a3;
        // Same rotation formula as the vector lanes (exact).
        let t3r = Cpx::new(t3.im * s, t3.re * -s);
        let r0 = t0 + t1;
        let r1 = (t2 + t3r) * w1;
        let r2 = (t0 - t1) * w2;
        let r3 = (t2 - t3r) * w3;
        y0[i] = r0.re;
        y0[i + 1] = r0.im;
        y1[i] = r1.re;
        y1[i + 1] = r1.im;
        y2[i] = r2.re;
        y2[i + 1] = r2.im;
        y3[i] = r3.re;
        y3[i + 1] = r3.im;
        i += 2;
    }
}

instantiate!(radix4_scalar, radix4_avx2, radix4_body(w1: Cpx, w2: Cpx, w3: Cpx, s: f64, x0: &[f64], x1: &[f64], x2: &[f64], x3: &[f64], y0: &mut [f64], y1: &mut [f64], y2: &mut [f64], y3: &mut [f64]));

#[inline]
#[allow(clippy::too_many_arguments)]
fn radix4_pass(
    level: Level,
    w1: Cpx,
    w2: Cpx,
    w3: Cpx,
    s: f64,
    x0: &[f64],
    x1: &[f64],
    x2: &[f64],
    x3: &[f64],
    y0: &mut [f64],
    y1: &mut [f64],
    y2: &mut [f64],
    y3: &mut [f64],
) {
    match level {
        // SAFETY: Level::Avx2 only comes from the CPUID probe.
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { radix4_avx2(w1, w2, w3, s, x0, x1, x2, x3, y0, y1, y2, y3) },
        _ => radix4_scalar(w1, w2, w3, s, x0, x1, x2, x3, y0, y1, y2, y3),
    }
}

/// In-place `v *= s` over interleaved lanes (inverse normalization).
#[inline(always)]
fn scale_body(data: &mut [f64], s: f64) {
    let vs = F64x4::splat(s);
    let len = data.len();
    let len4 = len - len % 4;
    let mut i = 0;
    while i < len4 {
        F64x4::load(&data[i..]).mul(vs).store(&mut data[i..]);
        i += 4;
    }
    for v in &mut data[len4..] {
        *v *= s;
    }
}

instantiate!(scale_scalar, scale_avx2, scale_body(data: &mut [f64], s: f64));

#[inline]
fn scale_pass(level: Level, data: &mut [f64], s: f64) {
    match level {
        // SAFETY: Level::Avx2 only comes from the CPUID probe.
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { scale_avx2(data, s) },
        _ => scale_scalar(data, s),
    }
}

/// Fused `dst = src * s` (inverse normalization + ping-pong copy-back).
#[inline(always)]
fn scale_copy_body(dst: &mut [f64], src: &[f64], s: f64) {
    let vs = F64x4::splat(s);
    let len = dst.len();
    let len4 = len - len % 4;
    let mut i = 0;
    while i < len4 {
        F64x4::load(&src[i..]).mul(vs).store(&mut dst[i..]);
        i += 4;
    }
    for i in len4..len {
        dst[i] = src[i] * s;
    }
}

instantiate!(scale_copy_scalar, scale_copy_avx2, scale_copy_body(dst: &mut [f64], src: &[f64], s: f64));

#[inline]
fn scale_copy_pass(level: Level, dst: &mut [f64], src: &[f64], s: f64) {
    match level {
        // SAFETY: Level::Avx2 only comes from the CPUID probe.
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { scale_copy_avx2(dst, src, s) },
        _ => scale_copy_scalar(dst, src, s),
    }
}

fn factorize(mut n: usize) -> Vec<usize> {
    let mut fs = Vec::new();
    for r in [4usize, 2, 3, 5] {
        while n % r == 0 {
            fs.push(r);
            n /= r;
        }
    }
    let mut p = 7;
    while n > 1 {
        while n % p == 0 {
            fs.push(p);
            n /= p;
        }
        p += 2;
    }
    fs
}

/// One Stockham stage: radix `r`, remaining sub-transform length `l`
/// (after this stage) and interleaved group count `m` (before it).
///
/// For input viewed as `m`-interleaved sub-transforms of length `r*l`, the
/// stage computes, for every `j < l` and output row `u < r`:
/// `out[(r*j + u)*m + k] = w(rl)^(j*u) * sum_s in[(j + s*l)*m + k] * w(r)^(s*u)`
/// with `w(q) = exp(-2*pi*i/q)` — the classic DIF butterfly, autosorted.
struct Stage {
    radix: usize,
    l: usize,
    m: usize,
    /// `w(r*l)^(j*u)` for `j in 0..l`, `u in 1..r`, forward sign, laid out
    /// `[j][u-1]` (the `u = 0` column is identically one and omitted).
    fwd: Vec<Cpx>,
    /// Conjugate of `fwd` (inverse transform); a separate table so the
    /// kernels never branch on direction per butterfly.
    inv: Vec<Cpx>,
    /// `w(r)^(s*u)` laid out `[u][s]` — only populated for the generic
    /// (prime > 5) radix path; the hardcoded radices bake these in.
    fwd_radix: Vec<Cpx>,
    inv_radix: Vec<Cpx>,
}

impl Stage {
    fn new(radix: usize, l: usize, m: usize) -> Stage {
        let rl = radix * l;
        let mut fwd = Vec::with_capacity(l * (radix - 1));
        for j in 0..l {
            for u in 1..radix {
                let a = -2.0 * std::f64::consts::PI * ((j * u) % rl) as f64 / rl as f64;
                fwd.push(Cpx::new(a.cos(), a.sin()));
            }
        }
        let inv = fwd.iter().map(|c| c.conj()).collect();
        let (fwd_radix, inv_radix) = if matches!(radix, 2 | 3 | 4 | 5) {
            (Vec::new(), Vec::new())
        } else {
            let mut t = Vec::with_capacity(radix * radix);
            for u in 0..radix {
                for s in 0..radix {
                    let a = -2.0 * std::f64::consts::PI * ((s * u) % radix) as f64
                        / radix as f64;
                    t.push(Cpx::new(a.cos(), a.sin()));
                }
            }
            let ti = t.iter().map(|c: &Cpx| c.conj()).collect();
            (t, ti)
        };
        Stage { radix, l, m, fwd, inv, fwd_radix, inv_radix }
    }

    fn apply(&self, src: &[Cpx], dst: &mut [Cpx], batch: usize, inverse: bool, level: Level) {
        match (self.radix, inverse) {
            (2, false) => self.radix2::<false>(src, dst, batch, level),
            (2, true) => self.radix2::<true>(src, dst, batch, level),
            (3, false) => self.radix3::<false>(src, dst, batch),
            (3, true) => self.radix3::<true>(src, dst, batch),
            (4, false) => self.radix4::<false>(src, dst, batch, level),
            (4, true) => self.radix4::<true>(src, dst, batch, level),
            (5, false) => self.radix5::<false>(src, dst, batch),
            (5, true) => self.radix5::<true>(src, dst, batch),
            (_, false) => self.radix_any::<false>(src, dst, batch),
            (_, true) => self.radix_any::<true>(src, dst, batch),
        }
    }

    fn radix2<const INV: bool>(&self, src: &[Cpx], dst: &mut [Cpx], batch: usize, level: Level) {
        let (l, m) = (self.l, self.m);
        let mb = m * batch;
        let tw = if INV { &self.inv } else { &self.fwd };
        for j in 0..l {
            let w = tw[j];
            let x0 = cpx_f64(&src[j * mb..(j + 1) * mb]);
            let x1 = cpx_f64(&src[(j + l) * mb..(j + l + 1) * mb]);
            let (y0, y1) = dst[2 * j * mb..(2 * j + 2) * mb].split_at_mut(mb);
            radix2_pass(level, w, x0, x1, cpx_f64_mut(y0), cpx_f64_mut(y1));
        }
    }

    fn radix3<const INV: bool>(&self, src: &[Cpx], dst: &mut [Cpx], batch: usize) {
        const SQRT3_2: f64 = 0.866_025_403_784_438_6;
        let (l, m) = (self.l, self.m);
        let mb = m * batch;
        let tw = if INV { &self.inv } else { &self.fwd };
        for j in 0..l {
            let w1 = tw[2 * j];
            let w2 = tw[2 * j + 1];
            let x0 = &src[j * mb..(j + 1) * mb];
            let x1 = &src[(j + l) * mb..(j + l + 1) * mb];
            let x2 = &src[(j + 2 * l) * mb..(j + 2 * l + 1) * mb];
            let out = &mut dst[3 * j * mb..(3 * j + 3) * mb];
            let (y0, rest) = out.split_at_mut(mb);
            let (y1, y2) = rest.split_at_mut(mb);
            for i in 0..mb {
                let a = x0[i];
                let s = x1[i] + x2[i];
                let d = (x1[i] - x2[i]).scale(SQRT3_2);
                let e = a - s.scale(0.5);
                let di = if INV { d.mul_i() } else { d.mul_neg_i() };
                y0[i] = a + s;
                y1[i] = (e + di) * w1;
                y2[i] = (e - di) * w2;
            }
        }
    }

    fn radix4<const INV: bool>(&self, src: &[Cpx], dst: &mut [Cpx], batch: usize, level: Level) {
        let (l, m) = (self.l, self.m);
        let mb = m * batch;
        let tw = if INV { &self.inv } else { &self.fwd };
        // +-i rotation sign for t3 (+1 forward / -1 inverse), applied as
        // swap_pairs * [s, -s, ..] — exact vs mul_neg_i/mul_i.
        let s = if INV { -1.0 } else { 1.0 };
        for j in 0..l {
            let w1 = tw[3 * j];
            let w2 = tw[3 * j + 1];
            let w3 = tw[3 * j + 2];
            let x0 = cpx_f64(&src[j * mb..(j + 1) * mb]);
            let x1 = cpx_f64(&src[(j + l) * mb..(j + l + 1) * mb]);
            let x2 = cpx_f64(&src[(j + 2 * l) * mb..(j + 2 * l + 1) * mb]);
            let x3 = cpx_f64(&src[(j + 3 * l) * mb..(j + 3 * l + 1) * mb]);
            let out = &mut dst[4 * j * mb..(4 * j + 4) * mb];
            let (y0, rest) = out.split_at_mut(mb);
            let (y1, rest) = rest.split_at_mut(mb);
            let (y2, y3) = rest.split_at_mut(mb);
            radix4_pass(
                level,
                w1,
                w2,
                w3,
                s,
                x0,
                x1,
                x2,
                x3,
                cpx_f64_mut(y0),
                cpx_f64_mut(y1),
                cpx_f64_mut(y2),
                cpx_f64_mut(y3),
            );
        }
    }

    fn radix5<const INV: bool>(&self, src: &[Cpx], dst: &mut [Cpx], batch: usize) {
        // cos/sin of 2*pi/5 and 4*pi/5.
        const C72: f64 = 0.309_016_994_374_947_45;
        const C144: f64 = -0.809_016_994_374_947_5;
        const S72: f64 = 0.951_056_516_295_153_5;
        const S144: f64 = 0.587_785_252_292_473_1;
        let (l, m) = (self.l, self.m);
        let mb = m * batch;
        let tw = if INV { &self.inv } else { &self.fwd };
        for j in 0..l {
            let w1 = tw[4 * j];
            let w2 = tw[4 * j + 1];
            let w3 = tw[4 * j + 2];
            let w4 = tw[4 * j + 3];
            let x0 = &src[j * mb..(j + 1) * mb];
            let x1 = &src[(j + l) * mb..(j + l + 1) * mb];
            let x2 = &src[(j + 2 * l) * mb..(j + 2 * l + 1) * mb];
            let x3 = &src[(j + 3 * l) * mb..(j + 3 * l + 1) * mb];
            let x4 = &src[(j + 4 * l) * mb..(j + 4 * l + 1) * mb];
            let out = &mut dst[5 * j * mb..(5 * j + 5) * mb];
            let (y0, rest) = out.split_at_mut(mb);
            let (y1, rest) = rest.split_at_mut(mb);
            let (y2, rest) = rest.split_at_mut(mb);
            let (y3, y4) = rest.split_at_mut(mb);
            for i in 0..mb {
                let a = x0[i];
                let t1 = x1[i] + x4[i];
                let t2 = x2[i] + x3[i];
                let t3 = x1[i] - x4[i];
                let t4 = x2[i] - x3[i];
                let m1 = a + t1.scale(C72) + t2.scale(C144);
                let m2 = a + t1.scale(C144) + t2.scale(C72);
                let v1 = t3.scale(S72) + t4.scale(S144);
                let v2 = t3.scale(S144) - t4.scale(S72);
                let iv1 = if INV { v1.mul_i() } else { v1.mul_neg_i() };
                let iv2 = if INV { v2.mul_i() } else { v2.mul_neg_i() };
                y0[i] = a + t1 + t2;
                y1[i] = (m1 + iv1) * w1;
                y2[i] = (m2 + iv2) * w2;
                y3[i] = (m2 - iv2) * w3;
                y4[i] = (m1 - iv1) * w4;
            }
        }
    }

    /// Exact O(n·r) fallback for prime radices > 5.
    fn radix_any<const INV: bool>(&self, src: &[Cpx], dst: &mut [Cpx], batch: usize) {
        let (r, l, m) = (self.radix, self.l, self.m);
        let mb = m * batch;
        let tw = if INV { &self.inv } else { &self.fwd };
        let rt = if INV { &self.inv_radix } else { &self.fwd_radix };
        for j in 0..l {
            let jb = j * mb;
            let out = &mut dst[r * j * mb..(r * j + r) * mb];
            for (u, y) in out.chunks_exact_mut(mb).enumerate() {
                let row = &rt[u * r..(u + 1) * r];
                let w = if u == 0 { Cpx::ONE } else { tw[j * (r - 1) + (u - 1)] };
                for (i, yv) in y.iter_mut().enumerate() {
                    let mut acc = Cpx::ZERO;
                    for (s, &c) in row.iter().enumerate() {
                        acc += src[jb + s * l * mb + i] * c;
                    }
                    *yv = acc * w;
                }
            }
        }
    }
}

/// Precomputed FFT plan for one transform length.
///
/// Immutable after construction (all scratch is caller-owned), hence
/// `Send + Sync`: one plan is safely shared across environment worker
/// threads.
pub struct Plan {
    n: usize,
    stages: Vec<Stage>,
    /// SIMD dispatch level baked in at construction (every level computes
    /// bit-identical transforms; pinning it keeps dispatch off the inner
    /// loops and lets benches/tests A/B explicitly).
    level: Level,
}

// Compile-time proof that plans and scratch can be shared/sent across the
// env-worker threads (the seed plan's RefCell scratch made Plan !Sync).
#[allow(dead_code)]
fn assert_plan_send_sync() {
    fn check<T: Send + Sync>() {}
    check::<Plan>();
    check::<FftScratch>();
}

impl Plan {
    /// Build a plan for length `n` (any n >= 1) at the CPU-probed SIMD
    /// level (`RELEXI_SIMD=scalar` forces the reference path).
    pub fn new(n: usize) -> Plan {
        Plan::with_level(n, simd::level())
    }

    /// Build a plan pinned to an explicit SIMD dispatch level — the
    /// scalar-vs-SIMD A/B hook for benches and kernel-agreement tests.
    pub fn with_level(n: usize, level: Level) -> Plan {
        assert!(n >= 1);
        let mut stages = Vec::new();
        let mut l = n;
        let mut m = 1;
        for r in factorize(n) {
            l /= r;
            stages.push(Stage::new(r, l, m));
            m *= r;
        }
        Plan { n, stages, level }
    }

    /// Transform length.
    pub fn len(&self) -> usize {
        self.n
    }

    /// The SIMD dispatch level this plan was built with.
    pub fn level(&self) -> Level {
        self.level
    }

    /// Whether this plan is for length 1 (identity).
    pub fn is_empty(&self) -> bool {
        self.n == 1
    }

    /// In-place forward DFT of one line: X[k] = sum_j x[j] e^{-2 pi i jk/n}.
    ///
    /// Convenience wrapper that allocates its own scratch; hot paths should
    /// use [`Plan::forward_batch`] with caller-owned scratch instead.
    pub fn forward(&self, data: &mut [Cpx]) {
        let mut scratch = vec![Cpx::ZERO; self.n];
        self.forward_batch(data, 1, &mut scratch);
    }

    /// In-place inverse DFT of one line with 1/n normalization
    /// (allocating convenience wrapper, see [`Plan::forward`]).
    pub fn inverse(&self, data: &mut [Cpx]) {
        let mut scratch = vec![Cpx::ZERO; self.n];
        self.inverse_batch(data, 1, &mut scratch);
    }

    /// Forward-transform `batch` lines at once, zero allocations.
    ///
    /// Batched layout: `data[t * batch + b]` holds element `t` of line `b`
    /// (line index outer, batch index inner/contiguous), `data.len() ==
    /// n * batch`.  `scratch` must hold at least `n * batch` elements.
    pub fn forward_batch(&self, data: &mut [Cpx], batch: usize, scratch: &mut [Cpx]) {
        self.transform_batch(data, batch, scratch, false);
    }

    /// Inverse-transform `batch` lines at once (1/n normalization each),
    /// zero allocations.  Layout as in [`Plan::forward_batch`].
    pub fn inverse_batch(&self, data: &mut [Cpx], batch: usize, scratch: &mut [Cpx]) {
        self.transform_batch(data, batch, scratch, true);
    }

    fn transform_batch(
        &self,
        data: &mut [Cpx],
        batch: usize,
        scratch: &mut [Cpx],
        inverse: bool,
    ) {
        let total = self.n * batch;
        assert_eq!(data.len(), total, "data is not {} lines of length {}", batch, self.n);
        assert!(scratch.len() >= total, "scratch too small: {} < {total}", scratch.len());
        if total == 0 || self.n == 1 {
            return;
        }
        let scratch = &mut scratch[..total];
        // Ping-pong between the two buffers; track which one holds the
        // newest result so at most one copy-back is ever needed.
        let mut src: &mut [Cpx] = data;
        let mut dst: &mut [Cpx] = scratch;
        let mut in_data = true;
        for st in &self.stages {
            st.apply(src, dst, batch, inverse, self.level);
            std::mem::swap(&mut src, &mut dst);
            in_data = !in_data;
        }
        if inverse {
            let s = 1.0 / self.n as f64;
            if in_data {
                scale_pass(self.level, cpx_f64_mut(src), s);
            } else {
                // Fuse the normalization with the copy back into `data`.
                scale_copy_pass(self.level, cpx_f64_mut(dst), cpx_f64(src), s);
                in_data = true;
            }
        }
        if !in_data {
            dst.copy_from_slice(src);
        }
    }
}

/// Caller-owned workspace arena for the batched 3-D transforms.
///
/// Sized for one `n^3` cube; owned by the solver workspace (one per
/// environment) so the steady-state step loop performs no heap
/// allocations.  Fields are public so layers above (`solver::spectral`)
/// can split-borrow them.
pub struct FftScratch {
    /// Stockham ping-pong buffer (`n^3`, the z-pass transforms the whole
    /// cube as one batch).
    pub buf: Vec<Cpx>,
    /// Transpose staging plane for the x-pass (`n^2`).
    pub plane: Vec<Cpx>,
    /// Packing buffer for the Hermitian-pair trick in `solver::spectral`.
    /// Starts empty and is grown to `n^3` on first pair transform, so
    /// callers that never pair (init, benches, diagnostics) don't pay for
    /// it; steady-state it is reused without reallocation.
    pub pair: Vec<Cpx>,
}

impl FftScratch {
    /// Allocate scratch for an `n^3` cube.
    pub fn new(n: usize) -> FftScratch {
        FftScratch {
            buf: vec![Cpx::ZERO; n * n * n],
            plane: vec![Cpx::ZERO; n * n],
            pair: Vec::new(),
        }
    }
}

/// Blocked (cache-friendly) transpose of an `n x n` plane: `dst[j*n + i] =
/// src[i*n + j]`.
fn transpose(src: &[Cpx], dst: &mut [Cpx], n: usize) {
    const B: usize = 16;
    debug_assert!(src.len() == n * n && dst.len() == n * n);
    let mut ib = 0;
    while ib < n {
        let imax = (ib + B).min(n);
        let mut jb = 0;
        while jb < n {
            let jmax = (jb + B).min(n);
            for i in ib..imax {
                for j in jb..jmax {
                    dst[j * n + i] = src[i * n + j];
                }
            }
            jb += B;
        }
        ib += B;
    }
}

/// In-place 3-D FFT over an `n^3` cube (layout `idx = (z*n + y)*n + x`)
/// using one shared 1-D plan and a caller-owned workspace — the
/// zero-allocation hot path used by the solver.
pub fn fft3d_ws(data: &mut [Cpx], plan: &Plan, inverse: bool, ws: &mut FftScratch) {
    fft3d_with(data, plan, inverse, &mut ws.buf, &mut ws.plane);
}

/// In-place 3-D FFT with explicitly provided buffers (`buf` >= n^3,
/// `plane` >= n^2); the engine behind [`fft3d_ws`], exposed so callers
/// holding a split-borrowed [`FftScratch`] can reach it.  Plane passes run
/// on the process-wide worker pool (`[hpc] threads`); see [`fft3d_pool`].
pub fn fft3d_with(
    data: &mut [Cpx],
    plan: &Plan,
    inverse: bool,
    buf: &mut [Cpx],
    plane: &mut [Cpx],
) {
    fft3d_pool(data, plan, inverse, buf, plane, &pool::global())
}

/// [`fft3d_with`] against an explicit worker pool — the thread-count A/B
/// hook for benches and determinism tests.
///
/// The x- and y-passes are plane-local, so they run fused, one z-plane
/// per pool task, each task staging through its own `n²` chunk of `buf`
/// (x-pass: transpose into the chunk, transform there with the data plane
/// as ping-pong scratch, transpose back; y-pass: transform the plane in
/// place with the chunk as scratch).  Per-plane arithmetic is identical
/// to the serial engine, so results are **bit-identical for every pool
/// width**.  The z-pass — one memory-bound `batch = n²` call — stays
/// serial.  `plane` is retained as the workspace's serial staging area
/// (the pre-pool engine used it for the x-pass) and validated for layout
/// compatibility, but the pooled passes stage through `buf` chunks so
/// tasks never share a buffer.
pub fn fft3d_pool(
    data: &mut [Cpx],
    plan: &Plan,
    inverse: bool,
    buf: &mut [Cpx],
    plane: &mut [Cpx],
    pool: &Pool,
) {
    let n = plan.len();
    let n2 = n * n;
    assert_eq!(data.len(), n2 * n);
    assert!(buf.len() >= n2 * n, "buf too small");
    assert!(plane.len() >= n2, "plane too small");
    let buf = &mut buf[..n2 * n];
    let run = |p: &mut [Cpx], batch: usize, scratch: &mut [Cpx]| {
        if inverse {
            plan.inverse_batch(p, batch, scratch);
        } else {
            plan.forward_batch(p, batch, scratch);
        }
    };
    // Fused x+y pass, one task per z-plane:
    // * x-pass — transpose the plane so the x-lines are batch-inner
    //   (batch = n over y), transform, transpose back;
    // * y-pass — the plane already holds y-lines in batched layout
    //   (batch = n over contiguous x), transform in place.
    pool.parallel_chunks_mut2(data, buf, n2, |_, p, bz| {
        transpose(p, bz, n);
        run(bz, n, p);
        transpose(bz, p, n);
        run(p, n, bz);
    });
    // z-pass: the whole cube is one batched set of z-lines (batch = n^2
    // over the contiguous (y, x) planes).
    run(data, n2, buf);
}

/// In-place 3-D FFT, allocating its own scratch — convenience for tests
/// and cold paths; hot paths use [`fft3d_ws`].
pub fn fft3d(data: &mut [Cpx], plan: &Plan, inverse: bool) {
    let n = plan.len();
    let mut buf = vec![Cpx::ZERO; n * n * n];
    let mut plane = vec![Cpx::ZERO; n * n];
    fft3d_with(data, plan, inverse, &mut buf, &mut plane);
}

/// Naive O(n^2) DFT used as the correctness oracle in tests.
pub fn dft_naive(x: &[Cpx], inverse: bool) -> Vec<Cpx> {
    let n = x.len();
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut out = vec![Cpx::ZERO; n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = Cpx::ZERO;
        for (j, &xj) in x.iter().enumerate() {
            let a = sign * 2.0 * std::f64::consts::PI * (j * k % n) as f64 / n as f64;
            acc += Cpx::new(a.cos(), a.sin()) * xj;
        }
        *o = if inverse { acc.scale(1.0 / n as f64) } else { acc };
    }
    out
}

/// Signed integer wavenumber for FFT bin `i` of length `n`
/// (0, 1, ..., n/2, -(n/2-1), ..., -1).
#[inline]
pub fn wavenumber(i: usize, n: usize) -> i64 {
    if i <= n / 2 {
        i as i64
    } else {
        i as i64 - n as i64
    }
}

pub mod seed {
    //! The seed FFT engine, frozen verbatim: a recursive per-line
    //! Cooley–Tukey with `RefCell` scratch (hence `!Sync`) and per-element
    //! strided gather/scatter in `fft3d`.  Kept **only** as the baseline
    //! for the head-to-head comparison in `benches/bench_fft.rs`; new code
    //! must use the batched engine in the parent module.

    use super::{factorize, Cpx};

    /// Seed plan: recursive engine + interior scratch (the design the
    /// batched engine replaces).
    pub struct Plan {
        n: usize,
        factors: Vec<usize>,
        /// exp(-2*pi*i*k/n) for k in 0..n (forward sign convention).
        twiddles: Vec<Cpx>,
        /// Reused scratch for out-of-place recursion (makes Plan !Sync).
        scratch: std::cell::RefCell<Vec<Cpx>>,
    }

    impl Plan {
        /// Build a plan for length `n` (any n >= 1).
        pub fn new(n: usize) -> Plan {
            assert!(n >= 1);
            let twiddles = (0..n)
                .map(|k| {
                    let a = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
                    Cpx::new(a.cos(), a.sin())
                })
                .collect();
            Plan {
                n,
                factors: factorize(n),
                twiddles,
                scratch: std::cell::RefCell::new(vec![Cpx::ZERO; n]),
            }
        }

        /// Transform length.
        pub fn len(&self) -> usize {
            self.n
        }

        /// Whether this plan is for length 1 (identity).
        pub fn is_empty(&self) -> bool {
            self.n == 1
        }

        /// In-place forward DFT.
        pub fn forward(&self, data: &mut [Cpx]) {
            self.transform(data, false)
        }

        /// In-place inverse DFT with 1/n normalization.
        pub fn inverse(&self, data: &mut [Cpx]) {
            self.transform(data, true);
            let s = 1.0 / self.n as f64;
            for x in data.iter_mut() {
                *x = x.scale(s);
            }
        }

        fn transform(&self, data: &mut [Cpx], inverse: bool) {
            assert_eq!(data.len(), self.n);
            if self.n == 1 {
                return;
            }
            let mut scratch = self.scratch.borrow_mut();
            scratch.copy_from_slice(data);
            self.rec(&scratch, 1, data, self.n, 1, 0, inverse);
        }

        #[inline]
        fn tw(&self, idx: usize, inverse: bool) -> Cpx {
            let t = self.twiddles[idx % self.n];
            if inverse {
                t.conj()
            } else {
                t
            }
        }

        /// Recursive decimation-in-time.  `inp` is strided (`stride`),
        /// `out` is contiguous of length `n`; `tw_stride = N/n`; `depth`
        /// indexes factors.
        #[allow(clippy::too_many_arguments)]
        fn rec(
            &self,
            inp: &[Cpx],
            stride: usize,
            out: &mut [Cpx],
            n: usize,
            tw_stride: usize,
            depth: usize,
            inverse: bool,
        ) {
            if n == 1 {
                out[0] = inp[0];
                return;
            }
            let r = self.factors[depth];
            let m = n / r;
            for l in 0..r {
                self.rec(
                    &inp[l * stride..],
                    stride * r,
                    &mut out[l * m..(l + 1) * m],
                    m,
                    tw_stride * r,
                    depth + 1,
                    inverse,
                );
            }
            // Combine r sub-transforms: butterflies per output column q.
            let mut tmp_stack = [Cpx::ZERO; 16];
            let mut tmp_heap;
            let tmp: &mut [Cpx] = if r <= 16 {
                &mut tmp_stack[..r]
            } else {
                tmp_heap = vec![Cpx::ZERO; r];
                &mut tmp_heap[..]
            };
            for q in 0..m {
                for (l, t) in tmp.iter_mut().enumerate() {
                    *t = out[l * m + q];
                }
                for s in 0..r {
                    let kout = q + s * m;
                    let mut acc = tmp[0];
                    for (l, t) in tmp.iter().enumerate().skip(1) {
                        acc += self.tw(l * kout * tw_stride, inverse) * *t;
                    }
                    out[kout] = acc;
                }
            }
        }
    }

    /// Seed 3-D FFT: one line at a time, element-wise gather/scatter for
    /// the strided y/z passes.
    pub fn fft3d(data: &mut [Cpx], plan: &Plan, inverse: bool) {
        let n = plan.len();
        assert_eq!(data.len(), n * n * n);
        let mut line = vec![Cpx::ZERO; n];
        let run = |plan: &Plan, line: &mut [Cpx]| {
            if inverse {
                plan.inverse(line);
            } else {
                plan.forward(line);
            }
        };
        // x-lines (contiguous)
        for zy in 0..n * n {
            let base = zy * n;
            line.copy_from_slice(&data[base..base + n]);
            run(plan, &mut line);
            data[base..base + n].copy_from_slice(&line);
        }
        // y-lines (stride n)
        for z in 0..n {
            for x in 0..n {
                let base = z * n * n + x;
                for (y, l) in line.iter_mut().enumerate() {
                    *l = data[base + y * n];
                }
                run(plan, &mut line);
                for (y, l) in line.iter().enumerate() {
                    data[base + y * n] = *l;
                }
            }
        }
        // z-lines (stride n^2)
        for y in 0..n {
            for x in 0..n {
                let base = y * n + x;
                for (z, l) in line.iter_mut().enumerate() {
                    *l = data[base + z * n * n];
                }
                run(plan, &mut line);
                for (z, l) in line.iter().enumerate() {
                    data[base + z * n * n] = *l;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_signal(n: usize, seed: u64) -> Vec<Cpx> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| Cpx::new(rng.normal(), rng.normal())).collect()
    }

    fn assert_close(a: &[Cpx], b: &[Cpx], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (*x - *y).norm_sq().sqrt() < tol,
                "bin {i}: {x:?} vs {y:?}"
            );
        }
    }

    /// Gather line `b` out of the batched `[t][b]` layout.
    fn extract_line(data: &[Cpx], n: usize, batch: usize, b: usize) -> Vec<Cpx> {
        (0..n).map(|t| data[t * batch + b]).collect()
    }

    #[test]
    fn matches_naive_dft_for_solver_sizes() {
        for n in [1usize, 2, 3, 4, 5, 6, 8, 12, 16, 20, 24, 30, 32, 48, 64, 96] {
            let plan = Plan::new(n);
            let x = rand_signal(n, n as u64);
            let mut got = x.clone();
            plan.forward(&mut got);
            let want = dft_naive(&x, false);
            assert_close(&got, &want, 1e-9 * (n as f64));
        }
    }

    #[test]
    fn matches_naive_dft_prime_lengths() {
        for n in [7usize, 11, 13, 17, 31] {
            let plan = Plan::new(n);
            let x = rand_signal(n, 100 + n as u64);
            let mut got = x.clone();
            plan.forward(&mut got);
            assert_close(&got, &dft_naive(&x, false), 1e-9 * n as f64);
        }
    }

    #[test]
    fn batched_matches_naive_paper_sizes() {
        // Paper-relevant sizes at batch 1 and 7; each line checked
        // independently against the O(n^2) oracle.
        for n in [24usize, 32, 48, 64, 96] {
            for batch in [1usize, 7] {
                let plan = Plan::new(n);
                let mut data = rand_signal(n * batch, (n * 1000 + batch) as u64);
                let orig = data.clone();
                let mut scratch = vec![Cpx::ZERO; n * batch];
                plan.forward_batch(&mut data, batch, &mut scratch);
                for b in 0..batch {
                    let line = extract_line(&orig, n, batch, b);
                    let want = dft_naive(&line, false);
                    let got = extract_line(&data, n, batch, b);
                    assert_close(&got, &want, 1e-9 * n as f64);
                }
            }
        }
    }

    #[test]
    fn batched_matches_naive_generic_radix() {
        // Prime length exercises the generic-radix fallback stage.
        for (n, batch) in [(31usize, 1usize), (31, 7), (35, 4)] {
            let plan = Plan::new(n);
            let mut data = rand_signal(n * batch, (n + batch) as u64);
            let orig = data.clone();
            let mut scratch = vec![Cpx::ZERO; n * batch];
            plan.forward_batch(&mut data, batch, &mut scratch);
            for b in 0..batch {
                let want = dft_naive(&extract_line(&orig, n, batch, b), false);
                assert_close(&extract_line(&data, n, batch, b), &want, 1e-9 * n as f64);
            }
        }
    }

    #[test]
    fn batched_full_plane_batch() {
        // batch = n^2 is exactly the z-pass of fft3d: every line must
        // still match the oracle.
        let n = 24;
        let batch = n * n;
        let plan = Plan::new(n);
        let mut data = rand_signal(n * batch, 77);
        let orig = data.clone();
        let mut scratch = vec![Cpx::ZERO; n * batch];
        plan.forward_batch(&mut data, batch, &mut scratch);
        for b in [0usize, 1, 17, batch / 2, batch - 1] {
            let want = dft_naive(&extract_line(&orig, n, batch, b), false);
            assert_close(&extract_line(&data, n, batch, b), &want, 1e-9 * n as f64);
        }
    }

    #[test]
    fn batched_roundtrip_property() {
        // forward_batch . inverse_batch == identity across radix mixes
        // (including prime and prime-power lengths) and batch sizes.
        for n in [24usize, 31, 35, 48, 49, 96] {
            for batch in [1usize, 7] {
                let plan = Plan::new(n);
                let orig = rand_signal(n * batch, (3 * n + batch) as u64);
                let mut data = orig.clone();
                let mut scratch = vec![Cpx::ZERO; n * batch];
                plan.forward_batch(&mut data, batch, &mut scratch);
                plan.inverse_batch(&mut data, batch, &mut scratch);
                assert_close(&data, &orig, 1e-10 * n as f64);
            }
        }
    }

    #[test]
    fn batched_agrees_with_per_line() {
        // The batched engine and the single-line convenience API are the
        // same transform.
        let (n, batch) = (48usize, 7usize);
        let plan = Plan::new(n);
        let mut data = rand_signal(n * batch, 11);
        let orig = data.clone();
        let mut scratch = vec![Cpx::ZERO; n * batch];
        plan.forward_batch(&mut data, batch, &mut scratch);
        for b in 0..batch {
            let mut line = extract_line(&orig, n, batch, b);
            plan.forward(&mut line);
            assert_close(&extract_line(&data, n, batch, b), &line, 1e-10 * n as f64);
        }
    }

    #[test]
    fn batched_3d_matches_seed_engine() {
        // The frozen seed engine is the head-to-head bench baseline; the
        // two engines must compute the same transform, both directions.
        for n in [12usize, 24] {
            let plan = Plan::new(n);
            let seed_plan = seed::Plan::new(n);
            let mut ws = FftScratch::new(n);
            for inverse in [false, true] {
                let orig = rand_signal(n * n * n, n as u64 + inverse as u64);
                let mut a = orig.clone();
                let mut b = orig.clone();
                fft3d_ws(&mut a, &plan, inverse, &mut ws);
                seed::fft3d(&mut b, &seed_plan, inverse);
                assert_close(&a, &b, 1e-8 * (n * n * n) as f64);
            }
        }
    }

    #[test]
    fn forward_inverse_roundtrip() {
        for n in [24usize, 32, 48] {
            let plan = Plan::new(n);
            let x = rand_signal(n, 7);
            let mut y = x.clone();
            plan.forward(&mut y);
            plan.inverse(&mut y);
            assert_close(&y, &x, 1e-10 * n as f64);
        }
    }

    #[test]
    fn parseval() {
        let n = 48;
        let plan = Plan::new(n);
        let x = rand_signal(n, 9);
        let phys: f64 = x.iter().map(|c| c.norm_sq()).sum();
        let mut y = x.clone();
        plan.forward(&mut y);
        let spec: f64 = y.iter().map(|c| c.norm_sq()).sum::<f64>() / n as f64;
        assert!((phys - spec).abs() < 1e-8 * phys);
    }

    #[test]
    fn linearity() {
        let n = 30;
        let plan = Plan::new(n);
        let a = rand_signal(n, 1);
        let b = rand_signal(n, 2);
        let sum: Vec<Cpx> = a.iter().zip(&b).map(|(x, y)| *x + *y).collect();
        let mut fa = a.clone();
        let mut fb = b.clone();
        let mut fs = sum.clone();
        plan.forward(&mut fa);
        plan.forward(&mut fb);
        plan.forward(&mut fs);
        let combined: Vec<Cpx> = fa.iter().zip(&fb).map(|(x, y)| *x + *y).collect();
        assert_close(&fs, &combined, 1e-9 * n as f64);
    }

    #[test]
    fn delta_transforms_to_ones() {
        let n = 24;
        let plan = Plan::new(n);
        let mut x = vec![Cpx::ZERO; n];
        x[0] = Cpx::new(1.0, 0.0);
        plan.forward(&mut x);
        for c in &x {
            assert!((c.re - 1.0).abs() < 1e-12 && c.im.abs() < 1e-12);
        }
    }

    #[test]
    fn fft3d_roundtrip_and_single_mode() {
        let n = 12;
        let plan = Plan::new(n);
        // A single Fourier mode k=(2,1,3) should produce one spectral peak.
        let mut data = vec![Cpx::ZERO; n * n * n];
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    let phase = 2.0 * std::f64::consts::PI
                        * (2.0 * x as f64 + 1.0 * y as f64 + 3.0 * z as f64)
                        / n as f64;
                    data[(z * n + y) * n + x] = Cpx::new(phase.cos(), phase.sin());
                }
            }
        }
        let orig = data.clone();
        let mut ws = FftScratch::new(n);
        fft3d_ws(&mut data, &plan, false, &mut ws);
        // Expect peak at (x=2, y=1, z=3) with magnitude n^3.
        let idx = (3 * n + 1) * n + 2;
        assert!((data[idx].re - (n * n * n) as f64).abs() < 1e-6);
        let total: f64 = data.iter().map(|c| c.norm_sq()).sum();
        assert!((total - ((n * n * n) as f64).powi(2)).abs() < 1e-4 * total);
        fft3d_ws(&mut data, &plan, true, &mut ws);
        assert_close(&data, &orig, 1e-9);
    }

    #[test]
    fn fft3d_alloc_wrapper_matches_ws() {
        let n = 8;
        let plan = Plan::new(n);
        let orig = rand_signal(n * n * n, 5);
        let mut a = orig.clone();
        let mut b = orig;
        let mut ws = FftScratch::new(n);
        fft3d(&mut a, &plan, false);
        fft3d_ws(&mut b, &plan, false, &mut ws);
        assert_eq!(a, b);
    }

    #[test]
    fn transpose_roundtrip() {
        let n = 20; // not a multiple of the blocking factor
        let src = rand_signal(n * n, 3);
        let mut t = vec![Cpx::ZERO; n * n];
        let mut back = vec![Cpx::ZERO; n * n];
        transpose(&src, &mut t, n);
        assert_eq!(t[3 * n + 5], src[5 * n + 3]);
        transpose(&t, &mut back, n);
        assert_eq!(src, back);
    }

    #[test]
    fn plan_is_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<Plan>();
        check::<FftScratch>();
    }

    #[test]
    fn simd_levels_compute_bit_identical_transforms() {
        // 24 = 4·2·3, 32 = 4·4·2, 40 = 4·2·5, 48 = 4·4·3 — exercises the
        // SIMD radix-2/4 paths alongside the scalar radix-3/5, with an odd
        // batch to hit the scalar remainder lanes.  The pinned-scalar plan
        // is the reference; the probed plan must match it bit-for-bit (on
        // CPUs without AVX2 the two coincide and this degenerates to a
        // self-check).
        for n in [24usize, 32, 40, 48] {
            for batch in [1usize, 5] {
                let reference = Plan::with_level(n, Level::Scalar);
                let probed = Plan::new(n);
                let orig = rand_signal(n * batch, (7 * n + batch) as u64);
                let mut scratch = vec![Cpx::ZERO; n * batch];
                let mut a = orig.clone();
                let mut b = orig;
                reference.forward_batch(&mut a, batch, &mut scratch);
                probed.forward_batch(&mut b, batch, &mut scratch);
                for i in 0..n * batch {
                    assert_eq!(a[i].re.to_bits(), b[i].re.to_bits(), "fwd re[{i}] n={n}");
                    assert_eq!(a[i].im.to_bits(), b[i].im.to_bits(), "fwd im[{i}] n={n}");
                }
                // The inverse also exercises the SIMD scale/copy-back.
                reference.inverse_batch(&mut a, batch, &mut scratch);
                probed.inverse_batch(&mut b, batch, &mut scratch);
                for i in 0..n * batch {
                    assert_eq!(a[i].re.to_bits(), b[i].re.to_bits(), "inv re[{i}] n={n}");
                    assert_eq!(a[i].im.to_bits(), b[i].im.to_bits(), "inv im[{i}] n={n}");
                }
            }
        }
    }

    #[test]
    fn fft3d_bit_identical_across_pool_widths() {
        // Plane partitioning must not perturb a single bit, whatever the
        // pool width — the solver's lockstep-equivalence gate depends on
        // fft3d results being thread-count-independent.
        let n = 12;
        let plan = Plan::new(n);
        for inverse in [false, true] {
            let orig = rand_signal(n * n * n, 90 + inverse as u64);
            let run_with = |threads: usize| {
                let pool = Pool::new(threads);
                let mut d = orig.clone();
                let mut buf = vec![Cpx::ZERO; n * n * n];
                let mut plane = vec![Cpx::ZERO; n * n];
                fft3d_pool(&mut d, &plan, inverse, &mut buf, &mut plane, &pool);
                d
            };
            let base = run_with(1);
            for threads in [2usize, 8] {
                let got = run_with(threads);
                for i in 0..base.len() {
                    assert_eq!(base[i].re.to_bits(), got[i].re.to_bits(), "re[{i}] @{threads}");
                    assert_eq!(base[i].im.to_bits(), got[i].im.to_bits(), "im[{i}] @{threads}");
                }
            }
        }
    }

    #[test]
    fn wavenumber_convention() {
        assert_eq!(wavenumber(0, 8), 0);
        assert_eq!(wavenumber(4, 8), 4);
        assert_eq!(wavenumber(5, 8), -3);
        assert_eq!(wavenumber(7, 8), -1);
    }
}
