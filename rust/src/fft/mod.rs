//! Mixed-radix complex FFT, built from scratch (no FFT crate in the image).
//!
//! The pseudo-spectral solver (DESIGN.md S1/S2) needs sizes 24, 32, 48, 64,
//! 96 — products of 2, 3 and 5 — so a recursive Cooley–Tukey with small
//! radices covers everything; other prime factors fall back to an O(n·p)
//! in-level DFT which is still exact.
//!
//! [`Plan`] precomputes the twiddle table for one length and is reused
//! across the many transforms per solver step (plan reuse is one of the
//! §Perf items in EXPERIMENTS.md).

/// Complex number (f64) with the handful of ops the FFT and solver need.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Cpx {
    pub re: f64,
    pub im: f64,
}

impl Cpx {
    pub const ZERO: Cpx = Cpx { re: 0.0, im: 0.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> Cpx {
        Cpx { re, im }
    }

    #[inline]
    pub fn conj(self) -> Cpx {
        Cpx { re: self.re, im: -self.im }
    }

    #[inline]
    pub fn scale(self, s: f64) -> Cpx {
        Cpx { re: self.re * s, im: self.im * s }
    }

    #[inline]
    pub fn norm_sq(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// Multiply by i (used for spectral derivatives).
    #[inline]
    pub fn mul_i(self) -> Cpx {
        Cpx { re: -self.im, im: self.re }
    }
}

impl std::ops::Add for Cpx {
    type Output = Cpx;
    #[inline]
    fn add(self, o: Cpx) -> Cpx {
        Cpx::new(self.re + o.re, self.im + o.im)
    }
}

impl std::ops::Sub for Cpx {
    type Output = Cpx;
    #[inline]
    fn sub(self, o: Cpx) -> Cpx {
        Cpx::new(self.re - o.re, self.im - o.im)
    }
}

impl std::ops::Mul for Cpx {
    type Output = Cpx;
    #[inline]
    fn mul(self, o: Cpx) -> Cpx {
        Cpx::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl std::ops::AddAssign for Cpx {
    #[inline]
    fn add_assign(&mut self, o: Cpx) {
        self.re += o.re;
        self.im += o.im;
    }
}

/// Precomputed FFT plan for one transform length.
pub struct Plan {
    n: usize,
    /// Factorization of n into radices (smallest first).
    factors: Vec<usize>,
    /// exp(-2*pi*i*k/n) for k in 0..n (forward sign convention).
    twiddles: Vec<Cpx>,
    /// Reused scratch for out-of-place recursion.
    scratch: std::cell::RefCell<Vec<Cpx>>,
}

fn factorize(mut n: usize) -> Vec<usize> {
    let mut fs = Vec::new();
    for r in [4usize, 2, 3, 5] {
        while n % r == 0 {
            fs.push(r);
            n /= r;
        }
    }
    let mut p = 7;
    while n > 1 {
        while n % p == 0 {
            fs.push(p);
            n /= p;
        }
        p += 2;
    }
    fs
}

impl Plan {
    /// Build a plan for length `n` (any n >= 1).
    pub fn new(n: usize) -> Plan {
        assert!(n >= 1);
        let twiddles = (0..n)
            .map(|k| {
                let a = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
                Cpx::new(a.cos(), a.sin())
            })
            .collect();
        Plan {
            n,
            factors: factorize(n),
            twiddles,
            scratch: std::cell::RefCell::new(vec![Cpx::ZERO; n]),
        }
    }

    /// Transform length.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Whether this plan is for length 1 (identity).
    pub fn is_empty(&self) -> bool {
        self.n == 1
    }

    /// In-place forward DFT: X[k] = sum_j x[j] e^{-2 pi i jk/n}.
    pub fn forward(&self, data: &mut [Cpx]) {
        self.transform(data, false)
    }

    /// In-place inverse DFT with 1/n normalization.
    pub fn inverse(&self, data: &mut [Cpx]) {
        self.transform(data, true);
        let s = 1.0 / self.n as f64;
        for x in data.iter_mut() {
            *x = x.scale(s);
        }
    }

    fn transform(&self, data: &mut [Cpx], inverse: bool) {
        assert_eq!(data.len(), self.n);
        if self.n == 1 {
            return;
        }
        let mut scratch = self.scratch.borrow_mut();
        scratch.copy_from_slice(data);
        self.rec(&scratch, 1, data, self.n, 1, 0, inverse);
    }

    #[inline]
    fn tw(&self, idx: usize, inverse: bool) -> Cpx {
        let t = self.twiddles[idx % self.n];
        if inverse {
            t.conj()
        } else {
            t
        }
    }

    /// Recursive decimation-in-time.  `inp` is strided (`stride`), `out` is
    /// contiguous of length `n`; `tw_stride = N/n`; `depth` indexes factors.
    #[allow(clippy::too_many_arguments)]
    fn rec(
        &self,
        inp: &[Cpx],
        stride: usize,
        out: &mut [Cpx],
        n: usize,
        tw_stride: usize,
        depth: usize,
        inverse: bool,
    ) {
        if n == 1 {
            out[0] = inp[0];
            return;
        }
        let r = self.factors[depth];
        let m = n / r;
        for l in 0..r {
            self.rec(
                &inp[l * stride..],
                stride * r,
                &mut out[l * m..(l + 1) * m],
                m,
                tw_stride * r,
                depth + 1,
                inverse,
            );
        }
        // Combine r sub-transforms: butterflies per output column q.
        // Stack buffer for the common small radices; heap for large primes.
        let mut tmp_stack = [Cpx::ZERO; 16];
        let mut tmp_heap;
        let tmp: &mut [Cpx] = if r <= 16 {
            &mut tmp_stack[..r]
        } else {
            tmp_heap = vec![Cpx::ZERO; r];
            &mut tmp_heap[..]
        };
        for q in 0..m {
            for (l, t) in tmp.iter_mut().enumerate() {
                *t = out[l * m + q];
            }
            for s in 0..r {
                let kout = q + s * m;
                let mut acc = tmp[0];
                for (l, t) in tmp.iter().enumerate().skip(1) {
                    acc += self.tw(l * kout * tw_stride, inverse) * *t;
                }
                out[kout] = acc;
            }
        }
    }
}

/// Naive O(n^2) DFT used as the correctness oracle in tests.
pub fn dft_naive(x: &[Cpx], inverse: bool) -> Vec<Cpx> {
    let n = x.len();
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut out = vec![Cpx::ZERO; n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = Cpx::ZERO;
        for (j, &xj) in x.iter().enumerate() {
            let a = sign * 2.0 * std::f64::consts::PI * (j * k % n) as f64 / n as f64;
            acc += Cpx::new(a.cos(), a.sin()) * xj;
        }
        *o = if inverse { acc.scale(1.0 / n as f64) } else { acc };
    }
    out
}

// ---------------------------------------------------------------------------
// 3-D helpers over cube-shaped fields (layout: idx = (z*n + y)*n + x)
// ---------------------------------------------------------------------------

/// In-place 3-D FFT over an `n^3` cube using one shared 1-D plan.
pub fn fft3d(data: &mut [Cpx], plan: &Plan, inverse: bool) {
    let n = plan.len();
    assert_eq!(data.len(), n * n * n);
    let mut line = vec![Cpx::ZERO; n];
    let run = |plan: &Plan, line: &mut [Cpx]| {
        if inverse {
            plan.inverse(line);
        } else {
            plan.forward(line);
        }
    };
    // x-lines (contiguous)
    for zy in 0..n * n {
        let base = zy * n;
        line.copy_from_slice(&data[base..base + n]);
        run(plan, &mut line);
        data[base..base + n].copy_from_slice(&line);
    }
    // y-lines (stride n)
    for z in 0..n {
        for x in 0..n {
            let base = z * n * n + x;
            for (y, l) in line.iter_mut().enumerate() {
                *l = data[base + y * n];
            }
            run(plan, &mut line);
            for (y, l) in line.iter().enumerate() {
                data[base + y * n] = *l;
            }
        }
    }
    // z-lines (stride n^2)
    for y in 0..n {
        for x in 0..n {
            let base = y * n + x;
            for (z, l) in line.iter_mut().enumerate() {
                *l = data[base + z * n * n];
            }
            run(plan, &mut line);
            for (z, l) in line.iter().enumerate() {
                data[base + z * n * n] = *l;
            }
        }
    }
}

/// Signed integer wavenumber for FFT bin `i` of length `n`
/// (0, 1, ..., n/2, -(n/2-1), ..., -1).
#[inline]
pub fn wavenumber(i: usize, n: usize) -> i64 {
    if i <= n / 2 {
        i as i64
    } else {
        i as i64 - n as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_signal(n: usize, seed: u64) -> Vec<Cpx> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| Cpx::new(rng.normal(), rng.normal())).collect()
    }

    fn assert_close(a: &[Cpx], b: &[Cpx], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (*x - *y).norm_sq().sqrt() < tol,
                "bin {i}: {x:?} vs {y:?}"
            );
        }
    }

    #[test]
    fn matches_naive_dft_for_solver_sizes() {
        for n in [1usize, 2, 3, 4, 5, 6, 8, 12, 16, 20, 24, 30, 32, 48, 64, 96] {
            let plan = Plan::new(n);
            let x = rand_signal(n, n as u64);
            let mut got = x.clone();
            plan.forward(&mut got);
            let want = dft_naive(&x, false);
            assert_close(&got, &want, 1e-9 * (n as f64));
        }
    }

    #[test]
    fn matches_naive_dft_prime_lengths() {
        for n in [7usize, 11, 13, 17] {
            let plan = Plan::new(n);
            let x = rand_signal(n, 100 + n as u64);
            let mut got = x.clone();
            plan.forward(&mut got);
            assert_close(&got, &dft_naive(&x, false), 1e-9 * n as f64);
        }
    }

    #[test]
    fn forward_inverse_roundtrip() {
        for n in [24usize, 32, 48] {
            let plan = Plan::new(n);
            let x = rand_signal(n, 7);
            let mut y = x.clone();
            plan.forward(&mut y);
            plan.inverse(&mut y);
            assert_close(&y, &x, 1e-10 * n as f64);
        }
    }

    #[test]
    fn parseval() {
        let n = 48;
        let plan = Plan::new(n);
        let x = rand_signal(n, 9);
        let phys: f64 = x.iter().map(|c| c.norm_sq()).sum();
        let mut y = x.clone();
        plan.forward(&mut y);
        let spec: f64 = y.iter().map(|c| c.norm_sq()).sum::<f64>() / n as f64;
        assert!((phys - spec).abs() < 1e-8 * phys);
    }

    #[test]
    fn linearity() {
        let n = 30;
        let plan = Plan::new(n);
        let a = rand_signal(n, 1);
        let b = rand_signal(n, 2);
        let sum: Vec<Cpx> = a.iter().zip(&b).map(|(x, y)| *x + *y).collect();
        let mut fa = a.clone();
        let mut fb = b.clone();
        let mut fs = sum.clone();
        plan.forward(&mut fa);
        plan.forward(&mut fb);
        plan.forward(&mut fs);
        let combined: Vec<Cpx> = fa.iter().zip(&fb).map(|(x, y)| *x + *y).collect();
        assert_close(&fs, &combined, 1e-9 * n as f64);
    }

    #[test]
    fn delta_transforms_to_ones() {
        let n = 24;
        let plan = Plan::new(n);
        let mut x = vec![Cpx::ZERO; n];
        x[0] = Cpx::new(1.0, 0.0);
        plan.forward(&mut x);
        for c in &x {
            assert!((c.re - 1.0).abs() < 1e-12 && c.im.abs() < 1e-12);
        }
    }

    #[test]
    fn fft3d_roundtrip_and_single_mode() {
        let n = 12;
        let plan = Plan::new(n);
        // A single Fourier mode k=(2,1,3) should produce one spectral peak.
        let mut data = vec![Cpx::ZERO; n * n * n];
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    let phase = 2.0 * std::f64::consts::PI
                        * (2.0 * x as f64 + 1.0 * y as f64 + 3.0 * z as f64)
                        / n as f64;
                    data[(z * n + y) * n + x] = Cpx::new(phase.cos(), phase.sin());
                }
            }
        }
        let orig = data.clone();
        fft3d(&mut data, &plan, false);
        // Expect peak at (x=2, y=1, z=3) with magnitude n^3.
        let idx = (3 * n + 1) * n + 2;
        assert!((data[idx].re - (n * n * n) as f64).abs() < 1e-6);
        let total: f64 = data.iter().map(|c| c.norm_sq()).sum();
        assert!((total - ((n * n * n) as f64).powi(2)).abs() < 1e-4 * total);
        fft3d(&mut data, &plan, true);
        assert_close(&data, &orig, 1e-9);
    }

    #[test]
    fn wavenumber_convention() {
        assert_eq!(wavenumber(0, 8), 0);
        assert_eq!(wavenumber(4, 8), 4);
        assert_eq!(wavenumber(5, 8), -3);
        assert_eq!(wavenumber(7, 8), -1);
    }
}
