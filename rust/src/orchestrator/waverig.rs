//! Wave-exchange micro-harness behind the strong/weak scaling benches
//! (`BENCH_strong_scaling` / `BENCH_weak_scaling`): `E` env threads,
//! each speaking a chosen transport into the trainer's store, exchange
//! one state/action pair per wave with a trainer loop that mirrors the
//! event-driven collector's store traffic (arrival-order subscription
//! consume, answer, re-register).  No CFD work anywhere — what remains
//! is exactly the per-wave exchange latency of the transport under
//! test, so `inproc` vs `shm` vs `tcp` rows are directly comparable.

use super::store::Subscription;
use super::transport::{InprocTransport, RemoteTransport, Transport};
use super::{Client, ExchangeServer, Key, Orchestrator, Value};
use anyhow::{Context, Result};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Generous stall bound: a wave that takes this long is wedged, not slow.
const WAVE_TIMEOUT: Duration = Duration::from_secs(60);

fn state_key(env: usize, wave: usize) -> String {
    format!("wave:st:{env}:{wave}")
}

fn action_key(env: usize, wave: usize) -> String {
    format!("wave:ac:{env}:{wave}")
}

/// The measured exchange: env threads publish states and block on their
/// action keys; [`WaveRig::run_wave`] serves one full wave from the
/// trainer side.  Dropping the rig delivers a stop sentinel to every env
/// thread and joins them before the exchange server goes away.
pub struct WaveRig {
    orch: Orchestrator,
    trainer: Client,
    sub: Subscription,
    /// Per-env next wave index (the trainer's view).
    wave: Vec<usize>,
    act: Vec<f32>,
    handles: Vec<JoinHandle<Result<()>>>,
    /// Batched mode (PR-9): env indices per worker block.  Empty in
    /// per-key mode.
    blocks: Vec<Vec<usize>>,
    /// Exchange serving the remote kinds; must outlive the env threads
    /// (joined in `Drop`'s body, before fields drop).
    _server: Option<ExchangeServer>,
}

impl WaveRig {
    /// Launch a rig on transport `kind` (`"inproc" | "shm" | "tcp"`).
    /// `state_floats[e]` sizes env `e`'s per-wave state tensor;
    /// `act_floats` sizes the trainer's per-wave action tensor.
    pub fn start(kind: &str, state_floats: &[usize], act_floats: usize) -> Result<WaveRig> {
        Self::start_inner(kind, state_floats, act_floats, None)
    }

    /// The wave-coalesced variant (PR-9 `batch_ops`): `n_blocks`
    /// block threads each publish ONE `put_many` of all their envs'
    /// states per wave and drain their actions through batched takes,
    /// while the trainer scatters each block's action wave as one
    /// `put_many` — the worker-process wire pattern, measurable A/B
    /// against [`WaveRig::start`] on the same transport.
    pub fn start_batched(
        kind: &str,
        state_floats: &[usize],
        act_floats: usize,
        n_blocks: usize,
    ) -> Result<WaveRig> {
        Self::start_inner(kind, state_floats, act_floats, Some(n_blocks))
    }

    fn start_inner(
        kind: &str,
        state_floats: &[usize],
        act_floats: usize,
        n_blocks: Option<usize>,
    ) -> Result<WaveRig> {
        let orch = Orchestrator::launch(8);
        let server = if kind == "inproc" {
            None
        } else {
            Some(orch.serve("127.0.0.1:0")?)
        };
        // One transport per rig, shared by every env thread: the remote
        // kinds pool one connection per concurrent blocking op, exactly
        // like a multi-env worker process does.
        let transport: Arc<dyn Transport> = match &server {
            None => Arc::new(InprocTransport::new(orch.store().clone())),
            Some(s) => RemoteTransport::connect(kind, &s.addr().to_string(), 3)?,
        };
        let mut handles = Vec::with_capacity(state_floats.len());
        let mut blocks: Vec<Vec<usize>> = Vec::new();
        match n_blocks {
            None => {
                for (e, &floats) in state_floats.iter().enumerate() {
                    let t = transport.clone();
                    handles.push(
                        std::thread::Builder::new()
                            .name(format!("wave-env-{e}"))
                            .spawn(move || env_loop(t, e, floats))
                            .context("spawn wave env thread")?,
                    );
                }
            }
            Some(nb) => {
                // Contiguous near-even blocks, like the worker plan.
                let nb = nb.clamp(1, state_floats.len().max(1));
                for b in 0..nb {
                    let start = b * state_floats.len() / nb;
                    let end = (b + 1) * state_floats.len() / nb;
                    if start == end {
                        continue;
                    }
                    let envs: Vec<usize> = (start..end).collect();
                    let floats: Vec<usize> = envs.iter().map(|&e| state_floats[e]).collect();
                    let t = transport.clone();
                    let thread_envs = envs.clone();
                    handles.push(
                        std::thread::Builder::new()
                            .name(format!("wave-block-{b}"))
                            .spawn(move || block_loop(t, thread_envs, floats))
                            .context("spawn wave block thread")?,
                    );
                    blocks.push(envs);
                }
            }
        }
        let mut sub = Subscription::new(orch.store().clone());
        for e in 0..state_floats.len() {
            sub.add(e, &state_key(e, 0));
        }
        Ok(WaveRig {
            trainer: orch.client(),
            sub,
            wave: vec![0; state_floats.len()],
            act: vec![0.5f32; act_floats.max(1)],
            handles,
            blocks,
            _server: server,
            orch,
        })
    }

    /// Envs in the rig.
    pub fn n_envs(&self) -> usize {
        self.wave.len()
    }

    /// Serve one full wave: consume `E` states in arrival order through
    /// the persistent subscription, answer each with an action, and
    /// re-register that env's next state key — the collector's exact
    /// per-wave store traffic.  In batched mode the states drain
    /// through `wait_take_many` and the actions scatter as one
    /// `put_many` per block.
    pub fn run_wave(&mut self) {
        if self.blocks.is_empty() {
            for _ in 0..self.wave.len() {
                let (e, state) = self.sub.wait_take(WAVE_TIMEOUT).expect("wave stalled");
                debug_assert!(state.as_tensor().is_some());
                self.trainer.put_tensor(
                    &action_key(e, self.wave[e]),
                    vec![self.act.len()],
                    self.act.clone(),
                );
                self.wave[e] += 1;
                self.sub.add(e, &state_key(e, self.wave[e]));
            }
            return;
        }
        let n = self.wave.len();
        let mut arrived = 0usize;
        while arrived < n {
            let hits = self.sub.wait_take_many(WAVE_TIMEOUT, n - arrived);
            assert!(!hits.is_empty(), "wave stalled");
            for (_, state) in &hits {
                debug_assert!(state.as_tensor().is_some());
            }
            arrived += hits.len();
        }
        for block in &self.blocks {
            self.trainer.put_many(
                block
                    .iter()
                    .map(|&e| {
                        (
                            Key::new(action_key(e, self.wave[e])),
                            Value::tensor(vec![self.act.len()], self.act.clone()),
                        )
                    })
                    .collect(),
            );
        }
        for e in 0..n {
            self.wave[e] += 1;
            self.sub.add(e, &state_key(e, self.wave[e]));
        }
    }
}

impl Drop for WaveRig {
    fn drop(&mut self) {
        // Whatever an env thread is doing, its next blocking point is
        // the action key of the trainer's per-env wave index: a Flag
        // there is the stop sentinel.
        for e in 0..self.wave.len() {
            self.trainer.put_flag(&action_key(e, self.wave[e]), true);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.orch.clear();
    }
}

/// One block thread (batched mode): publish every env's state as ONE
/// `put_many` per wave, then drain the block's actions through batched
/// takes.  A Flag anywhere is the rig's stop sentinel — finish the
/// drain, then exit.
fn block_loop(t: Arc<dyn Transport>, envs: Vec<usize>, floats: Vec<usize>) -> Result<()> {
    let states: Vec<Vec<f32>> = floats.iter().map(|&f| vec![1.0f32; f.max(1)]).collect();
    for w in 0.. {
        t.put_many(
            envs.iter()
                .zip(&states)
                .map(|(&e, s)| (state_key(e, w), Value::tensor(vec![s.len()], s.clone())))
                .collect(),
        )?;
        let keys: Vec<String> = envs.iter().map(|&e| action_key(e, w)).collect();
        let mut taken = vec![false; envs.len()];
        let mut missing = envs.len();
        let mut stop = false;
        while missing > 0 {
            let mut map: Vec<usize> = Vec::with_capacity(missing);
            let mut refs: Vec<&str> = Vec::with_capacity(missing);
            for (i, k) in keys.iter().enumerate() {
                if !taken[i] {
                    map.push(i);
                    refs.push(k.as_str());
                }
            }
            let got = t.take_many(&refs, WAVE_TIMEOUT)?;
            if got.is_empty() {
                return Ok(()); // wedge bound hit: the rig is going away
            }
            for (ri, v) in got {
                if matches!(v, Value::Flag(_)) {
                    stop = true;
                }
                taken[map[ri]] = true;
                missing -= 1;
            }
        }
        if stop {
            return Ok(());
        }
    }
    Ok(())
}

/// One env thread: publish the wave's state, block for the action (a
/// Flag instead of a tensor is the rig's stop sentinel), repeat.
fn env_loop(t: Arc<dyn Transport>, e: usize, floats: usize) -> Result<()> {
    let state = vec![1.0f32; floats.max(1)];
    for w in 0.. {
        t.put(
            &state_key(e, w),
            Value::tensor(vec![state.len()], state.clone()),
        )?;
        match t.wait(&action_key(e, w), WAVE_TIMEOUT, true)? {
            Some(Value::Flag(_)) | None => return Ok(()),
            Some(_) => {}
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_rig_completes_waves_and_stops_cleanly() {
        let mut rig = WaveRig::start("inproc", &[64, 64, 64], 8).unwrap();
        assert_eq!(rig.n_envs(), 3);
        for _ in 0..3 {
            rig.run_wave();
        }
        assert_eq!(rig.wave, vec![3, 3, 3]);
        drop(rig); // must not hang
    }

    #[test]
    fn tcp_rig_exchanges_real_frames() {
        let mut rig = WaveRig::start("tcp", &[32, 32], 4).unwrap();
        rig.run_wave();
        rig.run_wave();
        assert_eq!(rig.wave, vec![2, 2]);
    }

    #[test]
    fn batched_tcp_rig_coalesces_waves_and_stops_cleanly() {
        // 4 envs in 2 blocks: each wave must move through the batched
        // PutMany/TakeMany path and count batched keys on the server.
        let mut rig = WaveRig::start_batched("tcp", &[32, 32, 32, 32], 4, 2).unwrap();
        for _ in 0..3 {
            rig.run_wave();
        }
        assert_eq!(rig.wave, vec![3, 3, 3, 3]);
        let stats = rig.orch.store().stats();
        assert!(
            stats.batched_keys > 0,
            "batched rig must use the batched ops"
        );
        drop(rig); // must not hang
    }

    #[test]
    fn batched_inproc_rig_matches_wave_count() {
        let mut rig = WaveRig::start_batched("inproc", &[16, 16, 16], 4, 2).unwrap();
        rig.run_wave();
        rig.run_wave();
        assert_eq!(rig.wave, vec![2, 2, 2]);
    }
}
