//! The in-memory datastore backing the orchestrator.
//!
//! Two backends mirror the paper's observation (§3.1) that swapping Redis
//! for its multithreaded fork KeyDB "provided significantly more
//! performance":
//!
//! * [`ShardedStore`] — N independently locked shards (KeyDB analogue):
//!   concurrent clients hitting different keys proceed in parallel.
//! * a 1-shard store — every operation serializes on one lock, the
//!   single-threaded-Redis analogue.
//!
//! `bench_db` regenerates the comparison (experiment A1 in DESIGN.md §6).

use super::value::Value;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Operation counters (throughput metrics for the §Perf pass).
#[derive(Debug, Default)]
pub struct StoreStats {
    pub puts: AtomicU64,
    pub gets: AtomicU64,
    pub hits: AtomicU64,
    pub poll_misses: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
}

/// Snapshot of the counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub puts: u64,
    pub gets: u64,
    pub hits: u64,
    pub poll_misses: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

struct Shard {
    map: Mutex<HashMap<String, Value>>,
    cv: Condvar,
}

/// Sharded in-memory key-value store.
pub struct ShardedStore {
    shards: Vec<Shard>,
    stats: StoreStats,
}

fn fnv1a(key: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in key.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl ShardedStore {
    /// Create a store with `shards` independent locks (1 = Redis-like).
    pub fn new(shards: usize) -> ShardedStore {
        assert!(shards >= 1);
        ShardedStore {
            shards: (0..shards)
                .map(|_| Shard {
                    map: Mutex::new(HashMap::new()),
                    cv: Condvar::new(),
                })
                .collect(),
            stats: StoreStats::default(),
        }
    }

    fn shard(&self, key: &str) -> &Shard {
        let i = (fnv1a(key) as usize) % self.shards.len();
        &self.shards[i]
    }

    /// Number of shards (1 = single-lock backend).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Store a value under a key (overwrites), waking pollers.
    pub fn put(&self, key: &str, value: Value) {
        self.stats.puts.fetch_add(1, Ordering::Relaxed);
        self.stats
            .bytes_in
            .fetch_add(value.size_bytes() as u64, Ordering::Relaxed);
        let shard = self.shard(key);
        let mut map = shard.map.lock().unwrap();
        map.insert(key.to_string(), value);
        shard.cv.notify_all();
    }

    /// Fetch a clone of the value, if present.
    pub fn get(&self, key: &str) -> Option<Value> {
        self.stats.gets.fetch_add(1, Ordering::Relaxed);
        let shard = self.shard(key);
        let map = shard.map.lock().unwrap();
        let v = map.get(key).cloned();
        if let Some(ref val) = v {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            self.stats
                .bytes_out
                .fetch_add(val.size_bytes() as u64, Ordering::Relaxed);
        }
        v
    }

    /// Atomically fetch and remove (consume a message).
    pub fn take(&self, key: &str) -> Option<Value> {
        self.stats.gets.fetch_add(1, Ordering::Relaxed);
        let shard = self.shard(key);
        let mut map = shard.map.lock().unwrap();
        let v = map.remove(key);
        if let Some(ref val) = v {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            self.stats
                .bytes_out
                .fetch_add(val.size_bytes() as u64, Ordering::Relaxed);
        }
        v
    }

    /// Does the key exist?
    pub fn exists(&self, key: &str) -> bool {
        self.shard(key).map.lock().unwrap().contains_key(key)
    }

    /// Remove a key; true if it existed.
    pub fn delete(&self, key: &str) -> bool {
        self.shard(key).map.lock().unwrap().remove(key).is_some()
    }

    /// Remove everything (between training iterations).
    pub fn clear(&self) {
        for s in &self.shards {
            s.map.lock().unwrap().clear();
        }
    }

    /// Total number of stored keys.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.map.lock().unwrap().len()).sum()
    }

    /// True if no keys are stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking poll: wait until `key` appears (condvar-backed, the
    /// SmartRedis `poll_tensor` analogue) or `timeout` elapses.
    pub fn wait_for(&self, key: &str, timeout: Duration) -> Option<Value> {
        let deadline = Instant::now() + timeout;
        let shard = self.shard(key);
        let mut map = shard.map.lock().unwrap();
        loop {
            if let Some(v) = map.get(key) {
                self.stats.gets.fetch_add(1, Ordering::Relaxed);
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                self.stats
                    .bytes_out
                    .fetch_add(v.size_bytes() as u64, Ordering::Relaxed);
                return Some(v.clone());
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            self.stats.poll_misses.fetch_add(1, Ordering::Relaxed);
            let (m, res) = shard.cv.wait_timeout(map, deadline - now).unwrap();
            map = m;
            if res.timed_out() && !map.contains_key(key) {
                return None;
            }
        }
    }

    /// Blocking poll-and-take: wait until `key` appears, then consume it.
    pub fn wait_take(&self, key: &str, timeout: Duration) -> Option<Value> {
        let deadline = Instant::now() + timeout;
        let shard = self.shard(key);
        let mut map = shard.map.lock().unwrap();
        loop {
            if let Some(v) = map.remove(key) {
                self.stats.gets.fetch_add(1, Ordering::Relaxed);
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                self.stats
                    .bytes_out
                    .fetch_add(v.size_bytes() as u64, Ordering::Relaxed);
                return Some(v);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            self.stats.poll_misses.fetch_add(1, Ordering::Relaxed);
            let (m, res) = shard.cv.wait_timeout(map, deadline - now).unwrap();
            map = m;
            if res.timed_out() && !map.contains_key(key) {
                return None;
            }
        }
    }

    /// Snapshot the op counters.
    pub fn stats(&self) -> StatsSnapshot {
        StatsSnapshot {
            puts: self.stats.puts.load(Ordering::Relaxed),
            gets: self.stats.gets.load(Ordering::Relaxed),
            hits: self.stats.hits.load(Ordering::Relaxed),
            poll_misses: self.stats.poll_misses.load(Ordering::Relaxed),
            bytes_in: self.stats.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.stats.bytes_out.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn put_get_take() {
        let s = ShardedStore::new(4);
        s.put("a", Value::Scalar(1.5));
        assert_eq!(s.get("a"), Some(Value::Scalar(1.5)));
        assert_eq!(s.take("a"), Some(Value::Scalar(1.5)));
        assert_eq!(s.get("a"), None);
        assert!(s.is_empty());
    }

    #[test]
    fn overwrite_and_delete() {
        let s = ShardedStore::new(2);
        s.put("k", Value::Flag(false));
        s.put("k", Value::Flag(true));
        assert_eq!(s.get("k").unwrap().as_flag(), Some(true));
        assert!(s.delete("k"));
        assert!(!s.delete("k"));
    }

    #[test]
    fn wait_for_times_out() {
        let s = ShardedStore::new(1);
        let t0 = Instant::now();
        assert!(s.wait_for("nope", Duration::from_millis(30)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn wait_for_sees_concurrent_put() {
        let s = Arc::new(ShardedStore::new(4));
        let s2 = s.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            s2.put("late", Value::Scalar(7.0));
        });
        let v = s.wait_for("late", Duration::from_secs(2));
        h.join().unwrap();
        assert_eq!(v, Some(Value::Scalar(7.0)));
    }

    #[test]
    fn wait_take_consumes() {
        let s = Arc::new(ShardedStore::new(4));
        let s2 = s.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            s2.put("x", Value::Scalar(1.0));
        });
        assert!(s.wait_take("x", Duration::from_secs(2)).is_some());
        h.join().unwrap();
        assert!(!s.exists("x"));
    }

    #[test]
    fn concurrent_clients_consistent() {
        let s = Arc::new(ShardedStore::new(8));
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    s.put(&format!("t{t}:k{i}"), Value::Scalar(i as f64));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 800);
        let st = s.stats();
        assert_eq!(st.puts, 800);
        for t in 0..8 {
            for i in (0..100).step_by(17) {
                assert_eq!(
                    s.get(&format!("t{t}:k{i}")).unwrap().as_scalar(),
                    Some(i as f64)
                );
            }
        }
    }

    #[test]
    fn stats_track_bytes() {
        let s = ShardedStore::new(2);
        s.put("t", Value::tensor(vec![8], vec![0.0; 8]));
        s.get("t");
        let st = s.stats();
        assert_eq!(st.bytes_in, 8 + 32);
        assert_eq!(st.bytes_out, 8 + 32);
        assert_eq!(st.hits, 1);
    }
}
